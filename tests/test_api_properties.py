"""Property test: random :class:`ClusterConfig`\\ s never half-build.

For any randomly drawn config — tier subsets and capacities, invoker
counts, journal homes, fault schedules, block-store geometry —
``MarvelClient(config)`` must either (a) come up as a *working* stack
(state tier serves a put/get, the gateway serves a stateful invocation,
a dataset job runs end to end) and tear down leaving no threads behind,
or (b) raise a typed :class:`ConfigError` with nothing leaked.  Any other
exception, or a leaked invoker/flusher thread, is a bug.

Runs under real hypothesis when installed, else the deterministic
fallback sampler (tests/hypothesis_compat.py).
"""

import threading

from hypothesis_compat import given, settings, st

from repro.api import (
    ClusterConfig,
    ConfigError,
    FaultSpec,
    MarvelClient,
    TierSpec,
)
from repro.core.stateful import StatefulFunction

#: kinds in stack order; subsets are drawn as bitmasks over this list.
_KINDS = ("dram", "pmem", "ssd", "s3")

_counter = [0]


def _config_from_draw(
    tier_mask: int,
    cap_exp: int,
    invokers: int,
    warm_pool: int,
    journal_pick: int,
    nodes: int,
    replication: int,
    fault_pick: int,
) -> ClusterConfig:
    """Deterministically decode a drawn tuple into a ClusterConfig —
    deliberately able to produce invalid configs (empty tier lists,
    replication > nodes, capacity on the home level …)."""
    kinds = [k for i, k in enumerate(_KINDS) if tier_mask & (1 << i)]
    tiers = []
    for i, kind in enumerate(kinds):
        cap = None
        if i < len(kinds) - 1 and cap_exp:
            cap = 1 << (10 + cap_exp)
        elif i == len(kinds) - 1 and cap_exp == 7:
            cap = 1 << 16  # invalid: bounded home level
        tiers.append(TierSpec(kind, capacity_bytes=cap))
    journal = ("volatile", "none", "pmem")[journal_pick % 3]
    faults = None
    if fault_pick == 1:
        faults = FaultSpec(seed=fault_pick, spike_rate=0.01,
                           spike_seconds=0.0, schedule=(("get", 3),))
    elif fault_pick == 2:
        faults = FaultSpec(put_error_rate=1.5)  # invalid rate
    _counter[0] += 1
    return ClusterConfig(
        name=f"prop{_counter[0]:04d}",
        tiers=tuple(tiers),
        invokers=invokers,
        warm_pool=warm_pool,
        journal=journal,
        journal_path=None,  # journal="pmem" without a path must be caught
        nodes=nodes,
        block_size=1 << 12,
        replication=replication,
        faults=faults,
    )


def _exercise(client: MarvelClient) -> None:
    """A built client must actually work: tier I/O, a gateway
    invocation, and a tiny dataset job."""
    client.state.put("probe/k", b"v")
    assert client.state.get("probe/k") == b"v"
    client.register(StatefulFunction(
        "bump", lambda s: ({"n": s["n"] + 1}, s["n"] + 1),
        init=lambda: {"n": 0}, jit=False,
    ))
    sess = client.session("p")
    assert sess.invoke("bump") == 1
    assert sess.invoke("bump") == 2
    out = (
        client.dataset([b"a b a"], name="p")
        .map(lambda rec: [(w, 1) for w in rec.split()])
        .shuffle(partitions=2)
        .reduce(lambda k, vs: [(k, sum(vs))])
        .collect()
    )
    assert sorted(out) == sorted([b"b'a'\t2", b"b'b'\t1"])


@settings(max_examples=20, deadline=None)
@given(
    st.integers(min_value=0, max_value=15),   # tier subset bitmask
    st.integers(min_value=0, max_value=7),    # capacity shape
    st.integers(min_value=0, max_value=4),    # invokers (0 invalid)
    st.integers(min_value=0, max_value=8),    # warm_pool (0 invalid)
    st.integers(min_value=0, max_value=2),    # journal pick
    st.integers(min_value=1, max_value=4),    # nodes
    st.integers(min_value=1, max_value=5),    # replication (may exceed nodes)
    st.integers(min_value=0, max_value=2),    # fault pick (2 invalid)
)
def test_random_configs_build_or_raise_typed(
    tier_mask, cap_exp, invokers, warm_pool, journal_pick, nodes,
    replication, fault_pick,
):
    cfg = _config_from_draw(
        tier_mask, cap_exp, invokers, warm_pool, journal_pick, nodes,
        replication, fault_pick,
    )
    before = {t for t in threading.enumerate()}
    try:
        client = MarvelClient(cfg)
    except ConfigError:
        # the typed failure path: nothing may have leaked
        leaked = [
            t for t in threading.enumerate()
            if t not in before and t.is_alive()
            and t.name.startswith((cfg.name, f"{cfg.name}-"))
        ]
        assert not leaked, f"half-built cluster leaked {leaked}"
        return
    try:
        _exercise(client)
    finally:
        client.close()
    leaked = [
        t for t in threading.enumerate()
        if t not in before and t.is_alive()
        and t.name.startswith((cfg.name, f"{cfg.name}-"))
    ]
    assert not leaked, f"close() leaked {leaked}"


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=1, max_value=3),
       st.integers(min_value=0, max_value=1000))
def test_scheduled_faults_surface_as_io_errors_not_corruption(
    invokers, seed,
):
    """A client with an aggressive fault schedule on the home tier still
    constructs; injected faults surface as IOErrors on the faulting op
    (or are absorbed by the fast level), never as wrong bytes."""
    cfg = ClusterConfig(
        name=f"pfault{seed}",
        tiers=(TierSpec("dram", capacity_bytes=1 << 20), "s3"),
        invokers=invokers,
        faults=FaultSpec(seed=seed, get_error_rate=0.5, spike_seconds=0.0),
    )
    with MarvelClient(cfg) as client:
        for i in range(5):
            client.state.put(f"k{i}", bytes([i]))
        for i in range(5):
            # Fast-level hits never touch the faulty home level.
            assert client.state.get(f"k{i}") == bytes([i])
