"""Model layers + per-arch smoke + decode/forward equivalence."""

import math
from dataclasses import replace

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models import (
    ShapeConfig,
    decode_step,
    forward,
    init_params,
    logits_fn,
    model_defs,
    reduced_for_smoke,
)
from repro.models.layers import (
    apply_rope,
    chunked_attention,
    chunked_ce_loss,
    decode_attention,
    rms_norm,
)

SMOKE_SHAPE = ShapeConfig(
    name="smoke", kind="train", seq_len=32, global_batch=2,
    q_chunk=16, kv_chunk=16, loss_chunk=16, remat="none",
)

_f32 = lambda t: jax.tree_util.tree_map(
    lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x, t
)


def _np_attn(q, k, v, causal=True, window=None, cap=None, scale=None):
    B, Tq, H, Dh = q.shape
    Tk, Kv = k.shape[1], k.shape[2]
    rep = H // Kv
    scale = scale or 1.0 / math.sqrt(Dh)
    kk = np.repeat(np.asarray(k, np.float32), rep, axis=2)
    vv = np.repeat(np.asarray(v, np.float32), rep, axis=2)
    s = np.einsum("bqhd,bkhd->bhqk", np.asarray(q, np.float32), kk) * scale
    if cap:
        s = cap * np.tanh(s / cap)
    qp = np.arange(Tq)[:, None]
    kp = np.arange(Tk)[None, :]
    mask = np.ones((Tq, Tk), bool)
    if causal:
        mask &= qp >= kp
    if window:
        mask &= qp - kp < window
    s = np.where(mask, s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    return np.einsum("bhqk,bkhd->bqhd", p, vv)


# -- layer oracles ---------------------------------------------------------

@pytest.mark.parametrize("causal,window,cap", [
    (True, None, None), (True, 64, None), (False, None, None),
    (True, None, 30.0),
])
def test_chunked_attention_oracle(rng, causal, window, cap):
    B, T, H, Kv, D = 2, 200, 8, 2, 32
    q = rng.standard_normal((B, T, H, D)).astype(np.float32)
    k = rng.standard_normal((B, T, Kv, D)).astype(np.float32)
    v = rng.standard_normal((B, T, Kv, D)).astype(np.float32)
    got = chunked_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        causal=causal, window=window, attn_softcap=cap,
        q_chunk=64, kv_chunk=48,
    )
    want = _np_attn(q, k, v, causal, window, cap)
    np.testing.assert_allclose(np.asarray(got), want, atol=2e-5, rtol=2e-5)


def test_decode_attention_is_last_row_of_full(rng):
    B, S, H, Kv, D = 2, 96, 8, 2, 32
    kc = rng.standard_normal((B, S, Kv, D)).astype(np.float32)
    vc = rng.standard_normal((B, S, Kv, D)).astype(np.float32)
    qd = rng.standard_normal((B, H, D)).astype(np.float32)
    L = np.array([50, 96], np.int32)
    got = decode_attention(
        jnp.asarray(qd), jnp.asarray(kc), jnp.asarray(vc), jnp.asarray(L)
    )
    for b in range(B):
        w = _np_attn(
            qd[b].reshape(1, 1, H, D), kc[b : b + 1, : L[b]],
            vc[b : b + 1, : L[b]], causal=False,
        )
        np.testing.assert_allclose(np.asarray(got[b]), w[0, 0], atol=2e-5,
                                   rtol=2e-5)


def test_rope_relative_property(rng):
    x = rng.standard_normal((1, 5, 1, 16)).astype(np.float32)
    pos = jnp.arange(5)[None]
    r1 = apply_rope(jnp.asarray(x), pos)
    r2 = apply_rope(jnp.asarray(x), pos + 7)
    s1 = np.einsum("bthd,bshd->ts", np.asarray(r1), np.asarray(r1))
    s2 = np.einsum("bthd,bshd->ts", np.asarray(r2), np.asarray(r2))
    np.testing.assert_allclose(s1, s2, atol=1e-4)


def test_partial_rope_passthrough(rng):
    x = rng.standard_normal((1, 4, 2, 16)).astype(np.float32)
    out = apply_rope(jnp.asarray(x), jnp.arange(4)[None], dh_rot=8)
    np.testing.assert_array_equal(np.asarray(out)[..., 8:], x[..., 8:])


def test_chunked_ce_loss_oracle(rng):
    B, T, D, V = 2, 37, 16, 50
    x = rng.standard_normal((B, T, D)).astype(np.float32)
    U = rng.standard_normal((D, V)).astype(np.float32) * 0.1
    lbl = rng.integers(0, V, (B, T)).astype(np.int32)
    lbl[0, :5] = -100
    loss, n = chunked_ce_loss(
        jnp.asarray(x), jnp.asarray(U), jnp.asarray(lbl), t_chunk=16
    )
    logits = x @ U
    lse = np.log(np.exp(logits - logits.max(-1, keepdims=True)).sum(-1)) + \
        logits.max(-1)
    ll = np.take_along_axis(logits, np.maximum(lbl, 0)[..., None], -1)[..., 0]
    valid = lbl >= 0
    np.testing.assert_allclose(
        float(loss), ((lse - ll) * valid).sum() / valid.sum(), rtol=1e-5
    )
    assert int(n) == valid.sum()


def test_rms_norm_scale_invariance(rng):
    x = rng.standard_normal((2, 8)).astype(np.float32)
    y1 = rms_norm(jnp.asarray(x), jnp.ones(8))
    y2 = rms_norm(jnp.asarray(x * 100.0), jnp.ones(8))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


# -- per-arch smoke (reduced config, REAL forward + train grad) -------------

def _inputs_for(cfg, key, B, T):
    if cfg.frontend == "tokens":
        return {"tokens": jax.random.randint(key, (B, T), 0, cfg.vocab)}
    if cfg.frontend == "frames":
        return {"frames": jax.random.normal(key, (B, T, cfg.frame_dim),
                                            jnp.bfloat16)}
    return {
        "tokens": jax.random.randint(key, (B, T - cfg.n_patches), 0,
                                     cfg.vocab),
        "patches": jax.random.normal(key, (B, cfg.n_patches, cfg.d_model),
                                     jnp.bfloat16),
    }


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_grad(arch):
    """One forward + one grad step per assigned architecture (reduced)."""
    cfg = reduced_for_smoke(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = _f32(init_params(model_defs(cfg), key))
    B, T = 2, 32
    inputs = _inputs_for(cfg, key, B, T)
    h, aux = forward(params, cfg, inputs, SMOKE_SHAPE)
    assert h.shape == (B, T, cfg.d_model)
    logits = logits_fn(params, cfg, h)
    assert not bool(jnp.isnan(logits).any())
    labels = jax.random.randint(key, (B, T), 0, cfg.vocab)

    def loss_fn(p):
        hh, a = forward(p, cfg, inputs, SMOKE_SHAPE)
        loss, _ = chunked_ce_loss(hh, p["unembed"], labels, t_chunk=16)
        return loss + 0.01 * a

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    gnorm = sum(
        float(jnp.sum(jnp.square(g.astype(jnp.float32))))
        for g in jax.tree_util.tree_leaves(grads)
    )
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", [
    "qwen2.5-3b", "gemma2-9b", "deepseek-v2-lite-16b", "mamba2-2.7b",
    "recurrentgemma-9b", "gemma-2b",
])
def test_arch_decode_matches_forward(arch):
    """Token-by-token decode reproduces teacher-forced logits."""
    cfg = reduced_for_smoke(get_config(arch))
    if cfg.moe:
        cfg = replace(cfg, moe=replace(cfg.moe, capacity_factor=8.0))
    key = jax.random.PRNGKey(1)
    params = _f32(init_params(model_defs(cfg), key))
    B, T = 2, 24
    shape = replace(SMOKE_SHAPE, q_chunk=8, kv_chunk=8)
    toks = jax.random.randint(key, (B, T), 0, cfg.vocab)
    h, _ = forward(params, cfg, {"tokens": toks}, shape)
    full_logits = np.asarray(logits_fn(params, cfg, h))
    from repro.models import init_cache

    cache = _f32(init_cache(cfg, B, T, jnp.float32))
    step = jax.jit(lambda p, tok, c, t: decode_step(p, cfg, tok, c, t))
    errs = []
    for t in range(T):
        lg, cache = step(params, toks[:, t : t + 1], cache, jnp.int32(t))
        errs.append(np.abs(np.asarray(lg) - full_logits[:, t]).max())
    assert max(errs) < 2e-2, f"{arch}: max decode divergence {max(errs)}"


def test_prefill_cache_matches_decode_path():
    """prefill(T) then decode == decode from scratch for T+k tokens."""
    cfg = reduced_for_smoke(get_config("gemma2-9b"))
    key = jax.random.PRNGKey(2)
    params = _f32(init_params(model_defs(cfg), key))
    B, Tp, Tg = 2, 16, 4
    total = Tp + Tg
    shape = replace(SMOKE_SHAPE, q_chunk=8, kv_chunk=8)
    toks = jax.random.randint(key, (B, total), 0, cfg.vocab)
    # path A: full decode from scratch
    from repro.models import init_cache

    cache = _f32(init_cache(cfg, B, total, jnp.float32))
    step = jax.jit(lambda p, tok, c, t: decode_step(p, cfg, tok, c, t))
    logits_a = None
    for t in range(total):
        logits_a, cache = step(params, toks[:, t : t + 1], cache,
                               jnp.int32(t))
    # path B: prefill Tp, decode the rest
    h, _aux, cache_b = forward(
        params, cfg, {"tokens": toks[:, :Tp]}, shape,
        collect_cache=True, cache_len=total,
    )
    cache_b = _f32(cache_b)
    logits_b = logits_fn(params, cfg, h[:, -1])
    for i in range(Tg):
        logits_b, cache_b = step(
            params, toks[:, Tp + i : Tp + i + 1], cache_b,
            jnp.int32(Tp + i),
        )
    np.testing.assert_allclose(
        np.asarray(logits_a), np.asarray(logits_b), atol=2e-2, rtol=1e-2
    )


def test_param_counts_match_public_sizes():
    """approx_params within ~25% of each model's nominal size."""
    expected = {
        "deepseek-v2-lite-16b": 15.7e9,
        "dbrx-132b": 132e9,
        "mamba2-2.7b": 2.7e9,
        "qwen2.5-3b": 3.1e9,
        "gemma-2b": 2.5e9,
        "gemma2-9b": 9.2e9,
        "qwen1.5-32b": 32e9,
        "recurrentgemma-9b": 9e9,
    }
    for arch, want in expected.items():
        got = get_config(arch).approx_params()
        assert abs(got - want) / want < 0.30, (arch, got, want)


def test_moe_dense_path_routes_topk(rng):
    from repro.models.moe import moe_apply_dense

    cfg = reduced_for_smoke(get_config("dbrx-132b"))
    cfg = replace(cfg, moe=replace(cfg.moe, capacity_factor=8.0))
    from repro.models.transformer import _ffn_defs
    from repro.models.config import BlockSpec

    defs = _ffn_defs(BlockSpec(ffn="moe"), cfg)
    params = _f32(init_params(defs, jax.random.PRNGKey(0)))
    x = jnp.asarray(rng.standard_normal((2, 8, cfg.d_model)).astype(np.float32))
    out, aux = moe_apply_dense(params, x, cfg)
    assert out.shape == x.shape
    assert float(aux) > 0  # load-balance loss computed
    assert not bool(jnp.isnan(out).any())


def test_int8_kv_cache_decode_close_to_bf16():
    """int8 KV cache (quant_cache.py): small logit perturbation, same
    argmax path on a tiny model — the compression tier for decode state."""
    cfg = reduced_for_smoke(get_config("qwen1.5-32b"))
    key = jax.random.PRNGKey(0)
    params = _f32(init_params(model_defs(cfg), key))
    from repro.models import init_cache

    B, T = 2, 12
    toks = jax.random.randint(key, (B, T), 0, cfg.vocab)
    c_ref = _f32(init_cache(cfg, B, T, jnp.float32))
    c_q = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x,
        init_cache(cfg, B, T, jnp.float32, quant_attn=True),
    )
    errs, agree = [], 0
    for t in range(T):
        l1, c_ref = decode_step(params, cfg, toks[:, t : t + 1], c_ref,
                                jnp.int32(t))
        l2, c_q = decode_step(params, cfg, toks[:, t : t + 1], c_q,
                              jnp.int32(t))
        errs.append(np.abs(np.asarray(l1) - np.asarray(l2)).max())
        agree += int(
            np.array_equal(np.argmax(l1, -1), np.argmax(l2, -1))
        )
    assert max(errs) < 0.5, max(errs)  # bounded quantization noise
    assert agree >= T - 2  # argmax path essentially unchanged


def test_quant_cache_roundtrip_accuracy(rng):
    from repro.models.quant_cache import quantize_kv

    x = jnp.asarray(rng.standard_normal((2, 7, 3, 32)).astype(np.float32))
    q, s = quantize_kv(x)
    deq = q.astype(np.float32) * np.asarray(s, np.float32)[..., None]
    rel = np.abs(deq - np.asarray(x)).max() / np.abs(np.asarray(x)).max()
    assert rel < 0.01  # 1/127 per-head relative error bound
