"""Property tests for the int8 KV-cache quantizer behind Marvel-Serve.

The pager's compressed demotion path (DESIGN.md §14) rides on
``quantize_kv`` / ``quant_decode_attention``; these properties pin down
the contract the pager assumes: round-trip error bounded by half a
quantization step, all-zero rows survive the 1e-8 scale floor without
NaN/Inf anywhere downstream, and single-token attention over the int8
cache matches the float reference within int8 tolerance on random
shapes.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.models.layers import decode_attention
from repro.models.quant_cache import (
    QuantAttnCache,
    quant_decode_attention,
    quantize_kv,
)


def _rand(key, shape, scale=1.0):
    return jax.random.normal(key, shape, jnp.float32) * scale


# -- round-trip error bound ---------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    st.integers(0, 2**31 - 1),
    st.integers(1, 3),   # B
    st.integers(1, 9),   # S
    st.integers(1, 4),   # Kv
    st.integers(1, 32),  # dh
)
def test_quantize_roundtrip_error_bounded(seed, B, S, Kv, dh):
    key = jax.random.PRNGKey(seed)
    # Mix magnitudes across rows so scales differ by orders of magnitude.
    mag = jnp.exp(_rand(jax.random.fold_in(key, 1), (B, S, Kv, 1), 2.0))
    x = _rand(key, (B, S, Kv, dh)) * mag
    q, s = quantize_kv(x)
    assert q.dtype == jnp.int8 and s.dtype == jnp.bfloat16
    deq = q.astype(jnp.float32) * s.astype(jnp.float32)[..., None]
    # Per row: |x - deq| <= scale/2 (rounding) + |q|*scale*2^-8 (the
    # bf16 cast of the scale carries ~8 mantissa bits of relative error).
    step = np.asarray(s, np.float32)[..., None]
    err = np.abs(np.asarray(x) - np.asarray(deq))
    bound = step * 0.5 + np.abs(np.asarray(q, np.float32)) * step * 2.0**-8
    assert np.all(err <= bound + 1e-7)


def test_quantize_zero_rows_floor():
    """All-zero rows hit the 1e-8 scale floor: q == 0, dequant exactly 0."""
    x = jnp.zeros((2, 4, 2, 8), jnp.float32)
    q, s = quantize_kv(x)
    assert np.all(np.asarray(q) == 0)
    assert np.all(np.asarray(s, np.float32) > 0)  # floored, never 0
    deq = q.astype(jnp.float32) * s.astype(jnp.float32)[..., None]
    assert np.all(np.asarray(deq) == 0.0)


def test_zero_cache_attention_finite():
    """Attention over an all-zero quantized cache is finite (no 0/0)."""
    B, S, Kv, dh, H = 2, 6, 2, 8, 4
    k_q, k_s = quantize_kv(jnp.zeros((B, S, Kv, dh)))
    v_q, v_s = quantize_kv(jnp.zeros((B, S, Kv, dh)))
    cache = QuantAttnCache(k_q=k_q, v_q=v_q, k_s=k_s, v_s=v_s)
    q = _rand(jax.random.PRNGKey(7), (B, H, dh))
    length = jnp.array([1, S], jnp.int32)
    o = quant_decode_attention(q, cache, length)
    assert np.all(np.isfinite(np.asarray(o, np.float32)))


# -- parity vs the float path -------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    st.integers(0, 2**31 - 1),
    st.integers(1, 3),                # B
    st.integers(2, 12),               # S
    st.sampled_from([(2, 2), (4, 2), (4, 4)]),  # (H, Kv)
    st.sampled_from([8, 16]),         # dh
    st.sampled_from([None, 30.0]),    # softcap
)
def test_quant_attention_parity(seed, B, S, heads, dh, softcap):
    H, Kv = heads
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    k = _rand(ks[0], (B, S, Kv, dh))
    v = _rand(ks[1], (B, S, Kv, dh))
    q = _rand(ks[2], (B, H, dh))
    length = jax.random.randint(ks[3], (B,), 1, S + 1)
    k_q, k_s = quantize_kv(k)
    v_q, v_s = quantize_kv(v)
    cache = QuantAttnCache(k_q=k_q, v_q=v_q, k_s=k_s, v_s=v_s)
    got = quant_decode_attention(q, cache, length, attn_softcap=softcap,
                                 s_chunk=4)
    # Reference: float attention over the *dequantized* cache isolates the
    # attention math; vs the raw float cache bounds the end-to-end error.
    k_d = (k_q.astype(jnp.float32) * k_s.astype(jnp.float32)[..., None])
    v_d = (v_q.astype(jnp.float32) * v_s.astype(jnp.float32)[..., None])
    ref_deq = decode_attention(q, k_d, v_d, length, attn_softcap=softcap)
    ref_raw = decode_attention(q, k, v, length, attn_softcap=softcap)
    got32 = np.asarray(got, np.float32)
    np.testing.assert_allclose(
        got32, np.asarray(ref_deq, np.float32), atol=2e-2, rtol=0
    )
    np.testing.assert_allclose(
        got32, np.asarray(ref_raw, np.float32), atol=8e-2, rtol=0
    )
