"""Property tests: the tier hierarchy is linearizable against a plain dict.

A `TieredStore` — whatever promotion/demotion/write-back/crash schedule it
goes through — must be observationally equivalent to a dict: `get` returns
the last acknowledged `put`, `delete` removes, a crash+recover cycle with
a durable journal and persistent home level loses **nothing** that was
acknowledged.  Runs under real hypothesis when installed, else the
deterministic fallback sampler (tests/hypothesis_compat.py).
"""

from hypothesis_compat import given, nightly_examples, settings, st

from repro.storage import (
    DramTier,
    FaultInjectingTier,
    PlacementPolicy,
    StateCache,
    TieredStore,
    TierLevel,
)


class _DurableDram(DramTier):
    """In-memory stand-in for a PMEM device: survives `crash()`."""

    name = "fakepmem"
    persistent = True


def _fresh(write_back: bool, torn_rate: float = 0.0, seed: int = 0):
    """A 3-level stack (tiny DRAM, mid, durable home) + durable journal."""
    home = _DurableDram()
    faulty = FaultInjectingTier(home, seed=seed, torn_put_many_rate=torn_rate)
    journal = StateCache(memory=_DurableDram())
    store = TieredStore(
        [
            TierLevel("dram", DramTier(), 160),
            TierLevel("mid", DramTier(), 320),
            TierLevel("home", faulty),
        ],
        policy=PlacementPolicy(
            write_back=write_back, promote_after=2, flush_interval=0.002
        ),
        journal=journal,
        name="prop",
    )
    return store, faulty


_OPS = st.lists(
    st.tuples(
        st.sampled_from(["put", "get", "delete", "demote", "flush", "crash"]),
        st.integers(0, 5),  # key index
        st.binary(min_size=0, max_size=48),
    ),
    min_size=1,
    max_size=40,
)


def _run_schedule(store, faulty, ops, write_back):
    model = {}
    for op, ki, value in ops:
        key = f"k{ki}"
        if op == "put":
            store.put(key, value)  # acked here
            model[key] = value
        elif op == "get":
            if key in model:
                assert store.get(key) == model[key]
            else:
                try:
                    store.get(key)
                    raise AssertionError(f"get({key}) should have raised")
                except KeyError:
                    pass
        elif op == "delete":
            store.delete(key)
            model.pop(key, None)
        elif op == "demote":
            store.demote(key)
            if key in model:  # placement must not change the value
                assert store.get(key) == model[key]
        elif op == "flush":
            if write_back:
                faulty.heal()
                store.flush()
                faulty.arm()
        elif op == "crash":
            # Volatile levels die; journal + home survive.  Every
            # acknowledged put must still be readable after recover.
            store.crash()
            store.recover()
    # Final audit: the store and the model agree on the whole key space.
    for key, value in model.items():
        assert store.get(key) == value
    for ki in range(6):
        key = f"k{ki}"
        assert store.contains(key) == (key in model)


@settings(max_examples=nightly_examples(25), deadline=None)
@given(_OPS)
def test_write_through_store_is_linearizable(ops):
    store, faulty = _fresh(write_back=False)
    try:
        _run_schedule(store, faulty, ops, write_back=False)
    finally:
        store.close()


@settings(max_examples=nightly_examples(25), deadline=None)
@given(_OPS)
def test_write_back_store_is_linearizable(ops):
    store, faulty = _fresh(write_back=True)
    try:
        _run_schedule(store, faulty, ops, write_back=True)
    finally:
        store.close()


@settings(max_examples=nightly_examples(20), deadline=None)
@given(_OPS, st.integers(0, 10_000))
def test_write_back_crash_never_loses_acked_put_under_torn_flushes(ops, seed):
    """Torn home flushes at every round + crash + recover: an acked put
    is either still dirty (journal replays it) or flushed (home has it)
    — never gone."""
    store, faulty = _fresh(write_back=True, torn_rate=0.7, seed=seed)
    try:
        _run_schedule(store, faulty, ops, write_back=True)
        # One more crash at the very end, then drain with the device
        # healed — the home tier must converge to the full model.
        store.crash()
        store.recover()
        faulty.heal()
        store.flush()
        assert store.dirty_keys == []
    finally:
        store.close()
