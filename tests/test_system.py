"""End-to-end system behaviour: the paper's claims, reproduced.

Marvel's evaluation (paper §4) makes four claims; each is a test here:
  1. stateful execution on a serverless substrate (state survives across
     invocations and crashes via the PMEM tier),
  2. the in-memory intermediate tier beats storage-mediated shuffles
     (Fig. 4/5 ordering: IGFS < PMEM-HDFS < SSD < S3),
  3. the Lambda/S3 baseline collapses at scale (15 GB quota),
  4. intermediate data exceeds input for shuffle-heavy jobs (Table 1).
"""

from collections import Counter

import pytest

from repro.core import Scheduler, run_job
from repro.core.mapreduce import wordcount_job
from repro.storage import (
    BlockStore,
    DataNode,
    DramTier,
    PmemTier,
    S3_SPEC,
    SimulatedTier,
    StateCache,
)
from repro.storage.tiers import PMEM_SPEC, SSD_SPEC, DeviceSpec


def _corpus(rng, n_lines=800):
    words = [f"word{i}".encode() for i in range(60)]
    lines = [b" ".join(rng.choice(words, size=8)) for _ in range(n_lines)]
    return b"\n".join(lines), Counter(w for ln in lines for w in ln.split())


def _cluster(tmp_path=None, n=4):
    tiers = [
        PmemTier(f"{tmp_path}/n{i}") if tmp_path else DramTier()
        for i in range(n)
    ]
    nodes = [DataNode(f"w{i}", t) for i, t in enumerate(tiers)]
    bs = BlockStore(nodes, block_size=2048, replication=2)
    sched = Scheduler([n.node_id for n in nodes], speculation_factor=None)
    return bs, sched


def test_claim1_stateful_execution_end_to_end(tmp_path, rng):
    """Job journal in the PMEM-backed cache: a crashed job resumes without
    recomputation, on PMEM-backed HDFS DataNodes."""
    data, oracle = _corpus(rng)
    bs, sched = _cluster(tmp_path)
    bs.write("/in", data, record_delim=b"\n")
    journal = StateCache(write_through=PmemTier(f"{tmp_path}/journal"))
    inter = DramTier()
    r1 = run_job(wordcount_job(4), bs, "/in", "/out", inter, sched,
                 journal=journal)
    journal.crash()  # node failure: DRAM tier gone
    journal.recover()  # ... but the PMEM tier has the journal
    r2 = run_job(wordcount_job(4), bs, "/in", "/out", inter, sched,
                 journal=journal)
    assert r2.resumed_tasks == r1.map_tasks + r1.reduce_tasks


def test_claim2_tier_ordering_reproduces_fig4(rng):
    data, _ = _corpus(rng)
    modeled = {}
    for name, tier in [
        ("igfs", DramTier()),
        ("pmem", SimulatedTier(PMEM_SPEC)),
        ("ssd", SimulatedTier(SSD_SPEC)),
        ("s3", SimulatedTier(S3_SPEC)),
    ]:
        bs, sched = _cluster()
        bs.write("/in", data, record_delim=b"\n")
        rep = run_job(wordcount_job(4), bs, "/in", f"/out_{name}", tier, sched)
        modeled[name] = rep.total_seconds
    assert modeled["igfs"] < modeled["ssd"] < modeled["s3"]
    assert modeled["pmem"] < modeled["ssd"]
    # headline claim: >= 86.6% reduction vs the S3 path on modeled time
    reduction = 1 - modeled["igfs"] / modeled["s3"]
    assert reduction > 0.866, f"only {reduction:.1%} reduction"


def test_claim3_s3_quota_failure(rng):
    tiny_s3 = DeviceSpec(name="s3", read_bw=90e6, write_bw=90e6,
                         read_latency=0, write_latency=0,
                         transfer_quota=1_000)
    data, _ = _corpus(rng, n_lines=200)
    bs, sched = _cluster()
    bs.write("/in", data, record_delim=b"\n")
    with pytest.raises(Exception) as ei:
        run_job(wordcount_job(2), bs, "/in", "/out", SimulatedTier(tiny_s3),
                sched)
    assert "Quota" in repr(ei.value)


def test_claim4_intermediate_blowup_table1(rng):
    """WordCount without a combiner produces intermediate > input."""
    data, _ = _corpus(rng, n_lines=400)
    bs, sched = _cluster()
    bs.write("/in", data, record_delim=b"\n")
    import repro.core.mapreduce as mr

    base = mr.wordcount_job()
    wc_nocombine = mr.MapReduceJob("wc", base.mapper, base.reducer,
                                   combiner=None, n_reducers=4)
    rep = run_job(wc_nocombine, bs, "/in", "/out", DramTier(), sched)
    assert rep.intermediate_bytes > rep.input_bytes  # Table 1 WordCount rows
    assert rep.output_bytes < rep.input_bytes


def test_full_stack_wordcount_on_pmem_cluster(tmp_path, rng):
    """Everything together: PMEM DataNodes, locality scheduling, combiner,
    journal, retries — output equals the oracle."""
    data, oracle = _corpus(rng)
    bs, sched = _cluster(tmp_path)
    sched.speculation_factor = 2.0
    bs.write("/in", data, record_delim=b"\n")
    journal = StateCache(write_through=PmemTier(f"{tmp_path}/j"))
    rep = run_job(
        wordcount_job(4), bs, "/in", "/out", DramTier(), sched,
        journal=journal, fail_map_attempts={"map_00001": 1},
    )
    got = {}
    for p in range(4):
        for line in bs.read(f"/out/part_{p:04d}").splitlines():
            k, v = line.split(b"\t")
            got[eval(k)] = eval(v)
    assert got == dict(oracle)
    assert rep.retried_tasks >= 1
