"""Property test: paged decode is observationally lossless.

Whatever schedule of {decode, evict (demote), resume, crash+recover} a
set of conversations goes through, a ``lossless=True`` pager over a
journaled tier stack must behave exactly like a never-evicted in-memory
decode: every emitted token matches the oracle's token at that position
(no lost acked steps — the store journals every block write), and the
final per-layer cache bytes are identical to the oracle's cache.  Runs
under real hypothesis when installed, else the deterministic fallback
sampler (tests/hypothesis_compat.py).
"""

import numpy as np

import jax
import jax.numpy as jnp
from hypothesis_compat import given, nightly_examples, settings, st

from repro.configs import get_config
from repro.models import init_params, model_defs, reduced_for_smoke
from repro.serving import (
    KVPager,
    PagedDecoder,
    flatten_cache,
    unflatten_cache,
)
from repro.storage import (
    DramTier,
    PlacementPolicy,
    StateCache,
    TieredStore,
    TierLevel,
)

PROMPT_LEN, MAX_TOKENS = 8, 24
_SIDS = ["s0", "s1", "s2"]

_MODEL = None


def _model():
    """Module-cached tiny model (shared across property examples)."""
    global _MODEL
    if _MODEL is None:
        cfg = reduced_for_smoke(get_config("qwen2.5-3b"))
        params = init_params(model_defs(cfg), jax.random.PRNGKey(0))
        _MODEL = (cfg, params)
    return _MODEL


class _DurableDram(DramTier):
    name = "fakepmem"
    persistent = True


def _fresh_store():
    """Capped write-back DRAM over a durable home, durable journal —
    acked puts must survive a crash at any point."""
    return TieredStore(
        [TierLevel("dram", DramTier(), 1 << 20),
         TierLevel("home", _DurableDram())],
        policy=PlacementPolicy(write_back=True, promote_after=1,
                               flush_interval=0.002),
        journal=StateCache(memory=_DurableDram()),
        name="serve-prop",
    )


class _Oracle:
    """Never-evicted reference: same jitted decode, plain in-memory
    cache."""

    def __init__(self, decoder):
        self.decoder = decoder
        self.cache = {}   # sid -> layer list
        self.state = {}   # sid -> (t, tok)
        self.tokens = {}  # sid -> [token arrays]

    def start(self, sid, layers, state, tok):
        self.cache[sid] = list(layers)
        self.state[sid] = (int(state["t"]), state["tok"])
        self.tokens[sid] = [np.asarray(tok)]

    def step(self, sid):
        t, tok = self.state[sid]
        cache = unflatten_cache(self.decoder._treedef, self.cache[sid])
        t = t + 1
        logits, new_cache = self.decoder._decode(
            self.decoder.params, tok, cache, jnp.int32(t))
        new_tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        self.cache[sid], _ = flatten_cache(new_cache)
        self.state[sid] = (t, new_tok)
        self.tokens[sid].append(np.asarray(new_tok))
        return np.asarray(new_tok)


_OPS = st.lists(
    st.tuples(
        st.sampled_from(["decode", "decode", "evict", "resume", "crash"]),
        st.integers(0, len(_SIDS) - 1),
    ),
    min_size=4,
    max_size=22,
)


@settings(max_examples=nightly_examples(3), deadline=None)
@given(st.integers(0, 2**31 - 1), _OPS)
def test_paged_decode_lossless_under_interleavings(seed, ops):
    cfg, params = _model()
    store = _fresh_store()
    try:
        pager = KVPager(store, block_tokens=4, lossless=True)
        decoder = PagedDecoder(params, cfg, pager,
                               prompt_len=PROMPT_LEN, max_tokens=MAX_TOKENS)
        oracle = _Oracle(decoder)
        states = {}  # sid -> paged function state (journaled in the
        # real system at commit_every=1; held by the test harness here)
        steps = {sid: 0 for sid in _SIDS}

        for op, si in ops:
            sid = _SIDS[si]
            if op == "decode":
                if steps[sid] >= MAX_TOKENS - 1:
                    continue  # cache ring would wrap past total_len
                if sid not in states:
                    prompt = jax.random.randint(
                        jax.random.fold_in(jax.random.PRNGKey(seed), si),
                        (1, PROMPT_LEN), 0, cfg.vocab)
                    states[sid] = decoder._init(sid, prompt)
                    layers, _t = pager.load(sid)
                    oracle.start(sid, layers, states[sid],
                                 states[sid]["tok"])
                else:
                    states[sid], tok = decoder._step(states[sid])
                    want = oracle.step(sid)
                    assert np.array_equal(np.asarray(tok), want), (
                        f"token diverged for {sid} at step {steps[sid]}")
                steps[sid] += 1
            elif op == "evict":
                if sid in states:
                    pager.demote(sid)
            elif op == "resume":
                if sid in states:
                    pager.resume(sid, prefetch=bool(si % 2))
            elif op == "crash":
                # lose the serving process and every volatile tier —
                # acked puts ride the journal; nothing was flushed
                # explicitly before the crash
                pager.crash()
                store.crash()
                store.recover()
                assert pager.recover() == len(states)

        # final byte identity: every session's paged cache equals the
        # never-evicted oracle's, leaf for leaf
        for sid in states:
            layers, t = pager.load(sid)
            assert t == oracle.state[sid][0], (
                f"{sid}: acked step lost (t={t} != {oracle.state[sid][0]})")
            for li, (got, want) in enumerate(zip(layers, oracle.cache[sid])):
                for gf, wf in zip(got, want):
                    ga, wa = np.asarray(gf), np.asarray(wf)
                    assert ga.dtype == wa.dtype
                    assert np.array_equal(ga, wa), (
                        f"{sid} layer {li}: cache bytes diverged")
    finally:
        store.close(flush=False)
