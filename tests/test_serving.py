"""Marvel-Serve unit + integration tests (DESIGN.md §14).

Covers the pager's placement transitions (create / per-step write-back /
demote / resume / drop / recover) against a hand-built tier stack, the
prefix-filtered ``keys()`` delegation fix (listing one namespace must not
touch unrelated keys' accounting or placement), and the gateway-facing
``ServingPool`` built through the :class:`~repro.api.MarvelClient`
façade — warm-pool eviction routing to demotion, KV-pressure load
snapshots, and admission shedding against the DRAM block budget.
"""

import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import (
    ClusterConfig,
    ConfigError,
    MarvelClient,
    ServingConfig,
    TierSpec,
)
from repro.configs import get_config
from repro.core.gateway import AdmissionError
from repro.models import init_params, model_defs, reduced_for_smoke
from repro.models.attention import AttnCache
from repro.models.quant_cache import QuantAttnCache
from repro.serving import KVPager
from repro.storage import (
    DramTier,
    PlacementPolicy,
    StateCache,
    TieredStore,
    TierLevel,
)


class _DurableDram(DramTier):
    """In-memory PMEM stand-in: survives `crash()`."""

    name = "fakepmem"
    persistent = True


def _store(cap=1 << 20, write_back=False):
    """Two-level stack: capped DRAM over an unbounded durable home."""
    home = _DurableDram()
    journal = StateCache(memory=_DurableDram())
    store = TieredStore(
        [TierLevel("dram", DramTier(), cap), TierLevel("pmem", home)],
        policy=PlacementPolicy(write_back=write_back, promote_after=1,
                               flush_interval=0.002),
        journal=journal,
        name="serve-test",
    )
    return store, home


def _layers(seed=0, n=2, B=1, S=8, Kv=2, dh=4):
    """A hand-built per-layer cache list (no model needed)."""
    key = jax.random.PRNGKey(seed)
    out = []
    for i in range(n):
        k1, k2, key = jax.random.split(jax.random.fold_in(key, i), 3)
        out.append(AttnCache(
            k=jax.random.normal(k1, (B, S, Kv, dh), jnp.float32),
            v=jax.random.normal(k2, (B, S, Kv, dh), jnp.float32),
        ))
    return out


def _assert_layers_equal(got, want, exact=True):
    assert len(got) == len(want)
    for g, w in zip(got, want):
        for gf, wf in zip(g, w):
            ga, wa = np.asarray(gf), np.asarray(wf)
            if exact:
                assert ga.dtype == wa.dtype
                assert np.array_equal(ga, wa)
            else:
                np.testing.assert_allclose(ga, wa, atol=5e-2)


# -- pager placement transitions ----------------------------------------


class TestKVPager:
    def test_create_write_load_roundtrip(self):
        store, _ = _store()
        pager = KVPager(store, block_tokens=4, lossless=True)
        layers = _layers()
        pager.create("s0", layers, t=3)
        got, t = pager.load("s0")
        assert t == 3
        _assert_layers_equal(got, layers)
        # per-step write-back: only the dirty block is rewritten
        before = pager.stats.blocks_written
        new_layers = _layers(seed=1)
        pager.write("s0", new_layers, t=4)
        # 2 layers x 1 dirty block each (+ meta, not counted)
        assert pager.stats.blocks_written == before + 2
        got, t = pager.load("s0")
        assert t == 4
        _assert_layers_equal(got, new_layers)

    def test_blocks_are_per_session_layer_block_keys(self):
        store, _ = _store()
        pager = KVPager(store, block_tokens=4)
        pager.create("s0", _layers(S=8), t=0)
        keys = sorted(store.keys("kv/s0/"))
        # 2 layers x (8/4 = 2 blocks) + meta
        assert keys == [
            "kv/s0/L000/B00000", "kv/s0/L000/B00001",
            "kv/s0/L001/B00000", "kv/s0/L001/B00001",
            "kv/s0/meta",
        ]
        assert all(store.level_of(k) == "dram" for k in keys)
        assert pager.session_prefix("s0") in store.pinned_prefixes

    def test_lossless_demote_resume_byte_identity(self):
        store, _ = _store()
        pager = KVPager(store, block_tokens=4, lossless=True)
        layers = _layers()
        pager.create("s0", layers, t=5)
        assert pager.demote("s0")
        # all blocks left the fast level; pin released
        for k in store.keys("kv/s0/"):
            assert store.level_of(k) == "pmem"
        assert pager.session_prefix("s0") not in store.pinned_prefixes
        assert not pager.is_hot("s0")
        got, t = pager.load("s0")  # demand-fault resume
        assert t == 5
        _assert_layers_equal(got, layers, exact=True)
        assert pager.stats.demand_faults == 1
        assert pager.is_hot("s0")

    def test_quantized_demote_shrinks_and_still_decodes(self):
        store, _ = _store()
        pager = KVPager(store, block_tokens=4, lossless=False)
        layers = _layers(S=8, dh=16)
        pager.create("s0", layers, t=5)
        hot_bytes = sum(store.size_of(k) for k in store.keys("kv/s0/"))
        assert pager.demote("s0")
        cold_bytes = sum(store.size_of(k) for k in store.keys("kv/s0/"))
        # int8 + bf16 scales vs float32: well under half the bytes
        assert cold_bytes < hot_bytes * 0.6
        assert pager.stats.quantized_blocks > 0
        got, _t = pager.load("s0")
        assert all(isinstance(l, QuantAttnCache) for l in got)
        # dequantized content close to the original
        for g, w in zip(got, layers):
            deq = np.asarray(g.k_q, np.float32) * np.asarray(
                g.k_s, np.float32)[..., None]
            np.testing.assert_allclose(deq, np.asarray(w.k), atol=5e-2)

    def test_double_demote_is_noop(self):
        store, _ = _store()
        pager = KVPager(store, lossless=True)
        pager.create("s0", _layers(), t=0)
        assert pager.demote("s0")
        assert not pager.demote("s0")
        assert not pager.demote("missing")
        assert pager.stats.demotions == 1

    def test_resume_prefetch_promotes_in_background(self):
        store, _ = _store()
        pager = KVPager(store, block_tokens=4, lossless=True)
        layers = _layers()
        pager.create("s0", layers, t=2)
        pager.demote("s0")
        assert pager.resume("s0", prefetch=True)
        # background promotion: poll until the worker pulls all blocks up
        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline:
            if all(store.level_of(k) == "dram"
                   for k in store.keys("kv/s0/")):
                break
            time.sleep(0.005)
        for k in store.keys("kv/s0/"):
            assert store.level_of(k) == "dram"
        # the subsequent load is a hot-path assembly, not a demand fault
        got, _ = pager.load("s0")
        _assert_layers_equal(got, layers)
        assert pager.stats.demand_faults == 0
        assert pager.stats.resumes == 1

    def test_crash_recover_adopts_sessions(self):
        store, _ = _store()
        pager = KVPager(store, block_tokens=4, lossless=True)
        layers = _layers()
        pager.create("s0", layers, t=7)
        pager.create("s1", _layers(seed=3), t=1)
        pager.sync()
        # lose the process + volatile tiers
        pager.crash()
        store.crash()
        store.recover()
        assert pager.sessions == []
        assert pager.recover() == 2
        assert sorted(pager.sessions) == ["s0", "s1"]
        assert pager.paged_sessions == 2  # adopted cold
        got, t = pager.load("s0")
        assert t == 7
        _assert_layers_equal(got, layers)

    def test_drop_removes_all_tiers(self):
        store, _ = _store()
        pager = KVPager(store, lossless=True)
        pager.create("s0", _layers(), t=0)
        pager.demote("s0")
        pager.drop("s0")
        assert list(store.keys("kv/s0/")) == []
        assert pager.sessions == []

    def test_admission_accounting(self):
        store, _ = _store()
        pager = KVPager(store, block_tokens=4, lossless=True,
                        dram_budget_bytes=None)
        assert pager.can_admit()  # no budget admits everything
        pager.create("a", _layers(), t=0)
        one = pager.dram_bytes()
        assert one > 0
        pager.create("b", _layers(seed=1), t=0)
        assert pager.dram_bytes() == 2 * one
        pager.dram_budget_bytes = int(2.5 * one)
        assert not pager.can_admit()  # a third session would not fit
        pager.demote(pager.lru_hot()[0])  # LRU victim = "a"
        assert pager.dram_bytes() == one
        assert pager.can_admit()
        assert pager.lru_hot() == ["b"]


# -- keys(prefix) delegation fix ----------------------------------------


class _LegacyTier(DramTier):
    """A tier predating the prefix parameter on ``keys()``."""

    name = "legacy"

    def keys(self):  # noqa: D102 - old signature on purpose
        return iter(list(self._data))


class TestPrefixListing:
    def test_statecache_keys_delegates_prefix(self):
        cache = StateCache(memory=DramTier())
        for i in range(4):
            cache.put(f"ns1/k{i}", b"x" * 8)
            cache.put(f"ns2/k{i}", b"y" * 8)
        tier = cache.memory
        before = (tier.stats.bytes_read, tier.stats.read_ops)
        assert sorted(cache.keys("ns1/")) == [f"ns1/k{i}" for i in range(4)]
        # listing is metadata-only: no value reads charged to the tier
        assert (tier.stats.bytes_read, tier.stats.read_ops) == before

    def test_statecache_keys_legacy_tier_fallback(self):
        cache = StateCache(memory=_LegacyTier())
        cache.put("a/1", b"x")
        cache.put("b/1", b"y")
        assert sorted(cache.keys("a/")) == ["a/1"]
        assert sorted(cache.keys()) == ["a/1", "b/1"]

    def test_tiered_keys_prefix_leaves_placement_alone(self):
        store, _ = _store()
        for i in range(4):
            store.put(f"ns1/k{i}", b"x" * 16)
            store.put(f"ns2/k{i}", b"y" * 16)
        store.demote("ns2/k0")
        placement = {k: store.level_of(k) for k in store.keys()}
        stats = {
            lv: (s.bytes_read, s.read_ops)
            for lv, s in store.stats_by_level().items()
        }
        assert sorted(store.keys("ns1/")) == [f"ns1/k{i}" for i in range(4)]
        # unrelated keys: placement, LRU recency, and read accounting
        # untouched by the namespaced listing
        assert {k: store.level_of(k) for k in store.keys()} == placement
        assert {
            lv: (s.bytes_read, s.read_ops)
            for lv, s in store.stats_by_level().items()
        } == stats

    def test_pmem_tier_prefix_walks_subtree_only(self, tmp_path):
        from repro.storage import PmemTier

        tier = PmemTier(str(tmp_path))
        tier.put("kv/s0/L000/B00000", b"a")
        tier.put("kv/s1/L000/B00000", b"b")
        tier.put("other/x", b"c")
        assert sorted(tier.keys("kv/s0/")) == ["kv/s0/L000/B00000"]
        assert sorted(tier.keys("kv/")) == [
            "kv/s0/L000/B00000", "kv/s1/L000/B00000"
        ]
        assert list(tier.keys("missing/")) == []


# -- the façade-built serving pool --------------------------------------


def _model():
    cfg = reduced_for_smoke(get_config("qwen2.5-3b"))
    params = init_params(model_defs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def _prompt(cfg, seed=0, B=1, plen=8):
    return jax.random.randint(jax.random.PRNGKey(seed), (B, plen), 0,
                              cfg.vocab)


class TestServingPool:
    def test_serving_config_validation(self):
        with pytest.raises(ConfigError):
            ServingConfig(block_tokens=0).validate()
        with pytest.raises(ConfigError):
            ServingConfig(dram_budget_bytes=-1).validate()
        ClusterConfig(serving=ServingConfig(block_tokens=8)).validate()
        with pytest.raises(ConfigError):
            ClusterConfig(serving=ServingConfig(block_tokens=0)).validate()

    def test_pool_end_to_end(self, tmp_path):
        cfg, params = _model()
        cluster = ClusterConfig(
            name="serve-test",
            tiers=(TierSpec("dram", capacity_bytes=8 << 20), "pmem"),
            invokers=2, warm_pool=3, commit_every=1,
            journal="pmem", journal_path=str(tmp_path),
            serving=ServingConfig(block_tokens=8, lossless=True),
        )
        with MarvelClient(cluster) as client:
            pool = client.serving(params, cfg, prompt_len=8, max_tokens=8)
            prompt = _prompt(cfg)
            toks = {}
            convs = [f"c{i}" for i in range(5)]
            for c in convs:
                toks[c] = [np.asarray(pool.start(c, prompt).result())]
            for c in convs:
                toks[c].append(np.asarray(pool.step(c).result()))
            # warm_pool=3 < 5 conversations: evictions routed to demotion
            assert pool.stats()["demotions"] > 0
            assert sorted(pool.conversations()) == sorted(convs)
            # KV pressure shows up in gateway load snapshots
            snap = client.gateway.load_snapshot()
            assert snap.resident_sessions + snap.paged_sessions == 5
            # suspend/resume round-trip continues the conversation
            first = np.asarray(pool.step("c0").result())
            assert pool.is_resident("c0")  # just stepped -> hot
            assert pool.suspend("c0")
            assert not pool.is_resident("c0")
            assert pool.resume("c0")
            tok = np.asarray(pool.step("c0").result())
            assert tok.shape == first.shape

    def test_admission_sheds_when_budget_exhausted(self, tmp_path):
        cfg, params = _model()
        with MarvelClient(ClusterConfig(
            name="shed-test", tiers=("dram", "pmem"),
            invokers=1, warm_pool=8, commit_every=1,
            journal="pmem", journal_path=str(tmp_path),
        )) as client:
            pool = client.serving(
                params, cfg, prompt_len=8, max_tokens=4,
                config=ServingConfig(block_tokens=8, lossless=True),
            )
            prompt = _prompt(cfg)
            pool.start("c0", prompt).result()
            # budget: room for exactly one resident session
            one = pool.pager.dram_bytes()
            pool.pager.dram_budget_bytes = int(1.5 * one)
            # idle LRU demotion makes room -> admitted, c0 demoted
            pool.start("c1", prompt).result()
            assert not pool.is_resident("c0")
            assert pool.is_resident("c1")
            assert pool.stats()["shed"] == 0
            # now pin both hot: nothing demotable -> shed
            pool.resume("c0")
            pool.pager.dram_budget_bytes = 1
            with pytest.raises(AdmissionError):
                pool.start("c2", prompt)
            assert pool.stats()["shed"] == 1

    def test_serving_rejects_sharded_client(self):
        cfg, params = _model()
        with MarvelClient(ClusterConfig(name="x", sharded=True,
                                        nodes=2)) as client:
            with pytest.raises(ConfigError):
                client.serving(params, cfg, prompt_len=4, max_tokens=2)

    def test_restart_resumes_through_pager(self, tmp_path):
        cfg, params = _model()
        cluster = ClusterConfig(
            name="restart-test",
            tiers=(TierSpec("dram", capacity_bytes=8 << 20),
                   TierSpec("pmem", path=str(tmp_path / "pmem"))),
            invokers=1, warm_pool=4, commit_every=1,
            journal="pmem", journal_path=str(tmp_path / "journal"),
            serving=ServingConfig(block_tokens=8, lossless=True),
        )
        prompt = _prompt(cfg)
        with MarvelClient(cluster) as client:
            pool = client.serving(params, cfg, prompt_len=8, max_tokens=8)
            pool.start("c0", prompt).result()
            baseline = [np.asarray(pool.step("c0").result())
                        for _ in range(3)]
            client.runtime.commit_all()
            pool.pager.sync()
        # fresh client over the same durable config: the pager re-adopts
        # the session from the PMEM tier and decode continues mid-stream
        with MarvelClient(cluster) as client:
            pool = client.serving(params, cfg, prompt_len=8, max_tokens=8)
            assert pool.pager.recover() == 1
            tok = np.asarray(pool.step("c0").result())
            assert tok.shape == baseline[-1].shape
