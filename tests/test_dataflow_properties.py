"""Property tests: the iterative dataflow engine under random graphs and
random crash schedules.

Two invariants, hunted with hypothesis (10x examples nightly via
``STRESS_SCALE`` — see .github/workflows/stress.yml):

  * **stage barriers hold** for arbitrary stage/task structures: no task
    of stage *k* starts before every task of its dependency stages
    finished, and every task runs exactly once;
  * **no committed superstep is ever lost**: for any schedule of halts,
    volatile-level crashes, torn markers, and lost state blobs, re-running
    the loop converges to byte-identical final state, committed-and-intact
    supersteps are never recomputed, and progress is monotone.
"""

import hashlib
import threading

from tests.hypothesis_compat import given, nightly_examples, settings, st

from repro.core import Scheduler
from repro.core.dataflow import Stage, StageTask, lower_stages, run_loop
from repro.storage import DramTier, StateCache
from repro.storage.hierarchy import PlacementPolicy, TieredStore, TierLevel


def _sched():
    return Scheduler(["w0", "w1", "w2"], speculation_factor=None)


class _PersistentDram(DramTier):
    """A DRAM tier that *claims* persistence — the test double for a PMEM
    home level (contents survive ``TieredStore.crash``) without touching
    the filesystem inside hypothesis examples."""

    name = "pdram"
    persistent = True


# -- random stage graphs ------------------------------------------------------

@settings(max_examples=nightly_examples(25), deadline=None)
@given(
    st.lists(st.integers(min_value=1, max_value=3), min_size=1, max_size=4),
    st.integers(min_value=0, max_value=2 ** 30),
)
def test_random_stage_graphs_respect_barriers(stage_sizes, seed):
    started = {}
    finished = {}
    lock = threading.Lock()
    clock = [0]

    def mk(tid):
        def run(_ctx):
            with lock:
                started[tid] = clock[0]
                clock[0] += 1
            with lock:
                finished[tid] = clock[0]
                clock[0] += 1

        return run

    stages = []
    for si, n_tasks in enumerate(stage_sizes):
        stages.append(Stage(f"s{si}", [
            StageTask(f"s{si}t{ti}", mk(f"s{si}t{ti}"))
            for ti in range(n_tasks)
        ]))
    dag = lower_stages("prop", stages, namespace="prop/")
    results = _sched().run_dag(dag.specs, initial_tokens=dag.initial_tokens)
    assert len(results) == sum(stage_sizes)
    # every task ran exactly once, and no stage-k task started before
    # every stage-(k-1) task finished
    for si in range(1, len(stage_sizes)):
        prev_done = max(
            finished[f"s{si - 1}t{ti}"]
            for ti in range(stage_sizes[si - 1])
        )
        for ti in range(stage_sizes[si]):
            assert started[f"s{si}t{ti}"] > prev_done


# -- crash schedules never lose a committed superstep -------------------------

def _hash_chain(seed: bytes, iterations: int):
    """Golden loop state: x_{k} = blake2b(x_{k-1} || k)."""
    x = seed
    out = [x]
    for k in range(1, iterations + 1):
        x = hashlib.blake2b(x + str(k).encode(), digest_size=16).digest()
        out.append(x)
    return out


def _loop_pieces(executed):
    def init(ctx):
        ctx.write("x", b"seed")

    def superstep(ctx):
        def run(_tc):
            prev = ctx.read("x")
            ctx.write("x", hashlib.blake2b(
                prev + str(ctx.iteration).encode(), digest_size=16
            ).digest())
            executed.append(ctx.iteration)

        return [Stage("s", [StageTask("t", run)])]

    return init, superstep


def _fresh_store():
    return TieredStore(
        [
            TierLevel("dram", DramTier(), None),
            TierLevel("home", _PersistentDram()),
        ],
        policy=PlacementPolicy(write_back=True, flush_interval=0.002),
        journal=StateCache(write_through=_PersistentDram()),
        name="prop",
    )


@settings(max_examples=nightly_examples(20), deadline=None)
@given(
    st.lists(
        st.tuples(
            st.integers(min_value=1, max_value=3),  # supersteps this leg
            st.sampled_from(
                ["none", "crash", "partial", "lost_blob"]
            ),
        ),
        min_size=0,
        max_size=4,
    ),
)
def test_crash_schedules_never_lose_committed_supersteps(legs):
    total = 6
    golden = _hash_chain(b"seed", total)
    executed = []
    init, superstep = _loop_pieces(executed)
    store = _fresh_store()
    journal_cache = store._journal_cache  # durable write-through cache
    journal = StateCache(write_through=_PersistentDram())
    sched = _sched()
    kw = dict(state=store, journal=journal, max_iterations=total)
    try:
        committed_intact = -1  # highest superstep guaranteed to survive
        for steps, action in legs:
            before = len(executed)
            rep = run_loop("chain", init, superstep, lambda ctx: False,
                           scheduler=sched, halt_after=steps, **kw)
            if rep.last_iteration >= total:
                break
            # committed-and-intact supersteps were not recomputed
            assert all(k > committed_intact for k in executed[before:])
            committed_intact = rep.last_iteration
            if action == "crash":
                # volatile levels die; write-back redo replays acked state
                store.crash()
                journal.crash()
                journal.recover()
                journal_cache.crash()
                journal_cache.recover()
                store.recover()
            elif action == "partial":
                # realistic mid-superstep crash state: the next
                # superstep's blobs (partially) landed but its marker
                # never committed — resume must sweep and re-run it
                store.put(
                    f"df/chain/state/"
                    f"it{rep.last_iteration + 1:05d}/x",
                    b"partial-garbage",
                )
            elif action == "lost_blob":
                # the only surviving copy of the newest state evaporated
                # (data loss beyond the durability contract): the loop
                # must still converge to golden bytes, via deterministic
                # recompute from scratch — resume guarantees are off
                store.delete(
                    f"df/chain/state/it{rep.last_iteration:05d}/x"
                )
                committed_intact = -1
        before = len(executed)
        final = run_loop("chain", init, superstep, lambda ctx: False,
                         scheduler=sched, **kw)
        assert all(k > committed_intact for k in executed[before:])
        assert final.last_iteration == total
        got = store.get(f"df/chain/state/it{total:05d}/x")
        assert got == golden[total]
    finally:
        store.close()


@settings(max_examples=nightly_examples(15), deadline=None)
@given(st.integers(min_value=1, max_value=5))
def test_resume_progress_is_monotone(halt_every):
    """Driving the loop in fixed-size legs always terminates in
    ceil(total+1 / halt_every) legs — no leg loses the previous legs'
    progress (init counts as the first committed iteration)."""
    total = 5
    executed = []
    init, superstep = _loop_pieces(executed)
    state = DramTier()
    journal = StateCache()
    sched = _sched()
    last = -1
    legs = 0
    while True:
        rep = run_loop("mono", init, superstep, lambda ctx: False,
                       state=state, journal=journal, max_iterations=total,
                       pin_state=False, scheduler=sched,
                       halt_after=halt_every)
        assert rep.last_iteration > last or rep.last_iteration == total
        last = rep.last_iteration
        legs += 1
        assert legs <= total + 2
        if rep.last_iteration >= total:
            break
    assert state.get(f"df/mono/state/it{total:05d}/x") \
        == _hash_chain(b"seed", total)[total]
