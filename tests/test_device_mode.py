"""Device execution mode (`device=`): the engine lowered onto the Pallas
kernel layer must be byte-identical to host execution — wordcount and
terasort outputs, with and without capacity-overflow spill, plus the
config validation that gates the mode off-TPU.

Kernels run in interpret mode (CPU CI); on TPU hardware the same tests
exercise the compiled Mosaic kernels.
"""

import numpy as np
import pytest

from repro.api import ClusterConfig, ConfigError, MarvelClient
from repro.core.mapreduce import aggregation_job, wordcount_job


def _corpus(seed=7, parts=6, words_per_part=150):
    rng = np.random.default_rng(seed)
    words = [f"w{i:03d}" for i in range(40)]
    return [
        " ".join(rng.choice(words, size=words_per_part)).encode()
        for _ in range(parts)
    ]


def _wordcount(client, name, device):
    ds = (
        client.dataset(_corpus(), name=name)
        .map(lambda rec: [(w, 1) for w in rec.split()])
        .shuffle(partitions=4)
        .reduce(lambda k, vs: [(k, sum(vs))], kind="sum")
    )
    return ds.collect(device=device)


def test_wordcount_device_byte_identical():
    with MarvelClient(ClusterConfig(device_interpret=True)) as c:
        host = _wordcount(c, "wc-host", device=False)
        dev = _wordcount(c, "wc-dev", device=True)
    assert host == dev
    assert host  # non-trivial output


def test_wordcount_device_spill_byte_identical():
    """A tiny capacity factor forces nearly every pair through the
    intermediate-tier spill path; output bytes must not change."""
    with MarvelClient(ClusterConfig(device_interpret=True)) as c:
        host = _wordcount(c, "wcs-host", device=False)
    cfg = ClusterConfig(
        device=True, device_interpret=True, device_capacity_factor=0.05
    )
    with MarvelClient(cfg) as c:
        ds = (
            c.dataset(_corpus(), name="wcs-dev")
            .map(lambda rec: [(w, 1) for w in rec.split()])
            .shuffle(partitions=4)
            .reduce(lambda k, vs: [(k, sum(vs))], kind="sum")
        )
        h = ds.run()
        dev = []
        for p in range(4):
            path = f"{h.result}/part_{p:04d}"
            if c.store.exists(path):
                dev.extend(
                    ln for ln in c.store.read(path).split(b"\n") if ln
                )
    assert host == dev
    extra = h.report.extra
    assert extra["device_mode"] == 1
    assert extra["device_spilled_pairs"] > 0  # the spill path actually ran
    assert extra["device_groups"] > 0  # reduce lowered to the segment-sum


def _run_wc_mapreduce(device):
    """Fresh client per run — shared journals would let the second run
    resume the first one's map tasks and skip the device path."""
    corpus = _corpus(seed=3)
    with MarvelClient(ClusterConfig(device_interpret=True)) as c:
        c.store.write("/dev-acct/in", b"\n".join(corpus), record_delim=b"\n")
        h = c.mapreduce(
            wordcount_job(), "/dev-acct/in", "/dev-acct/out", device=device
        )
        outs = []
        for p in range(4):
            path = f"/dev-acct/out/part_{p:04d}"
            outs.append(c.store.read(path) if c.store.exists(path) else None)
        return h.report.extra, outs


def test_mapreduce_device_reports_accounting():
    host_extra, host_outs = _run_wc_mapreduce(device=False)
    dev_extra, dev_outs = _run_wc_mapreduce(device=True)
    assert host_extra["device_mode"] == 0
    assert dev_extra["device_mode"] == 1
    assert dev_extra["device_pairs"] > 0
    assert dev_extra["device_groups"] > 0
    assert host_outs == dev_outs


def test_float_reduce_falls_back_to_host():
    """aggregation sums floats: device runs must keep the host reducer
    (float addition order) yet still partition on the kernel."""
    rows = [
        b"\n".join(
            f"k{i % 5},{(i * 7 % 13) / 8}".encode() for i in range(40)
        )
        for _ in range(3)
    ]
    def run(device):
        with MarvelClient(ClusterConfig(device_interpret=True)) as c:
            c.store.write("/agg/in", b"\n".join(rows), record_delim=b"\n")
            h = c.mapreduce(
                aggregation_job(), "/agg/in", "/agg/out", device=device
            )
            outs = []
            for p in range(4):
                path = f"/agg/out/part_{p:04d}"
                outs.append(
                    c.store.read(path) if c.store.exists(path) else None
                )
            return h.report.extra, outs

    _, host_outs = run(device=False)
    dev_extra, dev_outs = run(device=True)
    assert dev_extra["device_fallback_tasks"] > 0
    assert dev_extra["device_groups"] == 0
    assert host_outs == dev_outs


def test_terasort_device_byte_identical():
    rng = np.random.default_rng(11)
    parts = [
        b"\n".join(
            f"r{v:06d}".encode()
            for v in rng.integers(0, 99999, 200)
        )
        for _ in range(3)
    ]
    with MarvelClient(ClusterConfig(device_interpret=True)) as c:
        host = c.terasort("ts-host", parts, n_ranges=4).result
    with MarvelClient(ClusterConfig(device_interpret=True)) as c:
        handle = c.terasort("ts-dev", parts, n_ranges=4, device=True)
    assert handle.result == host
    assert handle.result == sorted(handle.result)
    assert handle.report.extra["device_tasks"] == 3  # one per scatter


def test_device_requires_tpu_or_interpret():
    with pytest.raises(ConfigError, match="interpret"):
        ClusterConfig(device=True).validate()
    # per-call opt-in is validated the same way
    with MarvelClient(ClusterConfig()) as c:
        with pytest.raises(ConfigError, match="interpret"):
            c.terasort("ts-err", [b"a\nb"], device=True)
    # interpret mode is the CPU CI escape hatch
    ClusterConfig(device=True, device_interpret=True).validate()


def test_bad_device_capacity_factor():
    with pytest.raises(ConfigError, match="capacity_factor"):
        ClusterConfig(device_capacity_factor=0.0).validate()


def test_dataset_rejects_unknown_reduce_kind():
    with MarvelClient(ClusterConfig()) as c:
        ds = c.dataset([b"a b"], name="bad-kind").map(
            lambda rec: [(w, 1) for w in rec.split()]
        )
        with pytest.raises(ConfigError, match="reduce kind"):
            ds.reduce(lambda k, vs: [(k, sum(vs))], kind="max")
