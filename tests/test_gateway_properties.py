"""Property tests of the lock-striped gateway (ISSUE 6 tentpole).

Random schedules of ``invoke`` / ``scale_to`` / ``evict`` against a
sharded gateway must preserve the three invariants the striping refactor
is not allowed to trade away:

  * **per-session FIFO** — invocations of one session execute in
    submission order (the lane lease serializes them even when the pool
    is resizing underneath);
  * **lease exclusivity** — no two invocations of the same session ever
    run concurrently, on any pair of invokers;
  * **no lost updates** — after the drain, every session's state holds
    exactly the submitted values, in order, across evictions (which
    round-trip state through the cache) and pool resizes.

Runs under ``tests/hypothesis_compat`` (real hypothesis when installed,
deterministic fallback sampler otherwise); the nightly stress workflow
scales ``max_examples`` via ``$STRESS_SCALE``.
"""

import threading

from repro.core import FunctionRuntime, Gateway, StatefulFunction
from repro.storage import StateCache, serde

from tests.hypothesis_compat import given, nightly_examples, settings, st

N_SESSIONS = 6

#: one schedule op: (kind, a, b) with kind 0=invoke(session a, value b),
#: 1=scale_to(a invokers), 2=evict(session a)
_OPS = st.lists(
    st.tuples(
        st.integers(min_value=0, max_value=9),  # 0-7 invoke, 8 scale, 9 evict
        st.integers(min_value=0, max_value=N_SESSIONS - 1),
        st.integers(min_value=1, max_value=100),
    ),
    min_size=1,
    max_size=40,
)


def _appender_runtime(active, violations):
    """Appender whose step asserts session-exclusive execution: ``active``
    counts in-flight steps per session; two at once is a lease breach."""

    def step(state, sess, value):
        with active["lock"]:
            active[sess] = active.get(sess, 0) + 1
            if active[sess] != 1:
                violations.append(sess)
        state = dict(state)
        state["values"] = state["values"] + [value]
        with active["lock"]:
            active[sess] -= 1
        return state, len(state["values"])

    rt = FunctionRuntime(cache=StateCache(), commit_every=1,
                         group_commit=True)
    rt.register(
        StatefulFunction(
            "append", step, init=lambda: {"values": []}, jit=False
        )
    )
    return rt


@settings(max_examples=nightly_examples(25), deadline=None)
@given(_OPS, st.integers(min_value=1, max_value=4))
def test_random_schedule_preserves_gateway_invariants(ops, stripes):
    active = {"lock": threading.Lock()}
    violations = []
    rt = _appender_runtime(active, violations)
    # warm_pool=3 < N_SESSIONS so LRU eviction churns alongside the
    # schedule's explicit evicts; stripes varies down to 1 (degenerate =
    # the old single-lock layout must satisfy the same invariants)
    gw = Gateway(rt, invokers=3, warm_pool=3, stripes=stripes)
    expected = {s: [] for s in range(N_SESSIONS)}
    futures = []
    try:
        for kind, sess, value in ops:
            if kind == 8:
                gw.scale_to(1 + (value % 4))
            elif kind == 9:
                # runtime-level evict races the invokers on purpose; the
                # slot lock serializes it against in-flight steps
                rt.evict("append", f"s{sess}", commit=True)
            else:
                futures.append(
                    gw.submit("append", session=f"s{sess}",
                              sess=sess, value=value)
                )
                expected[sess].append(value)
        for f in futures:
            f.result(timeout=60)
    finally:
        gw.close(drain=True)
        rt.close()
    assert not violations, f"lease breached for sessions {set(violations)}"
    for sess, values in expected.items():
        if not values:
            continue
        # state_bytes falls back to the committed cache blob when the
        # slot was evicted — hot and committed views must both hold the
        # full, ordered history
        data = rt.state_bytes("append", f"s{sess}")
        assert data is not None, f"s{sess} lost its state entirely"
        state = serde.loads(data)
        assert state["values"] == values, (
            f"s{sess}: {state['values']} != submitted {values}"
        )
