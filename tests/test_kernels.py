"""Per-kernel shape/dtype sweeps, assert_allclose vs the ref.py oracles.

All Pallas kernels run with interpret=True on CPU (TPU is the target)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _mk(rng, shape, dtype):
    x = rng.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x).astype(dtype)


# -- flash attention ----------------------------------------------------------

@pytest.mark.parametrize("T,dh,causal", [
    (128, 64, True),
    (300, 64, True),   # unaligned seq -> padding path
    (256, 128, False),
    (65, 32, True),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(rng, T, dh, causal, dtype):
    B, H, Kv = 2, 4, 2
    q = _mk(rng, (B, T, H, dh), dtype)
    k = _mk(rng, (B, T, Kv, dh), dtype)
    v = _mk(rng, (B, T, Kv, dh), dtype)
    got = ops.flash_attention(q, k, v, causal=causal)
    kk = jnp.repeat(k, H // Kv, 2)
    vv = jnp.repeat(v, H // Kv, 2)
    want = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3).reshape(B * H, T, dh),
        kk.transpose(0, 2, 1, 3).reshape(B * H, T, dh),
        vv.transpose(0, 2, 1, 3).reshape(B * H, T, dh),
        causal=causal,
    ).reshape(B, H, T, dh).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol,
    )


def test_flash_attention_softcap(rng):
    B, T, H, dh = 1, 128, 2, 64
    q = _mk(rng, (B, T, H, dh), jnp.float32)
    k = _mk(rng, (B, T, H, dh), jnp.float32)
    v = _mk(rng, (B, T, H, dh), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True, softcap=30.0)
    want = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3).reshape(B * H, T, dh),
        k.transpose(0, 2, 1, 3).reshape(B * H, T, dh),
        v.transpose(0, 2, 1, 3).reshape(B * H, T, dh),
        causal=True, softcap=30.0,
    ).reshape(B, H, T, dh).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5
    )


# -- decode attention ------------------------------------------------------

@pytest.mark.parametrize("S,dh", [(256, 64), (1000, 128), (64, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(rng, S, dh, dtype):
    B, H, Kv = 2, 4, 2
    q = _mk(rng, (B, H, dh), dtype)
    kc = _mk(rng, (B, S, Kv, dh), dtype)
    vc = _mk(rng, (B, S, Kv, dh), dtype)
    lengths = jnp.asarray([S // 3, S], jnp.int32)
    got = ops.decode_attention(q, kc, vc, lengths)
    kke = jnp.repeat(kc, H // Kv, 2)
    vve = jnp.repeat(vc, H // Kv, 2)
    # reference on expanded heads: flatten (B,H) into kernel batch layout
    s = jnp.einsum(
        "bhd,bshd->bhs", q.astype(jnp.float32), kke.astype(jnp.float32)
    ) / np.sqrt(dh)
    mask = jnp.arange(S)[None, None, :] < lengths[:, None, None]
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    want = jnp.einsum("bhs,bshd->bhd", p, vve.astype(jnp.float32))
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol,
    )


# -- SSD chunk ---------------------------------------------------------------

@pytest.mark.parametrize("Q,H,P,N,hb", [
    (64, 16, 32, 16, 8),
    (32, 8, 64, 32, 8),
    (128, 4, 16, 8, 4),
])
def test_ssd_chunk_sweep(rng, Q, H, P, N, hb):
    BC = 2
    x = _mk(rng, (BC, Q, H, P), jnp.float32)
    dt = jnp.asarray(rng.random((BC, Q, H)).astype(np.float32))
    dA = jnp.asarray(
        -np.cumsum(rng.random((BC, Q, H)).astype(np.float32) * 0.1, axis=1)
    )
    Bm = _mk(rng, (BC, Q, H, N), jnp.float32)
    Cm = _mk(rng, (BC, Q, H, N), jnp.float32)
    y, S_ = ops.ssd_chunk(x, dt, dA, Bm, Cm, head_block=hb)
    yr, Sr = ref.ssd_chunk_ref(x, dt, dA, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-3,
                               rtol=2e-3)
    np.testing.assert_allclose(np.asarray(S_), np.asarray(Sr), atol=2e-3,
                               rtol=2e-3)


def test_ssd_kernel_consistent_with_model_layer(rng):
    """Kernel output == the jnp chunked-SSD inner terms used by models/ssm."""
    from repro.models.ssm import _ssd_chunked

    B, L, H, P, N, Q = 1, 128, 8, 16, 8, 32
    x = jnp.asarray(rng.standard_normal((B, L, H, P)).astype(np.float32))
    dt = jnp.asarray(rng.random((B, L, H)).astype(np.float32))
    A = -jnp.asarray(rng.random((H,)).astype(np.float32))
    Bm = jnp.asarray(rng.standard_normal((B, L, H, N)).astype(np.float32))
    Cm = jnp.asarray(rng.standard_normal((B, L, H, N)).astype(np.float32))
    y_model, _ = _ssd_chunked(x, dt, A, Bm, Cm, Q)

    # reproduce via kernel: chunk, compute within-chunk + states, then the
    # same inter-chunk recurrence
    nc = L // Q
    xc = x.reshape(B * nc, Q, H, P)
    dtc = dt.reshape(B * nc, Q, H)
    dA_cs = jnp.cumsum((dt * A).reshape(B, nc, Q, H), axis=2).reshape(
        B * nc, Q, H
    )
    Bc = Bm.reshape(B * nc, Q, H, N)
    Cc = Cm.reshape(B * nc, Q, H, N)
    y_diag, S_ = ops.ssd_chunk(xc, dtc, dA_cs, Bc, Cc, head_block=8)
    y_diag = y_diag.reshape(B, nc, Q, H, P)
    S_ = S_.reshape(B, nc, H, P, N)
    seg = dA_cs.reshape(B, nc, Q, H)[:, :, -1]
    h = jnp.zeros((B, H, P, N))
    outs = []
    for c in range(nc):
        y_off = jnp.einsum(
            "bqhn,bhpn,bqh->bqhp",
            Cc.reshape(B, nc, Q, H, N)[:, c], h,
            jnp.exp(dA_cs.reshape(B, nc, Q, H)[:, c]),
        )
        outs.append(y_diag[:, c] + y_off)
        h = jnp.exp(seg[:, c])[:, :, None, None] * h + S_[:, c]
    y_kernel = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_kernel), np.asarray(y_model), atol=2e-3, rtol=2e-3
    )


# -- bucket histogram ------------------------------------------------------

@pytest.mark.parametrize("n,buckets,block", [
    (1000, 16, 256),
    (5000, 128, 2048),
    (100, 7, 64),  # unaligned
])
def test_bucket_histogram_sweep(rng, n, buckets, block):
    keys = rng.integers(-1, buckets, n).astype(np.int32)
    got = ops.shuffle_histogram(jnp.asarray(keys), buckets, block=block)
    want = ref.bucket_histogram_ref(jnp.asarray(keys), buckets)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


def test_bucket_histogram_empty_input():
    # N == 0 used to collapse block to zero and divide by it.
    got = ops.shuffle_histogram(jnp.zeros((0,), jnp.int32), 16)
    assert got.shape == (16,)
    assert int(jnp.sum(got)) == 0


def test_bucket_histogram_smaller_than_block(rng):
    keys = rng.integers(-1, 8, 5).astype(np.int32)
    got = ops.shuffle_histogram(jnp.asarray(keys), 8, block=2048)
    want = ref.bucket_histogram_ref(jnp.asarray(keys), 8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_bucket_histogram_all_padding():
    got = ops.shuffle_histogram(jnp.full((64,), -1, jnp.int32), 8)
    assert int(jnp.sum(got)) == 0


def test_bucket_histogram_int_accumulator(rng):
    # Count workloads accumulate in int32 by default (f32 loses exactness
    # above 2^24); weighted callers can still ask for f32.
    keys = rng.integers(0, 16, 1000).astype(np.int32)
    got = ops.shuffle_histogram(jnp.asarray(keys), 16)
    assert got.dtype == jnp.int32
    f32 = ops.shuffle_histogram(jnp.asarray(keys), 16, out_dtype=jnp.float32)
    assert f32.dtype == jnp.float32
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(f32).astype(np.int32)
    )


def test_partition_counts(rng):
    # The engine entry point: arbitrary (non-lane-aligned) n_parts.
    dest = rng.integers(-1, 7, 999).astype(np.int32)
    got = np.asarray(ops.partition_counts(jnp.asarray(dest), 7))
    want = np.bincount(dest[dest >= 0], minlength=7)
    np.testing.assert_array_equal(got, want)


def test_partition_counts_rejects_bad_n_parts():
    with pytest.raises(ValueError):
        ops.partition_counts(jnp.zeros((4,), jnp.int32), 0)
