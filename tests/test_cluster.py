"""Multi-node cluster: ring placement, routing, golden equivalence.

The contract under test (DESIGN.md §12): ``ClusterConfig(sharded=True)``
builds one full Marvel stack per node behind a consistent-hash router —
and at ``nodes=1`` is *byte-identical* to the single-stack path, while at
``nodes>1`` the cluster shuffle still produces byte-identical job output
to the single-node engine (the engine's partition function, pair
encoding, and sorted output format are reused verbatim).
"""

from __future__ import annotations

import pytest

from repro.api import ClusterConfig, MarvelClient
from repro.core.cluster import ClusterRouter, HashRing, NetworkFabric, Node
from repro.core.gateway import Gateway
from repro.core.mapreduce import wordcount_job
from repro.core.stateful import FunctionRuntime, StatefulFunction
from repro.storage.blockstore import DataNode
from repro.storage.kvcache import StateCache
from repro.storage.tiers import DramTier
from tests.hypothesis_compat import given, nightly_examples, settings, st


def _corpus(n: int = 300) -> bytes:
    return b"\n".join(
        b"the quick brown fox jumps over lazy dog word%d" % (i % 13)
        for i in range(n)
    )


def _counter(client: MarvelClient) -> None:
    client.register(
        StatefulFunction(
            "counter",
            lambda state, inc=1: ({"n": state["n"] + inc}, state["n"] + inc),
            lambda **kw: {"n": 0},
            jit=False,
        )
    )


def _read_parts(client: MarvelClient, path: str, n: int) -> bytes:
    return b"".join(client.store.read(f"{path}/part_{p:04d}") for p in range(n))


# -- consistent hashing --------------------------------------------------------


class TestHashRing:
    def test_owner_is_deterministic_and_live(self):
        ring = HashRing(["n0", "n1", "n2"])
        keys = [f"sess{i}" for i in range(100)]
        owners = {k: ring.owner(k) for k in keys}
        assert set(owners.values()) <= {"n0", "n1", "n2"}
        assert all(ring.owner(k) == owners[k] for k in keys)
        # enough vnodes that 100 keys don't all land on one node
        assert len(set(owners.values())) > 1

    def test_remove_moves_only_the_dead_arc(self):
        ring = HashRing(["n0", "n1", "n2", "n3"])
        keys = [f"k{i}" for i in range(500)]
        before = {k: ring.owner(k) for k in keys}
        ring.remove_node("n2")
        for k in keys:
            after = ring.owner(k)
            if before[k] == "n2":
                assert after != "n2"
            else:
                assert after == before[k]

    def test_add_moves_only_the_new_arc(self):
        ring = HashRing(["n0", "n1", "n2"])
        keys = [f"k{i}" for i in range(500)]
        before = {k: ring.owner(k) for k in keys}
        ring.add_node("n3")
        moved = 0
        for k in keys:
            after = ring.owner(k)
            if after != before[k]:
                assert after == "n3"  # keys only ever move TO the new node
                moved += 1
        assert 0 < moved < len(keys)

    def test_add_then_remove_restores_ownership(self):
        ring = HashRing(["n0", "n1"])
        keys = [f"k{i}" for i in range(200)]
        before = {k: ring.owner(k) for k in keys}
        ring.add_node("nX")
        ring.remove_node("nX")
        assert {k: ring.owner(k) for k in keys} == before

    def test_owners_are_distinct(self):
        ring = HashRing(["n0", "n1", "n2", "n3"])
        owners = ring.owners("some-key", 3)
        assert len(owners) == 3
        assert len(set(owners)) == 3

    @settings(max_examples=nightly_examples(20), deadline=None)
    @given(st.lists(st.integers(min_value=0, max_value=7), min_size=1, max_size=6))
    def test_arc_stability_property(self, adds):
        """Random add sequences: every key move targets the node added."""
        ring = HashRing(["a", "b"])
        keys = [f"k{i}" for i in range(120)]
        for x in adds:
            nid = f"n{x}"
            before = {k: ring.owner(k) for k in keys}
            ring.add_node(nid)
            for k in keys:
                after = ring.owner(k)
                assert after == before[k] or after == nid


# -- golden equivalence: sharded nodes=1 == single-stack -----------------------


class TestGoldenEquivalence:
    def test_nodes1_job_bytes_and_report_identical(self):
        outs, reports = [], []
        for sharded in (False, True):
            with MarvelClient(
                ClusterConfig(name="g", nodes=1, replication=1,
                              sharded=sharded, block_size=2048)
            ) as client:
                client.store.write("/in", _corpus(), record_delim=b"\n")
                handle = client.mapreduce(wordcount_job(4), "/in", "/out")
                outs.append(_read_parts(client, "/out", 4))
                reports.append(handle.report)
        assert outs[0] == outs[1]
        for fld in ("tasks", "resumed_tasks", "iterations", "kind"):
            assert getattr(reports[0], fld) == getattr(reports[1], fld)
        # nodes=1 sharded runs the very same single-stack engine: same
        # mode, same tier rollup shape (no "net" level appears).
        assert reports[0].extra.get("mode") == reports[1].extra.get("mode")
        assert sorted(reports[0].tiers) == sorted(reports[1].tiers)

    def test_nodes1_session_results_identical(self):
        results = []
        for sharded in (False, True):
            with MarvelClient(
                ClusterConfig(name="g", nodes=1, replication=1, sharded=sharded)
            ) as client:
                _counter(client)
                results.append(
                    [
                        client.invoke("counter", session=f"s{i % 3}")
                        for i in range(12)
                    ]
                )
        assert results[0] == results[1]

    def test_nodes1_cluster_engine_matches_host_engine(self):
        """Even the router's own mapreduce path (which api routes to only
        at nodes>1) is byte-identical at nodes=1."""
        with MarvelClient(
            ClusterConfig(name="g", nodes=1, replication=1,
                          sharded=True, block_size=2048)
        ) as client:
            client.store.write("/in", _corpus(), record_delim=b"\n")
            client.mapreduce(wordcount_job(4), "/in", "/eng")
            client.cluster.run_mapreduce(wordcount_job(4), "/in", "/clu")
            assert _read_parts(client, "/eng", 4) == _read_parts(client, "/clu", 4)


# -- multi-node routing and shuffle --------------------------------------------


class TestClusterRouting:
    def test_shuffle_byte_identical_to_single_node(self):
        with MarvelClient(
            ClusterConfig(name="ref", nodes=2, block_size=2048)
        ) as ref:
            ref.store.write("/in", _corpus(), record_delim=b"\n")
            ref.mapreduce(wordcount_job(4), "/in", "/out")
            expect = _read_parts(ref, "/out", 4)
        with MarvelClient(
            ClusterConfig(name="clu", nodes=3, sharded=True, block_size=2048)
        ) as client:
            client.store.write("/in", _corpus(), record_delim=b"\n")
            handle = client.mapreduce(wordcount_job(4), "/in", "/out")
            assert _read_parts(client, "/out", 4) == expect
            # cross-node shuffle is charged to the modeled network tier,
            # reported distinctly from the storage tiers
            assert handle.report.extra["mode"] == "cluster"
            assert handle.report.extra["net_bytes"] > 0
            assert handle.report.extra["net_seconds"] > 0
            assert "net" in handle.report.tiers
            assert any(k.startswith("n1/") for k in handle.report.tiers)

    def test_sessions_spread_and_route_to_ring_owner(self):
        with MarvelClient(
            ClusterConfig(name="r", nodes=4, sharded=True)
        ) as client:
            _counter(client)
            owners = set()
            for i in range(40):
                sess = f"sess{i}"
                node = client.cluster.owner_node(sess)
                owners.add(node.node_id)
                assert client.invoke("counter", session=sess) == 1
                # state landed on the ring owner's runtime, nobody else's
                assert node.runtime.state_bytes("counter", sess) is not None
                for other in client.cluster.nodes.values():
                    if other is not node:
                        assert not other.runtime.cache.contains(
                            f"state/{sess}/counter"
                        )
            assert len(owners) > 1

    def test_session_object_survives_rerouting(self):
        with MarvelClient(
            ClusterConfig(name="r", nodes=3, sharded=True)
        ) as client:
            _counter(client)
            sess = client.session("chatty")
            assert [sess.invoke("counter") for _ in range(3)] == [1, 2, 3]

    def test_replication_spans_nodes(self):
        with MarvelClient(
            ClusterConfig(name="r", nodes=4, sharded=True,
                          replication=2, block_size=2048)
        ) as client:
            client.store.write("/in", _corpus(), record_delim=b"\n")
            for block in client.store.locate("/in"):
                assert len(set(block.replicas)) == 2
            victim = client.store.locate("/in")[0].replicas[0]
            client.store.fail_node(victim)
            assert client.store.read("/in") == _corpus()

    def test_add_node_joins_ring_store_and_functions(self):
        with MarvelClient(
            ClusterConfig(name="r", nodes=2, sharded=True)
        ) as client:
            _counter(client)
            state = DramTier()
            runtime = FunctionRuntime(cache=StateCache(memory=state))
            node = Node(
                node_id="n9",
                state=state,
                runtime=runtime,
                gateway=Gateway(runtime, invokers=1, name="r-n9"),
                datanode=DataNode("r/n9", DramTier()),
                workers=1,
            )
            client.cluster.add_node(node)
            assert "n9" in client.cluster.ring.node_ids
            assert "r/n9" in client.store.nodes
            # registered functions followed the new node; sessions that
            # hash onto it just work
            sess = next(
                f"s{i}"
                for i in range(300)
                if client.cluster.ring.owner(f"s{i}") == "n9"
            )
            assert client.invoke("counter", session=sess) == 1


class TestFabricAccounting:
    def test_transfer_charges_links_and_total(self):
        fabric = NetworkFabric()
        fabric.transfer("a", "b", 1000)
        fabric.transfer("a", "b", 500, ops=2)
        fabric.transfer("b", "a", 100)
        assert fabric.transfer("a", "a", 10**9) == 0.0  # local is free
        by_link = fabric.stats_by_link()
        assert by_link["a->b"].bytes_written == 1500
        assert by_link["a->b"].write_ops == 3
        assert by_link["b->a"].bytes_written == 100
        assert fabric.total.bytes_written == 1600
        spec = fabric.spec
        expect = spec.latency * 4 + 1600 / spec.bandwidth
        assert fabric.total.modeled_seconds == pytest.approx(expect)

    def test_router_requires_nodes(self):
        with pytest.raises(ValueError):
            ClusterRouter([], store=None)
