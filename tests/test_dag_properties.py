"""Property tests for ``Scheduler.run_dag`` over random DAGs.

Concurrency bugs hide in interleavings no example test pins down, so these
properties are checked over randomized DAG shapes (sizes, edges, failure
injections, stragglers) via ``hypothesis`` — or the deterministic fallback
sampler in ``hypothesis_compat`` when hypothesis isn't installed:

  1. **dependency safety** — no task starts before every dep token has
     published (observed as: starts strictly after each dep's run ended);
  2. **liveness** — streaming consumers never deadlock: every run
     terminates and the consumer saw every published data token;
  3. **commit uniqueness** — retry + speculation never duplicate a
     committed partition: ``on_complete`` fires exactly once per task and
     every (possibly re-)written partition blob is byte-identical.
"""

import random
import threading
import time
from collections import defaultdict

from hypothesis_compat import given, settings, st

from repro.core import Scheduler, StateJournal, TaskSpec, task_token
from repro.storage import DramTier, StateCache


def _rand_deps(rnd: random.Random, n_tasks: int, max_deps: int = 2):
    """Random DAG edges: each task depends on a few earlier tasks."""
    return {
        i: sorted(rnd.sample(range(i), min(i, rnd.randint(0, max_deps))))
        for i in range(n_tasks)
    }


@settings(max_examples=12, deadline=None)
@given(
    st.integers(min_value=3, max_value=10),
    st.integers(min_value=0, max_value=2**30),
    st.integers(min_value=1, max_value=4),
)
def test_no_task_starts_before_its_deps_publish(n_tasks, seed, n_workers):
    rnd = random.Random(seed)
    deps = _rand_deps(rnd, n_tasks)
    durations = [rnd.uniform(0.0, 0.004) for _ in range(n_tasks)]
    starts, ends = {}, {}
    lock = threading.Lock()

    def mk(i):
        def run(ctx):
            t = time.perf_counter()
            with lock:
                starts[i] = t
            time.sleep(durations[i])
            t = time.perf_counter()
            with lock:
                ends[i] = t
            return i

        return TaskSpec(
            f"t{i}", run,
            deps=frozenset(task_token(f"t{j}") for j in deps[i]),
        )

    sched = Scheduler(
        [f"w{k}" for k in range(n_workers)], speculation_factor=None
    )
    res = sched.run_dag([mk(i) for i in range(n_tasks)])
    assert len(res) == n_tasks
    # A dep's token publishes only after its run returned, so a correct
    # scheduler can never start a dependent before the dep's end time.
    for i, ds in deps.items():
        for j in ds:
            assert starts[i] >= ends[j], (
                f"t{i} started {ends[j] - starts[i]:.6f}s before dep t{j} "
                "finished"
            )


@settings(max_examples=12, deadline=None)
@given(
    st.integers(min_value=1, max_value=6),
    st.integers(min_value=0, max_value=2**30),
    st.integers(min_value=1, max_value=3),
)
def test_streaming_consumers_never_deadlock(n_producers, seed, n_workers):
    rnd = random.Random(seed)
    n_parts = [rnd.randint(0, 3) for _ in range(n_producers)]
    durations = [rnd.uniform(0.0, 0.01) for _ in range(n_producers)]
    consumed = []

    def producer(i):
        def run(ctx):
            time.sleep(durations[i])
            for p in range(n_parts[i]):
                ctx.publish(f"data:p{i}.{p}")
            return i

        return TaskSpec(f"p{i}", run)

    def consumer_run(ctx):
        done = set()
        seen = []
        while len(done) < n_producers or not ctx.events.empty():
            tok = ctx.next_event(timeout=0.01)
            if tok is None:
                continue
            if tok.startswith("task:"):
                done.add(tok)
            else:
                seen.append(tok)
        consumed.extend(seen)
        return len(seen)

    specs = [producer(i) for i in range(n_producers)]
    specs.append(
        TaskSpec(
            "consumer", consumer_run, streaming=True,
            listens=lambda tok: tok.startswith(("data:", "task:p")),
        )
    )
    sched = Scheduler(
        [f"w{k}" for k in range(n_workers)], speculation_factor=None
    )
    results = {}

    def go():
        results.update(sched.run_dag(specs))

    t = threading.Thread(target=go, daemon=True)
    t.start()
    t.join(timeout=30.0)
    assert not t.is_alive(), "run_dag deadlocked with a streaming consumer"
    assert len(results) == n_producers + 1
    expected = sorted(
        f"data:p{i}.{p}" for i in range(n_producers) for p in range(n_parts[i])
    )
    assert sorted(consumed) == expected, "consumer missed data tokens"


class _RecordingTier(DramTier):
    """DramTier that remembers every value ever written per key."""

    def __init__(self):
        super().__init__()
        self.history = defaultdict(list)
        self._hist_lock = threading.Lock()

    def put(self, key, value):
        with self._hist_lock:
            self.history[key].append(value)
        super().put(key, value)


@settings(max_examples=10, deadline=None)
@given(
    st.integers(min_value=2, max_value=8),
    st.integers(min_value=0, max_value=2**30),
)
def test_retry_and_speculation_never_duplicate_commits(n_tasks, seed):
    rnd = random.Random(seed)
    deps = _rand_deps(rnd, n_tasks, max_deps=1)
    fail_budget = {i: rnd.randint(0, 2) for i in range(n_tasks)}
    straggles = {i: rnd.random() < 0.25 for i in range(n_tasks)}
    tier = _RecordingTier()
    journal = StateJournal(StateCache(), "prop")
    commits = defaultdict(int)
    attempts = defaultdict(int)
    lock = threading.Lock()

    def mk(i):
        def run(ctx):
            with lock:
                attempts[i] += 1
                a = attempts[i]
            if a <= fail_budget[i]:
                raise RuntimeError(f"transient #{a} in t{i}")
            if straggles[i] and a == fail_budget[i] + 1:
                time.sleep(0.12)  # bait a speculative backup
            tier.put(f"part/{i}", f"partition-{i}".encode())
            return i

        def on_complete(res):
            with lock:
                commits[i] += 1
            journal.commit(f"t{i}", {"v": res.value})

        return TaskSpec(
            f"t{i}", run, on_complete=on_complete,
            deps=frozenset(task_token(f"t{j}") for j in deps[i]),
        )

    sched = Scheduler(
        ["w0", "w1", "w2"], max_attempts=4,
        speculation_factor=1.5, min_speculation_seconds=0.03,
    )
    res = sched.run_dag([mk(i) for i in range(n_tasks)])
    assert len(res) == n_tasks
    for i in range(n_tasks):
        # exactly one commit per task, no matter how many attempts ran
        assert commits[i] == 1, f"t{i} committed {commits[i]} times"
        assert journal.committed(f"t{i}")
        # duplicate attempts may re-put the partition, but every write
        # must be byte-identical (content-addressed idempotence)
        writes = tier.history[f"part/{i}"]
        assert len(writes) >= 1
        assert all(w == writes[0] for w in writes)
    assert len(journal.entries()) == n_tasks
