"""Façade tests: golden equivalence vs the legacy entry points, unified
report schema, lazy dataset lowering, and client lifecycle.

The golden-equivalence suite is the acceptance gate for the api_redesign
PR: every path through :class:`repro.api.MarvelClient` must produce
byte-identical outputs to the legacy ``run_job`` / ``run_stages`` /
``run_loop`` call sites, and the legacy names must now be deprecation
shims that delegate to the façade.
"""

import numpy as np
import pytest

from repro.api import (
    ClientClosedError,
    ClusterConfig,
    ConfigError,
    FaultSpec,
    JobReport,
    MarvelClient,
    TierSpec,
    unify_report,
)
from repro.core import Scheduler, run_job, run_loop, run_stages
from repro.core.dataflow import Stage, StageTask
from repro.core.mapreduce import wordcount_job
from repro.core.workloads import (
    kmeans_loop,
    kmeans_points,
    pagerank_graph,
    pagerank_loop,
    terasort,
    terasort_output,
)
from repro.storage import BlockStore, DataNode, DramTier


def _corpus(n_lines=60, seed=0):
    rng = np.random.default_rng(seed)
    words = [f"w{i:02d}".encode() for i in range(20)]
    return b"\n".join(
        b" ".join(rng.choice(words, size=5)) for _ in range(n_lines)
    )


def _legacy_cluster(n=4, block_size=1 << 12):
    nodes = [DataNode(f"w{i}", DramTier()) for i in range(n)]
    store = BlockStore(nodes, block_size=block_size, replication=2)
    sched = Scheduler([nd.node_id for nd in nodes], speculation_factor=None)
    return store, sched


def _read_parts(store, path, n):
    return [
        store.read(f"{path}/part_{p:04d}")
        for p in range(n)
        if store.exists(f"{path}/part_{p:04d}")
    ]


def wc_map(rec):
    for w in rec.split():
        yield (w, 1)


def wc_reduce(k, vs):
    yield (k, sum(vs))


# -- golden equivalence --------------------------------------------------------

class TestGoldenEquivalence:
    def test_dataset_matches_legacy_run_job(self):
        data = _corpus()
        store, sched = _legacy_cluster()
        store.write("/in", data, record_delim=b"\n")
        with pytest.deprecated_call():
            run_job(wordcount_job(4), store, "/in", "/out", DramTier(), sched)
        golden = _read_parts(store, "/out", 4)
        assert golden, "legacy run produced no output"

        with MarvelClient(ClusterConfig(name="eq", tiers=("dram",))) as c:
            handle = (
                c.dataset([data], name="wc")
                .map(wc_map)
                .shuffle(partitions=4)
                .reduce(wc_reduce)
                .combine(wc_reduce)
                .run()
            )
            got = _read_parts(c.store, handle.result, 4)
        assert got == golden

    def test_mapreduce_method_matches_legacy_same_stack(self):
        """Same store/tier through both entry points → identical bytes."""
        data = _corpus(seed=3)
        store, sched = _legacy_cluster()
        store.write("/in", data, record_delim=b"\n")
        with pytest.deprecated_call():
            run_job(wordcount_job(4), store, "/in", "/legacy", DramTier(),
                    sched)
        client = MarvelClient.from_components(
            store=store, state=DramTier(), scheduler=sched,
        )
        client.mapreduce(wordcount_job(4), "/in", "/facade")
        assert _read_parts(store, "/facade", 4) == \
            _read_parts(store, "/legacy", 4)

    def test_stages_matches_legacy_run_stages(self):
        rng = np.random.default_rng(5)
        parts = [
            b"\n".join(rng.bytes(8).hex().encode() for _ in range(40))
            for _ in range(3)
        ]
        legacy_state = DramTier()
        terasort("ts", legacy_state, parts, n_ranges=3)
        golden = terasort_output(legacy_state, "ts", 3)

        with MarvelClient(ClusterConfig(name="eqts", tiers=("dram",))) as c:
            handle = c.terasort("ts", parts, n_ranges=3)
        assert handle.result == golden
        assert handle.report.kind == "stages"

    def test_iterate_matches_legacy_run_loop(self):
        src, dst = pagerank_graph(n_nodes=120, n_edges=700, seed=9)
        legacy = pagerank_loop(
            "pr", DramTier(), src, dst, 120, tol=1e-8,
            max_iterations=8, pin_state=False,
        )
        with MarvelClient(ClusterConfig(name="eqpr", tiers=("dram",))) as c:
            handle = c.pagerank("pr", src, dst, 120, tol=1e-8,
                                max_iterations=8, pin_state=False)
        assert handle.result.rank_bytes == legacy.rank_bytes
        assert handle.report.kind == "loop"
        assert handle.report.iterations == legacy.report.iterations

    def test_kmeans_matches_legacy(self):
        pts, _ = kmeans_points(n_points=120, dim=3, k=4, seed=2)
        legacy = kmeans_loop("km", DramTier(), pts, 4, tol=1e-9,
                             max_iterations=10, pin_state=False)
        with MarvelClient(ClusterConfig(name="eqkm", tiers=("dram",))) as c:
            handle = c.kmeans("km", pts, 4, tol=1e-9, max_iterations=10,
                              pin_state=False)
        assert handle.result.centroid_bytes == legacy.centroid_bytes

    def test_raw_run_stages_shim_delegates(self):
        """Bare run_stages still works (and warns) via the façade."""
        state = DramTier()

        def t1(_):
            state.put("x", b"1")
            return {}

        def t2(_):
            return {"v": state.get("x")}

        with pytest.deprecated_call():
            rep = run_stages("s", [
                Stage("a", [StageTask("t1", t1, outputs=["x"])]),
                Stage("b", [StageTask("t2", t2)]),
            ], state)
        assert rep.result("t2").value["v"] == b"1"

    def test_raw_run_loop_shim_delegates(self):
        state = DramTier()

        def init(ctx):
            ctx.write("v", b"\x00")

        def superstep(ctx):
            def bump(_):
                ctx.write("v", bytes([ctx.read("v")[0] + 1]))
                return {}

            return [Stage("s", [StageTask("bump", bump)])]

        with pytest.deprecated_call():
            rep = run_loop("l", init, superstep,
                           lambda ctx: ctx.read_current("v")[0] >= 3,
                           state, pin_state=False)
        assert rep.converged
        assert state.get("df/l/state/it00003/v") == b"\x03"


# -- journaled resume through the façade --------------------------------------

class TestFacadeResume:
    def test_dataset_journaled_resume(self):
        data = _corpus(seed=7)
        cfg = ClusterConfig(name="res", tiers=("dram",))
        with MarvelClient(cfg) as c:
            ds = (
                c.dataset([data], name="wc")
                .map(wc_map).shuffle(partitions=3).reduce(wc_reduce)
            )
            h1 = ds.run()
            first = _read_parts(c.store, h1.result, 3)
            h2 = ds.run()  # same journal, same job name → full resume
            assert h2.report.resumed_tasks == h2.report.tasks
            assert _read_parts(c.store, h2.result, 3) == first

    def test_iterate_journal_resume_byte_identical(self):
        src, dst = pagerank_graph(n_nodes=80, n_edges=500, seed=4)
        with MarvelClient(ClusterConfig(name="resl", tiers=("dram",))) as c:
            partial = c.pagerank("pr", src, dst, 80, tol=0.0,
                                 max_iterations=6, pin_state=False,
                                 halt_after=3)
            assert not partial.report.converged
            done = c.pagerank("pr", src, dst, 80, tol=0.0,
                              max_iterations=6, pin_state=False)
            assert done.report.extra["resumed_iterations"] > 0
        with MarvelClient(ClusterConfig(name="resg", tiers=("dram",))) as c:
            golden = c.pagerank("pr", src, dst, 80, tol=0.0,
                                max_iterations=6, pin_state=False)
        assert done.result.rank_bytes == golden.result.rank_bytes


# -- unified report schema -----------------------------------------------------

class TestUnifiedReport:
    def test_field_accessor_fails_loudly(self):
        rep = JobReport(job="j", kind="stages", wall_seconds=1.0)
        assert rep.field("wall_seconds") == 1.0
        assert rep.field("total_seconds") == rep.total_seconds
        with pytest.raises(KeyError, match="unknown JobReport field"):
            rep.field("walls_seconds")

    def test_unify_rejects_unknown_shapes(self):
        with pytest.raises(TypeError):
            unify_report(object())

    def test_all_kinds_share_schema(self):
        data = _corpus(seed=1)
        src, dst = pagerank_graph(n_nodes=60, n_edges=300, seed=1)
        with MarvelClient(ClusterConfig(name="sch", tiers=("dram",))) as c:
            handles = [
                c.dataset([data], name="wc").map(wc_map)
                .shuffle(partitions=2).reduce(wc_reduce).run(),
                c.terasort("ts", [data], n_ranges=2),
                c.pagerank("pr", src, dst, 60, tol=1e-6, max_iterations=4,
                           pin_state=False),
            ]
        kinds = {h.report.kind for h in handles}
        assert kinds == {"mapreduce", "stages", "loop"}
        for h in handles:
            d = h.report.to_dict()
            for key in ("wall_seconds", "modeled_io_seconds",
                        "total_seconds", "tasks", "resumed_tasks",
                        "iterations", "tiers"):
                assert key in d, (h.report.kind, key)
            assert h.report.tiers, "tier rollup missing"


# -- dataset plan validation ---------------------------------------------------

class TestDatasetPlan:
    def test_lazy_until_run(self):
        with MarvelClient(ClusterConfig(name="lazy")) as c:
            ds = c.dataset([b"a b"], name="n").map(wc_map)
            assert not c.store.exists("/api/n/in")  # nothing ran yet
            with pytest.raises(ConfigError, match="reduce"):
                ds.run()

    def test_requires_mapper(self):
        with MarvelClient(ClusterConfig(name="nomap")) as c:
            with pytest.raises(ConfigError, match="map"):
                c.dataset([b"x"], name="n").reduce(wc_reduce).run()

    def test_shuffle_by_rekeys(self):
        with MarvelClient(ClusterConfig(name="rekey")) as c:
            out = (
                c.dataset([b"aa ab ba"], name="n")
                .map(wc_map)
                .shuffle(by=lambda k: k[:1], partitions=2)
                .reduce(wc_reduce)
                .collect()
            )
        assert sorted(out) == sorted([b"b'a'\t2", b"b'b'\t1"])

    def test_anonymous_datasets_get_distinct_names(self):
        with MarvelClient(ClusterConfig(name="anon")) as c:
            a = (c.dataset([b"aaa bbb"]).map(wc_map)
                 .shuffle(partitions=2).reduce(wc_reduce))
            b = (c.dataset([b"ccc ddd"]).map(wc_map)
                 .shuffle(partitions=2).reduce(wc_reduce))
            assert a.name != b.name
            assert sorted(b.collect()) == sorted(
                [b"b'ccc'\t1", b"b'ddd'\t1"]
            )

    def test_same_name_different_input_refused(self):
        with MarvelClient(ClusterConfig(name="clash")) as c:
            (c.dataset([b"aaa"], name="n").map(wc_map)
             .shuffle(partitions=1).reduce(wc_reduce).run())
            with pytest.raises(ConfigError, match="different.*input"):
                (c.dataset([b"bbb"], name="n").map(wc_map)
                 .shuffle(partitions=1).reduce(wc_reduce).run())

    def test_plan_immutable(self):
        with MarvelClient(ClusterConfig(name="imm")) as c:
            base = c.dataset([b"x"], name="n")
            mapped = base.map(wc_map)
            assert base.mapper is None and mapped.mapper is wc_map
            with pytest.raises(ConfigError, match="already has a mapper"):
                mapped.map(wc_map)


# -- lifecycle -----------------------------------------------------------------

class TestLifecycle:
    def test_double_close(self):
        c = MarvelClient(ClusterConfig(name="dc"))
        c.close()
        c.close()  # idempotent
        assert c.closed

    def test_crash_inside_with_still_closes(self):
        with pytest.raises(RuntimeError, match="boom"):
            with MarvelClient(ClusterConfig(name="crash")) as c:
                raise RuntimeError("boom")
        assert c.closed
        # gateway rejects new work after the abortive exit
        from repro.core.gateway import GatewayClosedError

        with pytest.raises(GatewayClosedError):
            c.gateway.submit("nope")

    def test_session_after_close_raises(self):
        c = MarvelClient(ClusterConfig(name="sac"))
        c.close()
        with pytest.raises(ClientClosedError):
            c.session("s")
        with pytest.raises(ClientClosedError):
            c.dataset([b"x"])
        with pytest.raises(ClientClosedError):
            c.iterate("l", init=lambda ctx: None,
                      superstep=lambda ctx: [], until=lambda ctx: True)

    def test_reenter_after_close_raises(self):
        c = MarvelClient(ClusterConfig(name="re"))
        c.close()
        with pytest.raises(ClientClosedError):
            with c:
                pass

    def test_from_components_close_leaves_components_alive(self):
        state = DramTier()
        sched = Scheduler(["w0"])
        client = MarvelClient.from_components(state=state, scheduler=sched)
        client.close()
        state.put("k", b"v")  # still usable: the caller owns it
        assert state.get("k") == b"v"
        sched.close()

    def test_construction_failure_is_transactional(self):
        import threading

        before = {t.name for t in threading.enumerate()}
        with pytest.raises(ConfigError):
            MarvelClient(ClusterConfig(
                name="txn",
                tiers=(TierSpec("dram", capacity_bytes=1 << 20), "s3"),
                replication=9, nodes=2,  # invalid: caught by validate()
            ))
        # an unexpected mid-build failure must also tear down cleanly
        class Boom(TierSpec):
            def build(self):
                raise RuntimeError("device exploded")

        with pytest.raises(ConfigError, match="construction failed"):
            MarvelClient(ClusterConfig(name="txn2", tiers=(Boom("dram"),)))
        after = {t.name for t in threading.enumerate()}
        leaked = {t for t in after - before if t.startswith(("txn", "gw"))}
        assert not leaked, f"leaked threads: {leaked}"


# -- config surface ------------------------------------------------------------

class TestClusterConfig:
    def test_overrides_kwargs(self):
        c = MarvelClient(ClusterConfig(name="ov"), invokers=2)
        try:
            assert len(c.gateway.invokers) == 2
        finally:
            c.close()

    def test_unknown_override_raises(self):
        with pytest.raises(ConfigError, match="unknown ClusterConfig"):
            MarvelClient(ClusterConfig(), invokerz=3)

    def test_tiered_stack_with_faults(self):
        cfg = ClusterConfig(
            name="ft",
            tiers=(TierSpec("dram", capacity_bytes=1 << 20), "s3"),
            faults=FaultSpec(seed=1, schedule=(("get", 0),)),
        )
        with MarvelClient(cfg) as c:
            from repro.storage import TieredStore

            assert isinstance(c.state, TieredStore)
            assert c.state.levels[-1].tier.name.startswith("faulty:")
            c.state.put("k", b"v")
            assert c.state.get("k") == b"v"  # served from fast level

    def test_validate_rejects_bad_fault_rates(self):
        with pytest.raises(ConfigError, match="put_error_rate"):
            ClusterConfig(faults=FaultSpec(put_error_rate=1.5)).validate()
