"""Fault injection + the crash/recovery matrix.

The matrix is the paper's durability claim, tested deterministically:
for {DRAM-only, PMEM write-through} x {crash mid-invocation, crash
mid-commit (torn/failed put via FaultInjectingTier)} — write-through
sessions resume from the last commit **byte-identically**, DRAM-only
sessions report state lost and cold-start.
"""

import pytest

from repro.core import FunctionRuntime, StatefulFunction, StateJournal
from repro.storage import (
    DramTier,
    FaultInjectingTier,
    InjectedIOError,
    PmemTier,
    StateCache,
    TornWriteError,
)


def _counter_runtime(cache, commit_every=1):
    rt = FunctionRuntime(cache=cache, commit_every=commit_every)
    rt.register(
        StatefulFunction(
            "counter", lambda s, x: (s + x, s + x), init=lambda: 0, jit=False
        )
    )
    return rt


STATE_KEY = "state/a/counter"


# -- FaultInjectingTier unit behavior -----------------------------------------

def test_fault_tier_is_deterministic_per_seed():
    def run(seed):
        tier = FaultInjectingTier(DramTier(), seed=seed, put_error_rate=0.3)
        outcomes = []
        for i in range(50):
            try:
                tier.put(f"k{i}", b"v")
                outcomes.append(True)
            except InjectedIOError:
                outcomes.append(False)
        return outcomes

    assert run(7) == run(7)
    assert run(7) != run(8)  # different schedule, same shape


def test_fault_tier_scheduled_faults_fire_exactly():
    tier = FaultInjectingTier(
        DramTier(), schedule=[("put", 1), ("get", 0)]
    )
    tier.put("a", b"1")  # put #0 ok
    with pytest.raises(InjectedIOError):
        tier.put("b", b"2")  # put #1 injected
    tier.put("c", b"3")  # put #2 ok
    with pytest.raises(InjectedIOError):
        tier.get("a")  # get #0 injected
    assert tier.get("a") == b"1"
    assert tier.injected == {"put": 1, "get": 1, "torn": 0, "spike": 0}


def test_fault_tier_torn_put_many_persists_strict_prefix():
    tier = FaultInjectingTier(DramTier(), seed=3, schedule=[("torn", 0)])
    items = {f"k{i}": bytes([i]) for i in range(8)}
    with pytest.raises(TornWriteError) as ei:
        tier.put_many(items)
    landed = ei.value.landed
    assert 0 <= landed < 8
    keys = list(items)
    for i, key in enumerate(keys):
        assert tier.contains(key) == (i < landed)
    # healed tier serves normally
    tier.heal()
    tier.put_many(items)
    assert sorted(tier.keys()) == sorted(keys)


def test_fault_tier_latency_spike_delays_but_succeeds():
    tier = FaultInjectingTier(
        DramTier(), spike_seconds=0.05, schedule=[("spike", 0)]
    )
    import time

    t0 = time.perf_counter()
    tier.put("k", b"v")
    assert time.perf_counter() - t0 >= 0.05
    assert tier.get("k") == b"v"
    assert tier.injected["spike"] == 1


# -- torn journal batches ------------------------------------------------------

def test_journal_marker_never_survives_without_details():
    """commit_many_ordered puts the summary marker last, so a torn batch
    can leave details without a marker but never the reverse."""
    wt = FaultInjectingTier(DramTier(), seed=11, schedule=[("torn", 0)])
    cache = StateCache(write_through=wt)
    journal = StateJournal(cache, "mr/job")
    entries = {f"map_0.part_{p:04d}": {"bytes": p} for p in range(6)}
    entries["map_0"] = {"task": "map_0"}
    with pytest.raises(TornWriteError):
        journal.commit_many_ordered(entries, marker="map_0")
    cache.crash()  # volatile view gone; durable view = the torn prefix
    wt.heal()
    durable = set(journal.entries())
    assert "map_0" not in durable  # marker was last — cannot have landed
    # what did land is a prefix of the detail entries
    detail_order = [f"map_0.part_{p:04d}" for p in range(6)]
    assert durable == set(detail_order[: len(durable)])


# -- the crash/recovery matrix -------------------------------------------------

def _fresh(tmp_path, kind, commit_every, fault_schedule=()):
    """(runtime, faulty_tier_or_None) for one matrix cell."""
    if kind == "dram":
        memory = FaultInjectingTier(DramTier(), schedule=fault_schedule) \
            if fault_schedule else DramTier()
        return _counter_runtime(StateCache(memory=memory), commit_every), memory
    wt = PmemTier(str(tmp_path / "pmem"))
    faulty = FaultInjectingTier(wt, schedule=fault_schedule) \
        if fault_schedule else wt
    return _counter_runtime(
        StateCache(write_through=faulty), commit_every
    ), faulty


@pytest.mark.parametrize("kind", ["dram", "pmem_wt"])
def test_matrix_crash_mid_invocation(tmp_path, kind):
    rt, _ = _fresh(tmp_path, kind, commit_every=3)
    for _ in range(4):  # commit lands after invocation 3; #4 is uncommitted
        rt.invoke("counter", session="a", x=1)
    if kind == "pmem_wt":
        committed_blob = rt.cache.write_through.get(STATE_KEY)
    rt.crash()
    rt.recover()
    if kind == "pmem_wt":
        # resumes from the last commit, byte-identically
        assert rt.cache.get(STATE_KEY) == committed_blob
        assert rt.state_report("counter", "a") == "warm"
        assert rt.session("a").seq == 3  # the seq the commit reflects + 1
        assert rt.invoke("counter", session="a", x=1) == 4
    else:
        # stock-serverless: everything since birth is gone
        assert rt.state_report("counter", "a") == "lost"
        assert rt.session("a").seq == 0
        assert rt.invoke("counter", session="a", x=1) == 1
        assert rt.log[-1].cold


@pytest.mark.parametrize("kind", ["dram", "pmem_wt"])
def test_matrix_crash_mid_commit(tmp_path, kind):
    # Each invocation issues 2 durable puts (state blob, journal marker).
    # Fail the *state* put of invocation 3 -> the commit is interrupted
    # exactly between invocations 2 and 3.
    rt, faulty = _fresh(
        tmp_path, kind, commit_every=1, fault_schedule=[("put", 4)]
    )
    for _ in range(2):
        rt.invoke("counter", session="a", x=1)
    if kind == "pmem_wt":
        committed_blob = faulty.get(STATE_KEY)
    with pytest.raises(InjectedIOError):
        rt.invoke("counter", session="a", x=1)
    rt.crash()
    faulty.heal()
    rt.recover()
    if kind == "pmem_wt":
        # the torn commit must not have corrupted the durable state: the
        # session resumes from the previous commit byte-identically
        assert rt.cache.get(STATE_KEY) == committed_blob
        assert rt.state_report("counter", "a") == "warm"
        assert rt.session("a").seq == 2
        # the value of the failed invocation 3 was recorded nowhere —
        # re-running it converges to the same result
        assert rt.invoke("counter", session="a", x=1) == 3
    else:
        assert rt.state_report("counter", "a") == "lost"
        assert rt.invoke("counter", session="a", x=1) == 1


def _hierarchy_runtime(tmp_path, commit_every=1, torn_rate=0.0):
    """Matrix extension: runtime state on a write-back TieredStore whose
    home level (PMEM) can fault, with the redo journal on its own durable
    cache — the Ignite-over-PMEM configuration of DESIGN.md §7."""
    from repro.storage import PlacementPolicy, TieredStore, TierLevel

    journal = StateCache(write_through=PmemTier(str(tmp_path / "jrnl")))
    home = PmemTier(str(tmp_path / "home"))
    faulty = (
        FaultInjectingTier(home, torn_put_many_rate=torn_rate)
        if torn_rate else home
    )
    hier = TieredStore(
        [TierLevel("dram", DramTier(), None), TierLevel("pmem", faulty)],
        policy=PlacementPolicy(write_back=True, flush_interval=0.005),
        journal=journal, name="state",
    )
    rt = _counter_runtime(StateCache(memory=hier), commit_every)
    return rt, hier, journal, faulty


def test_matrix_crash_mid_invocation_hierarchy(tmp_path):
    """Write-back hierarchy cell: commits ack at DRAM latency, yet a
    crash after the 4th (uncommitted) invocation resumes from the last
    commit byte-identically — the redo journal covers whatever the
    background flusher had not drained yet."""
    rt, hier, journal, _ = _hierarchy_runtime(tmp_path, commit_every=3)
    for _ in range(4):
        rt.invoke("counter", session="a", x=1)
    committed_blob = rt.cache.get(STATE_KEY)
    rt.crash()  # hierarchy loses its DRAM level only
    journal.crash()  # the journal's volatile view dies too
    journal.recover()
    rt.recover()
    assert rt.cache.get(STATE_KEY) == committed_blob
    assert rt.state_report("counter", "a") in ("warm", "hot")
    assert rt.session("a").seq == 3
    assert rt.invoke("counter", session="a", x=1) == 4
    hier.close()


def test_matrix_crash_mid_flush_hierarchy(tmp_path):
    """Write-back hierarchy cell, torn-flush variant: every home flush
    tears before the crash, so the acked commits exist *only* in DRAM +
    journal at crash time.  Recovery must still be byte-identical (a
    torn flush may never lose an acked write)."""
    rt, hier, journal, faulty = _hierarchy_runtime(
        tmp_path, commit_every=1, torn_rate=1.0,
    )
    for _ in range(3):
        rt.invoke("counter", session="a", x=1)
    committed_blob = rt.cache.get(STATE_KEY)
    assert hier.dirty_keys  # flusher could not drain anything
    rt.crash()
    journal.crash()
    journal.recover()
    faulty.heal()
    rt.recover()
    assert rt.cache.get(STATE_KEY) == committed_blob
    assert rt.session("a").seq == 3
    assert rt.invoke("counter", session="a", x=1) == 4
    hier.flush()
    assert hier.dirty_keys == []
    hier.close()


def test_matrix_crash_torn_group_commit(tmp_path):
    """Group-commit cell: a torn ``put_many`` mid flush round.  The batch
    is pair-adjacent (blob_a, marker_a, blob_b, marker_b, ...), so the
    strict-prefix tear can strand at most one blob without its marker
    and **never** a marker without its blob; every marker-landed session
    resumes byte-identically at its last committed state."""
    wt = PmemTier(str(tmp_path / "pmem"))
    # seed 7 tears after 5 of the 8 batch items: sessions 0-1 land both
    # blob and marker, session 2's blob is stranded without its marker,
    # session 3 loses both — all three recovery classes in one cell.
    faulty = FaultInjectingTier(wt, seed=7, schedule=[("torn", 0)])
    cache = StateCache(write_through=faulty)
    rt = FunctionRuntime(
        cache=cache, commit_every=1, group_commit=True, flush_interval=0.2
    )
    rt.register(
        StatefulFunction(
            "counter", lambda s, x: (s + x, s + x), init=lambda: 0, jit=False
        )
    )
    sessions = [f"s{i}" for i in range(4)]
    tickets, expected = {}, {}
    # deferred commits pile into one flush round (the 0.2s accumulation
    # window opens at the first enqueue; the rest land microseconds later)
    for s in sessions:
        _, rec = rt.invoke_with_record(
            "counter", session=s, defer_commit=True, x=1
        )
        tickets[s] = rec.commit_ticket
        expected[s] = rt.state_bytes("counter", s)
    for s in sessions:
        with pytest.raises(TornWriteError):
            tickets[s].wait(timeout=10)
    rt.crash()
    faulty.heal()
    blobs = {s for s in sessions if wt.contains(f"state/{s}/counter")}
    markers = {s for s in sessions if wt.contains(f"fn/done/{s}/counter")}
    # the pair-adjacency invariant on the durable prefix
    assert markers <= blobs, "a journal marker landed without its blob"
    assert len(blobs - markers) <= 1, "tear stranded more than one blob"
    # enqueue order == flush order: what landed is a session prefix
    assert sorted(blobs) == sessions[: len(blobs)]
    rt.recover()
    for s in sessions:
        if s in markers:
            # acked-at-marker sessions resume byte-identically
            assert rt.cache.get(f"state/{s}/counter") == expected[s]
            assert rt.state_report("counter", s) == "warm"
            assert rt.session(s).seq == 1
            assert rt.invoke("counter", session=s, x=1) == 2
        elif s not in blobs:
            # fully-lost sessions cold-start from scratch
            assert rt.state_report("counter", s) == "lost"
            assert rt.invoke("counter", session=s, x=1) == 1
    rt.close()


# -- mid-iteration cells: the iterative dataflow loop --------------------------

def _loop_stack(tmp_path):
    """Loop state on a write-back TieredStore with a PMEM home + durable
    redo journal, loop markers on a PMEM write-through cache — the full
    iterative-dataflow durability stack (DESIGN.md §8)."""
    from repro.storage import PlacementPolicy, TieredStore, TierLevel

    redo = StateCache(write_through=PmemTier(str(tmp_path / "redo")))
    store = TieredStore(
        [
            TierLevel("dram", DramTier(), None),
            TierLevel("pmem", PmemTier(str(tmp_path / "home"))),
        ],
        policy=PlacementPolicy(write_back=True, flush_interval=0.002),
        journal=redo, name="loop",
    )
    journal = StateCache(write_through=PmemTier(str(tmp_path / "jrnl")))
    return store, redo, journal


def _crash_loop_stack(store, redo, journal):
    store.crash()  # DRAM level gone
    journal.crash()
    journal.recover()  # loop markers back from PMEM
    redo.crash()
    redo.recover()  # redo records back from PMEM
    store.recover()  # acked-unflushed state replayed


@pytest.mark.parametrize("workload", ["pagerank", "kmeans"])
@pytest.mark.parametrize("cell", ["between_supersteps", "mid_superstep"])
def test_matrix_crash_mid_iteration(tmp_path, cell, workload):
    """Matrix extension: kill an iterative dataflow job {between
    supersteps, mid-superstep (partial next-version state, no marker)} —
    the journal-resumed run recomputes nothing that committed and its
    final output is byte-identical to an uninterrupted run."""
    import numpy as np

    from repro.core import Scheduler
    from repro.core.workloads import (
        kmeans_loop, kmeans_points, pagerank_graph, pagerank_loop,
    )

    def sched():
        return Scheduler(["w0", "w1"], speculation_factor=None)

    if workload == "pagerank":
        src, dst = pagerank_graph(90, 500, seed=21)

        def run(state, journal, **kw):
            res = pagerank_loop(
                "mx", state, src, dst, 90, n_parts=2, tol=0.0,
                max_iterations=5, journal=journal, scheduler=sched(), **kw
            )
            return res.report, res.rank_bytes
    else:
        pts, _ = kmeans_points(160, 2, 3, seed=22)

        def run(state, journal, **kw):
            res = kmeans_loop(
                "mx", state, pts, 3, n_parts=2, tol=0.0,
                max_iterations=5, journal=journal, scheduler=sched(), **kw
            )
            return res.report, res.centroid_bytes

    _, golden_bytes = run(DramTier(), None)
    store, redo, journal = _loop_stack(tmp_path)
    try:
        first, _ = run(store, journal, halt_after=3)
        assert first.last_iteration == 2  # init + 2 supersteps committed
        if cell == "mid_superstep":
            # superstep 3 died after (some) state landed, before its marker
            store.put("df/mx/state/it00003/partial", b"garbage")
        _crash_loop_stack(store, redo, journal)
        second, got_bytes = run(store, journal)
        assert second.resumed_iterations == first.iterations
        assert second.last_iteration == 5
        assert got_bytes == golden_bytes
    finally:
        store.close()


def test_serde_state_roundtrip_is_byte_identical(tmp_path):
    """The byte-identical recovery claim requires dumps(loads(x)) == x —
    including NamedTuple nodes (attention KV caches), which a previous
    serde version silently collapsed into plain tuples."""
    import jax.numpy as jnp

    from repro.models.attention import AttnCache
    from repro.storage import serde

    state = {
        "cache": AttnCache(
            jnp.arange(12.0).reshape(1, 3, 2, 2),
            jnp.ones((1, 3, 2, 2)),
        ),
        "t": 3,
        "nested": [(1, 2), None],
    }
    blob = serde.dumps(state)
    restored = serde.loads(blob)
    assert isinstance(restored["cache"], AttnCache)
    assert serde.dumps(restored) == blob
