"""Multi-device tests — run in subprocesses so XLA_FLAGS device forcing
never leaks into the single-device test session."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_device_histogram_multidevice():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import device_histogram
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((8,), ("data",))
        rng = np.random.default_rng(0)
        vocab, n = 101, 8 * 64
        keys = rng.integers(0, vocab, n).astype(np.int32)
        res = device_histogram(jnp.asarray(keys), jnp.ones(n, jnp.float32),
                               mesh, "data", vocab=vocab, capacity_factor=8.0)
        np.testing.assert_allclose(np.asarray(res.counts),
                                   np.bincount(keys, minlength=vocab))
        assert int(res.dropped) == 0
        print("OK")
    """))


def test_moe_a2a_matches_dense_oracle():
    """The shard_map EP dispatch == the dense reference, on a 2x4 mesh."""
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from dataclasses import replace
        from repro.configs import get_config
        from repro.models import reduced_for_smoke, init_params
        from repro.models.moe import moe_defs, moe_apply_a2a, moe_apply_dense, moe_apply_gather
        cfg = reduced_for_smoke(get_config("deepseek-v2-lite-16b"))
        cfg = replace(cfg, moe=replace(cfg.moe, n_experts=8, top_k=2,
                                       capacity_factor=16.0))
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        defs = moe_defs(cfg)
        params = init_params(defs, jax.random.PRNGKey(0))
        params = jax.tree_util.tree_map(
            lambda x: x.astype(jnp.float32), params)
        B, T = 4, 8  # T divisible by model axis (4)
        x = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model),
                              jnp.float32)
        ref_out, ref_aux = moe_apply_dense(params, x, cfg)
        got, aux = moe_apply_a2a(params, x, cfg, mesh, ("data",), "model")
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref_out),
                                   atol=2e-4, rtol=2e-4)
        # aux is a per-shard load-balance estimate (pmean of local stats),
        # not bit-identical to the global one — just sanity-bound it
        assert 0.5 * float(ref_aux) < float(aux) < 2.0 * float(ref_aux)
        got2, aux2 = moe_apply_gather(params, x, cfg, mesh, ("data",), "model")
        np.testing.assert_allclose(np.asarray(got2), np.asarray(ref_out),
                                   atol=2e-4, rtol=2e-4)
        print("OK")
    """, devices=8))


def test_mesh_construction_512():
    print(_run("""
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        assert m1.devices.shape == (16, 16)
        assert m1.axis_names == ("data", "model")
        m2 = make_production_mesh(multi_pod=True)
        assert m2.devices.shape == (2, 16, 16)
        assert m2.axis_names == ("pod", "data", "model")
        print("OK")
    """, devices=512))


@pytest.mark.parametrize("arch,shape", [
    ("gemma-2b", "decode_32k"),
    ("mamba2-2.7b", "long_500k"),
])
def test_dryrun_cell_compiles_single_pod(arch, shape):
    out = _run(f"""
        from repro.launch.dryrun import run_cell
        rec = run_cell("{arch}", "{shape}", multi_pod=False)
        assert rec["status"] == "ok", rec
        assert rec["coll_bytes"] >= 0
        assert rec["flops"] > 0
        print("OK", rec["bottleneck"])
    """, devices=512)
    assert "OK" in out


def test_dryrun_cell_compiles_multi_pod():
    out = _run("""
        from repro.launch.dryrun import run_cell
        rec = run_cell("gemma-2b", "decode_32k", multi_pod=True)
        assert rec["status"] == "ok", rec
        assert rec["mesh"] == "2x16x16"
        print("OK")
    """, devices=512)
    assert "OK" in out


def test_dryrun_skips_are_principled():
    out = _run("""
        from repro.launch.dryrun import run_cell
        rec = run_cell("hubert-xlarge", "decode_32k", multi_pod=False)
        assert rec["status"] == "skipped" and "encoder" in rec["reason"]
        rec = run_cell("qwen2.5-3b", "long_500k", multi_pod=False)
        assert rec["status"] == "skipped" and "quadratic" in rec["reason"]
        print("OK")
    """, devices=512)
    assert "OK" in out


def test_sharded_train_step_runs_numerically():
    """Real sharded execution (2x4 mesh): loss finite and decreasing."""
    print(_run("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.data.pipeline import PipelineConfig, make_batch
        from repro.launch.steps import make_train_step
        from repro.models import ShapeConfig, init_params, model_defs, reduced_for_smoke
        from repro.optim.adamw import AdamWConfig, adamw_init
        cfg = reduced_for_smoke(get_config("qwen2.5-3b"))
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        shape = ShapeConfig(name="t", kind="train", seq_len=64,
                            global_batch=8, microbatches=2, q_chunk=32,
                            kv_chunk=32, loss_chunk=32, remat="none")
        bundle = make_train_step(cfg, shape, mesh,
                                 AdamWConfig(lr=3e-3, weight_decay=0.0))
        fn = bundle.jitted(mesh)
        params = jax.tree_util.tree_map(
            lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x,
            init_params(model_defs(cfg), jax.random.PRNGKey(0)))
        opt = adamw_init(params)
        pipe = PipelineConfig(vocab=cfg.vocab, seq_len=64, global_batch=8)
        losses = []
        for step in range(8):
            batch = {k: jnp.asarray(v) for k, v in make_batch(pipe, step).items()}
            params, opt, m = fn(params, opt, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0], losses
        print("OK", [round(l, 3) for l in losses])
    """, devices=8))
