"""Gateway: concurrent multi-tenant routing, leases, warm pool, admission.

The stress test is the PR's acceptance gate: N invokers x M sessions x K
invocations/session with a counter function must show (a) no lost updates
(per-session final state == K * delta), (b) per-session
``InvocationRecord.seq`` strictly increasing in execution order, and
(c) cross-session isolation (distinct deltas never bleed).
"""

import os
import threading
import time

import pytest

from repro.core import (
    AdmissionError,
    FunctionRuntime,
    Gateway,
    GatewayClosedError,
    StatefulFunction,
    run_job,
)
from repro.core.mapreduce import wordcount_job
from repro.storage import (
    BlockStore,
    DataNode,
    DramTier,
    PmemTier,
    StateCache,
)


def _counter_runtime(cache=None, commit_every=1):
    rt = FunctionRuntime(cache=cache or StateCache(), commit_every=commit_every)
    rt.register(
        StatefulFunction(
            "counter", lambda s, x: (s + x, s + x), init=lambda: 0, jit=False
        )
    )
    return rt


def _gather(futures, timeout=60.0):
    deadline = time.monotonic() + timeout
    return [f.result(timeout=max(0.1, deadline - time.monotonic()))
            for f in futures]


# -- the acceptance stress test ------------------------------------------------

#: nightly stress (.github/workflows/stress.yml) sets STRESS_SCALE=10 to
#: multiply the invocation volume — rare interleavings need iterations.
STRESS_SCALE = max(1, int(os.environ.get("STRESS_SCALE", "1")))


def test_gateway_stress_no_lost_updates_and_fifo():
    n_invokers, n_sessions, k = 8, 32, 50 * STRESS_SCALE
    rt = _counter_runtime()
    gw = Gateway(rt, invokers=n_invokers, warm_pool=n_sessions)
    try:
        futures = []
        # interleave submissions across sessions (worst-case routing churn)
        for _ in range(k):
            for s in range(n_sessions):
                futures.append(
                    gw.submit("counter", session=f"s{s:02d}", x=s + 1)
                )
        _gather(futures)
        # (a) no lost updates + (c) isolation: each session's counter saw
        # exactly its own k increments of its own delta
        for s in range(n_sessions):
            final = gw.invoke("counter", session=f"s{s:02d}", x=0)
            assert final == k * (s + 1), f"session s{s:02d}: {final}"
        # (b) per-session seq strictly increasing in execution (log) order
        per_session = {}
        for rec in rt.log:
            per_session.setdefault(rec.session, []).append(rec.seq)
        assert len(per_session) == n_sessions
        for sid, seqs in per_session.items():
            assert seqs == list(range(len(seqs))), f"{sid}: {seqs[:10]}..."
        # completion bookkeeping intentionally trails Future resolution
        # (the warm path never waits on accounting) — sync before counting
        assert gw.quiesce(timeout=10)
        stats = gw.stats()
        assert stats.completed == n_sessions * (k + 1)
        assert stats.inflight == 0
        # work actually spread across the pool
        busy = [s for s in stats.invokers if s.invocations > 0]
        assert len(busy) >= 2
    finally:
        gw.close()


def test_gateway_per_session_fifo_order():
    """Inputs drain in submit order per session even across invokers."""
    rt = FunctionRuntime(cache=StateCache())
    rt.register(
        StatefulFunction(
            "trace", lambda s, x: (s + [x], s + [x]),
            init=lambda: [], jit=False,
        )
    )
    gw = Gateway(rt, invokers=4, warm_pool=16)
    try:
        futures = []
        for i in range(40):
            for s in ("a", "b", "c"):
                futures.append(gw.submit("trace", session=s, x=i))
        _gather(futures)
        for s in ("a", "b", "c"):
            assert rt.peek_state("trace", s) == list(range(40))
    finally:
        gw.close()


# -- warm pool ----------------------------------------------------------------

def test_warm_pool_lru_eviction_and_reload():
    rt = _counter_runtime()
    gw = Gateway(rt, invokers=2, warm_pool=2)
    try:
        for s in range(6):
            gw.invoke("counter", session=f"s{s}", x=10)
        assert len(gw.warm_contexts()) <= 2
        assert gw.stats().evictions >= 4
        # evicted contexts were committed, not dropped: state survives
        for s in range(6):
            assert gw.invoke("counter", session=f"s{s}", x=1) == 11
        st = gw.stats()
        # round-robin over 6 sessions with capacity 2 thrashes the LRU:
        # every re-visit is a cold reload (6 inits + 6 reloads)
        assert st.cold_starts == 12
        # an immediate re-invocation of the most recent session is warm
        assert gw.invoke("counter", session="s5", x=0) == 11
        assert gw.stats().warm_hits == 1
    finally:
        gw.close()


def test_warm_hit_vs_cold_reload_recorded():
    rt = _counter_runtime()
    gw = Gateway(rt, invokers=1, warm_pool=1)
    try:
        gw.invoke("counter", session="a", x=1)   # cold init
        gw.invoke("counter", session="a", x=1)   # warm hit
        gw.invoke("counter", session="b", x=1)   # cold init, evicts a
        gw.invoke("counter", session="a", x=1)   # cold reload from cache
        flags = [(r.session, r.cold, r.warm) for r in rt.log]
        assert flags == [
            ("a", True, False), ("a", False, True),
            ("b", True, False), ("a", False, False),
        ]
        assert rt.peek_state("counter", "a") == 3
    finally:
        gw.close()


# -- admission control --------------------------------------------------------

def test_admission_control_sheds_and_backpressures():
    rt = _counter_runtime()
    release = threading.Event()
    rt.register(
        StatefulFunction(
            "slow", lambda s: (s, release.wait(10)), init=lambda: 0, jit=False
        )
    )
    gw = Gateway(rt, invokers=2, warm_pool=8, target_inflight=2)
    try:
        f1 = gw.submit("slow", session="a")
        f2 = gw.submit("slow", session="b")
        with pytest.raises(AdmissionError):
            gw.submit("counter", session="c", block=False, x=1)
        with pytest.raises(AdmissionError):
            gw.submit("counter", session="c", timeout=0.05, x=1)
        assert gw.stats().rejected == 2
        release.set()
        _gather([f1, f2])
        # capacity freed — admitted again
        assert gw.invoke("counter", session="c", x=5) == 5
    finally:
        release.set()
        gw.close()


# -- autoscaling + shared worker pool -----------------------------------------

def test_autoscaling_live_and_shared_scheduler_tracks_pool():
    rt = _counter_runtime()
    gw = Gateway(rt, invokers=1, warm_pool=8)
    try:
        sched = gw.shared_scheduler()
        assert sched.workers == gw.invokers
        gw.add_invokers(3)
        assert len(gw.invokers) == 4
        assert sorted(sched.workers) == gw.invokers
        # traffic keeps flowing across a live resize
        futures = [
            gw.submit("counter", session=f"s{i % 4}", x=1) for i in range(40)
        ]
        gw.remove_invokers(2)
        _gather(futures)
        deadline = time.monotonic() + 5
        while len(gw.invokers) != 2 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert len(gw.invokers) == 2
        assert sorted(sched.workers) == gw.invokers
        with pytest.raises(ValueError):
            gw.remove_invokers(2)  # must keep >= 1
        total = sum(
            rt.peek_state("counter", f"s{i}") for i in range(4)
        )
        assert total == 40
    finally:
        gw.close()


def test_back_to_back_scale_down_cannot_drain_pool():
    """Queued-but-unconsumed retire tokens count against capacity, so
    repeated scale-downs can never remove the last invoker."""
    rt = _counter_runtime()
    gw = Gateway(rt, invokers=4, warm_pool=8)
    try:
        gw.remove_invokers(3)  # may not be consumed yet
        with pytest.raises(ValueError):
            gw.remove_invokers(1)
        # the pool still serves
        assert gw.invoke("counter", session="x", x=2) == 2
    finally:
        gw.close()


def test_mapreduce_runs_on_gateway_invoker_pool():
    """MapReduce is just another tenant of the gateway's worker pool."""
    rt = _counter_runtime()
    gw = Gateway(rt, invokers=3, warm_pool=8)
    try:
        nodes = [DataNode(w, DramTier()) for w in gw.invokers]
        bs = BlockStore(nodes, block_size=600, replication=2)
        bs.write("/in", b"\n".join([b"x y x"] * 100), record_delim=b"\n")
        rep = run_job(
            wordcount_job(2), bs, "/in", "/out", DramTier(), gateway=gw
        )
        assert rep.output_bytes > 0
        # function traffic still serves while/after the job
        assert gw.invoke("counter", session="mt", x=7) == 7
    finally:
        gw.close()


# -- lifecycle ----------------------------------------------------------------

def test_close_drains_then_rejects():
    rt = _counter_runtime()
    gw = Gateway(rt, invokers=2, warm_pool=8)
    futures = [gw.submit("counter", session=f"s{i % 3}", x=1) for i in range(30)]
    gw.close(drain=True)
    assert all(f.done() for f in futures)
    _gather(futures)
    with pytest.raises(GatewayClosedError):
        gw.submit("counter", session="s0", x=1)


def test_session_routes_through_gateway():
    rt = _counter_runtime()
    gw = Gateway(rt, invokers=2, warm_pool=8)
    try:
        sess = gw.session("chat", app="tenant1")
        assert sess.invoke("counter", x=3) == 3
        assert sess.invoke("counter", x=4) == 7
        assert sess.seq == 2
        # app-scoped: another tenant's same-named session is isolated
        other = gw.session("chat", app="tenant2")
        assert other.invoke("counter", x=1) == 1
    finally:
        gw.close()


def test_per_invoker_tier_accounting(tmp_path):
    """Invoker stats carry that worker's share of tier I/O."""
    rt = _counter_runtime(
        cache=StateCache(write_through=PmemTier(str(tmp_path)))
    )
    gw = Gateway(rt, invokers=2, warm_pool=8)
    try:
        futures = [
            gw.submit("counter", session=f"s{i % 8}", x=1) for i in range(64)
        ]
        _gather(futures)
        st = gw.stats()
        per_invoker_writes = sum(s.tier.bytes_written for s in st.invokers)
        assert per_invoker_writes > 0
        # every write is attributed to exactly one invoker: the scoped sum
        # equals the global per-tier counters (DRAM view + write-through)
        global_writes = (
            rt.cache.memory.stats.bytes_written
            + rt.cache.write_through.stats.bytes_written
        )
        assert per_invoker_writes == global_writes
    finally:
        gw.close()


def test_striped_tier_accounting_rollup(tmp_path):
    """Striped-path variant: with group commit on, the deferred blob and
    marker writes land on the flusher thread (scoped to the committer's
    stats, not any invoker's).  The merged ``GatewayStats.tier`` rollup
    must equal the global tier counters exactly — every physical op
    attributed to exactly one scope, none double counted."""
    rt = FunctionRuntime(
        cache=StateCache(write_through=PmemTier(str(tmp_path))),
        commit_every=1, group_commit=True,
    )
    rt.register(
        StatefulFunction(
            "counter", lambda s, x: (s + x, s + x), init=lambda: 0, jit=False
        )
    )
    gw = Gateway(rt, invokers=4, warm_pool=8, stripes=4)
    try:
        futures = [
            gw.submit("counter", session=f"s{i % 8}", x=1) for i in range(64)
        ]
        _gather(futures)
        rt.commit_all()  # drain the committer: all deferred I/O has landed
        st = gw.stats()
        invoker_writes = sum(s.tier.bytes_written for s in st.invokers)
        committer_writes = rt._committer.stats.bytes_written
        assert committer_writes > 0  # commits really ran on the flusher
        global_writes = (
            rt.cache.memory.stats.bytes_written
            + rt.cache.write_through.stats.bytes_written
        )
        assert st.tier.bytes_written == invoker_writes + committer_writes
        assert st.tier.bytes_written == global_writes
    finally:
        gw.close()
        rt.close()
