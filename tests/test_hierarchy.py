"""Adaptive tier hierarchy: placement policy, write-back, prefetch, wiring.

Covers the `TieredStore` state machine (DESIGN.md §7) and its integration
points: the gateway warm-pool demotion, the StateCache/FunctionRuntime
state path, hierarchy-backed BlockStore DataNodes, and the adaptive
(write-back) MapReduce shuffle.
"""

import time

import pytest

from repro.core import FunctionRuntime, Gateway, StatefulFunction, run_job
from repro.core.mapreduce import wordcount_job
from repro.storage import (
    S3_SPEC,
    BlockStore,
    DataNode,
    DeviceSpec,
    DramTier,
    FaultInjectingTier,
    PlacementPolicy,
    PmemTier,
    SimulatedTier,
    StateCache,
    TieredStore,
    TierLevel,
)


def _stack(cap0=None, policy=None, journal=None, home=None, name="hier"):
    home = home if home is not None else SimulatedTier(S3_SPEC)
    return TieredStore(
        [TierLevel("dram", DramTier(), cap0), TierLevel("home", home)],
        policy=policy, journal=journal, name=name,
    ), home


# -- placement: promotion / demotion ------------------------------------------

def test_put_lands_fast_and_get_serves_fast():
    store, home = _stack()
    store.put("k", b"v" * 100)
    assert store.level_of("k") == "dram"
    base = store.stats.modeled_seconds
    assert store.get("k") == b"v" * 100
    assert store.stats.modeled_seconds == base  # no device time inline
    store.close()


def test_capacity_triggers_demotion_and_read_promotes_back():
    store, home = _stack(cap0=100, policy=PlacementPolicy(promote_after=2))
    store.put("a", b"x" * 60)
    store.put("b", b"y" * 60)  # overflows: LRU victim "a" demoted
    assert store.level_of("a") == "home"
    assert store.level_of("b") == "dram"
    assert store.get("a") == b"x" * 60  # 1st lower-level hit: stays
    assert store.level_of("a") == "home"
    assert store.get("a") == b"x" * 60  # 2nd hit clears admission
    assert store.level_of("a") == "dram"
    assert store.promotions == 1
    store.close()


def test_size_aware_admission_never_promotes_huge_keys():
    store, _ = _stack(
        cap0=10_000,
        policy=PlacementPolicy(promote_after=1, max_promote_bytes=64),
    )
    store.put("big", b"z" * 500)
    store.demote("big")
    for _ in range(5):
        store.get("big")
    assert store.level_of("big") == "home"  # too big to admit
    store.put("small", b"s" * 10)
    store.demote("small")
    store.get("small")
    assert store.level_of("small") == "dram"
    store.close()


def test_cost_aware_eviction_prefers_big_cold_keys():
    store, _ = _stack(
        cap0=200, policy=PlacementPolicy(eviction="cost", promote_after=99)
    )
    store.put("bigcold", b"b" * 150)
    store.put("smallhot", b"s" * 40)
    for _ in range(4):
        store.get("smallhot")  # hits-per-byte: high
    store.put("new", b"n" * 100)  # overflow: must evict someone
    assert store.level_of("bigcold") == "home"
    assert store.level_of("smallhot") == "dram"
    store.close()


def test_demote_walks_down_one_level_per_call():
    mid = SimulatedTier(S3_SPEC)
    bottom = DramTier()
    store = TieredStore(
        [TierLevel("l0", DramTier(), None), TierLevel("l1", mid, None),
         TierLevel("l2", bottom)],
    )
    store.put("k", b"v")
    assert store.level_of("k") == "l0"
    assert store.demote("k")
    assert store.level_of("k") == "l1"
    assert store.demote("k")
    assert store.level_of("k") == "l2"
    assert not store.demote("k")  # already home
    assert store.get("k") == b"v"
    store.close()


def test_adopts_preexisting_data_in_lower_tiers():
    home = SimulatedTier(S3_SPEC)
    home.put("legacy", b"old-data")
    store = TieredStore(
        [TierLevel("dram", DramTier(), None), TierLevel("home", home)]
    )
    assert store.contains("legacy")
    assert store.get("legacy") == b"old-data"
    assert store.level_of("legacy") == "home"
    store.close()


# -- write-back ----------------------------------------------------------------

def test_write_back_acks_fast_and_flushes_home():
    store, home = _stack(policy=PlacementPolicy(write_back=True))
    base = store.stats.modeled_seconds
    store.put("k", b"v" * 1000)
    assert store.stats.modeled_seconds == base  # S3 latency off hot path
    store.flush()
    assert home.contains("k")
    assert store.dirty_keys == []
    store.close()


def test_flusher_batches_via_put_many():
    # Per-op latency is huge; a batched flush charges it once per round,
    # not once per key (the SimulatedTier.put_many contract).
    spec = DeviceSpec(name="slow", read_bw=1e9, write_bw=1e9,
                      read_latency=1.0, write_latency=1.0)
    home = SimulatedTier(spec)
    store = TieredStore(
        [TierLevel("dram", DramTier(), None), TierLevel("home", home)],
        policy=PlacementPolicy(write_back=True, flush_batch=64),
    )
    store.put_many({f"k{i}": b"v" for i in range(50)})
    store.flush()
    assert home.stats.write_ops == 50
    assert home.stats.modeled_seconds < 3.0  # ~1 request, never ~50
    store.close()


def test_torn_flush_never_loses_acked_writes(tmp_path):
    journal = StateCache(write_through=PmemTier(str(tmp_path / "j")))
    home = FaultInjectingTier(
        PmemTier(str(tmp_path / "home")), seed=3, torn_put_many_rate=1.0
    )
    store = TieredStore(
        [TierLevel("dram", DramTier(), None), TierLevel("home", home)],
        policy=PlacementPolicy(write_back=True, flush_interval=0.005),
        journal=journal, name="wb",
    )
    items = {f"k{i}": bytes([65 + i]) * 20 for i in range(8)}
    store.put_many(items)  # acked
    deadline = time.monotonic() + 10.0
    while store.flush_errors == 0 and time.monotonic() < deadline:
        time.sleep(0.005)  # torn flush rounds fail behind our back
    assert store.flush_errors > 0
    for k, v in items.items():
        assert store.get(k) == v  # still served from the fast level
    # crash with keys still dirty: the journal replays every acked put
    store.crash()
    assert store.recover() == len(items)
    home.heal()
    store.flush()
    for k, v in items.items():
        assert home.get(k) == v
    store.close()


def test_write_back_survives_process_restart(tmp_path):
    jpath, hpath = str(tmp_path / "j"), str(tmp_path / "home")

    def build():
        journal = StateCache(write_through=PmemTier(jpath))
        journal.recover()
        # torn batches keep flushes failing -> dirty at "process death"
        home = FaultInjectingTier(PmemTier(hpath), seed=1,
                                  torn_put_many_rate=1.0)
        return TieredStore(
            [TierLevel("dram", DramTier(), None), TierLevel("home", home)],
            policy=PlacementPolicy(write_back=True, flush_interval=5.0),
            journal=journal, name="wb",
        ), home

    s1, h1 = build()
    s1.put("durable", b"ack-then-die")
    del s1  # no close/flush: the process dies

    s2, h2 = build()
    assert s2.recover() == 1
    assert s2.get("durable") == b"ack-then-die"
    h2.heal()
    s2.flush()
    assert h2.get("durable") == b"ack-then-die"
    s2.close()


def test_demote_skips_keys_pinned_by_inflight_flush():
    """A key snapshotted by an unresolved flush round must not be
    demoted into the home level: the in-flight (older) batch write could
    clobber the newer home copy after its dirty record was cleared."""
    store, _ = _stack(policy=PlacementPolicy(write_back=True))
    store.put("k", b"v")
    with store._mutex:
        store._inflight_flush.add("k")
    assert not store.demote("k")  # pinned while the round is in flight
    assert store.level_of("k") == "dram"
    with store._mutex:
        store._inflight_flush.discard("k")
    assert store.demote("k")
    assert store.level_of("k") == "home"
    assert store.get("k") == b"v"
    store.close()


# -- stats: logical vs physical rollup ----------------------------------------

def test_promoted_read_counts_once_logically():
    store, home = _stack(policy=PlacementPolicy(promote_after=1))
    store.put("k", b"v" * 100)
    store.demote("k")
    n_reads = store.stats.read_ops
    assert store.get("k") == b"v" * 100  # hit at home + promotion
    assert store.stats.read_ops == n_reads + 1  # one logical read
    # physically: a home read and a fast-level write happened
    rolled = store.physical_stats()
    assert rolled.read_ops >= 1 and rolled.write_ops >= 2
    by_level = store.stats_by_level()
    assert by_level["home"].read_ops == 1
    store.close()


def test_hit_rates_roll_up_per_level():
    store, _ = _stack(policy=PlacementPolicy(promote_after=99))
    store.put("hot", b"h")
    store.put("cold", b"c")
    store.demote("cold")
    for _ in range(3):
        store.get("hot")
    store.get("cold")
    rates = store.hit_rates()
    assert rates["dram"] == pytest.approx(0.75)
    assert rates["home"] == pytest.approx(0.25)
    store.close()


# -- prefetch ------------------------------------------------------------------

def test_prefetch_pulls_producer_commits_into_fast_tier():
    shared = SimulatedTier(S3_SPEC)
    producer = TieredStore(
        [TierLevel("dram", DramTier(), None), TierLevel("s3", shared)],
        policy=PlacementPolicy(write_back=True, flush_interval=0.005),
        name="prod",
    )
    consumer = TieredStore(
        [TierLevel("dram", DramTier(), None), TierLevel("s3", shared)],
        policy=PlacementPolicy(write_back=True), name="cons",
    )
    consumer.prefetch("shuffle/")
    producer.put_many({f"shuffle/p{i}": b"d" * 64 for i in range(4)})
    producer.flush()
    deadline = time.monotonic() + 5.0
    while time.monotonic() < deadline:
        if all(consumer.level_of(f"shuffle/p{i}") == "dram" for i in range(4)):
            break
        time.sleep(0.005)
    levels = [consumer.level_of(f"shuffle/p{i}") for i in range(4)]
    assert levels == ["dram"] * 4
    base = consumer.stats.modeled_seconds
    assert consumer.get("shuffle/p0") == b"d" * 64
    assert consumer.stats.modeled_seconds == base  # hot before first ask
    producer.close()
    consumer.close()


# -- integration: gateway / runtime / blockstore / mapreduce -------------------

def _hier_cache(tmp_path):
    pmem = PmemTier(str(tmp_path / "pmem"))
    store = TieredStore(
        [TierLevel("dram", DramTier(), None), TierLevel("pmem", pmem)],
        policy=PlacementPolicy(promote_after=1), name="state",
    )
    return StateCache(memory=store), store


def test_gateway_warm_pool_eviction_demotes_state(tmp_path):
    cache, hier = _hier_cache(tmp_path)
    rt = FunctionRuntime(cache=cache, commit_every=1)
    rt.register(StatefulFunction(
        "counter", lambda s, x: (s + x, s + x), init=lambda: 0, jit=False
    ))
    gw = Gateway(rt, invokers=2, warm_pool=1)
    try:
        gw.invoke("counter", session="s0", x=5)
        gw.invoke("counter", session="s1", x=7)  # evicts+demotes s0
        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline:
            if hier.level_of("state/s0/counter") == "pmem":
                break
            time.sleep(0.005)
        assert hier.level_of("state/s0/counter") == "pmem"
        # demoted state reloads correctly (and re-promotes on the read)
        assert gw.invoke("counter", session="s0", x=1) == 6
    finally:
        gw.close()
    hier.close()


def test_runtime_on_hierarchy_survives_crash(tmp_path):
    cache, hier = _hier_cache(tmp_path)
    rt = FunctionRuntime(cache=cache, commit_every=1)
    rt.register(StatefulFunction(
        "counter", lambda s, x: (s + x, s + x), init=lambda: 0, jit=False
    ))
    assert rt.invoke("counter", session="s", x=3) == 3
    assert rt.invoke("counter", session="s", x=4) == 7
    rt.crash()  # drops DRAM level; PMEM level survives
    rt.recover()
    assert rt.state_report("counter", "s") in ("warm", "hot")
    assert rt.invoke("counter", session="s", x=1) == 8
    hier.close()


def test_blockstore_datanodes_can_be_hierarchy_backed(tmp_path):
    nodes = []
    for i in range(3):
        hier = TieredStore(
            [TierLevel("dram", DramTier(), 4096),
             TierLevel("pmem", PmemTier(str(tmp_path / f"n{i}")))],
            name=f"node{i}",
        )
        nodes.append(DataNode(f"w{i}", hier))
    bs = BlockStore(nodes, block_size=1024, replication=2)
    data = b"block-data " * 500
    bs.write("/f", data)
    assert bs.read("/f") == data
    # replica loss still recovers through the hierarchy tiers
    bs.fail_node("w0")
    assert bs.read("/f") == data
    for nd in nodes:
        nd.tier.close()


def test_adaptive_shuffle_matches_static_and_cuts_inline_io():
    def mkbs():
        nodes = [DataNode(f"w{i}", DramTier()) for i in range(4)]
        bs = BlockStore(nodes, block_size=800, replication=2)
        bs.write("/in", b"\n".join([b"a b a c b a"] * 300), record_delim=b"\n")
        return bs

    static = run_job(
        wordcount_job(4), mkbs(), "/in", "/out", SimulatedTier(S3_SPEC),
        mode="pipelined",
    )
    backing = SimulatedTier(S3_SPEC)
    adaptive = run_job(
        wordcount_job(4), mkbs(), "/in", "/out", backing,
        mode="pipelined", adaptive=True,
    )
    assert adaptive.output_bytes == static.output_bytes
    # inline S3 latency left the map/reduce critical path entirely …
    assert adaptive.modeled_io_seconds < 0.25 * static.modeled_io_seconds
    # … yet the backing tier holds the shuffle data (background flush)
    assert any(k.startswith("mr/wordcount/") for k in backing.keys())


def test_adaptive_journaled_job_resumes(tmp_path):
    journal = StateCache(write_through=PmemTier(str(tmp_path / "j")))
    backing = SimulatedTier(S3_SPEC)
    nodes = [DataNode(f"w{i}", DramTier()) for i in range(4)]
    bs = BlockStore(nodes, block_size=800, replication=2)
    bs.write("/in", b"\n".join([b"x y z x"] * 200), record_delim=b"\n")
    r1 = run_job(wordcount_job(4), bs, "/in", "/o", backing,
                 journal=journal, adaptive=True)
    r2 = run_job(wordcount_job(4), bs, "/in", "/o", backing,
                 journal=journal, adaptive=True)
    assert r1.resumed_tasks == 0
    assert r2.resumed_tasks == r2.map_tasks + r2.reduce_tasks


def test_pin_survives_crash_and_promotes_on_first_read(tmp_path):
    """A pinned prefix keeps working across a node failure: survivors are
    re-adopted at the persistent home, and the first read promotes them
    straight back into the fast level (pins bypass frequency admission)."""
    store = TieredStore(
        [
            TierLevel("dram", DramTier(), None),
            TierLevel("pmem", PmemTier(str(tmp_path / "home"))),
        ],
        policy=PlacementPolicy(promote_after=5),  # high admission bar
        name="pin-crash",
    )
    store.pin("df/job/")
    store.put("df/job/state", b"loop-state")
    store.put("unpinned", b"cold")
    assert store.level_of("df/job/state") == "dram"
    store.crash()
    # both survive at the persistent home
    assert store.level_of("df/job/state") == "pmem"
    assert store.level_of("unpinned") == "pmem"
    # one read: the pinned key skips the promote_after=5 bar …
    assert store.get("df/job/state") == b"loop-state"
    assert store.level_of("df/job/state") == "dram"
    # … the unpinned key does not
    assert store.get("unpinned") == b"cold"
    assert store.level_of("unpinned") == "pmem"
    store.close()
