"""Property-test shim: real hypothesis when installed, else a deterministic
fallback sampler.

The container image does not ship ``hypothesis`` (and nothing may be pip
installed), which used to fail three test modules at *collection*.  The
fallback implements just the strategy surface these tests use —
``integers``, ``binary``, ``lists``, ``tuples``, ``sampled_from`` — and runs
each property ``max_examples`` times with seeds derived from the example
index, so the properties still execute (deterministically) without the
shrinking machinery.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import functools
    import inspect
    import random

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def sample(self, rnd: random.Random):
            return self._sample(rnd)

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def binary(min_size: int = 0, max_size: int = 100) -> _Strategy:
            return _Strategy(
                lambda r: bytes(
                    r.randrange(256)
                    for _ in range(r.randint(min_size, max_size))
                )
            )

        @staticmethod
        def lists(elements: _Strategy, min_size: int = 0,
                  max_size: int = 10) -> _Strategy:
            return _Strategy(
                lambda r: [
                    elements.sample(r)
                    for _ in range(r.randint(min_size, max_size))
                ]
            )

        @staticmethod
        def tuples(*elements: _Strategy) -> _Strategy:
            return _Strategy(lambda r: tuple(e.sample(r) for e in elements))

        @staticmethod
        def sampled_from(seq) -> _Strategy:
            choices = list(seq)
            return _Strategy(lambda r: r.choice(choices))

    st = _Strategies()

    def settings(max_examples: int = 20, **_ignored):
        """Records ``max_examples``; ``deadline`` etc. are ignored."""

        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(*strategies: _Strategy):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", 20)
                for i in range(n):
                    rnd = random.Random(0x9E3779B1 * (i + 1))
                    drawn = [s.sample(rnd) for s in strategies]
                    fn(*args, *drawn, **kwargs)

            # Hide the property parameters from pytest's fixture resolution
            # (the strategies supply them, not fixtures).
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper

        return deco


def nightly_examples(base: int) -> int:
    """``max_examples`` scaled by ``$STRESS_SCALE`` — the nightly stress
    workflow (.github/workflows/stress.yml) sets it to 10 so the slow,
    rare-interleaving-hunting runs stay off the per-PR critical path."""
    import os

    return base * max(1, int(os.environ.get("STRESS_SCALE", "1")))


__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS", "nightly_examples"]
