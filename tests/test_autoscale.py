"""Autoscaler policy loop (repro.core.autoscale).

The hypothesis properties are the ISSUE 9 contract: arbitrary traffic
never drives an invoker pool outside ``[min, max]``, the removal picker
never nominates a node owning in-flight work, and a step-function load
converges (no oscillation) within K control intervals.  Deterministic
tests pin the decision math, the warm-pool actuator, node join/leave
patience, and the loop against a real ``MarvelClient``.
"""

from __future__ import annotations

import time

from repro.api import ClusterConfig, MarvelClient
from repro.core.autoscale import (
    Autoscaler,
    PolicyController,
    PolicySpec,
    pick_removal_candidate,
)
from repro.core.gateway import LoadSnapshot
from repro.core.stateful import StatefulFunction
from tests.hypothesis_compat import given, nightly_examples, settings, st


def _snap(queue=0, inflight=0, invokers=1) -> LoadSnapshot:
    return LoadSnapshot(
        queue_depth=queue,
        queue_per_stripe=[queue],
        inflight=inflight,
        invokers=invokers,
        warm_hits=0,
        cold_starts=0,
        rejected=0,
        wait_p99_ms=0.0,
    )


class FakeGateway:
    """Just enough surface for the Autoscaler: snapshot + actuators."""

    def __init__(self, invokers=1, queue=0, inflight=0):
        self.invokers = invokers
        self.queue = queue
        self.inflight = inflight
        self.warm_pool = 64
        self.scale_calls = []

    def load_snapshot(self) -> LoadSnapshot:
        return _snap(self.queue, self.inflight, self.invokers)

    def scale_to(self, n: int) -> None:
        self.scale_calls.append(n)
        self.invokers = n


# -- the pure decision rule ------------------------------------------------


class TestPolicyController:
    def test_scales_up_proportionally_to_demand(self):
        spec = PolicySpec(min_invokers=1, max_invokers=8, target_per_invoker=4)
        ctl = PolicyController(spec)
        # queue 20 > 4*1, demand 24 -> ceil(24/4) = 6 invokers in one step
        assert ctl.decide(_snap(queue=20, inflight=4), invokers=1, now=0.0) == 6

    def test_up_clamps_at_max(self):
        spec = PolicySpec(min_invokers=1, max_invokers=4, target_per_invoker=4)
        ctl = PolicyController(spec)
        assert ctl.decide(_snap(queue=500), invokers=1, now=0.0) == 4

    def test_scales_down_one_step_when_idle(self):
        spec = PolicySpec(min_invokers=1, max_invokers=8, target_per_invoker=4)
        ctl = PolicyController(spec)
        assert ctl.decide(_snap(queue=0, inflight=1), invokers=4, now=0.0) == 3

    def test_down_respects_cooldown(self):
        spec = PolicySpec(
            min_invokers=1, max_invokers=8, target_per_invoker=4,
            down_cooldown_s=5.0,
        )
        ctl = PolicyController(spec)
        ctl.note_action(0.0, scaled_up=True)
        assert ctl.decide(_snap(), invokers=4, now=1.0) == 4  # too soon
        assert ctl.decide(_snap(), invokers=4, now=6.0) == 3

    def test_holds_steady_in_deadband(self):
        spec = PolicySpec(min_invokers=1, max_invokers=8, target_per_invoker=4)
        ctl = PolicyController(spec)
        # queue below the up bar, demand too high for the down bar
        assert ctl.decide(_snap(queue=3, inflight=6), invokers=2, now=0.0) == 2

    def test_never_below_min(self):
        spec = PolicySpec(min_invokers=2, max_invokers=8, target_per_invoker=4)
        ctl = PolicyController(spec)
        assert ctl.decide(_snap(), invokers=2, now=0.0) == 2


# -- properties ------------------------------------------------------------


@settings(max_examples=nightly_examples(25), deadline=None)
@given(
    st.lists(
        st.tuples(
            st.integers(min_value=0, max_value=500),
            st.integers(min_value=0, max_value=64),
        ),
        min_size=1,
        max_size=40,
    )
)
def test_invokers_never_leave_bounds(traffic):
    """Property: whatever the traffic does, the pool stays in [min, max]."""
    spec = PolicySpec(
        min_invokers=1, max_invokers=6, target_per_invoker=4, down_cooldown_s=0.0
    )
    gw = FakeGateway(invokers=1)
    auto = Autoscaler({"n0": gw}, spec, interval_s=1.0)
    for i, (queue, inflight) in enumerate(traffic):
        gw.queue, gw.inflight = queue, inflight
        auto.tick(float(i))
        assert spec.min_invokers <= gw.invokers <= spec.max_invokers
    assert auto.peak_invokers <= spec.max_invokers


@settings(max_examples=nightly_examples(25), deadline=None)
@given(
    st.lists(
        st.tuples(
            st.integers(min_value=0, max_value=5),
            st.integers(min_value=0, max_value=5),
        ),
        min_size=1,
        max_size=6,
    )
)
def test_removal_candidate_never_owns_inflight_work(loads):
    """Property: the picker only ever nominates a fully idle, unprotected
    node."""
    snaps = {
        f"n{i}": _snap(queue=q, inflight=f) for i, (q, f) in enumerate(loads)
    }
    candidate = pick_removal_candidate(snaps, protected=("n0",))
    if candidate is not None:
        assert candidate != "n0"
        assert snaps[candidate].inflight == 0
        assert snaps[candidate].queue_depth == 0


@settings(max_examples=nightly_examples(15), deadline=None)
@given(
    st.integers(min_value=1, max_value=10),
    st.integers(min_value=1, max_value=30),
)
def test_step_load_converges_without_oscillation(before, after):
    """Property: a step from ``before`` to ``after`` arrivals/tick settles
    to a fixed pool size within K=10 intervals and never flaps again.

    The fleet is simulated as a fluid queue: each tick serves
    ``invokers * target_per_invoker`` requests, inflight is the work in
    service, the backlog carries over.
    """
    spec = PolicySpec(
        min_invokers=1,
        max_invokers=50,
        target_per_invoker=4,
        down_cooldown_s=2.0,
    )
    gw = FakeGateway(invokers=1)
    auto = Autoscaler({"n0": gw}, spec, interval_s=1.0)
    K, tail = 10, 30
    queue = 0
    sizes = []
    for t in range(K + tail):
        arrivals = before if t < 2 else after
        capacity = gw.invokers * spec.target_per_invoker
        served = min(queue + arrivals, capacity)
        queue = queue + arrivals - served
        gw.queue, gw.inflight = queue, served
        auto.tick(float(t))
        sizes.append(gw.invokers)
    settled = sizes[K - 1 + 2 :]  # step happens at t=2; K intervals later
    assert len(set(settled)) == 1, f"pool still moving: {sizes}"
    assert queue == 0


# -- the loop + actuators --------------------------------------------------


class TestAutoscalerLoop:
    def test_maybe_tick_respects_interval(self):
        auto = Autoscaler({"n0": FakeGateway()}, PolicySpec(), interval_s=1.0)
        assert auto.maybe_tick(0.0)
        assert not auto.maybe_tick(0.5)
        assert auto.maybe_tick(1.1)
        assert auto.ticks == 2

    def test_warm_pool_tracks_invoker_count(self):
        spec = PolicySpec(
            min_invokers=1, max_invokers=8, target_per_invoker=4,
            warm_pool_per_invoker=32,
        )
        gw = FakeGateway(invokers=1, queue=12, inflight=0)
        auto = Autoscaler({"n0": gw}, spec, interval_s=1.0)
        auto.tick(0.0)
        assert gw.invokers == 3
        assert gw.warm_pool == 96
        assert auto.actions[0]["kind"] == "scale_up"

    def test_add_node_needs_patience(self):
        gws = {"n0": FakeGateway(invokers=2, queue=50)}
        added = []

        def add_node():
            nid = f"n{len(gws)}"
            gws[nid] = FakeGateway(invokers=2, queue=50)
            added.append(nid)
            return nid

        spec = PolicySpec(
            min_invokers=1, max_invokers=2, target_per_invoker=4,
            max_nodes=2, node_up_patience=3,
        )
        auto = Autoscaler(lambda: gws, spec, interval_s=1.0, add_node=add_node)
        auto.tick(0.0)
        auto.tick(1.0)
        assert not added  # two hot ticks < patience
        auto.tick(2.0)
        assert added == ["n1"]
        auto.tick(3.0)
        assert added == ["n1"]  # fleet is at max_nodes now
        assert auto.peak_nodes == 2

    def test_remove_node_needs_idle_patience_and_skips_protected(self):
        gws = {
            "n0": FakeGateway(invokers=1, queue=0, inflight=0),
            "n1": FakeGateway(invokers=1, queue=0, inflight=0),
        }
        removed = []

        def remove_node(nid):
            removed.append(nid)
            del gws[nid]

        spec = PolicySpec(
            min_invokers=1, max_invokers=2, target_per_invoker=4,
            min_nodes=1, max_nodes=2, node_down_patience=2,
        )
        auto = Autoscaler(
            lambda: gws, spec, interval_s=1.0, remove_node=remove_node
        )
        auto.tick(0.0)
        assert not removed
        auto.tick(1.0)
        assert removed == ["n1"]  # n0 is protected, n1 idle long enough
        auto.tick(2.0)
        auto.tick(3.0)
        assert removed == ["n1"]  # fleet is at min_nodes now

    def test_remove_refusal_is_logged_not_fatal(self):
        gws = {
            "n0": FakeGateway(),
            "n1": FakeGateway(),
        }

        def remove_node(nid):
            raise RuntimeError("owns in-flight work")

        spec = PolicySpec(max_nodes=2, node_down_patience=1)
        auto = Autoscaler(
            lambda: gws, spec, interval_s=1.0, remove_node=remove_node
        )
        auto.tick(0.0)
        kinds = [a["kind"] for a in auto.actions]
        assert "remove_node_refused" in kinds
        assert set(gws) == {"n0", "n1"}

    def test_busy_candidate_resets_idle_clock(self):
        gw1 = FakeGateway()
        gws = {"n0": FakeGateway(), "n1": gw1}
        removed = []
        spec = PolicySpec(max_nodes=2, node_down_patience=2)
        auto = Autoscaler(
            lambda: gws, spec, interval_s=1.0,
            remove_node=lambda nid: removed.append(nid),
        )
        auto.tick(0.0)  # idle tick 1
        gw1.inflight = 3  # busy again before patience runs out
        auto.tick(1.0)
        gw1.inflight = 0
        auto.tick(2.0)  # idle tick 1 (clock restarted)
        assert not removed
        auto.tick(3.0)
        assert removed == ["n1"]


# -- against a real client -------------------------------------------------


class TestOnRealClient:
    def test_scales_up_under_burst_then_back_down(self):
        with MarvelClient(
            ClusterConfig(name="as", invokers=1, journal="none")
        ) as client:

            def step(state, ms=5.0):
                time.sleep(ms / 1e3)
                return state + 1, state + 1

            client.register(
                StatefulFunction("sleeper", step, init=lambda: 0, jit=False)
            )
            auto = client.autoscaler(
                PolicySpec(
                    min_invokers=1, max_invokers=4, target_per_invoker=2,
                    down_cooldown_s=0.0, warm_pool_per_invoker=32,
                )
            )
            futs = [
                client.submit("sleeper", session=f"s{i}") for i in range(32)
            ]
            auto.maybe_tick(0.0)
            assert client.gateway.load_snapshot().invokers > 1
            for f in futs:
                f.result(timeout=30.0)
            client.gateway.quiesce(timeout=10.0)
            for t in range(1, 8):
                auto.maybe_tick(float(t))
            assert client.gateway.load_snapshot().invokers == 1
            assert auto.peak_invokers >= 2
            kinds = {a["kind"] for a in auto.actions}
            assert kinds == {"scale_up", "scale_down"}

    def test_facade_spec_overrides_and_quiet_ticks(self):
        with MarvelClient(
            ClusterConfig(name="as1", invokers=1, journal="none")
        ) as client:
            auto = client.autoscaler(max_invokers=2)
            assert auto.spec.max_invokers == 2
            auto.maybe_tick(0.0)  # no traffic: nothing to do, no crash
            assert auto.actions == []
