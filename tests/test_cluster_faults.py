"""Cluster fault matrix: node loss and link partitions.

Crash-matrix cells (ISSUE 8): {node loss mid-invocation, node loss
mid-shuffle, link partition during replication} × {sessions re-homed
byte-identically, under-replicated blocks re-replicated}.  Byte-identity
is asserted the same way the single-node crash matrix does it:
``FunctionRuntime.state_bytes`` for sessions, whole output files for
jobs.
"""

from __future__ import annotations

import time

import pytest

from repro.api import ClusterConfig, MarvelClient
from repro.core.cluster import NodeDownError
from repro.core.mapreduce import wordcount_job
from repro.core.stateful import StatefulFunction
from repro.storage.faults import LinkPartitionError
from tests.hypothesis_compat import given, nightly_examples, settings, st


def _corpus(n: int = 300) -> bytes:
    return b"\n".join(
        b"alpha beta gamma delta epsilon zeta word%d tail" % (i % 11)
        for i in range(n)
    )


def _counter(client: MarvelClient) -> None:
    client.register(
        StatefulFunction(
            "counter",
            lambda state, inc=1: ({"n": state["n"] + inc}, state["n"] + inc),
            lambda **kw: {"n": 0},
            jit=False,
        )
    )


def _read_parts(client: MarvelClient, path: str, n: int) -> bytes:
    return b"".join(client.store.read(f"{path}/part_{p:04d}") for p in range(n))


def _session_on(client: MarvelClient, node_id: str) -> str:
    """A session id the ring places on ``node_id``."""
    for i in range(2000):
        if client.cluster.ring.owner(f"sess{i}") == node_id:
            return f"sess{i}"
    raise AssertionError(f"no session hashed onto {node_id}")


def _reference_output(n_reducers: int = 4) -> bytes:
    with MarvelClient(
        ClusterConfig(name="ref", nodes=2, block_size=2048)
    ) as ref:
        ref.store.write("/in", _corpus(), record_delim=b"\n")
        ref.mapreduce(wordcount_job(n_reducers), "/in", "/out")
        return _read_parts(ref, "/out", n_reducers)


# -- node loss mid-invocation --------------------------------------------------


class TestNodeLossMidInvocation:
    def test_sessions_rehomed_byte_identically(self, tmp_path):
        with MarvelClient(
            ClusterConfig(name="c", nodes=4, sharded=True,
                          journal="pmem", journal_path=str(tmp_path / "j"))
        ) as client:
            _counter(client)
            victim = "n1"
            sess = _session_on(client, victim)
            for _ in range(5):
                client.invoke("counter", session=sess)
            pre = client.cluster.nodes[victim].runtime.state_bytes(
                "counter", sess
            )
            summary = client.cluster.fail_node(victim)
            assert summary["sessions_rehomed"] >= 1
            assert summary["net_bytes"] > 0  # replay rode the fabric
            new_owner = client.cluster.owner_node(sess)
            assert new_owner.node_id != victim
            # byte-identical state on the survivor, sequence resumes
            assert new_owner.runtime.state_bytes("counter", sess) == pre
            assert client.invoke("counter", session=sess) == 6

    def test_every_durable_session_of_the_dead_node_moves(self, tmp_path):
        with MarvelClient(
            ClusterConfig(name="c", nodes=3, sharded=True,
                          journal="pmem", journal_path=str(tmp_path / "j"))
        ) as client:
            _counter(client)
            victim = "n2"
            mine, theirs = [], []
            for i in range(60):
                sess = f"s{i}"
                (mine if client.cluster.ring.owner(sess) == victim
                 else theirs).append(sess)
                client.invoke("counter", session=sess)
            assert mine, "no sessions hashed onto the victim"
            summary = client.cluster.fail_node(victim)
            assert summary["sessions_rehomed"] == len(mine)
            for sess in mine + theirs:
                assert client.invoke("counter", session=sess) == 2

    def test_volatile_sessions_restart_from_scratch(self):
        """No PMEM journal → nothing survives the node (stock-Marvel
        semantics, matching the single-node volatile contract)."""
        with MarvelClient(
            ClusterConfig(name="c", nodes=3, sharded=True)
        ) as client:
            _counter(client)
            victim = "n0"
            sess = _session_on(client, victim)
            assert client.invoke("counter", session=sess) == 1
            summary = client.cluster.fail_node(victim)
            assert summary["sessions_rehomed"] == 0
            assert client.invoke("counter", session=sess) == 1  # fresh

    def test_routing_to_dead_node_never_happens(self):
        with MarvelClient(
            ClusterConfig(name="c", nodes=3, sharded=True)
        ) as client:
            _counter(client)
            client.cluster.fail_node("n1")
            for i in range(30):
                assert client.cluster.owner_node(f"s{i}").node_id != "n1"
            with pytest.raises(NodeDownError):
                client.cluster.nodes["n1"].submit(lambda: None)


# -- node loss mid-shuffle -----------------------------------------------------


class TestNodeLossMidShuffle:
    def test_kill_one_node_mid_job_output_byte_identical(self):
        expect = _reference_output()
        with MarvelClient(
            ClusterConfig(name="k", nodes=4, sharded=True,
                          replication=2, block_size=2048)
        ) as client:
            client.store.write("/in", _corpus(), record_delim=b"\n")
            killed = []

            def on_map_done(count):
                if count == 2 and not killed:
                    killed.append(True)
                    client.cluster.fail_node("n1")

            raw = client.cluster.run_mapreduce(
                wordcount_job(4), "/in", "/out", on_map_done=on_map_done
            )
            assert killed
            assert len(client.cluster.live_nodes()) == 3
            assert _read_parts(client, "/out", 4) == expect
            assert raw.mode == "cluster"
            # the dead node's blocks were re-replicated onto survivors
            assert client.store.under_replicated() == []

    @settings(max_examples=nightly_examples(4), deadline=None)
    @given(
        st.integers(min_value=0, max_value=3),
        st.integers(min_value=1, max_value=6),
    )
    def test_any_victim_any_time_output_byte_identical(self, victim, after):
        """Property: whichever node dies after however many maps, the
        job completes with byte-identical output (nightly scales the
        schedule count via STRESS_SCALE)."""
        expect = _reference_output()
        with MarvelClient(
            ClusterConfig(name="k", nodes=4, sharded=True,
                          replication=2, block_size=2048)
        ) as client:
            client.store.write("/in", _corpus(), record_delim=b"\n")
            killed = []

            def on_map_done(count):
                if count == after and not killed:
                    killed.append(True)
                    client.cluster.fail_node(f"n{victim}")

            client.cluster.run_mapreduce(
                wordcount_job(4), "/in", "/out", on_map_done=on_map_done
            )
            assert _read_parts(client, "/out", 4) == expect


# -- link partitions -----------------------------------------------------------


class TestLinkPartition:
    def test_transfer_raises_while_partitioned_then_heals(self):
        with MarvelClient(
            ClusterConfig(name="p", nodes=3, sharded=True)
        ) as client:
            fabric = client.cluster.fabric
            fabric.partition("n0", "n1")
            with pytest.raises(LinkPartitionError):
                fabric.transfer("n0", "n1", 100)
            with pytest.raises(LinkPartitionError):
                fabric.transfer("n1", "n0", 100)  # symmetric
            fabric.transfer("n0", "n2", 100)  # other links unaffected
            fabric.heal("n0", "n1")
            assert fabric.transfer("n0", "n1", 100) > 0

    def test_partition_during_replication_leaves_under_replicated(self):
        """Re-replication across a partitioned link is skipped — the
        block stays under-replicated until the link heals, then the next
        re_replicate restores the factor."""
        with MarvelClient(
            ClusterConfig(name="p", nodes=3, sharded=True,
                          replication=2, block_size=2048)
        ) as client:
            client.store.write("/in", _corpus(), record_delim=b"\n")
            # n2 is the only survivor that can take new replicas, but the
            # live source n0 can't reach it
            client.cluster.fabric.partition("n0", "n2")
            summary = client.cluster.fail_node("n1")
            assert summary["blocks_rereplicated"] == 0
            under = client.store.under_replicated()
            assert under  # degraded but serving
            assert client.store.read("/in") == _corpus()
            client.cluster.fabric.heal()
            assert client.cluster.re_replicate() == len(under)
            assert client.store.under_replicated() == []

    def test_shuffle_routes_around_partitioned_link(self):
        expect = _reference_output()
        with MarvelClient(
            ClusterConfig(name="p", nodes=3, sharded=True,
                          replication=3, block_size=2048)
        ) as client:
            client.store.write("/in", _corpus(), record_delim=b"\n")
            client.cluster.fabric.partition("n0", "n1")
            client.cluster.run_mapreduce(wordcount_job(4), "/in", "/out")
            assert _read_parts(client, "/out", 4) == expect


# -- elastic membership (ISSUE 9: add/remove under the autoscaler) -------------


class TestElasticMembership:
    def test_add_node_mid_job_output_byte_identical(self):
        """Mirror of the kill-node cell: a node *joins* mid-WordCount and
        the re-plan loop must land byte-identical output anyway."""
        expect = _reference_output()
        with MarvelClient(
            ClusterConfig(name="g", nodes=3, sharded=True,
                          replication=1, block_size=2048)
        ) as client:
            client.store.write("/in", _corpus(), record_delim=b"\n")
            joined = []

            def on_map_done(count):
                if count == 2 and not joined:
                    joined.append(client.add_node())

            client.cluster.run_mapreduce(
                wordcount_job(4), "/in", "/out", on_map_done=on_map_done
            )
            assert joined == ["n3"]
            assert len(client.cluster.live_nodes()) == 4
            assert _read_parts(client, "/out", 4) == expect

    def test_add_node_lazily_migrates_only_moved_sessions(self):
        with MarvelClient(
            ClusterConfig(name="g", nodes=2, sharded=True)
        ) as client:
            _counter(client)
            sessions = [f"sess{i}" for i in range(30)]
            for sess in sessions:
                for _ in range(3):
                    client.invoke("counter", session=sess)
            before = {s: client.cluster.owner_node(s).node_id for s in sessions}
            nid = client.add_node()
            after = {s: client.cluster.owner_node(s).node_id for s in sessions}
            moved = [s for s in sessions if after[s] != before[s]]
            assert moved, "ring rebalance moved nothing (vnode fluke?)"
            assert all(after[s] == nid for s in moved)
            # only the moved arcs' sessions were shipped
            assert client.cluster.migrations["sessions"] == len(moved)
            # every session continues from its exact prior state
            for sess in sessions:
                assert client.invoke("counter", session=sess) == 4

    def test_remove_node_ships_state_to_survivors(self):
        with MarvelClient(
            ClusterConfig(name="g", nodes=2, sharded=True)
        ) as client:
            _counter(client)
            nid = client.add_node()
            sess = _session_on(client, nid)
            for _ in range(3):
                client.invoke("counter", session=sess)
            summary = client.remove_node(nid)
            assert summary["sessions_moved"] >= 1
            assert nid not in client.cluster.nodes
            assert len(client.cluster.live_nodes()) == 2
            assert client.invoke("counter", session=sess) == 4

    def test_remove_node_refuses_inflight_work(self):
        with MarvelClient(
            ClusterConfig(name="g", nodes=3, sharded=True)
        ) as client:
            client.register(
                StatefulFunction(
                    "sleeper",
                    lambda state, **kw: (_sleep_step(state)),
                    lambda **kw: 0,
                    jit=False,
                )
            )
            sess = _session_on(client, "n1")
            fut = client.submit("sleeper", session=sess)
            with pytest.raises(RuntimeError, match="in-flight"):
                client.remove_node("n1")
            fut.result(timeout=30.0)
            # once drained, removal goes through (poll past the decrement)
            deadline = time.monotonic() + 5.0
            while True:
                try:
                    client.remove_node("n1")
                    break
                except RuntimeError:
                    if time.monotonic() >= deadline:
                        raise
                    time.sleep(0.01)
            assert len(client.cluster.live_nodes()) == 2

    def test_anchor_and_last_node_protected(self):
        with MarvelClient(
            ClusterConfig(name="g", nodes=2, sharded=True)
        ) as client:
            from repro.api import ConfigError

            with pytest.raises(ConfigError, match="n0"):
                client.remove_node("n0")
            with pytest.raises(NodeDownError):
                client.cluster.remove_node("n9")
            client.cluster.remove_node("n1")
            with pytest.raises(RuntimeError, match="last live node"):
                client.cluster.remove_node("n0")

    def test_load_snapshots_cover_live_nodes(self):
        with MarvelClient(
            ClusterConfig(name="g", nodes=2, sharded=True)
        ) as client:
            snaps = client.cluster.load_snapshots()
            assert set(snaps) == {"n0", "n1"}
            assert all(s.inflight == 0 for s in snaps.values())
            assert all(s.queue_depth == 0 for s in snaps.values())


def _sleep_step(state):
    time.sleep(0.3)
    return state + 1, state + 1
