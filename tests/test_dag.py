"""Stage-DAG engine: dependency dispatch, streaming, journal, multi-job."""

import time

import pytest

from repro.core import (
    Scheduler,
    StageDag,
    StateJournal,
    TaskFailedError,
    TaskSpec,
    lower_job,
    run_job,
    run_jobs,
    task_token,
)
from repro.core.mapreduce import wordcount_job, grep_job
from repro.storage import BlockStore, DataNode, DramTier, StateCache


def _sched(n=2, **kw):
    kw.setdefault("speculation_factor", None)
    return Scheduler([f"w{i}" for i in range(n)], **kw)


def _cluster(n=4, block_size=1500):
    nodes = [DataNode(f"w{i}", DramTier()) for i in range(n)]
    bs = BlockStore(nodes, block_size=block_size, replication=2)
    sched = Scheduler([nd.node_id for nd in nodes], speculation_factor=None)
    return bs, sched


def _corpus(rng, n_lines=300):
    words = [f"w{i}".encode() for i in range(40)]
    lines = [b" ".join(rng.choice(words, size=6)) for _ in range(n_lines)]
    return b"\n".join(lines)


# -- scheduler.run_dag ---------------------------------------------------------

def test_dag_respects_dependencies():
    order = []

    def mk(tid, deps=()):
        def run(ctx):
            order.append(tid)
            return tid

        return TaskSpec(tid, run, deps=frozenset(deps))

    specs = [
        mk("c", deps=[task_token("a"), task_token("b")]),
        mk("a"),
        mk("b", deps=[task_token("a")]),
    ]
    res = _sched().run_dag(specs)
    assert set(res) == {"a", "b", "c"}
    assert order.index("a") < order.index("b") < order.index("c")


def test_dag_completion_callbacks_fire_before_dependents():
    committed = []

    def on_complete(res):
        committed.append(res.task_id)

    def run_b(ctx):
        # a's callback must have run before b could be dispatched
        assert "a" in committed
        return "b"

    specs = [
        TaskSpec("a", lambda ctx: "a", on_complete=on_complete),
        TaskSpec("b", run_b, deps=frozenset([task_token("a")]),
                 on_complete=on_complete),
    ]
    res = _sched().run_dag(specs)
    assert committed == ["a", "b"]
    assert res["a"].value == "a"


def test_dag_retry_and_permanent_failure():
    attempts = {"n": 0}

    def flaky(ctx):
        attempts["n"] += 1
        if attempts["n"] < 3:
            raise RuntimeError("transient")
        return "ok"

    res = _sched(1, max_attempts=3).run_dag([TaskSpec("t", flaky)])
    assert res["t"].value == "ok" and res["t"].attempts == 3

    with pytest.raises(TaskFailedError):
        _sched(1, max_attempts=2).run_dag(
            [TaskSpec("x", lambda ctx: 1 / 0)]
        )


def test_dag_stall_detection():
    """A dep no task produces -> clean failure, not a hang."""
    spec = TaskSpec("t", lambda ctx: 1, deps=frozenset(["never"]))
    with pytest.raises(TaskFailedError, match="stalled"):
        _sched().run_dag([spec])


def test_dag_locality_preference():
    res = _sched(3).run_dag(
        [TaskSpec("t", lambda ctx: ctx.worker, preferred=["w2"])]
    )
    assert res["t"].value == "w2"


def test_dag_streaming_receives_primed_and_live_tokens():
    got = []

    def producer(ctx):
        ctx.publish("data/live")
        return "p"

    def consumer(ctx):
        while len(got) < 2:
            tok = ctx.next_event(timeout=0.01)
            if tok is not None:
                got.append(tok)
        return got

    specs = [
        TaskSpec("cons", consumer, streaming=True,
                 listens=lambda t: t.startswith("data/")),
        TaskSpec("prod", producer),
    ]
    res = _sched().run_dag(specs, initial_tokens=["data/primed"])
    assert sorted(res["cons"].value) == ["data/live", "data/primed"]


def test_dag_streaming_does_not_starve_producers():
    """More streaming consumers than workers + pending producers: the
    overlap-slot design must still finish (no deadlock)."""
    n_consumers, n_producers = 4, 6

    def consumer(ctx):
        while True:
            tok = ctx.next_event(timeout=0.01)
            if tok == "data/stop":
                return "done"

    def producer(i):
        def run(ctx):
            time.sleep(0.01)
            if i == n_producers - 1:
                ctx.publish("data/stop")
            return i

        return TaskSpec(f"prod_{i}", run)

    specs = [
        TaskSpec(f"cons_{c}", consumer, streaming=True,
                 listens=lambda t: t.startswith("data/"))
        for c in range(n_consumers)
    ] + [producer(i) for i in range(n_producers)]
    res = _sched(2).run_dag(specs)
    assert len(res) == n_consumers + n_producers


def test_stage_dag_validates_tokens():
    dag = StageDag("d")
    dag.add(TaskSpec("a", lambda ctx: 1))
    dag.add(TaskSpec("b", lambda ctx: 1, deps=frozenset(["typo-token"])))
    with pytest.raises(ValueError, match="unsatisfiable"):
        dag.validate()
    dag.validate(external_tokens=["typo-token"])  # primed -> fine
    with pytest.raises(ValueError, match="duplicate"):
        dag.add(TaskSpec("a", lambda ctx: 2))


# -- multi-job shared pool -----------------------------------------------------

def test_two_jobs_share_one_worker_pool(rng):
    data = _corpus(rng)
    bs, sched = _cluster()
    bs.write("/in", data, record_delim=b"\n")
    lowered = [
        lower_job(wordcount_job(2), bs, "/in", "/out_wc", DramTier(),
                  mode="pipelined"),
        lower_job(grep_job(rb"w1", 2), bs, "/in", "/out_grep", DramTier(),
                  mode="pipelined"),
    ]
    reports = run_jobs(lowered, sched)
    assert [r.job for r in reports] == ["wordcount", "grep"]
    for rep, out in zip(reports, ("/out_wc", "/out_grep")):
        assert rep.output_bytes > 0
        assert bs.exists(f"{out}/part_0000")
    # cross-check one mode against a solo run
    bs2, sched2 = _cluster()
    bs2.write("/in", data, record_delim=b"\n")
    solo = run_job(wordcount_job(2), bs2, "/in", "/out_wc", DramTier(),
                   sched2, mode="wave")
    assert bs.read("/out_wc/part_0000") == bs2.read("/out_wc/part_0000")
    assert bs.read("/out_wc/part_0001") == bs2.read("/out_wc/part_0001")
    assert solo.output_bytes == reports[0].output_bytes


# -- StateJournal --------------------------------------------------------------

def test_state_journal_roundtrip():
    sj = StateJournal(StateCache(), "jobx")
    assert not sj.committed("t1")
    sj.commit("t1", {"bytes": 10})
    sj.commit_many({"t2": {"bytes": 20}, "t2.part_0001": {}})
    assert sj.committed("t1") and sj.committed("t2")
    assert sj.meta("t1") == {"bytes": 10}
    assert set(sj.entries()) == {"t1", "t2", "t2.part_0001"}
    assert set(sj.entries(prefix="t2")) == {"t2", "t2.part_0001"}
    assert sj.pending(["t1", "t3"]) == ["t3"]
    sj.clear()
    assert sj.entries() == {}


def test_state_journal_mapreduce_key_layout_compatible():
    """Journals written by the pre-DAG engine (mr/<job>/done/<task>) must
    still resume under StateJournal."""
    cache = StateCache()
    cache.put("mr/wc/done/map_00000", b'{"task": "map_00000", "sizes": {}}')
    sj = StateJournal(cache, "mr/wc")
    assert sj.committed("map_00000")
    assert sj.meta("map_00000")["task"] == "map_00000"
