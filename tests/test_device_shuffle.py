"""Device-resident shuffle (the TPU-native fast tier) vs oracles.

Multi-device behavior is covered in test_dryrun.py (subprocess with forced
host devices); here the mesh is 1 device — the collective paths still
execute (degenerate all_to_all), and the storage path is exercised fully.
"""

import numpy as np

import jax.numpy as jnp

from hypothesis_compat import given, settings, st
from repro.core import device_histogram, pack_buckets, storage_histogram
from repro.launch.mesh import make_mesh_compat
from repro.storage import DramTier


def _mesh1():
    return make_mesh_compat((1,), ("data",))


def test_pack_buckets_partitions_correctly(rng):
    n, ndev, cap = 64, 4, 64
    keys = rng.integers(0, 100, n).astype(np.int32)
    dest = keys % ndev
    bk, bv, dropped = pack_buckets(
        jnp.asarray(keys), jnp.ones(n, jnp.float32), jnp.asarray(dest),
        ndev, cap,
    )
    assert int(dropped) == 0
    bk = np.asarray(bk)
    for d in range(ndev):
        sent = sorted(k for k in bk[d] if k >= 0)
        assert sent == sorted(keys[dest == d])


def test_pack_buckets_capacity_drops(rng):
    n, ndev, cap = 64, 2, 3
    keys = np.zeros(n, np.int32)  # all to bucket 0
    bk, bv, dropped = pack_buckets(
        jnp.asarray(keys), jnp.ones(n, jnp.float32),
        jnp.zeros(n, jnp.int32), ndev, cap,
    )
    assert int(dropped) == n - cap


def test_pack_buckets_ignores_invalid(rng):
    keys = np.array([-1, 5, -1, 7], np.int32)
    dest = np.array([-1, 1, -1, 0], np.int32)
    bk, bv, dropped = pack_buckets(
        jnp.asarray(keys), jnp.ones(4, jnp.float32), jnp.asarray(dest), 2, 4
    )
    assert int(dropped) == 0
    assert sorted(np.asarray(bk).ravel().tolist()) == [-1] * 6 + [5, 7]


def test_device_histogram_matches_numpy(rng):
    vocab, n = 101, 512
    keys = rng.integers(0, vocab, n).astype(np.int32)
    res = device_histogram(
        jnp.asarray(keys), jnp.ones(n, jnp.float32), _mesh1(), "data",
        vocab=vocab, capacity_factor=4.0,
    )
    np.testing.assert_allclose(
        np.asarray(res.counts), np.bincount(keys, minlength=vocab)
    )
    assert int(res.dropped) == 0


def test_storage_histogram_matches_device(rng):
    vocab, n, ndev = 64, 256, 4
    keys = rng.integers(0, vocab, n).astype(np.int32)
    vals = rng.random(n).astype(np.float32)
    res = storage_histogram(
        keys, vals, ndev, DramTier(), vocab=vocab, capacity_factor=8.0
    )
    want = np.zeros(vocab, np.float32)
    np.add.at(want, keys, vals)
    np.testing.assert_allclose(np.asarray(res.counts), want, rtol=1e-5)
    assert res.shuffled_bytes > 0


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31), st.integers(2, 50), st.integers(1, 8))
def test_storage_histogram_property(seed, vocab, ndev):
    rng = np.random.default_rng(seed)
    n = ndev * 32
    keys = rng.integers(0, vocab, n).astype(np.int32)
    res = storage_histogram(
        keys, np.ones(n, np.float32), ndev, DramTier(), vocab=vocab,
        capacity_factor=float(ndev) * 4,
    )
    np.testing.assert_allclose(
        np.asarray(res.counts), np.bincount(keys, minlength=vocab)
    )


def test_weighted_histogram(rng):
    """GroupBy-sum (the paper's aggregation query) on device."""
    vocab, n = 32, 256
    keys = rng.integers(0, vocab, n).astype(np.int32)
    vals = rng.random(n).astype(np.float32)
    res = device_histogram(
        jnp.asarray(keys), jnp.asarray(vals), _mesh1(), "data",
        vocab=vocab, capacity_factor=8.0,
    )
    want = np.zeros(vocab, np.float32)
    np.add.at(want, keys, vals)
    np.testing.assert_allclose(np.asarray(res.counts), want, rtol=1e-5)
