"""Device-resident shuffle (the TPU-native fast tier) vs oracles.

Multi-device behavior is covered in test_dryrun.py (subprocess with forced
host devices); here the mesh is 1 device — the collective paths still
execute (degenerate all_to_all), and the storage path is exercised fully.
"""

import numpy as np

import jax.numpy as jnp

from hypothesis_compat import given, settings, st
from repro.core import (
    device_histogram,
    device_partition,
    device_segment_reduce,
    host_histogram,
    pack_buckets,
    storage_histogram,
)
from repro.launch.mesh import make_mesh_compat
from repro.storage import DramTier


def _mesh1():
    return make_mesh_compat((1,), ("data",))


def test_pack_buckets_partitions_correctly(rng):
    n, ndev, cap = 64, 4, 64
    keys = rng.integers(0, 100, n).astype(np.int32)
    dest = keys % ndev
    bk, bv, dropped = pack_buckets(
        jnp.asarray(keys), jnp.ones(n, jnp.float32), jnp.asarray(dest),
        ndev, cap,
    )
    assert int(dropped) == 0
    bk = np.asarray(bk)
    for d in range(ndev):
        sent = sorted(k for k in bk[d] if k >= 0)
        assert sent == sorted(keys[dest == d])


def test_pack_buckets_capacity_drops(rng):
    n, ndev, cap = 64, 2, 3
    keys = np.zeros(n, np.int32)  # all to bucket 0
    bk, bv, dropped = pack_buckets(
        jnp.asarray(keys), jnp.ones(n, jnp.float32),
        jnp.zeros(n, jnp.int32), ndev, cap,
    )
    assert int(dropped) == n - cap


def test_pack_buckets_ignores_invalid(rng):
    keys = np.array([-1, 5, -1, 7], np.int32)
    dest = np.array([-1, 1, -1, 0], np.int32)
    bk, bv, dropped = pack_buckets(
        jnp.asarray(keys), jnp.ones(4, jnp.float32), jnp.asarray(dest), 2, 4
    )
    assert int(dropped) == 0
    assert sorted(np.asarray(bk).ravel().tolist()) == [-1] * 6 + [5, 7]


def test_device_histogram_matches_numpy(rng):
    vocab, n = 101, 512
    keys = rng.integers(0, vocab, n).astype(np.int32)
    res = device_histogram(
        jnp.asarray(keys), jnp.ones(n, jnp.float32), _mesh1(), "data",
        vocab=vocab, capacity_factor=4.0,
    )
    np.testing.assert_allclose(
        np.asarray(res.counts), np.bincount(keys, minlength=vocab)
    )
    assert int(res.dropped) == 0


def test_storage_histogram_matches_device(rng):
    vocab, n, ndev = 64, 256, 4
    keys = rng.integers(0, vocab, n).astype(np.int32)
    vals = rng.random(n).astype(np.float32)
    res = storage_histogram(
        keys, vals, ndev, DramTier(), vocab=vocab, capacity_factor=8.0
    )
    want = np.zeros(vocab, np.float32)
    np.add.at(want, keys, vals)
    np.testing.assert_allclose(np.asarray(res.counts), want, rtol=1e-5)
    assert res.shuffled_bytes > 0


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31), st.integers(2, 50), st.integers(1, 8))
def test_storage_histogram_property(seed, vocab, ndev):
    rng = np.random.default_rng(seed)
    n = ndev * 32
    keys = rng.integers(0, vocab, n).astype(np.int32)
    res = storage_histogram(
        keys, np.ones(n, np.float32), ndev, DramTier(), vocab=vocab,
        capacity_factor=float(ndev) * 4,
    )
    np.testing.assert_allclose(
        np.asarray(res.counts), np.bincount(keys, minlength=vocab)
    )


def test_weighted_histogram(rng):
    """GroupBy-sum (the paper's aggregation query) on device."""
    vocab, n = 32, 256
    keys = rng.integers(0, vocab, n).astype(np.int32)
    vals = rng.random(n).astype(np.float32)
    res = device_histogram(
        jnp.asarray(keys), jnp.asarray(vals), _mesh1(), "data",
        vocab=vocab, capacity_factor=8.0,
    )
    want = np.zeros(vocab, np.float32)
    np.add.at(want, keys, vals)
    np.testing.assert_allclose(np.asarray(res.counts), want, rtol=1e-5)


# -- seed bug regressions ------------------------------------------------------

def test_storage_histogram_prime_length_tail(rng):
    """n_global % ndev != 0 used to silently drop the tail remainder."""
    vocab, n, ndev = 50, 101, 4
    keys = rng.integers(0, vocab, n).astype(np.int32)
    vals = np.ones(n, np.int32)
    res = storage_histogram(
        keys, vals, ndev, DramTier(), vocab=vocab, capacity_factor=8.0
    )
    want = host_histogram(keys, vals, vocab)
    np.testing.assert_array_equal(np.asarray(res.counts), want)
    assert int(np.asarray(res.counts).sum()) == n  # every pair counted


def test_count_exactness_above_2_24():
    """int32 accumulation stays exact where an f32 accumulator saturates."""
    n = (1 << 24) + 65
    ids = np.zeros(n, np.int32)
    vals = np.ones(n, np.int32)
    exact = device_segment_reduce(ids, vals, 1)
    assert exact.dtype == np.int32
    assert int(exact[0]) == n
    stuck = device_segment_reduce(ids, vals, 1, value_dtype=np.float32)
    # 2^24 + 65 is odd; f32 spacing at that magnitude is 2 — no f32
    # accumulator can represent the true count.
    assert int(stuck[0]) != n


def test_empty_and_all_invalid_inputs():
    """N == 0 and all-padding inputs yield zero histograms, dropped == 0."""
    mesh = _mesh1()
    for keys, vals in (
        (np.zeros(0, np.int32), np.zeros(0, np.int32)),
        (np.full(16, -1, np.int32), np.ones(16, np.int32)),
    ):
        d = device_histogram(
            jnp.asarray(keys), jnp.asarray(vals), mesh, "data", vocab=8
        )
        assert int(jnp.sum(d.counts)) == 0
        assert int(d.dropped) == 0
        assert d.shuffled_bytes == 0
        s = storage_histogram(keys, vals, 4, DramTier(), vocab=8)
        assert int(np.asarray(s.counts).sum()) == 0
        assert int(s.dropped) == 0
        assert s.shuffled_bytes == 0


def test_shuffled_bytes_counts_pairs_not_buffers(rng):
    """Device and storage paths report comparable actual-pair bytes;
    the capacity-buffer footprint is a separate field."""
    vocab, n, ndev = 64, 256, 4
    keys = rng.integers(0, vocab, n).astype(np.int32)
    vals = np.ones(n, np.int32)
    d = device_histogram(
        jnp.asarray(keys), jnp.asarray(vals), _mesh1(), "data",
        vocab=vocab, capacity_factor=8.0,
    )
    s = storage_histogram(
        keys, vals, ndev, DramTier(), vocab=vocab, capacity_factor=8.0
    )
    itemsize = 8  # int32 key + int32 value
    assert d.shuffled_bytes == n * itemsize
    assert s.shuffled_bytes == n * itemsize
    assert d.buffer_bytes > d.shuffled_bytes  # padding lives here
    assert s.buffer_bytes > s.shuffled_bytes


# -- spill path ----------------------------------------------------------------

def test_device_histogram_spills_instead_of_dropping(rng):
    vocab, n = 32, 300
    keys = (rng.zipf(1.4, n) % vocab).astype(np.int32)
    vals = np.ones(n, np.int32)
    want = host_histogram(keys, vals, vocab)
    tight = device_histogram(
        jnp.asarray(keys), jnp.asarray(vals), _mesh1(), "data",
        vocab=vocab, capacity_factor=0.05,
    )
    assert int(tight.dropped) > 0  # without a spill tier, pairs are lost
    spilled = device_histogram(
        jnp.asarray(keys), jnp.asarray(vals), _mesh1(), "data",
        vocab=vocab, capacity_factor=0.05, spill_tier=DramTier(),
    )
    assert int(spilled.dropped) == 0
    assert spilled.spilled == int(tight.dropped)
    assert spilled.spilled_bytes > 0
    np.testing.assert_array_equal(np.asarray(spilled.counts), want)


def test_storage_histogram_spills_instead_of_dropping(rng):
    vocab, n, ndev = 32, 300, 4
    keys = (rng.zipf(1.4, n) % vocab).astype(np.int32)
    vals = np.ones(n, np.int32)
    want = host_histogram(keys, vals, vocab)
    tier = DramTier()
    res = storage_histogram(
        keys, vals, ndev, tier, vocab=vocab, capacity_factor=0.1, spill=True
    )
    assert int(res.dropped) == 0
    assert res.spilled > 0
    assert tier.contains("shuffle/spill")  # overflow rode the tier
    np.testing.assert_array_equal(np.asarray(res.counts), want)


# -- engine-facing helpers -----------------------------------------------------

def test_device_partition_preserves_order(rng):
    n = 500
    dest = rng.integers(0, 7, n).astype(np.int32)
    parts, ovf = device_partition(dest, 7)
    assert len(ovf) == 0
    for p, idxs in enumerate(parts):
        np.testing.assert_array_equal(idxs, np.flatnonzero(dest == p))


def test_device_partition_capacity_overflow(rng):
    n, cap = 200, 10
    dest = rng.integers(0, 3, n).astype(np.int32)
    parts, ovf = device_partition(dest, 3, capacity=cap)
    kept = np.concatenate(parts)
    for p, idxs in enumerate(parts):
        np.testing.assert_array_equal(
            idxs, np.flatnonzero(dest == p)[:cap]  # first cap, in order
        )
    # kept + overflow is a permutation of all pairs: nothing is lost
    assert sorted(kept.tolist() + ovf.tolist()) == list(range(n))


def test_device_partition_empty():
    parts, ovf = device_partition(np.zeros(0, np.int32), 3)
    assert [len(p) for p in parts] == [0, 0, 0]
    assert len(ovf) == 0


def test_device_segment_reduce_matches_bincount(rng):
    n, segs = 1000, 37
    ids = rng.integers(0, segs, n).astype(np.int32)
    vals = rng.integers(-50, 50, n).astype(np.int32)
    got = device_segment_reduce(ids, vals, segs)
    want = np.bincount(ids, weights=vals, minlength=segs).astype(np.int32)
    np.testing.assert_array_equal(got, want)


# -- cross-path byte identity --------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(
    st.integers(0, 2**31),
    st.sampled_from([37, 101, 128]),   # prime / non-divisible / aligned
    st.integers(1, 4),
    st.sampled_from([1, 40]),          # tight (spill) vs roomy capacity
)
def test_cross_path_byte_identity(seed, n, ndev, cap_pct):
    """Host numpy, storage-tier, and device (interpret) paths produce
    byte-identical int32 histograms — skewed keys, negative padding,
    non-divisible lengths, and the capacity-overflow spill path."""
    rng = np.random.default_rng(seed)
    vocab = 24
    keys = (rng.zipf(1.3, n) % vocab).astype(np.int32)
    keys[rng.random(n) < 0.1] = -1
    vals = rng.integers(1, 5, n).astype(np.int32)
    cap = cap_pct / 10.0
    want = host_histogram(keys, vals, vocab)
    s = storage_histogram(
        keys, vals, ndev, DramTier(), vocab=vocab, capacity_factor=cap,
        spill=True,
    )
    assert np.asarray(s.counts).tobytes() == want.tobytes()
    assert int(s.dropped) == 0
    d = device_histogram(
        jnp.asarray(keys), jnp.asarray(vals), _mesh1(), "data",
        vocab=vocab, capacity_factor=cap, spill_tier=DramTier(),
    )
    assert np.asarray(d.counts).tobytes() == want.tobytes()
    assert int(d.dropped) == 0
