"""Trace generator + SLO replay harness (repro.core.loadgen).

Generation is gated on determinism and on tracking its own declared
rate function (envelope, burst episodes, Zipf tenant skew, op mix);
replay is gated on count conservation (offered == completed + shed +
errors) and on the shed/backpressure distinction under a saturating
trace.  The SLO math is unit-tested on synthetic series where the right
answer is computable by hand.
"""

from __future__ import annotations

import json
import time

import pytest

from repro.api import ClusterConfig, MarvelClient
from repro.core.loadgen import (
    Arrival,
    BurstSpec,
    OpSpec,
    ReplayResult,
    TenantSeries,
    TraceSpec,
    generate_trace,
    rate_at,
    replay,
)
from repro.core.stateful import StatefulFunction


def _flat(duration=5.0, base_rate=400.0, **kw) -> TraceSpec:
    kw.setdefault("amplitude", 0.0)
    kw.setdefault("tenants", 4)
    return TraceSpec(seed=3, duration=duration, base_rate=base_rate, **kw)


class TestGeneration:
    def test_same_seed_same_trace(self):
        spec = _flat()
        assert generate_trace(spec) == generate_trace(spec)

    def test_different_seed_different_trace(self):
        spec = _flat()
        other = TraceSpec(
            seed=4, duration=spec.duration, base_rate=spec.base_rate, amplitude=0.0
        )
        assert generate_trace(spec) != generate_trace(other)

    def test_arrival_count_tracks_rate(self):
        spec = _flat()
        n = len(generate_trace(spec))
        expect = spec.base_rate * spec.duration
        assert abs(n - expect) / expect < 0.12

    def test_arrivals_sorted_and_in_range(self):
        spec = _flat(duration=2.0)
        trace = generate_trace(spec)
        times = [a.t for a in trace]
        assert times == sorted(times)
        assert all(0.0 <= t < spec.duration for t in times)
        assert all(a.tenant in spec.tenant_names() for a in trace)
        assert all(a.session.startswith("s") for a in trace)

    def test_zipf_tenant_skew(self):
        spec = _flat(duration=8.0, zipf_skew=1.0)
        trace = generate_trace(spec)
        counts = {name: 0 for name in spec.tenant_names()}
        for a in trace:
            counts[a.tenant] += 1
        # weights 1 : 1/2 : 1/3 : 1/4 — the head tenant dominates the tail
        assert counts["t0"] > 2.5 * counts["t3"]

    def test_burst_multiplies_target_tenant(self):
        spec = _flat(
            duration=6.0,
            bursts=(BurstSpec(start=2.0, duration=2.0, factor=4.0, tenant="t0"),),
        )
        trace = generate_trace(spec)
        before = sum(1 for a in trace if a.tenant == "t0" and a.t < 2.0)
        during = sum(1 for a in trace if a.tenant == "t0" and 2.0 <= a.t < 4.0)
        assert during > 2.5 * before
        # the *other* tenants' offered rate is untouched by t0's burst
        calm_b = sum(1 for a in trace if a.tenant != "t0" and a.t < 2.0)
        calm_d = sum(1 for a in trace if a.tenant != "t0" and 2.0 <= a.t < 4.0)
        assert calm_d < 1.5 * calm_b

    def test_diurnal_envelope_shapes_halves(self):
        spec = TraceSpec(
            seed=5, duration=6.0, base_rate=400.0, amplitude=0.5, period=6.0
        )
        trace = generate_trace(spec)
        first = sum(1 for a in trace if a.t < 3.0)
        second = len(trace) - first
        # sin is positive the first half-period, negative the second
        assert first > 1.4 * second

    def test_op_mix_weights(self):
        spec = _flat(
            duration=6.0,
            ops=(OpSpec("hot", weight=3.0), OpSpec("cold", weight=1.0)),
        )
        trace = generate_trace(spec)
        hot = sum(1 for a in trace if a.op.fn == "hot")
        assert 0.6 < hot / len(trace) < 0.9

    def test_rate_at_matches_components(self):
        spec = _flat(
            bursts=(BurstSpec(start=1.0, duration=1.0, factor=4.0, tenant="t0"),)
        )
        w0 = spec.tenant_weights()[0]
        calm = rate_at(spec, 0.5)
        burst = rate_at(spec, 1.5)
        assert burst == pytest.approx(calm + 3.0 * w0 * spec.base_rate)
        assert rate_at(spec, 1.5, "t0") == pytest.approx(4.0 * w0 * spec.base_rate)


# -- the SLO math on synthetic series --------------------------------------


def _result(**tenants) -> ReplayResult:
    spec = TraceSpec(
        duration=4.0,
        bursts=(BurstSpec(start=1.0, duration=1.0, factor=4.0, tenant="t0"),),
    )
    res = ReplayResult(spec=spec, slo_ms=100.0, window_s=1.0)
    res.tenants = dict(tenants)
    return res


class TestSloMath:
    def test_window_p99_and_slo_frac(self):
        ts = TenantSeries(
            "t0",
            offered=3,
            completed=2,
            shed=1,
            latencies=[(0.5, 0.010), (1.5, 0.500)],
            shed_t=[2.5],
        )
        res = _result(t0=ts)
        per_window = res.window_p99_ms()
        assert per_window[0] == pytest.approx(10.0)
        assert per_window[1] == pytest.approx(500.0)
        assert per_window[2] == float("inf")  # all-shed window fails
        assert res.p99_under_slo_frac() == pytest.approx(1 / 3)

    def test_goodput_counts_only_in_slo_completions(self):
        ts = TenantSeries(
            "t0",
            offered=4,
            completed=3,
            shed=1,
            latencies=[(0.1, 0.01), (0.2, 0.02), (0.3, 0.5)],
            shed_t=[0.4],
        )
        res = _result(t0=ts)
        assert res.goodput_frac() == pytest.approx(0.5)

    def test_isolation_reads_other_tenants_only(self):
        burster = TenantSeries(
            "t0", offered=2, completed=2, latencies=[(1.2, 9.0), (1.3, 9.0)]
        )
        bystander = TenantSeries(
            "t1",
            offered=4,
            completed=4,
            latencies=[(0.5, 0.050), (1.2, 0.200), (1.8, 0.200), (3.0, 0.050)],
        )
        res = _result(t0=burster, t1=bystander)
        iso = res.isolation()
        assert iso.burst_tenant == "t0"
        assert iso.burst_p99_ms == pytest.approx(200.0)
        assert iso.calm_p99_ms == pytest.approx(50.0)
        assert iso.ratio == pytest.approx(4.0)

    def test_series_dict_is_json_serializable(self):
        ts = TenantSeries(
            "t0", offered=2, completed=1, shed=1, latencies=[(0.5, 0.01)],
            shed_t=[1.5],
        )
        res = _result(t0=ts)
        payload = json.loads(json.dumps(res.series_dict()))
        assert payload["tenants"]["t0"]["offered"] == 2
        assert payload["tenants"]["t0"]["latency_ms"] == [[0.5, 10.0]]


# -- replay against a real client ------------------------------------------


def _sleepy_client(**cfg) -> MarvelClient:
    client = MarvelClient(ClusterConfig(name="lg", journal="none", **cfg))

    def step(state, ms=1.0):
        time.sleep(ms / 1e3)
        return state + 1, state + 1

    client.register(StatefulFunction("sleeper", step, init=lambda: 0, jit=False))
    return client


def _saturating_spec() -> TraceSpec:
    return TraceSpec(
        seed=9,
        duration=1.2,
        base_rate=120.0,
        tenants=2,
        sessions_per_tenant=8,
        amplitude=0.0,
        ops=(OpSpec("sleeper", inputs=(("ms", 20.0),)),),
    )


class TestReplay:
    def test_counts_conserved_and_sheds_under_saturation(self):
        spec = _saturating_spec()
        with _sleepy_client(invokers=1, target_inflight=1) as client:
            res = replay(
                client.submit,
                generate_trace(spec),
                spec=spec,
                slo_ms=100.0,
            )
        assert res.offered == len(generate_trace(spec))
        assert res.offered == res.completed + res.shed + res.errors
        assert res.errors == 0
        assert res.shed > 0  # 1 inflight slot vs ~120/s of 20ms calls
        assert res.backpressured == 0

    def test_block_admission_backpressures_instead(self):
        spec = _saturating_spec()
        with _sleepy_client(invokers=2, target_inflight=2) as client:
            res = replay(
                client.submit,
                generate_trace(spec),
                spec=spec,
                slo_ms=100.0,
                admission="block",
                retry_timeout=30.0,
            )
        assert res.backpressured > 0
        assert res.offered == res.completed + res.shed + res.errors
        assert res.errors == 0

    def test_tick_is_pumped(self):
        spec = TraceSpec(
            seed=1, duration=0.6, base_rate=60.0, tenants=1, amplitude=0.0,
            ops=(OpSpec("sleeper", inputs=(("ms", 1.0),)),),
        )
        ticks = []
        with _sleepy_client(invokers=2) as client:
            replay(
                client.submit,
                generate_trace(spec),
                spec=spec,
                tick=ticks.append,
                tick_interval=0.05,
            )
        assert len(ticks) >= 5
        assert ticks == sorted(ticks)

    def test_unknown_admission_policy_rejected(self):
        with pytest.raises(ValueError):
            replay(lambda **kw: None, [], admission="drop")

    def test_per_tenant_series_recorded(self):
        spec = TraceSpec(
            seed=2, duration=0.8, base_rate=80.0, tenants=3, amplitude=0.0,
            ops=(OpSpec("sleeper", inputs=(("ms", 1.0),)),),
        )
        with _sleepy_client(invokers=4) as client:
            res = replay(client.submit, generate_trace(spec), spec=spec)
        assert set(res.tenants) == {"t0", "t1", "t2"}
        for ts in res.tenants.values():
            assert ts.offered == ts.completed + ts.shed + ts.errors
            assert len(ts.latencies) == ts.completed
