"""Shared fixtures.  NOTE: no XLA_FLAGS here on purpose — smoke tests and
benches must see the real single CPU device; only the dry-run subprocess
(tests/test_dryrun.py) forces 512 host devices, in its own process."""

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
