"""Storage substrate: tiers, block store, state cache, checkpointing."""

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.storage import (
    BlockStore,
    CheckpointManager,
    DataNode,
    DramTier,
    PmemTier,
    QuotaExceededError,
    S3_SPEC,
    SimulatedTier,
    StateCache,
)
from repro.storage import serde


# -- tiers ---------------------------------------------------------------

def test_dram_tier_roundtrip():
    t = DramTier()
    t.put("a", b"x" * 100)
    assert t.get("a") == b"x" * 100
    assert t.contains("a")
    t.delete("a")
    assert not t.contains("a")


def test_dram_capacity_enforced():
    t = DramTier(capacity_bytes=10)
    with pytest.raises(MemoryError):
        t.put("a", b"y" * 11)


def test_pmem_tier_persistence(tmp_path):
    t = PmemTier(str(tmp_path))
    t.put("dir/blob", b"hello")
    # a new instance over the same root sees the data (process restart)
    t2 = PmemTier(str(tmp_path))
    assert t2.get("dir/blob") == b"hello"
    assert "dir/blob" in list(t2.keys())


def test_simulated_tier_models_time_and_quota():
    s3 = SimulatedTier(S3_SPEC)
    s3.put("k", b"z" * 10_000)
    assert s3.stats.modeled_seconds > 0
    s3.reset_quota()
    with pytest.raises(QuotaExceededError):
        # exceeds the 15 GB transfer quota in one logical move
        for i in range(16):
            s3._charge(10**9, write=True)


def test_tier_accounting():
    t = DramTier()
    t.put("a", b"12345")
    t.get("a")
    assert t.stats.bytes_written == 5
    assert t.stats.bytes_read == 5
    assert t.stats.write_ops == 1 and t.stats.read_ops == 1


def test_tier_watch_fires_on_put_and_put_many(tmp_path):
    for tier in (DramTier(), PmemTier(str(tmp_path)),
                 SimulatedTier(S3_SPEC)):
        seen = []
        unsub = tier.watch("job/", seen.append)
        tier.put("job/a", b"1")
        tier.put("other/b", b"2")  # outside the prefix
        tier.put_many({"job/c": b"3", "job/d": b"4"})
        assert seen == ["job/a", "job/c", "job/d"], tier.name
        unsub()
        tier.put("job/e", b"5")
        assert seen == ["job/a", "job/c", "job/d"]  # unsubscribed


def test_tier_watch_value_readable_in_callback():
    t = DramTier()
    got = {}
    t.watch("", lambda k: got.setdefault(k, t.get(k)))
    t.put("k", b"v")
    assert got == {"k": b"v"}


def test_simulated_put_many_batches_request_latency():
    """A batch pays one request latency; N puts pay N — the streaming
    shuffle's fast path."""
    blobs = {f"k{i}": b"x" * 1000 for i in range(16)}
    one_by_one = SimulatedTier(S3_SPEC)
    for k, v in blobs.items():
        one_by_one.put(k, v)
    batched = SimulatedTier(S3_SPEC)
    batched.put_many(blobs)
    assert batched.stats.bytes_written == one_by_one.stats.bytes_written
    assert all(batched.contains(k) for k in blobs)
    lat = S3_SPEC.write_latency
    saved = one_by_one.stats.modeled_seconds - batched.stats.modeled_seconds
    assert saved == pytest.approx(15 * lat, rel=1e-6)


# -- serde ---------------------------------------------------------------

def test_serde_roundtrip_pytree():
    tree = {
        "w": np.arange(6, dtype=np.float32).reshape(2, 3),
        "b": jnp.ones((3,), jnp.bfloat16),
        "step": 7,
        "nested": (1, [2.5, "s"], {"x": None}),
    }
    back = serde.loads(serde.dumps(tree))
    assert back["step"] == 7
    assert back["nested"] == (1, [2.5, "s"], {"x": None})
    np.testing.assert_array_equal(back["w"], tree["w"])
    assert np.asarray(back["b"]).dtype == jnp.bfloat16.dtype


@settings(max_examples=25, deadline=None)
@given(
    st.lists(
        st.tuples(
            st.sampled_from(["f32", "i32", "bf16"]),
            st.lists(st.integers(1, 5), min_size=0, max_size=3),
        ),
        min_size=1,
        max_size=4,
    ),
    st.integers(0, 2**31),
)
def test_serde_property_roundtrip(specs, seed):
    """Any pytree of arrays round-trips bit-exactly."""
    r = np.random.default_rng(seed)
    tree = {}
    for i, (kind, shape) in enumerate(specs):
        if kind == "f32":
            arr = r.standard_normal(shape).astype(np.float32)
        elif kind == "i32":
            arr = r.integers(-100, 100, shape).astype(np.int32)
        else:
            arr = jnp.asarray(
                r.standard_normal(shape).astype(np.float32)
            ).astype(jnp.bfloat16)
        tree[f"k{i}"] = arr
    back = serde.loads(serde.dumps(tree))
    for k, v in tree.items():
        np.testing.assert_array_equal(
            np.asarray(back[k]).view(np.uint16)
            if np.asarray(v).dtype == jnp.bfloat16.dtype
            else np.asarray(back[k]),
            np.asarray(v).view(np.uint16)
            if np.asarray(v).dtype == jnp.bfloat16.dtype
            else np.asarray(v),
        )


# -- block store --------------------------------------------------------

def _store(n=4, block_size=100, repl=2):
    return BlockStore(
        [DataNode(f"n{i}", DramTier()) for i in range(n)],
        block_size=block_size,
        replication=repl,
    )


def test_blockstore_roundtrip_and_locality():
    bs = _store()
    data = bytes(range(256)) * 3
    bs.write("/f", data)
    assert bs.read("/f") == data
    blocks = bs.locate("/f")
    assert all(len(b.replicas) == 2 for b in blocks)


def test_blockstore_record_aligned_split():
    bs = _store(block_size=50)
    lines = [f"line {i} {'x' * (i % 17)}".encode() for i in range(40)]
    data = b"\n".join(lines)
    bs.write("/t", data, record_delim=b"\n")
    # every block except maybe the last ends on a record boundary
    for bm in bs.locate("/t")[:-1]:
        assert bs.read_block(bm).endswith(b"\n")
    assert bs.read("/t") == data


@settings(max_examples=20, deadline=None)
@given(st.binary(min_size=0, max_size=5000), st.integers(10, 300))
def test_blockstore_property_roundtrip(data, block_size):
    bs = _store(block_size=block_size)
    bs.write("/p", data)
    assert bs.read("/p") == data


def test_blockstore_survives_replica_failure():
    bs = _store()
    data = b"important" * 100
    bs.write("/f", data)
    victim = bs.locate("/f")[0].replicas[0]
    bs.fail_node(victim)
    assert bs.read("/f") == data
    fixed = bs.re_replicate()
    assert fixed >= 1
    # now every block is back at full replication on live nodes
    for bm in bs.locate("/f"):
        assert len([r for r in bm.replicas if r != victim]) >= 2


def test_blockstore_detects_corruption():
    bs = _store(repl=1)
    bs.write("/f", b"data data data")
    bm = bs.locate("/f")[0]
    node = bs.nodes[bm.replicas[0]]
    node.tier.put(node.block_key(bm.block_id), b"corrupted!!")
    with pytest.raises(IOError):
        bs.read("/f")


# -- state cache --------------------------------------------------------

def test_state_cache_write_through_recovery(tmp_path):
    sc = StateCache(write_through=PmemTier(str(tmp_path)))
    sc.put("s1", b"state one")
    sc.put("s2", b"state two")
    sc.crash()
    assert sc.get("s1") == b"state one"  # demand fault
    assert sc.recover() >= 1
    assert sc.get("s2") == b"state two"


def test_state_cache_volatile_loses_data():
    sc = StateCache()
    sc.put("k", b"v")
    sc.crash()
    with pytest.raises(KeyError):
        sc.get("k")


def test_state_cache_put_many_and_watch(tmp_path):
    sc = StateCache(write_through=PmemTier(str(tmp_path)))
    seen = []
    unsub = sc.watch("mr/", seen.append)
    sc.put_many({"mr/a": b"1", "mr/b": b"2", "x/c": b"3"})
    assert sorted(seen) == ["mr/a", "mr/b"]
    sc.crash()
    assert sc.get("mr/a") == b"1"  # batch reached the persistent tier
    # the demand-fault re-read is not a commit -> no phantom event
    assert sorted(seen) == ["mr/a", "mr/b"]
    unsub()


def test_state_cache_namespacing():
    sc = StateCache()
    a = sc.namespaced("app1")
    b = sc.namespaced("app2")
    a.put("k", b"1")
    b.put("k", b"2")
    assert a.get("k") == b"1" and b.get("k") == b"2"
    assert a.keys() == ["k"]


# -- checkpoint manager ---------------------------------------------------

def test_checkpoint_roundtrip_and_gc(tmp_path):
    cm = CheckpointManager(PmemTier(str(tmp_path)), "ck", keep=2)
    for s in (1, 2, 3):
        cm.save(s, {"w": np.full((4,), s, np.float32), "step": s})
    cm.wait()
    assert cm.steps() == [2, 3]
    state = cm.restore()
    assert state["step"] == 3
    state2 = cm.restore(step=2)
    assert state2["step"] == 2
    cm.close()


def test_checkpoint_integrity_check(tmp_path):
    tier = PmemTier(str(tmp_path))
    cm = CheckpointManager(tier, "ck", keep=2)
    cm.save(1, {"w": np.ones(3)})
    cm.wait()
    blob_key = [k for k in tier.keys() if k.endswith(".blob")][0]
    tier.put(blob_key, b"garbage")
    with pytest.raises(IOError):
        cm.restore()
    cm.close()


def test_checkpoint_restore_is_crash_consistent(tmp_path):
    """A blob without its manifest (crash mid-drain) is invisible."""
    tier = PmemTier(str(tmp_path))
    cm = CheckpointManager(tier, "ck", keep=5)
    cm.save(1, {"x": np.ones(2)})
    cm.wait()
    # simulate a partial step-2 checkpoint: blob only, no manifest commit
    tier.put("ck/step_000000000002.blob", b"partial")
    assert cm.steps() == [1]
    assert np.all(cm.restore()["x"] == 1)
    cm.close()
