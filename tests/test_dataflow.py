"""Iterative multi-stage dataflow engine: stages, loops, pinning, resume.

Covers the ISSUE-4 tentpole surface: ``lower_stages`` barrier wiring and
namespacing, ``run_stages`` task-granular resume (TeraSort), ``run_loop``
superstep commit markers + byte-identical resume (PageRank, k-means),
loop-state pinning in the ``TieredStore`` fast level, and warm gateway
sessions carrying centroid state across iterations.
"""

import threading

import numpy as np
import pytest

from repro.core import FunctionRuntime, Gateway, Scheduler, StateJournal
from repro.core.dag import StageDag, TaskSpec, task_token
from repro.core.dataflow import (
    Stage,
    StageTask,
    lower_stages,
    run_loop,
    run_stages,
)
from repro.core.workloads import (
    kmeans_loop,
    kmeans_points,
    pagerank_graph,
    pagerank_loop,
    terasort,
    terasort_output,
)
from repro.storage import (
    S3_SPEC,
    DramTier,
    PlacementPolicy,
    SimulatedTier,
    StateCache,
    TieredStore,
    TierLevel,
)


def _sched():
    return Scheduler(["w0", "w1", "w2", "w3"], speculation_factor=None)


def _pinned_store(name="t"):
    return TieredStore(
        [
            TierLevel("dram", DramTier(), None),
            TierLevel("s3", SimulatedTier(S3_SPEC)),
        ],
        policy=PlacementPolicy(write_back=True, promote_after=1),
        journal=StateCache(),
        name=name,
    )


# -- lower_stages -------------------------------------------------------------

def test_lower_stages_barriers_consecutive_stages():
    order = []
    lock = threading.Lock()

    def mk(tid):
        def run(_ctx):
            with lock:
                order.append(tid)

        return run

    dag = lower_stages("j", [
        Stage("a", [StageTask("a0", mk("a0")), StageTask("a1", mk("a1"))]),
        Stage("b", [StageTask("b0", mk("b0"))]),
        Stage("c", [StageTask("c0", mk("c0"))]),
    ], namespace="j/")
    res = _sched().run_dag(dag.specs, initial_tokens=dag.initial_tokens)
    assert set(res) == {"j/a0", "j/a1", "j/b0", "j/c0"}
    assert order.index("b0") > max(order.index("a0"), order.index("a1"))
    assert order.index("c0") > order.index("b0")


def test_lower_stages_namespaces_task_deps():
    hit = []
    dag = lower_stages("j", [
        Stage("s", [
            StageTask("first", lambda _: hit.append("first")),
            StageTask("second", lambda _: hit.append("second"),
                      deps=["task:first"]),
        ]),
    ], namespace="ns/")
    assert {s.task_id for s in dag.specs} == {"ns/first", "ns/second"}
    second = next(s for s in dag.specs if s.task_id == "ns/second")
    assert second.deps == frozenset({task_token("ns/first")})
    _sched().run_dag(dag.specs)
    assert hit == ["first", "second"]


def test_lower_stages_rejects_duplicate_and_unknown_stage():
    with pytest.raises(ValueError, match="duplicate stage"):
        lower_stages("j", [Stage("s", []), Stage("s", [])])
    with pytest.raises(ValueError, match="unknown"):
        lower_stages("j", [Stage("s", [], after=("nope",))])
    with pytest.raises(ValueError, match="unknown"):
        # forward barriers can never be satisfied — rejected up front
        lower_stages("j", [Stage("a", [], after=("b",)), Stage("b", [])])


def test_lower_stages_resumed_task_satisfies_barrier():
    ran = []
    dag = lower_stages("j", [
        Stage("a", [
            StageTask("a0", resumed=True, outputs=["data/x"]),
            StageTask("a1", lambda _: ran.append("a1")),
        ]),
        Stage("b", [StageTask("b0", lambda _: ran.append("b0"))]),
    ])
    assert task_token("a0") in dag.initial_tokens
    assert "data/x" in dag.initial_tokens
    res = _sched().run_dag(dag.specs, initial_tokens=dag.initial_tokens)
    assert set(res) == {"a1", "b0"}
    assert ran == ["a1", "b0"]


# -- StageDag.resume / stage_tokens ------------------------------------------

def test_stagedag_resume_and_stage_tokens():
    dag = StageDag("d")
    dag.add(TaskSpec("live", lambda c: None, stage="s"))
    dag.resume("done", stage="s", produces=["k1"])
    assert dag.stage_tokens("s") == frozenset(
        {task_token("live"), task_token("done")}
    )
    assert dag.initial_tokens == [task_token("done"), "k1"]
    with pytest.raises(ValueError):
        dag.resume("live", stage="s")
    other = StageDag("o")
    other.resume("other_done", stage="s2")
    dag.merge(other)
    assert task_token("other_done") in dag.initial_tokens
    assert dag.stage_tokens("s2") == frozenset({task_token("other_done")})


# -- external tokens ----------------------------------------------------------

def test_lower_stages_external_tokens_satisfy_data_deps():
    """A data-key dep published from outside the DAG (tier watch,
    pre-existing tier data) must pass validation when declared."""
    with pytest.raises(ValueError, match="unsatisfiable"):
        lower_stages("j", [
            Stage("s", [StageTask("t", lambda _: None,
                                  deps=["ext/data"])]),
        ])
    dag = lower_stages("j", [
        Stage("s", [StageTask("t", lambda _: None, deps=["ext/data"])]),
    ], external_tokens=["ext/data"])
    res = _sched().run_dag(dag.specs, initial_tokens=["ext/data"])
    assert set(res) == {"t"}


# -- scheduler.pooled ---------------------------------------------------------

def test_scheduler_pooled_reuses_one_executor():
    sched = _sched()
    with sched.pooled():
        sched.run_dag([TaskSpec("a", lambda c: 1)])
        pool = sched._pool
        assert pool is not None
        sched.run_dag([TaskSpec("b", lambda c: 2)])
        assert sched._pool is pool  # same executor across runs
    assert sched._pool is None  # scope created it, scope reaped it
    assert sched.reuse_pool is False


# -- run_stages / TeraSort ----------------------------------------------------

def _records(rng, n, parts):
    return [
        b"\n".join(rng.bytes(10).hex().encode() for _ in range(n))
        for _ in range(parts)
    ]


def test_terasort_sorts_globally(rng):
    parts = _records(rng, 80, 4)
    state = DramTier()
    rep = terasort("ts", state, parts, n_ranges=3, scheduler=_sched())
    assert rep.tasks == 4 + (1 + 4) + 3
    out = terasort_output(state, "ts", 3)
    assert out == sorted(r for p in parts for r in p.split(b"\n"))


def test_terasort_journal_resume_skips_done_and_reruns_lost(rng):
    parts = _records(rng, 40, 3)
    state, journal = DramTier(), StateCache()
    rep1 = terasort("ts", state, parts, n_ranges=2, journal=journal,
                    scheduler=_sched())
    assert rep1.resumed_tasks == 0
    rep2 = terasort("ts", state, parts, n_ranges=2, journal=journal,
                    scheduler=_sched())
    assert rep2.resumed_tasks == rep2.tasks  # nothing recomputed
    # a lost output invalidates exactly that task's resume
    state.delete("df/ts/out/r001")
    rep3 = terasort("ts", state, parts, n_ranges=2, journal=journal,
                    scheduler=_sched())
    assert rep3.resumed_tasks == rep3.tasks - 1
    assert terasort_output(state, "ts", 2) == sorted(
        r for p in parts for r in p.split(b"\n")
    )


# -- run_loop / PageRank ------------------------------------------------------

def _pagerank_reference(src, dst, n, iterations, damping=0.85):
    r = np.full(n, 1.0 / n)
    deg = np.bincount(src, minlength=n)
    for _ in range(iterations):
        contrib = np.zeros(n)
        np.add.at(contrib, dst, r[src] / deg[src])
        r = (1.0 - damping) / n + damping * contrib
    return r


def test_pagerank_matches_reference_power_iteration():
    src, dst = pagerank_graph(150, 900, seed=3)
    res = pagerank_loop("pr", DramTier(), src, dst, 150, n_parts=3,
                        tol=0.0, max_iterations=6, scheduler=_sched())
    assert res.report.last_iteration == 6
    ref = _pagerank_reference(src, dst, 150, 6)
    np.testing.assert_allclose(res.ranks, ref, rtol=0, atol=1e-12)
    assert abs(res.ranks.sum() - 1.0) < 0.2  # damping keeps mass ~1


def test_pagerank_converges_under_tolerance():
    src, dst = pagerank_graph(100, 800, seed=4)
    res = pagerank_loop("pr", DramTier(), src, dst, 100, n_parts=2,
                        tol=1e-4, max_iterations=50, scheduler=_sched())
    assert res.report.converged
    assert res.report.last_iteration < 50


def test_pagerank_resume_is_byte_identical(rng):
    src, dst = pagerank_graph(120, 700, seed=5)
    golden = pagerank_loop("pr", DramTier(), src, dst, 120, n_parts=3,
                           tol=0.0, max_iterations=7, scheduler=_sched())
    state, journal = DramTier(), StateCache()
    first = pagerank_loop("pr", state, src, dst, 120, n_parts=3,
                          tol=0.0, max_iterations=7, journal=journal,
                          halt_after=4, scheduler=_sched())
    assert first.report.iterations == 4  # init + 3 supersteps
    assert not first.report.converged
    second = pagerank_loop("pr", state, src, dst, 120, n_parts=3,
                           tol=0.0, max_iterations=7, journal=journal,
                           scheduler=_sched())
    # the committed supersteps were skipped, not recomputed
    assert second.report.resumed_iterations == first.report.iterations
    assert second.report.last_iteration == 7
    assert second.rank_bytes == golden.rank_bytes


def test_pagerank_resume_of_converged_loop_is_noop():
    src, dst = pagerank_graph(80, 600, seed=6)
    state, journal = DramTier(), StateCache()
    kw = dict(tol=1e-4, max_iterations=50, journal=journal)
    first = pagerank_loop("pr", state, src, dst, 80, n_parts=2,
                          scheduler=_sched(), **kw)
    assert first.report.converged
    again = pagerank_loop("pr", state, src, dst, 80, n_parts=2,
                          scheduler=_sched(), **kw)
    assert again.report.converged
    assert again.report.iterations == 0
    assert again.rank_bytes == first.rank_bytes


# -- loop-state pinning -------------------------------------------------------

def test_loop_state_pinned_in_fast_level_and_released():
    src, dst = pagerank_graph(100, 600, seed=7)
    store = _pinned_store()
    res = pagerank_loop("pr", store, src, dst, 100, n_parts=2,
                        tol=0.0, max_iterations=4, scheduler=_sched())
    # pinned for the life of the loop: zero inline modeled device time
    # (writes acked in DRAM, reads served from DRAM)
    assert res.report.modeled_io_seconds == 0.0
    assert store.pinned_prefixes == []  # released on exit
    store.close()


def test_pinned_vs_cold_outputs_byte_identical():
    src, dst = pagerank_graph(100, 700, seed=8)
    store = _pinned_store()
    hot = pagerank_loop("pr", store, src, dst, 100, n_parts=2,
                        tol=0.0, max_iterations=5, scheduler=_sched())
    store.close()
    cold = pagerank_loop("pr", SimulatedTier(S3_SPEC), src, dst, 100,
                         n_parts=2, tol=0.0, max_iterations=5,
                         pin_state=False, scheduler=_sched())
    assert hot.rank_bytes == cold.rank_bytes
    assert cold.report.modeled_io_seconds > 0.0


def test_tieredstore_pin_blocks_demotion_and_promotes():
    store = TieredStore(
        [
            TierLevel("dram", DramTier(), 4096),
            TierLevel("s3", SimulatedTier(S3_SPEC)),
        ],
        name="p",
    )
    store.put("loop/x", b"a" * 1024)
    store.demote("loop/x")
    assert store.level_of("loop/x") == "s3"
    store.pin("loop/")
    # pin promotes already-resident matching keys immediately
    assert store.level_of("loop/x") == "dram"
    # pinned keys refuse explicit demotion...
    assert store.demote("loop/x") is False
    assert store.level_of("loop/x") == "dram"
    # ...and are never capacity victims: unpinned traffic overflows past
    # them without displacing the pinned key
    for i in range(8):
        store.put(f"other/{i}", b"b" * 1024)
    assert store.level_of("loop/x") == "dram"
    store.unpin("loop/")
    assert store.demote("loop/x") is True
    store.close()


# -- k-means + warm gateway sessions -----------------------------------------

def test_kmeans_warm_session_matches_cold_bytes():
    pts, _ = kmeans_points(300, 3, 4, seed=9)
    cold = kmeans_loop("km", DramTier(), pts, 4, n_parts=3, tol=0.0,
                       max_iterations=5, scheduler=_sched())
    assert cold.warm_read_frac == 0.0
    gw = Gateway(FunctionRuntime(cache=StateCache()), invokers=2)
    try:
        warm = kmeans_loop("km", DramTier(), pts, 4, n_parts=3, tol=0.0,
                           max_iterations=5, gateway=gw)
        # iterations >= 2 read centroids straight from the hot session
        assert warm.warm_read_frac > 0.5
        assert warm.centroid_bytes == cold.centroid_bytes
        # the gateway served the update invocations warm after the first
        stats = gw.stats()
        assert stats.warm_hits >= stats.cold_starts
    finally:
        gw.close()


def test_kmeans_resume_is_byte_identical():
    pts, _ = kmeans_points(240, 2, 3, seed=10)
    golden = kmeans_loop("km", DramTier(), pts, 3, n_parts=2, tol=0.0,
                         max_iterations=6, scheduler=_sched())
    state, journal = DramTier(), StateCache()
    kmeans_loop("km", state, pts, 3, n_parts=2, tol=0.0, max_iterations=6,
                journal=journal, halt_after=3, scheduler=_sched())
    res = kmeans_loop("km", state, pts, 3, n_parts=2, tol=0.0,
                      max_iterations=6, journal=journal, scheduler=_sched())
    assert res.report.resumed_iterations == 3
    assert res.centroid_bytes == golden.centroid_bytes


def test_gateway_pin_warm_survives_pool_pressure():
    rt = FunctionRuntime(cache=StateCache())
    from repro.core.stateful import StatefulFunction

    rt.register(StatefulFunction(
        "f", lambda s, x: (s + x, s + x), init=lambda: 0, jit=False
    ))
    gw = Gateway(rt, invokers=1, warm_pool=2)
    try:
        gw.invoke("f", session="pinned", x=1)
        gw.pin_warm("f", session="pinned")
        for i in range(6):
            gw.invoke("f", session=f"churn{i}", x=1)
        assert ("f", "pinned") in gw.warm_contexts()
        assert rt.state_report("f", "pinned") == "hot"
        gw.unpin_warm("f", session="pinned")
        for i in range(6):
            gw.invoke("f", session=f"churn2_{i}", x=1)
        assert ("f", "pinned") not in gw.warm_contexts()
    finally:
        gw.close()


# -- engine-level loop behaviours --------------------------------------------

def test_run_loop_mid_superstep_garbage_is_swept_and_rerun():
    """Partial state from a crashed superstep (blobs, no marker) must not
    poison the resume: the superstep re-runs and output bytes match."""
    state, journal = DramTier(), StateCache()

    def init(ctx):
        ctx.write("x", b"seed")

    def superstep(ctx):
        def run(_tc):
            import hashlib

            prev = ctx.read("x")
            ctx.write("x", hashlib.blake2b(
                prev + str(ctx.iteration).encode(), digest_size=16
            ).digest())

        return [Stage("s", [StageTask("t", run)])]

    kw = dict(state=state, journal=journal, max_iterations=5,
              pin_state=False)
    run_loop("hash", init, superstep, lambda ctx: False,
             scheduler=_sched(), halt_after=3, **kw)
    # simulate a crash mid-superstep-3: partial version-3 blobs landed
    # (including a key the re-run will never rewrite), no marker
    state.put("df/hash/state/it00003/x", b"partial-garbage")
    state.put("df/hash/state/it00003/orphan", b"never-rewritten")
    res = run_loop("hash", init, superstep, lambda ctx: False,
                   scheduler=_sched(), **kw)
    assert res.resumed_iterations == 3  # init + supersteps 1..2
    # the resume sweep collected the partial version entirely
    assert not state.contains("df/hash/state/it00003/orphan")
    golden_state = DramTier()
    golden = run_loop("hash", init, superstep, lambda ctx: False,
                      state=golden_state, journal=None, max_iterations=5,
                      pin_state=False, scheduler=_sched())
    assert golden.last_iteration == res.last_iteration
    assert (state.get("df/hash/state/it00005/x")
            == golden_state.get("df/hash/state/it00005/x"))


def test_run_loop_retracts_orphan_markers_on_resume():
    """Interrupted GC (crash after commit(k), before retract(k-1)) must
    not grow the loop journal forever: resume retracts every marker but
    the resume point's."""
    state, journal = DramTier(), StateCache()

    def init(ctx):
        ctx.write("x", b"0")

    def superstep(ctx):
        def run(_tc):
            ctx.write("x", ctx.read("x") + b".")

        return [Stage("s", [StageTask("t", run)])]

    kw = dict(state=state, journal=journal, max_iterations=4,
              pin_state=False)
    run_loop("orph", init, superstep, lambda ctx: False,
             scheduler=_sched(), halt_after=3, **kw)
    # simulate the interrupted GC: an old marker survived retraction
    sj = StateJournal(journal, "df/orph/loop")
    sj.commit("it00001", {"keys": ["x"], "converged": False})
    run_loop("orph", init, superstep, lambda ctx: False,
             scheduler=_sched(), **kw)
    assert list(sj.entries()) == ["it00004"]  # O(1) journal restored


def test_run_loop_retires_old_state_versions():
    state = DramTier()

    def init(ctx):
        ctx.write("x", b"0")

    def superstep(ctx):
        def run(_tc):
            ctx.write("x", ctx.read("x") + b".")

        return [Stage("s", [StageTask("t", run)])]

    run_loop("gc", init, superstep, lambda ctx: False, state=state,
             journal=StateCache(), max_iterations=6, pin_state=False,
             scheduler=_sched())
    versions = sorted(
        k for k in state.keys() if k.startswith("df/gc/state/")
    )
    # only the final version survives: the pinned working set is O(1)
    assert versions == ["df/gc/state/it00006/x"]


def test_run_stages_reports_timing_and_results():
    state = DramTier()
    rep = run_stages("j", [
        Stage("only", [StageTask("t", lambda _: {"v": 41})]),
    ], state, scheduler=_sched())
    assert rep.result("t").value == {"v": 41}
    assert rep.tasks == 1 and rep.resumed_tasks == 0
    assert rep.wall_seconds >= 0.0
