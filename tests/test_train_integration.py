"""End-to-end training integration: loss descent, checkpoint/restart
determinism, optimizer correctness, gradient compression."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import PipelineConfig, SyntheticTokens, make_batch
from repro.launch.mesh import make_smoke_mesh
from repro.launch.steps import make_train_step
from repro.models import ShapeConfig, init_params, model_defs, reduced_for_smoke
from repro.optim.adamw import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
)
from repro.optim.compression import compress_decompress, ef_init
from repro.storage import CheckpointManager, PmemTier

SHAPE = ShapeConfig(
    name="t", kind="train", seq_len=64, global_batch=8, microbatches=2,
    q_chunk=32, kv_chunk=32, loss_chunk=32, remat="none",
)


def _setup(arch="qwen2.5-3b", lr=3e-3, **kw):
    cfg = reduced_for_smoke(get_config(arch))
    mesh = make_smoke_mesh()
    bundle = make_train_step(cfg, SHAPE, mesh,
                             AdamWConfig(lr=lr, weight_decay=0.0), **kw)
    fn = bundle.jitted(mesh)
    params = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x,
        init_params(model_defs(cfg), jax.random.PRNGKey(0)),
    )
    opt = adamw_init(params)
    pipe = PipelineConfig(vocab=cfg.vocab, seq_len=SHAPE.seq_len,
                          global_batch=SHAPE.global_batch)
    return cfg, fn, params, opt, pipe


def _j(batch):
    return {k: jnp.asarray(v) for k, v in batch.items()}


def test_training_reduces_loss():
    cfg, fn, params, opt, pipe = _setup()
    losses = []
    for step in range(15):
        params, opt, metrics = fn(params, opt, _j(make_batch(pipe, step)))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.85, losses[::4]
    assert np.isfinite(losses).all()


def test_training_microbatching_equivalence():
    """n_mb=1 and n_mb=2 give (near-)identical grads -> same loss path."""
    import dataclasses

    cfg = reduced_for_smoke(get_config("qwen2.5-3b"))
    mesh = make_smoke_mesh()
    outs = []
    for n_mb in (1, 2):
        shape = dataclasses.replace(SHAPE, microbatches=n_mb)
        bundle = make_train_step(cfg, shape, mesh,
                                 AdamWConfig(lr=1e-3, weight_decay=0.0))
        fn = bundle.jitted(mesh)
        params = jax.tree_util.tree_map(
            lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x,
            init_params(model_defs(cfg), jax.random.PRNGKey(0)),
        )
        opt = adamw_init(params)
        pipe = PipelineConfig(vocab=cfg.vocab, seq_len=shape.seq_len,
                              global_batch=shape.global_batch)
        for step in range(3):
            params, opt, metrics = fn(params, opt, _j(make_batch(pipe, step)))
        outs.append(float(metrics["loss"]))
    assert abs(outs[0] - outs[1]) < 0.05, outs


def test_checkpoint_restart_is_deterministic(tmp_path):
    """Crash + restore replays the identical loss trajectory."""
    cfg, fn, params, opt, pipe = _setup()
    ckpt = CheckpointManager(PmemTier(str(tmp_path)), "t", keep=2)
    losses = {}
    for step in range(10):
        params, opt, metrics = fn(params, opt, _j(make_batch(pipe, step)))
        losses[step + 1] = float(metrics["loss"])
        if (step + 1) == 5:
            ckpt.save(5, {
                "params": jax.tree_util.tree_leaves(params),
                "opt": jax.tree_util.tree_leaves(opt),
            })
    ckpt.wait()
    # crash: rebuild from checkpoint and replay steps 5..10
    cfg2, fn2, params2, opt2, _ = _setup()
    state = ckpt.restore()
    params2 = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(params2), state["params"])
    opt2 = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(opt2), state["opt"])
    for step in range(5, 10):
        params2, opt2, metrics = fn2(params2, opt2,
                                     _j(make_batch(pipe, step)))
        assert abs(float(metrics["loss"]) - losses[step + 1]) < 1e-4, step
    ckpt.close()


def test_compressed_grads_still_learn():
    cfg, fn, params, opt, pipe = _setup(lr=3e-3, compress_grads=True)
    ef = ef_init(params)
    losses = []
    for step in range(12):
        params, opt, metrics, ef = fn(params, opt, _j(make_batch(pipe, step)),
                                      ef)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::5]


# -- optimizer units ---------------------------------------------------------

def test_adamw_converges_on_quadratic():
    params = {"x": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.3, weight_decay=0.0)
    for _ in range(150):
        grads = {"x": 2 * params["x"]}
        params, opt, _ = adamw_update(params, grads, opt, cfg)
    assert float(jnp.abs(params["x"]).max()) < 1e-2


def test_grad_clip():
    grads = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


def test_cosine_schedule_shape():
    sched = cosine_schedule(1.0, warmup=10, total=100, min_frac=0.1)
    assert float(sched(jnp.int32(0))) == 0.0
    assert float(sched(jnp.int32(10))) == pytest.approx(1.0)
    assert float(sched(jnp.int32(100))) == pytest.approx(0.1, abs=1e-6)


def test_error_feedback_preserves_signal():
    """EF property: cumulative decompressed grads track cumulative true
    grads (the residual stays bounded, bias cancels)."""
    rng = np.random.default_rng(0)
    g_true = [
        {"w": jnp.asarray(rng.standard_normal(64).astype(np.float32))}
        for _ in range(30)
    ]
    ef = ef_init(g_true[0])
    total_true = np.zeros(64, np.float32)
    total_deq = np.zeros(64, np.float32)
    for g in g_true:
        deq, ef, _err = compress_decompress(g, ef)
        total_true += np.asarray(g["w"])
        total_deq += np.asarray(deq["w"])
    # cumulative error is bounded by one quantization step, not O(steps)
    resid = np.abs(total_true - total_deq).max()
    per_step_q = max(np.abs(np.asarray(g["w"])).max() for g in g_true) / 127
    assert resid < 10 * per_step_q


# -- data pipeline ---------------------------------------------------------

def test_pipeline_deterministic():
    pipe = PipelineConfig(vocab=100, seq_len=16, global_batch=4)
    a = make_batch(pipe, 7)
    b = make_batch(pipe, 7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = make_batch(pipe, 8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_pipeline_labels_shifted():
    pipe = PipelineConfig(vocab=100, seq_len=16, global_batch=2, p_rule=1.0)
    b = make_batch(pipe, 0)
    # with p_rule=1 the affine rule holds everywhere
    a, c = 31337 % 100, 17
    np.testing.assert_array_equal(
        b["labels"][:, :-1], b["tokens"][:, 1:]
    )
    np.testing.assert_array_equal(
        (b["tokens"] * a + c) % 100, b["labels"]
    )


def test_pipeline_prefetch_iterator():
    pipe = PipelineConfig(vocab=50, seq_len=8, global_batch=2)
    it = SyntheticTokens(pipe, start_step=3)
    first = next(it)
    np.testing.assert_array_equal(first["tokens"],
                                  make_batch(pipe, 3)["tokens"])
    it.close()


def test_pipeline_process_sharding():
    sh0 = PipelineConfig(vocab=50, seq_len=8, global_batch=4,
                         process_index=0, process_count=2)
    b = make_batch(sh0, 0)
    assert b["tokens"].shape == (2, 8)
