"""The benchmark report schema gate: unified JobReport keys only.

`benchmarks/common.py::emit_job` serializes job rows from the unified
`repro.api.JobReport`; `benchmarks/compare.py` refuses TRACKED metrics
whose field is outside the declared schema.  Both must fail loudly on
unknown keys — the per-benchmark ad-hoc-key bug class this PR removed.
"""

import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks import common, compare  # noqa: E402
from repro.api import JobReport  # noqa: E402


@pytest.fixture(autouse=True)
def fresh_results():
    common.reset_results()
    yield
    common.reset_results()


def _report(**kw):
    base = dict(job="j", kind="stages", wall_seconds=0.5,
                modeled_io_seconds=0.25, tasks=3, resumed_tasks=1,
                iterations=2)
    base.update(kw)
    return JobReport(**base)


class TestEmitJob:
    def test_serializes_canonical_keys(self):
        common.emit_job("row", _report(), extra_key=7)
        row = common.RESULTS["row"]
        derived = row["derived"]
        for key in common.JOB_FIELD_KEYS.values():
            assert key in derived, key
        assert derived["total_s"] == 0.75
        assert derived["extra_key"] == 7
        assert row["us_per_call"] == pytest.approx(0.75e6)

    def test_extra_shadowing_canonical_key_raises(self):
        with pytest.raises(ValueError, match="shadows a canonical"):
            common.emit_job("row", _report(), total_s=1.0)

    def test_non_scalar_extra_raises(self):
        with pytest.raises(ValueError, match="must be scalar"):
            common.emit_job("row", _report(), bad=[1, 2])

    def test_non_report_raises(self):
        with pytest.raises(TypeError, match="JobHandle/JobReport"):
            common.emit_job("row", {"wall_s": 1.0})


class TestCompareSchema:
    def test_tracked_fields_all_declared(self):
        # the shipped TRACKED list must satisfy its own gate
        compare.validate_tracked()

    def test_unknown_tracked_field_fails_loudly(self, monkeypatch):
        bad = compare.Metric("fig9/summary", "per_iter_steady_msec", True)
        monkeypatch.setattr(compare, "TRACKED", compare.TRACKED + [bad])
        with pytest.raises(compare.SchemaError, match="per_iter_steady_msec"):
            compare.validate_tracked()
        with pytest.raises(compare.SchemaError):
            compare.compare({"results": {}}, {"results": {}})

    def test_job_fields_mirror_common(self):
        assert compare.JOB_FIELDS == frozenset(
            common.JOB_FIELD_KEYS.values()
        )

    def test_missing_tracked_metric_still_regresses(self):
        regressions, _ = compare.compare({"results": {}}, {"results": {}})
        assert len(regressions) == len(compare.TRACKED)


class TestCompareLoudFailures:
    def test_crashed_module_count_regresses(self):
        """A current file with failures > 0 must fail the gate even when
        no TRACKED metric lives in the crashed module."""
        regressions, lines = compare.compare(
            {"results": {}}, {"results": {}, "failures": 2}
        )
        assert any("2 failed benchmark module" in r for r in regressions)
        assert any("FAILED" in line for line in lines)

    def test_whole_module_drop_regresses_not_notes(self):
        """A module with rows in baseline but zero rows in current is a
        regression, not an informational note — an untracked module
        crashing must not pass silently."""
        baseline = {
            "results": {"figX/row_a": {"us_per_call": 1.0, "derived": {}}}
        }
        current = {
            "results": {"figY/row_b": {"us_per_call": 1.0, "derived": {}}}
        }
        regressions, _ = compare.compare(baseline, current)
        assert any(
            "figX" in r and "zero rows" in r for r in regressions
        )

    def test_row_level_churn_within_module_stays_a_note(self):
        baseline = {
            "results": {
                "figX/row_a": {"us_per_call": 1.0, "derived": {}},
                "figX/row_b": {"us_per_call": 1.0, "derived": {}},
            }
        }
        current = {
            "results": {"figX/row_a": {"us_per_call": 1.0, "derived": {}}}
        }
        regressions, lines = compare.compare(baseline, current)
        assert not any("figX" in r for r in regressions)
        assert any("rows no longer emitted" in line for line in lines)


class TestTrend:
    def _file(self, **rows):
        return {
            "sha": "abc",
            "results": {
                name: {"us_per_call": 0.0, "derived": derived}
                for name, derived in rows.items()
            },
        }

    def test_trend_lines_cover_tracked(self):
        prev = self._file(**{"fig11/summary": {"speedup_4v1": 2.0}})
        cur = self._file(**{"fig11/summary": {"speedup_4v1": 2.4}})
        trends = compare.trend_lines(prev, cur)
        assert len(trends) == len(compare.TRACKED)
        by_label = {t[0]: t for t in trends}
        label, p, c, delta = by_label["fig11/summary[speedup_4v1]"]
        assert (p, c) == (2.0, 2.4)
        assert delta == pytest.approx(0.2)

    def test_trend_missing_values_are_tolerated(self):
        trends = compare.trend_lines({"results": {}}, {"results": {}})
        assert all(delta is None for _, _, _, delta in trends)

    def test_missing_trend_file_does_not_fail_main(self, tmp_path):
        bench = self._file(**{"fig11/summary": {"speedup_4v1": 2.0}})
        # make every TRACKED metric present so the gate itself passes
        import json

        for m in compare.TRACKED:
            bench["results"].setdefault(
                m.name, {"us_per_call": 1.0, "derived": {}}
            )
            bench["results"][m.name]["derived"].setdefault(m.field, 1.0)
        p = tmp_path / "bench.json"
        p.write_text(json.dumps(bench))
        rc = compare.main(
            [str(p), str(p), "--trend", str(tmp_path / "missing.json")]
        )
        assert rc == 0

    def test_step_summary_written(self, tmp_path, monkeypatch):
        import json

        bench = self._file()
        for m in compare.TRACKED:
            bench["results"].setdefault(
                m.name, {"us_per_call": 1.0, "derived": {}}
            )
            bench["results"][m.name]["derived"].setdefault(m.field, 1.0)
        p = tmp_path / "bench.json"
        p.write_text(json.dumps(bench))
        summary = tmp_path / "summary.md"
        monkeypatch.setenv("GITHUB_STEP_SUMMARY", str(summary))
        rc = compare.main([str(p), str(p), "--trend", str(p)])
        assert rc == 0
        text = summary.read_text()
        assert "Bench trend" in text
        assert "fig11/summary[speedup_4v1]" in text
