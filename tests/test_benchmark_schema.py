"""The benchmark report schema gate: unified JobReport keys only.

`benchmarks/common.py::emit_job` serializes job rows from the unified
`repro.api.JobReport`; `benchmarks/compare.py` refuses TRACKED metrics
whose field is outside the declared schema.  Both must fail loudly on
unknown keys — the per-benchmark ad-hoc-key bug class this PR removed.
"""

import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks import common, compare  # noqa: E402
from repro.api import JobReport  # noqa: E402


@pytest.fixture(autouse=True)
def fresh_results():
    common.reset_results()
    yield
    common.reset_results()


def _report(**kw):
    base = dict(job="j", kind="stages", wall_seconds=0.5,
                modeled_io_seconds=0.25, tasks=3, resumed_tasks=1,
                iterations=2)
    base.update(kw)
    return JobReport(**base)


class TestEmitJob:
    def test_serializes_canonical_keys(self):
        common.emit_job("row", _report(), extra_key=7)
        row = common.RESULTS["row"]
        derived = row["derived"]
        for key in common.JOB_FIELD_KEYS.values():
            assert key in derived, key
        assert derived["total_s"] == 0.75
        assert derived["extra_key"] == 7
        assert row["us_per_call"] == pytest.approx(0.75e6)

    def test_extra_shadowing_canonical_key_raises(self):
        with pytest.raises(ValueError, match="shadows a canonical"):
            common.emit_job("row", _report(), total_s=1.0)

    def test_non_scalar_extra_raises(self):
        with pytest.raises(ValueError, match="must be scalar"):
            common.emit_job("row", _report(), bad=[1, 2])

    def test_non_report_raises(self):
        with pytest.raises(TypeError, match="JobHandle/JobReport"):
            common.emit_job("row", {"wall_s": 1.0})


class TestCompareSchema:
    def test_tracked_fields_all_declared(self):
        # the shipped TRACKED list must satisfy its own gate
        compare.validate_tracked()

    def test_unknown_tracked_field_fails_loudly(self, monkeypatch):
        bad = compare.Metric("fig9/summary", "per_iter_steady_msec", True)
        monkeypatch.setattr(compare, "TRACKED", compare.TRACKED + [bad])
        with pytest.raises(compare.SchemaError, match="per_iter_steady_msec"):
            compare.validate_tracked()
        with pytest.raises(compare.SchemaError):
            compare.compare({"results": {}}, {"results": {}})

    def test_job_fields_mirror_common(self):
        assert compare.JOB_FIELDS == frozenset(
            common.JOB_FIELD_KEYS.values()
        )

    def test_missing_tracked_metric_still_regresses(self):
        regressions, _ = compare.compare({"results": {}}, {"results": {}})
        assert len(regressions) == len(compare.TRACKED)
