"""MapReduce engine: correctness vs python oracles, tiers, fault paths."""

from collections import Counter

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import Scheduler, run_job
from repro.core.mapreduce import (
    aggregation_job,
    grep_job,
    join_job,
    scan_job,
    wordcount_job,
)
from repro.storage import (
    BlockStore,
    DataNode,
    DramTier,
    QuotaExceededError,
    S3_SPEC,
    SimulatedTier,
    StateCache,
)


def _cluster(n=4, block_size=1500):
    nodes = [DataNode(f"w{i}", DramTier()) for i in range(n)]
    bs = BlockStore(nodes, block_size=block_size, replication=2)
    sched = Scheduler([n.node_id for n in nodes], speculation_factor=None)
    return bs, sched


def _parse_output(bs, path, n_parts):
    out = {}
    for p in range(n_parts):
        fp = f"{path}/part_{p:04d}"
        if not bs.exists(fp):
            continue
        for line in bs.read(fp).splitlines():
            k, v = line.split(b"\t")
            out[eval(k)] = eval(v)
    return out


def _wordcount_data(rng, n_words=40, n_lines=300):
    words = [f"w{i}".encode() for i in range(n_words)]
    lines = [b" ".join(rng.choice(words, size=6)) for _ in range(n_lines)]
    return b"\n".join(lines), Counter(w for ln in lines for w in ln.split())


def test_wordcount_matches_oracle(rng):
    data, oracle = _wordcount_data(rng)
    bs, sched = _cluster()
    bs.write("/in", data, record_delim=b"\n")
    rep = run_job(wordcount_job(4), bs, "/in", "/out", DramTier(), sched)
    assert _parse_output(bs, "/out", 4) == dict(oracle)
    assert rep.input_bytes == len(data)
    assert rep.intermediate_bytes > 0
    assert rep.output_bytes > 0


def test_grep_matches_oracle(rng):
    data, oracle = _wordcount_data(rng)
    bs, sched = _cluster()
    bs.write("/in", data, record_delim=b"\n")
    run_job(grep_job(rb"w1"), bs, "/in", "/out", DramTier(), sched)
    got = _parse_output(bs, "/out", 4)
    want = {w: c for w, c in oracle.items() if b"w1" in w}
    assert got == want


def test_aggregation_matches_oracle(rng):
    rows = [(f"k{rng.integers(0, 10)}", float(rng.random())) for _ in range(500)]
    data = b"\n".join(f"{k},{v}".encode() for k, v in rows)
    oracle = {}
    for k, v in rows:
        oracle[k.encode()] = oracle.get(k.encode(), 0.0) + v
    bs, sched = _cluster()
    bs.write("/in", data, record_delim=b"\n")
    run_job(aggregation_job(3), bs, "/in", "/out", DramTier(), sched)
    got = _parse_output(bs, "/out", 3)
    assert set(got) == set(oracle)
    for k in oracle:
        assert got[k] == pytest.approx(oracle[k])


def test_join_matches_oracle(rng):
    left = [(f"k{i % 5}", f"l{i}") for i in range(20)]
    right = [(f"k{i % 7}", f"r{i}") for i in range(20)]
    recs = [f"L,{k},{v}" for k, v in left] + [f"R,{k},{v}" for k, v in right]
    data = "\n".join(recs).encode()
    oracle = set()
    for lk, lv in left:
        for rk, rv in right:
            if lk == rk:
                oracle.add((lk.encode(), lv.encode(), rv.encode()))
    bs, sched = _cluster()
    bs.write("/in", data, record_delim=b"\n")
    run_job(join_job(2), bs, "/in", "/out", DramTier(), sched)
    got = set()
    for p in range(2):
        for line in bs.read(f"/out/part_{p:04d}").splitlines():
            k, v = line.split(b"\t")
            lv, rv = eval(v)
            got.add((eval(k), lv, rv))
    assert got == oracle


def test_join_intermediate_blowup(rng):
    """Table 1's join row: intermediate exceeds input (cross-tag copies)."""
    recs = [f"{'L' if i % 2 else 'R'},k{i % 3},v{i}" for i in range(200)]
    data = "\n".join(recs).encode()
    bs, sched = _cluster()
    bs.write("/in", data, record_delim=b"\n")
    rep = run_job(join_job(2), bs, "/in", "/out", DramTier(), sched)
    assert rep.intermediate_bytes > rep.input_bytes * 0.5


def test_scan_small_output(rng):
    data, _ = _wordcount_data(rng)
    bs, sched = _cluster()
    bs.write("/in", data, record_delim=b"\n")
    rep = run_job(
        scan_job(lambda r: r.startswith(b"w1")), bs, "/in", "/out", DramTier(),
        sched,
    )
    assert rep.output_bytes < rep.input_bytes


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31), st.integers(1, 6), st.integers(200, 2000))
def test_wordcount_property(seed, n_reducers, block_size):
    """Engine result == oracle for any seed/reducers/block size."""
    rng = np.random.default_rng(seed)
    data, oracle = _wordcount_data(rng, n_words=15, n_lines=60)
    bs, sched = _cluster(block_size=block_size)
    bs.write("/in", data, record_delim=b"\n")
    run_job(wordcount_job(n_reducers), bs, "/in", "/out", DramTier(), sched)
    assert _parse_output(bs, "/out", n_reducers) == dict(oracle)


def test_retry_on_injected_failure(rng):
    data, oracle = _wordcount_data(rng)
    bs, sched = _cluster()
    bs.write("/in", data, record_delim=b"\n")
    rep = run_job(
        wordcount_job(2), bs, "/in", "/out", DramTier(), sched,
        fail_map_attempts={"map_00000": 2},
    )
    assert rep.retried_tasks >= 1
    assert _parse_output(bs, "/out", 2) == dict(oracle)


def test_journal_resume_skips_done_work(rng):
    data, oracle = _wordcount_data(rng)
    bs, sched = _cluster()
    bs.write("/in", data, record_delim=b"\n")
    journal = StateCache()
    inter = DramTier()
    r1 = run_job(wordcount_job(2), bs, "/in", "/out", inter, sched,
                 journal=journal)
    r2 = run_job(wordcount_job(2), bs, "/in", "/out", inter, sched,
                 journal=journal)
    assert r2.resumed_tasks == r1.map_tasks + r1.reduce_tasks
    assert _parse_output(bs, "/out", 2) == dict(oracle)


def test_s3_quota_kills_large_job(rng):
    """The paper's 15 GB Lambda/S3 failure, reproduced via the quota model.

    (Quota scaled down via a tiny spec so the test stays fast.)"""
    from repro.storage.tiers import DeviceSpec

    tiny_s3 = DeviceSpec(
        name="s3", read_bw=90e6, write_bw=90e6, read_latency=0.0,
        write_latency=0.0, transfer_quota=2_000,
    )
    data, _ = _wordcount_data(rng)
    bs, sched = _cluster()
    bs.write("/in", data, record_delim=b"\n")
    with pytest.raises(Exception) as exc_info:
        run_job(wordcount_job(2), bs, "/in", "/out",
                SimulatedTier(tiny_s3), sched)
    assert "QuotaExceeded" in repr(exc_info.value) or isinstance(
        exc_info.value, QuotaExceededError
    )


def test_partition_arbitrary_key_types():
    """Regression: tuples/None (composite join keys) used to raise
    TypeError, and floats were int()-truncated (3.1 and 3.9 collided on
    one partition); now they hash deterministically via the pickled key."""
    from repro.core.mapreduce import _partition

    for key in (3.7, -0.5, ("k1", 7), (b"a", 2.5), None, frozenset({1, 2})):
        p1 = _partition(key, 5)
        assert 0 <= p1 < 5
        assert p1 == _partition(key, 5)  # deterministic
    # established key types keep their historical placement
    assert _partition(b"abc", 4) == _partition("abc", 4)
    assert _partition(7, 4) == 3


def test_composite_key_job_runs(rng):
    """A join on composite (tuple) keys — exercises the _partition
    fallback end to end."""
    import repro.core.mapreduce as mr

    def mapper(record):
        a, b, v = record.split(b",")
        yield ((a, int(b)), float(v))

    def reducer(k, vs):
        yield (k, sum(vs))

    rows = [(f"g{i % 3}", i % 4, i * 0.5) for i in range(60)]
    data = b"\n".join(f"{a},{b},{v}".encode() for a, b, v in rows)
    oracle = {}
    for a, b, v in rows:
        oracle[(a.encode(), b)] = oracle.get((a.encode(), b), 0.0) + v
    bs, sched = _cluster()
    bs.write("/in", data, record_delim=b"\n")
    job = mr.MapReduceJob("composite", mapper, reducer, combiner=reducer,
                          n_reducers=3)
    run_job(job, bs, "/in", "/out", DramTier(), sched)
    got = _parse_output(bs, "/out", 3)
    assert set(got) == set(oracle)
    for k, v in oracle.items():
        assert got[k] == pytest.approx(v)


@pytest.mark.parametrize("mode", ["wave", "pipelined"])
def test_midwave_crash_resume_runs_only_uncommitted(rng, mode):
    """Kill a job after some map tasks commit; the re-run must execute
    only uncommitted tasks and produce output bytes identical to an
    uninterrupted run."""
    data, _ = _wordcount_data(rng)

    def serial_cluster():
        # one worker -> maps run serially in task order, so exactly the
        # maps before the injected failure commit.
        nodes = [DataNode(f"w{i}", DramTier()) for i in range(4)]
        bs = BlockStore(nodes, block_size=400, replication=2)
        return bs, Scheduler(["w0"], speculation_factor=None, max_attempts=2)

    # uninterrupted reference run
    bs_ref, sched_ref = serial_cluster()
    bs_ref.write("/in", data, record_delim=b"\n")
    run_job(wordcount_job(2), bs_ref, "/in", "/out", DramTier(),
            sched_ref, mode=mode)
    ref_parts = [bs_ref.read(f"/out/part_{p:04d}") for p in range(2)]

    # crashed run: map_00002 fails permanently mid-wave
    bs, sched = serial_cluster()
    bs.write("/in", data, record_delim=b"\n")
    journal, inter = StateCache(), DramTier()
    from repro.core import StateJournal, TaskFailedError

    with pytest.raises(TaskFailedError):
        run_job(wordcount_job(2), bs, "/in", "/out", inter, sched,
                journal=journal, fail_map_attempts={"map_00002": 99},
                mode=mode)
    committed = set(StateJournal(journal, "mr/wordcount").entries())
    committed_tasks = {c for c in committed if "." not in c}
    assert {"map_00000", "map_00001"} <= committed_tasks
    assert "map_00002" not in committed_tasks

    # resume with the same journal: only uncommitted work re-executes
    _, sched2 = serial_cluster()
    r2 = run_job(wordcount_job(2), bs, "/in", "/out", inter, sched2,
                 journal=journal, mode=mode)
    assert r2.resumed_tasks == len(committed_tasks)
    got_parts = [bs.read(f"/out/part_{p:04d}") for p in range(2)]
    assert got_parts == ref_parts  # byte-identical to uninterrupted run


def test_pipelined_matches_wave_bit_for_bit(rng):
    """The streaming shuffle must not change observable results: output
    bytes and intermediate bytes identical; overlap metrics present."""
    data, oracle = _wordcount_data(rng, n_lines=600)
    reports, parts = {}, {}
    for mode in ("wave", "pipelined"):
        bs, sched = _cluster()
        bs.write("/in", data, record_delim=b"\n")
        rep = run_job(wordcount_job(4), bs, "/in", "/out", DramTier(), sched,
                      mode=mode)
        reports[mode] = rep
        parts[mode] = [bs.read(f"/out/part_{p:04d}") for p in range(4)]
        assert _parse_output(bs, "/out", 4) == dict(oracle)
    assert parts["wave"] == parts["pipelined"]
    assert (reports["wave"].intermediate_bytes
            == reports["pipelined"].intermediate_bytes)
    assert reports["wave"].output_bytes == reports["pipelined"].output_bytes
    assert reports["wave"].overlap_seconds == 0.0
    assert reports["wave"].partitions_streamed == 0
    assert reports["pipelined"].overlap_seconds > 0.0
    assert reports["pipelined"].partitions_streamed > 0


def test_pipelined_retry_on_injected_failure(rng):
    data, oracle = _wordcount_data(rng)
    bs, sched = _cluster()
    bs.write("/in", data, record_delim=b"\n")
    rep = run_job(
        wordcount_job(2), bs, "/in", "/out", DramTier(), sched,
        fail_map_attempts={"map_00000": 2}, mode="pipelined",
    )
    assert rep.retried_tasks >= 1
    assert _parse_output(bs, "/out", 2) == dict(oracle)


def test_fast_tier_beats_slow_tier_modeled_time(rng):
    """Fig. 4 ordering: DRAM/IGFS < PMEM < SSD < S3 on modeled time."""
    from repro.storage.tiers import PMEM_SPEC, SSD_SPEC

    data, _ = _wordcount_data(rng, n_lines=600)
    times = {}
    for name, tier in [
        ("dram", DramTier()),
        ("pmem", SimulatedTier(PMEM_SPEC)),
        ("ssd", SimulatedTier(SSD_SPEC)),
        ("s3", SimulatedTier(S3_SPEC)),
    ]:
        bs, sched = _cluster()
        bs.write("/in", data, record_delim=b"\n")
        rep = run_job(wordcount_job(2), bs, "/in", "/out", tier, sched)
        times[name] = rep.modeled_io_seconds
    assert times["dram"] <= times["pmem"] < times["ssd"] < times["s3"]
