"""Stateful function runtime + task scheduler."""

import time

import jax.numpy as jnp
import pytest

from repro.core import FunctionRuntime, Scheduler, Task, TaskFailedError
from repro.storage import DramTier, PmemTier, StateCache


def _counter_runtime(tmp_path=None):
    cache = StateCache(
        write_through=PmemTier(str(tmp_path)) if tmp_path else DramTier()
    )
    rt = FunctionRuntime(cache=cache)

    @rt.function("counter", init=lambda start=0: jnp.int32(start))
    def step(state, x):
        new = state + x
        return new, new

    return rt


def test_stateful_invocations_accumulate():
    rt = _counter_runtime()
    assert int(rt.invoke("counter", x=jnp.int32(5))) == 5
    assert int(rt.invoke("counter", x=jnp.int32(2))) == 7
    assert rt.log[0].cold and not rt.log[1].cold


def test_sessions_isolate_state():
    rt = _counter_runtime()
    rt.invoke("counter", session="a", x=jnp.int32(10))
    rt.invoke("counter", session="b", x=jnp.int32(1))
    assert int(rt.invoke("counter", session="a", x=jnp.int32(0))) == 10
    assert int(rt.invoke("counter", session="b", x=jnp.int32(0))) == 1


def test_init_kwargs_cold_start():
    rt = _counter_runtime()
    out = rt.invoke("counter", init_kwargs={"start": 100}, x=jnp.int32(1))
    assert int(out) == 101


def test_crash_recovery_with_write_through(tmp_path):
    rt = _counter_runtime(tmp_path)
    rt.invoke("counter", x=jnp.int32(41))
    rt.commit_all()
    rt.crash()
    rt.recover()
    assert int(rt.invoke("counter", x=jnp.int32(1))) == 42


def test_crash_without_persistence_loses_state():
    rt = FunctionRuntime(cache=StateCache())  # stock stateless-serverless

    @rt.function("c", init=lambda: jnp.int32(0))
    def step(state, x):
        return state + x, state + x

    rt.invoke("c", x=jnp.int32(5))
    rt.crash()
    # state re-initialized from scratch — computation lost (paper §1)
    assert int(rt.invoke("c", x=jnp.int32(1))) == 1


def test_commit_every_controls_durability(tmp_path):
    cache = StateCache(write_through=PmemTier(str(tmp_path)))
    rt = FunctionRuntime(cache=cache, commit_every=3)

    @rt.function("c", init=lambda: jnp.int32(0))
    def step(state, x):
        return state + x, state + x

    for _ in range(2):
        rt.invoke("c", x=jnp.int32(1))
    rt.crash()
    # only 2 invocations — below commit_every, nothing durable yet
    assert int(rt.invoke("c", x=jnp.int32(1))) == 1


def test_invocation_seq_is_per_session():
    """Regression: seq used to record the *global* log position; recovery
    ordering must be per-session."""
    rt = _counter_runtime()
    rt.invoke("counter", session="a", x=jnp.int32(1))
    rt.invoke("counter", session="b", x=jnp.int32(1))
    rt.invoke("counter", session="a", x=jnp.int32(1))
    rt.invoke("counter", session="b", x=jnp.int32(1))
    seqs = {(r.session, r.seq) for r in rt.log}
    assert seqs == {("a", 0), ("a", 1), ("b", 0), ("b", 1)}


def test_session_object_wires_invocations():
    rt = _counter_runtime()
    sess = rt.session("chat")
    assert int(sess.invoke("counter", x=jnp.int32(3))) == 3
    assert int(sess.invoke("counter", x=jnp.int32(4))) == 7
    assert sess.seq == 2
    assert rt.session("chat") is sess


def test_session_seq_resumes_from_journal_after_crash(tmp_path):
    """Per-session sequence survives a crash via the unified journal."""
    rt = _counter_runtime(tmp_path)
    for _ in range(3):
        rt.invoke("counter", session="a", x=jnp.int32(1))
    rt.invoke("counter", session="b", x=jnp.int32(5))
    rt.crash()
    rt.recover()
    # sessions rebuild from committed journal entries, not from zero
    assert rt.session("a").seq == 3
    assert rt.session("b").seq == 1
    rt.invoke("counter", session="a", x=jnp.int32(1))
    assert rt.log[-1].seq == 3 and rt.log[-1].session == "a"


# -- scheduler ---------------------------------------------------------------

def test_scheduler_runs_all_tasks():
    sched = Scheduler(["w0", "w1"], speculation_factor=None)
    tasks = [Task(f"t{i}", lambda w, i=i: i * 2) for i in range(10)]
    res = sched.run_wave(tasks)
    assert sorted(r.value for r in res.values()) == [i * 2 for i in range(10)]


def test_scheduler_retries_transient_failures():
    attempts = {"n": 0}

    def flaky(worker):
        attempts["n"] += 1
        if attempts["n"] < 3:
            raise RuntimeError("transient")
        return "ok"

    sched = Scheduler(["w0"], max_attempts=3, speculation_factor=None)
    res = sched.run_wave([Task("t", flaky)])
    assert res["t"].value == "ok"
    assert res["t"].attempts == 3


def test_scheduler_permanent_failure_raises():
    def broken(worker):
        raise RuntimeError("always")

    sched = Scheduler(["w0"], max_attempts=2, speculation_factor=None)
    with pytest.raises(TaskFailedError):
        sched.run_wave([Task("t", broken)])


def test_scheduler_speculation_beats_straggler():
    calls = {"n": 0}

    def task(worker):
        calls["n"] += 1
        if calls["n"] == 1:  # first attempt is a straggler
            time.sleep(2.0)
            return "slow"
        return "fast"

    sched = Scheduler(
        ["w0", "w1"], speculation_factor=1.5, min_speculation_seconds=0.02
    )
    fast = [Task(f"f{i}", lambda w: "ok") for i in range(4)]
    t0 = time.perf_counter()
    res = sched.run_wave(fast + [Task("straggler", task)])
    dt = time.perf_counter() - t0
    assert res["straggler"].value in ("fast", "slow")
    # the backup attempt should win well before the 2 s straggler finishes
    assert dt < 1.8
    assert res["straggler"].speculative_win or res["straggler"].value == "fast"


def test_scheduler_elastic_pool():
    sched = Scheduler(["w0"], speculation_factor=None)
    sched.add_workers(["w1", "w2"])
    assert len(sched.workers) == 3
    sched.remove_workers(["w0"])
    res = sched.run_wave([Task("t", lambda w: w)])
    assert res["t"].worker in ("w1", "w2")


def test_scheduler_locality_preference():
    sched = Scheduler(["w0", "w1", "w2"], speculation_factor=None)
    res = sched.run_wave([Task("t", lambda w: w, preferred=["w2"])])
    assert res["t"].worker == "w2"
