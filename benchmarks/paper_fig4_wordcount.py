"""Paper Fig. 4 (and Fig. 1): WordCount completion time vs input size,
per intermediate-storage tier.

Four cluster configurations mirror the paper's, each declared as a
one-line :class:`~repro.api.ClusterConfig` and run through the
:class:`~repro.api.MarvelClient` façade:
  igfs  — Marvel w/ Ignite (DRAM intermediate)          [best]
  pmem  — Marvel w/ PMEM-HDFS intermediate (modeled bw)
  ssd   — local SSD intermediate (modeled)
  s3    — Corral/Lambda-style S3 intermediate (modeled; quota-limited)

Reported time = wall compute + modeled device seconds.  The S3 row at the
largest scale trips the (scaled) transfer quota — the paper's 15 GB
failure — and is reported as FAILED.  The derived field carries the
headline reduction vs S3.
"""

from __future__ import annotations

from repro.api import ClusterConfig, TierSpec
from repro.core.mapreduce import wordcount_job
from repro.storage import QuotaExceededError
from repro.storage.tiers import DeviceSpec, S3_SPEC

from benchmarks.common import emit, emit_job, make_client, make_corpus

#: S3 with the transfer quota scaled 1000x down so the failure point is
#: reachable at benchmark-size inputs (15 GB -> 15 MB).
S3_SCALED = DeviceSpec(
    name="s3",
    read_bw=S3_SPEC.read_bw,
    write_bw=S3_SPEC.write_bw,
    read_latency=S3_SPEC.read_latency,
    write_latency=S3_SPEC.write_latency,
    transfer_quota=15 * 10**6,
)

JOB = wordcount_job

#: the paper's four static tier assignments, declaratively.
TIER_CONFIGS = [
    ("igfs", TierSpec("dram")),
    ("pmem", TierSpec("pmem")),
    ("ssd", TierSpec("ssd")),
    ("s3", TierSpec(device=S3_SCALED)),
]


def run_tiers(
    job_factory=JOB,
    scales=(1 << 18, 1 << 20, 1 << 22),
    tag="fig4/wordcount",
    device_scale=1 << 15,
) -> None:
    for scale in scales:
        data = make_corpus(scale)
        reports = {}
        for name, spec in TIER_CONFIGS:
            cfg = ClusterConfig(
                name="fig4",
                tiers=(spec,),
                block_size=max(scale // 8, 65536),
            )
            with make_client(cfg) as client:
                client.store.write("/in", data, record_delim=b"\n")
                try:
                    reports[name] = client.mapreduce(
                        job_factory(4), "/in", "/out"
                    ).report
                except QuotaExceededError:
                    reports[name] = None  # the paper's 15 GB S3 collapse
        s3_total = reports["s3"].total_seconds if reports.get("s3") else None
        for name, rep in reports.items():
            if rep is None:
                emit(f"{tag}/{name}/in={scale}", -1.0, "FAILED:quota")
                continue
            extras = {}
            if s3_total:
                extras["reduction_vs_s3"] = round(1 - rep.total_seconds / s3_total, 3)
            emit_job(f"{tag}/{name}/in={scale}", rep, **extras)

    # ---- device execution mode vs host (byte-identity asserted) ------------
    # The Pallas lowering runs on the best tier (igfs analog); interpret
    # mode keeps it runnable on CPU, at a small fixed scale.
    data = make_corpus(device_scale)

    def run(device: bool):
        cfg = ClusterConfig(
            name="fig4dev",
            tiers=(TIER_CONFIGS[0][1],),
            block_size=max(device_scale // 4, 1 << 14),
            device_interpret=True,
        )
        with make_client(cfg) as client:
            client.store.write("/in", data, record_delim=b"\n")
            handle = client.mapreduce(job_factory(4), "/in", "/out", device=device)
            outs = []
            for p in range(4):
                path = f"/out/part_{p:04d}"
                outs.append(
                    client.store.read(path) if client.store.exists(path) else None
                )
            return handle.report, outs

    host_rep, host_out = run(False)
    dev_rep, dev_out = run(True)
    emit_job(f"{tag}/host/in={device_scale}", host_rep)
    emit_job(
        f"{tag}/device/in={device_scale}",
        dev_rep,
        outputs_identical=int(dev_out == host_out),
        device_pairs=dev_rep.field("device_pairs"),
    )


def main() -> None:
    run_tiers()


if __name__ == "__main__":
    main()
