"""Paper Fig. 4 (and Fig. 1): WordCount completion time vs input size,
per intermediate-storage tier.

Four configurations mirror the paper's:
  igfs  — Marvel w/ Ignite (DRAM intermediate)          [best]
  pmem  — Marvel w/ PMEM-HDFS intermediate (modeled bw)
  ssd   — local SSD intermediate (modeled)
  s3    — Corral/Lambda-style S3 intermediate (modeled; quota-limited)

Reported time = wall compute + modeled device seconds.  The S3 row at the
largest scale trips the (scaled) transfer quota — the paper's 15 GB
failure — and is reported as FAILED.  The derived field carries the
headline reduction vs S3.
"""

from __future__ import annotations

from repro.core import run_job
from repro.core.mapreduce import wordcount_job
from repro.storage import DramTier, QuotaExceededError, SimulatedTier
from repro.storage.tiers import DeviceSpec, PMEM_SPEC, S3_SPEC, SSD_SPEC

from benchmarks.common import cluster, emit, make_corpus

#: S3 with the transfer quota scaled 1000x down so the failure point is
#: reachable at benchmark-size inputs (15 GB -> 15 MB).
S3_SCALED = DeviceSpec(
    name="s3", read_bw=S3_SPEC.read_bw, write_bw=S3_SPEC.write_bw,
    read_latency=S3_SPEC.read_latency, write_latency=S3_SPEC.write_latency,
    transfer_quota=15 * 10**6,
)

JOB = wordcount_job


def run_tiers(job_factory=JOB, scales=(1 << 18, 1 << 20, 1 << 22),
              tag="fig4/wordcount") -> None:
    for scale in scales:
        data = make_corpus(scale)
        times = {}
        for name, tier in [
            ("igfs", DramTier()),
            ("pmem", SimulatedTier(PMEM_SPEC)),
            ("ssd", SimulatedTier(SSD_SPEC)),
            ("s3", SimulatedTier(S3_SCALED)),
        ]:
            bs, sched = cluster(block_size=max(scale // 8, 65536))
            bs.write("/in", data, record_delim=b"\n")
            try:
                rep = run_job(job_factory(4), bs, "/in", "/out", tier, sched)
                times[name] = rep.total_seconds
            except QuotaExceededError:
                times[name] = None  # the paper's 15 GB Lambda/S3 collapse
        for name, t in times.items():
            if t is None:
                emit(f"{tag}/{name}/in={scale}", -1.0, "FAILED:quota")
            else:
                derived = ""
                if times.get("s3") and t is not None:
                    derived = f"reduction_vs_s3={1 - t / times['s3']:.3f}"
                emit(f"{tag}/{name}/in={scale}", t * 1e6, derived)


def main() -> None:
    run_tiers()


if __name__ == "__main__":
    main()
