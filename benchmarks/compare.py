"""Benchmark regression gate: compare a BENCH_<sha>.json against baseline.

CI runs ``benchmarks/run.py --smoke --out BENCH_<sha>.json`` and then

    python benchmarks/compare.py BENCH_baseline.json BENCH_<sha>.json

which fails (exit 1) if any *tracked* metric regresses more than the
threshold (default 20%) versus the committed ``BENCH_baseline.json``,
**or** is missing from the current file entirely (the schema check: a
silently-dropped metric is indistinguishable from an infinite regression,
and a bench module that stops emitting a row must fail loudly even
before a baseline for it exists).

Only metrics listed in ``TRACKED`` gate the build: raw wall-clock numbers
on shared CI runners are too noisy to gate at 20%, so the tracked set is
deliberately dominated by *modeled/derived* quantities (device-time
ratios, hit rates, speedups) that are deterministic given the code.
Untracked metrics are still reported as an informational diff.

Refreshing the baseline (required when a tracked metric legitimately
moves — an optimization, a model recalibration): run the smoke suite
locally and commit the new file, noting why in the commit message::

    PYTHONPATH=src:. python benchmarks/run.py --smoke --out BENCH_baseline.json
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from dataclasses import dataclass
from typing import Optional


class SchemaError(RuntimeError):
    """A TRACKED metric names a field outside the declared schema."""


#: serialized keys of the unified ``repro.api.JobReport`` schema — must
#: mirror ``benchmarks/common.py::JOB_FIELD_KEYS`` (job rows emitted via
#: ``emit_job`` carry exactly these canonical keys plus declared extras).
JOB_FIELDS = frozenset(
    {
        "wall_s",
        "modeled_io_s",
        "total_s",
        "tasks",
        "resumed",
        "iterations",
    }
)

#: benchmark-specific derived keys a TRACKED metric may reference, beyond
#: the unified job schema.  Adding a TRACKED metric with a key not listed
#: here (or in JOB_FIELDS) fails the gate immediately — per-benchmark
#: ad-hoc keys drifting out of sync with the emitters was a real bug
#: class (a typo'd field silently read as "missing baseline" forever).
EXTRA_FIELDS = frozenset(
    {
        # fig6 pipeline rows
        "overlap_s",
        "streamed",
        "out",
        # fig4/fig6 device-vs-host rows (device execution mode)
        "outputs_identical",
        "device_pairs",
        "spilled_pairs",
        # fig7 summary + throughput rows
        "warm_over_cold_p50",
        "speedup_8v1_invokers",
        "group_commit_gain",
        "inv_per_s",
        # fig7b contention rows + summary
        "lazy_frac",
        "p99_lane_wait_ms",
        "commit_entries",
        # fig8 rows + summary
        "dram_hit_rate",
        "adaptive_over_s3_speedup",
        "hot_set_vs_dram_factor",
        "get_p50_us",
        "get_p99_us",
        "hot_get_us",
        "promotions",
        "demotions",
        # fig9 rows + summary
        "per_iter_steady_ms",
        "warm_read_frac",
        "last_iteration",
        "sorted_ok",
        "pagerank_stateful_over_cold",
        "pagerank_outputs_identical",
        "kmeans_outputs_identical",
        "kmeans_warm_read_frac",
        "terasort_sorted_ok",
        "cold_modeled_io_s",
        # fig10 serving rows + summary
        "sessions_sustained",
        "max_resident",
        "budget_bytes",
        "session_bytes",
        "tok_per_s",
        "demand_faults",
        "resumes",
        "conversations",
        "capacity_ratio",
        "prefetch_speedup",
        "p99_ttft_ms",
        # fig11 cluster rows + summary
        "jobs_per_s",
        "p99_ms",
        "nodes",
        "net_mb",
        "rehomed_sessions",
        "reblocks",
        "speedup_4v1",
        "jobs_per_s_1",
        "jobs_per_s_4",
        # fig12 SLO-harness rows + summary
        "p99_under_slo_frac",
        "goodput_frac",
        "isolation_ratio",
        "scale_actions",
        "peak_invokers",
        "peak_nodes",
        "offered",
        "completed",
        "shed",
        "backpressured",
        "slo_ms",
        "sessions_migrated",
        "joined_node",
        "single_fixed_slo",
        "single_auto_slo",
        "cluster_fixed_slo",
        "cluster_auto_slo",
        "auto_goodput",
        "fixed_goodput",
        "node_actions",
        "errors",
    }
)

KNOWN_FIELDS = frozenset({"us_per_call"}) | JOB_FIELDS | EXTRA_FIELDS


@dataclass(frozen=True)
class Metric:
    """One gated metric: where to find it and which direction is good."""

    name: str  # emit() row name
    field: str  # "us_per_call" or a derived key
    higher_is_better: bool
    #: per-metric override of the global threshold (fraction, e.g. 0.2).
    threshold: Optional[float] = None


TRACKED = [
    # fig8 — the adaptive-hierarchy acceptance metrics.  The hit rate and
    # the static-S3 modeled total are deterministic; the speedup's
    # denominator is wall-clock (runner-noisy), so only an
    # order-of-magnitude collapse gates it.
    Metric("fig8/summary", "adaptive_over_s3_speedup", True, threshold=0.9),
    Metric("fig8/adaptive", "dram_hit_rate", True),
    Metric("fig8/static-s3", "total_s", False, threshold=0.25),
    # fig7 — serving-side scaling.  warm_over_cold_p50 is deliberately
    # NOT tracked: its baseline is a microsecond-scale machine-specific
    # ratio (~0.002) and the smoke run already asserts the meaningful
    # bar (< 0.2) — gating drift on it would fail CI on runner noise.
    Metric("fig7/summary", "speedup_8v1_invokers", True, threshold=0.5),
    # fig7b — warm-path contention.  lazy_frac is deterministic (exact
    # read fraction of the op mix); inv/s is wall-clock on a shared
    # runner, so only an order-of-magnitude collapse gates it.
    Metric("fig7b/summary", "lazy_frac", True, threshold=0.05),
    Metric("fig7b/contention", "inv_per_s", True, threshold=0.9),
    # fig6 — pipelining must keep streaming partitions into the map tail.
    Metric("fig6/pipeline/ssd/pipelined", "streamed", True, threshold=0.5),
    # fig6 — device execution mode: the Pallas lowering must not change a
    # single output byte (exact flags), with and without the tier-spill
    # path engaged; the pair/spill counters are deterministic given the
    # fixed corpus and capacity factor, so any drift is a code change.
    Metric("fig6/device/wordcount/device", "outputs_identical", True, threshold=0.0),
    Metric("fig6/device/wordcount/device", "device_pairs", True, threshold=0.01),
    Metric(
        "fig6/device/wordcount/device_spill", "outputs_identical", True, threshold=0.0
    ),
    Metric("fig6/device/wordcount/device_spill", "spilled_pairs", True, threshold=0.01),
    # table2 — calibrated device constants: any drift is a code change.
    Metric("table2/pmem_model/seq_read", "us_per_call", False, threshold=0.01),
    Metric("table2/s3_model/seq_write", "us_per_call", False, threshold=0.01),
    # fig9 — iterative dataflow acceptance metrics.  The output-identity
    # flags are exact (any drop below 1.0 fails); the speedup's numerator
    # is modeled-S3-dominated and its denominator wall-clock, so only a
    # collapse below the 3x smoke bar's comfortable margin gates it; the
    # cold config's modeled inline I/O is deterministic given the code.
    Metric("fig9/summary", "pagerank_stateful_over_cold", True, threshold=0.9),
    Metric("fig9/summary", "pagerank_outputs_identical", True, threshold=0.0),
    Metric("fig9/summary", "kmeans_outputs_identical", True, threshold=0.0),
    Metric("fig9/summary", "kmeans_warm_read_frac", True, threshold=0.2),
    Metric("fig9/summary", "cold_modeled_io_s", False, threshold=0.25),
    # fig10 — the KV-paging serving acceptance metrics.  The capacity
    # ratio and the identity flag are deterministic (session admission is
    # byte-accounting, not timing); the prefetch-vs-demand TTFT speedup
    # is a wall-clock ratio of two sleep-dominated cells on the same
    # runner, so only a collapse below 1x (prefetch no longer winning)
    # gates it.
    Metric("fig10/summary", "outputs_identical", True, threshold=0.0),
    Metric("fig10/summary", "capacity_ratio", True, threshold=0.05),
    Metric("fig10/capacity/paged", "sessions_sustained", True, threshold=0.05),
    Metric("fig10/capacity/paged", "shed", False, threshold=0.0),
    Metric("fig10/summary", "prefetch_speedup", True, threshold=0.75),
    # fig11 — the multi-node cluster acceptance metrics.  The smoke run
    # already asserts the hard bars (speedup >= 2x, byte-identical
    # output after a mid-job node kill); the gate here catches silent
    # decay: the speedup is a wall-clock ratio of two sleep-dominated
    # rows on the same runner (stable, but only a collapse gates it) and
    # the identity flag is exact.
    Metric("fig11/summary", "speedup_4v1", True, threshold=0.5),
    Metric("fig11/kill_node", "outputs_identical", True, threshold=0.0),
    # fig12 — the SLO-harness acceptance metrics.  The autoscaled cells'
    # p99-under-SLO fraction and goodput sit at 1.0 with a wide capacity
    # margin (smoke already asserts the 0.95 bar), so a 5% band only
    # trips on real control-loop decay.  scale_actions / peak_invokers
    # bound controller churn from both sides: the loop must act (a drop
    # to zero actions means the policy went inert) but must not thrash
    # past its clamp.  The membership identity flag is exact.
    Metric("fig12/single/auto", "p99_under_slo_frac", True, threshold=0.05),
    Metric("fig12/single/auto", "goodput_frac", True, threshold=0.05),
    Metric("fig12/single/auto", "scale_actions", True, threshold=0.75),
    Metric("fig12/single/auto", "peak_invokers", False, threshold=0.5),
    Metric("fig12/single/auto", "isolation_ratio", False, threshold=3.0),
    Metric("fig12/cluster/auto", "p99_under_slo_frac", True, threshold=0.05),
    Metric("fig12/add_node", "outputs_identical", True, threshold=0.0),
]


def validate_tracked() -> None:
    """Schema gate: every TRACKED metric must read a declared field.

    Raises :class:`SchemaError` on an unknown key — loudly, before any
    comparison runs — instead of letting a typo'd or renamed field read
    as None forever."""
    bad = [f"{m.name}[{m.field}]" for m in TRACKED if m.field not in KNOWN_FIELDS]
    if bad:
        raise SchemaError(
            "TRACKED metrics reference fields outside the declared schema "
            f"(JOB_FIELDS/EXTRA_FIELDS): {', '.join(bad)}"
        )


def _lookup(results: dict, metric: Metric) -> Optional[float]:
    row = results.get(metric.name)
    if row is None:
        return None
    if metric.field == "us_per_call":
        value = row.get("us_per_call")
    else:
        value = row.get("derived", {}).get(metric.field)
    return float(value) if isinstance(value, (int, float)) else None


def compare(baseline: dict, current: dict, threshold: float = 0.20):
    """Returns (regressions, report_lines)."""
    validate_tracked()
    base_r = baseline.get("results", {})
    cur_r = current.get("results", {})
    regressions = []
    lines = []
    # A bench module that crashed emits zero rows; run.py records the
    # failure count in the JSON.  Comparing such a file must fail loudly
    # even when no TRACKED metric happens to live in the crashed module —
    # an untracked module silently dropping every row is a regression,
    # not a note.
    failures = int(current.get("failures", 0) or 0)
    if failures:
        regressions.append(
            f"current run recorded {failures} failed benchmark module(s) "
            "(see the bench log; its rows are missing below)"
        )
        lines.append(f"  FAILED   {failures} module(s) crashed in current run")
    base_modules = {name.split("/", 1)[0] for name in base_r}
    cur_modules = {name.split("/", 1)[0] for name in cur_r}
    for module in sorted(base_modules - cur_modules):
        regressions.append(
            f"module {module!r}: rows present in baseline, zero rows in "
            "current (whole-module drop)"
        )
        lines.append(f"  MISSING  module {module}: zero rows in current")
    for metric in TRACKED:
        limit = metric.threshold if metric.threshold is not None else threshold
        base = _lookup(base_r, metric)
        cur = _lookup(cur_r, metric)
        label = f"{metric.name}[{metric.field}]"
        if cur is None:
            # Schema check: every TRACKED metric must be present in the
            # current file, baseline or not — a dropped emit() row must
            # not pass silently while its baseline ages out.
            if base is not None:
                detail = f"present in baseline ({base:g}), missing now"
            else:
                detail = "missing from current results (schema violation)"
            regressions.append(f"{label}: {detail}")
            lines.append(f"  MISSING  {label}")
            continue
        if base is None:
            lines.append(f"  new      {label}: {cur} (no baseline; not gated)")
            continue
        if base == 0:
            delta = 0.0 if cur == 0 else float("inf")
        else:
            delta = (cur - base) / abs(base)
        worse = -delta if metric.higher_is_better else delta
        status = "ok"
        if worse > limit:
            status = "REGRESSED"
            regressions.append(
                f"{label}: {base:g} -> {cur:g} "
                f"({worse:+.1%} worse, limit {limit:.0%})"
            )
        lines.append(f"  {status:9s}{label}: {base:g} -> {cur:g} ({delta:+.1%})")
    # informational: untracked rows that disappeared entirely (whole
    # modules are caught loudly above; this covers row-level churn)
    gone = sorted(set(base_r) - set(cur_r))
    if gone:
        lines.append(f"  note: rows no longer emitted: {', '.join(gone)}")
    return regressions, lines


def trend_lines(previous: dict, current: dict) -> list:
    """Two-point trend of every TRACKED metric: previous main run ->
    current run.  Purely informational (the gate is vs the committed
    baseline); surfaces drift *within* the allowed envelope."""
    prev_r = previous.get("results", {})
    cur_r = current.get("results", {})
    out = []
    for metric in TRACKED:
        prev = _lookup(prev_r, metric)
        cur = _lookup(cur_r, metric)
        label = f"{metric.name}[{metric.field}]"
        if prev is None or cur is None:
            out.append((label, prev, cur, None))
            continue
        if prev == 0:
            delta = 0.0 if cur == 0 else float("inf")
        else:
            delta = (cur - prev) / abs(prev)
        out.append((label, prev, cur, delta))
    return out


def _write_step_summary(path: str, prev_sha: str, cur_sha: str, trends: list) -> None:
    with open(path, "a") as f:
        f.write(f"### Bench trend: `{prev_sha}` → `{cur_sha}`\n\n")
        f.write("| metric | previous | current | delta |\n")
        f.write("|---|---|---|---|\n")
        for label, prev, cur, delta in trends:
            p = f"{prev:g}" if prev is not None else "—"
            c = f"{cur:g}" if cur is not None else "—"
            d = f"{delta:+.1%}" if delta is not None else "—"
            f.write(f"| `{label}` | {p} | {c} | {d} |\n")
        f.write("\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", help="committed BENCH_baseline.json")
    ap.add_argument("current", help="freshly produced BENCH_<sha>.json")
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.20,
        help="default allowed regression fraction (0.20 = 20%%)",
    )
    ap.add_argument(
        "--trend",
        default="",
        metavar="PREV_JSON",
        help="previous main run's BENCH_*.json: print a two-point trend "
        "next to the baseline gate (and append it to "
        "$GITHUB_STEP_SUMMARY in CI); a missing file is not an error",
    )
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.current) as f:
        current = json.load(f)
    regressions, lines = compare(baseline, current, args.threshold)
    base_sha = str(baseline.get("sha", "?"))[:12]
    cur_sha = str(current.get("sha", "?"))[:12]
    print(f"benchmark compare: baseline {base_sha} vs current {cur_sha}")
    for line in lines:
        print(line)
    if args.trend:
        try:
            with open(args.trend) as f:
                previous = json.load(f)
        except (OSError, ValueError) as exc:
            print(f"trend: previous run unavailable ({exc}); skipping")
            previous = None
        if previous is not None:
            prev_sha = str(previous.get("sha", "?"))[:12]
            trends = trend_lines(previous, current)
            print(f"trend: previous main run {prev_sha} -> {cur_sha}")
            for label, prev, cur, delta in trends:
                p = f"{prev:g}" if prev is not None else "?"
                c = f"{cur:g}" if cur is not None else "?"
                d = f" ({delta:+.1%})" if delta is not None else ""
                print(f"  trend    {label}: {p} -> {c}{d}")
            summary = os.environ.get("GITHUB_STEP_SUMMARY", "")
            if summary:
                _write_step_summary(summary, prev_sha, cur_sha, trends)
    if regressions:
        print(
            f"\n{len(regressions)} tracked metric(s) regressed beyond limit:",
            file=sys.stderr,
        )
        for r in regressions:
            print(f"  - {r}", file=sys.stderr)
        print(
            "\nIf the change is intentional, refresh the baseline "
            "(see benchmarks/compare.py docstring).",
            file=sys.stderr,
        )
        return 1
    print("all tracked metrics within limits")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
