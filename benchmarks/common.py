"""Shared benchmark utilities."""

from __future__ import annotations

import time
from typing import Callable, Dict, List

import numpy as np

from repro.core import Scheduler
from repro.storage import BlockStore, DataNode, DramTier

#: Machine-readable mirror of every ``emit()`` row from the current run:
#: ``{name: {"us_per_call": float, "derived": {k: float|str}}}``.  The CI
#: harness (benchmarks/run.py --out) serializes this to ``BENCH_<sha>.json``
#: and ``benchmarks/compare.py`` gates regressions against the committed
#: baseline.
RESULTS: Dict[str, dict] = {}


def _parse_derived(derived: str) -> Dict[str, object]:
    """``"p50_us=12.3;n=100"`` → ``{"p50_us": 12.3, "n": 100.0}`` (values
    that don't parse as float stay strings)."""
    out: Dict[str, object] = {}
    for part in derived.split(";"):
        if not part or "=" not in part:
            continue
        k, _, v = part.partition("=")
        try:
            out[k] = float(v)
        except ValueError:
            out[k] = v
    return out


def reset_results() -> None:
    RESULTS.clear()


def timeit(fn: Callable, repeats: int = 3) -> float:
    """Median wall seconds."""
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return sorted(times)[len(times) // 2]


def make_corpus(n_bytes: int, n_words: int = 1000, seed: int = 0) -> bytes:
    """Synthetic text corpus of ~n_bytes (Zipf-ish word frequencies)."""
    rng = np.random.default_rng(seed)
    words = np.array([f"word{i:04d}".encode() for i in range(n_words)])
    # Zipf weights
    w = 1.0 / np.arange(1, n_words + 1)
    w /= w.sum()
    out: List[bytes] = []
    size = 0
    while size < n_bytes:
        line = b" ".join(rng.choice(words, size=10, p=w))
        out.append(line)
        size += len(line) + 1
    return b"\n".join(out)


def cluster(n: int = 4, block_size: int = 1 << 20):
    nodes = [DataNode(f"w{i}", DramTier()) for i in range(n)]
    bs = BlockStore(nodes, block_size=block_size, replication=2)
    sched = Scheduler([nd.node_id for nd in nodes], speculation_factor=None)
    return bs, sched


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """CSV row: name,us_per_call,derived (also recorded in RESULTS)."""
    print(f"{name},{us_per_call:.1f},{derived}")
    RESULTS[name] = {
        "us_per_call": float(us_per_call),
        "derived": _parse_derived(derived),
    }
