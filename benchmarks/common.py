"""Shared benchmark utilities."""

from __future__ import annotations

import time
from typing import Callable, Dict, List

import numpy as np

from repro.core import Scheduler
from repro.storage import BlockStore, DataNode, DramTier


def timeit(fn: Callable, repeats: int = 3) -> float:
    """Median wall seconds."""
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return sorted(times)[len(times) // 2]


def make_corpus(n_bytes: int, n_words: int = 1000, seed: int = 0) -> bytes:
    """Synthetic text corpus of ~n_bytes (Zipf-ish word frequencies)."""
    rng = np.random.default_rng(seed)
    words = np.array([f"word{i:04d}".encode() for i in range(n_words)])
    # Zipf weights
    w = 1.0 / np.arange(1, n_words + 1)
    w /= w.sum()
    out: List[bytes] = []
    size = 0
    while size < n_bytes:
        line = b" ".join(rng.choice(words, size=10, p=w))
        out.append(line)
        size += len(line) + 1
    return b"\n".join(out)


def cluster(n: int = 4, block_size: int = 1 << 20):
    nodes = [DataNode(f"w{i}", DramTier()) for i in range(n)]
    bs = BlockStore(nodes, block_size=block_size, replication=2)
    sched = Scheduler([nd.node_id for nd in nodes], speculation_factor=None)
    return bs, sched


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """CSV row: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")
