"""Shared benchmark utilities.

Job-shaped rows are serialized from the unified :class:`repro.api.
JobReport` via :func:`emit_job` — the one schema every benchmark reads
and writes (``benchmarks/compare.py`` validates its TRACKED fields
against the same key set, so an ad-hoc per-benchmark key fails loudly
instead of silently diverging)."""

from __future__ import annotations

import time
from typing import Callable, Dict, List

import numpy as np

from repro.api import ClusterConfig, JobHandle, JobReport, MarvelClient

#: Machine-readable mirror of every ``emit()`` row from the current run:
#: ``{name: {"us_per_call": float, "derived": {k: float|str}}}``.  The CI
#: harness (benchmarks/run.py --out) serializes this to ``BENCH_<sha>.json``
#: and ``benchmarks/compare.py`` gates regressions against the committed
#: baseline.
RESULTS: Dict[str, dict] = {}


def _parse_derived(derived: str) -> Dict[str, object]:
    """``"p50_us=12.3;n=100"`` → ``{"p50_us": 12.3, "n": 100.0}`` (values
    that don't parse as float stay strings)."""
    out: Dict[str, object] = {}
    for part in derived.split(";"):
        if not part or "=" not in part:
            continue
        k, _, v = part.partition("=")
        try:
            out[k] = float(v)
        except ValueError:
            out[k] = v
    return out


def reset_results() -> None:
    RESULTS.clear()


def timeit(fn: Callable, repeats: int = 3) -> float:
    """Median wall seconds."""
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return sorted(times)[len(times) // 2]


def make_corpus(n_bytes: int, n_words: int = 1000, seed: int = 0) -> bytes:
    """Synthetic text corpus of ~n_bytes (Zipf-ish word frequencies)."""
    rng = np.random.default_rng(seed)
    words = np.array([f"word{i:04d}".encode() for i in range(n_words)])
    # Zipf weights
    w = 1.0 / np.arange(1, n_words + 1)
    w /= w.sum()
    out: List[bytes] = []
    size = 0
    while size < n_bytes:
        line = b" ".join(rng.choice(words, size=10, p=w))
        out.append(line)
        size += len(line) + 1
    return b"\n".join(out)


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """CSV row: name,us_per_call,derived (also recorded in RESULTS)."""
    print(f"{name},{us_per_call:.1f},{derived}")
    RESULTS[name] = {
        "us_per_call": float(us_per_call),
        "derived": _parse_derived(derived),
    }


def make_client(config: ClusterConfig | None = None, **overrides) -> MarvelClient:
    """A benchmark cluster through the declarative façade (the successor
    of the old hand-assembled ``cluster()``)."""
    return MarvelClient(config, **overrides)


#: the serialized names of the unified JobReport schema — one derived key
#: per canonical field.  ``benchmarks/compare.py::JOB_FIELDS`` mirrors
#: this list; keep them in sync (compare.py's schema gate enforces it
#: for TRACKED metrics).
JOB_FIELD_KEYS = {
    "wall_seconds": "wall_s",
    "modeled_io_seconds": "modeled_io_s",
    "total_seconds": "total_s",
    "tasks": "tasks",
    "resumed_tasks": "resumed",
    "iterations": "iterations",
}


def emit_job(
    name: str,
    job: "JobHandle | JobReport",
    us_per_call: float | None = None,
    **extras: object,
) -> None:
    """Emit one job-shaped row from the unified report schema.

    Canonical fields are always serialized under their stable derived
    keys (``JOB_FIELD_KEYS``); ``extras`` ride along but may not shadow
    a canonical key — a collision (or a non-scalar value) raises instead
    of silently emitting an ad-hoc variant of a schema field."""
    report = job.report if isinstance(job, JobHandle) else job
    if not isinstance(report, JobReport):
        raise TypeError(
            f"emit_job needs a JobHandle/JobReport, got {type(job).__name__}"
        )
    pairs = [
        (key, report.field(field_name)) for field_name, key in JOB_FIELD_KEYS.items()
    ]
    for key, value in extras.items():
        if key in JOB_FIELD_KEYS.values():
            raise ValueError(
                f"extra key {key!r} shadows a canonical JobReport field"
            )
        if not isinstance(value, (int, float, str)):
            raise ValueError(f"extra key {key!r} must be scalar")
        pairs.append((key, value))
    derived = ";".join(
        f"{k}={v:.6g}" if isinstance(v, float) else f"{k}={v}" for k, v in pairs
    )
    if us_per_call is None:
        us_per_call = report.total_seconds * 1e6
    emit(name, us_per_call, derived)
