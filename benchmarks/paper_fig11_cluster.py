"""Fig. 11 (beyond the paper): multi-node scaling + kill-a-node row.

The paper deploys Marvel on a cluster but only reports single-machine
tier numbers; this figure measures what the sharded cluster adds.

Part 1 (scaling): J concurrent WordCount jobs on 1/2/4/8 nodes, every
row through the same ``ClusterRouter.run_mapreduce`` path (cluster vs
cluster, so the 1-node row pays the same driver overheads).  Node tiers
are sleeping SSDs — modeled device seconds become real (scaled) wall
time, so adding nodes' worker pools shows up as ``jobs_per_s``.  Each
row also drives a concurrent session burst through the routed gateways
and reports the p99 invoke latency.  The tracked ``speedup_4v1`` gates
the whole point of the subsystem: 4 nodes must stay >= 2x the 1-node
job throughput.

Part 2 (kill one node mid-job): nodes=4, replication=2, a node is
failed after the second map completes.  The router re-plans (dead
shuffle blobs invalidate their maps, reduces re-home to the shrunken
ring) and the tracked ``outputs_identical`` asserts the final output
bytes equal a 1-node run of the same job — fault tolerance with zero
output drift.
"""

from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor

import repro.core.mapreduce as mr
from repro.api import ClusterConfig, TierSpec, unify_report
from repro.core.stateful import StatefulFunction

from benchmarks.common import emit, emit_job, make_client

#: sleeping SSD state tier: per-op modeled latency (not bandwidth)
#: dominates at benchmark blob sizes, so wall time tracks op parallelism.
_SLEEP = 6.0
#: 8 reducers weight the perfectly-partitioned reduce reads over the
#: map-side fan-out (whose per-destination batch cost grows with nodes).
_N_RED = 12


def _corpus(n_bytes: int) -> bytes:
    """Synthetic text whose words vary in leading byte *and* length —
    ``_partition`` keys bytes on their first 8 chars, so a fixed-prefix
    vocabulary (``make_corpus``'s ``word0042``) would collapse the whole
    shuffle onto one partition."""
    out, size, i = [], 0, 0
    while size < n_bytes:
        line = b" ".join(
            b"%cword%d" % (97 + (i + j) % 26, (i + j) % 97) for j in range(10)
        )
        out.append(line)
        size += len(line) + 1
        i += 10
    return b"\n".join(out)


def _wc(name: str, n_red: int = _N_RED) -> mr.MapReduceJob:
    base = mr.wordcount_job(n_red)
    return mr.MapReduceJob(
        name,
        base.mapper,
        base.reducer,
        base.combiner,
        n_red,
        reduce_kind=base.reduce_kind,
    )


def _read_parts(client, out_path: str, n: int) -> bytes:
    return b"".join(client.store.read(f"{out_path}/part_{p:04d}") for p in range(n))


def _cfg(
    name: str, nodes: int, block: int, replication: int = 1, **kw
) -> ClusterConfig:
    return ClusterConfig(
        name=name,
        nodes=nodes,
        sharded=True,
        replication=replication,
        block_size=block,
        **kw,
    )


def _scale_row(n_nodes: int, n_jobs: int, data: bytes, block: int, burst: int) -> float:
    cfg = _cfg(
        f"fig11n{n_nodes}",
        n_nodes,
        block,
        tiers=(TierSpec("ssd", sleep=True, sleep_scale=_SLEEP),),
    )
    with make_client(cfg) as client:
        client.store.write("/in", data, record_delim=b"\n")
        jobs = [_wc(f"wc{j}") for j in range(n_jobs)]
        t0 = time.perf_counter()
        with ThreadPoolExecutor(max_workers=n_jobs) as pool:
            futs = [
                pool.submit(client.cluster.run_mapreduce, jobs[j], "/in", f"/out{j}")
                for j in range(n_jobs)
            ]
            reports = [f.result() for f in futs]
        jobs_per_s = n_jobs / (time.perf_counter() - t0)

        # session burst: p99 invoke latency through the routed gateways
        client.register(
            StatefulFunction(
                "bump",
                lambda state, **kw: ({"n": state["n"] + 1}, state["n"] + 1),
                lambda **kw: {"n": 0},
                jit=False,
            )
        )

        def one(i: int) -> float:
            t = time.perf_counter()
            client.invoke("bump", session=f"s{i % 32}")
            return time.perf_counter() - t

        with ThreadPoolExecutor(max_workers=16) as pool:
            lat = sorted(pool.map(one, range(burst)))
        p99_ms = lat[min(len(lat) - 1, int(len(lat) * 0.99))] * 1e3

        net = client.cluster.fabric.total
        emit_job(
            f"fig11/scale/nodes={n_nodes}",
            unify_report(reports[0], tiers=client.tier_rollup()),
            jobs_per_s=round(jobs_per_s, 3),
            p99_ms=round(p99_ms, 2),
            nodes=n_nodes,
            net_mb=round(net.bytes_written / 2**20, 4),
        )
    return jobs_per_s


def _kill_row(data: bytes, block: int) -> int:
    with make_client(_cfg("fig11ref", 1, block)) as ref:
        ref.store.write("/in", data, record_delim=b"\n")
        ref.cluster.run_mapreduce(_wc("wckill"), "/in", "/out")
        expect = _read_parts(ref, "/out", _N_RED)

    with make_client(_cfg("fig11kill", 4, block, replication=2)) as client:
        client.store.write("/in", data, record_delim=b"\n")
        summaries = []

        def on_map_done(count: int) -> None:
            if count == 2 and not summaries:
                summaries.append(client.cluster.fail_node("n1"))

        raw = client.cluster.run_mapreduce(
            _wc("wckill"), "/in", "/out", on_map_done=on_map_done
        )
        identical = int(_read_parts(client, "/out", _N_RED) == expect)
        s = summaries[0]
        emit_job(
            "fig11/kill_node",
            unify_report(raw, tiers=client.tier_rollup()),
            outputs_identical=identical,
            rehomed_sessions=s["sessions_rehomed"],
            reblocks=s["blocks_rereplicated"],
            nodes=len(client.cluster.live_nodes()),
        )
    return identical


def main(
    nodes_list=(1, 2, 4, 8), jobs=12, corpus_bytes=32 << 10, burst=240, smoke=False
) -> None:
    data = _corpus(corpus_bytes)
    block = max(corpus_bytes // 8, 1 << 10)  # ~8 map tasks per job
    throughput = {}
    for n in nodes_list:
        throughput[n] = _scale_row(n, jobs, data, block, burst)
    speedup_4v1 = throughput[4] / throughput[1]
    identical = _kill_row(data, block)
    emit(
        "fig11/summary",
        0.0,
        f"speedup_4v1={speedup_4v1:.3f}"
        f";jobs_per_s_1={throughput[1]:.3f}"
        f";jobs_per_s_4={throughput[4]:.3f}",
    )
    if smoke:
        assert speedup_4v1 >= 2.0, (
            f"4-node throughput only {speedup_4v1:.2f}x the 1-node row"
        )
        assert identical == 1, "kill-one-node output drifted"


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="scaled-down run with the CI gate assertions",
    )
    args = ap.parse_args()
    if args.smoke:
        main(nodes_list=(1, 4), jobs=12, corpus_bytes=8 << 10, burst=64, smoke=True)
    else:
        main()
