"""Paper Table 2: IOPS / bandwidth / latency per storage tier.

Measures the real tiers (DRAM dict store, PMEM mmap files) with 4 KiB ops
— the same fio methodology scaled down — and prints the calibrated device
constants used by the simulated SSD/S3 tiers (which reproduce the paper's
10-100x PMEM-over-SSD gap by construction).
"""

from __future__ import annotations

import tempfile
import time

import numpy as np

from repro.storage import DramTier, PmemTier
from repro.storage.tiers import PMEM_SPEC, S3_SPEC, SSD_SPEC

from benchmarks.common import emit

BLOCK = 4096
N_OPS = 400


def _bench_tier(tier, name: str) -> None:
    blob = b"x" * BLOCK
    # sequential write
    t0 = time.perf_counter()
    for i in range(N_OPS):
        tier.put(f"seq/{i:06d}", blob)
    dt_w = time.perf_counter() - t0
    # sequential read
    t0 = time.perf_counter()
    for i in range(N_OPS):
        tier.get(f"seq/{i:06d}")
    dt_r = time.perf_counter() - t0
    # random read
    rng = np.random.default_rng(0)
    order = rng.permutation(N_OPS)
    t0 = time.perf_counter()
    for i in order:
        tier.get(f"seq/{i:06d}")
    dt_rr = time.perf_counter() - t0
    for op, dt in [("seq_write", dt_w), ("seq_read", dt_r), ("rand_read", dt_rr)]:
        iops = N_OPS / dt
        bw = N_OPS * BLOCK / dt
        emit(
            f"table2/{name}/{op}",
            dt / N_OPS * 1e6,
            f"iops={iops:.0f};bw_MBps={bw / 1e6:.1f}",
        )


def main() -> None:
    _bench_tier(DramTier(), "dram_measured")
    with tempfile.TemporaryDirectory() as td:
        _bench_tier(PmemTier(td), "pmem_measured")
    # calibrated constants (paper Table 2 / provider docs)
    for spec in (PMEM_SPEC, SSD_SPEC, S3_SPEC):
        emit(
            f"table2/{spec.name}_model/seq_read",
            spec.read_latency * 1e6,
            f"bw_GBps={spec.read_bw / 2**30:.2f}",
        )
        emit(
            f"table2/{spec.name}_model/seq_write",
            spec.write_latency * 1e6,
            f"bw_GBps={spec.write_bw / 2**30:.2f}",
        )


if __name__ == "__main__":
    main()
