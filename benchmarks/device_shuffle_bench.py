"""TPU-native adaptation benchmark: device-resident shuffle vs the
storage-mediated path (DESIGN.md §2 — Ignite→ICI, S3→host round-trip).

Single-host CPU numbers are illustrative of the *structure* (counts both
paths' moved bytes and wall time); the dry-run roofline carries the pod-
scale analysis.
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core import device_histogram, storage_histogram
from repro.storage import DramTier, SimulatedTier
from repro.storage.tiers import S3_SPEC

from benchmarks.common import emit, timeit


def main(n=1 << 16, vocab=8192) -> None:
    rng = np.random.default_rng(0)
    keys = rng.integers(0, vocab, n).astype(np.int32)
    vals = np.ones(n, np.float32)
    from repro.jax_compat import make_mesh

    mesh = make_mesh((1,), ("data",))
    kj, vj = jnp.asarray(keys), jnp.asarray(vals)

    def dev():
        device_histogram(
            kj, vj, mesh, "data", vocab=vocab, capacity_factor=2.0
        ).counts.block_until_ready()

    t_dev = timeit(dev)
    res = device_histogram(kj, vj, mesh, "data", vocab=vocab, capacity_factor=2.0)
    # shuffled_bytes counts actual pairs (comparable with the storage
    # path); the capacity-padded buffer footprint is reported separately.
    emit(
        "shuffle/device/n=%d" % n,
        t_dev * 1e6,
        f"shuffled_bytes={res.shuffled_bytes};buffer_bytes={res.buffer_bytes}",
    )

    ndev_sim = 8
    tier = DramTier()
    t_host = timeit(
        lambda: storage_histogram(
            keys, vals, ndev_sim, tier, vocab=vocab, capacity_factor=2.0
        )
    )
    emit(
        "shuffle/host_tier/n=%d" % n,
        t_host * 1e6,
        f"slowdown_vs_device={t_host / max(t_dev, 1e-9):.1f}x",
    )

    s3 = SimulatedTier(S3_SPEC)
    storage_histogram(keys, vals, ndev_sim, s3, vocab=vocab, capacity_factor=2.0)
    emit(
        "shuffle/s3_modeled/n=%d" % n,
        (t_host + s3.stats.modeled_seconds) * 1e6,
        f"modeled_io_s={s3.stats.modeled_seconds:.3f}",
    )


if __name__ == "__main__":
    main()
