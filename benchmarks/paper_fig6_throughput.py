"""Paper Fig. 6: intermediate-tier I/O throughput vs input size.

Throughput = shuffle bytes / tier seconds while running WordCount, for the
memory tier (IGFS analog) vs the PMEM-HDFS tier.  Reproduces the paper's
observation that the in-memory tier's throughput *scales up* with input
size (it amortizes per-op latency) while remaining above the persistent
tier.
"""

from __future__ import annotations

import repro.core.mapreduce as mr
from repro.core import run_job
from repro.storage import DramTier, SimulatedTier
from repro.storage.tiers import PMEM_SPEC

from benchmarks.common import cluster, emit, make_corpus


def main(scales=(1 << 18, 1 << 20, 1 << 22)) -> None:
    base = mr.wordcount_job(4)
    job = mr.MapReduceJob("wc", base.mapper, base.reducer, None, 4)
    for scale in scales:
        data = make_corpus(scale)
        for name, tier in [
            ("igfs", DramTier()),
            ("pmem_hdfs", SimulatedTier(PMEM_SPEC)),
        ]:
            bs, sched = cluster(block_size=max(scale // 8, 65536))
            bs.write("/in", data, record_delim=b"\n")
            rep = run_job(job, bs, "/in", "/out", tier, sched)
            moved = tier.stats.bytes_read + tier.stats.bytes_written
            secs = (
                tier.stats.modeled_seconds
                if tier.stats.modeled_seconds > 0
                else tier.stats.wall_seconds
            )
            gbps = moved * 8 / max(secs, 1e-9) / 1e9
            emit(
                f"fig6/{name}/in={scale}", secs * 1e6,
                f"shuffle_throughput_Gbps={gbps:.2f};moved={moved}",
            )


if __name__ == "__main__":
    main()
