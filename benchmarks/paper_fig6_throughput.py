"""Paper Fig. 6: intermediate-tier I/O throughput vs input size — plus the
pipelined-vs-barrier comparison the DAG engine adds on top of the paper.

Part 1 (the paper's figure): throughput = shuffle bytes / tier seconds
while running WordCount, for the memory tier (IGFS analog) vs the
PMEM-HDFS tier.  Reproduces the paper's observation that the in-memory
tier's throughput *scales up* with input size (it amortizes per-op
latency) while remaining above the persistent tier.

Part 2 (beyond the paper): the same WordCount run twice on the same input
and tier — ``mode="wave"`` (Corral-style barrier between map and reduce)
vs ``mode="pipelined"`` (streaming shuffle: reducers fetch/merge
partitions while late maps still run).  Tiers sleep a scaled fraction of
their modeled device time so the overlap is real wall time; the emitted
``total_s`` shows pipelined <= wave, with ``overlap_s > 0`` and the
partition count that streamed before the map stage finished.

Part 3 (device execution mode): the same WordCount with ``device=True``
— partitioning on the Pallas histogram kernel, reduce as the jitted
segment-sum — vs the host path, and once more with a starved capacity
factor so the tier-spill path carries most pairs.  The tracked
``outputs_identical`` flags assert the lowering changes *zero* output
bytes (kernels run in interpret mode off-TPU, so CI gates this on CPU).

Every cluster is declared as a :class:`~repro.api.ClusterConfig` and run
through the façade.
"""

from __future__ import annotations

import repro.core.mapreduce as mr
from repro.api import ClusterConfig, TierSpec

from benchmarks.common import emit, emit_job, make_client, make_corpus


def _shuffle_heavy_wordcount() -> mr.MapReduceJob:
    base = mr.wordcount_job(4)
    # no combiner -> full shuffle volume (paper Table 1 WordCount rows)
    return mr.MapReduceJob("wc", base.mapper, base.reducer, None, 4, reduce_kind="sum")


def _read_parts(client, out_path: str, n: int):
    outs = []
    for p in range(n):
        path = f"{out_path}/part_{p:04d}"
        outs.append(
            client.store.read(path) if client.store.exists(path) else None
        )
    return outs


def main(
    scales=(1 << 18, 1 << 20, 1 << 22),
    pipeline_scale=1 << 20,
    repeats=3,
    device_scale=1 << 15,
) -> None:
    job = _shuffle_heavy_wordcount()
    for scale in scales:
        data = make_corpus(scale)
        for name, spec in [
            ("igfs", TierSpec("dram")),
            ("pmem_hdfs", TierSpec("pmem")),
        ]:
            cfg = ClusterConfig(
                name="fig6",
                tiers=(spec,),
                block_size=max(scale // 8, 65536),
            )
            with make_client(cfg) as client:
                client.store.write("/in", data, record_delim=b"\n")
                client.mapreduce(job, "/in", "/out")
                stats = client.state.stats
                moved = stats.bytes_read + stats.bytes_written
                secs = (
                    stats.modeled_seconds
                    if stats.modeled_seconds > 0
                    else stats.wall_seconds
                )
            gbps = moved * 8 / max(secs, 1e-9) / 1e9
            emit(
                f"fig6/{name}/in={scale}",
                secs * 1e6,
                f"shuffle_throughput_Gbps={gbps:.2f};moved={moved}",
            )

    # ---- pipelined vs barrier (same input, same tier spec) -----------------
    data = make_corpus(pipeline_scale)
    # sleep_scale turns the modeled device seconds into real (scaled) wall
    # time so map/reduce overlap is physically observable; PMEM's modeled
    # times are so small they need a larger scale than SSD's.
    tier_specs = [
        ("pmem_hdfs", TierSpec("pmem", sleep=True, sleep_scale=1000.0)),
        ("ssd", TierSpec("ssd", sleep=True, sleep_scale=0.5)),
    ]
    # ~16 input blocks over 4 workers -> 4 map waves, so streaming
    # reducers have a real window to overlap with the map tail.
    block = max(pipeline_scale // 16, 1 << 14)
    for name, spec in tier_specs:
        for mode in ("wave", "pipelined"):
            reps = []
            for _ in range(repeats):
                cfg = ClusterConfig(name="fig6", tiers=(spec,), block_size=block)
                with make_client(cfg) as client:
                    client.store.write("/in", data, record_delim=b"\n")
                    reps.append(client.mapreduce(job, "/in", "/out", mode=mode).report)
            # report the median *run*, so total/overlap/streamed are one
            # consistent observation rather than a mix across repeats
            rep = sorted(reps, key=lambda r: r.total_seconds)[len(reps) // 2]
            emit_job(
                f"fig6/pipeline/{name}/{mode}",
                rep,
                overlap_s=round(rep.field("overlap_seconds"), 4),
                streamed=rep.field("partitions_streamed"),
                out=rep.field("output_bytes"),
            )

    # ---- device-vs-host lowering (byte-identity is the tracked metric) -----
    data = make_corpus(device_scale)

    def run_wc(device: bool, capacity_factor: float = 1.3):
        cfg = ClusterConfig(
            name="fig6dev",
            tiers=(TierSpec("dram"),),
            block_size=max(device_scale // 4, 1 << 14),
            device_interpret=True,
            device_capacity_factor=capacity_factor,
        )
        with make_client(cfg) as client:
            client.store.write("/in", data, record_delim=b"\n")
            handle = client.mapreduce(job, "/in", "/out", device=device)
            return handle.report, _read_parts(client, "/out", 4)

    host_rep, host_out = run_wc(False)
    dev_rep, dev_out = run_wc(True)
    # capacity_factor=0.05 starves the device buffers so nearly every
    # pair takes the tier-spill path — identity must survive that too.
    spill_rep, spill_out = run_wc(True, capacity_factor=0.05)
    emit_job("fig6/device/wordcount/host", host_rep)
    emit_job(
        "fig6/device/wordcount/device",
        dev_rep,
        outputs_identical=int(dev_out == host_out),
        device_pairs=dev_rep.field("device_pairs"),
    )
    emit_job(
        "fig6/device/wordcount/device_spill",
        spill_rep,
        outputs_identical=int(spill_out == host_out),
        spilled_pairs=spill_rep.field("device_spilled_pairs"),
    )


if __name__ == "__main__":
    main()
