"""Fig. 10 (beyond the paper): Marvel-Serve KV-paging capacity + TTFT.

The paper's tiering argument applied to LM serving: under a *fixed* DRAM
budget for KV blocks, how many concurrent conversations can a server
sustain, and what does resuming a cold one cost?  Three contrasts, all
driven through the declarative façade (``client.serving()``):

* ``fig10/capacity/*`` — no-paging baseline vs paged pool under the same
  DRAM block budget.  Without paging a conversation's cache must stay
  resident for its lifetime, so capacity is ``budget // session_bytes``
  and the rest shed; the paged pool demotes idle sessions to the slow
  tier and admits them all.  TRACKED: the paged pool sustains >= 4x the
  baseline's concurrent conversations with zero shed.
* ``fig10/identity`` — the same conversations decoded with an unbounded
  resident pool vs thrashing through a 2-session warm pool + tiny budget
  with ``lossless=True`` demotion.  TRACKED-exact: token streams are
  byte-identical (paging is a placement decision, not a numerics one).
* ``fig10/resume/*`` — p99 TTFT of resuming a suspended conversation on
  a modeled-latency SSD slow tier: promotion-on-resume (blocks prefetch
  during think time) vs demand-faulting inside the decode step.

``fig10/sweep/n*`` replays a Zipf-skewed step trace
(:class:`~repro.core.loadgen.TraceSpec`) over growing conversation
counts — 64 -> 512 in the full run — under the same fixed budget,
reporting decode throughput and peak residency.  ``--nightly`` scales
the sweep by ``STRESS_SCALE``.
"""

from __future__ import annotations

import os
import time

import jax
import numpy as np

from repro.api import ClusterConfig, MarvelClient, ServingConfig, TierSpec
from repro.configs import get_config
from repro.core.loadgen import TraceSpec, generate_trace
from repro.models import init_params, model_defs, reduced_for_smoke

from benchmarks.common import emit

PROMPT_LEN = 8
MAX_TOKENS = 8

_MODEL = None


def _model():
    global _MODEL
    if _MODEL is None:
        cfg = reduced_for_smoke(get_config("qwen2.5-3b"))
        params = init_params(model_defs(cfg), jax.random.PRNGKey(0))
        _MODEL = (cfg, params)
    return _MODEL


def _prompt(cfg, i):
    return jax.random.randint(jax.random.fold_in(jax.random.PRNGKey(1), i),
                              (1, PROMPT_LEN), 0, cfg.vocab)


def _cluster(name, *, budget=None, warm_pool=8, admission=True,
             slow=TierSpec("pmem"), dram_cap=256 << 20):
    return ClusterConfig(
        name=name,
        tiers=(TierSpec("dram", capacity_bytes=dram_cap), slow),
        invokers=2, warm_pool=warm_pool, commit_every=1,
        journal="volatile",
        serving=ServingConfig(block_tokens=8, lossless=True,
                              dram_budget_bytes=budget,
                              admission=admission),
    )


def _pool(client, cfg, params):
    return client.serving(params, cfg, prompt_len=PROMPT_LEN,
                          max_tokens=MAX_TOKENS)


def _zipf_steps(n_convs, steps, seed=10):
    """Zipf-skewed step order over ``n_convs`` conversations (hot head,
    idle tail), from the seeded trace generator."""
    spec = TraceSpec(seed=seed, duration=float(steps), base_rate=1.0,
                     tenants=1, sessions_per_tenant=n_convs,
                     session_skew=0.9)
    order = [int(a.session[1:]) for a in generate_trace(spec)]
    return order[:steps]


def _probe_session_bytes():
    """Measured bytes of one resident session's KV blocks."""
    cfg, params = _model()
    with MarvelClient(_cluster("fig10probe")) as client:
        pool = _pool(client, cfg, params)
        pool.start("probe", _prompt(cfg, 0)).result()
        return pool.pager.typical_session_bytes()


# -- capacity under a fixed DRAM block budget ------------------------------


def _capacity_cells(n_convs, tokens_per_conv, session_bytes, base_capacity):
    cfg, params = _model()
    budget = int((base_capacity + 0.5) * session_bytes)

    # No-paging baseline: a conversation's blocks must stay resident for
    # its lifetime — admit only what fits the budget, shed the rest.
    with MarvelClient(_cluster("fig10base", budget=budget, admission=False,
                               warm_pool=n_convs + 4)) as client:
        pool = _pool(client, cfg, params)
        admitted, shed = [], 0
        for i in range(n_convs):
            if pool.pager.can_admit(session_bytes):
                pool.start(f"c{i}", _prompt(cfg, i)).result()
                admitted.append(f"c{i}")
            else:
                shed += 1
        for _ in range(tokens_per_conv):
            for c in admitted:
                pool.step(c).result()
        base_sustained = len(admitted)
        emit("fig10/capacity/no_paging", 0.0,
             f"sessions_sustained={base_sustained};shed={shed}"
             f";max_resident={pool.pager.stats.max_resident}"
             f";budget_bytes={budget};session_bytes={session_bytes}")

    # Paged pool: idle sessions demote to the slow tier, everyone admitted.
    with MarvelClient(_cluster("fig10paged", budget=budget,
                               warm_pool=max(4, base_capacity))) as client:
        pool = _pool(client, cfg, params)
        t0 = time.perf_counter()
        tokens = 0
        for i in range(n_convs):
            pool.start(f"c{i}", _prompt(cfg, i)).result()
            tokens += 1
        for i in _zipf_steps(n_convs, n_convs * tokens_per_conv):
            pool.step(f"c{i}").result()
            tokens += 1
        dt = time.perf_counter() - t0
        stats = pool.stats()
        paged_sustained = len(pool.conversations())
        tok_per_s = tokens / dt
        emit("fig10/capacity/paged", dt / max(tokens, 1) * 1e6,
             f"sessions_sustained={paged_sustained};shed={stats['shed']}"
             f";max_resident={stats['max_resident']}"
             f";demotions={stats['demotions']}"
             f";demand_faults={stats['demand_faults']}"
             f";tok_per_s={tok_per_s:.2f};budget_bytes={budget}")
    return base_sustained, paged_sustained, stats["shed"], tok_per_s


# -- lossless byte identity -------------------------------------------------


def _identity_cell(n_convs, n_tokens):
    cfg, params = _model()

    def run(client):
        pool = _pool(client, cfg, params)
        toks = {c: [] for c in range(n_convs)}
        for c in range(n_convs):
            toks[c].append(
                int(np.asarray(pool.start(f"c{c}",
                                          _prompt(cfg, c)).result())[0, 0]))
        for _ in range(n_tokens - 1):
            for c in range(n_convs):  # round-robin: maximal churn
                toks[c].append(int(np.asarray(pool.step(f"c{c}")
                                              .result())[0, 0]))
        return toks, pool.stats()

    with MarvelClient(_cluster("fig10ref", warm_pool=n_convs + 4)) as client:
        want, _ = run(client)
    session_bytes = max(1, _probe_session_bytes())
    with MarvelClient(_cluster("fig10thrash",
                               budget=int(2.5 * session_bytes),
                               warm_pool=2)) as client:
        got, stats = run(client)
    identical = int(got == want)
    emit("fig10/identity", 0.0,
         f"outputs_identical={identical}"
         f";demotions={stats['demotions']}"
         f";demand_faults={stats['demand_faults']}"
         f";conversations={n_convs}")
    return identical, stats["demotions"]


# -- resume TTFT: prefetch vs demand-fault ---------------------------------


def _resume_cells(n_resumes, think_s=0.25, sleep_scale=4.0):
    cfg, params = _model()
    slow = TierSpec("ssd", sleep=True, sleep_scale=sleep_scale)
    out = {}
    for mode in ("demand", "prefetch"):
        with MarvelClient(_cluster(f"fig10{mode}", slow=slow)) as client:
            pool = _pool(client, cfg, params)
            convs = [f"c{i}" for i in range(3)]
            for i, c in enumerate(convs):
                pool.start(c, _prompt(cfg, i)).result()
            ttfts = []
            for r in range(n_resumes):
                c = convs[r % len(convs)]
                pool.suspend(c)
                if mode == "prefetch":
                    pool.resume(c, prefetch=True)
                time.sleep(think_s)  # user think time, both modes
                t0 = time.perf_counter()
                pool.step(c).result()
                ttfts.append(time.perf_counter() - t0)
            p99 = float(np.percentile(np.array(ttfts) * 1e3, 99))
            faults = pool.stats()["demand_faults"]
            emit(f"fig10/resume/{mode}", np.mean(ttfts) * 1e6,
                 f"p99_ttft_ms={p99:.3f};demand_faults={faults}"
                 f";resumes={pool.stats()['resumes']}")
            out[mode] = p99
    return out["prefetch"], out["demand"]


# -- Zipf sweep over conversation counts -----------------------------------


def _sweep(conv_counts, tokens_per_conv, session_bytes, base_capacity):
    cfg, params = _model()
    budget = int((base_capacity + 0.5) * session_bytes)
    for n in conv_counts:
        with MarvelClient(_cluster(f"fig10sweep{n}", budget=budget,
                                   warm_pool=max(4, base_capacity))) as client:
            pool = _pool(client, cfg, params)
            t0 = time.perf_counter()
            tokens = 0
            for i in range(n):
                pool.start(f"c{i}", _prompt(cfg, i)).result()
                tokens += 1
            for i in _zipf_steps(n, n * tokens_per_conv, seed=20 + n):
                pool.step(f"c{i}").result()
                tokens += 1
            dt = time.perf_counter() - t0
            stats = pool.stats()
            emit(f"fig10/sweep/n{n}", dt / max(tokens, 1) * 1e6,
                 f"tok_per_s={tokens / dt:.2f};shed={stats['shed']}"
                 f";max_resident={stats['max_resident']}"
                 f";demotions={stats['demotions']}"
                 f";demand_faults={stats['demand_faults']}"
                 f";conversations={n}")


# -- main ------------------------------------------------------------------


def main(conv_counts=(64, 128, 256, 512), capacity_convs=64,
         tokens_per_conv=3, base_capacity=16, identity_convs=4,
         identity_tokens=8, resumes=12, smoke=False):
    session_bytes = max(1, _probe_session_bytes())

    base, paged, paged_shed, tok_per_s = _capacity_cells(
        capacity_convs, tokens_per_conv, session_bytes, base_capacity)
    identical, demotions = _identity_cell(identity_convs, identity_tokens)
    prefetch_p99, demand_p99 = _resume_cells(resumes)
    _sweep(conv_counts, tokens_per_conv, session_bytes, base_capacity)

    ratio = paged / max(base, 1)
    speedup = demand_p99 / max(prefetch_p99, 1e-9)
    emit("fig10/summary", 0.0,
         f"capacity_ratio={ratio:.4g};outputs_identical={identical}"
         f";prefetch_speedup={speedup:.4g}"
         f";sessions_sustained={paged};shed={paged_shed}"
         f";tok_per_s={tok_per_s:.2f}"
         f";p99_ttft_ms={prefetch_p99:.3f}")

    if smoke:
        assert ratio >= 4.0, (
            f"paged pool sustained only {ratio:.1f}x the no-paging "
            f"baseline ({paged} vs {base} sessions)")
        assert paged_shed == 0, f"paged pool shed {paged_shed} conversations"
        assert identical == 1, "lossless paged decode drifted from baseline"
        assert demotions > 0, (
            "identity cell never demoted — the paged side wasn't paging")
        assert prefetch_p99 < demand_p99, (
            f"prefetch resume p99 {prefetch_p99:.1f}ms not better than "
            f"demand-fault {demand_p99:.1f}ms")


def _nightly():
    scale = max(1, int(os.environ.get("STRESS_SCALE", "1")))
    main(conv_counts=(64, 128 * scale), capacity_convs=64,
         tokens_per_conv=3, base_capacity=16, identity_convs=8,
         identity_tokens=MAX_TOKENS, resumes=24, smoke=True)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="scaled-down run with the CI gate assertions")
    ap.add_argument("--nightly", action="store_true",
                    help="large Zipf sweep (honors STRESS_SCALE)")
    args = ap.parse_args()
    if args.nightly:
        _nightly()
    elif args.smoke:
        main(conv_counts=(8, 16), capacity_convs=15, tokens_per_conv=2,
             base_capacity=3, identity_convs=3, identity_tokens=6,
             resumes=6, smoke=True)
    else:
        main()
