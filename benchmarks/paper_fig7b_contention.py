"""Fig. 7b (beyond the paper): gateway throughput under session contention.

A Zipf-skewed hammer over many sessions — the workload the warm-path
overhaul (lock-striped gateway + group commit + lazy serde) exists for.
One gateway with a striped lane map serves ``total`` invocations spread
over ``sessions`` sessions with Zipf(a) popularity, a read-mostly op mix
(reads leave the state object untouched; writes mutate it through the
copy-on-write wrapper), group commit on, commit-every-invocation.

Reported:

  * ``fig7b/contention`` — invocations/sec plus the p99 **lane wait**
    (submit → dispatch) from the gateway's striped wait samples; under
    the old single-lock gateway this is where contention showed up.
  * ``fig7b/summary`` — ``lazy_frac``: the fraction of invocations whose
    commit was elided by the serde fast path.  With ``cow=True`` a read
    returns the identical state object, so ``lazy_frac`` equals the read
    fraction of the op mix *exactly* — deterministic, and tracked by the
    regression gate.  ``commit_entries`` (pairs physically flushed) is
    asserted ``<= writes + sessions``: every write dirties once, every
    session's init commits once, and reads must never reach the tier.

``--smoke`` scales the hammer down (64 sessions) and asserts the
deterministic bars; the full run uses the paper-scale 256 sessions.
"""

from __future__ import annotations

import time

import numpy as np

from repro.api import ClusterConfig, TierSpec
from repro.core import StatefulFunction

from benchmarks.common import emit, make_client
from benchmarks.paper_fig7_gateway import SERVE_SPEC


def _contended_fn():
    """Counter whose reads keep state identity (COW elides their commits)."""

    def step(state, write):
        if write:
            state["n"] = state["n"] + 1
        return state, state["n"]

    def init():
        return {"n": 0}

    return StatefulFunction("hammer", step, init=init, jit=False, cow=True)


def main(
    sessions: int = 256,
    total: int = 12_000,
    write_frac: float = 0.1,
    zipf_a: float = 1.1,
    invokers: int = 8,
    stripes: int = 8,
    seed: int = 0,
    smoke: bool = False,
) -> None:
    rng = np.random.default_rng(seed)
    # Zipf(a) popularity over the session ids: a handful of hot sessions
    # take most of the traffic — the worst case for a single lane lock.
    ranks = np.arange(1, sessions + 1, dtype=np.float64)
    weights = ranks**-zipf_a
    weights /= weights.sum()
    targets = rng.choice(sessions, size=total, p=weights)
    # exact op mix (not per-invocation coin flips) so the elision math
    # below is deterministic: precisely `writes` invocations mutate state
    writes = int(total * write_frac)
    ops = np.zeros(total, dtype=bool)
    ops[:writes] = True
    rng.shuffle(ops)

    cfg = ClusterConfig(
        name="fig7b",
        tiers=(TierSpec(device=SERVE_SPEC, sleep=True),),
        invokers=invokers,
        warm_pool=sessions + 8,
        commit_every=1,
        group_commit=True,
        gateway_stripes=stripes,
    )
    with make_client(cfg) as client:
        client.register(_contended_fn())
        t0 = time.perf_counter()
        futures = [
            client.gateway.submit(
                "hammer", session=f"s{targets[i]}", write=bool(ops[i])
            )
            for i in range(total)
        ]
        for f in futures:
            f.result(timeout=120)
        dt = time.perf_counter() - t0
        stats = client.gateway.stats()
        lazy = client.runtime.lazy_hits
        entries = client.runtime.commit_entries
        batches = client.runtime.commit_batches

    reads = total - writes
    lazy_frac = lazy / total
    read_frac = reads / total
    emit(
        "fig7b/contention",
        dt / total * 1e6,
        f"inv_per_s={total / dt:.1f};"
        f"p99_lane_wait_ms={stats.lane_wait_p99_ms:.3f};"
        f"p50_lane_wait_ms={stats.lane_wait_p50_ms:.3f};n={total}",
    )
    emit(
        "fig7b/summary",
        dt / total * 1e6,
        f"lazy_frac={lazy_frac:.4f};read_frac={read_frac:.4f};"
        f"commit_entries={entries};commit_batches={batches};"
        f"write_bound={writes + sessions}",
    )
    if smoke:
        # deterministic bars: identity-preserving reads must elide their
        # commits, and only writes (+ one init per session) may reach the
        # tier — if either fails, the serde fast path has regressed
        assert lazy == reads, (
            f"lazy elisions {lazy} != reads {reads} — COW identity broken"
        )
        assert entries <= writes + sessions, (
            f"{entries} pairs flushed > writes+inits bound {writes + sessions}"
        )
        assert batches <= entries, f"{batches} batches > {entries} entries"


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="scaled-down hammer that asserts the elision bars",
    )
    args = ap.parse_args()
    if args.smoke:
        main(sessions=64, total=2_000, smoke=True)
    else:
        main()
