"""Benchmark harness: one module per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV rows.  Modules:

  paper_table1_sizes    — Table 1: phase data sizes / shuffle blowup
  paper_table2_tiers    — Table 2: tier IOPS/bandwidth/latency
  paper_fig4_wordcount  — Figs. 1+4: WordCount time per tier (+quota fail)
  paper_fig5_grep       — Fig. 5: Grep time per tier
  paper_fig6_throughput — Fig. 6: intermediate-tier throughput scaling
  paper_fig7_gateway    — Fig. 7: gateway warm/cold latency + scaling
  paper_fig7b_contention — Fig. 7b: Zipf-skewed session-contention hammer
  paper_fig8_tiering    — Fig. 8: static tiers vs adaptive hierarchy
  paper_fig9_iterative  — Fig. 9: iterative dataflow stateful vs cold-reload
  paper_fig11_cluster   — Fig. 11: multi-node scaling + kill-a-node row
  paper_fig12_slo       — Fig. 12: trace-driven SLO, fixed vs autoscaled
  device_shuffle_bench  — TPU-native shuffle vs storage path
  kernels_bench         — Pallas kernel plumbing + target FLOPs
  train_step_bench      — reduced-config train-step throughput

Roofline numbers come from the dry-run (see EXPERIMENTS.md §Roofline):
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out results.json

``--smoke`` runs a scaled-down subset (seconds, CPU-only) — CI uses it so
the perf scripts can't silently bit-rot.  ``--out FILE`` additionally
writes every emitted row as machine-readable JSON (CI uploads it as the
``BENCH_<sha>.json`` artifact; ``benchmarks/compare.py`` gates metric
regressions against the committed ``BENCH_baseline.json``).

Job-shaped rows are serialized from the unified ``repro.api.JobReport``
schema via ``benchmarks/common.py::emit_job`` (stable keys: ``wall_s``,
``modeled_io_s``, ``total_s``, ``tasks``, ``resumed``, ``iterations``);
smoke assertions read report fields through ``JobReport.field`` which
raises on unknown names — no per-benchmark ad-hoc keys.
"""

import argparse
import json
import os
import subprocess
import sys
import time
import traceback

from benchmarks import (
    common,
    device_shuffle_bench,
    kernels_bench,
    paper_fig4_wordcount,
    paper_fig5_grep,
    paper_fig6_throughput,
    paper_fig7_gateway,
    paper_fig7b_contention,
    paper_fig8_tiering,
    paper_fig9_iterative,
    paper_fig10_serving,
    paper_fig11_cluster,
    paper_fig12_slo,
    paper_table1_sizes,
    paper_table2_tiers,
    train_step_bench,
)

MODULES = [
    ("table1", paper_table1_sizes),
    ("table2", paper_table2_tiers),
    ("fig4", paper_fig4_wordcount),
    ("fig5", paper_fig5_grep),
    ("fig6", paper_fig6_throughput),
    ("fig7", paper_fig7_gateway),
    ("fig7b", paper_fig7b_contention),
    ("fig8", paper_fig8_tiering),
    ("fig9", paper_fig9_iterative),
    ("fig10", paper_fig10_serving),
    ("fig11", paper_fig11_cluster),
    ("fig12", paper_fig12_slo),
    ("device_shuffle", device_shuffle_bench),
    ("kernels", kernels_bench),
    ("train_step", train_step_bench),
]

#: smoke mode: subset of modules, scaled-down kwargs (must stay seconds).
SMOKE = [
    ("table1", paper_table1_sizes, {"scales": (1 << 14,)}),
    ("table2", paper_table2_tiers, {}),
    (
        "fig6",
        paper_fig6_throughput,
        {"scales": (1 << 16,), "pipeline_scale": 1 << 18, "repeats": 3},
    ),
    (
        "fig7",
        paper_fig7_gateway,
        {
            "invoker_counts": (1, 8),
            "sessions": 12,
            "per_session": 8,
            "latency_sessions": 6,
            "latency_per_session": 10,
            "smoke": True,
        },
    ),
    ("fig7b", paper_fig7b_contention, {"sessions": 64, "total": 2000, "smoke": True}),
    (
        "fig8",
        paper_fig8_tiering,
        {"n_keys": 512, "n_ops": 2000, "hot_keys": 32, "smoke": True},
    ),
    (
        "fig9",
        paper_fig9_iterative,
        {
            "iterations": 5,
            "n_nodes": 300,
            "n_edges": 1800,
            "km_points": 300,
            "ts_records": 120,
            "smoke": True,
        },
    ),
    (
        "fig10",
        paper_fig10_serving,
        {
            "conv_counts": (8, 16),
            "capacity_convs": 15,
            "tokens_per_conv": 2,
            "base_capacity": 3,
            "identity_convs": 3,
            "identity_tokens": 6,
            "resumes": 6,
            "smoke": True,
        },
    ),
    (
        "fig11",
        paper_fig11_cluster,
        {
            "nodes_list": (1, 4),
            "jobs": 12,
            "corpus_bytes": 8 << 10,
            "burst": 64,
            "smoke": True,
        },
    ),
    (
        "fig12",
        paper_fig12_slo,
        {"duration": 4.0, "corpus_bytes": 8 << 10, "smoke": True},
    ),
    ("device_shuffle", device_shuffle_bench, {"n": 1 << 12, "vocab": 512}),
]


def _git_sha() -> str:
    """The commit tag for the emitted JSON.

    ``GITHUB_SHA`` wins in CI.  Locally, a dirty working tree gets a
    stable ``dirty-<sha>`` tag (the numbers are not HEAD's numbers — the
    tag says so instead of silently impersonating the commit); a
    hung/absent git degrades to ``unknown`` rather than failing the run.
    """
    sha = os.environ.get("GITHUB_SHA", "")
    if sha:
        return sha
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True,
            text=True,
            timeout=10,
        ).stdout.strip()
        if sha:
            status = subprocess.run(
                ["git", "status", "--porcelain"],
                capture_output=True,
                text=True,
                timeout=10,
            )
            if status.returncode == 0 and status.stdout.strip():
                sha = f"dirty-{sha}"
    except (OSError, subprocess.SubprocessError):
        # a hung/absent git must not cost us the whole bench run
        sha = ""
    return sha or "unknown"


def _write_json(path: str, smoke: bool, failures: int) -> None:
    payload = {
        "sha": _git_sha(),
        "unix_time": int(time.time()),
        "smoke": smoke,
        "failures": failures,
        "results": common.RESULTS,
    }
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {path} ({len(common.RESULTS)} metrics)", file=sys.stderr)


def main(smoke: bool = False, out: str = "") -> None:
    print("name,us_per_call,derived")
    common.reset_results()
    failures = 0
    plan = SMOKE if smoke else [(n, m, {}) for n, m in MODULES]
    for name, mod, kwargs in plan:
        try:
            mod.main(**kwargs)
        except Exception as e:  # keep the harness going; report at the end
            failures += 1
            print(f"{name},ERROR,{e!r}", file=sys.stderr)
            traceback.print_exc()
    if out:
        _write_json(out, smoke, failures)
    if failures:
        raise SystemExit(f"{failures} benchmark modules failed")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="scaled-down subset for CI")
    ap.add_argument(
        "--out", default="", help="write results as JSON (the CI bench artifact)"
    )
    args = ap.parse_args()
    main(smoke=args.smoke, out=args.out)
