"""Training/serving step throughput on the reduced configs (CPU wall) —
the end-to-end driver cost the paper's Figs. 4/5 correspond to when the
"big-data application" is LM training (DESIGN.md §2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import PipelineConfig, make_batch
from repro.launch.mesh import make_smoke_mesh
from repro.launch.steps import make_train_step
from repro.models import ShapeConfig, init_params, model_defs, reduced_for_smoke
from repro.optim.adamw import AdamWConfig, adamw_init

from benchmarks.common import emit, timeit


def main(archs=("qwen2.5-3b", "mamba2-2.7b", "gemma2-9b")) -> None:
    shape = ShapeConfig(
        name="b",
        kind="train",
        seq_len=128,
        global_batch=8,
        microbatches=1,
        q_chunk=64,
        kv_chunk=64,
        loss_chunk=64,
        remat="none",
    )
    mesh = make_smoke_mesh()
    for arch in archs:
        cfg = reduced_for_smoke(get_config(arch))
        bundle = make_train_step(cfg, shape, mesh, AdamWConfig(lr=1e-3))
        fn = bundle.jitted(mesh)
        params = jax.tree_util.tree_map(
            lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x,
            init_params(model_defs(cfg), jax.random.PRNGKey(0)),
        )
        opt = adamw_init(params)
        pipe = PipelineConfig(
            vocab=cfg.vocab, seq_len=shape.seq_len, global_batch=shape.global_batch
        )
        batch = {k: jnp.asarray(v) for k, v in make_batch(pipe, 0).items()}
        params, opt, _ = fn(params, opt, batch)  # compile + warmup

        def step():
            nonlocal params, opt
            params, opt, m = fn(params, opt, batch)
            jax.block_until_ready(m["loss"])

        t = timeit(step, 3)
        toks = shape.global_batch * shape.seq_len
        emit(f"train_step/{arch}", t * 1e6, f"tok_per_s={toks / t:.0f}")


if __name__ == "__main__":
    main()
