"""Fig. 8 (beyond the paper): static tier assignment vs adaptive hierarchy.

The paper's Fig. 4–5 claim is that *where* function state lives dominates
end-to-end time; its measured configurations are static (all state in one
tier).  This benchmark replays a Zipfian key-value working set — the
access pattern of hot function state + shuffle partitions — against

  * ``static-s3``   — every op pays the modeled S3 device,
  * ``static-pmem`` — every op pays the modeled PMEM device,
  * ``dram``        — everything in DRAM (the unreachable ideal),
  * ``adaptive``    — the `TieredStore` stack DRAM→PMEM→SSD→S3 with
    write-back + frequency-aware promotion: the hot set migrates to
    DRAM, the cold tail drains down.

Reported per config: total modeled+wall device time for the op stream,
fast-tier hit rate, and p50/p99 per-op get latency (modeled device time
attributed per op).  ``--smoke`` asserts the adaptive stack beats
static-s3 outright and stays within a small factor of pure DRAM on the
hot set.
"""

from __future__ import annotations

import time

import numpy as np

from repro.api import ClusterConfig, TierSpec
from repro.storage import PlacementPolicy

from benchmarks.common import emit, make_client


def _percentile(samples, q):
    s = sorted(samples)
    return s[min(len(s) - 1, int(q * len(s)))]


def _workload(n_keys: int, n_ops: int, value_bytes: int, seed: int = 0):
    """Zipfian get/put stream over ``n_keys`` keys (~90% gets)."""
    rng = np.random.default_rng(seed)
    # Zipf(1.2) truncated to the key space: a small hot set, long tail.
    ranks = rng.zipf(1.2, size=4 * n_ops) - 1
    ranks = ranks[ranks < n_keys][:n_ops]
    is_get = rng.random(n_ops) < 0.9
    return ranks, is_get, b"v" * value_bytes


def _cluster_config(config: str, value_bytes: int, hot_keys: int) -> ClusterConfig:
    """The four measured assignments, each one declarative config."""
    if config == "adaptive":
        # Fast levels sized to hold ~the hot set: placement, not
        # provisioning, decides what lives there.
        return ClusterConfig(
            name="fig8",
            tiers=(
                TierSpec("dram", capacity_bytes=2 * hot_keys * value_bytes),
                TierSpec("pmem", capacity_bytes=8 * hot_keys * value_bytes),
                TierSpec("ssd", capacity_bytes=32 * hot_keys * value_bytes),
                TierSpec("s3"),
            ),
            placement=PlacementPolicy(write_back=True, promote_after=2),
        )
    kind = {"static-s3": "s3", "static-pmem": "pmem", "dram": "dram"}[config]
    return ClusterConfig(name="fig8", tiers=(TierSpec(kind),))


def _run_stream(store, ranks, is_get, value):
    """Drive the op stream; returns (total_cost_s, get_latencies_s).

    Per-op cost = wall time + modeled device seconds incurred inline
    (for a TieredStore the logical stats already exclude background
    flush work — exactly the end-to-end time a caller would see).
    """
    latencies = []
    stats = store.stats
    seen = set()
    t0 = time.perf_counter()
    modeled0 = stats.modeled_seconds
    for rank, get in zip(ranks, is_get):
        key = f"k{rank:06d}"
        if get and key in seen:
            m0 = stats.modeled_seconds
            w0 = time.perf_counter()
            store.get(key)
            latencies.append((time.perf_counter() - w0) + (stats.modeled_seconds - m0))
        else:
            store.put(key, value)
            seen.add(key)
    total = (time.perf_counter() - t0) + (stats.modeled_seconds - modeled0)
    return total, latencies


def _hot_set_latency(store, hot_keys: int, value: bytes, repeats: int = 3):
    """Mean per-get cost over the (already warmed) hot set."""
    stats = store.stats
    n = 0
    t0 = time.perf_counter()
    m0 = stats.modeled_seconds
    for _ in range(repeats):
        for rank in range(hot_keys):
            key = f"k{rank:06d}"
            if store.contains(key):
                store.get(key)
                n += 1
    total = (time.perf_counter() - t0) + (stats.modeled_seconds - m0)
    return total / max(1, n)


def main(
    n_keys: int = 2048,
    n_ops: int = 6000,
    value_bytes: int = 4096,
    hot_keys: int = 64,
    smoke: bool = False,
) -> None:
    ranks, is_get, value = _workload(n_keys, n_ops, value_bytes)
    results = {}
    hot_lat = {}
    for config in ("static-s3", "static-pmem", "dram", "adaptive"):
        cfg = _cluster_config(config, value_bytes, hot_keys)
        with make_client(cfg) as client:
            store = client.state
            total, lats = _run_stream(store, ranks, is_get, value)
            hot_lat[config] = _hot_set_latency(store, hot_keys, value)
            results[config] = total
            p50 = _percentile(lats, 0.50) * 1e6
            p99 = _percentile(lats, 0.99) * 1e6
            derived = (
                f"total_s={total:.4f};get_p50_us={p50:.2f};"
                f"get_p99_us={p99:.2f};"
                f"hot_get_us={hot_lat[config] * 1e6:.2f}"
            )
            if config == "adaptive":
                rates = store.hit_rates()
                derived += (
                    f";dram_hit_rate={rates.get('dram', 0.0):.3f}"
                    f";promotions={store.promotions}"
                    f";demotions={store.demotions}"
                )
        emit(f"fig8/{config}", total / n_ops * 1e6, derived)

    speedup_s3 = results["static-s3"] / max(results["adaptive"], 1e-12)
    hot_vs_dram = hot_lat["adaptive"] / max(hot_lat["dram"], 1e-12)
    emit(
        "fig8/summary",
        results["adaptive"] / n_ops * 1e6,
        f"adaptive_over_s3_speedup={speedup_s3:.2f};"
        f"hot_set_vs_dram_factor={hot_vs_dram:.2f}",
    )
    if smoke:
        # acceptance bars: adaptive placement must beat the static-S3
        # assignment outright, and the migrated hot set must serve at
        # near-DRAM cost (generous factor: pure bookkeeping overhead,
        # zero modeled device time).
        assert speedup_s3 > 2.0, f"adaptive only {speedup_s3:.2f}x over static-s3"
        assert hot_vs_dram < 50.0, (
            f"adaptive hot-set get {hot_vs_dram:.1f}x DRAM (want < 50x)"
        )


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="scaled-down run that asserts the acceptance bars",
    )
    args = ap.parse_args()
    if args.smoke:
        main(n_keys=512, n_ops=2000, hot_keys=32, smoke=True)
    else:
        main()
