"""Render the dry-run JSON into the EXPERIMENTS.md §Roofline table.

Usage: PYTHONPATH=src:. python -m benchmarks.roofline_report results/dryrun_baseline.json
"""

from __future__ import annotations

import json
import sys


def fmt_t(seconds: float) -> str:
    if seconds >= 1.0:
        return f"{seconds:.2f}s"
    if seconds >= 1e-3:
        return f"{seconds * 1e3:.1f}ms"
    return f"{seconds * 1e6:.0f}us"


def render(records, mesh_filter="16x16"):
    rows = []
    for r in records:
        if r.get("mesh") != mesh_filter and r["status"] == "ok":
            continue
        if r["status"] == "skipped":
            if mesh_filter == "16x16" and r["mesh"] in ("16x16",):
                rows.append(
                    f"| {r['arch']} | {r['shape']} | — | — | — | — | skipped |"
                    f" — | {r['reason']} |"
                )
            continue
        if r["status"] == "error":
            rows.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | — | ERROR |"
                f" — | {r.get('error', '')[:60]} |"
            )
            continue
        mem = r.get("memory_analysis", {})
        args_gib = mem.get("argument_size_in_bytes", 0) / 2**30
        temp_gib = mem.get("temp_size_in_bytes", 0) / 2**30
        rows.append(
            "| {arch} | {shape} | {tc} | {tm} | {tcl} | {bn} | "
            "{uf:.0f}% | {rf:.0f}% | args {a:.2f}+temp {t:.2f} GiB |".format(
                arch=r["arch"],
                shape=r["shape"],
                tc=fmt_t(r["t_compute"]),
                tm=fmt_t(r["t_memory"]),
                tcl=fmt_t(r["t_collective"]),
                bn=r["bottleneck"],
                uf=100 * (r.get("useful_flops_frac") or 0),
                rf=100 * (r.get("roofline_frac") or 0),
                a=args_gib,
                t=temp_gib,
            )
        )
    header = (
        "| arch | shape | t_compute | t_memory | t_collective | bottleneck "
        "| useful FLOPs | roofline | memory |\n"
        "|---|---|---|---|---|---|---|---|---|"
    )
    return header + "\n" + "\n".join(rows)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_baseline.json"
    with open(path) as f:
        records = json.load(f)
    ok = [r for r in records if r["status"] == "ok"]
    print(f"## Roofline table — single pod (16x16), {len(ok)} compiled cells\n")
    print(render(records, "16x16"))
    multi = [r for r in records if r["status"] == "ok" and r["mesh"] == "2x16x16"]
    if multi:
        print(f"\n## Multi-pod (2x16x16), {len(multi)} compiled cells\n")
        print(render(records, "2x16x16"))


if __name__ == "__main__":
    main()
