"""Paper Fig. 5: Grep completion time vs input size per tier.

Same harness as Fig. 4 with the grep job (selective mappers → much smaller
intermediate data, so tier differences compress — matching the paper's
fig-5-vs-fig-4 contrast).
"""

from __future__ import annotations

from repro.core.mapreduce import grep_job

from benchmarks.paper_fig4_wordcount import run_tiers


def main() -> None:
    run_tiers(
        job_factory=lambda n: grep_job(rb"word00", n),
        tag="fig5/grep",
    )


if __name__ == "__main__":
    main()
