"""Paper Table 1: dataset sizes at each MapReduce phase.

Runs the four workload families at several input scales and reports
input / intermediate / output bytes — validating the shuffle-blowup shape
(join intermediate >> input; aggregation output ≈ 0; wordcount
intermediate > input without a combiner) that motivates keeping the
intermediate tier fast.
"""

from __future__ import annotations

import numpy as np

import repro.core.mapreduce as mr
from repro.api import ClusterConfig

from benchmarks.common import emit_job, make_client, make_corpus


def _rows(scale: int):
    rng = np.random.default_rng(0)
    rows = []
    # wordcount (no combiner, like stock Hadoop mappers)
    base = mr.wordcount_job(4)
    wc = mr.MapReduceJob("wordcount", base.mapper, base.reducer, None, 4)
    rows.append(("wordcount", wc, make_corpus(scale)))
    # scan query (selective filter)
    rows.append(
        ("scan", mr.scan_job(lambda r: r.startswith(b"word00")), make_corpus(scale))
    )
    # aggregation query
    agg_data = b"\n".join(
        f"k{rng.integers(0, 50)},{rng.random():.4f}".encode()
        for _ in range(max(scale // 12, 10))
    )
    rows.append(("aggregation", mr.aggregation_job(4), agg_data))
    # join query
    join_data = b"\n".join(
        f"{'L' if i % 2 else 'R'},k{i % 40},v{i}".encode()
        for i in range(max(scale // 12, 10))
    )
    rows.append(("join", mr.join_job(4), join_data))
    return rows


def main(scales=(1 << 16, 1 << 18)) -> None:
    for scale in scales:
        for name, job, data in _rows(scale):
            cfg = ClusterConfig(name="table1", block_size=max(scale // 8, 4096))
            with make_client(cfg) as client:
                client.store.write("/in", data, record_delim=b"\n")
                handle = client.mapreduce(job, "/in", "/out")
                rep = handle.report
                emit_job(
                    f"table1/{name}/in={rep.field('input_bytes')}",
                    rep,
                    us_per_call=rep.wall_seconds * 1e6,
                    intermediate=rep.field("intermediate_bytes"),
                    out=rep.field("output_bytes"),
                    blowup=round(
                        rep.field("intermediate_bytes")
                        / max(rep.field("input_bytes"), 1),
                        2,
                    ),
                )


if __name__ == "__main__":
    main()
