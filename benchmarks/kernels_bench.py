"""Kernel micro-bench: µs/call in interpret mode (CPU correctness path)
plus the analytic FLOPs each call represents on the TPU target.

Wall numbers here are NOT TPU performance (interpret mode executes the
kernel body in Python); the derived FLOPs column is what the roofline
consumes.  On TPU hardware the same entry points compile via Mosaic.
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.kernels import ops

from benchmarks.common import emit, timeit


def main() -> None:
    rng = np.random.default_rng(0)
    B, T, H, Kv, dh = 1, 512, 4, 2, 64
    q = jnp.asarray(rng.standard_normal((B, T, H, dh)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, T, Kv, dh)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, T, Kv, dh)).astype(np.float32))
    t = timeit(lambda: ops.flash_attention(q, k, v).block_until_ready(), 2)
    flops = 4 * B * H * T * T * dh / 2
    emit("kernel/flash_attention", t * 1e6, f"target_flops={flops:.3g}")

    S = 2048
    qd = jnp.asarray(rng.standard_normal((B, H, dh)).astype(np.float32))
    kc = jnp.asarray(rng.standard_normal((B, S, Kv, dh)).astype(np.float32))
    vc = jnp.asarray(rng.standard_normal((B, S, Kv, dh)).astype(np.float32))
    L = jnp.asarray([S], jnp.int32)
    t = timeit(lambda: ops.decode_attention(qd, kc, vc, L).block_until_ready(), 2)
    emit("kernel/decode_attention", t * 1e6, f"cache_bytes={2 * S * Kv * dh * 4}")

    BC, Q, Hh, P, N = 2, 64, 8, 32, 16
    x = jnp.asarray(rng.standard_normal((BC, Q, Hh, P)).astype(np.float32))
    dt = jnp.asarray(rng.random((BC, Q, Hh)).astype(np.float32))
    dA = jnp.asarray(
        -np.cumsum(rng.random((BC, Q, Hh)).astype(np.float32) * 0.1, axis=1)
    )
    Bm = jnp.asarray(rng.standard_normal((BC, Q, Hh, N)).astype(np.float32))
    Cm = jnp.asarray(rng.standard_normal((BC, Q, Hh, N)).astype(np.float32))
    t = timeit(lambda: ops.ssd_chunk(x, dt, dA, Bm, Cm)[0].block_until_ready(), 2)
    emit(
        "kernel/ssd_chunk",
        t * 1e6,
        f"target_flops={2 * BC * Q * Q * Hh * (N + P):.3g}",
    )

    keys = jnp.asarray(rng.integers(0, 128, 1 << 14).astype(np.int32))
    t = timeit(lambda: ops.shuffle_histogram(keys, 128).block_until_ready(), 2)
    emit("kernel/bucket_histogram", t * 1e6, "n=16384;buckets=128")


if __name__ == "__main__":
    main()
