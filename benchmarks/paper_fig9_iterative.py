"""Fig. 9 (beyond the paper): iterative dataflow — stateful vs cold-reload.

The paper's measured jobs (wordcount, grep) are single-pass: state
residency saves each byte's round-trip exactly once.  Iterative analytics
re-touch the *same* loop-carried state every superstep, which is where
the in-memory/PMEM-resident state argument compounds (Cloudburst, Faasm —
see PAPERS.md).  This benchmark runs the three paper-class iterative /
multi-stage workloads from ``repro.core.workloads`` in two configurations:

  * ``stateful``     — loop state in a write-back ``TieredStore``
    (DRAM fast level over the modeled-S3 home) with the job prefix
    **pinned**; k-means additionally keeps centroids hot in a pinned
    gateway session, so warm invokers skip the tier reload;
  * ``cold-reload``  — the stock-serverless baseline: every superstep
    writes loop state to, and reloads it from, the modeled S3 device
    (no fast level, no pinning, no warm session).

Reported per workload/config: steady-state per-iteration cost (wall +
inline modeled device seconds, iterations >= 2 — past the cold-start
edge), total modeled inline I/O, and byte-identity of the outputs across
configurations.  ``--smoke`` asserts the acceptance bars: steady-state
PageRank iterations at least 3x faster stateful-vs-cold, outputs
byte-identical, k-means warm sessions actually serving centroid reads.
"""

from __future__ import annotations

import numpy as np

from repro.api import ClusterConfig
from repro.core.workloads import kmeans_points, pagerank_graph

from benchmarks.common import emit, emit_job, make_client


def _cluster_config(name: str, config: str) -> ClusterConfig:
    """``stateful``: write-back DRAM front over the modeled S3 home —
    the pinned loop state never pays the home device inline.
    ``cold-reload``: every op pays the modeled S3 device, no journal."""
    if config == "stateful":
        return ClusterConfig(name=name, tiers=("dram", "s3"))
    return ClusterConfig(name=name, tiers=("s3",), journal="none")


def _steady_per_iter(report) -> float:
    """Mean per-superstep cost (wall + inline modeled), iterations >= 2."""
    rows = [r for r in report.per_iteration if r["iteration"] >= 2]
    if not rows:
        return 0.0
    return sum(r["wall_s"] + r["modeled_s"] for r in rows) / len(rows)


def _run_pagerank(
    config: str, iterations: int, n_nodes: int, n_edges: int, n_parts: int
):
    src, dst = pagerank_graph(n_nodes, n_edges, seed=7)
    with make_client(_cluster_config("fig9-pr", config)) as client:
        return client.pagerank(
            f"fig9pr-{config}",
            src,
            dst,
            n_nodes,
            n_parts=n_parts,
            tol=0.0,
            max_iterations=iterations,
            pin_state=(config == "stateful"),
        )


def _run_kmeans(
    config: str, iterations: int, n_points: int, dim: int, k: int, n_parts: int
):
    pts, _ = kmeans_points(n_points, dim, k, seed=11)
    with make_client(_cluster_config("fig9-km", config)) as client:
        return client.kmeans(
            f"fig9km-{config}",
            pts,
            k,
            n_parts=n_parts,
            tol=0.0,
            max_iterations=iterations,
            warm_session=(config == "stateful"),
            pin_state=(config == "stateful"),
        )


def main(
    iterations: int = 6,
    n_nodes: int = 600,
    n_edges: int = 3600,
    n_parts: int = 4,
    km_points: int = 600,
    km_dim: int = 4,
    km_k: int = 5,
    ts_parts: int = 4,
    ts_records: int = 200,
    smoke: bool = False,
) -> None:
    # ---- PageRank: the headline stateful-vs-cold per-iteration gap ----------
    pr = {}
    for config in ("stateful", "cold-reload"):
        handle = _run_pagerank(config, iterations, n_nodes, n_edges, n_parts)
        pr[config] = handle
        steady = _steady_per_iter(handle.raw)
        emit_job(
            f"fig9/pagerank/{config}",
            handle,
            us_per_call=steady * 1e6,
            per_iter_steady_ms=round(steady * 1e3, 3),
            last_iteration=handle.report.field("last_iteration"),
        )
    pr_identical = float(
        pr["stateful"].result.rank_bytes == pr["cold-reload"].result.rank_bytes
    )
    pr_speedup = _steady_per_iter(pr["cold-reload"].raw) / max(
        _steady_per_iter(pr["stateful"].raw), 1e-12
    )

    # ---- k-means: warm gateway session vs cold tier reload ------------------
    km = {}
    for config in ("stateful", "cold-reload"):
        handle = _run_kmeans(config, iterations, km_points, km_dim, km_k, n_parts)
        km[config] = handle
        steady = _steady_per_iter(handle.raw)
        emit_job(
            f"fig9/kmeans/{config}",
            handle,
            us_per_call=steady * 1e6,
            per_iter_steady_ms=round(steady * 1e3, 3),
            warm_read_frac=round(handle.report.field("warm_read_frac"), 3),
        )
    km_identical = float(
        km["stateful"].result.centroid_bytes == km["cold-reload"].result.centroid_bytes
    )
    km_warm_frac = km["stateful"].report.field("warm_read_frac")

    # ---- TeraSort: the 3-stage DAG MapReduce cannot express -----------------
    rng = np.random.default_rng(3)
    parts = [
        b"\n".join(rng.bytes(10).hex().encode() for _ in range(ts_records))
        for _ in range(ts_parts)
    ]
    with make_client(ClusterConfig(name="fig9-ts")) as client:
        ts = client.terasort("fig9ts", parts, n_ranges=n_parts)
        out = ts.result
    ts_sorted = float(out == sorted(r for p in parts for r in p.split(b"\n")))
    emit_job(
        "fig9/terasort",
        ts,
        us_per_call=ts.report.wall_seconds * 1e6 / max(1, ts.report.tasks),
        sorted_ok=int(ts_sorted),
    )

    # ---- summary: the gated acceptance metrics ------------------------------
    cold_modeled_io = pr["cold-reload"].report.field("modeled_io_seconds")
    emit(
        "fig9/summary",
        _steady_per_iter(pr["stateful"].raw) * 1e6,
        f"pagerank_stateful_over_cold={pr_speedup:.2f};"
        f"pagerank_outputs_identical={pr_identical:.0f};"
        f"kmeans_outputs_identical={km_identical:.0f};"
        f"kmeans_warm_read_frac={km_warm_frac:.3f};"
        f"terasort_sorted_ok={ts_sorted:.0f};"
        f"cold_modeled_io_s={cold_modeled_io:.4f}",
    )
    if smoke:
        # Acceptance bars (ISSUE 4): pinned loop state + warm sessions
        # must make steady-state iterations >= 3x faster than the
        # cold-reload configuration, with byte-identical outputs.
        assert pr_speedup >= 3.0, (
            f"stateful PageRank only {pr_speedup:.2f}x over cold-reload"
        )
        assert pr_identical == 1.0, "PageRank outputs diverged"
        assert km_identical == 1.0, "k-means outputs diverged"
        assert km_warm_frac > 0.5, (
            f"warm session served only {km_warm_frac:.0%} of centroid reads"
        )
        assert ts_sorted == 1.0, "TeraSort output not globally sorted"


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="scaled-down run that asserts the acceptance bars",
    )
    args = ap.parse_args()
    if args.smoke:
        main(
            iterations=5,
            n_nodes=300,
            n_edges=1800,
            km_points=300,
            ts_records=120,
            smoke=True,
        )
    else:
        main()
