"""Fig. 12 (beyond the paper): trace-driven SLO harness, fixed vs autoscaled.

Every other figure drives fixed offered load; this one replays a seeded
multi-tenant trace (Poisson arrivals, diurnal envelope, a 4x burst on
the heaviest tenant) against the gateway and asks the question the
paper's elasticity story hangs on: *does the fleet hold its latency SLO
through the burst?*

Four replay cells, one membership row:

* ``fig12/single/fixed`` — one node, one invoker, no controller.  The
  burst must overwhelm it (sheds + queue blowup), so its windowed
  ``p99_under_slo_frac`` is the *negative* control.
* ``fig12/single/auto`` — same trace, same starting fleet, but the
  :class:`~repro.core.autoscale.Autoscaler` pumps on the replay tick
  and may grow to 4 invokers.  TRACKED: it must keep
  ``p99_under_slo_frac >= 0.95`` and beat the fixed cell's goodput.
* ``fig12/cluster/fixed`` / ``fig12/cluster/auto`` — the same contrast
  on a 4-node sharded cluster (per-node gateways, ring-routed
  sessions).
* ``fig12/add_node`` — PR 8's kill-node cell, mirrored: a node *joins*
  mid-WordCount via :meth:`MarvelClient.add_node`; the re-plan loop
  must land the same output bytes as a static 1-node reference
  (TRACKED ``outputs_identical``).

The summary row carries the cross-cell gates (autoscaled vs fixed
goodput, tenant-isolation bound).  ``--nightly`` replays a long diurnal
trace on an elastic cluster (node join/leave under load, scaled by
``STRESS_SCALE``) and ``--series-out`` dumps the per-tenant latency
series for the stress artifact.
"""

from __future__ import annotations

import json
import os
import time

import repro.core.mapreduce as mr
from repro.api import ClusterConfig, unify_report
from repro.core.autoscale import PolicySpec
from repro.core.loadgen import (
    BurstSpec,
    OpSpec,
    TraceSpec,
    generate_trace,
    replay,
)
from repro.core.stateful import StatefulFunction

from benchmarks.common import emit, emit_job, make_client

#: latency SLO the windowed p99 is gated against (ms).
SLO_MS = 150.0
#: windowing for the p99-under-SLO fraction (s of virtual trace time).
WINDOW_S = 0.5
#: stateful service time per invocation (ms) — well under the SLO, so
#: violations come from queueing/shedding, never from service time.
SERVICE_MS = 5.0


def _sleeper() -> StatefulFunction:
    def step(state, ms=SERVICE_MS):
        time.sleep(ms / 1e3)
        return state + 1, state + 1

    return StatefulFunction("sleeper", step, init=lambda: 0, jit=False)


def _trace_spec(duration: float, base_rate: float, burst_at: float) -> TraceSpec:
    """The fig12 workload: 8 Zipf tenants, 16 sessions each, one 4x
    burst on the heaviest tenant, mild diurnal swell underneath."""
    return TraceSpec(
        seed=12,
        duration=duration,
        base_rate=base_rate,
        tenants=8,
        sessions_per_tenant=16,
        zipf_skew=0.8,
        session_skew=0.4,
        amplitude=0.25,
        period=max(12.0, duration * 2),
        bursts=(
            BurstSpec(
                start=burst_at, duration=duration * 0.35, factor=4.0, tenant="t0"
            ),
        ),
        ops=(OpSpec("sleeper", inputs=(("ms", SERVICE_MS),)),),
    )


def _replay_cell(name, cfg, tspec, auto_spec=None, series=None):
    """Run one replay cell; returns (ReplayResult, Autoscaler | None)."""
    with make_client(cfg) as client:
        client.register(_sleeper())
        auto = client.autoscaler(auto_spec) if auto_spec is not None else None
        result = replay(
            client.submit,
            generate_trace(tspec),
            spec=tspec,
            slo_ms=SLO_MS,
            window_s=WINDOW_S,
            tick=auto.maybe_tick if auto is not None else None,
        )
    iso = result.isolation()
    iso_ratio = iso.ratio if iso.calm_p99_ms > 0 else 1.0
    fields = {
        "p99_under_slo_frac": round(result.p99_under_slo_frac(), 4),
        "goodput_frac": round(result.goodput_frac(), 4),
        "isolation_ratio": round(min(iso_ratio, 99.0), 4),
        "offered": result.offered,
        "completed": result.completed,
        "shed": result.shed,
        "backpressured": result.backpressured,
        "slo_ms": SLO_MS,
        "scale_actions": auto.scale_actions if auto is not None else 0,
        "peak_invokers": auto.peak_invokers if auto is not None else cfg.invokers,
        "peak_nodes": (
            auto.peak_nodes
            if auto is not None
            else (cfg.nodes if cfg.sharded else 1)
        ),
    }
    derived = ";".join(f"{k}={v:.6g}" for k, v in fields.items())
    emit(name, result.p99_ms() * 1e3, derived)
    if series is not None:
        series[name] = result.series_dict()
    return result, auto


def _auto_spec(max_invokers: int, warm_pool: int) -> PolicySpec:
    return PolicySpec(
        min_invokers=1,
        max_invokers=max_invokers,
        target_per_invoker=4,
        down_cooldown_s=0.5,
        warm_pool_per_invoker=warm_pool,
    )


def _single_cfg(name: str) -> ClusterConfig:
    return ClusterConfig(
        name=name,
        invokers=1,
        warm_pool=128,
        target_inflight=256,
        journal="none",
    )


def _cluster_cfg(name: str, nodes: int) -> ClusterConfig:
    return ClusterConfig(
        name=name,
        nodes=nodes,
        sharded=True,
        replication=1,
        invokers=1,
        warm_pool=128,
        target_inflight=256,
        journal="none",
    )


# -- membership row: add a node mid-job, outputs must not drift ------------

_N_RED = 12


def _read_parts(client, out_path: str, n: int) -> bytes:
    return b"".join(client.store.read(f"{out_path}/part_{p:04d}") for p in range(n))


def _corpus(n_bytes: int) -> bytes:
    out, size, i = [], 0, 0
    while size < n_bytes:
        line = b" ".join(
            b"%cword%d" % (97 + (i + j) % 26, (i + j) % 97) for j in range(10)
        )
        out.append(line)
        size += len(line) + 1
        i += 10
    return b"\n".join(out)


def _add_node_row(corpus_bytes: int) -> int:
    data = _corpus(corpus_bytes)
    block = max(corpus_bytes // 8, 1 << 10)  # ~8 map tasks
    job = mr.wordcount_job(_N_RED)
    with make_client(
        ClusterConfig(
            name="fig12ref", nodes=1, sharded=True, replication=1, block_size=block
        )
    ) as ref:
        ref.store.write("/in", data, record_delim=b"\n")
        ref.cluster.run_mapreduce(job, "/in", "/out")
        expect = _read_parts(ref, "/out", _N_RED)

    with make_client(
        ClusterConfig(
            name="fig12grow", nodes=3, sharded=True, replication=1, block_size=block
        )
    ) as client:
        client.store.write("/in", data, record_delim=b"\n")
        joined = []

        def on_map_done(count: int) -> None:
            if count == 2 and not joined:
                joined.append(client.add_node())

        raw = client.cluster.run_mapreduce(
            job, "/in", "/out", on_map_done=on_map_done
        )
        identical = int(_read_parts(client, "/out", _N_RED) == expect)
        migrated = client.cluster.migrations["sessions"]
        emit_job(
            "fig12/add_node",
            unify_report(raw, tiers=client.tier_rollup()),
            outputs_identical=identical,
            joined_node=joined[0] if joined else "none",
            sessions_migrated=migrated,
            nodes=len(client.cluster.live_nodes()),
        )
    return identical


# -- nightly: long elastic replay with node churn --------------------------


def _nightly(series_out=None) -> None:
    scale = max(1, int(os.environ.get("STRESS_SCALE", "1")))
    duration = 6.0 * scale
    # Tuned so both node actuators actually engage: the 6x burst on the
    # head tenant saturates every gateway at max_invokers=2 (the node-up
    # trigger), and the deep diurnal trough (amplitude 0.9) leaves joined
    # nodes idle long enough to cross node_down_patience.
    tspec = TraceSpec(
        seed=12,
        duration=duration,
        base_rate=480.0,
        tenants=8,
        sessions_per_tenant=16,
        zipf_skew=0.8,
        session_skew=0.4,
        amplitude=0.9,
        period=duration / 2,
        bursts=(
            BurstSpec(duration * 0.2, duration * 0.15, 6.0, "t0"),
            BurstSpec(duration * 0.6, duration * 0.15, 4.0, "t1"),
        ),
        ops=(OpSpec("sleeper", inputs=(("ms", SERVICE_MS),)),),
    )
    spec = PolicySpec(
        min_invokers=1,
        max_invokers=2,
        target_per_invoker=4,
        down_cooldown_s=0.5,
        warm_pool_per_invoker=128,
        min_nodes=2,
        max_nodes=4,
        node_up_patience=3,
        node_down_patience=10,
    )
    series = {}
    result, auto = _replay_cell(
        "fig12/nightly/elastic",
        _cluster_cfg("fig12night", nodes=2),
        tspec,
        auto_spec=spec,
        series=series,
    )
    churn = [a for a in auto.actions if a["kind"].endswith("_node")]
    emit(
        "fig12/nightly/summary",
        0.0,
        f"node_actions={len(churn)}"
        f";peak_nodes={auto.peak_nodes}"
        f";errors={result.errors}",
    )
    if series_out:
        payload = series["fig12/nightly/elastic"]
        payload["actions"] = auto.actions
        with open(series_out, "w") as fh:
            json.dump(payload, fh)
        print(f"# per-tenant series -> {series_out}")
    assert result.errors == 0, f"{result.errors} invocations errored"
    adds = [a for a in churn if a["kind"] == "add_node"]
    assert adds, "burst never drove a node join — the churn cell is inert"


# -- main ------------------------------------------------------------------


def main(duration=6.0, corpus_bytes=16 << 10, smoke=False, series_out=None):
    series = {} if series_out else None

    single = _trace_spec(duration, base_rate=120.0, burst_at=duration * 0.3)
    fixed_1, _ = _replay_cell(
        "fig12/single/fixed", _single_cfg("fig12f1"), single, series=series
    )
    auto_1, ctl_1 = _replay_cell(
        "fig12/single/auto",
        _single_cfg("fig12a1"),
        single,
        auto_spec=_auto_spec(max_invokers=4, warm_pool=128),
        series=series,
    )

    cluster = _trace_spec(duration, base_rate=480.0, burst_at=duration * 0.3)
    fixed_4, _ = _replay_cell(
        "fig12/cluster/fixed", _cluster_cfg("fig12f4", 4), cluster, series=series
    )
    auto_4, ctl_4 = _replay_cell(
        "fig12/cluster/auto",
        _cluster_cfg("fig12a4", 4),
        cluster,
        auto_spec=_auto_spec(max_invokers=4, warm_pool=128),
        series=series,
    )

    identical = _add_node_row(corpus_bytes)

    iso = auto_1.isolation()
    emit(
        "fig12/summary",
        0.0,
        f"outputs_identical={identical}"
        f";single_fixed_slo={fixed_1.p99_under_slo_frac():.4g}"
        f";single_auto_slo={auto_1.p99_under_slo_frac():.4g}"
        f";cluster_fixed_slo={fixed_4.p99_under_slo_frac():.4g}"
        f";cluster_auto_slo={auto_4.p99_under_slo_frac():.4g}"
        f";auto_goodput={auto_1.goodput_frac():.4g}"
        f";fixed_goodput={fixed_1.goodput_frac():.4g}",
    )
    if series_out:
        with open(series_out, "w") as fh:
            json.dump(series, fh)
        print(f"# per-tenant series -> {series_out}")
    if smoke:
        assert auto_1.p99_under_slo_frac() >= 0.95, (
            f"single/auto p99_under_slo_frac {auto_1.p99_under_slo_frac():.3f}"
        )
        assert fixed_1.p99_under_slo_frac() < 0.95, (
            "fixed fleet unexpectedly held the SLO — burst too weak to gate on"
        )
        assert auto_4.p99_under_slo_frac() >= 0.95, (
            f"cluster/auto p99_under_slo_frac {auto_4.p99_under_slo_frac():.3f}"
        )
        assert auto_1.goodput_frac() >= fixed_1.goodput_frac(), "autoscaled goodput"
        assert auto_4.goodput_frac() >= fixed_4.goodput_frac(), "autoscaled goodput"
        assert ctl_1.scale_actions >= 1, "autoscaler never acted"
        assert identical == 1, "add-node-mid-job output drifted"
        assert iso.burst_p99_ms <= max(3.0 * iso.calm_p99_ms, SLO_MS), (
            f"t0 burst moved other tenants' p99: {iso.burst_p99_ms:.1f}ms "
            f"(calm {iso.calm_p99_ms:.1f}ms)"
        )


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="scaled-down run with the CI gate assertions",
    )
    ap.add_argument(
        "--nightly",
        action="store_true",
        help="long elastic-cluster replay (node churn; honors STRESS_SCALE)",
    )
    ap.add_argument(
        "--series-out",
        default=None,
        help="write the per-tenant latency series as JSON",
    )
    args = ap.parse_args()
    if args.nightly:
        _nightly(series_out=args.series_out)
    elif args.smoke:
        main(
            duration=4.0,
            corpus_bytes=8 << 10,
            smoke=True,
            series_out=args.series_out,
        )
    else:
        main(series_out=args.series_out)
