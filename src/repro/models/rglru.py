"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Linear recurrence with input + recurrence gates:

    r_t = sigmoid(x_t @ W_a)          (recurrence gate)
    i_t = sigmoid(x_t @ W_x)          (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill uses ``jax.lax.associative_scan`` over T (parallel prefix,
log-depth), decode is the O(1) update.  The conv1d front and gated-GeLU
output mirror Griffin's recurrent block.
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.ctx import constrain
from repro.models.param import FSDP, TP, ParamDef

__all__ = ["rglru_defs", "rglru_apply", "rglru_decode", "init_rglru_cache", "RGLRUCache"]


def _width(cfg: ModelConfig) -> int:
    return cfg.rglru.lru_width or cfg.d_model


def rglru_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    D = cfg.d_model
    W = _width(cfg)
    K = cfg.rglru.d_conv
    return {
        "wx_in": ParamDef((D, W), (FSDP, TP)),  # x branch
        "wg_in": ParamDef((D, W), (FSDP, TP)),  # gelu gate branch
        "conv_w": ParamDef((K, W), (None, TP)),
        "conv_b": ParamDef((W,), (TP,), init_scale=0.0),
        "wa": ParamDef((W, W), (FSDP, TP)),  # recurrence gate
        "wi": ParamDef((W, W), (FSDP, TP)),  # input gate
        "lam": ParamDef((W,), (TP,), dtype=jnp.float32, init_value=0.7),
        "wo": ParamDef((W, D), (TP, FSDP)),
    }


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    K = w.shape[0]
    up = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros(u.shape, jnp.float32)
    for i in range(K):
        out = out + up[:, i : i + u.shape[1]].astype(jnp.float32) * w[i].astype(
            jnp.float32
        )
    return (out + b.astype(jnp.float32)).astype(u.dtype)


def _gates(p, xb):
    """a_t (fp32), gated input (fp32). xb: (B, T, W) post-conv."""
    r = jax.nn.sigmoid((xb @ p["wa"]).astype(jnp.float32))
    i = jax.nn.sigmoid((xb @ p["wi"]).astype(jnp.float32))
    log_a = -cfg_c(p) * jax.nn.softplus(p["lam"]) * r  # (B, T, W)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (
        i * xb.astype(jnp.float32)
    )
    return a, gated


def cfg_c(p) -> float:
    return 8.0  # sharpening constant c (Griffin)


class RGLRUCache(NamedTuple):
    conv: jax.Array  # (B, K-1, W)
    h: jax.Array  # (B, W) fp32


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype) -> RGLRUCache:
    W = _width(cfg)
    return RGLRUCache(
        conv=jnp.zeros((batch, cfg.rglru.d_conv - 1, W), dtype),
        h=jnp.zeros((batch, W), jnp.float32),
    )


def rglru_apply(p: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig,
                collect_cache: bool = False, ctx=None):
    """Full-sequence RG-LRU via associative scan. x: (B, T, D)."""
    xb_pre = x @ p["wx_in"]
    gate = x @ p["wg_in"]
    xb = _causal_conv(xb_pre, p["conv_w"], p["conv_b"])
    xb = constrain(xb, ctx, "b", None, "tp")
    a, gated = _gates(p, xb)

    # h_t = a_t h_{t-1} + gated_t  — associative scan on (a, b) pairs.
    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, a_r * b_l + b_r

    a_s, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    y = (h * jax.nn.gelu(gate.astype(jnp.float32))).astype(x.dtype)
    out = y @ p["wo"]
    if not collect_cache:
        return out
    K = cfg.rglru.d_conv
    return out, RGLRUCache(conv=xb_pre[:, x.shape[1] - (K - 1):], h=h[:, -1])


def rglru_decode(
    p: Dict[str, jax.Array],
    x: jax.Array,  # (B, 1, D)
    cache: RGLRUCache,
    cfg: ModelConfig,
    ctx=None,
) -> Tuple[jax.Array, RGLRUCache]:
    xb = x @ p["wx_in"]  # (B, 1, W)
    gate = x @ p["wg_in"]
    hist = jnp.concatenate([cache.conv, xb], axis=1)  # (B, K, W)
    w = p["conv_w"]
    conv = jnp.einsum(
        "bkc,kc->bc", hist.astype(jnp.float32), w.astype(jnp.float32)
    ) + p["conv_b"].astype(jnp.float32)
    xb1 = conv[:, None, :].astype(x.dtype)  # (B, 1, W)
    a, gated = _gates(p, xb1)
    h = a[:, 0] * cache.h + gated[:, 0]  # (B, W)
    h = constrain(h, ctx, "b", "tp")
    y = (h[:, None, :] * jax.nn.gelu(gate.astype(jnp.float32))).astype(x.dtype)
    return y @ p["wo"], RGLRUCache(conv=hist[:, 1:], h=h)
