"""Model configuration schema shared by all 10 assigned architectures.

A model is: frontend (tokens / frames / tokens+patches) → ``prelude`` blocks
(unstacked) → ``n_periods × pattern`` blocks (stacked + scanned) →
``postlude`` blocks (unstacked) → final norm → unembed.

Heterogeneous stacks (gemma2's local/global alternation, recurrentgemma's
recurrent-recurrent-local pattern, deepseek's first-dense-then-MoE) are
expressed by the pattern machinery so scan-over-layers keeps the HLO small
for the 512-device dry-run compiles.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional, Tuple

__all__ = [
    "BlockSpec",
    "MoEConfig",
    "MLAConfig",
    "SSMConfig",
    "RGLRUConfig",
    "ModelConfig",
    "ShapeConfig",
]


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    #: router softmax over all experts (deepseek) vs over top-k (dbrx-style)
    normalize_top_k: bool = True
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    q_lora_rank: Optional[int] = None  # v2-lite projects q directly


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256
    n_groups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0  # 0 -> d_model
    d_conv: int = 4
    c: float = 8.0  # recurrence sharpening exponent


@dataclass(frozen=True)
class BlockSpec:
    """One transformer block: a sequence mixer + an FFN."""

    mixer: str = "attn"  # attn | local | mla | ssm | rglru
    ffn: str = "dense"  # dense | moe | none (ssm blocks have no ffn)
    window: Optional[int] = None  # for mixer == "local"


@dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    # block structure
    prelude: Tuple[BlockSpec, ...] = ()
    pattern: Tuple[BlockSpec, ...] = (BlockSpec(),)
    n_periods: int = 1
    postlude: Tuple[BlockSpec, ...] = ()
    # flavor knobs
    act: str = "silu"
    norm: str = "rms"  # rms | ln
    rms_plus_one: bool = False
    embed_scale: bool = False  # gemma: embeddings * sqrt(d_model)
    qkv_bias: bool = False
    rope_theta: float = 1e4
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    post_block_norm: bool = False  # gemma2 pre+post norm sandwich
    causal: bool = True  # False = encoder (hubert)
    query_scale: Optional[float] = None  # override 1/sqrt(head_dim)
    #: pad attention heads up to the TP degree with dead (masked) heads so
    #: q/k/v shard on heads instead of head_dim — kills the per-chunk score
    #: all-reduces for H % 16 != 0 archs (see EXPERIMENTS.md §Perf)
    pad_heads: bool = False
    # sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # frontend
    frontend: str = "tokens"  # tokens | frames | tokens+patches
    n_patches: int = 0  # for tokens+patches
    frame_dim: int = 0  # for frames (0 -> d_model)
    # numerics
    dtype: str = "bfloat16"

    @property
    def n_layers(self) -> int:
        return (
            len(self.prelude)
            + self.n_periods * len(self.pattern)
            + len(self.postlude)
        )

    def all_blocks(self) -> Tuple[BlockSpec, ...]:
        return self.prelude + self.pattern * self.n_periods + self.postlude

    def approx_params(self) -> int:
        """Rough parameter count (for roofline MODEL_FLOPS = 6·N·D)."""
        n = self.vocab * self.d_model * 2  # embed + unembed
        for blk in self.all_blocks():
            n += self._block_params(blk)
        return n

    def active_params(self) -> int:
        """Active (per-token) params — MoE counts only routed top-k."""
        n = self.vocab * self.d_model * 2
        for blk in self.all_blocks():
            n += self._block_params(blk, active_only=True)
        return n

    def _block_params(self, blk: BlockSpec, active_only: bool = False) -> int:
        d = self.d_model
        n = 0
        if blk.mixer in ("attn", "local"):
            n += d * self.n_heads * self.head_dim  # q
            n += 2 * d * self.n_kv_heads * self.head_dim  # k, v
            n += self.n_heads * self.head_dim * d  # o
        elif blk.mixer == "mla":
            m = self.mla
            n += d * self.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
            n += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            n += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
            n += self.n_heads * m.v_head_dim * d
        elif blk.mixer == "ssm":
            s = self.ssm
            di = s.d_inner(d)
            n += d * (2 * di + 2 * s.n_groups * s.d_state + s.n_heads(d))
            n += di * d
        elif blk.mixer == "rglru":
            w = self.rglru.lru_width or d
            n += 2 * d * w + 2 * w * w + w * d
        if blk.ffn == "dense":
            mult = 3 if self.act in ("silu", "gelu") else 2
            n += mult * d * self.d_ff
        elif blk.ffn == "moe":
            mcfg = self.moe
            e = mcfg.top_k if active_only else mcfg.n_experts
            n += 3 * e * d * mcfg.d_expert
            n += 3 * mcfg.n_shared * d * mcfg.d_expert
            n += d * mcfg.n_experts  # router
        return n


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell + the memory knobs tuned per cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int
    #: gradient-accumulation microbatches (train only)
    microbatches: int = 1
    #: chunk sizes for the streaming attention / CE loss
    q_chunk: int = 512
    kv_chunk: int = 1024
    loss_chunk: int = 512
    #: remat policy for the scanned blocks: "full" | "dots" | "none"
    remat: str = "full"


def reduced_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    kw = dict(
        d_model=64,
        n_heads=4,
        n_kv_heads=min(4, max(1, cfg.n_kv_heads)),
        head_dim=16,
        d_ff=128,
        vocab=256,
        n_periods=min(cfg.n_periods, 2),
        prelude=cfg.prelude[:1],
        postlude=cfg.postlude[:1],
        n_patches=min(cfg.n_patches, 4),
        frame_dim=64 if cfg.frame_dim else 0,
    )
    if cfg.moe is not None:
        kw["moe"] = replace(
            cfg.moe, n_experts=8, top_k=2, d_expert=32,
            n_shared=min(cfg.moe.n_shared, 1),
        )
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(
            kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8,
            v_head_dim=16,
        )
    if cfg.ssm is not None:
        kw["ssm"] = replace(cfg.ssm, d_state=16, head_dim=16, chunk=16)
    if cfg.rglru is not None:
        kw["rglru"] = replace(cfg.rglru, lru_width=64)
    return replace(cfg, **kw)
