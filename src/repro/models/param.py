"""Parameter definition trees: one source of truth for shape + sharding.

A model is described by a pytree of :class:`ParamDef` (shape, PartitionSpec,
init scale).  From it we derive:

  * ``init_params``  — materialized arrays (smoke tests, examples),
  * ``abstract_params`` — ``ShapeDtypeStruct`` tree (dry-run, no allocation),
  * ``param_specs`` — the PartitionSpec tree handed to pjit.

Sharding axis conventions (see DESIGN.md §4): ``tp`` is the tensor-parallel
mesh axis name ('model'), ``fsdp`` the fully-sharded-data-parallel axis
('data').  Specs here are written with the *logical* names "tp"/"fsdp" and
resolved against a concrete mesh at lowering time, so the same model def
serves the 1-device smoke mesh, the 16×16 pod, and the 2×16×16 multi-pod.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = [
    "ParamDef",
    "init_params",
    "abstract_params",
    "param_specs",
    "resolve_spec",
    "stack_defs",
]

#: logical axis names used in ParamDef specs
TP = "tp"
FSDP = "fsdp"


@dataclass(frozen=True)
class ParamDef:
    """Declarative parameter: shape + logical sharding + init."""

    shape: Tuple[int, ...]
    #: logical spec: tuple with entries in {"tp", "fsdp", None, ("tp","fsdp"), ...}
    spec: Tuple[Any, ...] = ()
    dtype: Any = jnp.bfloat16
    #: stddev of truncated-normal init; 0.0 -> zeros; None -> fan-in default
    init_scale: Optional[float] = None
    #: constant initialization value (overrides init_scale)
    init_value: Optional[float] = None

    def fan_in_scale(self) -> float:
        fan_in = self.shape[-2] if len(self.shape) >= 2 else max(self.shape[-1], 1)
        return 1.0 / math.sqrt(fan_in)


def _is_def(x: Any) -> bool:
    return isinstance(x, ParamDef)


def tree_map_defs(fn: Callable[[ParamDef], Any], tree: Any) -> Any:
    return jax.tree_util.tree_map(fn, tree, is_leaf=_is_def)


def init_params(defs: Any, key: jax.Array) -> Any:
    """Materialize arrays from a ParamDef tree (deterministic per-leaf keys)."""
    leaves, treedef = jax.tree_util.tree_flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(key, max(len(leaves), 1))
    out = []
    for i, pd in enumerate(leaves):
        if pd.init_value is not None:
            arr = jnp.full(pd.shape, pd.init_value, dtype=pd.dtype)
        elif pd.init_scale == 0.0:
            arr = jnp.zeros(pd.shape, dtype=pd.dtype)
        else:
            scale = pd.init_scale if pd.init_scale is not None else pd.fan_in_scale()
            arr = (
                jax.random.truncated_normal(keys[i], -2.0, 2.0, pd.shape, jnp.float32)
                * scale
            ).astype(pd.dtype)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_params(defs: Any) -> Any:
    """ShapeDtypeStruct tree — for .lower() without allocating anything."""
    return tree_map_defs(
        lambda pd: jax.ShapeDtypeStruct(pd.shape, pd.dtype), defs
    )


def resolve_spec(
    logical: Tuple[Any, ...],
    tp_axis: Optional[str],
    fsdp_axis: Optional[Any],
) -> P:
    """Map a logical spec to a mesh PartitionSpec.

    ``fsdp_axis`` may be a string, a tuple of axes, or None (replicate).
    """

    def resolve_entry(e):
        if e is None:
            return None
        if isinstance(e, tuple):
            parts: list = []
            for sub in e:
                r = resolve_entry(sub)
                if r is None:
                    continue
                if isinstance(r, tuple):
                    parts.extend(r)
                else:
                    parts.append(r)
            return tuple(parts) if parts else None
        if e == TP:
            return tp_axis
        if e == FSDP:
            return fsdp_axis
        raise ValueError(f"unknown logical axis {e!r}")

    return P(*(resolve_entry(e) for e in logical))


def param_specs(
    defs: Any,
    tp_axis: Optional[str] = "model",
    fsdp_axis: Optional[Any] = "data",
    axis_sizes: Optional[Dict[str, int]] = None,
) -> Any:
    """PartitionSpec tree resolved against concrete mesh axis names.

    With ``axis_sizes`` (mesh axis -> size), any entry whose dim does not
    divide the axis product is dropped to replication (e.g. hubert's
    504-entry vocab vs TP=16)."""

    def entry_size(e) -> int:
        if e is None or axis_sizes is None:
            return 1
        if isinstance(e, tuple):
            n = 1
            for sub in e:
                n *= entry_size(sub)
            return n
        return axis_sizes.get(e, 1)

    def per_leaf(pd: ParamDef) -> P:
        spec = resolve_spec(pd.spec, tp_axis, fsdp_axis)
        if axis_sizes is None:
            return spec
        entries = list(spec) + [None] * (len(pd.shape) - len(spec))
        fixed = [
            e if e is None or dim % entry_size(e) == 0 else None
            for dim, e in zip(pd.shape, entries)
        ]
        return P(*fixed)

    return tree_map_defs(per_leaf, defs)


def stack_defs(defs: Any, n: int) -> Any:
    """Prepend a stacked-layers dim of size ``n`` (for scan-over-layers).

    The stacked dim is never sharded (it's the scan axis).
    """
    return tree_map_defs(
        lambda pd: ParamDef(
            shape=(n,) + pd.shape,
            spec=(None,) + tuple(pd.spec),
            dtype=pd.dtype,
            init_scale=pd.init_scale,
            init_value=pd.init_value,
        ),
        defs,
    )
