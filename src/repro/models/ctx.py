"""ShardCtx: mesh context threaded through model layers, plus the
``constrain`` helper that pins intermediate activations to the intended
layout.

GSPMD's propagation gives up inside scan bodies when an einsum mixes
sharded and replicated operands (measured: attention silently replicating
all heads on every device).  One ``with_sharding_constraint`` per mixer
keeps the solver honest.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ShardCtx", "constrain"]


@dataclass(frozen=True)
class ShardCtx:
    """Mesh context threaded to layers that use explicit collectives or
    sharding constraints."""

    mesh: Optional[Mesh] = None
    dp_axes: Tuple[str, ...] = ("data",)
    tp_axis: str = "model"
    #: weights arrive pre-gathered (TP-only layout) — ZeRO-1 step layout;
    #: MoE then skips its in-shard_map FSDP gathers
    zero1: bool = False

    def tp_size(self) -> int:
        if self.mesh is None or self.tp_axis not in self.mesh.axis_names:
            return 1
        return self.mesh.shape[self.tp_axis]

    def dp_size(self) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in self.dp_axes:
            if a in self.mesh.axis_names:
                n *= self.mesh.shape[a]
        return n


def constrain(x: jax.Array, ctx: Optional[ShardCtx], *entries) -> jax.Array:
    """Pin ``x`` to a layout given per-dim entries:

      'b'  -> the data axes if the dim divides, else replicated
      'tp' -> the TP axis if the dim divides, else replicated
      None -> replicated

    No-op without a mesh (smoke tests, single device).
    """
    if ctx is None or ctx.mesh is None:
        return x
    mesh = ctx.mesh
    spec = []
    for dim, e in zip(x.shape, entries):
        if e == "b":
            if ctx.dp_size() > 1 and dim % ctx.dp_size() == 0:
                spec.append(
                    ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]
                )
            else:
                spec.append(None)
        elif e == "tp":
            if ctx.tp_size() > 1 and dim % ctx.tp_size() == 0:
                spec.append(ctx.tp_axis)
            else:
                spec.append(None)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec))
    )
