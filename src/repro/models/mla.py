"""Multi-head Latent Attention (DeepSeek-V2) — compressed-KV attention.

Prefill/train uses the *expanded* form (decompress c_kv to per-head K/V and
run standard attention).  Decode uses the *absorbed* form: queries are
projected into the compressed space so attention runs directly over the
(kv_lora_rank + rope_dim)-wide cache — the cache is ~(H·dh / r)× smaller
than GQA, which is the technique's serving payoff and makes the 32k decode
cell cheap.
"""

from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.ctx import ShardCtx, constrain
from repro.models.layers import apply_rope, chunked_attention, rms_norm
from repro.models.param import FSDP, TP, ParamDef

__all__ = ["mla_defs", "mla_apply", "mla_decode", "init_mla_cache", "MLACache"]


def mla_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    m = cfg.mla
    D, H = cfg.d_model, cfg.n_heads
    dq = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq": ParamDef((D, H, dq), (FSDP, TP, None)),
        "wkv_a": ParamDef((D, m.kv_lora_rank + m.qk_rope_head_dim), (FSDP, None)),
        "kv_norm": ParamDef((m.kv_lora_rank,), (None,), init_value=1.0),
        "wk_b": ParamDef((m.kv_lora_rank, H, m.qk_nope_head_dim), (None, TP, None)),
        "wv_b": ParamDef((m.kv_lora_rank, H, m.v_head_dim), (None, TP, None)),
        "wo": ParamDef((H, m.v_head_dim, D), (TP, None, FSDP)),
    }


def _scale(cfg: ModelConfig) -> float:
    m = cfg.mla
    return 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)


def mla_apply(
    p: Dict[str, jax.Array],
    x: jax.Array,  # (B, T, D)
    cfg: ModelConfig,
    *,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    collect_cache: bool = False,
    cache_len: Optional[int] = None,
    ctx: Optional[ShardCtx] = None,
):
    """Expanded-form MLA for training/prefill.

    With ``collect_cache`` also returns the compressed (c_kv, k_pe) cache
    consumed by the absorbed-form decode."""
    m = cfg.mla
    B, T, D = x.shape
    H = cfg.n_heads
    pos = jnp.arange(T)[None, :]
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    q_nope, q_pe = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim :]
    q_pe = apply_rope(q_pe, pos, cfg.rope_theta)

    kv_a = x @ p["wkv_a"]  # (B, T, r + dr)
    c_kv = rms_norm(kv_a[..., : m.kv_lora_rank], p["kv_norm"])
    k_pe = kv_a[..., m.kv_lora_rank :][:, :, None, :]  # (B, T, 1, dr)
    k_pe = apply_rope(k_pe, pos, cfg.rope_theta)

    k_nope = jnp.einsum("btr,rhk->bthk", c_kv, p["wk_b"])
    v = jnp.einsum("btr,rhv->bthv", c_kv, p["wv_b"])
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe, (B, T, H, m.qk_rope_head_dim))], axis=-1
    )
    q_full = jnp.concatenate([q_nope, q_pe], axis=-1)
    ent = ("b", None, "tp", None)
    q_full = constrain(q_full, ctx, *ent)
    k = constrain(k, ctx, *ent)
    v = constrain(v, ctx, *ent)
    o = chunked_attention(
        q_full, k, v,
        causal=cfg.causal,
        scale=_scale(cfg),
        q_chunk=q_chunk,
        kv_chunk=kv_chunk,
    )
    o = constrain(o, ctx, *ent)
    out = jnp.einsum("bthv,hvd->btd", o, p["wo"])
    if not collect_cache:
        return out
    L = cache_len or T
    pad = L - T
    ck = jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0))) if pad else c_kv
    kp3 = k_pe[:, :, 0, :]
    kp = jnp.pad(kp3, ((0, 0), (0, pad), (0, 0))) if pad else kp3
    return out, MLACache(c_kv=ck, k_pe=kp)


class MLACache(NamedTuple):
    c_kv: jax.Array  # (B, S, r) compressed latents (normed)
    k_pe: jax.Array  # (B, S, dr) roped shared key


def init_mla_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype) -> MLACache:
    m = cfg.mla
    return MLACache(
        c_kv=jnp.zeros((batch, seq_len, m.kv_lora_rank), dtype),
        k_pe=jnp.zeros((batch, seq_len, m.qk_rope_head_dim), dtype),
    )


def mla_decode(
    p: Dict[str, jax.Array],
    x: jax.Array,  # (B, 1, D)
    cache: MLACache,
    t: jax.Array,  # scalar position
    cfg: ModelConfig,
    ctx: Optional[ShardCtx] = None,
) -> Tuple[jax.Array, MLACache]:
    """Absorbed-form decode: attention in the compressed space."""
    m = cfg.mla
    B = x.shape[0]
    pos = jnp.full((B, 1), t, jnp.int32)
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])[:, 0]  # (B, H, dq)
    q_nope, q_pe = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim :]
    q_pe = apply_rope(q_pe[:, None], pos, cfg.rope_theta)[:, 0]

    kv_a = (x @ p["wkv_a"])  # (B, 1, r + dr)
    c_kv_new = rms_norm(kv_a[..., : m.kv_lora_rank], p["kv_norm"])
    k_pe_new = apply_rope(
        kv_a[..., m.kv_lora_rank :][:, :, None, :], pos, cfg.rope_theta
    )[:, :, 0]
    c_kv = jax.lax.dynamic_update_slice_in_dim(cache.c_kv, c_kv_new, t, axis=1)
    k_pe = jax.lax.dynamic_update_slice_in_dim(cache.k_pe, k_pe_new, t, axis=1)
    c_kv = constrain(c_kv, ctx, "b", "tp", None)
    k_pe = constrain(k_pe, ctx, "b", "tp", None)

    # Absorb: q_c = q_nope @ wk_b  -> (B, H, r); scores over compressed cache.
    q_c = jnp.einsum("bhk,rhk->bhr", q_nope, p["wk_b"])
    s = (
        jnp.einsum("bhr,bsr->bhs", q_c.astype(jnp.float32),
                   c_kv.astype(jnp.float32))
        + jnp.einsum("bhk,bsk->bhs", q_pe.astype(jnp.float32),
                     k_pe.astype(jnp.float32))
    ) * _scale(cfg)
    valid = jnp.arange(c_kv.shape[1])[None, :] <= t
    s = jnp.where(valid[:, None, :], s, -1e30)
    attn = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", attn, c_kv.astype(jnp.float32))
    o = jnp.einsum("bhr,rhv->bhv", ctx, p["wv_b"].astype(jnp.float32))
    out = jnp.einsum("bhv,hvd->bd", o.astype(x.dtype), p["wo"])[:, None]
    return out, MLACache(c_kv=c_kv, k_pe=k_pe)
