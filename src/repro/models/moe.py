"""Mixture-of-Experts FFN with expert parallelism over the TP axis.

EP dispatch *is* the paper's shuffle: tokens are intermediate data routed
to their owner (expert) through the fast tier (ICI ``all_to_all``), exactly
like Marvel keeps MapReduce's mapper→reducer traffic in Ignite instead of
S3.  The dispatch machinery mirrors ``core/device_shuffle.pack_buckets``
(sort → capacity-pack → all_to_all → local compute → reverse path).

Three apply paths:

  * ``moe_apply_dense``   — reference: every token through its top-k experts
    via per-expert capacity gather; no mesh needed (smoke tests, oracle).
  * ``moe_apply_a2a``     — shard_map EP: tokens sequence-sharded over TP,
    two all_to_alls (dispatch + return).  Used for train/prefill.
  * ``moe_apply_gather``  — shard_map EP for tiny T (decode): tokens
    replicated over TP, each column computes its owned experts, psum
    combine.  One psum, no all_to_all.

Expert weights are 2D-sharded ``(TP on experts, FSDP on d_model)`` and
all-gathered over FSDP inside the shard_map (manual ZeRO-3 gather).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.jax_compat import shard_map as _shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.config import ModelConfig, MoEConfig
from repro.models.layers import mlp_apply, mlp_defs
from repro.models.param import FSDP, TP, ParamDef

__all__ = ["moe_defs", "moe_apply"]


def moe_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    m = cfg.moe
    D, E, F = cfg.d_model, m.n_experts, m.d_expert
    defs = {
        "router": ParamDef((D, E), (FSDP, None), dtype=jnp.float32),
        "w_gate": ParamDef((E, D, F), (TP, FSDP, None)),
        "w_up": ParamDef((E, D, F), (TP, FSDP, None)),
        "w_down": ParamDef((E, F, D), (TP, None, FSDP)),
    }
    if m.n_shared:
        defs["shared"] = mlp_defs(D, m.n_shared * F, gated=True)
    return defs


def _route(xf: jax.Array, router: jax.Array, m: MoEConfig):
    """Top-k routing. Returns (weights (N,k) f32, experts (N,k) i32, aux)."""
    logits = (xf.astype(jnp.float32) @ router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, m.top_k)
    if m.normalize_top_k:
        w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    # load-balance aux (Switch-style): E * sum_e f_e * p_e
    E = probs.shape[-1]
    f = jnp.mean(
        jax.nn.one_hot(idx, E, dtype=jnp.float32).sum(axis=1), axis=0
    )
    p = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f * p)
    return w, idx, aux


def _expert_ffn(gx: jax.Array, wg, wu, wd, act: str) -> jax.Array:
    """gx: (E_loc, C, D) -> (E_loc, C, D); batched gated FFN."""
    act_fn = jax.nn.silu if act == "silu" else jax.nn.gelu
    h = act_fn(jnp.einsum("ecd,edf->ecf", gx, wg)) * jnp.einsum(
        "ecd,edf->ecf", gx, wu
    )
    return jnp.einsum("ecf,efd->ecd", h, wd)


def _pack_by_group(
    groups: jax.Array,  # (M,) int32 group id, or big sentinel for invalid
    n_groups: int,
    capacity: int,
):
    """Sort-based capacity packing. Returns (order, grp_sorted, pos, keep)."""
    order = jnp.argsort(groups, stable=True)
    gs = groups[order]
    starts = jnp.searchsorted(gs, jnp.arange(n_groups + 1))
    pos = jnp.arange(groups.shape[0]) - starts[jnp.minimum(gs, n_groups)]
    keep = (pos < capacity) & (gs < n_groups)
    return order, gs, pos, keep


# -- reference path ---------------------------------------------------------

def moe_apply_dense(
    p: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig
) -> Tuple[jax.Array, jax.Array]:
    """Oracle: capacity-packed per-expert compute on one device."""
    m = cfg.moe
    B, T, D = x.shape
    N = B * T
    xf = x.reshape(N, D)
    w, idx, aux = _route(xf, p["router"], m)
    M = N * m.top_k
    e_flat = idx.reshape(M)
    w_flat = w.reshape(M)
    tok = jnp.repeat(jnp.arange(N), m.top_k)
    cap = max(1, int(math.ceil(M / m.n_experts * m.capacity_factor)))
    order, gs, pos, keep = _pack_by_group(e_flat, m.n_experts, cap)
    ge = jnp.minimum(gs, m.n_experts - 1)
    gp = jnp.minimum(pos, cap - 1)
    gx = jnp.zeros((m.n_experts, cap, D), x.dtype)
    gx = gx.at[jnp.where(keep, gs, m.n_experts), jnp.where(keep, pos, cap)].set(
        xf[tok[order]], mode="drop"
    )
    y = _expert_ffn(gx, p["w_gate"], p["w_up"], p["w_down"], cfg.act)
    vals = jnp.where(keep[:, None], y[ge, gp], 0.0)  # (M, D) sorted order
    contrib = jnp.zeros((N, D), y.dtype)
    contrib = contrib.at[tok[order]].add(vals * w_flat[order][:, None].astype(y.dtype))
    out = contrib.reshape(B, T, D).astype(x.dtype)
    if m.n_shared:
        out = out + mlp_apply(p["shared"], x, cfg.act)
    return out, aux


# -- sharded paths ---------------------------------------------------------

def _gather_experts(p, fsdp_axes):
    """Manual ZeRO gather of expert weights over the FSDP axis/axes."""
    wg, wu, wd = p["w_gate"], p["w_up"], p["w_down"]
    for ax in fsdp_axes:
        wg = jax.lax.all_gather(wg, ax, axis=1, tiled=True)
        wu = jax.lax.all_gather(wu, ax, axis=1, tiled=True)
        wd = jax.lax.all_gather(wd, ax, axis=2, tiled=True)
    return wg, wu, wd


def moe_apply_a2a(
    p: Dict[str, jax.Array],
    x: jax.Array,
    cfg: ModelConfig,
    mesh: Mesh,
    dp_axes: Tuple[str, ...],
    tp_axis: str,
    zero1: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """EP via two all_to_alls; tokens sequence-sharded along TP."""
    m = cfg.moe
    B, T, D = x.shape
    tp = mesh.shape[tp_axis]
    E_loc = m.n_experts // tp
    assert T % tp == 0, "a2a MoE path needs seq divisible by TP"

    # zero1: weights arrive pre-gathered -> no in-shard_map FSDP gathers
    fsdp_axes = () if zero1 else dp_axes[-1:]

    def shard_fn(xl, router, wg_l, wu_l, wd_l):
        wg, wu, wd = _gather_experts(
            {"w_gate": wg_l, "w_up": wu_l, "w_down": wd_l}, fsdp_axes
        )
        router_full = router
        for ax in fsdp_axes:
            router_full = jax.lax.all_gather(router_full, ax, axis=0, tiled=True)
        Bl, Tl, _ = xl.shape
        N = Bl * Tl
        xf = xl.reshape(N, D)
        w, idx, aux = _route(xf, router_full, m)
        M = N * m.top_k
        e_flat = idx.reshape(M)
        tok = jnp.repeat(jnp.arange(N), m.top_k)
        owner = e_flat // E_loc
        cap_s = max(1, int(math.ceil(M / tp * m.capacity_factor)))
        cap_e = max(1, int(math.ceil(M * tp / m.n_experts * m.capacity_factor)))

        # ---- dispatch pack (by owner column) ----
        order, gs, pos, keep = _pack_by_group(owner, tp, cap_s)
        row = jnp.where(keep, gs, tp)
        col = jnp.where(keep, pos, cap_s)
        send_x = jnp.zeros((tp, cap_s, D), xl.dtype)
        send_x = send_x.at[row, col].set(xf[tok[order]], mode="drop")
        send_e = jnp.full((tp, cap_s), -1, jnp.int32)
        send_e = send_e.at[row, col].set(e_flat[order].astype(jnp.int32), mode="drop")

        recv_x = jax.lax.all_to_all(send_x, tp_axis, 0, 0, tiled=True)
        recv_e = jax.lax.all_to_all(send_e, tp_axis, 0, 0, tiled=True)

        # ---- local expert grouping ----
        my_col = jax.lax.axis_index(tp_axis)
        le = jnp.where(recv_e >= 0, recv_e - my_col * E_loc, E_loc).reshape(-1)
        rxf = recv_x.reshape(tp * cap_s, D)
        order2, gs2, pos2, keep2 = _pack_by_group(le, E_loc, cap_e)
        gx = jnp.zeros((E_loc, cap_e, D), xl.dtype)
        gx = gx.at[
            jnp.where(keep2, gs2, E_loc), jnp.where(keep2, pos2, cap_e)
        ].set(rxf[order2], mode="drop")
        y = _expert_ffn(gx, wg, wu, wd, cfg.act)
        ge2 = jnp.minimum(gs2, E_loc - 1)
        gp2 = jnp.minimum(pos2, cap_e - 1)
        vals2 = jnp.where(keep2[:, None], y[ge2, gp2], 0.0).astype(xl.dtype)
        ret = jnp.zeros((tp * cap_s, D), xl.dtype).at[order2].set(vals2)

        back = jax.lax.all_to_all(
            ret.reshape(tp, cap_s, D), tp_axis, 0, 0, tiled=True
        )

        # ---- combine at source ----
        inv = jnp.argsort(order)  # entry -> sorted slot
        pos_of = pos[inv]
        keep_of = keep[inv]
        got = back[
            jnp.minimum(owner, tp - 1), jnp.minimum(pos_of, cap_s - 1)
        ]  # (M, D)
        got = jnp.where(keep_of[:, None], got, 0.0)
        wf = w.reshape(M).astype(got.dtype)
        contrib = jnp.zeros((N, D), got.dtype).at[tok].add(got * wf[:, None])
        out = contrib.reshape(Bl, Tl, D)
        aux = jax.lax.pmean(jax.lax.pmean(aux, tp_axis), dp_axes[0])
        for ax in dp_axes[1:]:
            aux = jax.lax.pmean(aux, ax)
        return out, aux

    w_fsdp = None if zero1 else dp_axes[-1]
    fn = _shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(
            P(dp_axes, tp_axis, None),  # x: sequence-sharded over TP
            P(w_fsdp, None),  # router
            P(tp_axis, w_fsdp, None),
            P(tp_axis, w_fsdp, None),
            P(tp_axis, None, w_fsdp),
        ),
        out_specs=(P(dp_axes, tp_axis, None), P()),
        check_vma=False,
    )
    out, aux = fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    if m.n_shared:
        out = out + mlp_apply(p["shared"], x, cfg.act)
    return out, aux


def moe_apply_gather(
    p: Dict[str, jax.Array],
    x: jax.Array,
    cfg: ModelConfig,
    mesh: Mesh,
    dp_axes: Tuple[str, ...],
    tp_axis: str,
    zero1: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """EP for decode-size T: tokens replicated over TP, psum combine."""
    m = cfg.moe
    B, T, D = x.shape
    tp = mesh.shape[tp_axis]
    E_loc = m.n_experts // tp

    fsdp_axes = () if zero1 else dp_axes[-1:]

    def shard_fn(xl, router, wg_l, wu_l, wd_l):
        wg, wu, wd = _gather_experts(
            {"w_gate": wg_l, "w_up": wu_l, "w_down": wd_l}, fsdp_axes
        )
        router_full = router
        for ax in fsdp_axes:
            router_full = jax.lax.all_gather(router_full, ax, axis=0, tiled=True)
        Bl, Tl, _ = xl.shape
        N = Bl * Tl
        xf = xl.reshape(N, D)
        w, idx, aux = _route(xf, router_full, m)
        M = N * m.top_k
        e_flat = idx.reshape(M)
        tok = jnp.repeat(jnp.arange(N), m.top_k)
        my_col = jax.lax.axis_index(tp_axis)
        le_all = e_flat - my_col * E_loc
        le = jnp.where((le_all >= 0) & (le_all < E_loc), le_all, E_loc)
        cap_e = max(1, int(math.ceil(M / m.n_experts * m.capacity_factor)))
        order, gs, pos, keep = _pack_by_group(le, E_loc, cap_e)
        gx = jnp.zeros((E_loc, cap_e, D), xl.dtype)
        gx = gx.at[
            jnp.where(keep, gs, E_loc), jnp.where(keep, pos, cap_e)
        ].set(xf[tok[order]], mode="drop")
        y = _expert_ffn(gx, wg, wu, wd, cfg.act)
        ge = jnp.minimum(gs, E_loc - 1)
        gp = jnp.minimum(pos, cap_e - 1)
        vals = jnp.where(keep[:, None], y[ge, gp], 0.0)
        wf = w.reshape(M).astype(vals.dtype)[order]
        contrib = jnp.zeros((N, D), vals.dtype).at[tok[order]].add(vals * wf[:, None])
        out = jax.lax.psum(contrib, tp_axis).reshape(Bl, Tl, D).astype(xl.dtype)
        aux = jax.lax.pmean(aux, dp_axes[0])
        for ax in dp_axes[1:]:
            aux = jax.lax.pmean(aux, ax)
        return out, aux

    w_fsdp = None if zero1 else dp_axes[-1]
    fn = _shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(
            P(dp_axes, None, None),
            P(w_fsdp, None),
            P(tp_axis, w_fsdp, None),
            P(tp_axis, w_fsdp, None),
            P(tp_axis, None, w_fsdp),
        ),
        out_specs=(P(dp_axes, None, None), P()),
        check_vma=False,
    )
    out, aux = fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    if m.n_shared:
        out = out + mlp_apply(p["shared"], x, cfg.act)
    return out, aux


def moe_apply(
    p: Dict[str, jax.Array],
    x: jax.Array,
    cfg: ModelConfig,
    mesh: Optional[Mesh] = None,
    dp_axes: Tuple[str, ...] = ("data",),
    tp_axis: str = "model",
    zero1: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Dispatching wrapper: picks dense / a2a / gather path."""
    if mesh is None or tp_axis not in mesh.axis_names or mesh.shape[tp_axis] == 1:
        return moe_apply_dense(p, x, cfg)
    tp = mesh.shape[tp_axis]
    if cfg.moe.n_experts % tp != 0:
        return moe_apply_dense(p, x, cfg)
    if x.shape[1] % tp == 0:
        return moe_apply_a2a(p, x, cfg, mesh, dp_axes, tp_axis, zero1)
    return moe_apply_gather(p, x, cfg, mesh, dp_axes, tp_axis, zero1)
