"""Model assembly: frontend → prelude → scanned pattern body → postlude →
final norm → unembed.  Covers all 10 assigned architectures via
:class:`ModelConfig` (see configs/).

Scan-over-layers: the repeating block pattern is stacked along a leading
``n_periods`` dim and driven by ``lax.scan`` — HLO size stays O(pattern),
which is what makes 512-device dry-run compiles fast.  Remat wraps the
scanned period body.  Decode threads per-layer caches through the same scan.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.ad_checkpoint
import jax.numpy as jnp

from repro.models import attention, mla, moe, rglru, ssm
from repro.models.config import BlockSpec, ModelConfig, ShapeConfig
from repro.models.ctx import ShardCtx
from repro.models.layers import layer_norm, mlp_apply, mlp_defs, rms_norm, softcap
from repro.models.param import FSDP, TP, ParamDef, stack_defs

__all__ = ["ShardCtx", "model_defs", "forward", "decode_step", "init_cache"]


# -- defs ---------------------------------------------------------------

def _norm_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    if cfg.norm == "ln":
        return {
            "scale": ParamDef((cfg.d_model,), (None,), init_value=1.0),
            "bias": ParamDef((cfg.d_model,), (None,), init_scale=0.0),
        }
    init = 0.0 if cfg.rms_plus_one else 1.0
    return {"scale": ParamDef((cfg.d_model,), (None,), init_value=init)}


def _norm_apply(p, x, cfg: ModelConfig):
    if cfg.norm == "ln":
        return layer_norm(x, p["scale"], p["bias"])
    return rms_norm(x, p["scale"], plus_one=cfg.rms_plus_one)


def _mixer_defs(blk: BlockSpec, cfg: ModelConfig) -> Dict[str, ParamDef]:
    if blk.mixer in ("attn", "local"):
        return attention.attn_defs(cfg)
    if blk.mixer == "mla":
        return mla.mla_defs(cfg)
    if blk.mixer == "ssm":
        return ssm.ssm_defs(cfg)
    if blk.mixer == "rglru":
        return rglru.rglru_defs(cfg)
    raise ValueError(blk.mixer)


def _ffn_defs(blk: BlockSpec, cfg: ModelConfig) -> Optional[Dict[str, ParamDef]]:
    if blk.ffn == "dense":
        # encoder-style plain MLP when act endswith _plain
        if cfg.act == "gelu_plain":
            return mlp_defs(cfg.d_model, cfg.d_ff, gated=False)
        return mlp_defs(cfg.d_model, cfg.d_ff, gated=True)
    if blk.ffn == "moe":
        return moe.moe_defs(cfg)
    return None


def _block_defs(blk: BlockSpec, cfg: ModelConfig) -> Dict[str, Any]:
    defs: Dict[str, Any] = {
        "norm1": _norm_defs(cfg),
        "mixer": _mixer_defs(blk, cfg),
    }
    if blk.ffn != "none":
        defs["norm2"] = _norm_defs(cfg)
        defs["ffn"] = _ffn_defs(blk, cfg)
    if cfg.post_block_norm:
        defs["post1"] = _norm_defs(cfg)
        if blk.ffn != "none":
            defs["post2"] = _norm_defs(cfg)
    return defs


def model_defs(cfg: ModelConfig) -> Dict[str, Any]:
    D, V = cfg.d_model, cfg.vocab
    defs: Dict[str, Any] = {}
    if cfg.frontend in ("tokens", "tokens+patches"):
        # D sharded over FSDP: the token gather stays local per D-shard and
        # GSPMD reshards (B,T,D/16)->(B/16,T,D) with an all-to-all, 16x
        # cheaper than the psum a vocab-sharded table would need.
        defs["embed"] = ParamDef((V, D), (None, FSDP), init_scale=0.02)
    if cfg.frontend == "frames":
        fd = cfg.frame_dim or D
        defs["frame_proj"] = {
            "w": ParamDef((fd, D), (None, FSDP)),
            "b": ParamDef((D,), (None,), init_scale=0.0),
        }
    defs["prelude"] = [ _block_defs(b, cfg) for b in cfg.prelude ]
    defs["body"] = [
        stack_defs(_block_defs(b, cfg), cfg.n_periods) for b in cfg.pattern
    ]
    defs["postlude"] = [ _block_defs(b, cfg) for b in cfg.postlude ]
    defs["final_norm"] = _norm_defs(cfg)
    # V over TP: logits shard the vocab dim with no sharded contraction;
    # logsumexp cross-shard reductions are (B,T)-sized, not (B,T,V).
    defs["unembed"] = ParamDef((D, V), (None, TP))
    return defs


# -- apply ---------------------------------------------------------------

def _mixer_apply(p, x, blk: BlockSpec, cfg: ModelConfig, shape: ShapeConfig,
                 ctx: ShardCtx, collect_cache: bool = False, cache_len=None):
    if blk.mixer in ("attn", "local"):
        out = attention.attn_apply(
            p, x, cfg,
            window=blk.window if blk.mixer == "local" else None,
            q_chunk=shape.q_chunk, kv_chunk=shape.kv_chunk,
            collect_cache=collect_cache, cache_len=cache_len, ctx=ctx,
        )
    elif blk.mixer == "mla":
        out = mla.mla_apply(p, x, cfg, q_chunk=shape.q_chunk,
                            kv_chunk=shape.kv_chunk,
                            collect_cache=collect_cache, cache_len=cache_len,
                            ctx=ctx)
    elif blk.mixer == "ssm":
        out = ssm.ssm_apply(p, x, cfg, collect_cache=collect_cache, ctx=ctx)
    elif blk.mixer == "rglru":
        out = rglru.rglru_apply(p, x, cfg, collect_cache=collect_cache, ctx=ctx)
    else:
        raise ValueError(blk.mixer)
    return out if collect_cache else (out, None)


def _ffn_apply(p, x, blk: BlockSpec, cfg: ModelConfig, ctx: ShardCtx):
    if blk.ffn == "dense":
        act = "gelu" if cfg.act == "gelu_plain" else cfg.act
        return mlp_apply(p, x, act), jnp.zeros((), jnp.float32)
    if blk.ffn == "moe":
        return moe.moe_apply(p, x, cfg, ctx.mesh, ctx.dp_axes, ctx.tp_axis,
                             zero1=getattr(ctx, 'zero1', False))
    raise ValueError(blk.ffn)


def _block_apply(p, x, blk: BlockSpec, cfg: ModelConfig, shape: ShapeConfig,
                 ctx: ShardCtx, collect_cache: bool = False, cache_len=None):
    h, cache = _mixer_apply(
        p["mixer"], _norm_apply(p["norm1"], x, cfg), blk, cfg, shape, ctx,
        collect_cache, cache_len,
    )
    h = jax.ad_checkpoint.checkpoint_name(h, "block_out")
    if cfg.post_block_norm:
        h = _norm_apply(p["post1"], h, cfg)
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    if blk.ffn != "none":
        h, aux = _ffn_apply(p["ffn"], _norm_apply(p["norm2"], x, cfg), blk, cfg, ctx)
        h = jax.ad_checkpoint.checkpoint_name(h, "block_out")
        if cfg.post_block_norm:
            h = _norm_apply(p["post2"], h, cfg)
        x = x + h
    return x, aux, cache


def _frontend(params, cfg: ModelConfig, inputs: Dict[str, jax.Array]):
    if cfg.frontend == "tokens":
        x = jnp.take(params["embed"], inputs["tokens"], axis=0)
    elif cfg.frontend == "frames":
        fp = params["frame_proj"]
        x = inputs["frames"] @ fp["w"] + fp["b"]
    elif cfg.frontend == "tokens+patches":
        tok = jnp.take(params["embed"], inputs["tokens"], axis=0)
        x = jnp.concatenate([inputs["patches"].astype(tok.dtype), tok], axis=1)
    else:
        raise ValueError(cfg.frontend)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    if policy == "save_block_out":
        # keep the post-collective mixer/FFN outputs: the backward pass
        # then reuses them instead of re-running the forward psums
        # (remat recompute was ~1/3 of train collective bytes — §Perf)
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.save_only_these_names("block_out"),
        )
    return jax.checkpoint(fn)


def forward(
    params: Dict[str, Any],
    cfg: ModelConfig,
    inputs: Dict[str, jax.Array],
    shape: ShapeConfig,
    ctx: Optional[ShardCtx] = None,
    collect_cache: bool = False,
    cache_len: Optional[int] = None,
):
    """Full-sequence forward. Returns (hidden (B,T,D), moe aux loss) or,
    with ``collect_cache`` (prefill), (hidden, aux, cache pytree).
    ``cache_len`` reserves decode headroom in the collected caches."""
    ctx = ctx or ShardCtx()
    x = _frontend(params, cfg, inputs)
    aux = jnp.zeros((), jnp.float32)
    caches = {"prelude": [], "body": [], "postlude": []}

    for p, blk in zip(params["prelude"], cfg.prelude):
        x, a, c = _block_apply(p, x, blk, cfg, shape, ctx, collect_cache,
                               cache_len)
        aux = aux + a
        caches["prelude"].append(c)

    if cfg.n_periods > 0:
        def period(carry, slot_params):
            xx, acc = carry
            slot_caches = []
            for sp, blk in zip(slot_params, cfg.pattern):
                xx, a, c = _block_apply(sp, xx, blk, cfg, shape, ctx,
                                        collect_cache, cache_len)
                acc = acc + a
                slot_caches.append(c)
            ys = tuple(slot_caches) if collect_cache else None
            return (xx, acc), ys

        period_fn = _remat(period, shape.remat)
        (x, aux), body_caches = jax.lax.scan(
            period_fn, (x, aux), tuple(params["body"])
        )
        if collect_cache:
            caches["body"] = list(body_caches)

    for p, blk in zip(params["postlude"], cfg.postlude):
        x, a, c = _block_apply(p, x, blk, cfg, shape, ctx, collect_cache,
                               cache_len)
        aux = aux + a
        caches["postlude"].append(c)

    x = _norm_apply(params["final_norm"], x, cfg)
    if collect_cache:
        return x, aux, caches
    return x, aux


def logits_fn(params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Final logits (fp32, softcapped). x: (..., D)."""
    logits = (x @ params["unembed"]).astype(jnp.float32)
    return softcap(logits, cfg.final_softcap)


# -- decode ---------------------------------------------------------------

def _mixer_cache(blk: BlockSpec, cfg: ModelConfig, batch: int, seq_len: int,
                 dtype, quant_attn: bool = False):
    if blk.mixer in ("attn", "local"):
        window = blk.window if blk.mixer == "local" else None
        if quant_attn:
            from repro.models.quant_cache import init_quant_cache
            return init_quant_cache(cfg, batch, seq_len, window)
        return attention.init_attn_cache(cfg, batch, seq_len, window, dtype)
    if blk.mixer == "mla":
        return mla.init_mla_cache(cfg, batch, seq_len, dtype)
    if blk.mixer == "ssm":
        return ssm.init_ssm_cache(cfg, batch, dtype)
    if blk.mixer == "rglru":
        return rglru.init_rglru_cache(cfg, batch, dtype)
    raise ValueError(blk.mixer)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=jnp.bfloat16,
               quant_attn: bool = False):
    """Decode cache pytree; ``quant_attn`` uses int8 attention caches."""
    stack = lambda c: jax.tree_util.tree_map(
        lambda leaf: jnp.broadcast_to(
            leaf[None], (cfg.n_periods,) + leaf.shape
        ).copy() if cfg.n_periods else leaf,
        c,
    )
    mk = lambda b: _mixer_cache(b, cfg, batch, seq_len, dtype, quant_attn)
    return {
        "prelude": [mk(b) for b in cfg.prelude],
        "body": [stack(mk(b)) for b in cfg.pattern],
        "postlude": [mk(b) for b in cfg.postlude],
    }


def _block_decode(p, x, cache, t, blk: BlockSpec, cfg: ModelConfig,
                  ctx: ShardCtx):
    xn = _norm_apply(p["norm1"], x, cfg)
    if blk.mixer in ("attn", "local"):
        h, new_cache = attention.attn_decode(
            p["mixer"], xn, cache, t, cfg,
            window=blk.window if blk.mixer == "local" else None, ctx=ctx,
        )
    elif blk.mixer == "mla":
        h, new_cache = mla.mla_decode(p["mixer"], xn, cache, t, cfg, ctx=ctx)
    elif blk.mixer == "ssm":
        h, new_cache = ssm.ssm_decode(p["mixer"], xn, cache, cfg, ctx=ctx)
    elif blk.mixer == "rglru":
        h, new_cache = rglru.rglru_decode(p["mixer"], xn, cache, cfg, ctx=ctx)
    else:
        raise ValueError(blk.mixer)
    if cfg.post_block_norm:
        h = _norm_apply(p["post1"], h, cfg)
    x = x + h
    if blk.ffn != "none":
        h, _ = _ffn_apply(p["ffn"], _norm_apply(p["norm2"], x, cfg), blk, cfg, ctx)
        if cfg.post_block_norm:
            h = _norm_apply(p["post2"], h, cfg)
        x = x + h
    return x, new_cache


def decode_step(
    params: Dict[str, Any],
    cfg: ModelConfig,
    tokens: jax.Array,  # (B, 1) int32 current token ids
    cache: Dict[str, Any],
    t: jax.Array,  # scalar int32 position of `tokens`
    ctx: Optional[ShardCtx] = None,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """One-token decode. Returns (logits (B, V) fp32, new cache)."""
    ctx = ctx or ShardCtx()
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)

    new_prelude = []
    for p, c, blk in zip(params["prelude"], cache["prelude"], cfg.prelude):
        x, nc = _block_decode(p, x, c, t, blk, cfg, ctx)
        new_prelude.append(nc)

    new_body = cache["body"]
    if cfg.n_periods > 0:
        def period(xx, scanned):
            slot_params, slot_caches = scanned
            new_caches = []
            for sp, sc, blk in zip(slot_params, slot_caches, cfg.pattern):
                xx, nc = _block_decode(sp, xx, sc, t, blk, cfg, ctx)
                new_caches.append(nc)
            return xx, tuple(new_caches)

        x, new_body = jax.lax.scan(
            period, x, (tuple(params["body"]), tuple(cache["body"]))
        )
        new_body = list(new_body)

    new_postlude = []
    for p, c, blk in zip(params["postlude"], cache["postlude"], cfg.postlude):
        x, nc = _block_decode(p, x, c, t, blk, cfg, ctx)
        new_postlude.append(nc)

    x = _norm_apply(params["final_norm"], x, cfg)
    logits = logits_fn(params, cfg, x[:, 0])
    return logits, {
        "prelude": new_prelude,
        "body": new_body,
        "postlude": new_postlude,
    }
