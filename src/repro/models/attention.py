"""GQA/MQA/MHA attention block: defs + prefill/train apply + decode apply.

Sharding rule (see DESIGN.md §4): tensor-parallel axis goes on the *heads*
dim when divisible by the production TP degree (16), otherwise on head_dim
(gemma-2b H=8, qwen1.5-32b H=40 — their scores pick up one extra
all-reduce, visible in the roofline and addressed in §Perf).

Decode uses ring-buffer caches for windowed (local) layers — cache memory
is O(window), which is what makes recurrentgemma's long_500k cell feasible.
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.ctx import ShardCtx, constrain
from repro.models.quant_cache import (
    QuantAttnCache,
    quant_decode_attention,
    quantize_kv,
)
from repro.models.layers import (
    apply_rope,
    chunked_attention,
    decode_attention,
)
from repro.models.param import FSDP, TP, ParamDef

__all__ = ["attn_defs", "attn_apply", "attn_decode", "init_attn_cache", "DEFAULT_TP"]

DEFAULT_TP = 16


def _head_specs(n_heads: int, head_dim: int):
    """(spec for (D, H, dh) proj, spec for (H, dh, D) out-proj)."""
    if n_heads % DEFAULT_TP == 0:
        return (FSDP, TP, None), (TP, None, FSDP)
    if head_dim % DEFAULT_TP == 0:
        return (FSDP, None, TP), (None, TP, FSDP)
    return (FSDP, None, None), (None, None, FSDP)


def _eff_heads(cfg: ModelConfig):
    """(H_eff, Kv_eff): padded head counts when cfg.pad_heads is set.

    Padding adds *dead* heads: their post-attention outputs are masked to
    zero before the out-projection, so the function space is exactly the
    unpadded model's (dead heads get zero gradients too).  The payoff is
    heads-sharded attention with no score all-reduces."""
    H, Kv = cfg.n_heads, cfg.n_kv_heads
    if not cfg.pad_heads or H % DEFAULT_TP == 0:
        return H, Kv
    H_eff = -(-H // DEFAULT_TP) * DEFAULT_TP
    Kv_eff = H_eff if Kv == H else Kv  # MHA pads kv too; GQA/MQA expands
    return H_eff, Kv_eff


def _expand_kv(cfg: ModelConfig) -> bool:
    """TP-on-heads mode with Kv < TP: replicate the (small) KV projections
    and expand K/V to H heads before attention so q/k/v share one layout.
    Mixing heads-sharded q with dh-sharded kv would all-reduce every score
    chunk (measured 300+ GB/step at 4k train) — never do that."""
    H, Kv = _eff_heads(cfg)
    return H % DEFAULT_TP == 0 and Kv % DEFAULT_TP != 0


def attn_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    D, dh = cfg.d_model, cfg.head_dim
    H, Kv = _eff_heads(cfg)
    q_spec, o_spec = _head_specs(H, dh)
    if _expand_kv(cfg):
        kv_spec = (FSDP, None, None)  # replicated heads, expanded at use
    else:
        kv_spec, _ = _head_specs(Kv, dh)
    defs = {
        "wq": ParamDef((D, H, dh), q_spec),
        "wk": ParamDef((D, Kv, dh), kv_spec),
        "wv": ParamDef((D, Kv, dh), kv_spec),
        "wo": ParamDef((H, dh, D), o_spec),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((H, dh), (q_spec[1], q_spec[2]), init_scale=0.0)
        defs["bk"] = ParamDef((Kv, dh), (kv_spec[1], kv_spec[2]), init_scale=0.0)
        defs["bv"] = ParamDef((Kv, dh), (kv_spec[1], kv_spec[2]), init_scale=0.0)
    return defs


def _project_qkv(p, x, cfg: ModelConfig):
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    return q, k, v


def attn_apply(
    p: Dict[str, jax.Array],
    x: jax.Array,  # (B, T, D)
    cfg: ModelConfig,
    *,
    window: Optional[int] = None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    collect_cache: bool = False,
    cache_len: Optional[int] = None,
    ctx: Optional[ShardCtx] = None,
):
    """Full-sequence attention (training / prefill).

    With ``collect_cache`` also returns the decode cache: full K/V for
    global layers, the last-``window`` ring for local layers (entry for
    position p at slot ``p % window``, matching ``attn_decode``).
    """
    B, T, D = x.shape
    H_eff, Kv_eff = _eff_heads(cfg)
    positions = jnp.arange(T)[None, :]
    q, k, v = _project_qkv(p, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    k_c, v_c = k, v  # compact (Kv-head) tensors for the decode cache
    if _expand_kv(cfg):
        rep = H_eff // Kv_eff
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    # Pin the layout: GSPMD otherwise replicates attention inside the scan.
    if H_eff % DEFAULT_TP == 0:
        ent = ("b", None, "tp", None)
    else:
        ent = ("b", None, None, "tp")
    q = constrain(q, ctx, *ent)
    k = constrain(k, ctx, *ent)
    v = constrain(v, ctx, *ent)
    o = chunked_attention(
        q, k, v,
        causal=cfg.causal,
        window=window,
        attn_softcap=cfg.attn_softcap,
        scale=cfg.query_scale,
        q_chunk=q_chunk,
        kv_chunk=kv_chunk,
    )
    o = constrain(o, ctx, *ent)
    if H_eff != cfg.n_heads:
        # dead padded heads: zero their outputs (exact fn equivalence)
        o = o * (jnp.arange(H_eff) < cfg.n_heads)[None, None, :, None].astype(
            o.dtype
        )
    out = jnp.einsum("bthk,hkd->btd", o, p["wo"])
    if not collect_cache:
        return out
    L = cache_len or T
    S = min(L, window) if window else L
    n = min(T, S)
    pos = T - n + jnp.arange(n)  # last n positions land in the cache
    slots = pos % S  # ring layout for local layers; identity when S >= T
    ck = jnp.zeros((B, S) + k_c.shape[2:], k_c.dtype).at[:, slots].set(k_c[:, pos])
    cv = jnp.zeros((B, S) + v_c.shape[2:], v_c.dtype).at[:, slots].set(v_c[:, pos])
    return out, AttnCache(ck, cv)


class AttnCache(NamedTuple):
    k: jax.Array  # (B, S, Kv, dh) — S = min(seq_len, window or seq_len)
    v: jax.Array


def init_attn_cache(
    cfg: ModelConfig, batch: int, seq_len: int, window: Optional[int], dtype
) -> AttnCache:
    S = min(seq_len, window) if window else seq_len
    shape = (batch, S, cfg.n_kv_heads, cfg.head_dim)
    return AttnCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def attn_decode(
    p: Dict[str, jax.Array],
    x: jax.Array,  # (B, 1, D) current token hidden
    cache: AttnCache,
    t: jax.Array,  # scalar int32: current position (0-based)
    cfg: ModelConfig,
    *,
    window: Optional[int] = None,
    ctx: Optional[ShardCtx] = None,
) -> Tuple[jax.Array, AttnCache]:
    """One decode step; returns (out (B,1,D), updated cache).

    Windowed layers use a ring buffer (slot = t mod W): every live entry is
    inside the window by construction, so only warmup masking is needed.
    """
    B = x.shape[0]
    quant = isinstance(cache, QuantAttnCache)
    S = (cache.k_q if quant else cache.k).shape[1]
    pos = jnp.full((B, 1), t, dtype=jnp.int32)
    q, k, v = _project_qkv(p, x, cfg)  # (B, 1, H/Kv, dh)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    slot = t % S  # ring slot; global layers have S == seq_len so slot == t
    # Valid entries: slots <= t (warmup) or everything once t >= S.
    n_valid = jnp.minimum(t + 1, S)
    lengths = jnp.full((B,), n_valid, jnp.int32)
    if quant:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        new_cache = QuantAttnCache(
            k_q=jax.lax.dynamic_update_slice_in_dim(cache.k_q, kq, slot, 1),
            v_q=jax.lax.dynamic_update_slice_in_dim(cache.v_q, vq, slot, 1),
            k_s=jax.lax.dynamic_update_slice_in_dim(
                cache.k_s, ks.astype(cache.k_s.dtype), slot, 1),
            v_s=jax.lax.dynamic_update_slice_in_dim(
                cache.v_s, vs.astype(cache.v_s.dtype), slot, 1),
        )
        new_cache = QuantAttnCache(
            k_q=constrain(new_cache.k_q, ctx, "b", "tp", None, None),
            v_q=constrain(new_cache.v_q, ctx, "b", "tp", None, None),
            k_s=constrain(new_cache.k_s, ctx, "b", "tp", None),
            v_s=constrain(new_cache.v_s, ctx, "b", "tp", None),
        )
        o = quant_decode_attention(
            q[:, 0], new_cache, lengths,
            attn_softcap=cfg.attn_softcap, scale=cfg.query_scale,
        ).astype(x.dtype)
        out = jnp.einsum("bhk,hkd->bd", o, p["wo"])[:, None, :]
        return out, new_cache
    new_k = jax.lax.dynamic_update_slice_in_dim(cache.k, k, slot, axis=1)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache.v, v, slot, axis=1)
    # flash-decode layout: cache sequence-sharded over TP
    new_k = constrain(new_k, ctx, "b", "tp", None, None)
    new_v = constrain(new_v, ctx, "b", "tp", None, None)
    # decode_attention masks by `length` over the slot axis; ring order does
    # not matter for softmax since all live entries are in-window.
    o = decode_attention(
        q[:, 0],
        new_k,
        new_v,
        lengths,
        window=None,  # windowing is enforced by the ring size
        attn_softcap=cfg.attn_softcap,
        scale=cfg.query_scale,
    )
    out = jnp.einsum("bhk,hkd->bd", o, p["wo"])[:, None, :]
    return out, AttnCache(new_k, new_v)
