"""int8-quantized KV cache — the compression tier for decode state.

The paper's storage argument applied to serving: when the hot tier (HBM)
can't hold the state, compress it rather than spill it.  qwen1.5-32b's
decode_32k cell needs 21 GiB/chip of bf16 MHA cache at the assigned
batch — int8 with per-(position, head) scales halves that to ~10.7 GiB
and fits (EXPERIMENTS.md §Perf bonus).

Layout: values int8, scales bf16 over the head_dim axis.  Attention runs
chunked over the sequence with online softmax, dequantizing one
``s_chunk`` panel at a time (no transient full-precision cache).  The
Pallas flash-decode kernel admits the same per-panel dequant on TPU.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

__all__ = ["QuantAttnCache", "init_quant_cache", "quantize_kv",
           "quant_decode_attention"]

MASK_VALUE = -1e30


class QuantAttnCache(NamedTuple):
    k_q: jax.Array  # (B, S, Kv, dh) int8
    v_q: jax.Array  # (B, S, Kv, dh) int8
    k_s: jax.Array  # (B, S, Kv) bf16 scales
    v_s: jax.Array  # (B, S, Kv) bf16 scales


def init_quant_cache(cfg: ModelConfig, batch: int, seq_len: int,
                     window: Optional[int] = None) -> QuantAttnCache:
    S = min(seq_len, window) if window else seq_len
    shape = (batch, S, cfg.n_kv_heads, cfg.head_dim)
    return QuantAttnCache(
        k_q=jnp.zeros(shape, jnp.int8),
        v_q=jnp.zeros(shape, jnp.int8),
        k_s=jnp.zeros(shape[:3], jnp.bfloat16),
        v_s=jnp.zeros(shape[:3], jnp.bfloat16),
    )


def quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(…, dh) -> (int8 values, bf16 scale over dh)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(
        jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127
    ).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def quant_decode_attention(
    q: jax.Array,  # (B, H, dh)
    cache: QuantAttnCache,
    length: jax.Array,  # (B,) valid entries
    *,
    attn_softcap: Optional[float] = None,
    scale: Optional[float] = None,
    s_chunk: int = 2048,
) -> jax.Array:
    """Single-token attention over the int8 cache, chunk-dequantized."""
    import math

    B, H, dh = q.shape
    _, S, Kv, _ = cache.k_q.shape
    rep = H // Kv
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    s_chunk = min(s_chunk, S)
    ns = -(-S // s_chunk)
    pad = ns * s_chunk - S
    kq = cache.k_q
    vq = cache.v_q
    ks = cache.k_s
    vs = cache.v_s
    if pad:
        kq = jnp.pad(kq, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vq = jnp.pad(vq, ((0, 0), (0, pad), (0, 0), (0, 0)))
        ks = jnp.pad(ks, ((0, 0), (0, pad), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, pad), (0, 0)))
    qr = q.reshape(B, Kv, rep, dh)

    def chunk_step(carry, si):
        acc, m, l = carry
        # index-based slices of the closed-over cache: no transposed copy
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, si * s_chunk, s_chunk, 1)
        kq_c, vq_c, ks_c, vs_c = sl(kq), sl(vq), sl(ks), sl(vs)
        # dequantize one panel: (B, C, Kv, dh)
        k = kq_c.astype(jnp.float32) * ks_c.astype(jnp.float32)[..., None]
        s = jnp.einsum("bkrd,bskd->bkrs", qr.astype(jnp.float32), k) * scale
        if attn_softcap is not None:
            s = attn_softcap * jnp.tanh(s / attn_softcap)
        pos = si * s_chunk + jnp.arange(s_chunk)
        valid = pos[None, :] < length[:, None]  # (B, C)
        s = jnp.where(valid[:, None, None, :], s, MASK_VALUE)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.where(
            valid[:, None, None, :], jnp.exp(s - m_new[..., None]), 0.0
        )
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        v = vq_c.astype(jnp.float32) * vs_c.astype(jnp.float32)[..., None]
        pv = jnp.einsum("bkrs,bskd->bkrd", p, v)
        return (acc * corr[..., None] + pv, m_new, l_new), None

    acc0 = jnp.zeros((B, Kv, rep, dh), jnp.float32)
    m0 = jnp.full((B, Kv, rep), MASK_VALUE, jnp.float32)
    l0 = jnp.zeros((B, Kv, rep), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(chunk_step, (acc0, m0, l0), jnp.arange(ns))
    o = acc / jnp.maximum(l[..., None], 1e-30)
    return o.reshape(B, H, dh).astype(jnp.bfloat16)
