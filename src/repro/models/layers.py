"""Shared neural layers: norms, RoPE, chunked (flash-style) attention,
decode attention over KV caches, gated MLPs, embeddings, chunked CE loss.

All functions are pure; parameters arrive as dicts built from the
:mod:`repro.models.param` definition trees.  Attention never materializes
the full (Tq, Tk) score matrix — it streams KV chunks with an online
softmax (the same algorithm as the Pallas flash kernel in
``repro/kernels/flash_attention.py``; this is its XLA-lowered twin, used
for CPU dry-runs and as the kernel oracle).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.param import FSDP, TP, ParamDef

__all__ = [
    "rms_norm",
    "layer_norm",
    "softcap",
    "apply_rope",
    "chunked_attention",
    "decode_attention",
    "mlp_defs",
    "mlp_apply",
    "chunked_ce_loss",
]

MASK_VALUE = -1e30


# -- norms ---------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6,
             plus_one: bool = False) -> jax.Array:
    """RMSNorm in fp32; ``plus_one`` uses the gemma ``(1 + scale)`` form."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    s = scale.astype(jnp.float32)
    if plus_one:
        s = 1.0 + s
    return (normed * s).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    normed = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (normed * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    """Gemma-2 logit soft-capping: ``cap * tanh(x / cap)``."""
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# -- rotary embeddings -----------------------------------------------------

def rope_freqs(dh_rot: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dh_rot, 2, dtype=jnp.float32) / dh_rot))


def apply_rope(
    x: jax.Array,  # (..., T, H, Dh) or (..., H, Dh) with positions broadcast
    positions: jax.Array,  # (..., T) int32
    theta: float = 10000.0,
    dh_rot: Optional[int] = None,
) -> jax.Array:
    """Rotary embedding on the first ``dh_rot`` head dims (rest pass through)."""
    dh = x.shape[-1]
    dh_rot = dh if dh_rot is None else dh_rot
    freqs = rope_freqs(dh_rot, theta)  # (dh_rot/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., T, dh_rot/2)
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    xr = x[..., :dh_rot].astype(jnp.float32)
    x1, x2 = xr[..., : dh_rot // 2], xr[..., dh_rot // 2 :]
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    out = jnp.concatenate([rotated.astype(x.dtype), x[..., dh_rot:]], axis=-1)
    return out


# -- attention ---------------------------------------------------------------

def _chunk_mask(
    q_pos: jax.Array,  # (Cq,)
    k_pos: jax.Array,  # (Ck,)
    causal: bool,
    window: Optional[int],
    k_len: Optional[jax.Array] = None,
) -> jax.Array:
    mask = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    if k_len is not None:
        mask &= k_pos[None, :] < k_len
    return mask


def chunked_attention(
    q: jax.Array,  # (B, Tq, H, Dh)
    k: jax.Array,  # (B, Tk, Kv, Dh)
    v: jax.Array,  # (B, Tk, Kv, Dhv)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    attn_softcap: Optional[float] = None,
    scale: Optional[float] = None,
    q_chunk: int = 512,
    kv_chunk: int = 512,
    q_offset: int = 0,
) -> jax.Array:
    """Streaming attention with online softmax; O(Cq·Ck) peak score memory.

    GQA: ``H = Kv * rep``.  Returns (B, Tq, H, Dhv).
    """
    B, Tq, H, Dh = q.shape
    _, Tk, Kv, _ = k.shape
    Dhv = v.shape[-1]
    rep = H // Kv
    scale = scale if scale is not None else 1.0 / math.sqrt(Dh)

    q_chunk = min(q_chunk, Tq)
    kv_chunk = min(kv_chunk, Tk)
    nq = -(-Tq // q_chunk)
    nk = -(-Tk // kv_chunk)
    pad_q = nq * q_chunk - Tq
    pad_k = nk * kv_chunk - Tk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))

    qc = q.reshape(B, nq, q_chunk, Kv, rep, Dh)
    kc = k.reshape(B, nk, kv_chunk, Kv, Dh)
    vc = v.reshape(B, nk, kv_chunk, Kv, Dhv)
    k_valid = Tk  # unpadded length

    def q_block(qi, q_blk):
        # q_blk: (B, Cq, Kv, rep, Dh)
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, inputs):
            acc, m, l = carry
            ki, k_blk, v_blk = inputs
            k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum(
                "bqkrd,bckd->bkrqc", q_blk, k_blk,
                preferred_element_type=jnp.float32,
            ) * scale
            s = softcap(s, attn_softcap)
            mask = _chunk_mask(q_pos, k_pos, causal, window, k_len=k_valid)
            s = jnp.where(mask[None, None, None], s, MASK_VALUE)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(mask[None, None, None], p, 0.0)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum(
                "bkrqc,bckd->bkrqd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32,
            )
            acc_new = acc * corr[..., None] + pv
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((B, Kv, rep, q_chunk, Dhv), jnp.float32)
        m0 = jnp.full((B, Kv, rep, q_chunk), MASK_VALUE, jnp.float32)
        l0 = jnp.zeros((B, Kv, rep, q_chunk), jnp.float32)
        kis = jnp.arange(nk)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0),
            (kis, jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)),
        )
        o = acc / jnp.maximum(l[..., None], 1e-30)
        # (B, Kv, rep, Cq, Dhv) -> (B, Cq, Kv, rep, Dhv)
        return jnp.transpose(o, (0, 3, 1, 2, 4))

    if causal and window is None and q_offset == 0 and nq > 1:
        # Block-causal skip: iterate only the lower-triangle (qi, ki) block
        # pairs — half the FLOPs of the dense sweep.  Accumulators for all
        # q blocks ride the scan carry; each step updates one q block.
        pairs = [(i, j) for i in range(nq) for j in range(nk)
                 if j * kv_chunk <= i * q_chunk + q_chunk - 1]
        pair_q = jnp.asarray([p_[0] for p_ in pairs])
        pair_k = jnp.asarray([p_[1] for p_ in pairs])

        def pair_step(carry, inputs):
            acc, m, l = carry  # (nq, B, Kv, rep, Cq, [Dhv])
            qi, ki = inputs
            q_blk = jax.lax.dynamic_index_in_dim(qc, qi, 1, keepdims=False)
            k_blk = jax.lax.dynamic_index_in_dim(kc, ki, 1, keepdims=False)
            v_blk = jax.lax.dynamic_index_in_dim(vc, ki, 1, keepdims=False)
            q_pos = qi * q_chunk + jnp.arange(q_chunk)
            k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum(
                "bqkrd,bckd->bkrqc", q_blk, k_blk,
                preferred_element_type=jnp.float32,
            ) * scale
            s = softcap(s, attn_softcap)
            mask = (q_pos[:, None] >= k_pos[None, :]) & (k_pos < Tk)[None, :]
            s = jnp.where(mask[None, None, None], s, MASK_VALUE)
            m_prev = jax.lax.dynamic_index_in_dim(m, qi, 0, keepdims=False)
            l_prev = jax.lax.dynamic_index_in_dim(l, qi, 0, keepdims=False)
            acc_prev = jax.lax.dynamic_index_in_dim(acc, qi, 0, keepdims=False)
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
            p = jnp.where(mask[None, None, None],
                          jnp.exp(s - m_new[..., None]), 0.0)
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum(
                "bkrqc,bckd->bkrqd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32,
            )
            acc_new = acc_prev * corr[..., None] + pv
            return (
                jax.lax.dynamic_update_index_in_dim(acc, acc_new, qi, 0),
                jax.lax.dynamic_update_index_in_dim(m, m_new, qi, 0),
                jax.lax.dynamic_update_index_in_dim(l, l_new, qi, 0),
            ), None

        acc0 = jnp.zeros((nq, B, Kv, rep, q_chunk, Dhv), jnp.float32)
        m0 = jnp.full((nq, B, Kv, rep, q_chunk), MASK_VALUE, jnp.float32)
        l0 = jnp.zeros((nq, B, Kv, rep, q_chunk), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            pair_step, (acc0, m0, l0), (pair_q, pair_k)
        )
        o = acc / jnp.maximum(l[..., None], 1e-30)
        # (nq, B, Kv, rep, Cq, Dhv) -> (B, nq*Cq, H, Dhv)
        o = jnp.transpose(o, (1, 0, 4, 2, 3, 5)).reshape(
            B, nq * q_chunk, H, Dhv
        )
        return o[:, :Tq].astype(v.dtype)

    qis = jnp.arange(nq)
    o = jax.lax.map(lambda args: q_block(*args), (qis, jnp.moveaxis(qc, 1, 0)))
    # o: (nq, B, Cq, Kv, rep, Dhv)
    o = jnp.moveaxis(o, 0, 1).reshape(B, nq * q_chunk, H, Dhv)
    return o[:, :Tq].astype(v.dtype)


def decode_attention(
    q: jax.Array,  # (B, H, Dh) — one new token per sequence
    k_cache: jax.Array,  # (B, S, Kv, Dh)
    v_cache: jax.Array,  # (B, S, Kv, Dhv)
    length: jax.Array,  # (B,) valid cache entries (incl. current token)
    *,
    window: Optional[int] = None,
    attn_softcap: Optional[float] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    """Single-token attention over a (possibly windowed) KV cache."""
    B, H, Dh = q.shape
    _, S, Kv, _ = k_cache.shape
    rep = H // Kv
    scale = scale if scale is not None else 1.0 / math.sqrt(Dh)
    qr = q.reshape(B, Kv, rep, Dh)
    s = jnp.einsum(
        "bkrd,bskd->bkrs", qr, k_cache, preferred_element_type=jnp.float32
    ) * scale
    s = softcap(s, attn_softcap)
    pos = jnp.arange(S)[None, :]  # (1, S)
    valid = pos < length[:, None]
    if window is not None:
        valid &= pos >= (length[:, None] - window)
    s = jnp.where(valid[:, None, None], s, MASK_VALUE)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum(
        "bkrs,bskd->bkrd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return o.reshape(B, H, v_cache.shape[-1]).astype(v_cache.dtype)


# -- MLP ---------------------------------------------------------------

def mlp_defs(d_model: int, d_ff: int, gated: bool = True) -> Dict[str, ParamDef]:
    if gated:
        return {
            "wi_gate": ParamDef((d_model, d_ff), (FSDP, TP)),
            "wi_up": ParamDef((d_model, d_ff), (FSDP, TP)),
            "wo": ParamDef((d_ff, d_model), (TP, FSDP)),
        }
    return {
        "wi": ParamDef((d_model, d_ff), (FSDP, TP)),
        "wo": ParamDef((d_ff, d_model), (TP, FSDP)),
    }


def mlp_apply(p: Dict[str, jax.Array], x: jax.Array, act: str = "silu") -> jax.Array:
    act_fn = {
        "silu": jax.nn.silu,
        "gelu": lambda y: jax.nn.gelu(y, approximate=True),
        "gelu_exact": lambda y: jax.nn.gelu(y, approximate=False),
        "relu": jax.nn.relu,
    }[act]
    if "wi_gate" in p:
        h = act_fn(x @ p["wi_gate"]) * (x @ p["wi_up"])
    else:
        h = act_fn(x @ p["wi"])
    return h @ p["wo"]


# -- loss ---------------------------------------------------------------

def chunked_ce_loss(
    x: jax.Array,  # (B, T, D) final hidden states
    unembed: jax.Array,  # (D, V)
    labels: jax.Array,  # (B, T) int32; -100 = ignore
    *,
    t_chunk: int = 512,
    logit_softcap: Optional[float] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Mean CE over valid tokens, computed in T-chunks so the (.., V)
    logits tensor never exists at full sequence length.  Returns
    ``(loss, n_valid)``."""
    B, T, D = x.shape
    t_chunk = min(t_chunk, T)
    nt = -(-T // t_chunk)
    pad = nt * t_chunk - T
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-100)
    xc = jnp.moveaxis(x.reshape(B, nt, t_chunk, D), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, nt, t_chunk), 1, 0)

    def chunk_loss(args):
        xb, lb = args  # (B, C, D), (B, C)
        logits = (xb @ unembed).astype(jnp.float32)
        logits = softcap(logits, logit_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(
            logits, jnp.maximum(lb, 0)[..., None], axis=-1
        )[..., 0]
        valid = lb >= 0
        return jnp.sum(jnp.where(valid, lse - ll, 0.0)), jnp.sum(valid)

    losses, counts = jax.lax.map(chunk_loss, (xc, lc))
    n = jnp.maximum(jnp.sum(counts), 1)
    return jnp.sum(losses) / n, n
