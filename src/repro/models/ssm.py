"""Mamba-2 SSD (state-space duality) block — chunked parallel form for
training/prefill, O(1) recurrent form for decode.

Follows the minimal-SSD formulation: within-chunk quadratic (attention-like
with decay masks) + across-chunk recurrent state passing via ``lax.scan``.
The inner-chunk einsums are the compute hot-spot mirrored by the Pallas
kernel in ``repro/kernels/ssd_scan.py``.

Sharding: d_inner (and so heads) over TP; B/C projections replicated.
Decode state is (B, H, P, N) — constant in sequence length, which is what
makes the long_500k cell feasible for this family (DESIGN.md §5).
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.ctx import ShardCtx, constrain
from repro.models.layers import rms_norm
from repro.models.param import FSDP, TP, ParamDef

__all__ = ["ssm_defs", "ssm_apply", "ssm_decode", "init_ssm_cache", "SSMCache"]


def ssm_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    s = cfg.ssm
    D = cfg.d_model
    di = s.d_inner(D)
    H = s.n_heads(D)
    G, N = s.n_groups, s.d_state
    convdim = di + 2 * G * N
    return {
        "wz": ParamDef((D, di), (FSDP, TP)),
        "wx": ParamDef((D, di), (FSDP, TP)),
        "wB": ParamDef((D, G * N), (FSDP, None)),
        "wC": ParamDef((D, G * N), (FSDP, None)),
        "wdt": ParamDef((D, H), (FSDP, TP)),
        "conv_w": ParamDef((s.d_conv, convdim), (None, None)),
        "conv_b": ParamDef((convdim,), (None,), init_scale=0.0),
        "A_log": ParamDef((H,), (TP,), dtype=jnp.float32, init_value=0.0),
        "Dskip": ParamDef((H,), (TP,), dtype=jnp.float32, init_value=1.0),
        "dt_bias": ParamDef((H,), (TP,), dtype=jnp.float32, init_value=0.0),
        "norm": ParamDef((di,), (TP,), init_value=1.0),
        "wo": ParamDef((di, D), (TP, FSDP)),
    }


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d. u: (B, T, C); w: (K, C)."""
    K = w.shape[0]
    up = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(u, dtype=jnp.float32)
    for i in range(K):  # K is tiny (4); unrolled taps
        out = out + up[:, i : i + u.shape[1]].astype(jnp.float32) * w[i].astype(
            jnp.float32
        )
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(u.dtype)


def _ssd_chunked(
    x: jax.Array,  # (B, L, H, P)
    dt: jax.Array,  # (B, L, H) fp32, post-softplus
    A: jax.Array,  # (H,) fp32, negative
    Bm: jax.Array,  # (B, L, H, N)
    Cm: jax.Array,  # (B, L, H, N)
    chunk: int,
    h0: Optional[jax.Array] = None,  # (B, H, P, N) initial state
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan. Returns (y (B,L,H,P), final state (B,H,P,N))."""
    B_, L, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, L)
    L_orig = L
    pad = (-L) % Q
    if pad:
        # Zero-dt padding is a no-op in the recurrence (decay exp(0)=1,
        # state contribution 0); padded outputs are sliced off below.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        L = L + pad
    nc = L // Q
    xc = x.reshape(B_, nc, Q, H, P)
    dtc = dt.reshape(B_, nc, Q, H)
    Bc = Bm.reshape(B_, nc, Q, H, N)
    Cc = Cm.reshape(B_, nc, Q, H, N)

    dA = dtc * A  # (B, nc, Q, H), negative
    dA_cs = jnp.cumsum(dA, axis=2)  # within-chunk cumulative
    seg = dA_cs[:, :, -1]  # (B, nc, H) total decay per chunk

    # Within-chunk (diagonal) term: masked attention with decay.
    # L[i,j] = exp(dA_cs[i] - dA_cs[j]) for i >= j else 0
    decay = jnp.exp(
        dA_cs[:, :, :, None, :] - dA_cs[:, :, None, :, :]
    )  # (B, nc, Qi, Qj, H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(mask[None, None, :, :, None], decay, 0.0)
    cb = jnp.einsum("bcqhn,bckhn->bcqkh", Cc, Bc)  # (B, nc, Qi, Qj, H)
    y_diag = jnp.einsum(
        "bcqkh,bckh,bckhp->bcqhp", cb * decay, dtc, xc
    )

    # Chunk states: S_c = sum_j exp(seg - dA_cs[j]) dt_j B_j x_j^T
    state_decay = jnp.exp(seg[:, :, None, :] - dA_cs)  # (B, nc, Q, H)
    S = jnp.einsum(
        "bcqh,bcqhn,bcqhp->bchpn", state_decay * dtc, Bc, xc
    )  # (B, nc, H, P, N)

    # Inter-chunk recurrence: h_{c} = exp(seg_c) h_{c-1} + S_c
    def step(h, inp):
        seg_c, S_c = inp  # (B, H), (B, H, P, N)
        h_new = jnp.exp(seg_c)[:, :, None, None] * h + S_c
        return h_new, h  # emit state *entering* the chunk

    h_init = (
        h0.astype(jnp.float32)
        if h0 is not None
        else jnp.zeros((B_, H, P, N), jnp.float32)
    )
    h_final, h_enter = jax.lax.scan(
        step, h_init, (jnp.moveaxis(seg, 1, 0), jnp.moveaxis(S, 1, 0))
    )
    h_enter = jnp.moveaxis(h_enter, 0, 1)  # (B, nc, H, P, N)

    # Off-diagonal term: y_off[i] = C_i · (exp(dA_cs[i]) h_enter)
    y_off = jnp.einsum(
        "bcqhn,bchpn,bcqh->bcqhp", Cc, h_enter, jnp.exp(dA_cs)
    )
    y = (y_diag + y_off).reshape(B_, L, H, P)[:, :L_orig]
    return y, h_final


class SSMCache(NamedTuple):
    conv: jax.Array  # (B, d_conv-1, convdim) last conv inputs
    state: jax.Array  # (B, H, P, N) fp32 SSM state


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> SSMCache:
    s = cfg.ssm
    D = cfg.d_model
    di = s.d_inner(D)
    H = s.n_heads(D)
    convdim = di + 2 * s.n_groups * s.d_state
    return SSMCache(
        conv=jnp.zeros((batch, s.d_conv - 1, convdim), dtype),
        state=jnp.zeros((batch, H, s.head_dim, s.d_state), jnp.float32),
    )


def _project(p, x, cfg):
    z = x @ p["wz"]
    xs = x @ p["wx"]
    Bp = x @ p["wB"]
    Cp = x @ p["wC"]
    dt_raw = (x @ p["wdt"]).astype(jnp.float32)
    u = jnp.concatenate([xs, Bp, Cp], axis=-1)  # conv input channels
    return z, u, dt_raw


def _split_conv(u, cfg):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    GN = s.n_groups * s.d_state
    xs = u[..., :di]
    Bp = u[..., di : di + GN]
    Cp = u[..., di + GN :]
    return xs, Bp, Cp


def ssm_apply(
    p: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig,
    collect_cache: bool = False,
    ctx: Optional[ShardCtx] = None,
):
    """Full-sequence SSD (training / prefill). x: (B, T, D)."""
    s = cfg.ssm
    B_, T, D = x.shape
    H = s.n_heads(D)
    P = s.head_dim
    N = s.d_state
    z, u_pre, dt_raw = _project(p, x, cfg)
    u = _causal_conv(u_pre, p["conv_w"], p["conv_b"])
    xs, Bp, Cp = _split_conv(u, cfg)
    xh = constrain(xs.reshape(B_, T, H, P), ctx, "b", None, "tp", None)
    # broadcast groups over heads (G=1)
    Bm = jnp.broadcast_to(
        Bp.reshape(B_, T, s.n_groups, 1, N), (B_, T, s.n_groups, H // s.n_groups, N)
    ).reshape(B_, T, H, N).astype(jnp.float32)
    Cm = jnp.broadcast_to(
        Cp.reshape(B_, T, s.n_groups, 1, N), (B_, T, s.n_groups, H // s.n_groups, N)
    ).reshape(B_, T, H, N).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, h_final = _ssd_chunked(xh.astype(jnp.float32), dt, A, Bm, Cm, s.chunk)
    y = y + p["Dskip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B_, T, H * P).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype), p["norm"])
    out = y @ p["wo"]
    if not collect_cache:
        return out
    # conv state = raw (pre-conv) inputs of the last K-1 positions
    conv_tail = u_pre[:, T - (s.d_conv - 1):]
    return out, SSMCache(conv=conv_tail, state=h_final)


def ssm_decode(
    p: Dict[str, jax.Array],
    x: jax.Array,  # (B, 1, D)
    cache: SSMCache,
    cfg: ModelConfig,
    ctx: Optional[ShardCtx] = None,
) -> Tuple[jax.Array, SSMCache]:
    """One recurrent step: h' = exp(dt·A) h + dt·(B ⊗ x); y = C·h' + D·x."""
    s = cfg.ssm
    B_, _, D = x.shape
    H = s.n_heads(D)
    P = s.head_dim
    N = s.d_state
    z, u, dt_raw = _project(p, x, cfg)  # u: (B, 1, convdim)
    # conv over (cached last K-1 inputs, current)
    hist = jnp.concatenate([cache.conv, u], axis=1)  # (B, K, convdim)
    w = p["conv_w"]
    conv_out = jnp.einsum(
        "bkc,kc->bc", hist.astype(jnp.float32), w.astype(jnp.float32)
    ) + p["conv_b"].astype(jnp.float32)
    uc = jax.nn.silu(conv_out)[:, None, :].astype(x.dtype)
    new_conv = hist[:, 1:]
    xs, Bp, Cp = _split_conv(uc, cfg)
    xh = xs.reshape(B_, H, P).astype(jnp.float32)
    Bm = jnp.broadcast_to(
        Bp.reshape(B_, s.n_groups, 1, N), (B_, s.n_groups, H // s.n_groups, N)
    ).reshape(B_, H, N).astype(jnp.float32)
    Cm = jnp.broadcast_to(
        Cp.reshape(B_, s.n_groups, 1, N), (B_, s.n_groups, H // s.n_groups, N)
    ).reshape(B_, H, N).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0] + p["dt_bias"])  # (B, H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)  # (B, H)
    h = dA[:, :, None, None] * cache.state + jnp.einsum(
        "bh,bhn,bhp->bhpn", dt, Bm, xh
    )
    h = constrain(h, ctx, "b", "tp", None, None)
    y = jnp.einsum("bhn,bhpn->bhp", Cm, h) + p["Dskip"][None, :, None] * xh
    y = y.reshape(B_, 1, H * P).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype), p["norm"])
    return y @ p["wo"], SSMCache(conv=new_conv, state=h)
