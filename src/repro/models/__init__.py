"""Model zoo: config schema, shared layers, and the block implementations
(GQA attention, MLA, MoE/EP, Mamba2-SSD, RG-LRU) assembled in
``transformer.py``."""

from repro.models.config import (
    BlockSpec,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    RGLRUConfig,
    SSMConfig,
    ShapeConfig,
    reduced_for_smoke,
)
from repro.models.param import (
    ParamDef,
    abstract_params,
    init_params,
    param_specs,
    stack_defs,
)
from repro.models.transformer import (
    ShardCtx,
    decode_step,
    forward,
    init_cache,
    logits_fn,
    model_defs,
)

__all__ = [
    "BlockSpec",
    "MLAConfig",
    "ModelConfig",
    "MoEConfig",
    "RGLRUConfig",
    "SSMConfig",
    "ShapeConfig",
    "reduced_for_smoke",
    "ParamDef",
    "abstract_params",
    "init_params",
    "param_specs",
    "stack_defs",
    "ShardCtx",
    "decode_step",
    "forward",
    "init_cache",
    "logits_fn",
    "model_defs",
]
