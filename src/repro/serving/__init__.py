"""Marvel-Serve: session-granular tiered KV-cache paging for LM decode.

The serving subsystem (DESIGN.md §14): :class:`KVPager` pages decode KV
caches through the tier hierarchy at (session, layer, block) granularity
— hot sessions pinned in DRAM, cold sessions demoted to the PMEM level
as int8-quantized blocks, promotion-on-resume ahead of the next decode
step.  :class:`PagedDecoder` wraps the stock ``decode_step`` as a
``StatefulFunction`` reading/writing through the pager, and
:class:`ServingPool` wires both into the gateway (eviction-routes-to-
demotion, KV-pressure load snapshots, admission shedding).
"""

from repro.serving.decode_runtime import (
    PagedDecoder,
    flatten_cache,
    unflatten_cache,
)
from repro.serving.kvpager import KVPager, PagerStats
from repro.serving.sessions import ServingPool

__all__ = [
    "KVPager",
    "PagerStats",
    "PagedDecoder",
    "ServingPool",
    "flatten_cache",
    "unflatten_cache",
]
