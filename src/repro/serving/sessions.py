"""``ServingPool`` — the gateway-facing face of the KV pager.

Three integrations turn the pager into a serving subsystem:

  * **eviction routes through the pager** — the gateway's warm-pool LRU
    eviction fires :attr:`Gateway.on_evict`; the pool demotes the evicted
    conversation's KV blocks to the PMEM level (quantized int8 by
    default) instead of letting them squat in DRAM as a dead blob.
  * **KV pressure is observable** — the pool installs a provider so
    :meth:`Gateway.load_snapshot` reports resident/paged session counts;
    the PR 9 autoscaler sees KV pressure the same way it sees queue
    depth.
  * **admission sheds instead of thrashing** — a new conversation that
    doesn't fit the DRAM block budget first demotes idle
    least-recently-used sessions; when nothing is demotable the
    conversation is shed (:class:`AdmissionError`), never admitted into a
    thrash loop.
"""

from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional

from repro.core.gateway import AdmissionError, Gateway
from repro.serving.decode_runtime import PagedDecoder
from repro.serving.kvpager import KVPager

__all__ = ["ServingPool"]


class ServingPool:
    """Session-granular decode serving over a gateway + pager pair.

    One conversation = one gateway session = one pager session (keyed by
    the gateway's scoped session id, so warm-pool evictions and pager
    demotions name the same thing).
    """

    def __init__(
        self,
        gateway: Gateway,
        pager: KVPager,
        decoder: PagedDecoder,
        *,
        app: str = "serve",
        admission: bool = True,
    ) -> None:
        self.gateway = gateway
        self.pager = pager
        self.decoder = decoder
        self.app = app
        self.admission = admission
        self.shed = 0
        self._inflight: Dict[str, int] = {}
        self._lock = threading.Lock()
        gateway.on_evict = self._on_evict
        gateway.set_kv_pressure(
            lambda: (pager.resident_sessions, pager.paged_sessions)
        )

    # -- gateway hooks ------------------------------------------------------
    def _scoped(self, conversation: str) -> str:
        return self.gateway.scoped_session(self.app, conversation)

    def _on_evict(self, fn_name: str, scoped_session: str) -> None:
        """Warm-pool eviction of a decode context: demote, don't drop.
        Runs on the evicting invoker's thread — the pager's per-session
        lock serializes against a concurrent resume."""
        if fn_name != self.decoder.fn.name:
            return
        self.pager.demote(scoped_session)

    # -- admission ----------------------------------------------------------
    def _admit(self, scoped: str) -> None:
        if not self.admission:
            return
        est = self.pager.typical_session_bytes()
        if self.pager.can_admit(est):
            return
        # Make room by demoting idle LRU sessions before giving up.
        for victim in self.pager.lru_hot():
            if victim == scoped or self._busy(victim):
                continue
            self.pager.demote(victim)
            if self.pager.can_admit(est):
                return
        self.shed += 1
        raise AdmissionError(
            f"serving pool: DRAM block budget exhausted "
            f"({self.pager.dram_bytes()}B resident, "
            f"budget {self.pager.dram_budget_bytes}B) — shedding {scoped!r}"
        )

    def _busy(self, scoped: str) -> bool:
        with self._lock:
            return self._inflight.get(scoped, 0) > 0

    def _track(self, scoped: str, future: Any) -> Any:
        with self._lock:
            self._inflight[scoped] = self._inflight.get(scoped, 0) + 1

        def _done(_f: Any) -> None:
            with self._lock:
                self._inflight[scoped] = max(
                    0, self._inflight.get(scoped, 1) - 1
                )

        future.add_done_callback(_done)
        return future

    # -- conversation lifecycle ---------------------------------------------
    def start(self, conversation: str, prompt: Any, **submit_kwargs: Any):
        """Admit a new conversation and run its prefill + first token.
        Returns the gateway Future of the first generated token; raises
        :class:`AdmissionError` (after demoting what it can) when the
        DRAM block budget cannot take one more resident session."""
        scoped = self._scoped(conversation)
        self._admit(scoped)
        fut = self.gateway.submit(
            self.decoder.fn.name, app=self.app, session=conversation,
            init_kwargs={"session": scoped, "prompt": prompt},
            **submit_kwargs,
        )
        return self._track(scoped, fut)

    def step(self, conversation: str, **submit_kwargs: Any):
        """One more decoded token for an admitted conversation.  A cold
        (demoted) conversation demand-faults its blocks back on this
        step — call :meth:`resume` ahead of time to hide that latency."""
        scoped = self._scoped(conversation)
        fut = self.gateway.submit(
            self.decoder.fn.name, app=self.app, session=conversation,
            **submit_kwargs,
        )
        return self._track(scoped, fut)

    def suspend(self, conversation: str) -> bool:
        """Explicitly push a conversation cold: commit + drop its warm
        decode context, then demote its KV blocks."""
        scoped = self._scoped(conversation)
        self.gateway.runtime.evict(
            self.decoder.fn.name, scoped, commit=True, demote=True
        )
        return self.pager.demote(scoped)

    def resume(self, conversation: str,
               prefetch: Optional[bool] = None) -> bool:
        """Promotion-on-resume: re-pin the conversation's blocks and
        start pulling them back to DRAM in the background, ahead of the
        next :meth:`step`."""
        return self.pager.resume(self._scoped(conversation),
                                 prefetch=prefetch)

    def is_resident(self, conversation: str) -> bool:
        return self.pager.is_hot(self._scoped(conversation))

    def drop(self, conversation: str) -> None:
        scoped = self._scoped(conversation)
        self.gateway.runtime.evict(
            self.decoder.fn.name, scoped, commit=False, demote=False
        )
        self.pager.drop(scoped)

    # -- introspection ------------------------------------------------------
    def conversations(self) -> List[str]:
        prefix = "" if self.app == "default" else f"{self.app}::"
        return [
            s[len(prefix):] for s in self.pager.sessions
            if s.startswith(prefix)
        ]

    def stats(self) -> Dict[str, int]:
        out = dict(self.pager.stats.as_dict())
        out["resident_sessions"] = self.pager.resident_sessions
        out["paged_sessions"] = self.pager.paged_sessions
        out["dram_bytes"] = self.pager.dram_bytes()
        out["shed"] = self.shed
        return out
