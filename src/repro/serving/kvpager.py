"""Session-granular paged KV-cache layout over the tier hierarchy.

The paper's thesis — function state resident in a PMEM-backed fast tier
instead of reloaded from slow storage — applied to the highest-traffic
stateful workload there is: LM decode KV caches.  A conversation's cache
is cut into fixed-size token blocks (the lite_llama-style ``(B, S, Kv,
dh)`` layout sliced along ``S``), one tier key per (session, layer,
block), so the hierarchy can place each session independently:

  * **hot** — the session's block prefix is pinned in the fast (DRAM)
    level via :meth:`TieredStore.pin`; every decode step writes back only
    the block containing the slot it touched.
  * **cold** — a warm-pool eviction routes through :meth:`demote`: blocks
    are re-encoded as int8 (``quantize_kv`` — per-(position, head) scales,
    ~4x smaller than bf16) and pushed one level down to the PMEM home.
    ``lossless=True`` demotes the raw bytes instead, for byte-identity
    guarantees (and tests).
  * **resuming** — :meth:`resume` re-pins lazily and hands the block list
    to :meth:`TieredStore.promote_async`, so a returning session's blocks
    climb back to DRAM on the prefetch worker *ahead of* its next decode
    step; ``prefetch=False`` keeps the demand-fault behaviour for
    comparison (the fig10 resume-TTFT contrast).

The pager is deliberately ignorant of transformer structure: it pages a
flat list of per-layer caches (:class:`AttnCache` /
:class:`QuantAttnCache` / opaque array leaves for recurrent mixers);
``decode_runtime`` owns the flatten/unflatten against the model's cache
pytree.
"""

from __future__ import annotations

import itertools
import json
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.models.attention import AttnCache
from repro.models.quant_cache import QuantAttnCache, quantize_kv
from repro.storage import serde

__all__ = ["KVPager", "PagerStats"]

#: per-layer kinds recorded in the session meta record
_ATTN, _QUANT, _OPAQUE = "attn", "quant", "opaque"


class PagerStats:
    """Cumulative pager counters (the fig10 observables)."""

    __slots__ = ("demotions", "resumes", "demand_faults", "quantized_blocks",
                 "blocks_written", "max_resident")

    def __init__(self) -> None:
        self.demotions = 0
        self.resumes = 0
        self.demand_faults = 0
        self.quantized_blocks = 0
        self.blocks_written = 0
        self.max_resident = 0

    def as_dict(self) -> Dict[str, int]:
        return {name: getattr(self, name) for name in self.__slots__}


class _Session:
    __slots__ = ("sid", "t", "resident", "hot", "quantized", "sizes",
                 "last_touch", "lock")

    def __init__(self, sid: str) -> None:
        self.sid = sid
        self.t = -1
        #: in-process handle on the assembled layer list while hot (the
        #: per-token fast path — no reassembly between steps).  The tier
        #: blocks stay the source of truth; this is dropped on demote.
        self.resident: Optional[List[Any]] = None
        self.hot = False
        self.quantized = False
        self.sizes: Dict[str, int] = {}
        self.last_touch = 0
        self.lock = threading.RLock()

    @property
    def nbytes(self) -> int:
        return sum(self.sizes.values())


def _layer_kind(layer: Any) -> str:
    if isinstance(layer, QuantAttnCache):
        return _QUANT
    if isinstance(layer, AttnCache):
        return _ATTN
    return _OPAQUE


def _seq_len(layer: Any) -> int:
    arr = layer.k_q if isinstance(layer, QuantAttnCache) else layer.k
    return int(arr.shape[-3])


def _quantize_layer(layer: AttnCache) -> QuantAttnCache:
    k_q, k_s = quantize_kv(layer.k)
    v_q, v_s = quantize_kv(layer.v)
    return QuantAttnCache(k_q=k_q, v_q=v_q, k_s=k_s, v_s=v_s)


def _slice_block(layer: Any, lo: int, hi: int) -> Dict[str, Any]:
    """One (layer, block) blob: the block's token slots from every array
    of the layer cache.  Values/int8 carry the sequence axis at -3,
    quant scales at -2; opaque leaves are stored whole."""
    if isinstance(layer, QuantAttnCache):
        return {
            "k_q": layer.k_q[..., lo:hi, :, :],
            "v_q": layer.v_q[..., lo:hi, :, :],
            "k_s": layer.k_s[..., lo:hi, :],
            "v_s": layer.v_s[..., lo:hi, :],
        }
    if isinstance(layer, AttnCache):
        return {"k": layer.k[..., lo:hi, :, :], "v": layer.v[..., lo:hi, :, :]}
    return {"x": layer}


def _join_blocks(kind: str, parts: List[Dict[str, Any]]) -> Any:
    if kind == _OPAQUE:
        return jnp.asarray(parts[0]["x"])
    cat = lambda name, axis: (
        jnp.asarray(parts[0][name]) if len(parts) == 1
        else jnp.concatenate([jnp.asarray(p[name]) for p in parts], axis=axis)
    )
    if kind == _QUANT:
        return QuantAttnCache(
            k_q=cat("k_q", -3), v_q=cat("v_q", -3),
            k_s=cat("k_s", -2), v_s=cat("v_s", -2),
        )
    return AttnCache(k=cat("k", -3), v=cat("v", -3))


class KVPager:
    """Block-table KV paging for decode sessions over a tier stack.

    ``store`` is duck-typed: a :class:`~repro.storage.hierarchy.
    TieredStore` engages the full pin/demote/promote machinery; a plain
    :class:`~repro.storage.kvcache.StateCache` (or single tier) degrades
    gracefully — demotion then just rewrites blocks in their demoted
    encoding wherever the store keeps them.
    """

    def __init__(
        self,
        store: Any,
        *,
        block_tokens: int = 16,
        lossless: bool = False,
        dram_budget_bytes: Optional[int] = None,
        prefetch_on_resume: bool = True,
        namespace: str = "kv",
    ) -> None:
        if block_tokens < 1:
            raise ValueError("block_tokens must be >= 1")
        self.store = store
        self.block_tokens = block_tokens
        self.lossless = lossless
        self.dram_budget_bytes = dram_budget_bytes
        self.prefetch_on_resume = prefetch_on_resume
        self.namespace = namespace.rstrip("/")
        self.stats = PagerStats()
        self._sessions: Dict[str, _Session] = {}
        self._lock = threading.Lock()
        self._clock = itertools.count(1)

    # -- key layout ---------------------------------------------------------
    def session_prefix(self, sid: str) -> str:
        return f"{self.namespace}/{sid}/"

    def _meta_key(self, sid: str) -> str:
        return self.session_prefix(sid) + "meta"

    def _block_key(self, sid: str, layer: int, block: int) -> str:
        return f"{self.session_prefix(sid)}L{layer:03d}/B{block:05d}"

    # -- introspection ------------------------------------------------------
    @property
    def sessions(self) -> List[str]:
        with self._lock:
            return sorted(self._sessions)

    @property
    def resident_sessions(self) -> int:
        with self._lock:
            return sum(1 for s in self._sessions.values() if s.hot)

    @property
    def paged_sessions(self) -> int:
        with self._lock:
            return sum(1 for s in self._sessions.values() if not s.hot)

    def is_hot(self, sid: str) -> bool:
        with self._lock:
            ent = self._sessions.get(sid)
        return bool(ent and ent.hot)

    def dram_bytes(self) -> int:
        """Bytes of block data attributable to hot (DRAM-pinned)
        sessions — the admission accounting, maintained from the blob
        sizes this pager wrote (no tier scan)."""
        with self._lock:
            return sum(s.nbytes for s in self._sessions.values() if s.hot)

    def typical_session_bytes(self) -> int:
        with self._lock:
            sized = [s.nbytes for s in self._sessions.values() if s.sizes]
        return max(sized) if sized else 0

    def can_admit(self, est_bytes: Optional[int] = None) -> bool:
        """Admission knob: would one more hot session fit the DRAM block
        budget?  ``None`` budget admits everything."""
        if self.dram_budget_bytes is None:
            return True
        est = est_bytes if est_bytes is not None else self.typical_session_bytes()
        return self.dram_bytes() + est <= self.dram_budget_bytes

    def lru_hot(self) -> List[str]:
        """Hot sessions, least-recently-touched first (demotion victims
        for admission-driven spills)."""
        with self._lock:
            hot = [(s.last_touch, sid) for sid, s in self._sessions.items()
                   if s.hot]
        return [sid for _, sid in sorted(hot)]

    # -- session registry ---------------------------------------------------
    def _entry(self, sid: str, create: bool = False) -> _Session:
        with self._lock:
            ent = self._sessions.get(sid)
            if ent is None:
                if not create:
                    raise KeyError(f"unknown pager session {sid!r}")
                ent = _Session(sid)
                self._sessions[sid] = ent
            return ent

    def _touch(self, ent: _Session) -> None:
        ent.last_touch = next(self._clock)

    def _note_resident_peak(self) -> None:
        self.stats.max_resident = max(
            self.stats.max_resident, self.resident_sessions
        )

    # -- write path ---------------------------------------------------------
    def create(self, sid: str, layers: Sequence[Any], t: int) -> None:
        """Install a freshly prefilled session: pin its prefix hot and
        write every block (the prefill result)."""
        ent = self._entry(sid, create=True)
        with ent.lock:
            ent.resident = list(layers)
            ent.t = int(t)
            ent.hot = True
            ent.quantized = any(
                isinstance(l, QuantAttnCache) for l in ent.resident
            )
            self._touch(ent)
            pin = getattr(self.store, "pin", None)
            if pin is not None:
                pin(self.session_prefix(sid))
            self._write_blocks(ent, dirty=None)
        self._note_resident_peak()

    def write(self, sid: str, layers: Sequence[Any], t: int) -> None:
        """Per-step write-back: only the block containing the slot the
        decode step at position ``t`` touched (per layer — windowed
        layers wrap at their own ring size)."""
        ent = self._entry(sid)
        with ent.lock:
            ent.resident = list(layers)
            ent.t = int(t)
            self._touch(ent)
            dirty = set()
            for li, layer in enumerate(ent.resident):
                kind = _layer_kind(layer)
                if kind == _OPAQUE:
                    dirty.add((li, 0))
                else:
                    slot = int(t) % _seq_len(layer)
                    dirty.add((li, slot // self.block_tokens))
            self._write_blocks(ent, dirty=dirty)

    def _write_blocks(
        self, ent: _Session, dirty: Optional[set] = None
    ) -> None:
        """Serialize + put the selected (layer, block) blobs and the meta
        record in one batched ``put_many``.  Caller holds ``ent.lock``."""
        assert ent.resident is not None
        items: Dict[str, bytes] = {}
        meta_layers = []
        for li, layer in enumerate(ent.resident):
            kind = _layer_kind(layer)
            if kind == _OPAQUE:
                nb, S = 1, 0
            else:
                S = _seq_len(layer)
                nb = -(-S // self.block_tokens)
            meta_layers.append({"kind": kind, "S": S, "blocks": nb})
            for b in range(nb):
                if dirty is not None and (li, b) not in dirty:
                    continue
                lo = b * self.block_tokens
                hi = min(S, lo + self.block_tokens) if kind != _OPAQUE else 0
                blob = serde.dumps(_slice_block(layer, lo, hi))
                items[self._block_key(ent.sid, li, b)] = blob
                if kind == _QUANT:
                    self.stats.quantized_blocks += 1
        meta = {
            "t": ent.t,
            "quantized": ent.quantized,
            "lossless": self.lossless,
            "layers": meta_layers,
        }
        items[self._meta_key(ent.sid)] = json.dumps(meta).encode()
        self.store.put_many(items)
        for key, blob in items.items():
            ent.sizes[key] = len(blob)
        self.stats.blocks_written += len(items) - 1

    # -- read path ----------------------------------------------------------
    def load(self, sid: str) -> Tuple[List[Any], int]:
        """The decode step's read: the resident handle when hot (no tier
        I/O), otherwise a demand-fault resume + full block assembly
        (reads promote pinned blocks back to the fast level)."""
        try:
            ent = self._entry(sid)
        except KeyError:
            if self.adopt(sid):
                ent = self._entry(sid)
            else:
                raise
        with ent.lock:
            if ent.resident is None:
                if not ent.hot:
                    self.stats.demand_faults += 1
                    self.resume(sid, prefetch=False)
                self._assemble(ent)
            self._touch(ent)
            assert ent.resident is not None
            return list(ent.resident), ent.t

    def _assemble(self, ent: _Session) -> None:
        meta = json.loads(self.store.get(self._meta_key(ent.sid)))
        layers: List[Any] = []
        for li, info in enumerate(meta["layers"]):
            parts = [
                serde.loads(self.store.get(self._block_key(ent.sid, li, b)))
                for b in range(info["blocks"])
            ]
            layers.append(_join_blocks(info["kind"], parts))
        ent.resident = layers
        ent.t = int(meta["t"])
        ent.quantized = bool(meta["quantized"])

    # -- placement transitions ----------------------------------------------
    def demote(self, sid: str) -> bool:
        """Hot → cold: re-encode blocks int8 (unless ``lossless`` or
        already quantized), unpin, and push every key one level down —
        the warm-pool eviction path (demote, don't drop).  Returns True
        if the session actually moved."""
        try:
            ent = self._entry(sid)
        except KeyError:
            return False
        with ent.lock:
            if not ent.hot:
                return False
            if not self.lossless and not ent.quantized:
                if ent.resident is None:
                    self._assemble(ent)
                assert ent.resident is not None
                ent.resident = [
                    _quantize_layer(l) if isinstance(l, AttnCache) else l
                    for l in ent.resident
                ]
                ent.quantized = any(
                    isinstance(l, QuantAttnCache) for l in ent.resident
                )
                self._write_blocks(ent, dirty=None)
            unpin = getattr(self.store, "unpin", None)
            if unpin is not None:
                unpin(self.session_prefix(sid))
            demoter = getattr(self.store, "demote", None)
            if demoter is not None:
                for key in list(ent.sizes):
                    demoter(key)
            ent.resident = None
            ent.hot = False
            self.stats.demotions += 1
            return True

    def resume(self, sid: str, prefetch: Optional[bool] = None) -> bool:
        """Cold → hot: lazily re-pin the session prefix and (by default)
        enqueue its blocks for background promotion so they are back in
        DRAM before the next decode step; ``prefetch=False`` leaves them
        to demand-fault on first read.  Cheap — no synchronous tier I/O
        either way."""
        prefetch = self.prefetch_on_resume if prefetch is None else prefetch
        try:
            ent = self._entry(sid)
        except KeyError:
            if not self.adopt(sid):
                raise
            ent = self._entry(sid)
        with ent.lock:
            if ent.hot:
                return False
            pin = getattr(self.store, "pin", None)
            if pin is not None:
                try:
                    pin(self.session_prefix(sid), eager=False)
                except TypeError:  # stores without the lazy-pin knob
                    pin(self.session_prefix(sid))
            ent.hot = True
            self._touch(ent)
            self.stats.resumes += 1
            if prefetch:
                promote = getattr(self.store, "promote_async", None)
                if promote is not None:
                    promote(list(self.store.keys(self.session_prefix(sid))))
        self._note_resident_peak()
        return True

    def drop(self, sid: str) -> None:
        """Forget a retired conversation entirely (all tiers)."""
        with self._lock:
            ent = self._sessions.pop(sid, None)
        unpin = getattr(self.store, "unpin", None)
        if unpin is not None:
            unpin(self.session_prefix(sid))
        keys = list(self.store.keys(self.session_prefix(sid)))
        if ent is not None:
            keys = sorted(set(keys) | set(ent.sizes))
        for key in keys:
            self.store.delete(key)

    # -- durability ---------------------------------------------------------
    def sync(self) -> None:
        """Flush the store's write-back queue: every acked block becomes
        crash-durable at the home level (the journal already covers the
        window in journaled configs)."""
        flush = getattr(self.store, "flush", None)
        if flush is not None:
            flush()

    def crash(self) -> None:
        """Simulate losing the serving process: resident handles and the
        session registry vanish; pins are released (a fresh process has
        none).  The store's own crash/recover is the caller's business."""
        with self._lock:
            sids = list(self._sessions)
            self._sessions.clear()
        unpin = getattr(self.store, "unpin", None)
        if unpin is not None:
            for sid in sids:
                unpin(self.session_prefix(sid))

    def adopt(self, sid: str) -> bool:
        """Register one session found in the store (post-restart); cold
        until resumed."""
        if not self.store.contains(self._meta_key(sid)):
            return False
        ent = self._entry(sid, create=True)
        with ent.lock:
            if ent.t < 0:
                meta = json.loads(self.store.get(self._meta_key(sid)))
                ent.t = int(meta["t"])
                ent.quantized = bool(meta["quantized"])
        return True

    def recover(self) -> int:
        """Rediscover every session the store still holds (the prefix
        listing fast path) and register them cold.  Returns the number
        of sessions adopted."""
        suffix = "/meta"
        ns = self.namespace + "/"
        adopted = 0
        for key in self.store.keys(ns):
            if not key.endswith(suffix):
                continue
            sid = key[len(ns):-len(suffix)]
            with self._lock:
                known = sid in self._sessions
            if not known and self.adopt(sid):
                adopted += 1
        return adopted
