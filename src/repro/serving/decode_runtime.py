"""A ``StatefulFunction``-compatible decode wrapper over the KV pager.

The seed ``serve_lm`` kept whole KV caches inside the function state blob
— opaque to the tier hierarchy, so a warm-pool eviction round-tripped the
entire cache and DRAM held every conversation ever admitted.  Here the
function state shrinks to ``{session, t, tok}`` (a few hundred bytes,
cheap to journal every commit) while the cache itself lives in the pager
as per-(layer, block) tier keys.

Each step reads the session's layer list through :meth:`KVPager.load`
(the resident handle when hot — no tier I/O), runs the stock
``decode_step``, and writes back only the dirty blocks.  Dispatch to the
int8 path is structural: a session that was demoted quantized comes back
as :class:`QuantAttnCache` leaves, which ``attn_decode`` routes to
``quant_decode_attention``; raw sessions keep the float ``decode_step``
path.  Both shapes get their own jitted trace, keyed by the leaf types.
"""

from __future__ import annotations

from typing import Any, List, Tuple

import jax
import jax.numpy as jnp

from repro.core.stateful import StatefulFunction
from repro.models import (
    ShapeConfig,
    decode_step,
    forward,
    init_cache,
    logits_fn,
)
from repro.models.attention import AttnCache
from repro.models.quant_cache import QuantAttnCache
from repro.serving.kvpager import KVPager

__all__ = ["PagedDecoder", "flatten_cache", "unflatten_cache"]


def _is_layer(x: Any) -> bool:
    return isinstance(x, (AttnCache, QuantAttnCache))


def flatten_cache(cache: Any) -> Tuple[List[Any], Any]:
    """Cache pytree → flat list of per-layer caches + treedef.  Attention
    caches stay whole (one pager layer each — the stacked body caches
    ride as single leaves with a leading period axis); anything else
    (ssm/rglru conv state) flattens to opaque array leaves the pager
    stores whole."""
    return jax.tree_util.tree_flatten(cache, is_leaf=_is_layer)


def unflatten_cache(treedef: Any, layers: List[Any]) -> Any:
    return jax.tree_util.tree_unflatten(treedef, layers)


class PagedDecoder:
    """Builds the paged decode :class:`StatefulFunction`.

    ``fn`` is registered with ``jit=False`` — the step does pager/tier
    I/O — while the pure model math inside (prefill forward, decode
    step) is jitted once per (batch shape, cache leaf types).
    """

    def __init__(
        self,
        params: Any,
        cfg: Any,
        pager: KVPager,
        *,
        prompt_len: int,
        max_tokens: int,
        name: str = "decode",
    ) -> None:
        self.params = params
        self.cfg = cfg
        self.pager = pager
        self.prompt_len = prompt_len
        self.total_len = prompt_len + max_tokens
        # Structure constant: the cache treedef does not depend on batch
        # size or values, so a throwaway template recovers it even when
        # this process never ran the prefill (post-restart resume).
        _, self._treedef = flatten_cache(init_cache(cfg, 1, 2))
        self._decode = jax.jit(
            lambda p, tok, cache, t: decode_step(p, cfg, tok, cache, t)
        )
        self.fn = StatefulFunction(name, self._step, init=self._init,
                                   jit=False)

    # -- prefill ------------------------------------------------------------
    def _init(self, session: str, prompt: jnp.ndarray) -> dict:
        B, plen = int(prompt.shape[0]), int(prompt.shape[1])
        shape = ShapeConfig(
            name="serve", kind="prefill", seq_len=plen, global_batch=B,
            q_chunk=min(8, plen), kv_chunk=min(8, plen), remat="none",
        )
        h, _aux, kv = forward(
            self.params, self.cfg, {"tokens": prompt}, shape,
            collect_cache=True, cache_len=self.total_len,
        )
        tok = jnp.argmax(
            logits_fn(self.params, self.cfg, h[:, -1]), -1
        ).astype(jnp.int32)[:, None]
        layers, _ = flatten_cache(kv)
        self.pager.create(session, layers, int(prompt.shape[1]) - 1)
        return {"session": session,
                "t": jnp.int32(int(prompt.shape[1]) - 1),
                "tok": tok}

    # -- one decode token ---------------------------------------------------
    def _step(self, state: dict) -> Tuple[dict, jnp.ndarray]:
        sid = state["session"]
        layers, _t_meta = self.pager.load(sid)
        cache = unflatten_cache(self._treedef, layers)
        t = jnp.int32(state["t"]) + 1
        logits, new_cache = self._decode(self.params, state["tok"], cache, t)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        new_layers, _ = flatten_cache(new_cache)
        self.pager.write(sid, new_layers, int(t))
        return {"session": sid, "t": t, "tok": tok}, tok
