"""JAX version-compatibility helpers — single home for API renames.

The reproduction targets whatever JAX the container bakes in; the two
surfaces that moved across releases are resolved here so call sites stay
version-agnostic:

  * ``shard_map``  — ``jax.shard_map`` (new) vs
    ``jax.experimental.shard_map.shard_map`` (old).
  * ``make_mesh``  — newer JAX takes an ``axis_types`` kwarg (we always
    want Auto so GSPMD keeps control); older releases predate the kwarg
    and are Auto-only already.

Pallas-specific renames live in ``repro.kernels.compat``.
"""

from __future__ import annotations

import jax

__all__ = ["shard_map", "make_mesh"]

try:
    _shard_map_impl = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map_impl

import inspect as _inspect

_SHARD_MAP_PARAMS = frozenset(
    _inspect.signature(_shard_map_impl).parameters
)


def shard_map(f, **kwargs):
    # ``check_rep`` was renamed ``check_vma``; accept the new spelling and
    # translate for older JAX.
    if "check_vma" in kwargs and "check_vma" not in _SHARD_MAP_PARAMS:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    return _shard_map_impl(f, **kwargs)


def make_mesh(shape, axes) -> "jax.sharding.Mesh":
    try:
        from jax.sharding import AxisType
    except ImportError:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
