"""qwen2.5-3b [dense] — 36L d_model=2048 16H (GQA kv=2) d_ff=11008
vocab=151936; QKV bias.  [hf:Qwen/Qwen2.5-0.5B family; hf]
"""

from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    d_model=2048,
    n_heads=16,
    n_kv_heads=2,
    head_dim=128,
    d_ff=11008,
    vocab=151936,
    pattern=(BlockSpec(mixer="attn", ffn="dense"),),
    n_periods=36,
    act="silu",
    qkv_bias=True,
    rope_theta=1e6,
)
