"""Assigned input-shape cells + per-arch applicability and memory knobs.

Shape semantics (per the brief):
  * train_4k / prefill_32k lower the full-sequence step,
  * decode_32k / long_500k lower ``serve_step`` (one token, KV cache of
    seq_len) — skipped for encoder-only archs (no decode),
  * long_500k needs sub-quadratic attention — only SSM/hybrid archs run it.

``microbatches`` and chunk sizes are the per-cell activation-memory knobs
(DESIGN.md §4); values here are the tuned baselines from §Perf.
"""

from __future__ import annotations

from dataclasses import replace
from typing import Dict, Optional

from repro.models.config import ModelConfig, ShapeConfig

__all__ = ["SHAPES", "shapes_for", "skip_reason"]

SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig(
        name="train_4k", kind="train", seq_len=4096, global_batch=256,
        microbatches=8, q_chunk=512, kv_chunk=1024, loss_chunk=512,
        remat="full",
    ),
    "prefill_32k": ShapeConfig(
        name="prefill_32k", kind="prefill", seq_len=32768, global_batch=32,
        q_chunk=512, kv_chunk=2048, loss_chunk=512, remat="full",
    ),
    "decode_32k": ShapeConfig(
        name="decode_32k", kind="decode", seq_len=32768, global_batch=128,
        remat="none",
    ),
    "long_500k": ShapeConfig(
        name="long_500k", kind="decode", seq_len=524288, global_batch=1,
        remat="none",
    ),
}

#: archs with O(seq) or O(window) decode state (may run long_500k)
SUBQUADRATIC = {"mamba2-2.7b", "recurrentgemma-9b"}
ENCODER_ONLY = {"hubert-xlarge"}


def skip_reason(cfg: ModelConfig, shape_name: str) -> Optional[str]:
    """None if the (arch, shape) cell runs; else the documented skip."""
    if cfg.name in ENCODER_ONLY and SHAPES[shape_name].kind == "decode":
        return "encoder-only: no decode step"
    if shape_name == "long_500k" and cfg.name not in SUBQUADRATIC:
        return "full attention is quadratic at 512k; skipped per brief"
    return None


#: per-(arch, shape) knob overrides — tuned so compiled memory fits 16 GB/chip
_OVERRIDES: Dict[tuple, dict] = {
    # NOTE: microbatch count must keep B_mb divisible by pod*data (=32
    # multi-pod), so 8 is the deepest slicing for global_batch=256.
    ("dbrx-132b", "train_4k"): {"microbatches": 8},
    ("qwen1.5-32b", "train_4k"): {"microbatches": 8},
    ("internvl2-26b", "train_4k"): {"microbatches": 8},
    ("mamba2-2.7b", "train_4k"): {"microbatches": 4},
    ("gemma-2b", "train_4k"): {"microbatches": 4},
}


#: §Perf-winning variant per cell kind (see EXPERIMENTS.md §Perf); applied
#: via ``dryrun --variant`` / ``hillclimb``.  Baselines stay paper-faithful.
BEST_VARIANTS: Dict[tuple, str] = {
    ("qwen1.5-32b", "prefill_32k"): "pad-heads+tp8",
    ("gemma-2b", "prefill_32k"): "pad-heads",
    ("qwen2.5-3b", "train_4k"): "zero1+tp2+mb2",
    ("deepseek-v2-lite-16b", "train_4k"): "zero1+tp8",
    # all dense decode cells: inference weights TP-only
    ("*", "decode_32k"): "no-fsdp",
}


def shapes_for(cfg: ModelConfig) -> Dict[str, ShapeConfig]:
    """Runnable shape cells for an arch, with per-cell knob overrides."""
    out = {}
    for name, sh in SHAPES.items():
        if skip_reason(cfg, name) is not None:
            continue
        ov = _OVERRIDES.get((cfg.name, name))
        out[name] = replace(sh, **ov) if ov else sh
    return out
