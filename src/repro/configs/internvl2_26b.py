"""internvl2-26b [vlm] — InternLM2-20B language backbone: 48L d_model=6144
48H (GQA kv=8) d_ff=16384 vocab=92553.  [arXiv:2404.16821; hf]

Per the brief, the InternViT vision frontend is a STUB: ``input_specs``
supplies 256 precomputed patch embeddings (B, 256, d_model) prepended to
the token stream; seq_len counts total positions.
"""

from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=92553,
    pattern=(BlockSpec(mixer="attn", ffn="dense"),),
    n_periods=48,
    act="silu",
    frontend="tokens+patches",
    n_patches=256,
)
