"""hubert-xlarge [audio] — 48L d_model=1280 16H d_ff=5120 vocab=504.
Encoder-only (bidirectional); same trunk as wav2vec2.
[arXiv:2106.07447; unverified]

The CNN waveform frontend is a STUB per the brief: ``input_specs`` feeds
precomputed 512-d frame features, projected to d_model.  No decode step —
decode_32k / long_500k cells are skipped (DESIGN.md §5).
"""

from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab=504,
    pattern=(BlockSpec(mixer="attn", ffn="dense"),),
    n_periods=48,
    act="gelu_plain",
    norm="ln",
    causal=False,
    frontend="frames",
    frame_dim=512,
)
