"""qwen1.5-32b [dense] — 64L d_model=5120 40H (MHA kv=40) d_ff=27392
vocab=152064; QKV bias.  [hf:Qwen/Qwen1.5 family; hf]

40 heads % TP(16) != 0, so attention TP lands on head_dim (DESIGN.md §4).
"""

from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    head_dim=128,
    d_ff=27392,
    vocab=152064,
    pattern=(BlockSpec(mixer="attn", ffn="dense"),),
    n_periods=64,
    act="silu",
    qkv_bias=True,
)
