"""Architecture registry: ``--arch <id>`` resolves here."""

from __future__ import annotations

import importlib
from typing import Dict

from repro.models.config import ModelConfig

__all__ = ["ARCH_IDS", "get_config", "all_configs"]

_MODULES = {
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "mamba2-2.7b": "repro.configs.mamba2_2_7b",
    "hubert-xlarge": "repro.configs.hubert_xlarge",
    "qwen2.5-3b": "repro.configs.qwen2_5_3b",
    "gemma-2b": "repro.configs.gemma_2b",
    "gemma2-9b": "repro.configs.gemma2_9b",
    "qwen1.5-32b": "repro.configs.qwen1_5_32b",
    "internvl2-26b": "repro.configs.internvl2_26b",
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list(_MODULES)}")
    return importlib.import_module(_MODULES[arch]).CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
