"""gemma-2b [dense] — 18L d_model=2048 8H (MQA kv=1) head_dim=256
d_ff=16384 vocab=256000; GeGLU.  [arXiv:2403.08295; hf]

8 heads < TP=16, so attention TP lands on head_dim (DESIGN.md §4) — this
arch is a candidate for the collective-bound hillclimb.
"""

from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab=256000,
    pattern=(BlockSpec(mixer="attn", ffn="dense"),),
    n_periods=18,
    act="gelu",
    rms_plus_one=True,
    embed_scale=True,
)
