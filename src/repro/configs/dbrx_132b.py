"""dbrx-132b [moe] — 40L d_model=6144 48H (GQA kv=8) d_ff=10752
vocab=100352, MoE 16 experts top-4 (fine-grained).
[hf:databricks/dbrx-base; unverified]
"""

from repro.models.config import BlockSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab=100352,
    pattern=(BlockSpec(mixer="attn", ffn="moe"),),
    n_periods=40,
    act="silu",
    rope_theta=5e5,
    moe=MoEConfig(
        n_experts=16,
        top_k=4,
        d_expert=10752,
        n_shared=0,
        normalize_top_k=True,
        capacity_factor=1.25,
    ),
)
