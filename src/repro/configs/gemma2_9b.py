"""gemma2-9b [dense] — 42L d_model=3584 16H (GQA kv=8) head_dim=256
d_ff=14336 vocab=256000; local(4096)+global alternating, logit softcaps,
pre+post sandwich norms.  [arXiv:2408.00118; hf]
"""

from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab=256000,
    pattern=(
        BlockSpec(mixer="local", ffn="dense", window=4096),
        BlockSpec(mixer="attn", ffn="dense"),
    ),
    n_periods=21,
    act="gelu",
    rms_plus_one=True,
    embed_scale=True,
    attn_softcap=50.0,
    final_softcap=30.0,
    post_block_norm=True,
)
