"""deepseek-v2-lite-16b [moe] — MLA + fine-grained MoE.

27L d_model=2048 16H d_ff(dense L0)=10944 vocab=102400; MLA kv_lora=512;
MoE: 64 routed top-6 + 2 shared, d_expert=1408, first layer dense.
[arXiv:2405.04434; hf]

Note: the assignment brief lists both "64e top-6" and "2 shared+160
routed"; 160 routed belongs to full V2 — we use the V2-*Lite* values
(64 routed) per the primary spec, recorded in DESIGN.md §5.
"""

from repro.models.config import BlockSpec, MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=192,  # qk_nope + qk_rope (bookkeeping; MLA dims below rule)
    d_ff=10944,  # the single dense layer
    vocab=102400,
    prelude=(BlockSpec(mixer="mla", ffn="dense"),),
    pattern=(BlockSpec(mixer="mla", ffn="moe"),),
    n_periods=26,
    act="silu",
    rope_theta=10000.0,
    mla=MLAConfig(
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        n_experts=64,
        top_k=6,
        d_expert=1408,
        n_shared=2,
        normalize_top_k=True,
        capacity_factor=1.25,
    ),
)
