"""recurrentgemma-9b [hybrid] — 38 blocks d_model=4096, RG-LRU + local
attention 1:2 (pattern R,R,L), 16H MQA kv=1 head_dim=256, d_ff=12288,
lru_width=4096, window=2048, vocab=256000.  [arXiv:2402.19427; unverified]

Runs long_500k: RG-LRU state + 2048-slot ring cache are O(1)/O(window).
38 = 12×(R,R,L) + 2 trailing recurrent blocks (postlude).
"""

from repro.models.config import BlockSpec, ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab=256000,
    pattern=(
        BlockSpec(mixer="rglru", ffn="dense"),
        BlockSpec(mixer="rglru", ffn="dense"),
        BlockSpec(mixer="local", ffn="dense", window=2048),
    ),
    n_periods=12,
    postlude=(
        BlockSpec(mixer="rglru", ffn="dense"),
        BlockSpec(mixer="rglru", ffn="dense"),
    ),
    act="gelu",
    rms_plus_one=True,
    embed_scale=True,
    rglru=RGLRUConfig(lru_width=4096, d_conv=4),
)
