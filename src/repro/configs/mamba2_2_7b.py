"""mamba2-2.7b [ssm] — 64L d_model=2560, attention-free SSD blocks,
ssm_state=128, vocab=50280.  [arXiv:2405.21060; unverified]

d_inner = 2*2560 = 5120, head_dim=64 → 80 SSD heads (TP-sharded 80/16=5).
Runs long_500k: decode state is O(1) in sequence length.
"""

from repro.models.config import BlockSpec, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    d_model=2560,
    n_heads=80,
    n_kv_heads=80,
    head_dim=64,
    d_ff=0,
    vocab=50280,
    pattern=(BlockSpec(mixer="ssm", ffn="none"),),
    n_periods=64,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
)
