"""Unified Marvel client — one declarative entry point over the gateway,
the dataflow engine, and the tiered state store.

After PRs 1-4 every example and benchmark hand-assembled its own stack:
build tiers, wrap a :class:`~repro.storage.hierarchy.TieredStore`,
construct a :class:`~repro.core.journal.StateJournal`, spin up a
:class:`~repro.core.gateway.Gateway`, then pick the right engine entry
point (``run_job`` vs ``run_stages`` vs ``run_loop``).  Cloudburst and
Faasm both show that the *client-facing* surface — a small, consistent
API over sessions, shared state, and job submission — is what makes
stateful FaaS usable; this module is that surface for Marvel:

  * :class:`ClusterConfig` — one declarative description of a cluster
    (tier stack + capacities, invoker count, placement policy, journal
    home, block store geometry, fault injection).  Validation is strict
    and typed: a bad config raises :class:`ConfigError`, never a
    half-built cluster (construction is transactional — partially built
    components are torn down before the error propagates).
  * :class:`MarvelClient` — a context manager owning the lifecycle of
    the tier stack, :class:`~repro.storage.kvcache.StateCache` journal,
    :class:`~repro.core.stateful.FunctionRuntime`, :class:`Gateway`, and
    pooled :class:`~repro.core.scheduler.Scheduler` built from that
    config.  Everything the engine layers expose is reachable from it:

      - ``client.dataset(parts).map(f).shuffle(by=k).reduce(g).run()`` —
        a lazy fluent plan lowered onto the MapReduce 2-stage dataflow;
      - ``client.stages(name, [...])`` — one-shot N-stage jobs;
      - ``client.iterate(name, init=..., superstep=..., until=...)`` —
        fixed-point loops with pinned, journaled loop state;
      - ``client.session(app)`` / ``client.function(...)`` — stateful
        function invocation through the gateway (FIFO lanes, leases,
        warm pool, admission control);
      - ``client.pagerank`` / ``client.kmeans`` / ``client.terasort`` —
        the paper-class workloads on the client's own stack.

  * :class:`JobHandle` + unified :class:`JobReport` — every submission
    path returns the same report schema (wall/modeled seconds, task and
    iteration counts, per-level tier rollup) regardless of which engine
    ran it, replacing the three divergent shapes
    (``mapreduce.JobReport`` / ``StageRunReport`` / ``LoopReport``).
    The raw engine report stays available as ``handle.raw``; unknown
    field reads fail loudly (``report.field("typo")`` raises).

The façade *lowers* onto the existing engines — it re-implements no
execution.  The legacy entry points (``run_job``, ``run_stages``,
``run_loop``) survive as deprecation shims that delegate here via
:meth:`MarvelClient.from_components`, byte-identical outputs and
journaled resume included (asserted by ``tests/test_api.py``).

See DESIGN.md §9 for the config schema, the lazy-plan lowering rules,
and the lifecycle/ownership diagram.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, field, replace
from typing import (
    Any,
    Callable,
    Dict,
    Iterable,
    List,
    Optional,
    Sequence,
    Tuple,
    Union,
)

from repro.core import dataflow as _dataflow
from repro.core import mapreduce as _mapreduce
from repro.core.cluster import ClusterRouter, LinkSpec, NetworkFabric, Node
from repro.core.dataflow import LoopContext, Stage
from repro.core.gateway import Gateway
from repro.core.scheduler import Scheduler
from repro.core.stateful import FunctionRuntime, Session, StatefulFunction
from repro.storage.blockstore import BlockStore, DataNode
from repro.storage.faults import FaultInjectingTier
from repro.storage.hierarchy import PlacementPolicy, TieredStore, TierLevel
from repro.storage.kvcache import StateCache
from repro.storage.tiers import (
    PMEM_SPEC,
    S3_SPEC,
    SSD_SPEC,
    DeviceSpec,
    DramTier,
    PmemTier,
    SimulatedTier,
    Tier,
    TierStats,
)

__all__ = [
    "ClientClosedError",
    "ClusterConfig",
    "ConfigError",
    "Dataset",
    "FaultSpec",
    "JobHandle",
    "JobReport",
    "MarvelClient",
    "REPORT_FIELDS",
    "ServingConfig",
    "TierSpec",
]


class ConfigError(ValueError):
    """A :class:`ClusterConfig` failed validation or could not be built.

    The contract is transactional: when this is raised, no cluster
    component survives — anything partially constructed has been torn
    down (no leaked invoker threads, flushers, or tier state).
    """


class ClientClosedError(RuntimeError):
    """The :class:`MarvelClient` is closed; submissions are refused."""


# -- declarative cluster description ------------------------------------------

#: tier kinds buildable by name alone.
_TIER_KINDS = ("dram", "pmem", "ssd", "s3")


@dataclass(frozen=True)
class TierSpec:
    """One level of the state-tier stack.

    ``kind`` names a built-in device model (``dram``, ``pmem``, ``ssd``,
    ``s3``); ``device`` overrides it with a custom
    :class:`~repro.storage.tiers.DeviceSpec` (the quota-scaled S3 of the
    fig4 benchmark, say); ``path`` makes ``pmem`` a real mmap-backed
    :class:`~repro.storage.tiers.PmemTier` instead of the modeled one.
    ``capacity_bytes`` bounds the level inside a multi-tier stack — the
    last (home) level must be unbounded.
    """

    kind: str = "dram"
    capacity_bytes: Optional[int] = None
    device: Optional[DeviceSpec] = None
    path: Optional[str] = None
    #: make the modeled device actually sleep its modeled seconds
    #: (scaled) — benchmarks use this so overlap is real wall time.
    sleep: bool = False
    sleep_scale: float = 1.0

    def build(self) -> Tier:
        if self.device is not None:
            return SimulatedTier(self.device, sleep=self.sleep,
                                 sleep_scale=self.sleep_scale)
        if self.kind == "dram":
            return DramTier()
        if self.kind == "pmem" and self.path:
            return PmemTier(self.path)
        spec = {"pmem": PMEM_SPEC, "ssd": SSD_SPEC, "s3": S3_SPEC}.get(self.kind)
        if spec is None:
            raise ConfigError(f"unknown tier kind {self.kind!r}")
        return SimulatedTier(spec, sleep=self.sleep,
                             sleep_scale=self.sleep_scale)


@dataclass(frozen=True)
class FaultSpec:
    """Seeded fault injection wrapped around the home (bottom) tier level.

    Mirrors :class:`~repro.storage.faults.FaultInjectingTier` — rates are
    per-op probabilities, ``schedule`` forces faults at exact per-kind op
    indices.  Deterministic given the op sequence.
    """

    seed: int = 0
    put_error_rate: float = 0.0
    get_error_rate: float = 0.0
    torn_put_many_rate: float = 0.0
    spike_rate: float = 0.0
    spike_seconds: float = 0.005
    schedule: Tuple[Tuple[str, int], ...] = ()

    def wrap(self, tier: Tier) -> FaultInjectingTier:
        return FaultInjectingTier(
            tier,
            seed=self.seed,
            put_error_rate=self.put_error_rate,
            get_error_rate=self.get_error_rate,
            torn_put_many_rate=self.torn_put_many_rate,
            spike_rate=self.spike_rate,
            spike_seconds=self.spike_seconds,
            schedule=self.schedule,
        )


@dataclass(frozen=True)
class ServingConfig:
    """Knobs for the KV-paging serving subsystem (DESIGN.md §14).

    ``block_tokens`` sets the paged-block granularity (token slots per
    (session, layer, block) tier key); ``dram_budget_bytes`` bounds the
    bytes of KV blocks resident for *hot* sessions — the serving pool
    demotes idle sessions and then sheds new conversations against it
    (``None`` admits everything).  ``lossless=True`` demotes raw bytes
    instead of int8-quantized blocks (byte-identity mode);
    ``prefetch_on_resume`` controls promotion-on-resume (off = cold
    sessions demand-fault their blocks inside the next decode step).
    """

    block_tokens: int = 16
    dram_budget_bytes: Optional[int] = None
    lossless: bool = False
    prefetch_on_resume: bool = True
    admission: bool = True

    def validate(self) -> None:
        if self.block_tokens < 1:
            raise ConfigError("serving.block_tokens must be >= 1")
        if self.dram_budget_bytes is not None and self.dram_budget_bytes <= 0:
            raise ConfigError(
                "serving.dram_budget_bytes must be positive (or None)"
            )


@dataclass(frozen=True)
class ClusterConfig:
    """Everything a Marvel cluster is, in one declarative value.

    ``tiers`` runs fastest → slowest; a single entry is used directly, two
    or more become a :class:`TieredStore` under ``placement`` (defaulting
    to write-back with first-read promotion — the fig8/fig9 configuration).
    ``journal`` picks the durability home for commit markers and
    write-back redo records: ``"volatile"`` (DRAM StateCache — stock
    Marvel), ``"pmem"`` (write-through to a PmemTier at ``journal_path``),
    or ``"none"``.  ``faults`` wraps the home tier level with seeded
    fault injection.  The block-store knobs (``nodes`` / ``block_size`` /
    ``replication``) shape the HDFS-analog input/output store.
    """

    name: str = "marvel"
    tiers: Tuple[Union[TierSpec, str], ...] = ("dram",)
    placement: Optional[PlacementPolicy] = None
    invokers: int = 4
    warm_pool: int = 64
    target_inflight: Optional[int] = None
    journal: str = "volatile"
    journal_path: Optional[str] = None
    nodes: int = 4
    block_size: int = 1 << 20
    replication: int = 2
    #: multi-node mode: build ``nodes`` full per-node stacks (each its own
    #: tier hierarchy, invoker pool, journal, and DataNode) behind a
    #: consistent-hash :class:`~repro.core.cluster.ClusterRouter`.  The
    #: default keeps today's single-stack geometry, where ``nodes`` only
    #: shapes the block store; ``sharded=True, nodes=1`` is byte-identical
    #: to it (golden-equivalence tested).
    sharded: bool = False
    #: cost model of the inter-node network links (sharded mode only);
    #: ``None`` = the ~10 GbE :class:`~repro.core.cluster.LinkSpec`
    #: default.
    network: Optional["LinkSpec"] = None
    #: function-state commit cadence (1 = commit after every invocation).
    commit_every: int = 1
    #: batch concurrent function-state commits into group flushes (the
    #: warm-path fast lane, DESIGN.md §10).  Invocation Futures then ack
    #: on durability, not on tier write completion; recovery bytes are
    #: unchanged.  Disable for the strictly sequential
    #: put(blob)+put(marker) op sequence (e.g. exact fault schedules).
    group_commit: bool = True
    #: lock stripes sharding the gateway's lane map / warm-pool LRU.
    gateway_stripes: int = 8
    faults: Optional[FaultSpec] = None
    #: device execution mode: lower the dataflow partition step onto the
    #: Pallas histogram kernel and eligible reduces onto the jitted
    #: device segment-sum (outputs stay byte-identical to host mode).
    device: bool = False
    #: run the Pallas kernels in interpret mode (required for
    #: ``device=True`` off TPU hardware — CPU CI).  ``None`` = auto
    #: (interpret off-TPU) but *only* valid when a TPU is attached.
    device_interpret: Optional[bool] = None
    #: sizing of the device partition send buffers relative to a
    #: balanced split; overflow beyond it spills through the
    #: intermediate tier instead of being dropped.
    device_capacity_factor: float = 1.3
    #: KV-paging serving subsystem defaults consumed by
    #: :meth:`MarvelClient.serving` (``None`` = subsystem defaults).
    serving: Optional[ServingConfig] = None

    def tier_specs(self) -> List[TierSpec]:
        out: List[TierSpec] = []
        for t in self.tiers:
            out.append(TierSpec(kind=t) if isinstance(t, str) else t)
        return out

    def validate(self) -> None:
        """Raise :class:`ConfigError` on any inconsistency; return None
        iff a :class:`MarvelClient` can be built from this config."""
        if not self.name or "/" in self.name:
            raise ConfigError(f"bad cluster name {self.name!r}")
        specs = self.tier_specs()
        if not specs:
            raise ConfigError("tiers must name at least one level")
        for spec in specs:
            if spec.device is None and spec.kind not in _TIER_KINDS:
                raise ConfigError(
                    f"unknown tier kind {spec.kind!r} "
                    f"(expected one of {_TIER_KINDS})"
                )
            if spec.capacity_bytes is not None and spec.capacity_bytes <= 0:
                raise ConfigError(
                    f"tier {spec.kind!r}: capacity_bytes must be positive"
                )
        if specs[-1].capacity_bytes is not None:
            raise ConfigError("the home (last) tier level must be unbounded")
        if self.invokers < 1:
            raise ConfigError("invokers must be >= 1")
        if self.warm_pool < 1:
            raise ConfigError("warm_pool must be >= 1")
        if self.target_inflight is not None and self.target_inflight < 1:
            raise ConfigError("target_inflight must be >= 1 (or None)")
        if self.journal not in ("volatile", "pmem", "none"):
            raise ConfigError(
                f"journal must be 'volatile', 'pmem', or 'none', "
                f"not {self.journal!r}"
            )
        if self.journal == "pmem" and not self.journal_path:
            raise ConfigError("journal='pmem' requires journal_path")
        if self.nodes < 1:
            raise ConfigError("nodes must be >= 1")
        if self.block_size < 1:
            raise ConfigError("block_size must be >= 1")
        if not 1 <= self.replication <= self.nodes:
            raise ConfigError(
                f"replication {self.replication} must be within "
                f"[1, nodes={self.nodes}]"
            )
        if self.commit_every < 1:
            raise ConfigError("commit_every must be >= 1")
        if self.gateway_stripes < 1:
            raise ConfigError("gateway_stripes must be >= 1")
        if self.device_capacity_factor <= 0:
            raise ConfigError("device_capacity_factor must be > 0")
        if self.device and self.device_interpret is not True:
            from repro.kernels.ops import on_tpu

            if not on_tpu():
                raise ConfigError(
                    "device=True needs TPU hardware; pass "
                    "device_interpret=True to run the Pallas kernels in "
                    "interpret mode (CPU CI)"
                )
        if self.serving is not None:
            self.serving.validate()
        if self.faults is not None:
            fs = self.faults
            for rate_name in ("put_error_rate", "get_error_rate",
                              "torn_put_many_rate", "spike_rate"):
                rate = getattr(fs, rate_name)
                if not 0.0 <= rate <= 1.0:
                    raise ConfigError(f"faults.{rate_name} must be in [0, 1]")
            for kind, idx in fs.schedule:
                if kind not in ("put", "get", "torn", "spike") or idx < 0:
                    raise ConfigError(
                        f"faults.schedule entry {(kind, idx)!r} invalid"
                    )


# -- unified report ------------------------------------------------------------

#: canonical numeric fields every unified report carries (the benchmark
#: serialization schema — ``benchmarks/common.py::emit_job`` writes these
#: and ``benchmarks/compare.py`` refuses TRACKED fields outside them).
REPORT_FIELDS = (
    "wall_seconds",
    "modeled_io_seconds",
    "total_seconds",
    "tasks",
    "resumed_tasks",
    "iterations",
)


@dataclass
class JobReport:
    """The one report schema every façade submission returns.

    ``kind`` says which engine ran the job (``"mapreduce"`` /
    ``"stages"`` / ``"loop"``); engine-specific facts live in ``extra``
    under stable names; ``tiers`` is the per-level I/O rollup captured
    from the client's tier stack across the run.  :meth:`field` is the
    loud accessor: unknown names raise instead of silently returning a
    default — the per-benchmark ad-hoc key bug class this schema removes.
    """

    job: str
    kind: str
    wall_seconds: float = 0.0
    modeled_io_seconds: float = 0.0
    tasks: int = 0
    resumed_tasks: int = 0
    iterations: int = 0
    converged: Optional[bool] = None
    tiers: Dict[str, Dict[str, float]] = field(default_factory=dict)
    extra: Dict[str, Any] = field(default_factory=dict)

    @property
    def total_seconds(self) -> float:
        return self.wall_seconds + self.modeled_io_seconds

    def field(self, name: str) -> Any:
        """Schema-checked field access: canonical fields and declared
        extras only — a typo raises ``KeyError`` with the valid names."""
        if name in REPORT_FIELDS:
            return getattr(self, name)
        if name in ("job", "kind", "converged"):
            return getattr(self, name)
        if name in self.extra:
            return self.extra[name]
        raise KeyError(
            f"unknown JobReport field {name!r}; canonical fields are "
            f"{REPORT_FIELDS}, extras here: {sorted(self.extra)}"
        )

    def to_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "job": self.job,
            "kind": self.kind,
            "converged": self.converged,
        }
        for name in REPORT_FIELDS:
            out[name] = getattr(self, name)
        out["tiers"] = self.tiers
        out.update(self.extra)
        return out


def _stats_dict(stats: TierStats) -> Dict[str, float]:
    return {
        "bytes_read": stats.bytes_read,
        "bytes_written": stats.bytes_written,
        "read_ops": stats.read_ops,
        "write_ops": stats.write_ops,
        "modeled_seconds": stats.modeled_seconds,
    }


def unify_report(raw: Any, tiers: Optional[Dict[str, Dict[str, float]]] = None
                 ) -> JobReport:
    """Normalize any engine report shape into the unified schema."""
    tiers = tiers or {}
    if isinstance(raw, _mapreduce.JobReport):
        return JobReport(
            job=raw.job,
            kind="mapreduce",
            wall_seconds=raw.wall_seconds,
            modeled_io_seconds=raw.modeled_io_seconds,
            tasks=raw.map_tasks + raw.reduce_tasks,
            resumed_tasks=raw.resumed_tasks,
            tiers=tiers,
            extra={
                "mode": raw.mode,
                "map_tasks": raw.map_tasks,
                "reduce_tasks": raw.reduce_tasks,
                "input_bytes": raw.input_bytes,
                "intermediate_bytes": raw.intermediate_bytes,
                "output_bytes": raw.output_bytes,
                "speculative_wins": raw.speculative_wins,
                "retried_tasks": raw.retried_tasks,
                "overlap_seconds": raw.overlap_seconds,
                "partitions_streamed": raw.partitions_streamed,
                "device_mode": int(raw.device_mode),
                "device_pairs": raw.device_pairs,
                "device_groups": raw.device_groups,
                "device_spilled_pairs": raw.device_spilled_pairs,
                "device_fallback_tasks": raw.device_fallback_tasks,
            },
        )
    if isinstance(raw, _dataflow.StageRunReport):
        return JobReport(
            job=raw.job,
            kind="stages",
            wall_seconds=raw.wall_seconds,
            modeled_io_seconds=raw.modeled_io_seconds,
            tasks=raw.tasks,
            resumed_tasks=raw.resumed_tasks,
            tiers=tiers,
            extra={"device_tasks": raw.device_tasks},
        )
    if isinstance(raw, _dataflow.LoopReport):
        return JobReport(
            job=raw.job,
            kind="loop",
            wall_seconds=raw.wall_seconds,
            modeled_io_seconds=raw.modeled_io_seconds,
            tasks=sum(r.get("tasks", 0) for r in raw.per_iteration),
            resumed_tasks=raw.resumed_iterations,
            iterations=raw.iterations,
            converged=raw.converged,
            tiers=tiers,
            extra={
                "last_iteration": raw.last_iteration,
                "resumed_iterations": raw.resumed_iterations,
                "per_iteration": list(raw.per_iteration),
            },
        )
    raise TypeError(f"cannot unify report of type {type(raw).__name__}")


@dataclass
class JobHandle:
    """What every façade submission returns: the unified report, the raw
    engine report, and the job's result payload (workload-specific —
    e.g. the final rank bytes for PageRank, the output path for a
    dataset job)."""

    job: str
    kind: str
    report: JobReport
    raw: Any
    result: Any = None


# -- the client ----------------------------------------------------------------

class MarvelClient:
    """Owns one Marvel cluster built from a :class:`ClusterConfig`.

    Construction is transactional (see :class:`ConfigError`); ``close``
    is idempotent and tears down the gateway (draining in-flight work),
    the pooled scheduler, and the tier stack.  Use as a context manager:

        with MarvelClient(ClusterConfig(tiers=("dram", "s3"))) as client:
            out = client.dataset(parts).map(f).shuffle().reduce(g).run()

    :meth:`from_components` wraps pre-built components *without* owning
    them — the legacy ``run_job``/``run_stages``/``run_loop`` shims
    delegate through it, so old call sites run the exact same engine path
    as façade users (byte-identical outputs, journaled resume intact).
    """

    def __init__(self, config: Optional[ClusterConfig] = None,
                 **overrides: Any) -> None:
        if config is None:
            config = ClusterConfig()
        if overrides:
            try:
                config = replace(config, **overrides)
            except TypeError as exc:
                raise ConfigError(f"unknown ClusterConfig field: {exc}") from exc
        config.validate()
        self.config = config
        self._closed = False
        self._owned = True
        self._dataset_seq = 0
        self.state: Optional[Tier] = None
        self.store: Optional[BlockStore] = None
        self.journal: Optional[StateCache] = None
        self.runtime: Optional[FunctionRuntime] = None
        self.gateway: Optional[Gateway] = None
        self.scheduler: Optional[Scheduler] = None
        self.cluster: Optional[ClusterRouter] = None
        try:
            self._build()
        except ConfigError:
            self._teardown_partial()
            raise
        except Exception as exc:
            self._teardown_partial()
            raise ConfigError(f"cluster construction failed: {exc}") from exc

    # -- construction ------------------------------------------------------
    def _build_stack(self, name: str, journal_path: Optional[str]):
        """Build one single-machine Marvel stack (tiers, journal cache,
        runtime, gateway, scheduler).  The non-sharded client *is* one
        stack; sharded mode builds one per node from the same specs —
        which is what makes ``sharded=True, nodes=1`` byte-identical to
        the single-node path."""
        cfg = self.config
        durable = PmemTier(journal_path) if cfg.journal == "pmem" else None
        journal = StateCache(write_through=durable) if cfg.journal != "none" else None
        specs = cfg.tier_specs()
        built = [spec.build() for spec in specs]
        if cfg.faults is not None:
            built[-1] = cfg.faults.wrap(built[-1])
        if len(built) == 1:
            state = built[0]
        else:
            policy = cfg.placement or PlacementPolicy(
                write_back=True, promote_after=1
            )
            state = TieredStore(
                [
                    TierLevel(spec.kind, tier, spec.capacity_bytes)
                    for spec, tier in zip(specs, built)
                ],
                policy=policy,
                journal=journal,
                name=name,
            )
        # Function/session state rides the stack's tier hierarchy (the
        # Marvel architecture: one state hierarchy under everything) and
        # shares the journal's durability home when one is configured.
        runtime = FunctionRuntime(
            cache=StateCache(memory=state, write_through=durable),
            commit_every=cfg.commit_every,
            group_commit=cfg.group_commit,
        )
        gateway = Gateway(
            runtime,
            invokers=cfg.invokers,
            warm_pool=cfg.warm_pool,
            target_inflight=cfg.target_inflight,
            stripes=cfg.gateway_stripes,
            name=name,
        )
        scheduler = gateway.shared_scheduler()
        return state, journal, runtime, gateway, scheduler, durable

    def _build(self) -> None:
        cfg = self.config
        if cfg.sharded:
            self._build_cluster()
            return
        (
            self.state,
            self.journal,
            self.runtime,
            self.gateway,
            self.scheduler,
            _durable,
        ) = self._build_stack(cfg.name, cfg.journal_path)
        self.store = BlockStore(
            [DataNode(f"{cfg.name}/n{i}", DramTier())
             for i in range(cfg.nodes)],
            block_size=cfg.block_size,
            replication=cfg.replication,
        )

    def _build_cluster(self) -> None:
        """Sharded mode: ``nodes`` full per-node stacks behind a
        consistent-hash router.  Node 0's components double as the
        client's own ``state``/``journal``/``runtime``/``gateway``/
        ``scheduler`` so every single-stack façade path still works (and
        at ``nodes=1`` is exactly the non-sharded build — same names,
        same journal path)."""
        cfg = self.config
        nodes: List[Node] = []
        try:
            for i in range(cfg.nodes):
                name = cfg.name if i == 0 else f"{cfg.name}-n{i}"
                jpath = cfg.journal_path
                if jpath is not None and i > 0:
                    jpath = f"{jpath}-n{i}"
                state, journal, runtime, gateway, scheduler, durable = (
                    self._build_stack(name, jpath)
                )
                nodes.append(
                    Node(
                        node_id=f"n{i}",
                        state=state,
                        runtime=runtime,
                        gateway=gateway,
                        datanode=DataNode(f"{cfg.name}/n{i}", DramTier()),
                        journal=journal,
                        durable=durable,
                        workers=cfg.invokers,
                    )
                )
                if i == 0:
                    self.state = state
                    self.journal = journal
                    self.runtime = runtime
                    self.gateway = gateway
                    self.scheduler = scheduler
        except Exception:
            for node in nodes:
                try:
                    node.close(drain=False)
                except Exception:
                    pass
            raise
        self.store = BlockStore(
            [n.datanode for n in nodes],
            block_size=cfg.block_size,
            replication=cfg.replication,
        )
        self.cluster = ClusterRouter(
            nodes, store=self.store, fabric=NetworkFabric(cfg.network)
        )
        #: next node index for elastic add_node (node ids stay unique
        #: across the cluster's lifetime, even after removals).
        self._node_seq = cfg.nodes

    def _teardown_partial(self) -> None:
        """Best-effort rollback of a failed build — nothing may leak."""
        if self.cluster is not None:
            try:
                self.cluster.close(drain=False)
            except Exception:
                pass
            self.cluster = None
            self.state = self.store = self.journal = None
            self.runtime = self.gateway = self.scheduler = None
            self._closed = True
            return
        if self.gateway is not None:
            try:
                self.gateway.close(drain=False)
            except Exception:
                pass
        if self.runtime is not None:
            try:
                self.runtime.close()
            except Exception:
                pass
        if isinstance(self.state, TieredStore):
            try:
                self.state.close(flush=False)
            except Exception:
                pass
        self.state = self.store = self.journal = None
        self.runtime = self.gateway = self.scheduler = None
        self._closed = True

    @classmethod
    def from_components(
        cls,
        *,
        store: Optional[BlockStore] = None,
        state: Optional[Tier] = None,
        scheduler: Optional[Scheduler] = None,
        journal: Optional[StateCache] = None,
        gateway: Optional[Gateway] = None,
        name: str = "legacy",
    ) -> "MarvelClient":
        """Wrap pre-built components without taking ownership.

        ``close`` on such a client is a no-op for the wrapped components
        (the caller built them, the caller closes them).  This is the
        deprecation-shim path: legacy entry points hand their arguments
        here and run through the same façade methods as new code.
        """
        client = cls.__new__(cls)
        client.config = ClusterConfig(name=name)
        client._closed = False
        client._owned = False
        client.store = store
        client.state = state
        client.scheduler = scheduler
        client.journal = journal
        client.gateway = gateway
        client.runtime = gateway.runtime if gateway is not None else None
        client.cluster = None
        client._dataset_seq = 0
        return client

    # -- lifecycle ---------------------------------------------------------
    @property
    def closed(self) -> bool:
        return self._closed

    def close(self, drain: bool = True) -> None:
        """Idempotent teardown: gateway (and its pooled scheduler) first,
        then the tier stack.  ``drain=False`` fails pending invocations
        fast instead of finishing them (the crash-path exit)."""
        if self._closed:
            return
        self._closed = True
        if not self._owned:
            return
        if self.cluster is not None:
            # node 0's components are the client's own; the router closes
            # every node with the same gateway-then-runtime-then-tiers
            # ordering as the single-stack path below.
            self.cluster.close(drain=drain)
            return
        if self.gateway is not None:
            self.gateway.close(drain=drain)
        if self.runtime is not None:
            # drain the group committer after the gateway (whose drained
            # close already awaited every in-flight durable ack).
            self.runtime.close()
        if isinstance(self.state, TieredStore):
            self.state.close(flush=drain)

    def __enter__(self) -> "MarvelClient":
        self._check_open()
        return self

    def __exit__(self, exc_type: Any, exc: Any, tb: Any) -> None:
        self.close(drain=exc_type is None)

    def _check_open(self) -> None:
        if self._closed:
            raise ClientClosedError(
                f"MarvelClient {self.config.name!r} is closed"
            )

    # -- tier accounting ---------------------------------------------------
    @staticmethod
    def _stack_rollup(state: Tier) -> Dict[str, Dict[str, float]]:
        if isinstance(state, TieredStore):
            return {
                name: _stats_dict(stats)
                for name, stats in state.stats_by_level().items()
            }
        return {state.name: _stats_dict(state.stats)}

    def tier_rollup(self) -> Dict[str, Dict[str, float]]:
        """Per-level physical I/O counters of the state stack (single
        tiers report one level under their own name).  Multi-node
        clusters report every node's levels under ``<node>/<level>``
        plus the network fabric under ``net`` — storage vs network bytes
        in one rollup."""
        if self.cluster is not None and len(self.cluster.nodes) > 1:
            out: Dict[str, Dict[str, float]] = {}
            for nid, node in sorted(self.cluster.nodes.items()):
                for level, stats in self._stack_rollup(node.state).items():
                    out[f"{nid}/{level}"] = stats
            out["net"] = _stats_dict(self.cluster.fabric.total)
            return out
        if self.state is None:
            return {}
        return self._stack_rollup(self.state)

    def _handle(self, raw: Any, result: Any = None) -> JobHandle:
        report = unify_report(raw, tiers=self.tier_rollup())
        return JobHandle(job=report.job, kind=report.kind, report=report,
                         raw=raw, result=result)

    def _device_exec(self, device: Optional[bool]):
        """A fresh per-submission device-execution context, or ``None``.

        ``device=None`` inherits the config's mode; a per-call
        ``device=True`` is validated the same way the config is (TPU
        required unless ``device_interpret=True``)."""
        cfg = self.config
        if device is None:
            device = cfg.device
        if not device:
            return None
        if cfg.device_interpret is not True:
            from repro.kernels.ops import on_tpu

            if not on_tpu():
                raise ConfigError(
                    "device=True needs TPU hardware; configure "
                    "device_interpret=True to run the Pallas kernels in "
                    "interpret mode (CPU CI)"
                )
        from repro.core.device_shuffle import DeviceExec

        return DeviceExec(
            interpret=cfg.device_interpret,
            capacity_factor=cfg.device_capacity_factor,
        )

    # -- stateful functions (gateway surface) ------------------------------
    def register(self, fn: StatefulFunction) -> StatefulFunction:
        self._check_open()
        if self.cluster is not None:
            # a session may hash onto any node: register everywhere.
            return self.cluster.register(fn)
        return self.runtime.register(fn)

    def function(self, name: str, init: Callable[..., Any],
                 jit: bool = True) -> Callable:
        """Decorator registering a stateful function on the runtime (on
        every node's runtime in sharded mode)."""
        self._check_open()
        if self.cluster is not None:
            def deco(step: Callable) -> StatefulFunction:
                return self.register(
                    StatefulFunction(name, step, init, jit=jit)
                )

            return deco
        return self.runtime.function(name, init, jit=jit)

    def session(self, session_id: str = "default",
                app: str = "default") -> Session:
        """A session whose ``invoke`` routes through the gateway (FIFO
        lane, state lease, warm pool, admission control).  Sharded
        clients resolve the ring owner per call, so the session survives
        node loss and re-homing."""
        self._check_open()
        if self.cluster is not None:
            return self.cluster.session(session_id, app=app)
        if self.gateway is None:
            raise ConfigError("this client wraps no gateway")
        return self.gateway.session(session_id, app=app)

    def invoke(self, fn_name: str, app: str = "default",
               session: str = "default", **inputs: Any) -> Any:
        self._check_open()
        if self.cluster is not None:
            return self.cluster.invoke(fn_name, app=app, session=session,
                                       **inputs)
        return self.gateway.invoke(fn_name, app=app, session=session,
                                   **inputs)

    def submit(self, fn_name: str, app: str = "default",
               session: str = "default",
               init_kwargs: Optional[dict] = None, block: bool = True,
               timeout: Optional[float] = None, **inputs: Any):
        """Async invoke: returns the gateway Future.  ``block=False``
        turns admission backpressure into an immediate
        :class:`~repro.core.gateway.AdmissionError` (load shedding) —
        the open-loop trace replay (``repro.core.loadgen``) submits
        through this.  Sharded clients resolve the ring owner per call."""
        self._check_open()
        if self.cluster is not None:
            return self.cluster.submit(
                fn_name, app=app, session=session, init_kwargs=init_kwargs,
                block=block, timeout=timeout, **inputs,
            )
        return self.gateway.submit(
            fn_name, app=app, session=session, init_kwargs=init_kwargs,
            block=block, timeout=timeout, **inputs,
        )

    # -- elastic membership (sharded mode) ---------------------------------
    def add_node(self) -> str:
        """Grow a sharded cluster by one node built from the same
        :class:`ClusterConfig` specs as the original fleet.  The node
        joins the ring (only its arcs re-home; their sessions migrate
        lazily on first touch), the block store, and gets every
        registered function.  Returns the new node id."""
        self._check_open()
        if self.cluster is None:
            raise ConfigError(
                "add_node needs a sharded cluster "
                "(ClusterConfig(sharded=True))"
            )
        cfg = self.config
        i = self._node_seq
        self._node_seq += 1
        jpath = cfg.journal_path
        if jpath is not None:
            jpath = f"{jpath}-n{i}"
        state, journal, runtime, gateway, _scheduler, durable = (
            self._build_stack(f"{cfg.name}-n{i}", jpath)
        )
        node = Node(
            node_id=f"n{i}",
            state=state,
            runtime=runtime,
            gateway=gateway,
            datanode=DataNode(f"{cfg.name}/n{i}", DramTier()),
            journal=journal,
            durable=durable,
            workers=cfg.invokers,
        )
        self.cluster.add_node(node)
        return node.node_id

    def remove_node(self, node_id: str) -> Dict[str, Any]:
        """Gracefully shrink a sharded cluster (see
        :meth:`~repro.core.cluster.ClusterRouter.remove_node` — refuses
        while the node owns in-flight work).  Node ``n0`` anchors the
        client's own ``state``/``gateway``/``scheduler`` and cannot be
        removed."""
        self._check_open()
        if self.cluster is None:
            raise ConfigError(
                "remove_node needs a sharded cluster "
                "(ClusterConfig(sharded=True))"
            )
        if node_id == "n0":
            raise ConfigError(
                "cannot remove n0: it anchors the client's own components"
            )
        return self.cluster.remove_node(node_id)

    def serving(
        self,
        params: Any,
        model_cfg: Any,
        *,
        prompt_len: int,
        max_tokens: int,
        config: Optional[ServingConfig] = None,
        app: str = "serve",
        fn_name: str = "decode",
    ):
        """Build the KV-paging serving pool (DESIGN.md §14) over this
        client's tier stack and gateway: a paged decode function is
        registered, warm-pool evictions route the victim's KV blocks
        through the pager, and the gateway's load snapshots grow
        resident/paged session counts.  ``config`` falls back to
        ``ClusterConfig.serving``, then subsystem defaults.  Returns a
        :class:`~repro.serving.ServingPool`."""
        self._check_open()
        if self.cluster is not None:
            raise ConfigError(
                "serving() drives a single-stack client; sharded serving "
                "is not supported yet"
            )
        from repro.serving import KVPager, PagedDecoder, ServingPool

        scfg = config or self.config.serving or ServingConfig()
        scfg.validate()
        pager = KVPager(
            self.state,
            block_tokens=scfg.block_tokens,
            lossless=scfg.lossless,
            dram_budget_bytes=scfg.dram_budget_bytes,
            prefetch_on_resume=scfg.prefetch_on_resume,
        )
        decoder = PagedDecoder(
            params, model_cfg, pager,
            prompt_len=prompt_len, max_tokens=max_tokens, name=fn_name,
        )
        self.register(decoder.fn)
        return ServingPool(
            self.gateway, pager, decoder, app=app,
            admission=scfg.admission,
        )

    def autoscaler(self, spec: Any = None, interval_s: float = 0.1,
                   **spec_overrides: Any):
        """An :class:`~repro.core.autoscale.Autoscaler` wired to this
        client's actuators: every (per-node) gateway's ``scale_to`` and
        warm pool, plus — for sharded clients when the spec enables a
        node band — :meth:`add_node` / :meth:`remove_node`.  The loop is
        tick-driven (``maybe_tick()``), not a thread: callers pump it
        from their replay/driver loop, which keeps runs deterministic."""
        self._check_open()
        from repro.core.autoscale import Autoscaler, PolicySpec

        if spec is None:
            spec = PolicySpec(**spec_overrides)
        elif spec_overrides:
            spec = replace(spec, **spec_overrides)
        if self.cluster is not None:
            cluster = self.cluster

            def gateways() -> Dict[str, Gateway]:
                return {n.node_id: n.gateway for n in cluster.live_nodes()}

            add = remove = None
            if spec.max_nodes is not None:
                add, remove = self.add_node, self.remove_node
            return Autoscaler(
                gateways, spec, interval_s=interval_s,
                add_node=add, remove_node=remove,
            )
        gateway = self.gateway
        return Autoscaler(
            {"n0": gateway}, spec, interval_s=interval_s,
        )

    # -- dataset / dataflow surface ----------------------------------------
    def dataset(self, parts: Sequence[bytes],
                name: Optional[str] = None) -> "Dataset":
        """A lazy dataset over newline-separated byte-record blobs.

        Nothing executes until ``.run()`` / ``.collect()``: the fluent
        chain builds a plan that lowers onto the MapReduce 2-stage
        dataflow at submission time."""
        self._check_open()
        if name is None:
            self._dataset_seq += 1
            name = f"ds{self._dataset_seq:04d}"
        return Dataset(self, tuple(parts), name=name)

    def mapreduce(
        self,
        job: "_mapreduce.MapReduceJob",
        input_path: str,
        output_path: str,
        mode: str = "wave",
        adaptive: bool = False,
        fail_map_attempts: Optional[Dict[str, int]] = None,
        intermediate: Optional[Tier] = None,
        store: Optional[BlockStore] = None,
        device: Optional[bool] = None,
    ) -> JobHandle:
        """Run a :class:`~repro.core.mapreduce.MapReduceJob` on the
        client's stack (or explicit overrides).  This is the lowering
        target of the dataset API and of the legacy ``run_job`` shim.
        ``device`` (default: the config's mode) lowers the partition /
        eligible-reduce steps onto the Pallas kernel layer — output
        bytes are identical to host execution.

        Multi-node sharded clients run the job on the cluster router
        (replica-local maps, ring-owned reduces, fabric-charged shuffle
        — byte-identical output to the single-node engine) unless the
        call overrides the store/intermediate/fault knobs or asks for
        device mode, which stay on node 0's single-stack engine."""
        self._check_open()
        use_cluster = (
            self.cluster is not None
            and len(self.cluster.nodes) > 1
            and store is None
            and intermediate is None
            and fail_map_attempts is None
            and not (self.config.device if device is None else device)
        )
        if use_cluster:
            net0 = self.cluster.fabric.total
            net_bytes0 = net0.bytes_written
            net_s0 = net0.modeled_seconds
            raw = self.cluster.run_mapreduce(job, input_path, output_path)
            handle = self._handle(raw, result=output_path)
            handle.report.extra.update(
                nodes=len(self.cluster.live_nodes()),
                net_bytes=net0.bytes_written - net_bytes0,
                net_seconds=net0.modeled_seconds - net_s0,
            )
            return handle
        raw = _mapreduce._run_job_impl(
            job,
            store if store is not None else self.store,
            input_path,
            output_path,
            intermediate if intermediate is not None else self.state,
            scheduler=self.scheduler,
            journal=self.journal,
            fail_map_attempts=fail_map_attempts,
            mode=mode,
            gateway=self.gateway,
            adaptive=adaptive,
            device=self._device_exec(device),
        )
        return self._handle(raw, result=output_path)

    def stages(
        self,
        name: str,
        stages: Sequence[Stage],
        state: Optional[Tier] = None,
        subscribers: Sequence[Callable] = (),
        external_tokens: Sequence[str] = (),
        device: Optional[bool] = None,
    ) -> JobHandle:
        """Execute a one-shot N-stage dataflow job (task-granular
        journaled resume when the client carries a journal).  ``device``
        binds a device-execution context around tasks that opted in with
        ``StageTask(device=True)``."""
        self._check_open()
        raw = _dataflow._run_stages_impl(
            name,
            stages,
            state if state is not None else self.state,
            scheduler=self.scheduler,
            journal=self.journal,
            gateway=self.gateway,
            subscribers=subscribers,
            external_tokens=external_tokens,
            device=self._device_exec(device),
        )
        return self._handle(raw)

    def iterate(
        self,
        name: str,
        *,
        init: Callable[[LoopContext], None],
        superstep: Callable[[LoopContext], Sequence[Stage]],
        until: Callable[[LoopContext], bool],
        state: Optional[Tier] = None,
        max_iterations: int = 50,
        pin_state: bool = True,
        halt_after: Optional[int] = None,
    ) -> JobHandle:
        """Drive a fixed-point loop to convergence (``until`` evaluated
        between supersteps) with loop state pinned hot in the client's
        tier stack and per-iteration journaled commit markers."""
        self._check_open()
        raw = _dataflow._run_loop_impl(
            name,
            init,
            superstep,
            until,
            state if state is not None else self.state,
            scheduler=self.scheduler,
            journal=self.journal,
            gateway=self.gateway,
            max_iterations=max_iterations,
            pin_state=pin_state,
            halt_after=halt_after,
        )
        return self._handle(raw)

    # -- paper-class workload conveniences ---------------------------------
    def pagerank(self, name: str, src: Any, dst: Any, n_nodes: int,
                 **kwargs: Any) -> JobHandle:
        """PageRank on the client's stack; ``handle.result`` is the
        :class:`~repro.core.workloads.PageRankResult`."""
        self._check_open()
        from repro.core import workloads

        res = workloads.pagerank_loop(
            name, self.state, src, dst, n_nodes,
            scheduler=self.scheduler, journal=self.journal, **kwargs,
        )
        handle = self._handle(res.report, result=res)
        handle.report.extra["output_bytes"] = len(res.rank_bytes)
        return handle

    def kmeans(self, name: str, points: Any, k: int,
               warm_session: bool = True, **kwargs: Any) -> JobHandle:
        """k-means on the client's stack.  ``warm_session=True`` keeps
        centroids hot in a pinned gateway session (warm invokers skip
        the tier reload); ``handle.result`` is the
        :class:`~repro.core.workloads.KMeansResult`."""
        self._check_open()
        from repro.core import workloads

        res = workloads.kmeans_loop(
            name, self.state, points, k,
            scheduler=self.scheduler, journal=self.journal,
            gateway=self.gateway if warm_session else None, **kwargs,
        )
        handle = self._handle(res.report, result=res)
        handle.report.extra["warm_read_frac"] = res.warm_read_frac
        return handle

    def terasort(self, name: str, input_parts: Sequence[bytes],
                 n_ranges: int = 4, device: Optional[bool] = None,
                 **kwargs: Any) -> JobHandle:
        """TeraSort (3-stage sample → range-partition → sort DAG);
        ``handle.result`` is the globally sorted record list.  With
        ``device`` the scatter stage buckets on the Pallas kernel."""
        self._check_open()
        from repro.core import workloads

        raw = workloads.terasort(
            name, self.state, input_parts, n_ranges=n_ranges,
            scheduler=self.scheduler, journal=self.journal,
            device=self._device_exec(device), **kwargs,
        )
        out = workloads.terasort_output(self.state, name, n_ranges)
        return self._handle(raw, result=out)


# -- lazy fluent dataset plan --------------------------------------------------

@dataclass(frozen=True)
class Dataset:
    """A lazy plan over partitioned byte records.

    Each fluent call returns a new plan; nothing touches the cluster
    until ``run``/``collect``, which lowers the plan onto the MapReduce
    2-stage dataflow (``map`` → map stage, ``shuffle`` → the partitioned
    exchange, ``reduce`` → reduce stage) and executes it through the
    owning client.  Records are newline-separated within each part.
    """

    client: MarvelClient
    parts: Tuple[bytes, ...]
    name: str
    mapper: Optional[Callable[[bytes], Iterable[Tuple[Any, Any]]]] = None
    combiner: Optional[Callable[[Any, List[Any]], Iterable[Tuple[Any, Any]]]] = None
    reducer: Optional[Callable[[Any, List[Any]], Iterable[Tuple[Any, Any]]]] = None
    key_fn: Optional[Callable[[Any], Any]] = None
    partitions: int = 4
    #: declared reduce semantics (see MapReduceJob.reduce_kind) — lets
    #: device runs lower the reduce onto the jitted segment-sum.
    reduce_kind: Optional[str] = None

    def map(self, fn: Callable[[bytes], Iterable[Tuple[Any, Any]]]
            ) -> "Dataset":
        """``fn(record) -> iterable[(key, value)]`` — the map phase."""
        if self.mapper is not None:
            raise ConfigError(f"dataset {self.name!r} already has a mapper")
        return replace(self, mapper=fn)

    def combine(self, fn: Callable[[Any, List[Any]],
                                   Iterable[Tuple[Any, Any]]]) -> "Dataset":
        """Map-side combiner (cuts shuffle volume; must be associative)."""
        return replace(self, combiner=fn)

    def shuffle(self, by: Optional[Callable[[Any], Any]] = None,
                partitions: int = 4) -> "Dataset":
        """The partitioned exchange: pairs are re-keyed by ``by`` (default:
        keep the map key) and hash-partitioned into ``partitions``."""
        if partitions < 1:
            raise ConfigError("shuffle needs at least one partition")
        return replace(self, key_fn=by, partitions=partitions)

    def reduce(self, fn: Callable[[Any, List[Any]],
                                  Iterable[Tuple[Any, Any]]],
               kind: Optional[str] = None) -> "Dataset":
        """``fn(key, values) -> iterable[(key, value)]`` — the reduce
        phase over each shuffle group.  ``kind="sum"`` declares that
        ``fn`` yields exactly ``(k, sum(vs))`` (order-independent), which
        lets device runs use the jitted segment-sum and the spill path."""
        if self.reducer is not None:
            raise ConfigError(f"dataset {self.name!r} already has a reducer")
        if kind not in (None, "sum"):
            raise ConfigError(f"unknown reduce kind {kind!r}")
        return replace(self, reducer=fn, reduce_kind=kind)

    # -- lowering ----------------------------------------------------------
    def _lower(self) -> "_mapreduce.MapReduceJob":
        if self.mapper is None:
            raise ConfigError(
                f"dataset {self.name!r}: .map(fn) is required before run()"
            )
        if self.reducer is None:
            raise ConfigError(
                f"dataset {self.name!r}: .reduce(fn) is required before run()"
            )
        mapper = self.mapper
        if self.key_fn is not None:
            key_fn, inner = self.key_fn, self.mapper

            def mapper(record: bytes):
                for k, v in inner(record):
                    yield key_fn(k), v

        return _mapreduce.MapReduceJob(
            self.name, mapper, self.reducer, combiner=self.combiner,
            n_reducers=self.partitions, reduce_kind=self.reduce_kind,
        )

    def run(self, output_path: Optional[str] = None, mode: str = "wave",
            adaptive: bool = False,
            device: Optional[bool] = None) -> JobHandle:
        """Lower the plan and execute it; returns the unified handle."""
        self.client._check_open()
        job = self._lower()
        input_path = f"/api/{self.name}/in"
        output_path = output_path or f"/api/{self.name}/out"
        store = self.client.store
        joined = b"\n".join(self.parts)
        if store.exists(input_path):
            # A re-run of the *same* dataset reuses its input (and its
            # journal); a different dataset colliding on the name would
            # silently compute over the wrong data — refuse instead.
            if store.read(input_path) != joined:
                raise ConfigError(
                    f"dataset name {self.name!r} already holds different "
                    f"input data at {input_path}; pass a unique name"
                )
        else:
            store.write(input_path, joined, record_delim=b"\n")
        return self.client.mapreduce(
            job, input_path, output_path, mode=mode, adaptive=adaptive,
            device=device,
        )

    def collect(self, mode: str = "wave",
                device: Optional[bool] = None) -> List[bytes]:
        """Run and return the output records (``repr(k)\\trepr(v)`` lines)
        in deterministic partition-then-key order."""
        handle = self.run(mode=mode, device=device)
        out: List[bytes] = []
        store = self.client.store
        for p in range(self.partitions):
            path = f"{handle.result}/part_{p:04d}"
            if store.exists(path):
                out.extend(
                    line for line in store.read(path).split(b"\n") if line
                )
        return out


# -- legacy entry-point delegation ---------------------------------------------

def _deprecated(old: str, new: str) -> None:
    # stacklevel: 1=this line, 2=_legacy_run_*, 3=the shim in core/*,
    # 4=the user's call site — the frame the warning should name.
    warnings.warn(
        f"{old} is deprecated; use {new} (see DESIGN.md §9)",
        DeprecationWarning,
        stacklevel=4,
    )


def _legacy_run_job(
    job: "_mapreduce.MapReduceJob",
    store: BlockStore,
    input_path: str,
    output_path: str,
    intermediate: Tier,
    scheduler: Optional[Scheduler] = None,
    journal: Optional[StateCache] = None,
    fail_map_attempts: Optional[Dict[str, int]] = None,
    mode: str = "wave",
    gateway: Optional[Gateway] = None,
    adaptive: bool = False,
) -> "_mapreduce.JobReport":
    _deprecated("repro.core.mapreduce.run_job",
                "repro.api.MarvelClient.dataset(...).run() / .mapreduce(...)")
    client = MarvelClient.from_components(
        store=store, state=intermediate, scheduler=scheduler,
        journal=journal, gateway=gateway,
    )
    return client.mapreduce(
        job, input_path, output_path, mode=mode, adaptive=adaptive,
        fail_map_attempts=fail_map_attempts,
    ).raw


def _legacy_run_stages(
    name: str,
    stages: Sequence[Stage],
    state: Tier,
    scheduler: Optional[Scheduler] = None,
    journal: Optional[StateCache] = None,
    gateway: Optional[Gateway] = None,
    subscribers: Sequence[Callable] = (),
    external_tokens: Sequence[str] = (),
) -> "_dataflow.StageRunReport":
    _deprecated("repro.core.dataflow.run_stages",
                "repro.api.MarvelClient.stages(...)")
    client = MarvelClient.from_components(
        state=state, scheduler=scheduler, journal=journal, gateway=gateway,
    )
    return client.stages(
        name, stages, subscribers=subscribers,
        external_tokens=external_tokens,
    ).raw


def _legacy_run_loop(
    name: str,
    init: Callable[[LoopContext], None],
    superstep: Callable[[LoopContext], Sequence[Stage]],
    converged: Callable[[LoopContext], bool],
    state: Tier,
    scheduler: Optional[Scheduler] = None,
    journal: Optional[StateCache] = None,
    gateway: Optional[Gateway] = None,
    max_iterations: int = 50,
    pin_state: bool = True,
    halt_after: Optional[int] = None,
) -> "_dataflow.LoopReport":
    _deprecated("repro.core.dataflow.run_loop",
                "repro.api.MarvelClient.iterate(...)")
    client = MarvelClient.from_components(
        state=state, scheduler=scheduler, journal=journal, gateway=gateway,
    )
    return client.iterate(
        name, init=init, superstep=superstep, until=converged,
        max_iterations=max_iterations, pin_state=pin_state,
        halt_after=halt_after,
    ).raw
