"""Gradient compression with error feedback — DP-axis bandwidth saver.

At 1000+-node scale the data-parallel gradient reduction crosses DCN links
(the slow tier in the paper's terms).  We compress per-leaf gradients to
int8 with a per-leaf fp32 scale before the (implicit) all-reduce and keep
the quantization residual in an error-feedback buffer so the bias cancels
over steps (1-bit-Adam-style EF-SGD argument).

Used optionally by ``train_step`` (off for paper-faithful baselines; on as
a beyond-paper optimization — §Perf records the collective-bytes delta).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["EFState", "ef_init", "compress_decompress"]


class EFState(NamedTuple):
    residual: Any  # fp32, sharded like grads


def ef_init(params: Any) -> EFState:
    return EFState(
        residual=jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
    )


def _quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_decompress(
    grads: Any, ef: EFState
) -> Tuple[Any, EFState, jax.Array]:
    """Simulate int8 all-reduce: quantize(g + residual), keep the error.

    Returns (decompressed grads, new EF state, mean |residual| metric).
    The int8 tensor is what would cross the DP axis; XLA sees the int8
    round-trip so collective-bytes accounting in the dry-run reflects the
    4x reduction when the reduction is staged through the quantized value.
    """
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(ef.residual)
    out_g, out_r, errs = [], [], []
    for g, r in zip(flat_g, flat_r):
        g32 = g.astype(jnp.float32) + r
        q, scale = _quantize(g32)
        deq = q.astype(jnp.float32) * scale
        out_g.append(deq.astype(g.dtype))
        out_r.append(g32 - deq)
        errs.append(jnp.mean(jnp.abs(g32 - deq)))
    new_g = jax.tree_util.tree_unflatten(treedef, out_g)
    new_r = jax.tree_util.tree_unflatten(treedef, out_r)
    err = jnp.mean(jnp.stack(errs)) if errs else jnp.zeros(())
    return new_g, EFState(residual=new_r), err
