"""Sharded AdamW + schedules.

Optimizer state mirrors parameter sharding exactly (same PartitionSpecs),
so with 2D-sharded params (FSDP×TP) the fp32 moments are ZeRO-partitioned
for free.  Parameters are stored fp32 and cast to the compute dtype inside
the step (single master copy, no duplicate bf16 weights at rest).
"""

from __future__ import annotations

import math
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "OptState", "adamw_init", "adamw_update",
           "cosine_schedule", "global_norm", "clip_by_global_norm"]


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: Optional[float] = 1.0


class OptState(NamedTuple):
    mu: Any  # first moment, fp32, sharded like params
    nu: Any  # second moment, fp32, sharded like params
    step: jax.Array  # scalar int32


def adamw_init(params: Any) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        mu=jax.tree_util.tree_map(zeros, params),
        nu=jax.tree_util.tree_map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def adamw_update(
    params: Any,
    grads: Any,
    state: OptState,
    cfg: AdamWConfig,
    lr: Optional[jax.Array] = None,
) -> Tuple[Any, OptState, jax.Array]:
    """One AdamW step. Returns (new_params, new_state, grad_norm)."""
    if cfg.grad_clip is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr_t = cfg.lr if lr is None else lr
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = cfg.b1 * m + (1 - cfg.b1) * g32
        v_new = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
        mhat = m_new / b1c
        vhat = v_new / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr_t * (delta + cfg.weight_decay * p32)
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, OptState(new_m, new_v, step), gnorm


def cosine_schedule(
    base_lr: float, warmup: int, total: int, min_frac: float = 0.1
) -> Callable[[jax.Array], jax.Array]:
    def lr(step: jax.Array) -> jax.Array:
        s = step.astype(jnp.float32)
        warm = base_lr * jnp.minimum(s / max(warmup, 1), 1.0)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(math.pi * prog)))
        return jnp.where(s < warmup, warm, cos)

    return lr
