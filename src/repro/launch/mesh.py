"""Mesh construction for the production pods.

Single pod: TPU v5e 16×16 = 256 chips, axes (data, model).
Multi-pod:  2 pods = 512 chips, axes (pod, data, model); the ``pod`` axis
composes with ``data`` for gradient reduction (DCN tier) while FSDP and TP
stay intra-pod (ICI tier) — the tiered-communication layout mirroring the
paper's storage tiers (DESIGN.md §2).

Defined as functions (never module-level) so importing this module does not
touch jax device state; the dry-run sets the 512-host-device XLA flag
before its first jax import.
"""

from __future__ import annotations

from jax.sharding import Mesh

# single compat shim, re-exported here for launch-layer callers
from repro.jax_compat import make_mesh as make_mesh_compat

__all__ = ["make_mesh_compat", "make_production_mesh", "make_smoke_mesh"]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_smoke_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Tiny mesh over however many (host) devices exist — tests only."""
    return make_mesh_compat((data, model), ("data", "model"))
