import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""§Perf hillclimb driver: run (arch, shape, variant) cells and append the
roofline records to results/perf_iterations.json.

Usage:
  PYTHONPATH=src python -m repro.launch.hillclimb qwen1.5-32b:prefill_32k:pad-heads ...
"""

import json
import sys

from repro.launch.dryrun import run_cell


def main():
    out_path = "results/perf_iterations.json"
    try:
        records = json.load(open(out_path))
    except Exception:
        records = []
    for spec in sys.argv[1:]:
        arch, shape, *rest = spec.split(":")
        variant = rest[0] if rest else ""
        try:
            rec = run_cell(arch, shape, multi_pod=False, variant=variant)
        except Exception as e:
            import traceback
            rec = {"arch": arch, "shape": shape, "variant": variant,
                   "status": "error", "error": repr(e),
                   "trace": traceback.format_exc()[-1500:]}
            print("ERROR", spec, repr(e)[:200], flush=True)
        records.append(rec)
        json.dump(records, open(out_path, "w"), indent=1, default=str)


if __name__ == "__main__":
    main()
