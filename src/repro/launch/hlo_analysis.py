"""Structural cost analysis of partitioned HLO text, with correct
while-loop weighting.

``compiled.cost_analysis()`` counts each while-loop body ONCE, which
under-reports scan-over-layers / microbatch-accumulation programs by the
trip count.  This parser rebuilds the call graph (ENTRY → fusions /
while bodies / conditionals), reads each while's
``backend_config={"known_trip_count":{"n":...}}``, and weights every
computation by its total invocation multiplicity.  From that it derives:

  * dot FLOPs (2 · prod(result dims) · prod(contracted dims)),
  * collective bytes by kind (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute result bytes),

both per-participant (the module is one SPMD partition).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["ModuleCosts", "analyze_hlo"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_OP_LINE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_SHAPE = re.compile(r"(\w+)\[([0-9,]*)\]")
_TRIP = re.compile(r'"known_trip_count":\{"n":"?(\d+)"?\}')
_CALL_ATTRS = (
    ("calls=", 1.0),            # fusion
    ("body=", None),            # while body — weight = trip count
    ("to_apply=", 1.0),         # reduce/sort/all-reduce applied fn (tiny)
)
_NAME_REF = re.compile(r"%([\w.\-]+)")


def _shape_dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _nbytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class _Op:
    name: str
    rest: str  # everything right of '='

    @property
    def result_type(self) -> str:
        # type is the prefix of `rest` up to the opcode token
        return self.rest

    def opcode(self) -> Optional[str]:
        # "(f32[..], ...) op-name(" or "f32[..]{..} op-name("
        m = re.match(r"\(?[^()]*?\)?\s*([\w\-]+)\(", self.rest)
        return m.group(1) if m else None


@dataclass
class _Computation:
    name: str
    ops: List[_Op] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)  # op name -> type str


@dataclass
class ModuleCosts:
    dot_flops: float = 0.0
    dot_flops_unweighted: float = 0.0
    collective_bytes: Dict[str, int] = field(default_factory=dict)
    collective_bytes_unweighted: Dict[str, int] = field(default_factory=dict)
    n_while: int = 0

    @property
    def total_collective_bytes(self) -> int:
        return sum(self.collective_bytes.values())


def _parse_computations(text: str) -> Tuple[Dict[str, _Computation], Optional[str]]:
    comps: Dict[str, _Computation] = {}
    entry = None
    cur: Optional[_Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" ") and ("->" in line) and line.endswith("{"):
            m = _COMP_HEADER.match(line.strip())
            if m:
                cur = _Computation(m.group(1))
                comps[cur.name] = cur
                if line.lstrip().startswith("ENTRY"):
                    entry = cur.name
                continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_LINE.match(line)
        if m:
            op = _Op(m.group(1), m.group(2))
            cur.ops.append(op)
            # record result type (text up to the opcode) for shape lookups
            tm = re.match(r"(\(?[^=]*?\)?)\s*[\w\-]+\(", op.rest)
            if tm:
                cur.shapes[op.name] = tm.group(1)
    return comps, entry


def _dot_flops(op: _Op, comp: _Computation) -> float:
    # result dims
    tm = re.match(r"(.*?)\s*dot\(", op.rest)
    if not tm:
        return 0.0
    res = _shape_dims(tm.group(1))
    if not res:
        return 0.0
    out_elems = 1
    for d in res[0][1]:
        out_elems *= d
    # lhs operand + contracting dims
    am = re.search(r"dot\(\s*%([\w.\-]+)", op.rest)
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    if not am or not cm:
        return 2.0 * out_elems  # degenerate
    lhs_type = comp.shapes.get(am.group(1), "")
    lhs = _shape_dims(lhs_type)
    contract = 1
    if lhs:
        dims = lhs[0][1]
        for idx in cm.group(1).split(","):
            if idx and int(idx) < len(dims):
                contract *= dims[int(idx)]
    # batch dims are part of out_elems already
    return 2.0 * out_elems * contract


def analyze_hlo(text: str) -> ModuleCosts:
    comps, entry = _parse_computations(text)
    costs = ModuleCosts(
        collective_bytes={k: 0 for k in COLLECTIVE_KINDS},
        collective_bytes_unweighted={k: 0 for k in COLLECTIVE_KINDS},
    )
    if entry is None:
        return costs

    # ---- direct per-computation costs + call edges -------------------------
    direct_flops: Dict[str, float] = {}
    direct_coll: Dict[str, Dict[str, int]] = {}
    edges: Dict[str, List[Tuple[str, float]]] = {}
    for name, comp in comps.items():
        fl = 0.0
        coll = {k: 0 for k in COLLECTIVE_KINDS}
        out_edges: List[Tuple[str, float]] = []
        for op in comp.ops:
            opcode = op.opcode()
            if opcode == "dot":
                fl += _dot_flops(op, comp)
            elif opcode:
                base = None
                for k in COLLECTIVE_KINDS:
                    if opcode == k or opcode == k + "-start":
                        base = k
                        break
                if base is not None:
                    tm = re.match(r"(\(?[^=]*?\)?)\s*[\w\-]+\(", op.rest)
                    if tm:
                        coll[base] += _nbytes(tm.group(1))
            if opcode == "while":
                costs.n_while += 1
                bm = re.search(r"body=%?([\w.\-]+)", op.rest)
                cm = re.search(r"condition=%?([\w.\-]+)", op.rest)
                tm = _TRIP.search(op.rest)
                trips = float(tm.group(1)) if tm else 1.0
                if bm:
                    out_edges.append((bm.group(1), trips))
                if cm:
                    out_edges.append((cm.group(1), trips + 1))
            else:
                for attr, w in _CALL_ATTRS:
                    if attr in op.rest and attr != "body=":
                        for m in re.finditer(attr + r"%?([\w.\-]+)", op.rest):
                            out_edges.append((m.group(1), w or 1.0))
                cm2 = re.search(r"branch_computations=\{([^}]*)\}", op.rest)
                if cm2:
                    for nm in _NAME_REF.findall(cm2.group(1)):
                        out_edges.append((nm, 1.0))
        direct_flops[name] = fl
        direct_coll[name] = coll
        edges[name] = out_edges

    # ---- weights by multiplicity from ENTRY -------------------------------
    weights: Dict[str, float] = {n: 0.0 for n in comps}
    # Topological accumulation via DFS with memo on (call graph is a DAG).
    import sys

    sys.setrecursionlimit(10000)
    order: List[str] = []
    seen = set()

    def topo(n: str):
        if n in seen or n not in comps:
            return
        seen.add(n)
        for child, _ in edges.get(n, ()):
            topo(child)
        order.append(n)

    topo(entry)
    weights[entry] = 1.0
    for n in reversed(order):
        w = weights.get(n, 0.0)
        if w == 0.0:
            continue
        for child, mult in edges.get(n, ()):
            if child in weights:
                weights[child] += w * mult

    for n in comps:
        w = weights.get(n, 0.0)
        costs.dot_flops += w * direct_flops[n]
        costs.dot_flops_unweighted += direct_flops[n]
        for k in COLLECTIVE_KINDS:
            costs.collective_bytes[k] += int(w * direct_coll[n][k])
            costs.collective_bytes_unweighted[k] += direct_coll[n][k]
    return costs
