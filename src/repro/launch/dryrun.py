import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves, without hardware:
  * the sharding config is coherent (GSPMD partitions cleanly at 256/512
    devices — sharding mismatches and unsupported collectives fail here),
  * the memory plan fits (``compiled.memory_analysis()``),
  * and it yields the roofline terms (``cost_analysis`` + HLO collective
    parse) recorded in EXPERIMENTS.md §Dry-run/§Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out results/
"""

import argparse
import json
import time
import traceback

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, shapes_for, skip_reason
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_step


#: §Perf hillclimb variants — '+'-separable tokens applied to a cell.
#:   pad-heads : dead-head padding so attention shards on heads (exact fn)
#:   tp4/tp8   : reshape the same 256-chip pod to (64,4)/(32,8) — smaller
#:               TP degree -> per-device activation psums shrink with the
#:               larger data axis
#:   no-fsdp   : inference params TP-only (no per-layer ZeRO gathers);
#:               only valid when the bf16 params fit HBM without FSDP
#:   mb<k>     : override gradient-accumulation microbatches
VARIANT_TOKENS = ("pad-heads", "tp4", "tp8", "no-fsdp")


def _apply_variant(cfg, shape, multi_pod: bool, variant: str):
    import dataclasses

    from repro.launch.mesh import make_mesh_compat

    step_kw = {}
    mesh = make_production_mesh(multi_pod=multi_pod)
    for tok in [t for t in (variant or "").split("+") if t]:
        if tok == "pad-heads":
            cfg = dataclasses.replace(cfg, pad_heads=True)
        elif tok in ("tp1", "tp2", "tp4", "tp8"):
            assert not multi_pod, "tp reshape defined for single pod"
            tp = int(tok[2:])
            mesh = make_mesh_compat((256 // tp, tp), ("data", "model"))
        elif tok == "no-fsdp":
            step_kw["param_fsdp"] = False
        elif tok == "zero1":
            step_kw["zero1"] = True
        elif tok == "remat-save":
            shape = dataclasses.replace(shape, remat="save_block_out")
        elif tok == "int8-cache":
            step_kw["quant_cache"] = True
        elif tok.startswith("mb"):
            shape = dataclasses.replace(shape, microbatches=int(tok[2:]))
        else:
            raise ValueError(f"unknown variant token {tok!r}")
    return cfg, shape, mesh, step_kw


def run_cell(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True,
             variant: str = ""):
    cfg = get_config(arch)
    reason = skip_reason(cfg, shape_name)
    if reason is not None:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": reason}
    shape = shapes_for(cfg)[shape_name]
    cfg, shape, mesh, step_kw = _apply_variant(cfg, shape, multi_pod, variant)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    t0 = time.perf_counter()
    bundle = make_step(cfg, shape, mesh, **step_kw)
    with mesh:
        lowered = bundle.lower(mesh)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower
    mem = compiled.memory_analysis()
    n_dev = mesh.devices.size
    # MODEL_FLOPS: 6·N_active·D tokens for train (fwd+bwd), 2·N_active·D
    # for single forward/prefill, 2·N_active per token for decode.
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    # matmul-active params: embedding gather contributes no FLOPs
    n_active = cfg.active_params()
    if cfg.frontend in ("tokens", "tokens+patches"):
        n_active -= cfg.vocab * cfg.d_model
    if shape.kind == "train":
        model_flops = 6.0 * n_active * tokens
    else:
        model_flops = 2.0 * n_active * tokens
    r = rl.derive(arch, shape_name, mesh_name, compiled, n_dev,
                  cfg=cfg, shape=shape, model_flops_global=model_flops)
    rec = r.to_dict()
    rec.update(
        status="ok",
        variant=variant,
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        step=bundle.name,
        memory_analysis={
            k: getattr(mem, k)
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                       "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)
        },
    )
    if verbose:
        ma = rec["memory_analysis"]
        print(
            f"[{bundle.name} @ {mesh_name}] compile {t_compile:.0f}s | "
            f"args {ma.get('argument_size_in_bytes', 0)/2**30:.2f} GiB  "
            f"temp {ma.get('temp_size_in_bytes', 0)/2**30:.2f} GiB | "
            f"t_comp {r.t_compute*1e3:.1f}ms t_mem {r.t_memory*1e3:.1f}ms "
            f"t_coll {r.t_collective*1e3:.1f}ms -> {r.bottleneck} | "
            f"useful {100*(r.useful_flops_frac or 0):.0f}% "
            f"roofline {100*r.roofline_frac:.0f}%",
            flush=True,
        )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS)
    ap.add_argument("--shape", default=None, choices=tuple(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", default="single",
                    choices=("single", "multi", "both"))
    ap.add_argument("--variant", default="", help="'+'-joined variant tokens")
    ap.add_argument("--out", default=None, help="JSON results path")
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or args.arch is None) else (args.arch,)
    shapes = tuple(SHAPES) if (args.all or args.shape is None) else (args.shape,)
    meshes = {"single": (False,), "multi": (True,), "both": (False, True)}[args.mesh]

    results = []
    for multi in meshes:
        for arch in archs:
            for shape_name in shapes:
                try:
                    rec = run_cell(arch, shape_name, multi,
                                   variant=args.variant)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": "2x16x16" if multi else "16x16",
                           "status": "error", "error": repr(e),
                           "trace": traceback.format_exc()[-2000:]}
                    print(f"[{arch}:{shape_name}] ERROR {e!r}", flush=True)
                results.append(rec)
                if args.out:
                    with open(args.out, "w") as f:
                        json.dump(results, f, indent=1, default=str)
    ok = sum(1 for r in results if r["status"] == "ok")
    sk = sum(1 for r in results if r["status"] == "skipped")
    err = sum(1 for r in results if r["status"] == "error")
    print(f"\ndry-run: {ok} ok, {sk} skipped, {err} errors "
          f"/ {len(results)} cells")
    return 1 if err else 0


if __name__ == "__main__":
    raise SystemExit(main())
