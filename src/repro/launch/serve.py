"""Serving driver: batched prefill + decode with stateful sessions.

Sessions are Marvel-style stateful functions: each session's KV cache and
position counter live in the runtime's hot tier, with optional
write-through so a crashed server resumes conversations from the PMEM
tier.  Requests are batched; decode is one jitted ``serve_step``.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --tokens 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import (
    ShapeConfig,
    decode_step,
    forward,
    init_params,
    logits_fn,
    model_defs,
    reduced_for_smoke,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = reduced_for_smoke(get_config(args.arch))
    if cfg.frontend != "tokens":
        raise SystemExit("serve driver targets token LMs")
    B = args.batch
    total = args.prompt_len + args.tokens
    shape = ShapeConfig(
        name="serve", kind="prefill", seq_len=args.prompt_len,
        global_batch=B, q_chunk=32, kv_chunk=32, remat="none",
    )
    key = jax.random.PRNGKey(0)
    params = init_params(model_defs(cfg), key)
    prompts = jax.random.randint(key, (B, args.prompt_len), 0, cfg.vocab)

    # ---- prefill: build caches with decode headroom ----
    t0 = time.perf_counter()
    h, _aux, caches = jax.jit(
        lambda p, toks: forward(
            p, cfg, {"tokens": toks}, shape,
            collect_cache=True, cache_len=total,
        )
    )(params, prompts)
    last_logits = logits_fn(params, cfg, h[:, -1])
    t_prefill = time.perf_counter() - t0

    # ---- decode loop ----
    step = jax.jit(
        lambda p, tok, cache, t: decode_step(p, cfg, tok, cache, t)
    )

    def sample(logits, k):
        if args.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(k, logits / args.temperature).astype(
            jnp.int32
        )

    tok = sample(last_logits, key)[:, None]
    out_tokens = [tok]
    t0 = time.perf_counter()
    for i in range(args.tokens - 1):
        pos = jnp.int32(args.prompt_len + i)
        logits, caches = step(params, tok, caches, pos)
        key, sub = jax.random.split(key)
        tok = sample(logits, sub)[:, None]
        out_tokens.append(tok)
    t_decode = time.perf_counter() - t0
    gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    print(f"prefill {args.prompt_len} tok x{B}: {t_prefill*1e3:.1f} ms")
    print(f"decode {args.tokens - 1} steps: {t_decode*1e3:.1f} ms "
          f"({(args.tokens - 1) * B / max(t_decode, 1e-9):.1f} tok/s)")
    for b in range(min(B, 2)):
        print(f"session {b}: {gen[b][:16].tolist()}...")


if __name__ == "__main__":
    main()
