"""End-to-end training driver with stateful-serverless semantics.

The training job runs as a Marvel-style stateful application:
  * model/optimizer state lives on device (the hot tier),
  * an async :class:`CheckpointManager` drains snapshots to the PMEM tier
    (mmap files) every ``--checkpoint-every`` steps,
  * ``--fail-at N`` injects a crash at step N: device + host state are
    dropped, and the driver restores from the last durable checkpoint and
    resumes — the paper's §4.3 fault-tolerance story, measurable here,
  * the data pipeline is deterministic in (seed, step), so the resumed run
    consumes exactly the batches it would have.

CPU-friendly defaults: reduced config, tiny mesh.  The same driver lowers
the full configs on the production mesh (that path is exercised by
``dryrun.py``; real-hardware use just flips ``--full``).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
      --steps 40 --reduced --ckpt-dir /tmp/ckpt [--fail-at 25]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.data.pipeline import PipelineConfig, make_batch
from repro.launch.mesh import make_smoke_mesh, make_production_mesh
from repro.launch.steps import make_train_step
from repro.models import ShapeConfig, init_params, model_defs, reduced_for_smoke
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.storage import CheckpointManager, PmemTier


def build(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_for_smoke(cfg)
    shape = ShapeConfig(
        name="cli", kind="train", seq_len=args.seq, global_batch=args.batch,
        microbatches=args.microbatches, q_chunk=min(512, args.seq),
        kv_chunk=min(1024, args.seq), loss_chunk=min(512, args.seq),
        remat="none" if args.reduced else "full",
    )
    mesh = (
        make_production_mesh() if args.full_mesh else
        make_smoke_mesh(*args.mesh)
    )
    return cfg, shape, mesh


def init_state(cfg, mesh, bundle, seed=0):
    defs = model_defs(cfg)
    params = init_params(defs, jax.random.PRNGKey(seed))
    # fp32 masters
    params = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x,
        params,
    )
    opt = adamw_init(params)
    return params, opt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--full-mesh", action="store_true")
    ap.add_argument("--mesh", type=int, nargs=2, default=(1, 1))
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/marvel_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=10)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a crash at this step (fault-tolerance demo)")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    cfg, shape, mesh = build(args)
    if cfg.frontend != "tokens":
        raise SystemExit("train driver supports token frontends; "
                         "see tests for frames/patches training")
    bundle = make_train_step(
        cfg, shape, mesh, AdamWConfig(lr=args.lr, weight_decay=0.0),
        compress_grads=args.compress_grads,
    )
    step_fn = bundle.jitted(mesh)

    ckpt = CheckpointManager(PmemTier(args.ckpt_dir), f"train/{cfg.name}",
                             keep=2)
    start = ckpt.latest_step()
    params, opt = init_state(cfg, mesh, bundle)
    if start is not None:
        state = ckpt.restore()
        params = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(params), state["params"])
        opt = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(opt), state["opt"])
        print(f"resumed from durable checkpoint @ step {start}")
    step0 = int(start or 0)

    pipe_cfg = PipelineConfig(
        vocab=cfg.vocab, seq_len=shape.seq_len, global_batch=shape.global_batch
    )
    failed = False
    t_start = time.perf_counter()
    step = step0
    while step < args.steps:
        batch = make_batch(pipe_cfg, step)
        out = step_fn(params, opt,
                      {k: jnp.asarray(v) for k, v in batch.items()})
        params, opt, metrics = out[:3]
        step += 1
        if step % 5 == 0 or step == args.steps:
            print(f"step {step:5d}  loss {float(metrics['loss']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}", flush=True)
        if step % args.checkpoint_every == 0:
            ckpt.save(step, {
                "params": jax.tree_util.tree_leaves(params),
                "opt": jax.tree_util.tree_leaves(opt),
            })
        if args.fail_at is not None and step == args.fail_at and not failed:
            failed = True
            print(f"!! injected crash at step {step}: dropping all state")
            del params, opt
            ckpt.wait()
            restore_step = ckpt.latest_step()
            if restore_step is None:
                raise SystemExit("no durable checkpoint — job lost (this is "
                                 "the stock-serverless failure the paper fixes)")
            state = ckpt.restore()
            params, opt = init_state(cfg, mesh, bundle)
            params = jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(params), state["params"])
            opt = jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(opt), state["opt"])
            step = restore_step
            print(f"recovered from PMEM tier @ step {restore_step}; resuming")
    ckpt.wait()
    dt = time.perf_counter() - t_start
    print(f"done: {args.steps - step0} steps in {dt:.1f}s "
          f"({(args.steps - step0) / dt:.2f} steps/s)")
    ckpt.close()


if __name__ == "__main__":
    main()
