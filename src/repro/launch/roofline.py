"""Roofline-term derivation from compiled dry-run artifacts.

Three terms, per the brief.  The partitioned HLO module describes ONE
participant, so every term is per-chip (chip counts cancel):

    compute    = device_dot_FLOPs / peak_FLOP/s       (197 TF bf16, v5e)
    memory     = device_HBM_bytes / HBM_bw            (819 GB/s)
    collective = device_collective_bytes / link_bw    (~50 GB/s/link ICI)

Sources:
  * FLOPs and collective bytes come from the structural HLO parse
    (``hlo_analysis.analyze_hlo``) with exact while-loop trip-count
    weighting — XLA's flat ``cost_analysis()`` counts loop bodies once and
    under-reports scanned programs by 1-2 orders of magnitude (verified;
    we report it alongside as ``xla_cost_*`` for reference).
  * HBM bytes use an analytic traffic model (params/grads/optimizer/cache/
    layer-boundary activations — documented in ``analytic_hbm_bytes``),
    since bytes-accessed from the CPU backend reflects CPU fusion, not TPU.
  * Peak memory comes from ``compiled.memory_analysis()``.
"""

from __future__ import annotations

from dataclasses import asdict, dataclass, field
from typing import Dict, Optional

from repro.launch.hlo_analysis import ModuleCosts, analyze_hlo
from repro.models.config import ModelConfig, ShapeConfig

__all__ = ["Roofline", "derive", "analytic_hbm_bytes"]

# TPU v5e hardware constants (per brief)
PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per ICI link
HBM_PER_CHIP = 16 * 2**30  # 16 GiB


def analytic_hbm_bytes(
    cfg: ModelConfig, shape: ShapeConfig, n_dev: int
) -> float:
    """Per-device HBM traffic model for one step (documented lower bound).

    train:   master params fp32 read + bf16 cast write, per-microbatch
             param re-reads (remat), fp32 grad accumulate read+write,
             AdamW moments read+write (3R+3W fp32)
             + layer-boundary activations (write fwd, read bwd, ~2x remat).
    prefill: bf16 params once + activations + cache write.
    decode:  bf16 params once per token + full cache read + cache write.
    """
    N = cfg.approx_params()
    N_act = cfg.active_params()
    L = cfg.n_layers
    D = cfg.d_model
    B, T = shape.global_batch, shape.seq_len
    # data-parallel width of the batch (256-chip pod: 16; batch may not shard)
    dp = min(16, B) if B >= 1 else 1
    B_dev = max(B // dp, 1)
    if shape.kind == "train":
        n_mb = shape.microbatches
        param_traffic = N / n_dev * (4 + 2 + n_mb * 2 + n_mb * 8 + 24)
        act_traffic = 6.0 * L * B_dev * T * D * 2
        return param_traffic + act_traffic
    if shape.kind == "prefill":
        param_traffic = 2.0 * N / n_dev
        act_traffic = 4.0 * L * B_dev * T * D * 2
        return param_traffic + act_traffic
    # decode: one token
    param_traffic = 2.0 * N_act / n_dev
    cache = _cache_bytes(cfg, shape) / n_dev
    return param_traffic + 2.0 * cache


def _cache_bytes(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Total decode-cache bytes across the fleet (read each step)."""
    B, S = shape.global_batch, shape.seq_len
    total = 0.0
    for blk in cfg.all_blocks():
        if blk.mixer in ("attn", "local"):
            s_eff = min(S, blk.window) if blk.window else S
            total += 2 * B * s_eff * cfg.n_kv_heads * cfg.head_dim * 2
        elif blk.mixer == "mla":
            m = cfg.mla
            total += B * S * (m.kv_lora_rank + m.qk_rope_head_dim) * 2
        elif blk.mixer == "ssm":
            s = cfg.ssm
            total += (
                B * s.n_heads(cfg.d_model) * s.head_dim * s.d_state * 4
            )
        elif blk.mixer == "rglru":
            total += B * (cfg.rglru.lru_width or cfg.d_model) * 4
    return total


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops: float  # per-device, loop-weighted dot flops from HLO
    hbm_bytes: float  # per-device, analytic model
    coll_bytes: int  # per-device, loop-weighted from HLO
    coll_breakdown: Dict[str, int] = field(default_factory=dict)
    peak_memory_bytes: Optional[float] = None
    model_flops: Optional[float] = None  # 6·N_active·D / n_dev
    xla_cost_flops: Optional[float] = None  # raw (loop-unaware) reference
    n_while: int = 0

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_frac(self) -> Optional[float]:
        """MODEL_FLOPS / compiled FLOPs — remat/redundancy/attention waste."""
        if not self.model_flops or not self.flops:
            return None
        return self.model_flops / self.flops

    @property
    def roofline_frac(self) -> float:
        """Achievable MFU at this layout: useful model FLOPs over the time
        the dominant term dictates (perfect overlap assumption)."""
        tmax = max(self.t_compute, self.t_memory, self.t_collective)
        if not tmax or not self.model_flops:
            return 0.0
        return (self.model_flops / PEAK_FLOPS) / tmax

    @property
    def fits_hbm(self) -> Optional[bool]:
        if self.peak_memory_bytes is None:
            return None
        return self.peak_memory_bytes <= HBM_PER_CHIP

    def to_dict(self) -> dict:
        d = asdict(self)
        d.update(
            t_compute=self.t_compute,
            t_memory=self.t_memory,
            t_collective=self.t_collective,
            bottleneck=self.bottleneck,
            useful_flops_frac=self.useful_flops_frac,
            roofline_frac=self.roofline_frac,
            fits_hbm=self.fits_hbm,
        )
        return d


def derive(
    arch: str,
    shape_name: str,
    mesh_name: str,
    compiled,
    n_devices: int,
    cfg: ModelConfig,
    shape: ShapeConfig,
    model_flops_global: Optional[float] = None,
) -> Roofline:
    costs: ModuleCosts = analyze_hlo(compiled.as_text())
    try:
        xla_flops = float(compiled.cost_analysis().get("flops", 0.0))
    except Exception:
        xla_flops = None
    try:
        mem = compiled.memory_analysis()
        peak = float(
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0)
        )
    except Exception:
        peak = None
    return Roofline(
        arch=arch,
        shape=shape_name,
        mesh=mesh_name,
        flops=costs.dot_flops,
        hbm_bytes=analytic_hbm_bytes(cfg, shape, n_devices),
        coll_bytes=costs.total_collective_bytes,
        coll_breakdown=dict(costs.collective_bytes),
        peak_memory_bytes=peak,
        model_flops=(model_flops_global / n_devices)
        if model_flops_global
        else None,
        xla_cost_flops=xla_flops,
        n_while=costs.n_while,
    )
