"""Step builders: train / prefill / decode, with full sharding metadata.

Each ``make_*_step`` returns a :class:`StepBundle`: the pure step function,
its in/out shardings, and ShapeDtypeStruct argument stand-ins — exactly
what the dry-run needs to ``jit(...).lower(...).compile()`` and what the
real launchers feed with live arrays.

Training step layout (DESIGN.md §4):
  * params fp32 masters, 2D-sharded (FSDP×TP); cast to bf16 inside the step,
  * grad accumulation over ``shape.microbatches`` via ``lax.scan`` (this is
    also the compute/comm overlap point: per-microbatch reduce-scatters
    can overlap the next microbatch's compute under XLA latency hiding),
  * optional int8 gradient compression with error feedback on the DP axis,
  * AdamW with ZeRO-sharded fp32 moments.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import (
    ModelConfig,
    ShapeConfig,
    ShardCtx,
    abstract_params,
    decode_step,
    forward,
    init_cache,
    model_defs,
    param_specs,
)
from repro.models.layers import chunked_ce_loss
from repro.models.param import tree_map_defs
from repro.optim.adamw import AdamWConfig, OptState, adamw_update
from repro.optim.compression import EFState, compress_decompress
from repro.parallel.sharding import (
    batch_entry,
    cache_pspecs,
    input_shardings,
    input_specs,
    mesh_axes,
    named,
)

__all__ = ["StepBundle", "make_train_step", "make_prefill_step",
           "make_decode_step", "make_ctx"]


@dataclass
class StepBundle:
    name: str
    fn: Callable
    args: Tuple[Any, ...]  # ShapeDtypeStruct trees
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()

    def jitted(self, mesh: Mesh):
        return jax.jit(
            self.fn,
            in_shardings=named(mesh, self.in_shardings),
            out_shardings=named(mesh, self.out_shardings),
            donate_argnums=self.donate_argnums,
        )

    def lower(self, mesh: Mesh):
        return self.jitted(mesh).lower(*self.args)


def make_ctx(mesh: Optional[Mesh]) -> ShardCtx:
    if mesh is None:
        return ShardCtx()
    dp, _, tp = mesh_axes(mesh)
    return ShardCtx(mesh=mesh, dp_axes=dp or ("data",), tp_axis=tp or "model")


def _abstract_f32(defs):
    return tree_map_defs(
        lambda pd: jax.ShapeDtypeStruct(
            pd.shape, jnp.float32 if pd.dtype == jnp.bfloat16 else pd.dtype
        ),
        defs,
    )


def _cast_tree(tree, dtype):
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype)
        if isinstance(x, jax.Array) and x.dtype == jnp.float32 and x.ndim > 0
        else x,
        tree,
    )


def _pspec_tree(defs, mesh: Mesh, fsdp_override=Ellipsis):
    _, fsdp, tp = mesh_axes(mesh)
    if fsdp_override is not Ellipsis:
        fsdp = fsdp_override
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return param_specs(defs, tp_axis=tp, fsdp_axis=fsdp, axis_sizes=sizes)


# -- train ---------------------------------------------------------------

def make_train_step(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh: Mesh,
    opt_cfg: AdamWConfig = AdamWConfig(),
    aux_coef: float = 0.01,
    compress_grads: bool = False,
    zero1: bool = False,
) -> StepBundle:
    """``zero1=True`` keeps optimizer state FSDP-sharded but gathers the
    bf16 weights ONCE per step (TP-only layout) instead of per layer per
    microbatch — trades ~``2·P/tp`` resident bytes for eliminating the
    per-microbatch ZeRO-3 re-gathers (measured 5-10x collective-bytes win;
    see EXPERIMENTS.md §Perf).  Valid when bf16 params fit HBM at TP-only
    sharding (every assigned arch except dbrx-132b)."""
    defs = model_defs(cfg)
    ctx = make_ctx(mesh)
    if zero1:
        import dataclasses
        ctx = dataclasses.replace(ctx, zero1=True)
    n_mb = shape.microbatches
    B = shape.global_batch
    assert B % n_mb == 0
    pspecs = _pspec_tree(defs, mesh)
    pspecs_nofsdp = _pspec_tree(defs, mesh, fsdp_override=None)

    def loss_fn(params_bf16, mb):
        h, aux = forward(params_bf16, cfg, {k: v for k, v in mb.items()
                                            if k != "labels"}, shape, ctx)
        loss, n = chunked_ce_loss(
            h, params_bf16["unembed"], mb["labels"],
            t_chunk=shape.loss_chunk, logit_softcap=cfg.final_softcap,
        )
        return loss + aux_coef * aux, (loss, n)

    def train_step(params, opt_state, batch, ef_state=None):
        mbs = jax.tree_util.tree_map(
            lambda x: x.reshape((n_mb, B // n_mb) + x.shape[1:]), batch
        )
        params_c = _cast_tree(params, jnp.bfloat16)
        if zero1:
            # gather once per step: compute weights live TP-only sharded
            params_c = jax.tree_util.tree_map(
                lambda x, s: jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, s)
                ),
                params_c, pspecs_nofsdp,
                is_leaf=lambda x: isinstance(x, jax.Array),
            )
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )

        def mb_step(acc, mb):
            (tot, (loss, n)), grads = jax.value_and_grad(
                loss_fn, has_aux=True
            )(params_c, mb)
            # accumulate at the FSDP (ZeRO) layout: under zero1 this is the
            # per-microbatch reduce-scatter of bf16 grads
            acc = jax.tree_util.tree_map(
                lambda a, g, s: jax.lax.with_sharding_constraint(
                    a + g.astype(jnp.float32), NamedSharding(mesh, s)
                ),
                acc, grads, pspecs,
            )
            return acc, (loss, n)

        grads, (losses, ns) = jax.lax.scan(mb_step, zeros, mbs)
        grads = jax.tree_util.tree_map(lambda g: g / n_mb, grads)
        metrics = {}
        new_ef = ef_state
        if compress_grads and ef_state is not None:
            grads, new_ef, qerr = compress_decompress(grads, ef_state)
            metrics["compression_err"] = qerr
        new_params, new_opt, gnorm = adamw_update(params, grads, opt_state, opt_cfg)
        metrics.update(
            loss=jnp.mean(losses),
            tokens=jnp.sum(ns),
            grad_norm=gnorm,
            step=new_opt.step,
        )
        out = (new_params, new_opt, metrics)
        return out + ((new_ef,) if compress_grads else ())

    params_sds = _abstract_f32(defs)
    opt_sds = OptState(
        mu=jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params_sds
        ),
        nu=jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params_sds
        ),
        step=jax.ShapeDtypeStruct((), jnp.int32),
    )
    opt_specs = OptState(mu=pspecs, nu=pspecs, step=P())
    batch_sds = input_specs(cfg, shape)
    batch_specs = input_shardings(cfg, shape, mesh)
    metric_specs = {"loss": P(), "tokens": P(), "grad_norm": P(), "step": P()}

    args = (params_sds, opt_sds, batch_sds)
    in_sh = (pspecs, opt_specs, batch_specs)
    out_sh = (pspecs, opt_specs, metric_specs)
    if compress_grads:
        ef_sds = EFState(residual=jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params_sds
        ))
        args = args + (ef_sds,)
        in_sh = in_sh + (EFState(residual=pspecs),)
        out_sh = out_sh + (EFState(residual=pspecs),)
        metric_specs["compression_err"] = P()
    return StepBundle(
        name=f"train:{cfg.name}:{shape.name}",
        fn=train_step,
        args=args,
        in_shardings=in_sh,
        out_shardings=out_sh,
        donate_argnums=(0, 1),
    )


# -- prefill ---------------------------------------------------------------

def make_prefill_step(
    cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
    param_fsdp: bool = True,
) -> StepBundle:
    defs = model_defs(cfg)
    ctx = make_ctx(mesh)
    if not param_fsdp:  # weights arrive TP-only: MoE skips FSDP gathers
        import dataclasses
        ctx = dataclasses.replace(ctx, zero1=True)

    def prefill_step(params, batch):
        h, _aux, caches = forward(
            params, cfg, batch, shape, ctx, collect_cache=True
        )
        last = h[:, -1]
        logits = (last @ params["unembed"]).astype(jnp.float32)
        from repro.models.layers import softcap
        return softcap(logits, cfg.final_softcap), caches

    pspecs = _pspec_tree(defs, mesh,
                         fsdp_override=Ellipsis if param_fsdp else None)
    params_sds = abstract_params(defs)
    batch_sds = input_specs(cfg, shape)
    batch_specs = input_shardings(cfg, shape, mesh)
    b = batch_entry(mesh, shape.global_batch)
    cache_specs = cache_pspecs(cfg, shape, mesh)
    return StepBundle(
        name=f"prefill:{cfg.name}:{shape.name}",
        fn=prefill_step,
        args=(params_sds, batch_sds),
        in_shardings=(pspecs, batch_specs),
        out_shardings=(P(b, None), cache_specs),
    )


# -- decode ---------------------------------------------------------------

def make_decode_step(
    cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, greedy: bool = True,
    param_fsdp: bool = True, quant_cache: bool = False,
) -> StepBundle:
    defs = model_defs(cfg)
    ctx = make_ctx(mesh)
    if not param_fsdp:  # weights arrive TP-only: MoE skips FSDP gathers
        import dataclasses
        ctx = dataclasses.replace(ctx, zero1=True)
    B, S = shape.global_batch, shape.seq_len

    def serve_step(params, tokens, cache, t):
        logits, new_cache = decode_step(params, cfg, tokens, cache, t, ctx)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tok, new_cache

    pspecs = _pspec_tree(defs, mesh,
                         fsdp_override=Ellipsis if param_fsdp else None)
    params_sds = abstract_params(defs)
    cache_sds = jax.eval_shape(
        lambda: init_cache(cfg, B, S, jnp.bfloat16, quant_attn=quant_cache)
    )
    cache_specs = cache_pspecs(cfg, shape, mesh, quant_attn=quant_cache)
    b = batch_entry(mesh, B)
    tok_sds = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    t_sds = jax.ShapeDtypeStruct((), jnp.int32)
    return StepBundle(
        name=f"decode:{cfg.name}:{shape.name}",
        fn=serve_step,
        args=(params_sds, tok_sds, cache_sds, t_sds),
        in_shardings=(pspecs, P(b, None), cache_specs, P()),
        out_shardings=(P(b, None), cache_specs),
        donate_argnums=(2,),
    )


def make_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, **kw) -> StepBundle:
    """Dispatch on the shape kind (the dry-run entry point)."""
    if shape.kind == "train":
        return make_train_step(cfg, shape, mesh, **kw)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, shape, mesh, **kw)
    return make_decode_step(cfg, shape, mesh, **kw)
