"""Deterministic synthetic data pipeline with sharded, prefetched batches.

The stream has learnable structure (an affine next-token rule applied with
probability ``p_rule``, Zipf-distributed resets otherwise), so the training
examples show real loss descent without external datasets.  Batches are
deterministic in (seed, step) — a restarted job resumes mid-epoch at the
exact batch, which the checkpoint/restart test relies on (the paper's
stateful-recovery semantics applied to the input pipeline).
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np

__all__ = ["PipelineConfig", "SyntheticTokens", "make_batch"]


@dataclass(frozen=True)
class PipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    p_rule: float = 0.9
    #: this process's shard (multi-host data parallelism)
    process_index: int = 0
    process_count: int = 1


def make_batch(cfg: PipelineConfig, step: int) -> Dict[str, np.ndarray]:
    """Batch for ``step`` — pure function of (cfg, step)."""
    assert cfg.global_batch % cfg.process_count == 0
    local_b = cfg.global_batch // cfg.process_count
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.process_index])
    )
    B, T, V = local_b, cfg.seq_len, cfg.vocab
    a = 31337 % V or 7
    c = 17
    toks = np.empty((B, T + 1), np.int64)
    toks[:, 0] = rng.integers(0, V, B)
    # Zipf-ish resets: sample from a small head of the vocab.
    head = max(2, V // 64)
    resets = rng.random((B, T)) > cfg.p_rule
    reset_vals = rng.integers(0, head, (B, T))
    for t in range(T):
        nxt = (toks[:, t] * a + c) % V
        toks[:, t + 1] = np.where(resets[:, t], reset_vals[:, t], nxt)
    return {
        "tokens": toks[:, :-1].astype(np.int32),
        "labels": toks[:, 1:].astype(np.int32),
    }


class SyntheticTokens:
    """Prefetching iterator over ``make_batch``.

    A background thread keeps ``prefetch`` batches ready (host-side input
    pipeline overlap, same role as Hadoop's input readers in the paper's
    stack).  ``start_step`` resumes a restarted run mid-stream.
    """

    def __init__(self, cfg: PipelineConfig, start_step: int = 0,
                 prefetch: int = 2) -> None:
        self.cfg = cfg
        self._step = start_step
        self._q: "queue.Queue[Dict[str, np.ndarray]]" = queue.Queue(prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self) -> None:
        step = self._step
        while not self._stop.is_set():
            batch = make_batch(self.cfg, step)
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        batch = self._q.get()
        self._step += 1
        return batch

    def close(self) -> None:
        self._stop.set()
