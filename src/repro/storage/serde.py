"""Pytree <-> bytes serialization for the storage tiers.

A minimal, dependency-free tensor container: header is JSON (tree structure
with leaf dtype/shape), payload is raw little-endian buffers.  Works for
arbitrary pytrees of jax/numpy arrays and python scalars.

Two warm-path helpers ride along (DESIGN.md §10):

  * :class:`VersionedCodec` memoizes ``dumps`` output per state *version*
    so committing an unchanged state re-uses the encoded bytes instead of
    re-flattening and re-pickling the pytree (the lazy serde fast path);
  * :class:`CowState` is a copy-on-write dict handle for imperative steps:
    reads proxy the underlying state, the first write takes a shallow
    copy, and ``collapse()`` returns the *original* object when nothing
    was written — which is exactly the identity the runtime's
    dirty-tracking keys on.
"""

from __future__ import annotations

import json
import struct
from collections.abc import MutableMapping
from typing import Any, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["dumps", "loads", "leaf_bytes", "CowState", "VersionedCodec"]

_MAGIC = b"MRVL1\n"


def _encode_leaf(x: Any) -> Tuple[dict, bytes]:
    if isinstance(x, (bool, int, float, str)) or x is None:
        return {"kind": "py", "value": x}, b""
    arr = np.asarray(x)
    # bfloat16 has no portable numpy name -> round-trip via uint16 view.
    if arr.dtype == jax.numpy.bfloat16.dtype:
        payload = arr.view(np.uint16).tobytes()
        return {"kind": "bf16", "shape": list(arr.shape)}, payload
    return (
        {"kind": "np", "dtype": arr.dtype.str, "shape": list(arr.shape)},
        arr.tobytes(),
    )


def _decode_leaf(meta: dict, payload: bytes) -> Any:
    kind = meta["kind"]
    if kind == "py":
        return meta["value"]
    if kind == "bf16":
        arr = np.frombuffer(payload, dtype=np.uint16).reshape(meta["shape"])
        return arr.view(jax.numpy.bfloat16.dtype)
    return np.frombuffer(payload, dtype=np.dtype(meta["dtype"])).reshape(meta["shape"])


#: per-treedef memo of the serialization constants that depend only on
#: the tree *structure*: ``(str(treedef), structure-JSON line)``.  Warm
#: invocations re-serialize the same state shape thousands of times a
#: second; recomputing ``str(treedef)`` and re-building the
#: unflatten/_jsonify structure example dominated ``dumps`` before this.
#: Benign data race under the GIL (worst case: duplicate compute).
_STRUCT_MEMO: dict = {}


def _struct_parts(treedef: Any, n_leaves: int) -> Tuple[str, bytes]:
    parts = _STRUCT_MEMO.get(treedef)
    if parts is None:
        example = jax.tree_util.tree_unflatten(
            treedef, list(range(n_leaves))
        )
        parts = (
            str(treedef),
            json.dumps(_jsonify(example)).encode() + b"\n",
        )
        _STRUCT_MEMO[treedef] = parts
    return parts


def dumps(tree: Any) -> bytes:
    """Serialize a pytree (device arrays are pulled to host)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    metas: List[dict] = []
    payloads: List[bytes] = [b"", b"", b""]  # magic/len/header placeholders
    for leaf in leaves:
        meta, payload = _encode_leaf(leaf)
        meta["len"] = len(payload)
        metas.append(meta)
        payloads.append(payload)
    # treedef string is not round-trippable; store the structure line too
    # (both memoized per treedef — only the leaf metas vary per call).
    treedef_str, structure_line = _struct_parts(treedef, len(leaves))
    header = json.dumps({"treedef": treedef_str, "leaves": metas}).encode()
    payloads[0] = _MAGIC
    payloads[1] = struct.pack("<Q", len(header))
    payloads[2] = header + structure_line
    return b"".join(payloads)


def _jsonify(x: Any) -> Any:
    """Encode a pytree-of-ints structure as JSON (dicts/lists/tuples)."""
    if x is None:  # None is a pytree *node* (empty subtree), not a leaf
        return {"__n": 0}
    if isinstance(x, dict):
        return {"__d": {k: _jsonify(v) for k, v in x.items()}}
    if isinstance(x, tuple):
        out = {"__t": [_jsonify(v) for v in x]}
        if hasattr(x, "_fields"):
            # NamedTuple (e.g. an attention KV cache): record the class so
            # recovery rebuilds the same node type — a plain tuple would
            # break attribute access in the restored state.
            out["__nt"] = f"{type(x).__module__}:{type(x).__qualname__}"
        return out
    if isinstance(x, list):
        return {"__l": [_jsonify(v) for v in x]}
    return x  # leaf index (int)


def _resolve_namedtuple(path: str) -> Any:
    import importlib

    modname, _, qualname = path.partition(":")
    obj: Any = importlib.import_module(modname)
    for part in qualname.split("."):
        obj = getattr(obj, part)
    return obj


def _unjsonify(x: Any, leaves: List[Any]) -> Any:
    if isinstance(x, dict):
        if "__n" in x:
            return None
        if "__d" in x:
            return {k: _unjsonify(v, leaves) for k, v in x["__d"].items()}
        if "__t" in x:
            children = [_unjsonify(v, leaves) for v in x["__t"]]
            if "__nt" in x:
                try:
                    return _resolve_namedtuple(x["__nt"])(*children)
                except (ImportError, AttributeError):
                    pass  # class gone since the blob was written
            return tuple(children)
        if "__l" in x:
            return [_unjsonify(v, leaves) for v in x["__l"]]
    return leaves[x]


def loads(data: bytes) -> Any:
    if not data.startswith(_MAGIC):
        raise ValueError("bad magic: not a Marvel blob")
    off = len(_MAGIC)
    (hlen,) = struct.unpack_from("<Q", data, off)
    off += 8
    header = json.loads(data[off : off + hlen])
    off += hlen
    nl = data.index(b"\n", off)
    structure = json.loads(data[off:nl])
    off = nl + 1
    leaves = []
    for meta in header["leaves"]:
        payload = data[off : off + meta["len"]]
        off += meta["len"]
        leaves.append(_decode_leaf(meta, payload))
    return _unjsonify(structure, leaves)


def leaf_bytes(tree: Any) -> int:
    """Total payload bytes of all array leaves (for accounting)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        if isinstance(leaf, (bool, int, float, str)) or leaf is None:
            continue
        arr = np.asarray(leaf)
        total += arr.size * arr.dtype.itemsize
    return total


class CowState(MutableMapping):
    """Copy-on-write handle over a dict-shaped state tree.

    An imperative step receives the handle, reads for free, and only the
    first mutation pays a shallow ``dict`` copy.  ``collapse()`` returns
    the original base object when the step never wrote — the runtime's
    dirty-tracking treats *object identity* as "unchanged", so a
    read-only invocation through a CowState skips re-serialization and
    the commit entirely.  Writing a key back to the identical value it
    already holds does not count as a mutation.

    Only host-side (``jit=False``) functions may use it: the handle is
    not a registered pytree node, so it must never cross a jit boundary.
    """

    __slots__ = ("_base", "_copy")

    def __init__(self, base: dict) -> None:
        self._base = base
        self._copy: Optional[dict] = None

    @property
    def mutated(self) -> bool:
        return self._copy is not None

    def _view(self) -> dict:
        return self._copy if self._copy is not None else self._base

    def __getitem__(self, key: Any) -> Any:
        return self._view()[key]

    def __setitem__(self, key: Any, value: Any) -> None:
        if self._copy is None:
            if key in self._base and self._base[key] is value:
                return  # writing the identical object: not a mutation
            self._copy = dict(self._base)
        self._copy[key] = value

    def __delitem__(self, key: Any) -> None:
        if self._copy is None:
            self._copy = dict(self._base)
        del self._copy[key]

    def __iter__(self) -> Any:
        return iter(self._view())

    def __len__(self) -> int:
        return len(self._view())

    def __contains__(self, key: Any) -> bool:
        return key in self._view()

    def __repr__(self) -> str:
        tag = "mutated" if self.mutated else "clean"
        return f"CowState({self._view()!r}, {tag})"

    def collapse(self) -> Any:
        """The effective state tree: the base object itself when clean
        (identity preserved), the shallow copy once mutated."""
        return self._base if self._copy is None else self._copy


class VersionedCodec:
    """One-slot ``dumps`` memo keyed by a state version stamp.

    The runtime bumps a slot's version stamp only when an invocation
    produces a *different* state object, so ``encode`` for an unchanged
    version returns the cached bytes without touching the pytree.
    ``prime`` seeds the memo from bytes just loaded out of the cache
    (``dumps(loads(b)) == b`` is the serde round-trip contract, so the
    loaded blob *is* the encoding of the loaded state).
    """

    __slots__ = ("_version", "_bytes")

    def __init__(self) -> None:
        self._version: Optional[int] = None
        self._bytes: Optional[bytes] = None

    def encode(self, tree: Any, version: int) -> bytes:
        if version != self._version or self._bytes is None:
            self._bytes = dumps(tree)
            self._version = version
        return self._bytes

    def prime(self, data: bytes, version: int) -> None:
        self._bytes = data
        self._version = version

    def invalidate(self) -> None:
        self._version = None
        self._bytes = None
