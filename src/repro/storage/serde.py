"""Pytree <-> bytes serialization for the storage tiers.

A minimal, dependency-free tensor container: header is JSON (tree structure
with leaf dtype/shape), payload is raw little-endian buffers.  Works for
arbitrary pytrees of jax/numpy arrays and python scalars.
"""

from __future__ import annotations

import io
import json
import struct
from typing import Any, List, Tuple

import jax
import numpy as np

__all__ = ["dumps", "loads", "leaf_bytes"]

_MAGIC = b"MRVL1\n"


def _encode_leaf(x: Any) -> Tuple[dict, bytes]:
    if isinstance(x, (bool, int, float, str)) or x is None:
        return {"kind": "py", "value": x}, b""
    arr = np.asarray(x)
    # bfloat16 has no portable numpy name -> round-trip via uint16 view.
    if arr.dtype == jax.numpy.bfloat16.dtype:
        payload = arr.view(np.uint16).tobytes()
        return {"kind": "bf16", "shape": list(arr.shape)}, payload
    return (
        {"kind": "np", "dtype": arr.dtype.str, "shape": list(arr.shape)},
        arr.tobytes(),
    )


def _decode_leaf(meta: dict, payload: bytes) -> Any:
    kind = meta["kind"]
    if kind == "py":
        return meta["value"]
    if kind == "bf16":
        arr = np.frombuffer(payload, dtype=np.uint16).reshape(meta["shape"])
        return arr.view(jax.numpy.bfloat16.dtype)
    return np.frombuffer(payload, dtype=np.dtype(meta["dtype"])).reshape(meta["shape"])


def dumps(tree: Any) -> bytes:
    """Serialize a pytree (device arrays are pulled to host)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    metas: List[dict] = []
    payloads: List[bytes] = []
    for leaf in leaves:
        meta, payload = _encode_leaf(leaf)
        meta["len"] = len(payload)
        metas.append(meta)
        payloads.append(payload)
    header = json.dumps({"treedef": str(treedef), "leaves": metas}).encode()
    buf = io.BytesIO()
    buf.write(_MAGIC)
    buf.write(struct.pack("<Q", len(header)))
    buf.write(header)
    # treedef string is not round-trippable; store the structure example too.
    structure = jax.tree_util.tree_structure(tree)
    example = jax.tree_util.tree_unflatten(structure, list(range(len(leaves))))
    buf.write(json.dumps(_jsonify(example)).encode() + b"\n")
    for p in payloads:
        buf.write(p)
    return buf.getvalue()


def _jsonify(x: Any) -> Any:
    """Encode a pytree-of-ints structure as JSON (dicts/lists/tuples)."""
    if x is None:  # None is a pytree *node* (empty subtree), not a leaf
        return {"__n": 0}
    if isinstance(x, dict):
        return {"__d": {k: _jsonify(v) for k, v in x.items()}}
    if isinstance(x, tuple):
        out = {"__t": [_jsonify(v) for v in x]}
        if hasattr(x, "_fields"):
            # NamedTuple (e.g. an attention KV cache): record the class so
            # recovery rebuilds the same node type — a plain tuple would
            # break attribute access in the restored state.
            out["__nt"] = f"{type(x).__module__}:{type(x).__qualname__}"
        return out
    if isinstance(x, list):
        return {"__l": [_jsonify(v) for v in x]}
    return x  # leaf index (int)


def _resolve_namedtuple(path: str) -> Any:
    import importlib

    modname, _, qualname = path.partition(":")
    obj: Any = importlib.import_module(modname)
    for part in qualname.split("."):
        obj = getattr(obj, part)
    return obj


def _unjsonify(x: Any, leaves: List[Any]) -> Any:
    if isinstance(x, dict):
        if "__n" in x:
            return None
        if "__d" in x:
            return {k: _unjsonify(v, leaves) for k, v in x["__d"].items()}
        if "__t" in x:
            children = [_unjsonify(v, leaves) for v in x["__t"]]
            if "__nt" in x:
                try:
                    return _resolve_namedtuple(x["__nt"])(*children)
                except (ImportError, AttributeError):
                    pass  # class gone since the blob was written
            return tuple(children)
        if "__l" in x:
            return [_unjsonify(v, leaves) for v in x["__l"]]
    return leaves[x]


def loads(data: bytes) -> Any:
    if not data.startswith(_MAGIC):
        raise ValueError("bad magic: not a Marvel blob")
    off = len(_MAGIC)
    (hlen,) = struct.unpack_from("<Q", data, off)
    off += 8
    header = json.loads(data[off : off + hlen])
    off += hlen
    nl = data.index(b"\n", off)
    structure = json.loads(data[off:nl])
    off = nl + 1
    leaves = []
    for meta in header["leaves"]:
        payload = data[off : off + meta["len"]]
        off += meta["len"]
        leaves.append(_decode_leaf(meta, payload))
    return _unjsonify(structure, leaves)


def leaf_bytes(tree: Any) -> int:
    """Total payload bytes of all array leaves (for accounting)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        if isinstance(leaf, (bool, int, float, str)) or leaf is None:
            continue
        arr = np.asarray(leaf)
        total += arr.size * arr.dtype.itemsize
    return total
