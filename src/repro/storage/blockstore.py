"""HDFS analog: a block store with locality metadata.

In Marvel, HDFS DataNodes (PMEM-backed) hold input/output blocks and the
NameNode serves block→node locality so YARN can schedule mappers next to
their data (compute/data co-location, paper §3.4.2).

Here a :class:`BlockStore` owns a set of :class:`DataNode` s (each a tier),
splits files into fixed-size blocks, replicates them, and exposes the
NameNode-style metadata the scheduler uses for locality-aware placement.
"""

from __future__ import annotations

import hashlib
import random
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

from repro.storage.tiers import Tier

__all__ = ["BlockMeta", "FileMeta", "DataNode", "BlockStore"]

DEFAULT_BLOCK_SIZE = 64 * 2**20  # HDFS-ish 64 MiB default (configurable)


@dataclass
class BlockMeta:
    block_id: str
    length: int
    #: node ids holding a replica, primary first (NameNode locality map).
    replicas: List[str]
    checksum: str


@dataclass
class FileMeta:
    path: str
    length: int
    block_size: int
    blocks: List[BlockMeta] = field(default_factory=list)


@dataclass
class DataNode:
    node_id: str
    tier: Tier

    def block_key(self, block_id: str) -> str:
        return f"blocks/{block_id}"


def _checksum(data: bytes) -> str:
    return hashlib.blake2b(data, digest_size=16).hexdigest()


class BlockStore:
    """NameNode + DataNodes in one object (metadata is process-local).

    The metadata operations mirror what the MapReduce scheduler needs:
    ``locate`` for locality-aware mapper placement, ``write``/``read`` for
    job input/output, and ``fail_node``/``decommission`` for the
    fault-tolerance tests (re-replication from surviving replicas).
    """

    def __init__(
        self,
        nodes: Sequence[DataNode],
        block_size: int = DEFAULT_BLOCK_SIZE,
        replication: int = 1,
        seed: int = 0,
    ) -> None:
        if not nodes:
            raise ValueError("BlockStore needs at least one DataNode")
        self.nodes: Dict[str, DataNode] = {n.node_id: n for n in nodes}
        self.block_size = block_size
        self.replication = min(replication, len(nodes))
        self._files: Dict[str, FileMeta] = {}
        self._rng = random.Random(seed)
        self._dead: set = set()

    # -- NameNode metadata --------------------------------------------------
    def exists(self, path: str) -> bool:
        return path in self._files

    def file_meta(self, path: str) -> FileMeta:
        return self._files[path]

    def locate(self, path: str) -> List[BlockMeta]:
        """Block→replica-nodes map (what mappers ask the NameNode for)."""
        return list(self._files[path].blocks)

    def live_nodes(self) -> List[str]:
        return [nid for nid in self.nodes if nid not in self._dead]

    def add_node(self, node: DataNode) -> None:
        """Register a new DataNode (cluster elasticity: ``add_node`` on the
        router grows the store too).  Existing blocks stay where they are;
        the new node becomes a candidate for future writes and
        re-replication."""
        self.nodes[node.node_id] = node
        self._dead.discard(node.node_id)

    # -- write/read ----------------------------------------------------------
    def _pick_replicas(self, k: int) -> List[str]:
        live = self.live_nodes()
        if len(live) < k:
            raise RuntimeError(f"not enough live DataNodes ({len(live)} < {k})")
        return self._rng.sample(live, k)

    def _split(self, data: bytes, record_delim: Optional[bytes]) -> List[bytes]:
        """Split into ~block_size chunks; if ``record_delim`` is given, cut
        only on delimiter boundaries so records never straddle blocks (the
        HDFS input-split contract MapReduce relies on)."""
        if not data:
            return [b""]
        chunks = []
        i = 0
        n = len(data)
        while i < n:
            j = min(i + self.block_size, n)
            if record_delim and j < n:
                cut = data.rfind(record_delim, i, j)
                if cut > i:
                    j = cut + len(record_delim)
            chunks.append(data[i:j])
            i = j
        return chunks

    def write(
        self, path: str, data: bytes, record_delim: Optional[bytes] = None
    ) -> FileMeta:
        meta = FileMeta(path=path, length=len(data), block_size=self.block_size)
        for i, chunk in enumerate(self._split(data, record_delim)):
            block_id = f"{_checksum(path.encode())[:8]}_{i:06d}"
            replicas = self._pick_replicas(self.replication)
            for nid in replicas:
                node = self.nodes[nid]
                node.tier.put(node.block_key(block_id), chunk)
            meta.blocks.append(
                BlockMeta(block_id, len(chunk), replicas, _checksum(chunk))
            )
        self._files[path] = meta
        return meta

    def read_block(self, block: BlockMeta, prefer_node: Optional[str] = None) -> bytes:
        """Read one block, preferring a local replica (data co-location)."""
        order = list(block.replicas)
        if prefer_node and prefer_node in order:
            order.remove(prefer_node)
            order.insert(0, prefer_node)
        last_err: Optional[Exception] = None
        for nid in order:
            if nid in self._dead:
                continue
            node = self.nodes[nid]
            try:
                data = node.tier.get(node.block_key(block.block_id))
            except Exception as e:  # replica lost
                last_err = e
                continue
            if _checksum(data) != block.checksum:
                last_err = IOError(f"checksum mismatch on {nid}:{block.block_id}")
                continue
            return data
        raise IOError(f"no live replica for block {block.block_id}") from last_err

    def read(self, path: str) -> bytes:
        return b"".join(self.read_block(b) for b in self._files[path].blocks)

    def delete(self, path: str) -> None:
        meta = self._files.pop(path, None)
        if meta is None:
            return
        for block in meta.blocks:
            for nid in block.replicas:
                node = self.nodes.get(nid)
                if node is not None:
                    node.tier.delete(node.block_key(block.block_id))

    # -- failure handling ------------------------------------------------------
    def fail_node(self, node_id: str) -> None:
        """Mark a DataNode dead (drops its replicas from service)."""
        self._dead.add(node_id)

    def recover_node(self, node_id: str) -> None:
        self._dead.discard(node_id)

    def re_replicate(
        self,
        on_copy: Optional[Callable[[str, str, int], None]] = None,
    ) -> int:
        """Restore replication factor after failures; returns blocks fixed.

        ``on_copy(src_node, dst_node, nbytes)`` is invoked before each
        replica copy — the cluster router charges the modeled network
        fabric here.  If the hook raises (e.g. the link is partitioned),
        that candidate is skipped and the block stays under-replicated
        until a later ``re_replicate`` after the link heals."""
        fixed = 0
        for meta in self._files.values():
            for block in meta.blocks:
                live = [r for r in block.replicas if r not in self._dead]
                if not live:
                    raise IOError(f"block {block.block_id} lost all replicas")
                need = self.replication - len(live)
                if need <= 0:
                    block.replicas = live
                    continue
                data = self.read_block(block)
                candidates = [n for n in self.live_nodes() if n not in live]
                for nid in candidates:
                    if need <= 0:
                        break
                    if on_copy is not None:
                        try:
                            on_copy(live[0], nid, len(data))
                        except Exception:
                            continue  # unreachable candidate; try the next
                    node = self.nodes[nid]
                    node.tier.put(node.block_key(block.block_id), data)
                    live.append(nid)
                    fixed += 1
                    need -= 1
                block.replicas = live
        return fixed

    def under_replicated(self) -> List[str]:
        """Block ids currently below the replication factor (live replicas
        only) — what the partition-tolerance tests assert on."""
        out = []
        for meta in self._files.values():
            for block in meta.blocks:
                live = [r for r in block.replicas if r not in self._dead]
                if len(live) < self.replication:
                    out.append(block.block_id)
        return out
