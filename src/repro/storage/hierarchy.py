"""Adaptive multi-tier cache hierarchy — policy-driven data placement.

The paper's tiers (Ignite/DRAM > PMEM > SSD > S3) were, until this module,
*statically* assigned: every caller picked one :class:`~repro.storage.
tiers.Tier` up front and data never moved.  :class:`TieredStore` presents
the single ``Tier`` protocol over an **ordered stack** of tiers and moves
data between them according to a :class:`PlacementPolicy`:

  * **read-through promotion** — a key served from a lower level has its
    hit count bumped; once it clears the size/frequency admission bar it
    is copied into the fastest level (the Cloudburst "autoscaling cache
    colocated with functions" win, PAPERS.md);
  * **capacity-triggered demotion** — each level carries a byte budget;
    overflow picks LRU (or cost-aware: lowest hits-per-byte) victims and
    pushes them one level down, cascading;
  * **write-back** — puts land in the fastest level and are acknowledged;
    a background flusher batches dirty keys via ``put_many`` into the
    *home* (bottom) level.  Crash safety comes from redo records in a
    :class:`~repro.core.journal.StateJournal`: when the journal rides a
    durable cache, an acknowledged put survives any crash/torn-flush
    schedule (the flusher only clears a dirty record after the home write
    of that exact version succeeded);
  * **prefetch** — ``prefetch(prefix)`` subscribes to the home (or an
    explicit source) tier's ``watch()`` events and pulls matching keys
    into the fast level in the background, so shuffle partitions
    committed by a producer are already hot when the consumer asks
    (FaaSFS-style transparent tiering behind one namespace).

Accounting is two-layered (see ``stats`` vs :meth:`physical_stats`):
``self.stats`` counts **logical** ops — one read per ``get`` no matter how
many levels it touched, with ``modeled_seconds`` covering only the device
time paid *inline* (a write-back put of a hot key costs DRAM, not S3).
Each level tier keeps its own physical counters; :meth:`stats_by_level`
/ :meth:`physical_stats` roll them up via :meth:`TierStats.merge`.  A
promoted read is therefore never double-counted at the logical layer,
while thread-scoped accounting (``tier_accounting``) still sees every
physical op exactly once via the capture-and-forward scope.

See DESIGN.md §7 for the promotion/demotion/write-back state machine and
the OpenWhisk/Ignite mapping.
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict
from dataclasses import dataclass
from typing import (
    TYPE_CHECKING,
    Callable,
    Dict,
    Iterable,
    Iterator,
    List,
    Mapping,
    Optional,
    Sequence,
    Tuple,
    Union,
)

from repro.storage.tiers import (
    DramTier,
    Tier,
    TierStats,
    tier_accounting_capture,
)

if TYPE_CHECKING:  # deferred: repro.core imports back into repro.storage
    from repro.storage.kvcache import StateCache

__all__ = [
    "PlacementPolicy",
    "TierLevel",
    "TieredStore",
    "adaptive_shuffle_tier",
]


@dataclass(frozen=True)
class PlacementPolicy:
    """Knobs for promotion, demotion, and the write path."""

    #: hits at a lower level before a key is promoted to the fast level.
    promote_after: int = 2
    #: keys larger than this never get promoted (None = any size) — the
    #: size half of size/frequency-aware admission.
    max_promote_bytes: Optional[int] = None
    #: write-back (ack from the fast level, background flush to home) vs
    #: write-through (home write inline with the put).
    write_back: bool = False
    #: victim selection when a level overflows: "lru" (least recently
    #: used) or "cost" (lowest hits-per-byte — big cold keys go first).
    eviction: str = "lru"
    #: background flusher cadence and batch bound (write-back only).
    flush_interval: float = 0.02
    flush_batch: int = 64

    def admits(self, freq: int, nbytes: int) -> bool:
        if freq < self.promote_after:
            return False
        return self.max_promote_bytes is None or nbytes <= self.max_promote_bytes


@dataclass
class TierLevel:
    """One level of the stack: a tier plus its byte budget.

    ``capacity_bytes=None`` means unbounded — required for the home
    (bottom) level, which is where overflow ultimately drains.
    """

    name: str
    tier: Tier
    capacity_bytes: Optional[int] = None


@dataclass
class _Entry:
    """Placement record for one key."""

    level: int  # fastest level currently holding the key
    size: int
    freq: int = 0
    version: int = 0
    #: the home (bottom) level also holds a clean copy of this version.
    home_copy: bool = False


class TieredStore(Tier):
    """The single ``Tier`` protocol over an ordered stack of tiers.

    ``levels`` runs fastest → slowest; the last level is the **home**
    level: unbounded, and the durability target of write-back flushes.
    ``journal`` (a :class:`StateCache`, ideally durable) carries the
    write-back redo log; without it, write-back still works but an
    acknowledged-unflushed put dies with the volatile fast level.

    Thread-safe: placement metadata is under one store lock, held across
    inline tier ops (they are fast levels by construction); the flusher's
    home ``put_many`` runs outside it so a slow home device never blocks
    the hot path.
    """

    def __init__(
        self,
        levels: Sequence[Union[TierLevel, Tier]],
        policy: Optional[PlacementPolicy] = None,
        journal: Optional["StateCache"] = None,
        name: str = "hier",
    ) -> None:
        super().__init__()
        if not levels:
            raise ValueError("TieredStore needs at least one level")
        self.levels: List[TierLevel] = [
            lv if isinstance(lv, TierLevel) else TierLevel(lv.name, lv)
            for lv in levels
        ]
        if self.levels[-1].capacity_bytes is not None:
            raise ValueError("the home (bottom) level must be unbounded")
        self.policy = policy or PlacementPolicy()
        self.name = name
        self.persistent = self.levels[-1].tier.persistent
        self._home = len(self.levels) - 1
        self._entries: Dict[str, _Entry] = {}
        #: per-level LRU order of resident keys (OrderedDict as a set).
        self._lru: List["OrderedDict[str, None]"] = [
            OrderedDict() for _ in self.levels
        ]
        self._used: List[int] = [0 for _ in self.levels]
        self._dirty: Dict[str, int] = {}  # key -> version awaiting flush
        #: pinned key prefixes: matching keys are held in the fast level —
        #: never demotion victims, promoted on first read (see :meth:`pin`).
        self._pins: set = set()
        #: keys snapshotted by a flush round whose home ``put_many`` has
        #: not completed yet.  A demotion must not land such a key at the
        #: home level: the in-flight (possibly stale) batch write could
        #: clobber it after the dirty record was cleared.
        self._inflight_flush: set = set()
        self._mutex = threading.RLock()
        #: flusher wake-up signal, deliberately NOT built on ``_mutex``:
        #: cross-store prefetch callbacks run on the writer's thread and
        #: must never need another store's placement lock.
        self._wake = threading.Event()
        self._flush_serial = threading.Lock()
        self._prefetch_lock = threading.Lock()
        if journal is not None:
            # Late import: repro.core pulls repro.storage back in.
            from repro.core.journal import StateJournal

            self._journal = StateJournal(journal, f"{name}/wb")
        else:
            self._journal = None
        self._journal_cache = journal
        self.promotions = 0
        self.demotions = 0
        self.flush_errors = 0
        self._hits: List[int] = [0 for _ in self.levels]
        self._closed = False
        self._flusher: Optional[threading.Thread] = None
        self._prefetch_worker: Optional[threading.Thread] = None
        self._prefetch_queue: List[Tuple[Tier, str]] = []
        self._unsubscribes: List[Callable[[], None]] = []
        if self.policy.write_back:
            self._flusher = threading.Thread(
                target=self._flusher_loop, name=f"{name}-flusher", daemon=True
            )
            self._flusher.start()

    # -- journal redo-log keys --------------------------------------------
    def _data_key(self, key: str) -> str:
        return f"{self.name}/wbdata/{key}"

    # -- placement internals (call with self._mutex held) -----------------
    def _touch(self, key: str, level: int) -> None:
        lru = self._lru[level]
        lru[key] = None
        lru.move_to_end(key)

    def _drop_from_level(self, key: str, level: int, size: int) -> None:
        self._lru[level].pop(key, None)
        self._used[level] -= size

    def _adopt(self, key: str) -> Optional[_Entry]:
        """Fault in a key written to the underlying tiers out-of-band
        (pre-existing data, or data re-exposed by ``recover``)."""
        for i, lv in enumerate(self.levels):
            if lv.tier.contains(key):
                size = lv.tier.size_of(key)
                ent = _Entry(level=i, size=size, home_copy=(i == self._home))
                self._entries[key] = ent
                self._used[i] += size
                self._touch(key, i)
                return ent
        return None

    def _pinned(self, key: str) -> bool:
        return any(key.startswith(p) for p in self._pins)

    def _victim(
        self, level: int, protect: Optional[str], skip: Optional[set] = None
    ) -> Optional[str]:
        lru = self._lru[level]
        if self.policy.eviction == "cost":
            # Lowest hits-per-byte goes first: big cold keys are the
            # cheapest capacity to reclaim.
            best, best_score = None, None
            for key in lru:
                if (
                    key == protect
                    or (skip is not None and key in skip)
                    or self._pinned(key)
                ):
                    continue
                ent = self._entries[key]
                score = ent.freq / max(1, ent.size)
                if best_score is None or score < best_score:
                    best, best_score = key, score
            return best
        for key in lru:  # LRU order: oldest first
            if (
                key != protect
                and (skip is None or key not in skip)
                and not self._pinned(key)
            ):
                return key
        return None

    def _ensure_room(self, level: int, nbytes: int, protect: str) -> None:
        cap = self.levels[level].capacity_bytes
        if cap is None:
            return
        undemotable: set = set()
        while self._used[level] + nbytes > cap:
            victim = self._victim(level, protect, skip=undemotable)
            if victim is None:
                break  # nothing evictable; let the level run hot briefly
            if not self._demote_locked(victim):
                undemotable.add(victim)

    def _demote_locked(self, key: str) -> bool:
        """Move ``key`` one level down (cascading capacity).  Returns
        False when the key is already home (nothing to demote) or is
        pinned by an in-flight flush."""
        ent = self._entries.get(key)
        if ent is None or ent.level >= self._home:
            return False
        if self._pinned(key):
            # Placement-policy pin: loop-carried dataflow state must stay
            # in the fast level for the life of the pin — explicit demote
            # requests (warm-pool spills) are refused too.
            return False
        src, dst = ent.level, ent.level + 1
        if dst == self._home and key in self._inflight_flush:
            # A flush round snapshotted this key and its home put_many
            # has not landed yet: writing home here and clearing the
            # dirty record would let the in-flight (older) batch clobber
            # the newer value afterwards.  Leave the key where it is;
            # the flusher settles it within a round.
            return False
        src_tier = self.levels[src].tier
        if dst == self._home and ent.home_copy and key not in self._dirty:
            # Clean copy already lives at home: demotion is just a drop
            # (no value read — the bytes would be discarded).
            pass
        else:
            value = src_tier.get(key)
            self._ensure_room(dst, len(value), protect=key)
            self.levels[dst].tier.put(key, value)
            if dst == self._home:
                ent.home_copy = True
                self._clear_dirty(key, ent.version)
        src_tier.delete(key)
        self._drop_from_level(key, src, ent.size)
        ent.level = dst
        self._used[dst] += ent.size
        self._touch(key, dst)
        self.demotions += 1
        return True

    def _promote_locked(self, key: str, value: bytes) -> None:
        ent = self._entries[key]
        src = ent.level
        # Detach from the source level *before* making room: the cascade
        # below walks LRU lists, and the key must not be victimizable
        # mid-promotion (a stale src would corrupt the byte accounting).
        if src != self._home or not ent.home_copy:
            # Move semantics between non-home levels; a clean home copy
            # stays put (inclusive bottom) so a later demotion is free.
            self.levels[src].tier.delete(key)
        self._drop_from_level(key, src, ent.size)
        self._ensure_room(0, len(value), protect=key)
        self.levels[0].tier.put(key, value)
        ent.level = 0
        self._used[0] += ent.size
        self._touch(key, 0)
        self.promotions += 1

    def _clear_dirty(self, key: str, version: int) -> None:
        if self._dirty.get(key) == version:
            del self._dirty[key]
            if self._journal is not None:
                self._journal.retract(key)
                self._journal_cache.delete(self._data_key(key))

    # -- logical accounting -------------------------------------------------
    def _logical_read(self, nbytes: int, wall: float, modeled: float) -> None:
        with self._lock:
            self.stats.bytes_read += nbytes
            self.stats.read_ops += 1
            self.stats.wall_seconds += wall
            self.stats.modeled_seconds += modeled

    def _logical_write(self, nbytes: int, wall: float, modeled: float,
                       ops: int = 1) -> None:
        with self._lock:
            self.stats.bytes_written += nbytes
            self.stats.write_ops += ops
            self.stats.wall_seconds += wall
            self.stats.modeled_seconds += modeled

    # -- Tier protocol ------------------------------------------------------
    def put(self, key: str, value: bytes) -> None:
        t0 = time.perf_counter()
        with tier_accounting_capture() as inline:
            with self._mutex:
                self._install(key, value)
                self._journal_put({key: value})
                if not self.policy.write_back:
                    self._write_home(key, value)
                else:
                    self._dirty[key] = self._entries[key].version
        if self.policy.write_back:
            self._wake.set()
        self._logical_write(len(value), time.perf_counter() - t0,
                            inline.modeled_seconds)
        self._notify(key)

    def put_many(self, items: Mapping[str, bytes]) -> None:
        if not items:
            return
        t0 = time.perf_counter()
        with tier_accounting_capture() as inline:
            with self._mutex:
                for key, value in items.items():
                    self._install(key, value)
                self._journal_put(items)
                if not self.policy.write_back:
                    # Same single-level guard as _write_home: on a
                    # one-level store _install already wrote the values.
                    if self._home != 0:
                        self.levels[self._home].tier.put_many(items)
                    for key in items:
                        self._entries[key].home_copy = True
                else:
                    for key in items:
                        self._dirty[key] = self._entries[key].version
        if self.policy.write_back:
            self._wake.set()
        total = sum(len(v) for v in items.values())
        self._logical_write(total, time.perf_counter() - t0,
                            inline.modeled_seconds, ops=len(items))
        for key in items:
            self._notify(key)

    def _install(self, key: str, value: bytes) -> None:
        """Land ``value`` in the fast level and update placement."""
        ent = self._entries.get(key)
        if ent is not None:
            self._drop_from_level(key, ent.level, ent.size)
            if ent.level != 0 and ent.level != self._home:
                self.levels[ent.level].tier.delete(key)
            ent.size = len(value)
            ent.level = 0
            ent.version += 1
            ent.home_copy = False
        else:
            ent = _Entry(level=0, size=len(value), version=1)
            self._entries[key] = ent
        self._ensure_room(0, ent.size, protect=key)
        self.levels[0].tier.put(key, value)
        self._used[0] += ent.size
        self._touch(key, 0)

    def _write_home(self, key: str, value: bytes) -> None:
        if self._home == 0:
            self._entries[key].home_copy = True
            return
        self.levels[self._home].tier.put(key, value)
        self._entries[key].home_copy = True

    def _journal_put(self, items: Mapping[str, bytes]) -> None:
        if self._journal is None or not self.policy.write_back:
            return
        # Redo blobs first, then their markers: a torn journal batch can
        # leave orphan blobs (garbage, harmless) but never a marker whose
        # blob is missing — recovery skips markers without blobs anyway.
        self._journal_cache.put_many(
            {self._data_key(k): v for k, v in items.items()}
        )
        self._journal.commit_many(
            {k: {"bytes": len(v), "seq": self._entries[k].version}
             for k, v in items.items()}
        )

    def get(self, key: str) -> bytes:
        t0 = time.perf_counter()
        with tier_accounting_capture() as inline:
            with self._mutex:
                ent = self._entries.get(key)
                if ent is None:
                    ent = self._adopt(key)
                if ent is None:
                    raise KeyError(key)
                value = self.levels[ent.level].tier.get(key)
                ent.freq += 1
                self._hits[ent.level] += 1
                self._touch(key, ent.level)
                if ent.level > 0 and (
                    self._pinned(key)
                    or self.policy.admits(ent.freq, ent.size)
                ):
                    # Pinned keys skip the frequency admission bar: the
                    # first read after a crash re-adopts them straight
                    # into the fast level.
                    self._promote_locked(key, value)
        self._logical_read(len(value), time.perf_counter() - t0,
                           inline.modeled_seconds)
        return value

    def contains(self, key: str) -> bool:
        with self._mutex:
            if key in self._entries:
                return True
        return any(lv.tier.contains(key) for lv in self.levels)

    def delete(self, key: str) -> None:
        with self._mutex:
            ent = self._entries.pop(key, None)
            if ent is not None:
                self._drop_from_level(key, ent.level, ent.size)
                self._clear_dirty(key, ent.version)
            self._dirty.pop(key, None)
            for lv in self.levels:
                lv.tier.delete(key)

    def keys(self, prefix: str = "") -> Iterator[str]:
        seen = set()
        with self._mutex:
            if prefix:
                seen.update(
                    k for k in self._entries if k.startswith(prefix)
                )
            else:
                seen.update(self._entries.keys())
        for lv in self.levels:
            seen.update(lv.tier.keys(prefix))
        return iter(sorted(seen))

    def size_of(self, key: str) -> int:
        with self._mutex:
            ent = self._entries.get(key)
            if ent is None:
                ent = self._adopt(key)
            if ent is not None:
                return ent.size
        raise KeyError(key)

    # -- explicit placement -------------------------------------------------
    def demote(self, key: str) -> bool:
        """Push ``key`` one level down (the gateway's warm-pool spill:
        evicted session state leaves DRAM for the next tier instead of
        being dropped).  Returns True if the key moved."""
        with self._mutex:
            if key not in self._entries and self._adopt(key) is None:
                return False
            return self._demote_locked(key)

    def pin(self, prefix: str, eager: bool = True) -> None:
        """Placement-policy hook: hold every key under ``prefix`` in the
        fast level — pinned keys are never demotion victims, explicit
        ``demote`` refuses them, and reads promote them past the
        size/frequency admission bar.  An iterative dataflow job pins its
        loop-state prefix so supersteps never round-trip through the
        modeled S3 home; :meth:`unpin` releases the keys back to normal
        policy when the loop retires them.

        With ``eager=True`` already-resident matching keys are promoted
        immediately (synchronously, under the placement lock);
        ``eager=False`` only registers the pin — resumed keys then reach
        the fast level via :meth:`promote_async` or on first read (the
        KV pager's promotion-on-resume path, which must not pay the
        slow-level read latency inside the resume call).  If the pinned
        set outgrows the fast level's budget the level runs hot (pins
        express a placement *requirement*, not extra capacity).
        """
        with self._mutex:
            self._pins.add(prefix)
            if not eager:
                return
            for key in [
                k for k, e in self._entries.items()
                if e.level > 0 and k.startswith(prefix)
            ]:
                value = self.levels[self._entries[key].level].tier.get(key)
                self._promote_locked(key, value)

    def unpin(self, prefix: str) -> None:
        """Remove a :meth:`pin`; matching keys become ordinary
        promotion/demotion candidates again (nothing moves eagerly)."""
        with self._mutex:
            self._pins.discard(prefix)

    @property
    def pinned_prefixes(self) -> List[str]:
        with self._mutex:
            return sorted(self._pins)

    def level_of(self, key: str) -> Optional[str]:
        """Name of the level currently serving ``key`` (None = absent)."""
        with self._mutex:
            ent = self._entries.get(key)
            if ent is None:
                ent = self._adopt(key)
            return self.levels[ent.level].name if ent is not None else None

    # -- write-back flushing ------------------------------------------------
    def _snapshot_batch(self) -> List[Tuple[str, int, bytes]]:
        with self._mutex:
            batch: List[Tuple[str, int, bytes]] = []
            for key in list(self._dirty)[: self.policy.flush_batch]:
                ent = self._entries.get(key)
                if ent is None:  # deleted since marked dirty
                    self._dirty.pop(key, None)
                    continue
                value = self.levels[ent.level].tier.get(key)
                batch.append((key, ent.version, value))
                # Pin: no demotion may land this key at home until the
                # round's put_many resolved (see _demote_locked).
                self._inflight_flush.add(key)
            return batch

    def _flush_once(self) -> int:
        """One flush round: snapshot → home ``put_many`` → clear the
        dirty records whose version is unchanged.  A torn home write
        leaves every record dirty (idempotent retry); acked data stays
        readable in the fast level and replayable from the journal, so
        **no acknowledged put is ever lost**."""
        with self._flush_serial:
            batch = self._snapshot_batch()
            if not batch:
                return 0
            try:
                # One batched request for the whole round (the
                # SimulatedTier charges a single modeled latency — same
                # fast path the streaming shuffle uses).
                self.levels[self._home].tier.put_many(
                    {key: value for key, _, value in batch}
                )
                with self._mutex:
                    for key, version, _ in batch:
                        ent = self._entries.get(key)
                        if ent is not None and ent.version == version:
                            ent.home_copy = True
                        elif ent is None:
                            # Deleted while the flush was in flight: undo
                            # the resurrected home copy.
                            self.levels[self._home].tier.delete(key)
                        self._clear_dirty(key, version)
            finally:
                with self._mutex:
                    self._inflight_flush.difference_update(
                        k for k, _, _ in batch
                    )
            return len(batch)

    def _flusher_loop(self) -> None:
        while True:
            self._wake.wait(timeout=self.policy.flush_interval)
            self._wake.clear()
            self._drain_prefetch()
            try:
                while self._flush_once():
                    pass
            except Exception:
                # Keys stay dirty; retried next round.  heal()-style
                # recovery on the home tier makes the retry succeed.
                self.flush_errors += 1
                time.sleep(self.policy.flush_interval)
            with self._mutex:
                # close(flush=True) drains synchronously before setting
                # the flag, so exiting here never abandons dirty keys
                # the caller wanted flushed.
                if self._closed:
                    return

    def flush(self, timeout: Optional[float] = 30.0) -> int:
        """Synchronously drain the dirty set (retrying failed rounds
        until ``timeout``).  Returns the number of keys flushed."""
        flushed = 0
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            with self._mutex:
                if not self._dirty:
                    return flushed
            try:
                flushed += self._flush_once()
            except Exception:
                self.flush_errors += 1
                if deadline is not None and time.monotonic() >= deadline:
                    raise
                time.sleep(min(0.005, self.policy.flush_interval))

    @property
    def dirty_keys(self) -> List[str]:
        with self._mutex:
            return sorted(self._dirty)

    # -- prefetch -----------------------------------------------------------
    def prefetch(
        self, prefix: str, source: Optional[Tier] = None
    ) -> Callable[[], None]:
        """Watch ``source`` (default: the home tier) and pull every key
        committed under ``prefix`` into the fast level in the background
        — a consumer's hierarchy warms itself from a producer's commits
        before the first ``get`` (the shuffle-prefetch path).  Returns
        the unsubscribe callable."""
        src = source if source is not None else self.levels[self._home].tier

        def on_commit(key: str) -> None:
            # Cheap, lock-light enqueue on the writer's thread (which may
            # hold *another* store's placement lock); the promotion I/O
            # happens on this store's background worker.
            with self._prefetch_lock:
                self._prefetch_queue.append((src, key))
            self._wake.set()

        if self._flusher is None:
            self._ensure_prefetch_worker()
        unsub = src.watch(prefix, on_commit)
        self._unsubscribes.append(unsub)
        return unsub

    def promote_async(self, keys: Iterable[str]) -> int:
        """Enqueue already-resident ``keys`` for background promotion to
        the fast level — the KV pager's promotion-on-resume: a returning
        session's blocks climb out of the slow level on the prefetch
        worker, ahead of the next decode step, instead of demand-faulting
        inside it.  Keys already fast, dirty, or absent are skipped by
        the drain worker's usual freshness rules.  Returns the number of
        keys enqueued."""
        batch: List[Tuple[Tier, str]] = []
        with self._mutex:
            for key in keys:
                ent = self._entries.get(key)
                if ent is None:
                    ent = self._adopt(key)
                if ent is None or ent.level == 0:
                    continue
                batch.append((self.levels[ent.level].tier, key))
        if not batch:
            return 0
        with self._prefetch_lock:
            self._prefetch_queue.extend(batch)
        if self._flusher is None:
            self._ensure_prefetch_worker()
        self._wake.set()
        return len(batch)

    def _ensure_prefetch_worker(self) -> None:
        """One persistent drain worker for stores without a flusher
        (write-through policy) — never a thread per watch event."""
        with self._mutex:
            if self._prefetch_worker is not None or self._closed:
                return
            self._prefetch_worker = threading.Thread(
                target=self._prefetch_loop,
                name=f"{self.name}-prefetch", daemon=True,
            )
            self._prefetch_worker.start()

    def _prefetch_loop(self) -> None:
        while True:
            self._wake.wait(timeout=0.05)
            self._wake.clear()
            self._drain_prefetch()
            with self._mutex:
                if self._closed:
                    return

    def _skip_prefetch(self, key: str) -> bool:
        """A prefetched (source) copy must never clobber a local copy
        that may be newer: anything resident above home, or anything
        dirty (our write awaiting flush).  Only keys we know solely
        through the shared home level — or not at all — are pulled."""
        ent = self._entries.get(key)
        if ent is None:
            return False
        return ent.level < self._home or key in self._dirty

    def _drain_prefetch(self) -> int:
        pulled = 0
        while True:
            with self._prefetch_lock:
                if not self._prefetch_queue:
                    return pulled
                src, key = self._prefetch_queue.pop(0)
            with self._mutex:
                if self._skip_prefetch(key):
                    continue  # local copy is as new or newer
            try:
                value = src.get(key)
            except (KeyError, FileNotFoundError, IOError):
                continue
            with self._mutex:
                if self._skip_prefetch(key):
                    continue
                ent = self._entries.get(key)
                if ent is not None:
                    # Resident at home: the home tier keeps its copy
                    # (inclusive bottom), only the placement record moves.
                    self._drop_from_level(key, ent.level, ent.size)
                at_home = ent is not None and ent.level == self._home
                is_home_src = src is self.levels[self._home].tier
                self._entries[key] = _Entry(
                    level=0, size=len(value), home_copy=at_home or is_home_src,
                    freq=ent.freq if ent else 0,
                    version=ent.version if ent else 0,
                )
                self._ensure_room(0, len(value), protect=key)
                self.levels[0].tier.put(key, value)
                self._used[0] += len(value)
                self._touch(key, 0)
                pulled += 1

    # -- crash / recovery ---------------------------------------------------
    def crash(self) -> None:
        """Volatile levels lose their contents (node failure); placement
        is rebuilt from whatever the persistent levels still hold."""
        with self._mutex:
            for lv in self.levels:
                if not lv.tier.persistent:
                    lv.tier.clear()
            self._entries.clear()
            self._dirty.clear()
            self._inflight_flush.clear()
            for lru in self._lru:
                lru.clear()
            self._used = [0 for _ in self.levels]
            # Re-adopt survivors, fastest level wins.
            for i, lv in enumerate(self.levels):
                for key in lv.tier.keys():
                    if key in self._entries:
                        continue
                    size = lv.tier.size_of(key)
                    self._entries[key] = _Entry(
                        level=i, size=size, home_copy=(i == self._home)
                    )
                    self._used[i] += size
                    self._touch(key, i)

    def recover(self) -> int:
        """Replay unflushed write-back redo records from the journal:
        every acknowledged put whose flush had not completed is
        reinstalled (still dirty, so it flushes again).  Returns the
        number of keys replayed."""
        if self._journal is None:
            return 0
        replayed = 0
        with self._mutex:
            for key, meta in self._journal.entries().items():
                data_key = self._data_key(key)
                if not self._journal_cache.contains(data_key):
                    continue  # torn journal batch: blob never landed
                value = self._journal_cache.get(data_key)
                self._install(key, value)
                self._entries[key].version = int(meta.get("seq", 1))
                self._dirty[key] = self._entries[key].version
                replayed += 1
        if replayed:
            self._wake.set()
        return replayed

    # -- stats rollup -------------------------------------------------------
    def stats_by_level(self) -> Dict[str, TierStats]:
        """Physical per-level counters (each level's own tier stats)."""
        return {lv.name: lv.tier.stats for lv in self.levels}

    def physical_stats(self) -> TierStats:
        """All levels merged into one :class:`TierStats` (physical ops:
        a promoted read shows up as one lower-level read plus one
        fast-level write — the logical ``self.stats`` counts it once)."""
        rolled = TierStats()
        for lv in self.levels:
            rolled = rolled.merge(lv.tier.stats)
        return rolled

    def hit_rates(self) -> Dict[str, float]:
        """Fraction of gets served per level (by level name)."""
        total = max(1, sum(self._hits))
        return {
            lv.name: self._hits[i] / total for i, lv in enumerate(self.levels)
        }

    # -- lifecycle ----------------------------------------------------------
    def close(self, flush: bool = True) -> None:
        if self._closed:
            return
        for unsub in self._unsubscribes:
            unsub()
        self._unsubscribes.clear()
        if flush and self.policy.write_back:
            self.flush()
        with self._mutex:
            self._closed = True
        self._wake.set()
        if self._flusher is not None:
            self._flusher.join(timeout=5.0)
        if self._prefetch_worker is not None:
            self._prefetch_worker.join(timeout=5.0)

    def __enter__(self) -> "TieredStore":
        return self

    def __exit__(self, *exc: object) -> None:
        self.close(flush=exc[0] is None)


def adaptive_shuffle_tier(
    backing: Tier,
    journal: Optional["StateCache"] = None,
    name: str = "shuffle",
    fast_capacity: Optional[int] = None,
) -> TieredStore:
    """A write-back DRAM front over ``backing`` for shuffle traffic.

    Map tasks' ``put_many`` lands in DRAM and is acknowledged there —
    the modeled S3/SSD latency moves off the map task's critical path
    onto the background flusher.  With a durable ``journal`` the redo
    log makes those acks crash-safe, and any unflushed partitions from
    a previous run are replayed immediately (``recover``), so journaled
    job resume still finds every committed partition.
    """
    store = TieredStore(
        [
            TierLevel("dram", DramTier(), fast_capacity),
            TierLevel(backing.name, backing),
        ],
        policy=PlacementPolicy(write_back=True, promote_after=1),
        journal=journal,
        name=name,
    )
    store.recover()
    return store
