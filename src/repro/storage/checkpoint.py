"""Async multi-tier checkpointing — the paper's §4.3 made first-class.

Checkpoint path mirrors the Marvel tier stack:

    device (HBM)  --sync copy-->  host staging (DRAM tier)
                  --background-->  persistent tier (PMEM analog)

``save`` returns as soon as the host staging copy exists (training resumes
immediately — compute/IO overlap); a background thread drains staged
checkpoints into the persistent tier with integrity checksums.  ``restore``
loads the newest *complete* checkpoint, so a crash mid-drain falls back to
the previous one (atomicity via a manifest written last).

This is also the substrate for elastic restart: the restored pytree is
host-resident numpy, so it can be re-sharded onto a *different* mesh than
the one that wrote it.
"""

from __future__ import annotations

import hashlib
import json
import queue
import threading
import time
from dataclasses import dataclass
from typing import Any, List, Optional

import jax

from repro.storage import serde
from repro.storage.tiers import Tier

__all__ = ["CheckpointManager", "CheckpointInfo"]


@dataclass
class CheckpointInfo:
    step: int
    nbytes: int
    checksum: str
    wall_time: float


def _digest(data: bytes) -> str:
    return hashlib.blake2b(data, digest_size=16).hexdigest()


class CheckpointManager:
    """Tiered, asynchronous, integrity-checked checkpointing.

    Parameters
    ----------
    tier:
        Persistent tier (PMEM analog) that durable checkpoints land in.
    prefix:
        Key namespace, e.g. ``"ckpt/run42"``.
    keep:
        Number of most-recent complete checkpoints retained.
    """

    def __init__(self, tier: Tier, prefix: str = "ckpt", keep: int = 2) -> None:
        self.tier = tier
        self.prefix = prefix.rstrip("/")
        self.keep = keep
        self._q: "queue.Queue[Optional[tuple]]" = queue.Queue()
        self._drain_err: Optional[BaseException] = None
        self._worker = threading.Thread(target=self._drain_loop, daemon=True)
        self._worker.start()

    # -- keys ---------------------------------------------------------------
    def _blob_key(self, step: int) -> str:
        return f"{self.prefix}/step_{step:012d}.blob"

    def _manifest_key(self, step: int) -> str:
        return f"{self.prefix}/step_{step:012d}.manifest"

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state: Any, block: bool = False) -> CheckpointInfo:
        """Checkpoint ``state`` (a pytree) at ``step``.

        Device→host copy happens here (synchronous, fast); serialization +
        persistent-tier write happen on the background thread unless
        ``block=True``.
        """
        self._check_drain_error()
        t0 = time.perf_counter()
        # Stage to host DRAM: device_get pulls all leaves. Under pjit each
        # addressable shard is fetched; for the single-process case this is
        # the full array.
        host_state = jax.device_get(state)
        nbytes = serde.leaf_bytes(host_state)
        info = CheckpointInfo(step, nbytes, "", time.perf_counter() - t0)
        self._q.put((step, host_state, info))
        if block:
            self.wait()
        return info

    def _drain_one(self, step: int, host_state: Any, info: CheckpointInfo) -> None:
        blob = serde.dumps(host_state)
        checksum = _digest(blob)
        self.tier.put(self._blob_key(step), blob)
        manifest = json.dumps(
            {"step": step, "nbytes": len(blob), "checksum": checksum}
        ).encode()
        # Manifest written last == commit point.
        self.tier.put(self._manifest_key(step), manifest)
        info.checksum = checksum
        self._gc()

    def _drain_loop(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            try:
                self._drain_one(*item)
            except BaseException as e:  # surfaced on next save/wait
                self._drain_err = e
            finally:
                self._q.task_done()

    def wait(self) -> None:
        """Block until all queued checkpoints are durable."""
        self._q.join()
        self._check_drain_error()

    def _check_drain_error(self) -> None:
        if self._drain_err is not None:
            err, self._drain_err = self._drain_err, None
            raise RuntimeError("async checkpoint drain failed") from err

    # -- restore ---------------------------------------------------------------
    def steps(self) -> List[int]:
        """Steps with *complete* (manifest-committed) checkpoints."""
        out = []
        for key in self.tier.keys():
            if key.startswith(self.prefix + "/") and key.endswith(".manifest"):
                stem = key[len(self.prefix) + 1 : -len(".manifest")]
                out.append(int(stem.split("_")[1]))
        return sorted(out)

    def restore(self, step: Optional[int] = None) -> Any:
        """Load the checkpoint at ``step`` (default: newest complete)."""
        self.wait()
        steps = self.steps()
        if not steps:
            raise FileNotFoundError(f"no checkpoints under {self.prefix}")
        if step is None:
            step = steps[-1]
        if step not in steps:
            raise FileNotFoundError(f"no complete checkpoint at step {step}")
        manifest = json.loads(self.tier.get(self._manifest_key(step)))
        blob = self.tier.get(self._blob_key(step))
        if _digest(blob) != manifest["checksum"]:
            raise IOError(f"checkpoint step {step} failed integrity check")
        return serde.loads(blob)

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    # -- gc ---------------------------------------------------------------
    def _gc(self) -> None:
        steps = self.steps()
        for old in steps[: -self.keep] if self.keep > 0 else []:
            self.tier.delete(self._manifest_key(old))
            self.tier.delete(self._blob_key(old))

    def close(self) -> None:
        self._q.put(None)
        self._worker.join(timeout=10)
