"""Storage tiers for Marvel-JAX.

The paper's central design object is a *tiered storage hierarchy*:

    Ignite (DRAM)  >  PMEM (AppDirect, DAX ext4)  >  local SSD  >  S3

Marvel keeps intermediate (shuffle) state in the top tier and durable
input/output in the PMEM tier, and shows that the S3-mediated baseline is
both slow and quota-limited (Lambda fails at 15 GB input).

On a TPU host there is no Optane DIMM; the tier *interface* is what the
system consumes.  We provide:

  * ``DramTier``     — plain in-process store (Ignite/IGFS analog).
  * ``PmemTier``     — mmap-backed, byte-addressable, persistent store
                       (AppDirect analog; on a real host this sits on a
                       DAX mount or NVMe — see DESIGN.md §2).
  * ``SimulatedTier``— wraps another tier and *models* the device's
                       bandwidth/latency/quotas (paper Table 2 for SSD,
                       AWS-documented limits for S3).  Used so the paper's
                       comparisons (Fig. 1/4/5) are reproducible on any box.

Every tier implements the same ``Tier`` protocol: byte-blob get/put/delete
plus accounting.  All sizes in bytes, all times in seconds.
"""

from __future__ import annotations

import contextlib
import mmap
import os
import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, Iterator, Mapping, Optional, Tuple

__all__ = [
    "TierStats",
    "Tier",
    "DramTier",
    "PmemTier",
    "SimulatedTier",
    "DeviceSpec",
    "PMEM_SPEC",
    "SSD_SPEC",
    "S3_SPEC",
    "QuotaExceededError",
    "tier_accounting",
    "tier_accounting_capture",
]


class QuotaExceededError(RuntimeError):
    """Raised by a simulated tier when a provider quota trips.

    Models the paper's observation that Corral-on-Lambda *fails* past 15 GB
    of input due to S3/Lambda rate limits (paper §1, §4.2 obs. (1)).
    Marked non-retryable: the scheduler fails the job immediately instead
    of burning attempts (quotas don't clear on retry).
    """

    non_retryable = True


@dataclass
class TierStats:
    """I/O accounting for one tier (drives the paper-figure benchmarks)."""

    bytes_read: int = 0
    bytes_written: int = 0
    read_ops: int = 0
    write_ops: int = 0
    #: Modeled (simulated) seconds spent in device time; real tiers leave 0.
    modeled_seconds: float = 0.0
    #: Wall-clock seconds actually spent inside tier calls.
    wall_seconds: float = 0.0

    def merge(self, other: "TierStats") -> "TierStats":
        return TierStats(
            self.bytes_read + other.bytes_read,
            self.bytes_written + other.bytes_written,
            self.read_ops + other.read_ops,
            self.write_ops + other.write_ops,
            self.modeled_seconds + other.modeled_seconds,
            self.wall_seconds + other.wall_seconds,
        )

    def merge_into(self, other: "TierStats") -> None:
        """In-place accumulate ``other`` (the hierarchy per-level rollup
        and the capture-and-forward accounting scope use this)."""
        self.bytes_read += other.bytes_read
        self.bytes_written += other.bytes_written
        self.read_ops += other.read_ops
        self.write_ops += other.write_ops
        self.modeled_seconds += other.modeled_seconds
        self.wall_seconds += other.wall_seconds


#: Thread-local accounting scope.  Tier stats are global per tier; a
#: multi-tenant caller (one gateway invoker among many) additionally wants
#: *its* share of the I/O.  ``tier_accounting(stats)`` routes every tier op
#: performed by the current thread into ``stats`` as well — per-scope
#: attribution without touching every call site.
_ACCOUNTING = threading.local()


@contextlib.contextmanager
def tier_accounting(stats: TierStats):
    """Also charge every tier op on this thread to ``stats`` (nestable —
    the enclosing scope is restored on exit).  The scoped stats are only
    touched by the owning thread, so no lock is needed on them."""
    prev = getattr(_ACCOUNTING, "stats", None)
    _ACCOUNTING.stats = stats
    try:
        yield stats
    finally:
        _ACCOUNTING.stats = prev


def _scoped_stats() -> Optional[TierStats]:
    return getattr(_ACCOUNTING, "stats", None)


@contextlib.contextmanager
def tier_accounting_capture():
    """Capture this thread's physical tier charges into a fresh
    :class:`TierStats` while still forwarding them to any enclosing
    ``tier_accounting`` scope on exit.

    The :class:`~repro.storage.hierarchy.TieredStore` uses this to learn
    how much modeled device time an op paid *inline* (its logical
    accounting) without hiding the physical ops from a gateway invoker's
    per-worker attribution — each op lands in the enclosing scope exactly
    once, so promoted reads are never double-counted there.
    """
    prev = getattr(_ACCOUNTING, "stats", None)
    captured = TierStats()
    _ACCOUNTING.stats = captured
    try:
        yield captured
    finally:
        _ACCOUNTING.stats = prev
        if prev is not None:
            prev.merge_into(captured)


class WatchRegistry:
    """Prefix-subscription registry: thread-safe, fire-after-commit.

    Shared by every tier and the :class:`~repro.storage.kvcache.StateCache`
    so watch semantics (handle lifecycle, snapshot-under-lock, fire
    outside it) live in exactly one place.
    """

    def __init__(self, lock: threading.Lock) -> None:
        self._lock = lock
        self._watchers: Dict[int, Tuple[str, Callable[[str], None]]] = {}
        self._seq = 0

    def watch(self, prefix: str, callback: Callable[[str], None]) -> Callable[[], None]:
        with self._lock:
            handle = self._seq
            self._seq += 1
            self._watchers[handle] = (prefix, callback)

        def unsubscribe() -> None:
            with self._lock:
                self._watchers.pop(handle, None)

        return unsubscribe

    def notify(self, key: str) -> None:
        if not self._watchers:
            return
        with self._lock:
            callbacks = [
                cb for prefix, cb in self._watchers.values()
                if key.startswith(prefix)
            ]
        for cb in callbacks:
            cb(key)


class Tier:
    """Byte-blob storage tier protocol."""

    name: str = "tier"
    #: Whether contents survive process restart (PMEM yes, DRAM no).
    persistent: bool = False

    def __init__(self) -> None:
        self.stats = TierStats()
        self._lock = threading.Lock()
        self._watch = WatchRegistry(self._lock)

    # -- protocol ---------------------------------------------------------
    def put(self, key: str, value: bytes) -> None:
        raise NotImplementedError

    def put_many(self, items: Mapping[str, bytes]) -> None:
        """Batched put.  The base implementation just loops; tiers with a
        per-op cost model override this to charge one request latency for
        the whole batch (the streaming-shuffle fast path)."""
        for key, value in items.items():
            self.put(key, value)

    def get(self, key: str) -> bytes:
        raise NotImplementedError

    def delete(self, key: str) -> None:
        raise NotImplementedError

    def contains(self, key: str) -> bool:
        raise NotImplementedError

    def keys(self, prefix: str = "") -> Iterator[str]:
        """Enumerate keys, optionally restricted to ``prefix``.

        Tiers push the filter down to their native index (dict scan,
        directory subtree) so a namespaced listing never enumerates —
        or accounts against — unrelated keys; the KV pager's per-session
        block listing made this a hot path."""
        raise NotImplementedError

    def size_of(self, key: str) -> int:
        return len(self.get(key))

    def clear(self) -> None:
        for k in list(self.keys()):
            self.delete(k)

    # -- events ----------------------------------------------------------
    def watch(self, prefix: str, callback: Callable[[str], None]) -> Callable[[], None]:
        """Invoke ``callback(key)`` after every committed put under
        ``prefix``.  Returns an unsubscribe callable.

        This is the hook that turns the state tier into an event bus: the
        DAG scheduler subscribes, so a shuffle partition landing in the
        tier immediately becomes a dataflow token for streaming consumers
        (no polling, no ``keys()`` rescans).
        Callbacks run on the writer's thread and must be cheap/non-blocking.
        """
        return self._watch.watch(prefix, callback)

    def _notify(self, key: str) -> None:
        """Fire watch callbacks for ``key`` (call *after* the value is
        readable, outside the tier lock)."""
        self._watch.notify(key)

    # -- accounting helpers -------------------------------------------------
    def _account_read(self, nbytes: int, wall: float, modeled: float = 0.0) -> None:
        with self._lock:
            self.stats.bytes_read += nbytes
            self.stats.read_ops += 1
            self.stats.wall_seconds += wall
            self.stats.modeled_seconds += modeled
        scoped = _scoped_stats()
        if scoped is not None:
            scoped.bytes_read += nbytes
            scoped.read_ops += 1
            scoped.wall_seconds += wall
            scoped.modeled_seconds += modeled

    def _account_write(self, nbytes: int, wall: float, modeled: float = 0.0) -> None:
        with self._lock:
            self.stats.bytes_written += nbytes
            self.stats.write_ops += 1
            self.stats.wall_seconds += wall
            self.stats.modeled_seconds += modeled
        scoped = _scoped_stats()
        if scoped is not None:
            scoped.bytes_written += nbytes
            scoped.write_ops += 1
            scoped.wall_seconds += wall
            scoped.modeled_seconds += modeled


class DramTier(Tier):
    """In-process DRAM store — the Ignite/IGFS analog.

    Fast path for intermediate (shuffle) data and function state; volatile.
    """

    name = "dram"
    persistent = False

    def __init__(self, capacity_bytes: Optional[int] = None) -> None:
        super().__init__()
        self._data: Dict[str, bytes] = {}
        self._capacity = capacity_bytes
        self._used = 0

    def put(self, key: str, value: bytes) -> None:
        t0 = time.perf_counter()
        with self._lock:
            old = self._data.get(key)
            new_used = self._used - (len(old) if old else 0) + len(value)
            if self._capacity is not None and new_used > self._capacity:
                raise MemoryError(
                    f"DramTier capacity {self._capacity} exceeded ({new_used} needed)"
                )
            self._data[key] = value
            self._used = new_used
        self._account_write(len(value), time.perf_counter() - t0)
        self._notify(key)

    def put_many(self, items: Mapping[str, bytes]) -> None:
        t0 = time.perf_counter()
        with self._lock:
            # Validate the whole batch before mutating: a capacity failure
            # must not leave unnotified, unaccounted orphan blobs behind.
            new_used = self._used
            for key, value in items.items():
                old = self._data.get(key)
                new_used += len(value) - (len(old) if old else 0)
            if self._capacity is not None and new_used > self._capacity:
                raise MemoryError(
                    f"DramTier capacity {self._capacity} exceeded "
                    f"({new_used} needed)"
                )
            self._data.update(items)
            self._used = new_used
        wall = time.perf_counter() - t0
        for key, value in items.items():
            self._account_write(len(value), wall / max(1, len(items)))
            self._notify(key)

    def get(self, key: str) -> bytes:
        t0 = time.perf_counter()
        value = self._data[key]
        self._account_read(len(value), time.perf_counter() - t0)
        return value

    def delete(self, key: str) -> None:
        with self._lock:
            old = self._data.pop(key, None)
            if old is not None:
                self._used -= len(old)

    def contains(self, key: str) -> bool:
        return key in self._data

    def keys(self, prefix: str = "") -> Iterator[str]:
        if not prefix:
            return iter(list(self._data.keys()))
        return iter([k for k in self._data if k.startswith(prefix)])

    def size_of(self, key: str) -> int:
        return len(self._data[key])

    @property
    def used_bytes(self) -> int:
        return self._used


class PmemTier(Tier):
    """mmap-backed persistent tier — the PMEM AppDirect / DAX-ext4 analog.

    Each blob is one file under ``root``; reads/writes go through ``mmap``
    so access is byte-addressable like a DAX mapping.  Contents survive
    process restart — this is the substrate for the checkpoint/restart
    fault-tolerance story (paper §4.3, implemented here).
    """

    name = "pmem"
    persistent = True

    def __init__(self, root: str) -> None:
        super().__init__()
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _path(self, key: str) -> str:
        # Keys may contain '/', which maps to subdirectories.
        safe = key.replace("..", "_")
        return os.path.join(self.root, safe)

    def put(self, key: str, value: bytes) -> None:
        t0 = time.perf_counter()
        path = self._path(key)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w+b") as f:
            if value:
                f.truncate(len(value))
                with mmap.mmap(f.fileno(), len(value)) as m:
                    m[:] = value
                    m.flush()  # persistence point (clwb/sfence analog)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)  # atomic publish
        self._account_write(len(value), time.perf_counter() - t0)
        self._notify(key)

    def get(self, key: str) -> bytes:
        t0 = time.perf_counter()
        path = self._path(key)
        with open(path, "rb") as f:
            size = os.fstat(f.fileno()).st_size
            if size == 0:
                value = b""
            else:
                with mmap.mmap(f.fileno(), size, access=mmap.ACCESS_READ) as m:
                    value = bytes(m)
        self._account_read(len(value), time.perf_counter() - t0)
        return value

    def delete(self, key: str) -> None:
        path = self._path(key)
        if os.path.exists(path):
            os.remove(path)

    def contains(self, key: str) -> bool:
        return os.path.exists(self._path(key))

    def keys(self, prefix: str = "") -> Iterator[str]:
        # Keys map to paths, so a '/'-delimited prefix names a directory
        # subtree: walk only that subtree instead of the whole root.
        start = self.root
        if prefix:
            dir_part = prefix.rsplit("/", 1)[0] if "/" in prefix else ""
            start = os.path.join(self.root, dir_part.replace("..", "_"))
            if not os.path.isdir(start):
                return iter([])
        out = []
        for dirpath, _dirnames, filenames in os.walk(start):
            for fn in filenames:
                if fn.endswith(".tmp"):
                    continue
                full = os.path.join(dirpath, fn)
                rel = os.path.relpath(full, self.root)
                if prefix and not rel.startswith(prefix):
                    continue
                out.append(rel)
        return iter(out)

    def size_of(self, key: str) -> int:
        return os.path.getsize(self._path(key))


@dataclass(frozen=True)
class DeviceSpec:
    """Bandwidth/latency/quota model of a storage device or service.

    Constants for PMEM/SSD come from paper Table 2 (fio, 4 KiB blocks);
    S3 constants follow the AWS-documented request-rate and Lambda quotas
    the paper cites for the 15 GB failure.
    """

    name: str
    read_bw: float  # bytes/s sustained
    write_bw: float  # bytes/s sustained
    read_latency: float  # seconds per op
    write_latency: float  # seconds per op
    #: max bytes a single job may move through this device (None = unlimited).
    transfer_quota: Optional[int] = None
    #: max concurrent requests before throttling errors (None = unlimited).
    request_quota: Optional[int] = None


# Paper Table 2 (seq read/write rows; GiB/s → bytes/s).
PMEM_SPEC = DeviceSpec(
    name="pmem",
    read_bw=41.0 * 2**30,
    write_bw=13.6 * 2**30,
    read_latency=0.6e-6,
    write_latency=1.9e-6,
)
SSD_SPEC = DeviceSpec(
    name="ssd",
    read_bw=0.4 * 2**30,
    write_bw=0.5 * 2**30,
    read_latency=4.7e-3,
    write_latency=5.0e-3,
)
# S3 through Lambda: ~90 MB/s effective per function stream, ~20 ms first
# byte; 15 GB aggregate transfer quota (the paper-observed failure point),
# 3500 PUT / 5500 GET per prefix-second modeled via request_quota.
S3_SPEC = DeviceSpec(
    name="s3",
    read_bw=90e6,
    write_bw=90e6,
    read_latency=20e-3,
    write_latency=30e-3,
    transfer_quota=15 * 10**9,
    request_quota=5500,
)


class SimulatedTier(Tier):
    """Wraps a backing tier with a :class:`DeviceSpec` cost/quota model.

    The blob actually lives in the backing store (so correctness is real);
    the *time* each op would take on the modeled device is accumulated in
    ``stats.modeled_seconds``.  ``sleep=True`` additionally sleeps a scaled
    fraction of the modeled time so end-to-end wall-clock comparisons (the
    paper's Fig. 4/5) show the same ordering without taking hours.
    """

    def __init__(
        self,
        spec: DeviceSpec,
        backing: Optional[Tier] = None,
        sleep: bool = False,
        sleep_scale: float = 1e-3,
    ) -> None:
        super().__init__()
        self.spec = spec
        self.name = f"sim:{spec.name}"
        self.persistent = backing.persistent if backing else False
        self._backing = backing if backing is not None else DramTier()
        self._sleep = sleep
        self._sleep_scale = sleep_scale
        self._transferred = 0

    # -- cost model -------------------------------------------------------
    def _charge(self, nbytes: int, write: bool, ops: int = 1) -> float:
        """Model ``ops`` request latencies + ``nbytes`` of transfer.

        A batched put (``put_many``) charges a single request latency for
        the whole batch — bandwidth is paid in full either way.
        """
        spec = self.spec
        if spec.transfer_quota is not None:
            with self._lock:
                self._transferred += nbytes
                if self._transferred > spec.transfer_quota:
                    raise QuotaExceededError(
                        f"{spec.name}: transfer quota {spec.transfer_quota} B "
                        f"exceeded ({self._transferred} B moved) — this is the "
                        f"paper's 15 GB Lambda/S3 failure mode"
                    )
        bw = spec.write_bw if write else spec.read_bw
        lat = spec.write_latency if write else spec.read_latency
        modeled = lat * ops + nbytes / bw
        if self._sleep:
            time.sleep(modeled * self._sleep_scale)
        return modeled

    # -- protocol ---------------------------------------------------------
    def put(self, key: str, value: bytes) -> None:
        t0 = time.perf_counter()
        modeled = self._charge(len(value), write=True)
        self._backing.put(key, value)
        self._account_write(len(value), time.perf_counter() - t0, modeled)
        self._notify(key)

    def put_many(self, items: Mapping[str, bytes]) -> None:
        """One modeled request for the whole batch (scatter/multi-part
        write) — the streaming shuffle's escape from per-blob latency."""
        if not items:
            return  # no request, no charge
        t0 = time.perf_counter()
        total = sum(len(v) for v in items.values())
        modeled = self._charge(total, write=True, ops=1)
        self._backing.put_many(items)
        wall = time.perf_counter() - t0
        n = max(1, len(items))
        for key, value in items.items():
            self._account_write(len(value), wall / n, modeled / n)
            self._notify(key)

    def get(self, key: str) -> bytes:
        t0 = time.perf_counter()
        value = self._backing.get(key)
        modeled = self._charge(len(value), write=False)
        self._account_read(len(value), time.perf_counter() - t0, modeled)
        return value

    def delete(self, key: str) -> None:
        self._backing.delete(key)

    def contains(self, key: str) -> bool:
        return self._backing.contains(key)

    def keys(self, prefix: str = "") -> Iterator[str]:
        return self._backing.keys(prefix)

    def size_of(self, key: str) -> int:
        return self._backing.size_of(key)

    def reset_quota(self) -> None:
        with self._lock:
            self._transferred = 0
