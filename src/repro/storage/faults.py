"""Deterministic fault injection for storage tiers.

Crash paths (torn commits, flaky devices, latency spikes) are the part of
the paper's durability story that example-based tests cannot reach: the
interesting failures happen *mid-operation*.  :class:`FaultInjectingTier`
wraps any :class:`~repro.storage.tiers.Tier` and injects faults from a
**seeded RNG** plus an optional explicit per-op schedule, so every failing
run is reproducible bit-for-bit:

  * ``put``/``get`` raising :class:`IOError` (device error, lost NIC),
  * **torn** ``put_many``: a strict prefix of the batch lands in the
    backing tier, then the op raises — models a crash mid-multi-part
    commit (the case partition-granular journaling must survive),
  * latency spikes: a slow op (sleeps ``spike_seconds``) without an error
    — models the paper's observed S3 tail latencies.

Faults are counted per *kind* against a monotonically increasing op
counter, so ``schedule={("put", 3)}`` means "the 4th put fails" regardless
of interleaving with gets.  ``heal()`` turns all injection off (the tier
keeps serving), which crash/recovery tests use to flip from the failing
phase to the recovery phase.
"""

from __future__ import annotations

import random
import time
from typing import Iterable, Iterator, Mapping, Optional, Set, Tuple

from repro.storage.tiers import Tier

__all__ = [
    "FaultInjectingTier",
    "InjectedIOError",
    "LinkPartitionError",
    "TornWriteError",
]


class InjectedIOError(IOError):
    """An injected device error (distinguishable from real IOErrors)."""


class LinkPartitionError(InjectedIOError):
    """A cross-node transfer attempted over a partitioned network link.

    Raised by :class:`repro.core.cluster.NetworkFabric` while a link is
    partitioned (``fabric.partition(a, b)``); heals with
    ``fabric.heal()``.  Subclassing :class:`InjectedIOError` keeps the
    cluster fault matrix on the same error taxonomy as the storage
    fault-injection harness."""


class TornWriteError(InjectedIOError):
    """A ``put_many`` that persisted only a strict prefix of the batch."""

    def __init__(self, message: str, landed: int, total: int) -> None:
        super().__init__(message)
        self.landed = landed
        self.total = total


class FaultInjectingTier(Tier):
    """Wraps ``backing`` with seeded, schedulable fault injection.

    ``*_error_rate`` are per-op probabilities drawn from ``random.Random
    (seed)`` — deterministic given the op sequence.  ``schedule`` is a set
    of ``(kind, op_index)`` pairs forcing a fault at an exact per-kind op
    index (0-based); kinds are ``"put"``, ``"get"``, ``"torn"`` (applies
    to ``put_many``), and ``"spike"`` (applies to both put and get).
    """

    def __init__(
        self,
        backing: Tier,
        seed: int = 0,
        put_error_rate: float = 0.0,
        get_error_rate: float = 0.0,
        torn_put_many_rate: float = 0.0,
        spike_rate: float = 0.0,
        spike_seconds: float = 0.005,
        schedule: Optional[Iterable[Tuple[str, int]]] = None,
    ) -> None:
        super().__init__()
        self._backing = backing
        self.name = f"faulty:{backing.name}"
        self.persistent = backing.persistent
        self._rng = random.Random(seed)
        self.put_error_rate = put_error_rate
        self.get_error_rate = get_error_rate
        self.torn_put_many_rate = torn_put_many_rate
        self.spike_rate = spike_rate
        self.spike_seconds = spike_seconds
        self._schedule: Set[Tuple[str, int]] = set(schedule or ())
        self._ops = {"put": 0, "get": 0, "torn": 0, "spike": 0}
        self._armed = True
        self.injected = {"put": 0, "get": 0, "torn": 0, "spike": 0}

    # -- control -----------------------------------------------------------
    def heal(self) -> None:
        """Stop injecting (the tier keeps serving, faults stay counted)."""
        self._armed = False

    def arm(self) -> None:
        self._armed = True

    def _trip(self, kind: str, rate: float) -> bool:
        """One fault decision; advances the per-kind op counter either way
        (so RNG draws and schedule indices are stable across arm/heal)."""
        with self._lock:
            idx = self._ops[kind]
            self._ops[kind] += 1
            fire = (kind, idx) in self._schedule or (
                rate > 0.0 and self._rng.random() < rate
            )
            if fire and self._armed:
                self.injected[kind] += 1
                return True
            return False

    def _maybe_spike(self) -> None:
        if self._trip("spike", self.spike_rate):
            time.sleep(self.spike_seconds)

    # -- protocol ----------------------------------------------------------
    def put(self, key: str, value: bytes) -> None:
        self._maybe_spike()
        if self._trip("put", self.put_error_rate):
            raise InjectedIOError(f"injected put failure for {key!r}")
        self._backing.put(key, value)
        self._notify(key)

    def put_many(self, items: Mapping[str, bytes]) -> None:
        if self._trip("torn", self.torn_put_many_rate) and len(items) > 0:
            # Persist a strict prefix (possibly empty), then fail: the
            # batch is torn exactly where a crash mid-commit would tear it.
            pairs = list(items.items())
            landed = self._rng.randrange(len(pairs))
            for key, value in pairs[:landed]:
                self._backing.put(key, value)
                self._notify(key)
            raise TornWriteError(
                f"injected torn put_many: {landed}/{len(pairs)} landed",
                landed, len(pairs),
            )
        self._maybe_spike()
        self._backing.put_many(items)
        for key in items:
            self._notify(key)

    def get(self, key: str) -> bytes:
        self._maybe_spike()
        if self._trip("get", self.get_error_rate):
            raise InjectedIOError(f"injected get failure for {key!r}")
        return self._backing.get(key)

    def delete(self, key: str) -> None:
        self._backing.delete(key)

    def contains(self, key: str) -> bool:
        return self._backing.contains(key)

    def keys(self, prefix: str = "") -> Iterator[str]:
        return self._backing.keys(prefix)

    def size_of(self, key: str) -> int:
        return self._backing.size_of(key)

    @property
    def stats(self):  # I/O accounting lives in the backing tier
        return self._backing.stats

    @stats.setter
    def stats(self, value) -> None:  # Tier.__init__ assigns; ignore
        pass
