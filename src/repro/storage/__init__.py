"""Storage substrate: tiers (DRAM/PMEM/simulated SSD/S3), HDFS-analog block
store, Ignite-analog state cache, and tiered async checkpointing."""

from repro.storage.blockstore import BlockStore, DataNode
from repro.storage.checkpoint import CheckpointManager
from repro.storage.faults import FaultInjectingTier, InjectedIOError, TornWriteError
from repro.storage.hierarchy import PlacementPolicy, TieredStore, TierLevel
from repro.storage.kvcache import StateCache
from repro.storage.tiers import (
    PMEM_SPEC,
    S3_SPEC,
    SSD_SPEC,
    DeviceSpec,
    DramTier,
    PmemTier,
    QuotaExceededError,
    SimulatedTier,
    Tier,
    TierStats,
    tier_accounting,
)

__all__ = [
    "BlockStore",
    "DataNode",
    "CheckpointManager",
    "FaultInjectingTier",
    "InjectedIOError",
    "TornWriteError",
    "StateCache",
    "PlacementPolicy",
    "TierLevel",
    "TieredStore",
    "DeviceSpec",
    "DramTier",
    "PmemTier",
    "QuotaExceededError",
    "SimulatedTier",
    "Tier",
    "TierStats",
    "tier_accounting",
    "PMEM_SPEC",
    "SSD_SPEC",
    "S3_SPEC",
]
