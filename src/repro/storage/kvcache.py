"""Ignite/IGFS analog: an in-memory KV state cache with TTL + spill.

Marvel deploys Apache Ignite as the fast shared tier holding (a) function
state and (b) intermediate (shuffle) data.  The essential properties the
runtime consumes:

  * shared across all functions of an application (here: process-wide),
  * near-DRAM latency,
  * optional write-through to a persistent tier (the paper's §4.3 "Ignite
    on top of PMEM" future work — implemented here so state survives
    failures),
  * namespacing per application/session.

Values are arbitrary bytes; the pytree (de)serialization lives in
``storage/serde.py`` so jax arrays can ride through unchanged.
"""

from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List, Mapping, Optional

from repro.storage.tiers import DramTier, Tier, WatchRegistry

__all__ = ["StateCache"]


def _tier_keys(tier: Tier, prefix: str):
    """Delegate a prefix listing to the tier; fall back to filtering for
    legacy tiers whose ``keys()`` takes no prefix."""
    try:
        return tier.keys(prefix)
    except TypeError:
        return (k for k in tier.keys() if k.startswith(prefix))


class StateCache:
    """In-memory KV cache with optional write-through persistence.

    ``write_through=None`` reproduces stock Marvel (volatile Ignite).
    Passing a persistent tier gives the checkpoint-capable variant: every
    put lands in DRAM *and* the persistent tier, and ``recover()`` reloads
    the DRAM view after a (simulated) crash.

    Thread-safety: safe for concurrent use by many invokers.  Individual
    ops are atomic (tiers lock internally; TTL bookkeeping is under the
    cache lock; ``get`` tolerates a concurrent ``delete`` between its
    membership check and the read by falling through to the demand-fault
    path).  Cross-key consistency is the caller's job — the gateway's
    per-session leases guarantee one writer per state key.
    """

    def __init__(
        self,
        memory: Optional[Tier] = None,
        write_through: Optional[Tier] = None,
    ) -> None:
        self.memory = memory if memory is not None else DramTier()
        self.write_through = write_through
        self._ttl: Dict[str, float] = {}
        #: key -> version stamp of the blob last stored via
        #: :meth:`put_versioned` (volatile; cleared on ``crash``).
        self._versions: Dict[str, int] = {}
        self._lock = threading.Lock()
        self._watch = WatchRegistry(self._lock)

    # -- basic KV -----------------------------------------------------------
    def put(self, key: str, value: bytes, ttl: Optional[float] = None) -> None:
        self.memory.put(key, value)
        with self._lock:
            if ttl is not None:
                self._ttl[key] = time.monotonic() + ttl
            self._versions.pop(key, None)  # overwrite invalidates the memo
        if self.write_through is not None:
            self.write_through.put(key, value)
        self._notify(key)

    def put_many(self, items: Mapping[str, bytes]) -> None:
        """Batched put: one request to each tier for the whole batch (the
        tiers charge a single modeled latency — see ``Tier.put_many``)."""
        self.memory.put_many(items)
        with self._lock:
            for key in items:  # overwrite kills any stale TTL / version memo
                self._ttl.pop(key, None)
                self._versions.pop(key, None)
        if self.write_through is not None:
            self.write_through.put_many(items)
        for key in items:
            self._notify(key)

    def put_versioned(self, key: str, value: bytes, version: int) -> bool:
        """Put ``value`` unless this exact ``version`` of ``key`` was
        already stored through this method — the lazy serde fast path:
        committing an unchanged state becomes a dict probe instead of a
        physical tier write.  Version stamps must be unique per distinct
        value (the runtime draws them from one monotonic clock).  The
        memo is volatile: ``crash()`` clears it, so the first commit
        after recovery always re-persists.  Returns True iff the tier
        write happened."""
        with self._lock:
            if self._versions.get(key) == version:
                return False
        self.put(key, value)
        with self._lock:
            self._versions[key] = version
        return True

    def watch(self, prefix: str, callback: Callable[[str], None]) -> Callable[[], None]:
        """Invoke ``callback(key)`` after every *commit* (put/put_many)
        under ``prefix``.  Returns an unsubscribe callable.

        The cache keeps its own registry rather than delegating to the
        DRAM tier: internal re-reads (demand faults after a crash,
        ``recover()``) land in the memory tier too but are not new
        commits and must not produce events.
        """
        return self._watch.watch(prefix, callback)

    def _notify(self, key: str) -> None:
        self._watch.notify(key)

    def get(self, key: str) -> bytes:
        with self._lock:
            expiry = self._ttl.get(key)
            expired = expiry is not None and time.monotonic() > expiry
            if expired:
                self.memory.delete(key)
                del self._ttl[key]
        if not expired:
            try:
                return self.memory.get(key)
            except (KeyError, FileNotFoundError):
                pass  # deleted/evicted concurrently — try the durable tier
        # Demand-fault from the persistent tier (crash recovery path).
        if self.write_through is not None and self.write_through.contains(key):
            value = self.write_through.get(key)
            self.memory.put(key, value)
            return value
        raise KeyError(key)

    def contains(self, key: str) -> bool:
        if self.memory.contains(key):
            return True
        return self.write_through is not None and self.write_through.contains(key)

    def delete(self, key: str) -> None:
        self.memory.delete(key)
        if self.write_through is not None:
            self.write_through.delete(key)
        with self._lock:
            self._ttl.pop(key, None)
            self._versions.pop(key, None)

    def demote(self, key: str) -> bool:
        """Push ``key`` out of the fast tier without losing it — the
        gateway's warm-pool eviction calls this so a spilled session's
        state blob stops occupying DRAM.

        On a :class:`~repro.storage.hierarchy.TieredStore` memory tier
        this is a real one-level demotion; on a plain memory tier with
        write-through it drops the DRAM copy (the durable copy serves
        the next read); with neither there is nowhere to demote *to* and
        the key stays put.  Returns True if the key actually moved.
        """
        demoter = getattr(self.memory, "demote", None)
        if demoter is not None:
            return bool(demoter(key))
        if self.write_through is not None and self.write_through.contains(key):
            self.memory.delete(key)
            return True
        return False

    def keys(self, prefix: str = "") -> List[str]:
        """Prefix-filtered listing, pushed down to the tiers.

        Tiers filter against their native index (dict scan, directory
        subtree walk) so a namespaced listing never enumerates unrelated
        keys — the KV pager's per-session block enumeration made the
        old scan-everything-then-filter loop a hot path.  Tiers from
        outside this package that predate the ``prefix`` parameter are
        still accepted (filtered here instead)."""
        seen = set()
        seen.update(_tier_keys(self.memory, prefix))
        if self.write_through is not None:
            seen.update(_tier_keys(self.write_through, prefix))
        return sorted(seen)

    # -- crash / recovery --------------------------------------------------
    def crash(self) -> None:
        """Drop the volatile view (simulates node loss of the DRAM tier).

        A hierarchy-backed memory tier loses only its volatile *levels*
        (``TieredStore.crash``) — wiping its persistent levels too would
        simulate a disk fire, not a node failure."""
        crasher = getattr(self.memory, "crash", None)
        if crasher is not None:
            crasher()
        else:
            self.memory.clear()
        with self._lock:
            self._ttl.clear()
            self._versions.clear()  # next put_versioned must re-persist

    def recover(self) -> int:
        """Reload the fast view from persistent storage; returns keys
        restored (journal-replayed write-back keys count for a hierarchy
        memory tier)."""
        n = 0
        recoverer = getattr(self.memory, "recover", None)
        if recoverer is not None:
            n += int(recoverer())
        if self.write_through is None:
            return n
        for k in self.write_through.keys():
            self.memory.put(k, self.write_through.get(k))
            n += 1
        return n

    # -- namespacing helper --------------------------------------------------
    def namespaced(self, namespace: str) -> "NamespacedCache":
        return NamespacedCache(self, namespace)


class NamespacedCache:
    """View of a :class:`StateCache` under a fixed key prefix."""

    def __init__(self, cache: StateCache, namespace: str) -> None:
        self._cache = cache
        self._prefix = namespace.rstrip("/") + "/"

    def put(self, key: str, value: bytes, ttl: Optional[float] = None) -> None:
        self._cache.put(self._prefix + key, value, ttl)

    def get(self, key: str) -> bytes:
        return self._cache.get(self._prefix + key)

    def contains(self, key: str) -> bool:
        return self._cache.contains(self._prefix + key)

    def delete(self, key: str) -> None:
        self._cache.delete(self._prefix + key)

    def keys(self) -> List[str]:
        plen = len(self._prefix)
        return [k[plen:] for k in self._cache.keys(self._prefix)]
