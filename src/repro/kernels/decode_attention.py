"""Flash-decode — Pallas TPU kernel for single-token KV-cache attention.

One query row per (batch, kv-head group); the cache is streamed in
``s_block`` panels along the sequence axis (grid axis 1, sequential) with
online-softmax accumulators in VMEM.  This is the kernel twin of the
sequence-sharded decode layout in ``parallel/sharding.py`` — on a pod the
same partial-softmax trick runs across chips; inside a chip this kernel
runs it across VMEM panels.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import _compiler_params

__all__ = ["decode_attention_fwd"]

DEFAULT_S_BLOCK = 1024
MASK_VALUE = -1e30


def _kernel(
    length_ref,  # scalar prefetch: (1,) int32 valid cache length
    q_ref, k_ref, v_ref,
    o_ref,
    acc_ref, m_ref, l_ref,
    *,
    scale: float,
    softcap: Optional[float],
    s_block: int,
):
    si = pl.program_id(1)
    ns = pl.num_programs(1)

    @pl.when(si == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, MASK_VALUE)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = length_ref[pl.program_id(0)]
    block_live = si * s_block < length

    @pl.when(block_live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (H, dh)
        k = k_ref[0].astype(jnp.float32)  # (s_block, dh... ) -> (s_block, dh)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # (H, s_block)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        pos = si * s_block + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1
        )
        mask = pos < length
        s = jnp.where(mask, s, MASK_VALUE)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=-1)
        m_ref[...] = m_new
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv

    @pl.when(si == ns - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention_fwd(
    q: jax.Array,  # (B, H, dh) one token per sequence
    k_cache: jax.Array,  # (B, S, dh) — per-kv-head flattened upstream
    v_cache: jax.Array,  # (B, S, dh)
    lengths: jax.Array,  # (B,) int32 valid entries
    *,
    scale: Optional[float] = None,
    softcap: Optional[float] = None,
    s_block: int = DEFAULT_S_BLOCK,
    interpret: bool = False,
) -> jax.Array:
    """Single-token attention over a KV cache, streamed in S panels."""
    B, H, dh = q.shape
    _, S, _ = k_cache.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    s_block = min(s_block, S)
    ns = -(-S // s_block)
    pad = ns * s_block - S
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0)))

    kernel = functools.partial(
        _kernel, scale=scale, softcap=softcap, s_block=s_block
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, ns),
        in_specs=[
            pl.BlockSpec((1, H, dh), lambda b, s, L: (b, 0, 0)),
            pl.BlockSpec((1, s_block, dh), lambda b, s, L: (b, s, 0)),
            pl.BlockSpec((1, s_block, dh), lambda b, s, L: (b, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, dh), lambda b, s, L: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((H, dh), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, dh), q.dtype),
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lengths, q, k_cache, v_cache)
    return out
