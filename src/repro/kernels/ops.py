"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only: the
kernel bodies execute in Python for validation; on TPU hardware the same
calls compile to Mosaic).  GQA plumbing (head expansion / flattening)
lives here so the kernels stay single-layout.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.bucket_histogram import bucket_histogram
from repro.kernels.decode_attention import decode_attention_fwd
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.ssd_scan import ssd_chunk_fwd

__all__ = [
    "on_tpu",
    "flash_attention",
    "decode_attention",
    "ssd_chunk",
    "shuffle_histogram",
    "partition_counts",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interp(interpret: Optional[bool]) -> bool:
    return (not on_tpu()) if interpret is None else interpret


@functools.partial(
    jax.jit, static_argnames=("causal", "scale", "softcap", "interpret")
)
def flash_attention(
    q: jax.Array,  # (B, Tq, H, dh)
    k: jax.Array,  # (B, Tk, Kv, dh)
    v: jax.Array,
    causal: bool = True,
    scale: Optional[float] = None,
    softcap: Optional[float] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Batched GQA flash attention -> (B, Tq, H, dh)."""
    B, Tq, H, dh = q.shape
    Kv = k.shape[2]
    rep = H // Kv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Tq, dh)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, -1, dh)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, -1, dh)
    o = flash_attention_fwd(
        qf, kf, vf, causal=causal, scale=scale, softcap=softcap,
        interpret=_interp(interpret),
    )
    return o.reshape(B, H, Tq, dh).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("scale", "softcap", "interpret"))
def decode_attention(
    q: jax.Array,  # (B, H, dh)
    k_cache: jax.Array,  # (B, S, Kv, dh)
    v_cache: jax.Array,
    lengths: jax.Array,  # (B,) int32
    scale: Optional[float] = None,
    softcap: Optional[float] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    B, H, dh = q.shape
    S, Kv = k_cache.shape[1], k_cache.shape[2]
    rep = H // Kv
    # one kernel batch row per (b, kv head); q rows grouped by kv head
    qg = q.reshape(B, Kv, rep, dh).reshape(B * Kv, rep, dh)
    kf = k_cache.transpose(0, 2, 1, 3).reshape(B * Kv, S, dh)
    vf = v_cache.transpose(0, 2, 1, 3).reshape(B * Kv, S, dh)
    lg = jnp.repeat(lengths, Kv)
    o = decode_attention_fwd(
        qg, kf, vf, lg, scale=scale, softcap=softcap,
        interpret=_interp(interpret),
    )
    return o.reshape(B, Kv, rep, dh).reshape(B, H, dh)


@functools.partial(jax.jit, static_argnames=("head_block", "interpret"))
def ssd_chunk(
    x: jax.Array, dt: jax.Array, dA_cs: jax.Array, Bm: jax.Array,
    Cm: jax.Array, head_block: int = 8, interpret: Optional[bool] = None,
):
    return ssd_chunk_fwd(
        x, dt, dA_cs, Bm, Cm, head_block=head_block,
        interpret=_interp(interpret),
    )


@functools.partial(
    jax.jit, static_argnames=("n_buckets", "block", "interpret", "out_dtype")
)
def shuffle_histogram(
    keys: jax.Array, n_buckets: int, block: int = 2048,
    interpret: Optional[bool] = None, out_dtype=jnp.int32,
) -> jax.Array:
    return bucket_histogram(
        keys, n_buckets, block=block, interpret=_interp(interpret),
        out_dtype=out_dtype,
    )


def partition_counts(
    dest: jax.Array,  # (N,) int32 partition ids; negative = padding
    n_parts: int,
    block: int = 2048,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Per-partition pair counts for the shuffle planner — the dataflow
    engine's entry point onto :func:`bucket_histogram`.

    ``n_parts`` is the engine's reducer count (usually 4), far below the
    TPU lane width: the kernel runs over a lane-aligned bucket panel and
    the result is sliced back down.  Empty input yields zero counts.
    """
    if n_parts < 1:
        raise ValueError(f"n_parts must be >= 1, got {n_parts}")
    lanes = -(-n_parts // 128) * 128  # lane-aligned (min f32 tile is 128)
    hist = shuffle_histogram(
        dest, lanes, block=block, interpret=interpret
    )
    return hist[:n_parts]
