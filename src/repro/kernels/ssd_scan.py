"""Mamba-2 SSD within-chunk kernel — Pallas TPU.

Computes, for one (batch, chunk, head-block) cell:

    y_diag[q] = sum_{j<=q} (C_q·B_j) exp(dA_cs[q]-dA_cs[j]) dt_j x_j
    S         = sum_j exp(seg - dA_cs[j]) dt_j B_j ⊗ x_j     (chunk state)

i.e. the quadratic "attention-like" part of SSD plus the per-chunk state
contribution.  The cheap inter-chunk recurrence (nc steps over (H,P,N)
states) stays in jax ``lax.scan`` (models/ssm.py) — it's O(L/Q) elementwise
work, not a kernel-worthy hot spot.

Grid: (B*nc, H/head_block); blocks sized so the (Q, Q, Hb) decay tensor and
the (Q, P)/(Q, N) panels fit VMEM with MXU-aligned minor dims.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import _compiler_params

__all__ = ["ssd_chunk_fwd"]

DEFAULT_HEAD_BLOCK = 8


def _kernel(x_ref, dt_ref, dacs_ref, b_ref, c_ref, y_ref, s_ref):
    # shapes per block: x (1, Q, Hb, P); dt/dacs (1, Q, Hb); b/c (1, Q, Hb, N)
    x = x_ref[0].astype(jnp.float32)
    dt = dt_ref[0].astype(jnp.float32)
    dacs = dacs_ref[0].astype(jnp.float32)
    Bm = b_ref[0].astype(jnp.float32)
    Cm = c_ref[0].astype(jnp.float32)
    Q = x.shape[0]

    # decay L[q, j, h] = exp(dacs[q] - dacs[j]) masked to lower triangle
    decay = jnp.exp(dacs[:, None, :] - dacs[None, :, :])
    qi = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    kj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    tril = (qi >= kj)[:, :, None]
    decay = jnp.where(tril, decay, 0.0)

    cb = jnp.einsum("qhn,jhn->qjh", Cm, Bm,
                    preferred_element_type=jnp.float32)
    w = cb * decay * dt[None, :, :]  # (Q, Qj, H)
    y = jnp.einsum("qjh,jhp->qhp", w, x,
                   preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)

    # chunk state: S[h, p, n] = sum_j exp(seg - dacs[j]) dt_j B_j x_j
    seg = dacs[-1]  # (Hb,)
    sdecay = jnp.exp(seg[None, :] - dacs) * dt  # (Q, Hb)
    s_ref[0] = jnp.einsum(
        "jh,jhn,jhp->hpn", sdecay, Bm, x,
        preferred_element_type=jnp.float32,
    ).astype(s_ref.dtype)


def ssd_chunk_fwd(
    x: jax.Array,  # (BC, Q, H, P) chunked inputs (batch*chunks flattened)
    dt: jax.Array,  # (BC, Q, H) post-softplus
    dA_cs: jax.Array,  # (BC, Q, H) within-chunk cumsum of dt*A
    Bm: jax.Array,  # (BC, Q, H, N)
    Cm: jax.Array,  # (BC, Q, H, N)
    *,
    head_block: int = DEFAULT_HEAD_BLOCK,
    interpret: bool = False,
):
    """Returns (y_diag (BC,Q,H,P), chunk_states (BC,H,P,N))."""
    BC, Q, H, P = x.shape
    N = Bm.shape[-1]
    hb = min(head_block, H)
    assert H % hb == 0
    nh = H // hb

    out = pl.pallas_call(
        _kernel,
        grid=(BC, nh),
        in_specs=[
            pl.BlockSpec((1, Q, hb, P), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, Q, hb), lambda b, h: (b, 0, h)),
            pl.BlockSpec((1, Q, hb), lambda b, h: (b, 0, h)),
            pl.BlockSpec((1, Q, hb, N), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, Q, hb, N), lambda b, h: (b, 0, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, hb, P), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, hb, P, N), lambda b, h: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BC, Q, H, P), jnp.float32),
            jax.ShapeDtypeStruct((BC, H, P, N), jnp.float32),
        ],
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(x, dt, dA_cs, Bm, Cm)
    return out[0], out[1]
