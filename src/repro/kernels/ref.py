"""Pure-jnp oracles for every Pallas kernel (the correctness contracts).

Each function computes the same math with no tiling/blocking, in fp32.
Kernel tests sweep shapes/dtypes and ``assert_allclose`` against these.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "flash_attention_ref",
    "decode_attention_ref",
    "ssd_chunk_ref",
    "bucket_histogram_ref",
]


def flash_attention_ref(
    q: jax.Array,  # (BH, Tq, dh)
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    softcap: Optional[float] = None,
) -> jax.Array:
    BH, Tq, dh = q.shape
    Tk = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    s = jnp.einsum(
        "bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    if causal:
        mask = jnp.arange(Tq)[:, None] >= jnp.arange(Tk)[None, :]
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,  # (B, H, dh)
    k_cache: jax.Array,  # (B, S, dh)
    v_cache: jax.Array,
    lengths: jax.Array,  # (B,)
    *,
    scale: Optional[float] = None,
    softcap: Optional[float] = None,
) -> jax.Array:
    B, H, dh = q.shape
    S = k_cache.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    s = jnp.einsum(
        "bhd,bsd->bhs", q.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    mask = jnp.arange(S)[None, None, :] < lengths[:, None, None]
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bsd->bhd", p, v_cache.astype(jnp.float32)).astype(
        q.dtype
    )


def ssd_chunk_ref(x, dt, dA_cs, Bm, Cm):
    """(BC,Q,H,P),(BC,Q,H),(BC,Q,H),(BC,Q,H,N)x2 -> (y_diag, states)."""
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    da = dA_cs.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)
    Q = x.shape[1]
    decay = jnp.exp(da[:, :, None, :] - da[:, None, :, :])  # (BC,Qi,Qj,H)
    tril = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(tril[None, :, :, None], decay, 0.0)
    cb = jnp.einsum("bqhn,bjhn->bqjh", Cf, Bf)
    y = jnp.einsum("bqjh,bjh,bjhp->bqhp", cb * decay, dtf, xf)
    seg = da[:, -1]  # (BC, H)
    sdecay = jnp.exp(seg[:, None, :] - da) * dtf  # (BC, Q, H)
    S = jnp.einsum("bjh,bjhn,bjhp->bhpn", sdecay, Bf, xf)
    return y, S


def bucket_histogram_ref(
    keys: jax.Array, n_buckets: int, dtype=jnp.int32
) -> jax.Array:
    valid = keys >= 0
    clipped = jnp.where(valid, keys, 0)
    hist = jnp.zeros((n_buckets,), dtype).at[clipped].add(
        valid.astype(dtype)
    )
    return hist
