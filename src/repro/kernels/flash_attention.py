"""Flash attention forward — Pallas TPU kernel.

Grid ``(batch*heads, nq, nk)``; the kv axis is innermost (sequential) so
the online-softmax accumulators live in VMEM scratch across kv steps.
Block shapes are MXU-aligned (minor dims multiples of 128).  Causal blocks
strictly above the diagonal are skipped with ``pl.when`` — on TPU the MXU
is the bound, so gating compute is the win.

This is the TPU-native adaptation of the GPU flash algorithm: instead of
warp-level shared-memory tiling, HBM→VMEM tiling via BlockSpec with the
MXU consuming (q_blk × kv_blk) panels.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import _compiler_params

__all__ = ["flash_attention_fwd"]

DEFAULT_Q_BLOCK = 256
DEFAULT_KV_BLOCK = 512
MASK_VALUE = -1e30


def _kernel(
    q_ref, k_ref, v_ref,  # blocked inputs
    o_ref,  # blocked output
    acc_ref, m_ref, l_ref,  # VMEM scratch
    *,
    scale: float,
    causal: bool,
    softcap: Optional[float],
    q_block: int,
    kv_block: int,
    kv_len: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, MASK_VALUE)
        l_ref[...] = jnp.zeros_like(l_ref)

    block_live = jnp.logical_or(
        not causal, qi * q_block + q_block - 1 >= ki * kv_block
    )

    @pl.when(block_live)
    def _compute():
        q_pos = qi * q_block + jax.lax.broadcasted_iota(
            jnp.int32, (q_block, kv_block), 0
        )
        k_pos = ki * kv_block + jax.lax.broadcasted_iota(
            jnp.int32, (q_block, kv_block), 1
        )
        q = q_ref[0].astype(jnp.float32)  # (q_block, dh)
        k = k_ref[0].astype(jnp.float32)  # (kv_block, dh)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = k_pos < kv_len
        if causal:
            mask &= q_pos >= k_pos
        s = jnp.where(mask, s, MASK_VALUE)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=-1)
        m_ref[...] = m_new
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jax.Array,  # (BH, Tq, dh)
    k: jax.Array,  # (BH, Tk, dh)
    v: jax.Array,  # (BH, Tk, dh)
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    softcap: Optional[float] = None,
    q_block: int = DEFAULT_Q_BLOCK,
    kv_block: int = DEFAULT_KV_BLOCK,
    interpret: bool = False,
) -> jax.Array:
    """Heads-flattened flash attention forward pass (GQA: expand upstream)."""
    BH, Tq, dh = q.shape
    _, Tk, _ = k.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    q_block = min(q_block, Tq)
    kv_block = min(kv_block, Tk)
    nq = -(-Tq // q_block)
    nk = -(-Tk // kv_block)
    pq = nq * q_block - Tq
    pk = nk * kv_block - Tk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))

    kernel = functools.partial(
        _kernel,
        scale=scale,
        causal=causal,
        softcap=softcap,
        q_block=q_block,
        kv_block=kv_block,
        kv_len=Tk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, q_block, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, kv_block, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, kv_block, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, nq * q_block, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block, dh), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
        ],
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
    return out[:, :Tq]
