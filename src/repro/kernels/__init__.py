"""Pallas TPU kernels for the perf-critical compute layers, each with a
pure-jnp oracle in ``ref.py`` and jitted wrappers in ``ops.py``:

  * flash_attention — train/prefill attention (MXU-tiled online softmax)
  * decode_attention — single-token KV-cache attention (flash-decode)
  * ssd_scan — Mamba-2 SSD within-chunk quadratic + chunk states
  * bucket_histogram — MapReduce shuffle partition counting (one-hot MXU)

Validated with ``interpret=True`` on CPU; TPU is the compile target.
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
