"""Version compatibility shims for the Pallas TPU API.

The pallas-tpu compiler-params class was renamed across JAX releases
(``TPUCompilerParams`` → ``CompilerParams``); resolve whichever this
install provides so the kernels run on any toolchain the container bakes.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_CompilerParamsCls = getattr(
    pltpu, "CompilerParams", None
) or getattr(pltpu, "TPUCompilerParams")


def _compiler_params(**kwargs):
    return _CompilerParamsCls(**kwargs)
