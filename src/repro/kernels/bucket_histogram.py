"""Shuffle-partition histogram — Pallas TPU kernel.

The MapReduce shuffle planner (core/device_shuffle.py) needs per-bucket
counts of a key block to size capacity buffers — the partition step of the
paper's hot phase.  TPUs have no scatter-add in VMEM; the idiomatic
adaptation is a one-hot matmul: a (block, buckets) one-hot panel reduced
over the block axis on the MXU, accumulated across grid steps in the
(revisited) output block.

Grid: (n_blocks,) sequential; out BlockSpec pins the same (1, n_buckets)
block every step so it acts as an accumulator.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import _compiler_params

__all__ = ["bucket_histogram"]

DEFAULT_BLOCK = 2048


def _kernel(keys_ref, out_ref, *, n_buckets: int, block: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    keys = keys_ref[...]  # (block,)
    valid = keys >= 0
    # one-hot (block, n_buckets) panel; invalid rows are all-zero
    cols = jax.lax.broadcasted_iota(jnp.int32, (block, n_buckets), 1)
    onehot = jnp.where(
        valid[:, None] & (keys[:, None] == cols), 1.0, 0.0
    ).astype(jnp.float32)
    out_ref[...] += jnp.sum(onehot, axis=0, keepdims=True).astype(
        out_ref.dtype
    )


def bucket_histogram(
    keys: jax.Array,  # (N,) int32; negative = padding
    n_buckets: int,
    *,
    block: int = DEFAULT_BLOCK,
    interpret: bool = False,
) -> jax.Array:
    """Counts per bucket, f32 (N up to millions; buckets lane-aligned)."""
    (N,) = keys.shape
    block = min(block, N)
    nb = -(-N // block)
    pad = nb * block - N
    if pad:
        keys = jnp.pad(keys, (0, pad), constant_values=-1)
    kernel = functools.partial(_kernel, n_buckets=n_buckets, block=block)
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1, n_buckets), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n_buckets), jnp.float32),
        compiler_params=_compiler_params(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(keys)
    return out[0]
