"""Shuffle-partition histogram — Pallas TPU kernel.

The MapReduce shuffle planner (core/device_shuffle.py) needs per-bucket
counts of a key block to size capacity buffers — the partition step of the
paper's hot phase.  TPUs have no scatter-add in VMEM; the idiomatic
adaptation is a one-hot matmul: a (block, buckets) one-hot panel reduced
over the block axis on the MXU, accumulated across grid steps in the
(revisited) output block.

Counts accumulate in an **integer** output block by default: the one-hot
panel stays f32 (MXU-friendly) and its per-block sum is exact (a block
sums to at most ``block`` ≤ 2^24), but the cross-block accumulator must
not be f32 — above 2^24 pairs per bucket an f32 accumulator silently
stops incrementing.  Weighted reductions that want f32 semantics pass
``out_dtype=jnp.float32`` explicitly.

Grid: (n_blocks,) sequential; out BlockSpec pins the same (1, n_buckets)
block every step so it acts as an accumulator.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import _compiler_params

__all__ = ["bucket_histogram"]

DEFAULT_BLOCK = 2048


def _kernel(keys_ref, out_ref, *, n_buckets: int, block: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    keys = keys_ref[...]  # (block,)
    valid = keys >= 0
    # one-hot (block, n_buckets) panel; invalid rows are all-zero
    cols = jax.lax.broadcasted_iota(jnp.int32, (block, n_buckets), 1)
    onehot = jnp.where(
        valid[:, None] & (keys[:, None] == cols), 1.0, 0.0
    ).astype(jnp.float32)
    # The per-block f32 sum is exact (≤ block per bucket); the cast keeps
    # the cross-block accumulation in the output dtype (int32 by default).
    out_ref[...] += jnp.sum(onehot, axis=0, keepdims=True).astype(
        out_ref.dtype
    )


def bucket_histogram(
    keys: jax.Array,  # (N,) int32; negative = padding
    n_buckets: int,
    *,
    block: int = DEFAULT_BLOCK,
    interpret: bool = False,
    out_dtype=jnp.int32,
) -> jax.Array:
    """Counts per bucket (N up to millions; buckets lane-aligned).

    Empty input is a zero histogram, not a degenerate grid: ``N == 0``
    previously collapsed ``block`` to zero and divided by it.
    """
    (N,) = keys.shape
    if N == 0:
        return jnp.zeros((n_buckets,), out_dtype)
    block = min(block, N)
    nb = -(-N // block)
    pad = nb * block - N
    if pad:
        keys = jnp.pad(keys, (0, pad), constant_values=-1)
    kernel = functools.partial(_kernel, n_buckets=n_buckets, block=block)
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1, n_buckets), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n_buckets), out_dtype),
        compiler_params=_compiler_params(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(keys)
    return out[0]
