"""Concrete sharding rules: inputs, caches, and spec resolution.

Everything here maps *logical* layout decisions (DESIGN.md §4) onto a
concrete mesh: batch over the data axes (``('pod','data')`` multi-pod),
heads/ffn/experts over ``model``, FSDP over ``data``.  Dims that don't
divide the axis size fall back to replication (e.g. global_batch=1 in
long_500k).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.attention import AttnCache
from repro.models.config import BlockSpec, ModelConfig, ShapeConfig
from repro.models.mla import MLACache
from repro.models.quant_cache import QuantAttnCache
from repro.models.rglru import RGLRUCache
from repro.models.ssm import SSMCache

__all__ = [
    "mesh_axes",
    "batch_entry",
    "input_specs",
    "input_shardings",
    "cache_pspecs",
    "named",
]


def mesh_axes(mesh: Mesh) -> Tuple[Tuple[str, ...], Optional[str], Optional[str]]:
    """(dp_axes, fsdp_axis, tp_axis) present in this mesh."""
    names = mesh.axis_names
    dp = tuple(a for a in ("pod", "data") if a in names)
    fsdp = "data" if "data" in names else None
    tp = "model" if "model" in names else None
    return dp, fsdp, tp


def _axes_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def batch_entry(mesh: Mesh, batch: int):
    """Spec entry for a batch dim: data axes if divisible, else replicate."""
    dp, _, _ = mesh_axes(mesh)
    if dp and batch % _axes_size(mesh, dp) == 0:
        return dp if len(dp) > 1 else dp[0]
    return None


def _tp_entry(mesh: Mesh, dim: int):
    _, _, tp = mesh_axes(mesh)
    if tp and dim % mesh.shape[tp] == 0:
        return tp
    return None


# -- model inputs ---------------------------------------------------------

def input_specs(
    cfg: ModelConfig, shape: ShapeConfig
) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, T = shape.global_batch, shape.seq_len
    kind = shape.kind
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if kind == "decode":
        out["tokens"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        return out
    if cfg.frontend == "tokens":
        out["tokens"] = jax.ShapeDtypeStruct((B, T), jnp.int32)
    elif cfg.frontend == "frames":
        out["frames"] = jax.ShapeDtypeStruct((B, T, cfg.frame_dim), jnp.bfloat16)
    else:  # tokens+patches
        out["tokens"] = jax.ShapeDtypeStruct((B, T - cfg.n_patches), jnp.int32)
        out["patches"] = jax.ShapeDtypeStruct(
            (B, cfg.n_patches, cfg.d_model), jnp.bfloat16
        )
    if kind == "train":
        out["labels"] = jax.ShapeDtypeStruct((B, T), jnp.int32)
    return out


def input_shardings(
    cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh
) -> Dict[str, P]:
    b = batch_entry(mesh, shape.global_batch)
    specs = {}
    for name, sds in input_specs(cfg, shape).items():
        specs[name] = P(b, *([None] * (len(sds.shape) - 1)))
    return specs


# -- decode caches ---------------------------------------------------------

def _mixer_cache_pspec(blk: BlockSpec, cfg: ModelConfig, b, mesh: Mesh,
                       seq_len: int, quant_attn: bool = False):
    if blk.mixer in ("attn", "local"):
        # KV caches shard the *sequence* dim over TP (flash-decode style):
        # partial softmax stats are the only cross-shard traffic, so decode
        # attention scales over the whole pod even at Kv=1.
        S = min(seq_len, blk.window) if blk.window else seq_len
        s_e = _tp_entry(mesh, S)
        spec = P(b, s_e, None, None)
        if quant_attn:
            return QuantAttnCache(k_q=spec, v_q=spec,
                                  k_s=P(b, s_e, None), v_s=P(b, s_e, None))
        return AttnCache(k=spec, v=spec)
    if blk.mixer == "mla":
        s_e = _tp_entry(mesh, seq_len)
        return MLACache(c_kv=P(b, s_e, None), k_pe=P(b, s_e, None))
    if blk.mixer == "ssm":
        s = cfg.ssm
        convdim = s.d_inner(cfg.d_model) + 2 * s.n_groups * s.d_state
        return SSMCache(
            conv=P(b, None, _tp_entry(mesh, convdim)),
            state=P(b, _tp_entry(mesh, s.n_heads(cfg.d_model)), None, None),
        )
    if blk.mixer == "rglru":
        W = cfg.rglru.lru_width or cfg.d_model
        return RGLRUCache(
            conv=P(b, None, _tp_entry(mesh, W)), h=P(b, _tp_entry(mesh, W))
        )
    raise ValueError(blk.mixer)


def cache_pspecs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                 quant_attn: bool = False):
    """PartitionSpec pytree matching ``init_cache`` structure."""
    b = batch_entry(mesh, shape.global_batch)
    S = shape.seq_len
    stack = lambda tree: jax.tree_util.tree_map(
        lambda s: P(None, *s), tree,
        is_leaf=lambda x: isinstance(x, P),
    )
    mk = lambda blk: _mixer_cache_pspec(blk, cfg, b, mesh, S, quant_attn)
    return {
        "prelude": [mk(blk) for blk in cfg.prelude],
        "body": [stack(mk(blk)) for blk in cfg.pattern],
        "postlude": [mk(blk) for blk in cfg.postlude],
    }


def named(mesh: Mesh, spec_tree: Any) -> Any:
    """Wrap a PartitionSpec pytree into NamedShardings."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
