"""Paper-class dataflow workloads: PageRank, k-means, TeraSort.

Library front-ends over :mod:`repro.core.dataflow` — each is the shape of
workload the paper's statefulness argument targets but its measured jobs
(wordcount, grep: single-pass, 2-stage) never exercise:

  * :func:`pagerank_loop` — sparse adjacency partitions as static input,
    the rank vector as loop-carried state: every superstep re-reads the
    previous ranks, so keeping them pinned in the fast tier vs reloading
    from the modeled S3 home is the whole game
    (``benchmarks/paper_fig9_iterative.py`` measures exactly that gap);
  * :func:`kmeans_loop` — centroids as loop state, optionally resident in
    a **gateway session** (a :class:`~repro.core.stateful.
    StatefulFunction` slot): warm invokers then read centroids from the
    hot view and skip the tier reload entirely;
  * :func:`terasort` — sample → range-partition → per-partition sort, a
    3-stage non-iterative DAG the MapReduce front-end cannot express.

Everything is deterministic byte-for-byte given the same inputs: float
reductions run in fixed (partition-index) order, so the stateful/pinned
and cold-reload configurations — and a journal-resumed re-run — produce
identical output bytes.  Tests and the fig9 smoke gate assert this.
"""

from __future__ import annotations

import struct
import threading
from dataclasses import dataclass
from typing import TYPE_CHECKING, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.dataflow import (
    LoopContext,
    LoopReport,
    Stage,
    StageRunReport,
    StageTask,
    _run_loop_impl,
    _run_stages_impl,
    current_device_exec,
)

if TYPE_CHECKING:  # annotation only
    from repro.core.device_shuffle import DeviceExec
from repro.core.scheduler import Scheduler
from repro.storage import serde
from repro.storage.tiers import Tier

if TYPE_CHECKING:  # annotation only
    from repro.core.gateway import Gateway
    from repro.storage.kvcache import StateCache

__all__ = [
    "PageRankResult",
    "KMeansResult",
    "pagerank_graph",
    "pagerank_loop",
    "kmeans_points",
    "kmeans_loop",
    "terasort",
    "terasort_output",
]


# -- small codecs (fixed dtypes, deterministic bytes) -------------------------

def _pack_edges(src: np.ndarray, dst: np.ndarray) -> bytes:
    return (
        struct.pack("<Q", len(src))
        + src.astype("<i8").tobytes()
        + dst.astype("<i8").tobytes()
    )


def _unpack_edges(blob: bytes) -> Tuple[np.ndarray, np.ndarray]:
    (n,) = struct.unpack_from("<Q", blob, 0)
    body = np.frombuffer(blob, dtype="<i8", offset=8)
    return body[:n], body[n:]


def _f64(blob: bytes) -> np.ndarray:
    return np.frombuffer(blob, dtype="<f8")


# -- PageRank -----------------------------------------------------------------

def _part_bounds(n: int, parts: int) -> List[int]:
    return [i * n // parts for i in range(parts + 1)]


def pagerank_graph(
    n_nodes: int, n_edges: int, seed: int = 0
) -> Tuple[np.ndarray, np.ndarray]:
    """A deterministic random directed graph (self-loops removed)."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_nodes, size=n_edges, dtype=np.int64)
    dst = rng.integers(0, n_nodes, size=n_edges, dtype=np.int64)
    keep = src != dst
    return src[keep], dst[keep]


@dataclass
class PageRankResult:
    report: LoopReport
    #: final rank vector (float64, sums to ~1 minus the dangling leak).
    ranks: np.ndarray
    #: canonical concatenated rank bytes — the byte-identity handle.
    rank_bytes: bytes


def pagerank_loop(
    name: str,
    state: Tier,
    src: np.ndarray,
    dst: np.ndarray,
    n_nodes: int,
    n_parts: int = 4,
    damping: float = 0.85,
    tol: float = 1e-6,
    max_iterations: int = 20,
    scheduler: Optional[Scheduler] = None,
    journal: Optional["StateCache"] = None,
    gateway: Optional["Gateway"] = None,
    pin_state: bool = True,
    halt_after: Optional[int] = None,
) -> PageRankResult:
    """Power-iteration PageRank as an iterative 2-stage dataflow.

    Superstep *k*: stage ``contrib`` (one task per adjacency partition —
    read rank part *k-1*, scatter weighted contributions per destination
    partition) then stage ``apply`` (one task per rank partition — sum
    contributions in partition order, apply damping, report the L1
    residual).  Converged when the summed residual drops under ``tol``.
    """
    bounds = _part_bounds(n_nodes, n_parts)
    order = np.lexsort((dst, src))  # canonical edge order per partition
    src, dst = src[order], dst[order]

    ctx_probe = LoopContext(name, state)  # key naming only
    for i in range(n_parts):
        key = ctx_probe.input_key(f"adj/p{i:03d}")
        if not state.contains(key):
            m = (src >= bounds[i]) & (src < bounds[i + 1])
            state.put(key, _pack_edges(src[m], dst[m]))

    def init(ctx: LoopContext) -> None:
        for j in range(n_parts):
            size = bounds[j + 1] - bounds[j]
            ctx.write(
                f"rank/p{j:03d}",
                np.full(size, 1.0 / n_nodes, dtype="<f8").tobytes(),
            )

    def make_contrib(i: int):
        def run(_tc) -> dict:
            ctx = current_ctx[0]
            s, d = _unpack_edges(ctx.state.get(ctx.input_key(f"adj/p{i:03d}")))
            ranks = _f64(ctx.read(f"rank/p{i:03d}"))
            local = s - bounds[i]
            deg = np.bincount(local, minlength=bounds[i + 1] - bounds[i])
            w = ranks[local] / deg[local]
            blobs = {}
            for j in range(n_parts):
                m = (d >= bounds[j]) & (d < bounds[j + 1])
                contrib = np.bincount(
                    d[m] - bounds[j], weights=w[m],
                    minlength=bounds[j + 1] - bounds[j],
                )
                blobs[f"contrib/p{i:03d}to{j:03d}"] = (
                    contrib.astype("<f8").tobytes()
                )
            ctx.write_many(blobs)
            return {"edges": int(len(s))}

        return run

    def make_apply(j: int):
        def run(_tc) -> dict:
            ctx = current_ctx[0]
            size = bounds[j + 1] - bounds[j]
            total = np.zeros(size, dtype="<f8")
            for i in range(n_parts):  # fixed order: deterministic float sum
                total += _f64(ctx.read_current(f"contrib/p{i:03d}to{j:03d}"))
            new = (1.0 - damping) / n_nodes + damping * total
            prev = _f64(ctx.read(f"rank/p{j:03d}"))
            ctx.write(f"rank/p{j:03d}", new.tobytes())
            return {"residual": float(np.abs(new - prev).sum())}

        return run

    # Tasks close over the live LoopContext via one mutable cell (the
    # stage builders are instantiated fresh each superstep, but the run
    # callables want the *current* iteration's ctx).
    current_ctx: List[LoopContext] = [ctx_probe]

    def superstep(ctx: LoopContext) -> Sequence[Stage]:
        current_ctx[0] = ctx
        return [
            Stage("contrib", [
                StageTask(f"contrib_{i:03d}", make_contrib(i))
                for i in range(n_parts)
            ]),
            Stage("apply", [
                StageTask(f"apply_{j:03d}", make_apply(j))
                for j in range(n_parts)
            ]),
        ]

    def converged(ctx: LoopContext) -> bool:
        residual = sum(
            ctx.result(f"apply_{j:03d}").value["residual"]
            for j in range(n_parts)
        )
        return residual < tol

    report = _run_loop_impl(
        name, init, superstep, converged, state,
        scheduler=scheduler, journal=journal, gateway=gateway,
        max_iterations=max_iterations, pin_state=pin_state,
        halt_after=halt_after,
    )
    ctx_probe.iteration = max(0, report.last_iteration)
    parts = [
        _f64(ctx_probe.read_current(f"rank/p{j:03d}"))
        for j in range(n_parts)
    ]
    ranks = np.concatenate(parts) if parts else np.zeros(0)
    return PageRankResult(report, ranks, ranks.astype("<f8").tobytes())


# -- k-means ------------------------------------------------------------------

def kmeans_points(
    n_points: int, dim: int, k: int, seed: int = 0, spread: float = 0.15
) -> Tuple[np.ndarray, np.ndarray]:
    """Deterministic well-separated blobs: (points, true_centers)."""
    rng = np.random.default_rng(seed)
    centers = rng.uniform(-1.0, 1.0, size=(k, dim))
    labels = rng.integers(0, k, size=n_points)
    pts = centers[labels] + rng.normal(0.0, spread, size=(n_points, dim))
    return pts.astype("<f8"), centers.astype("<f8")


@dataclass
class KMeansResult:
    report: LoopReport
    centroids: np.ndarray
    centroid_bytes: bytes
    #: fraction of assign-stage centroid reads served from the hot
    #: gateway-session view (0.0 when no gateway was used).
    warm_read_frac: float


def _kmeans_fn_name(name: str) -> str:
    return f"kmeans/{name}"


def _register_kmeans_fn(runtime, fn_name: str) -> None:
    """Centroid-holder stateful function: state = {"it", "centroids"}.

    ``jit=False``: the step is host-side numpy (partition sums arrive as
    plain arrays), matching the MapReduce-task style of function."""
    if fn_name in runtime.functions:
        return

    def fn_init(centroids: bytes, k: int, dim: int, it: int) -> dict:
        return {
            "it": int(it),
            "centroids": np.frombuffer(centroids, dtype="<f8")
            .reshape(k, dim).copy(),
        }

    def fn_step(st: dict, sums, counts):
        old = st["centroids"]
        counts = np.asarray(counts, dtype="<f8").reshape(-1, 1)
        new = np.where(
            counts > 0, np.asarray(sums) / np.maximum(counts, 1.0), old
        )
        shift = float(np.abs(new - old).max())
        state = {"it": int(st["it"]) + 1, "centroids": new}
        return state, (new.astype("<f8").tobytes(), shift)

    from repro.core.stateful import StatefulFunction

    runtime.register(StatefulFunction(fn_name, fn_step, fn_init, jit=False))


def kmeans_loop(
    name: str,
    state: Tier,
    points: np.ndarray,
    k: int,
    n_parts: int = 4,
    tol: float = 1e-6,
    max_iterations: int = 30,
    scheduler: Optional[Scheduler] = None,
    journal: Optional["StateCache"] = None,
    gateway: Optional["Gateway"] = None,
    pin_state: bool = True,
    halt_after: Optional[int] = None,
) -> KMeansResult:
    """Lloyd's k-means as an iterative assign/update dataflow.

    With ``gateway``, the centroid state additionally lives in a gateway
    **session** (:class:`~repro.core.stateful.StatefulFunction` slot
    pinned in the warm pool): assign tasks read centroids from the hot
    view when its iteration tag matches — warm invokers skip the tier
    reload — and fall back to the versioned tier state otherwise (fresh
    start, crash resume).  Output bytes are identical either way.
    """
    n_points, dim = points.shape
    pbounds = _part_bounds(n_points, n_parts)
    ctx_probe = LoopContext(name, state)
    for i in range(n_parts):
        key = ctx_probe.input_key(f"points/p{i:03d}")
        if not state.contains(key):
            state.put(key, points[pbounds[i]:pbounds[i + 1]].tobytes())

    fn_name = _kmeans_fn_name(name)
    session_id = f"df::{name}"
    runtime = gateway.runtime if gateway is not None else None
    if gateway is not None:
        _register_kmeans_fn(runtime, fn_name)
        gateway.pin_warm(fn_name, session=session_id)
    warm_reads = [0, 0]  # [warm, total] across assign tasks
    warm_lock = threading.Lock()  # assign tasks run on parallel workers

    def init(ctx: LoopContext) -> None:
        # Deterministic seeding: the k lexicographically-first points.
        seed_idx = np.argsort(
            [points[i].tobytes() for i in range(n_points)]
        )[:k]
        ctx.write("centroids", points[np.sort(seed_idx)].tobytes())

    def read_centroids(ctx: LoopContext) -> np.ndarray:
        if runtime is not None:
            blob = runtime.state_bytes(fn_name, session=session_id)
            if blob is not None:
                st = serde.loads(blob)
                if int(st["it"]) == ctx.iteration - 1:
                    with warm_lock:
                        warm_reads[0] += 1
                        warm_reads[1] += 1
                    return np.asarray(st["centroids"], dtype="<f8")
        with warm_lock:
            warm_reads[1] += 1
        return _f64(ctx.read("centroids")).reshape(k, dim)

    def make_assign(i: int):
        def run(_tc) -> dict:
            ctx = current_ctx[0]
            cent = read_centroids(ctx)
            pts = _f64(
                ctx.state.get(ctx.input_key(f"points/p{i:03d}"))
            ).reshape(-1, dim)
            d2 = ((pts[:, None, :] - cent[None, :, :]) ** 2).sum(axis=2)
            assign = np.argmin(d2, axis=1)
            sums = np.zeros((k, dim), dtype="<f8")
            np.add.at(sums, assign, pts)
            counts = np.bincount(assign, minlength=k).astype("<i8")
            ctx.write(
                f"partial/p{i:03d}", sums.tobytes() + counts.tobytes()
            )
            return {"points": int(len(pts))}

        return run

    def update_run(_tc) -> dict:
        ctx = current_ctx[0]
        sums = np.zeros((k, dim), dtype="<f8")
        counts = np.zeros(k, dtype="<i8")
        for i in range(n_parts):  # fixed order: deterministic float sum
            blob = ctx.read_current(f"partial/p{i:03d}")
            sums += np.frombuffer(blob, dtype="<f8", count=k * dim) \
                .reshape(k, dim)
            counts += np.frombuffer(blob, dtype="<i8", offset=8 * k * dim)
        if runtime is not None:
            sess = gateway.session(session_id)
            blob = runtime.state_bytes(fn_name, session=session_id)
            stale = (
                blob is None
                or int(serde.loads(blob)["it"]) != ctx.iteration - 1
            )
            if stale:
                # Fresh start or journal resume: re-seed the session from
                # the authoritative versioned tier state.
                runtime.reset_state(fn_name, session=session_id)
                prev = ctx.read("centroids")
                new_bytes, shift = sess.invoke(
                    fn_name,
                    init_kwargs={
                        "centroids": prev, "k": k, "dim": dim,
                        "it": ctx.iteration - 1,
                    },
                    sums=sums, counts=counts,
                )
            else:
                new_bytes, shift = sess.invoke(fn_name, sums=sums,
                                               counts=counts)
        else:
            old = _f64(ctx.read("centroids")).reshape(k, dim)
            c = counts.astype("<f8").reshape(-1, 1)
            new = np.where(c > 0, sums / np.maximum(c, 1.0), old)
            new_bytes = new.astype("<f8").tobytes()
            shift = float(np.abs(new - old).max())
        ctx.write("centroids", new_bytes)
        return {"shift": float(shift)}

    current_ctx: List[LoopContext] = [ctx_probe]

    def superstep(ctx: LoopContext) -> Sequence[Stage]:
        current_ctx[0] = ctx
        return [
            Stage("assign", [
                StageTask(f"assign_{i:03d}", make_assign(i))
                for i in range(n_parts)
            ]),
            Stage("update", [StageTask("update", update_run)]),
        ]

    def converged(ctx: LoopContext) -> bool:
        return ctx.result("update").value["shift"] < tol

    try:
        report = _run_loop_impl(
            name, init, superstep, converged, state,
            scheduler=scheduler, journal=journal, gateway=gateway,
            max_iterations=max_iterations, pin_state=pin_state,
            halt_after=halt_after,
        )
    finally:
        if gateway is not None:
            gateway.unpin_warm(fn_name, session=session_id)
    ctx_probe.iteration = max(0, report.last_iteration)
    blob = ctx_probe.read_current("centroids")
    frac = warm_reads[0] / warm_reads[1] if warm_reads[1] else 0.0
    return KMeansResult(
        report, _f64(blob).reshape(k, dim), blob, frac
    )


# -- TeraSort -----------------------------------------------------------------

def _records(blob: bytes) -> List[bytes]:
    return [r for r in blob.split(b"\n") if r]


def terasort(
    name: str,
    state: Tier,
    input_parts: Sequence[bytes],
    n_ranges: int = 4,
    sample_every: int = 8,
    scheduler: Optional[Scheduler] = None,
    journal: Optional["StateCache"] = None,
    gateway: Optional["Gateway"] = None,
    device: Optional["DeviceExec"] = None,
) -> StageRunReport:
    """Sample → range-partition → per-partition sort over newline-separated
    byte records — the canonical 3-stage DAG (one ``bounds`` task inside
    the partition stage feeds the scatter tasks via an intra-stage dep).
    Output ranges land at ``df/<name>/out/rNNN``; concatenated in range
    order they are the globally sorted record stream
    (:func:`terasort_output`).

    With ``device``, the scatter tasks lower their range-bucketing onto
    the Pallas histogram kernel (exact-capacity buffers — records are
    opaque bytes, so nothing spills); output bytes are identical to the
    host path because the device pack preserves per-bucket record order.
    """
    prefix = f"df/{name}"
    n_inputs = len(input_parts)
    for i, blob in enumerate(input_parts):
        key = f"{prefix}/input/p{i:03d}"
        if not state.contains(key):
            state.put(key, blob)

    def make_sample(i: int):
        key_in = f"{prefix}/input/p{i:03d}"
        key_out = f"{prefix}/tmp/sample/p{i:03d}"

        def run(_tc) -> dict:
            recs = _records(state.get(key_in))
            sample = recs[::sample_every]
            state.put(key_out, b"\n".join(sample))
            return {"sampled": len(sample)}

        return run, [key_out]

    bounds_key = f"{prefix}/tmp/bounds"

    def bounds_run(_tc) -> dict:
        sample: List[bytes] = []
        for i in range(n_inputs):
            sample.extend(_records(state.get(f"{prefix}/tmp/sample/p{i:03d}")))
        sample.sort()
        cuts = [
            sample[(j + 1) * len(sample) // n_ranges - 1]
            for j in range(n_ranges - 1)
        ] if sample else []
        state.put(bounds_key, b"\n".join(cuts))
        return {"cuts": len(cuts)}

    def make_scatter(i: int):
        key_in = f"{prefix}/input/p{i:03d}"
        outs = [
            f"{prefix}/tmp/scatter/p{i:03d}_r{j:03d}" for j in range(n_ranges)
        ]

        def run(_tc) -> dict:
            cuts = _records(state.get(bounds_key))
            recs = _records(state.get(key_in))
            buckets: List[List[bytes]] = [[] for _ in range(n_ranges)]
            dev = current_device_exec()
            if dev is not None and recs:
                from bisect import bisect_left

                from repro.core.device_shuffle import device_partition

                # bisect_left(cuts, rec) == the scan loop below: the
                # count of cuts strictly below the record.
                dest = [bisect_left(cuts, rec) for rec in recs]
                idx_parts, _ = device_partition(
                    dest, n_ranges, interpret=dev.interpret
                )
                for j, idxs in enumerate(idx_parts):
                    buckets[j] = [recs[i] for i in idxs]
                dev.account(partitioned_pairs=len(recs))
            else:
                for rec in recs:
                    j = 0
                    while j < len(cuts) and rec > cuts[j]:
                        j += 1
                    buckets[j].append(rec)
            state.put_many({
                outs[j]: b"\n".join(buckets[j]) for j in range(n_ranges)
            })
            return {"records": sum(len(b) for b in buckets)}

        return run, outs

    def make_sort(j: int):
        key_out = f"{prefix}/out/r{j:03d}"

        def run(_tc) -> dict:
            recs: List[bytes] = []
            for i in range(n_inputs):  # fixed gather order
                recs.extend(_records(
                    state.get(f"{prefix}/tmp/scatter/p{i:03d}_r{j:03d}")
                ))
            recs.sort()
            state.put(key_out, b"\n".join(recs))
            return {"records": len(recs)}

        return run, [key_out]

    sample_tasks, partition_tasks, sort_tasks = [], [], []
    for i in range(n_inputs):
        run, outs = make_sample(i)
        sample_tasks.append(
            StageTask(f"sample_{i:03d}", run, outputs=outs)
        )
    partition_tasks.append(
        StageTask("bounds", bounds_run, outputs=[bounds_key])
    )
    for i in range(n_inputs):
        run, outs = make_scatter(i)
        partition_tasks.append(StageTask(
            f"scatter_{i:03d}", run, deps=["task:bounds"], outputs=outs,
            device=True,
        ))
    for j in range(n_ranges):
        run, outs = make_sort(j)
        sort_tasks.append(StageTask(f"sort_{j:03d}", run, outputs=outs))

    return _run_stages_impl(
        name,
        [
            Stage("sample", sample_tasks),
            Stage("partition", partition_tasks),
            Stage("sort", sort_tasks),
        ],
        state,
        scheduler=scheduler, journal=journal, gateway=gateway,
        device=device,
    )


def terasort_output(state: Tier, name: str, n_ranges: int) -> List[bytes]:
    """The globally sorted record stream (ranges concatenated in order)."""
    out: List[bytes] = []
    for j in range(n_ranges):
        out.extend(_records(state.get(f"df/{name}/out/r{j:03d}")))
    return out
