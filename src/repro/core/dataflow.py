"""Iterative multi-stage dataflow engine — N-stage jobs and fixed-point
loops lowered onto the stage-DAG machinery.

The MapReduce front-end (``core/mapreduce.py``) expresses exactly one job
shape: two stages with a shuffle between them.  The paper's statefulness
argument, though, pays off hardest on *iterative* analytics — PageRank,
k-means, any fixed-point computation — where the same loop-carried state
is touched every superstep and a stock-serverless runtime reloads it from
S3 each time (Cloudburst and Faasm both motivate shared in-memory state
with precisely these workloads; see PAPERS.md).  This module generalizes
the execution layer:

  * **declarative stages** — a job is an ordered list of :class:`Stage`\\ s
    of :class:`StageTask`\\ s; :func:`lower_stages` wires consecutive
    stages with barrier tokens (or per-stage overrides / streaming
    consumers) and emits one validated
    :class:`~repro.core.dag.StageDag`.  MapReduce now lowers through the
    same helper — it is just a 2-stage dataflow.
  * **one-shot N-stage jobs** — :func:`run_stages` executes a stage list
    with task-granular journaled resume (a re-run skips tasks whose
    commit marker and declared ``outputs`` both survive).  TeraSort's
    sample → range-partition → per-partition-sort pipeline, inexpressible
    in the MapReduce front-end, is three such stages.
  * **fixed-point loops** — :func:`run_loop` drives supersteps: each
    iteration instantiates a fresh per-iteration stage set (task ids
    namespaced ``df/<job>/itNNNNN/...``), runs it on a pooled scheduler
    (warm threads across supersteps), evaluates a convergence predicate
    *between* supersteps, and commits a per-iteration marker to the
    :class:`~repro.core.journal.StateJournal` so a crash mid-iteration
    resumes at the last completed superstep **byte-identically**.

Loop state protocol (DESIGN.md §8):

  * loop-carried state lives in a caller-supplied tier under versioned
    keys ``df/<job>/state/itNNNNN/<name>`` (:class:`LoopContext` owns the
    naming); superstep *k* reads version *k-1* and writes version *k*;
  * on a :class:`~repro.storage.hierarchy.TieredStore` the whole job
    prefix is **pinned** in the fast level for the life of the loop
    (``pin``/``unpin`` placement hook) — state stays hot instead of
    round-tripping through the modeled S3 home between supersteps;
  * the iteration marker commits strictly *after* the superstep's state
    blobs (they land during the DAG run), so a torn run leaves blobs
    without a marker — the resume path re-runs that superstep from the
    previous version and, tasks being deterministic, reproduces the same
    bytes — but never a marker whose state is missing;
  * after marker *k* commits, version *k-1* retires (blobs deleted,
    marker retracted): the journal and the pinned working set stay O(1)
    in the iteration count.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field, replace
from typing import (
    TYPE_CHECKING,
    Any,
    Callable,
    Dict,
    List,
    Mapping,
    Optional,
    Sequence,
    Tuple,
)

from repro.core.dag import StageDag, TaskContext, TaskSpec, task_token
from repro.core.journal import StateJournal
from repro.core.scheduler import Scheduler, TaskResult
from repro.storage.tiers import Tier

if TYPE_CHECKING:  # annotation only — keeps the import graph acyclic
    from repro.core.device_shuffle import DeviceExec
    from repro.core.gateway import Gateway
    from repro.storage.kvcache import StateCache

__all__ = [
    "Stage",
    "StageTask",
    "LoopContext",
    "LoopReport",
    "StageRunReport",
    "current_device_exec",
    "lower_stages",
    "run_stages",
    "run_loop",
    "stage_task_id",
]


# Device-execution context for the *current* task, set around opted-in
# task bodies (tasks run on scheduler worker threads, so this must be
# thread-local, not a module global).
_DEVICE_TLS = threading.local()


def current_device_exec() -> Optional["DeviceExec"]:
    """The :class:`~repro.core.device_shuffle.DeviceExec` of the running
    stage task, or ``None`` when the job runs host-side.  Task bodies
    that have a device lowering (e.g. TeraSort's scatter) consult this
    instead of taking a parameter — the engine owns the mode."""
    return getattr(_DEVICE_TLS, "exec", None)


def _with_device(
    run: Callable[[TaskContext], Any], device: "DeviceExec"
) -> Callable[[TaskContext], Any]:
    def wrapped(ctx: TaskContext) -> Any:
        _DEVICE_TLS.exec = device
        try:
            return run(ctx)
        finally:
            _DEVICE_TLS.exec = None

    return wrapped


# -- declarative stages -------------------------------------------------------

@dataclass
class StageTask:
    """One task of a dataflow stage.

    ``tid`` is relative to the lowering namespace (``lower_stages``
    prefixes it); ``deps`` entries of the form ``task:<tid>`` are
    namespaced the same way, so intra-graph task dependencies can be
    declared without knowing the final namespace.  Data-key deps (tier
    keys) pass through untouched.
    """

    tid: str
    run: Optional[Callable[[TaskContext], Any]] = None
    preferred: Sequence[str] = ()
    #: streaming consumer — overlap slot + event queue, no stage barrier.
    streaming: bool = False
    listens: Optional[Callable[[str], bool]] = None
    #: extra dependency tokens beyond the stage barrier.
    deps: Sequence[str] = ()
    #: extra tokens published on completion.
    produces: Sequence[str] = ()
    #: tier keys this task writes — ``run_stages`` checks these on resume
    #: (committed marker + missing output = the tier lost it: re-run).
    outputs: Sequence[str] = ()
    on_complete: Optional[Callable[[TaskResult], None]] = None
    speculatable: bool = True
    #: already committed by a prior run: its token (plus produces/outputs)
    #: primes the DAG instead of scheduling work.
    resumed: bool = False
    #: opt in to device execution: when the run gets a ``device=``
    #: context, this task's body sees it via :func:`current_device_exec`.
    device: bool = False


@dataclass
class Stage:
    """An ordered group of tasks.

    ``after`` names the stages whose *every* task must complete before
    this stage's (non-streaming) tasks dispatch.  ``None`` (default)
    means the previous stage in the list; ``()`` means no barrier —
    streaming stages and independent side-stages want that.
    """

    name: str
    tasks: List[StageTask]
    after: Optional[Sequence[str]] = None


def stage_task_id(job: str, tid: str) -> str:
    """The namespaced DAG task id ``run_stages`` gives task ``tid``."""
    return f"df/{job}/{tid}"


def lower_stages(
    name: str,
    stages: Sequence[Stage],
    namespace: str = "",
    external_tokens: Sequence[str] = (),
) -> StageDag:
    """Lower ordered ``stages`` into one validated :class:`StageDag`.

    Consecutive stages are wired with barrier tokens over the *full*
    task set of the dependency stage — live and resumed tasks alike
    (resumed tokens ride ``dag.initial_tokens``).  ``namespace`` (must
    end with ``/`` when given) prefixes every task id and rewrites
    ``task:`` deps accordingly — the iterative driver uses it for
    per-iteration instantiation.  ``external_tokens`` declares deps
    satisfied from outside the DAG (tier-watch subscribers, data already
    in the tier) so validation doesn't reject them as unsatisfiable.
    """
    if namespace and not namespace.endswith("/"):
        raise ValueError("namespace must end with '/'")
    dag = StageDag(name)
    seen_stages: set = set()

    def ns_dep(dep: str) -> str:
        if dep.startswith("task:"):
            return task_token(namespace + dep[len("task:"):])
        return dep

    for i, st in enumerate(stages):
        if st.name in seen_stages:
            raise ValueError(f"duplicate stage name {st.name!r}")
        after = st.after
        if after is None:
            after = (stages[i - 1].name,) if i else ()
        barrier: frozenset = frozenset()
        for dep_stage in after:
            if dep_stage not in seen_stages:
                # Stages register in list order, so a barrier may only
                # name an *earlier* stage — a forward barrier could
                # never be satisfied and would stall the run.
                raise ValueError(
                    f"stage {st.name!r} depends on unknown (or later) "
                    f"stage {dep_stage!r}"
                )
            barrier |= dag.stage_tokens(dep_stage)
        seen_stages.add(st.name)
        for t in st.tasks:
            sid = namespace + t.tid
            if t.resumed:
                dag.resume(
                    sid, stage=st.name,
                    produces=list(t.produces) + list(t.outputs),
                )
                continue
            if t.run is None:
                raise ValueError(f"live task {sid!r} has no run callable")
            deps = frozenset(ns_dep(d) for d in t.deps)
            if not t.streaming:
                deps |= barrier
            dag.add(TaskSpec(
                sid, t.run, stage=st.name, preferred=tuple(t.preferred),
                deps=deps, produces=tuple(t.produces),
                streaming=t.streaming, listens=t.listens,
                on_complete=t.on_complete, speculatable=t.speculatable,
            ))
    dag.validate(external_tokens=external_tokens)
    return dag


# -- shared driver plumbing ---------------------------------------------------

def _resolve_scheduler(
    scheduler: Optional[Scheduler], gateway: Optional["Gateway"]
) -> Scheduler:
    if scheduler is None and gateway is not None:
        scheduler = gateway.shared_scheduler()
    if scheduler is None:
        scheduler = Scheduler(workers=[f"w{i}" for i in range(4)])
    return scheduler


def _modeled(tier: Tier) -> float:
    return tier.stats.modeled_seconds


def _chain(
    first: Optional[Callable[[TaskResult], None]],
    second: Callable[[TaskResult], None],
) -> Callable[[TaskResult], None]:
    if first is None:
        return second

    def both(res: TaskResult) -> None:
        first(res)
        second(res)

    return both


# -- one-shot N-stage jobs ----------------------------------------------------

@dataclass
class StageRunReport:
    job: str
    tasks: int = 0
    resumed_tasks: int = 0
    wall_seconds: float = 0.0
    #: modeled device seconds the state tier charged inline during the run.
    modeled_io_seconds: float = 0.0
    #: tasks that ran with a device-execution context bound.
    device_tasks: int = 0
    results: Dict[str, TaskResult] = field(default_factory=dict)

    def result(self, tid: str) -> TaskResult:
        """Result of bare task id ``tid`` (namespace resolved)."""
        return self.results[stage_task_id(self.job, tid)]


def run_stages(
    name: str,
    stages: Sequence[Stage],
    state: Tier,
    scheduler: Optional[Scheduler] = None,
    journal: Optional["StateCache"] = None,
    gateway: Optional["Gateway"] = None,
    subscribers: Sequence[Callable] = (),
    external_tokens: Sequence[str] = (),
) -> StageRunReport:
    """Deprecated entry point — delegate through the :mod:`repro.api`
    façade (same engine, byte-identical outputs).  New code should use
    :meth:`repro.api.MarvelClient.stages`."""
    from repro.api import _legacy_run_stages

    return _legacy_run_stages(
        name, stages, state, scheduler=scheduler, journal=journal,
        gateway=gateway, subscribers=subscribers,
        external_tokens=external_tokens,
    )


def _run_stages_impl(
    name: str,
    stages: Sequence[Stage],
    state: Tier,
    scheduler: Optional[Scheduler] = None,
    journal: Optional["StateCache"] = None,
    gateway: Optional["Gateway"] = None,
    subscribers: Sequence[Callable] = (),
    external_tokens: Sequence[str] = (),
    device: Optional["DeviceExec"] = None,
) -> StageRunReport:
    """Execute a non-iterative N-stage dataflow job end to end.

    With ``journal``, every task commit is journaled under
    ``df/<name>/done/<tid>``; a re-run resumes tasks whose marker is
    committed *and* whose declared ``outputs`` are still present in
    ``state`` (a volatile tier may have lost them since).
    ``external_tokens`` declares data-key deps satisfied from outside
    the DAG — typically keys the ``subscribers`` tier watch publishes.
    ``device`` binds a device-execution context around every task that
    declared ``device=True`` (see :func:`current_device_exec`); tasks
    without a device lowering run unchanged.
    """
    scheduler = _resolve_scheduler(scheduler, gateway)
    sj = StateJournal(journal, f"df/{name}") if journal is not None else None
    committed = sj.entries() if sj is not None else {}
    report = StageRunReport(job=name)
    prepared: List[Stage] = []
    for st in stages:
        tasks: List[StageTask] = []
        for t in st.tasks:
            report.tasks += 1
            if (
                not t.resumed
                and t.tid in committed
                and all(state.contains(k) for k in t.outputs)
            ):
                t = replace(t, resumed=True)
            if t.resumed:
                report.resumed_tasks += 1
            elif sj is not None:
                def commit(res: TaskResult, tid: str = t.tid) -> None:
                    sj.commit(tid, {"task": tid})

                t = replace(t, on_complete=_chain(t.on_complete, commit))
            if (
                device is not None and t.device
                and not t.resumed and t.run is not None
            ):
                t = replace(t, run=_with_device(t.run, device))
                report.device_tasks += 1
            tasks.append(t)
        prepared.append(Stage(st.name, tasks, after=st.after))
    dag = lower_stages(name, prepared, namespace=f"df/{name}/",
                       external_tokens=external_tokens)
    t0 = time.perf_counter()
    io0 = _modeled(state)
    report.results = scheduler.run_dag(
        dag.specs, initial_tokens=dag.initial_tokens, subscribers=subscribers
    )
    report.wall_seconds = time.perf_counter() - t0
    report.modeled_io_seconds = _modeled(state) - io0
    return report


# -- fixed-point loops --------------------------------------------------------

class LoopContext:
    """Runtime handle given to a loop's ``init``/``superstep``/``converged``.

    Owns the versioned key naming for loop-carried state and tracks which
    state names the current superstep wrote (the iteration marker's key
    set).  ``write``/``read`` are thread-safe — superstep tasks call them
    concurrently from scheduler workers.
    """

    def __init__(self, job: str, state: Tier) -> None:
        self.job = job
        self.state = state
        self.prefix = f"df/{job}"
        #: current iteration: 0 is ``init``, supersteps are 1..N.
        self.iteration = 0
        #: raw DAG results of the just-finished superstep.
        self.results: Dict[str, TaskResult] = {}
        self._written: set = set()
        self._wlock = threading.Lock()

    # -- key naming -------------------------------------------------------
    def state_key(self, name: str, iteration: Optional[int] = None) -> str:
        it = self.iteration if iteration is None else iteration
        return f"{self.prefix}/state/it{it:05d}/{name}"

    def input_key(self, name: str) -> str:
        """Static (non-loop-carried) inputs live outside the versioned
        state area but inside the pinned job prefix."""
        return f"{self.prefix}/input/{name}"

    def task_id(self, tid: str) -> str:
        """The namespaced DAG task id of ``tid`` in the current superstep."""
        return f"{self.prefix}/it{self.iteration:05d}/{tid}"

    # -- loop state I/O ---------------------------------------------------
    def write(self, name: str, blob: bytes) -> None:
        """Write loop state ``name`` for the **current** iteration."""
        self.state.put(self.state_key(name), blob)
        with self._wlock:
            self._written.add(name)

    def write_many(self, blobs: Mapping[str, bytes]) -> None:
        """Batched :meth:`write` — one tier request for the whole set."""
        self.state.put_many(
            {self.state_key(nm): b for nm, b in blobs.items()}
        )
        with self._wlock:
            self._written.update(blobs)

    def read(self, name: str, iteration: Optional[int] = None) -> bytes:
        """Read loop state — from the **previous** iteration by default
        (the loop-carried edge); pass ``iteration`` for anything else."""
        it = self.iteration - 1 if iteration is None else iteration
        return self.state.get(self.state_key(name, it))

    def read_current(self, name: str) -> bytes:
        """Read state written earlier in the *current* superstep (a later
        stage consuming an earlier stage's output)."""
        return self.read(name, self.iteration)

    def result(self, tid: str) -> TaskResult:
        """A just-finished superstep task's result, by bare task id."""
        return self.results[self.task_id(tid)]


@dataclass
class LoopReport:
    job: str
    #: supersteps executed by this call (init counts when it ran here).
    iterations: int = 0
    #: committed supersteps skipped via the journal (init included).
    resumed_iterations: int = 0
    converged: bool = False
    #: highest committed iteration (0 = init; -1 = nothing ran).
    last_iteration: int = -1
    wall_seconds: float = 0.0
    modeled_io_seconds: float = 0.0
    #: one entry per superstep executed here:
    #: ``{"iteration", "wall_s", "modeled_s", "tasks"}``.
    per_iteration: List[dict] = field(default_factory=list)

    @property
    def total_seconds(self) -> float:
        return self.wall_seconds + self.modeled_io_seconds


def _marker(iteration: int) -> str:
    return f"it{iteration:05d}"


def _resume_point(
    sj: Optional[StateJournal], ctx: LoopContext
) -> Tuple[int, bool, List[str]]:
    """Highest committed iteration whose state blobs all survive, with
    its converged flag and key set; (-1, False, []) when starting fresh.

    Also retracts every *other* marker: an interrupted GC (crash after
    ``commit(k)`` but before ``retract(k-1)``) or a marker whose blobs
    the tier lost would otherwise linger forever — the loop journal must
    stay O(1) in the iteration count across any crash schedule.
    """
    if sj is None:
        return -1, False, []

    def intact(meta: dict, k: int) -> bool:
        return all(
            ctx.state.contains(ctx.state_key(nm, k))
            for nm in meta.get("keys", [])
        )

    entries = sj.entries(prefix="it")
    picked = -1
    meta: dict = {}
    # Zero-padded marker ids: lexicographic == numeric, newest first.
    for eid in sorted(entries, reverse=True):
        k = int(eid[2:])
        if intact(entries[eid], k):
            picked, meta = k, entries[eid]
            break
    for eid in entries:
        if int(eid[2:]) != picked:
            sj.retract(eid)
    if picked < 0:
        return -1, False, []
    return picked, bool(meta.get("converged")), list(meta.get("keys", []))


def _sweep_stale_state(ctx: LoopContext, keep: int) -> None:
    """Drop every state version except ``keep``: versions below it are
    GC leftovers whose delete was interrupted; versions above it are
    partial blobs from a superstep that crashed before its marker — both
    would otherwise sit in the (pinned!) fast level forever."""
    base = f"{ctx.prefix}/state/it"
    for key in list(ctx.state.keys()):
        if not key.startswith(base):
            continue
        version = key[len(base):len(base) + 5]
        if version.isdigit() and int(version) != keep:
            ctx.state.delete(key)


def run_loop(
    name: str,
    init: Callable[[LoopContext], None],
    superstep: Callable[[LoopContext], Sequence[Stage]],
    converged: Callable[[LoopContext], bool],
    state: Tier,
    scheduler: Optional[Scheduler] = None,
    journal: Optional["StateCache"] = None,
    gateway: Optional["Gateway"] = None,
    max_iterations: int = 50,
    pin_state: bool = True,
    halt_after: Optional[int] = None,
) -> LoopReport:
    """Deprecated entry point — delegate through the :mod:`repro.api`
    façade (same engine, byte-identical outputs).  New code should use
    :meth:`repro.api.MarvelClient.iterate`."""
    from repro.api import _legacy_run_loop

    return _legacy_run_loop(
        name, init, superstep, converged, state, scheduler=scheduler,
        journal=journal, gateway=gateway, max_iterations=max_iterations,
        pin_state=pin_state, halt_after=halt_after,
    )


def _run_loop_impl(
    name: str,
    init: Callable[[LoopContext], None],
    superstep: Callable[[LoopContext], Sequence[Stage]],
    converged: Callable[[LoopContext], bool],
    state: Tier,
    scheduler: Optional[Scheduler] = None,
    journal: Optional["StateCache"] = None,
    gateway: Optional["Gateway"] = None,
    max_iterations: int = 50,
    pin_state: bool = True,
    halt_after: Optional[int] = None,
) -> LoopReport:
    """Drive a fixed-point dataflow loop to convergence.

    ``init`` writes iteration-0 state through the :class:`LoopContext`;
    ``superstep`` returns the stage set for the current iteration (tasks
    read version *k-1* via ``ctx.read`` and write version *k* via
    ``ctx.write``); ``converged`` runs between supersteps over the
    just-finished iteration's state/results.

    ``journal``: per-iteration commit markers — a re-run (same ``name``,
    same journal) resumes at the last completed superstep byte-identically
    instead of recomputing it.  ``pin_state``: on a
    :class:`~repro.storage.hierarchy.TieredStore` the job prefix is
    pinned in the fast level for the life of the loop.  ``halt_after``:
    stop (without convergence) after executing that many supersteps in
    this call — the crash-schedule test hook.
    """
    ctx = LoopContext(name, state)
    sj = (
        StateJournal(journal, f"{ctx.prefix}/loop")
        if journal is not None else None
    )
    report = LoopReport(job=name)
    scheduler = _resolve_scheduler(scheduler, gateway)
    pinned = pin_state and hasattr(state, "pin")
    if pinned:
        state.pin(ctx.prefix + "/")
    try:
        with scheduler.pooled():
            t0 = time.perf_counter()
            io0 = _modeled(state)
            start, was_converged, prev_keys = _resume_point(sj, ctx)
            if sj is not None:
                # keep=-1 (nothing resumable) sweeps every version: a
                # journaled loop without an intact marker has no
                # committed state, only a dead run's leftovers.
                _sweep_stale_state(ctx, keep=start)
            if start >= 0:
                report.resumed_iterations = start + 1
                report.last_iteration = start
                report.converged = was_converged
                if was_converged:
                    return report
            else:
                # iteration 0: init writes the seed state.
                ctx.iteration = 0
                ctx._written.clear()
                w0, m0 = time.perf_counter(), _modeled(state)
                init(ctx)
                prev_keys = sorted(ctx._written)
                if sj is not None:
                    sj.commit(_marker(0), {"keys": prev_keys,
                                           "converged": False})
                report.iterations += 1
                report.last_iteration = 0
                report.per_iteration.append({
                    "iteration": 0,
                    "wall_s": time.perf_counter() - w0,
                    "modeled_s": _modeled(state) - m0,
                    "tasks": 0,
                })
            while not report.converged:
                k = report.last_iteration + 1
                if k > max_iterations:
                    break
                if halt_after is not None and report.iterations >= halt_after:
                    break
                ctx.iteration = k
                ctx.results = {}
                ctx._written.clear()
                w0, m0 = time.perf_counter(), _modeled(state)
                stages = list(superstep(ctx))
                dag = lower_stages(
                    f"{name}/it{k:05d}", stages,
                    namespace=f"{ctx.prefix}/it{k:05d}/",
                )
                ctx.results = scheduler.run_dag(
                    dag.specs, initial_tokens=dag.initial_tokens
                )
                conv = bool(converged(ctx))
                keys = sorted(ctx._written)
                # Marker strictly after the superstep's state blobs (they
                # landed during the DAG run): a torn run re-executes this
                # superstep; a marker never summarizes missing state.
                if sj is not None:
                    sj.commit(_marker(k), {"keys": keys, "converged": conv})
                # Version k-1 retires: k is all the next superstep (and a
                # resume) needs.  Marker first, then blobs — an
                # interrupted GC leaves garbage blobs that the next
                # resume's sweep collects, never a marker whose state is
                # half-deleted.
                if sj is not None:
                    sj.retract(_marker(k - 1))
                for nm in prev_keys:
                    state.delete(ctx.state_key(nm, k - 1))
                prev_keys = keys
                report.iterations += 1
                report.last_iteration = k
                report.converged = conv
                report.per_iteration.append({
                    "iteration": k,
                    "wall_s": time.perf_counter() - w0,
                    "modeled_s": _modeled(state) - m0,
                    "tasks": len(dag.specs),
                })
            report.wall_seconds = time.perf_counter() - t0
            report.modeled_io_seconds = _modeled(state) - io0
            return report
    finally:
        if pinned:
            state.unpin(ctx.prefix + "/")
