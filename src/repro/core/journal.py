"""Unified commit journal — one durability abstraction for every workload.

Before this module the repo had two ad-hoc journals: the MapReduce engine
wrote ``mr/<job>/done/<task>`` markers straight into a
:class:`~repro.storage.kvcache.StateCache`, and the stateful function
runtime serialized session state under ``state/...`` with its own commit
cadence.  :class:`StateJournal` is the shared abstraction both now use:

  * entries are **partition-granular**: a map task commits itself *and*
    each shuffle partition it published, so a job interrupted mid-wave
    resumes from individual committed partitions, not just wave
    boundaries;
  * commits carry a small JSON meta blob (sizes, sequence numbers) that
    recovery uses to re-prime the DAG token table without touching the
    data tier;
  * durability follows the backing cache: a volatile cache gives
    stock-Marvel semantics, a write-through (PMEM) cache survives crashes
    — the paper's central trade, unchanged.

Key layout is compatible with the pre-refactor MapReduce journal
(``<ns>/done/<entry>``), so journals written by older runs still resume.

Thread-safety: the journal itself holds no mutable state — every op is a
single atomic :class:`StateCache` operation, so concurrent invokers can
commit through one journal instance.  Crash consistency under a torn
``put_many`` (see :class:`~repro.storage.faults.FaultInjectingTier`) is an
*ordering* contract: batches persist in mapping order, so commit markers
that summarize other entries must come **last** in the batch —
:meth:`StateJournal.commit_many_ordered` encodes that rule.
"""

from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional

from repro.storage.kvcache import StateCache

__all__ = ["StateJournal"]


class StateJournal:
    """Append-only commit markers, namespaced, over a :class:`StateCache`."""

    def __init__(self, cache: StateCache, namespace: str) -> None:
        self.cache = cache
        self.namespace = namespace.rstrip("/")

    def _key(self, entry_id: str) -> str:
        return f"{self.namespace}/done/{entry_id}"

    # -- commit side -------------------------------------------------------
    def commit(self, entry_id: str, meta: Optional[dict] = None) -> None:
        self.cache.put(self._key(entry_id), json.dumps(meta or {}).encode())

    def commit_many(self, entries: Dict[str, dict]) -> None:
        self.cache.put_many(
            {self._key(e): json.dumps(m or {}).encode()
             for e, m in entries.items()}
        )

    def commit_many_ordered(
        self, entries: Dict[str, dict], marker: str
    ) -> None:
        """Commit a batch whose ``marker`` entry summarizes the rest.

        The marker is moved to the **end** of the batch so a torn
        ``put_many`` (crash mid-commit) can persist detail entries without
        their summary, but never a summary whose details are missing — the
        invariant mid-wave resume relies on.
        """
        ordered = {e: m for e, m in entries.items() if e != marker}
        if marker in entries:
            ordered[marker] = entries[marker]
        self.commit_many(ordered)

    def retract(self, entry_id: str) -> None:
        """Remove a commit marker (write-back dirty records retire this
        way once their home flush lands)."""
        self.cache.delete(self._key(entry_id))

    # -- recovery side -----------------------------------------------------
    def committed(self, entry_id: str) -> bool:
        return self.cache.contains(self._key(entry_id))

    def meta(self, entry_id: str) -> dict:
        return json.loads(self.cache.get(self._key(entry_id)))

    def entries(self, prefix: str = "") -> Dict[str, dict]:
        """All committed entry ids (under ``prefix``) with their meta."""
        base = f"{self.namespace}/done/{prefix}"
        plen = len(f"{self.namespace}/done/")
        out: Dict[str, dict] = {}
        for key in self.cache.keys(base):
            out[key[plen:]] = json.loads(self.cache.get(key))
        return out

    def pending(self, entry_ids: Iterable[str]) -> List[str]:
        """The subset of ``entry_ids`` not yet committed (work remaining)."""
        return [e for e in entry_ids if not self.committed(e)]

    def clear(self) -> None:
        for key in self.cache.keys(f"{self.namespace}/done/"):
            self.cache.delete(key)
