"""Unified commit journal — one durability abstraction for every workload.

Before this module the repo had two ad-hoc journals: the MapReduce engine
wrote ``mr/<job>/done/<task>`` markers straight into a
:class:`~repro.storage.kvcache.StateCache`, and the stateful function
runtime serialized session state under ``state/...`` with its own commit
cadence.  :class:`StateJournal` is the shared abstraction both now use:

  * entries are **partition-granular**: a map task commits itself *and*
    each shuffle partition it published, so a job interrupted mid-wave
    resumes from individual committed partitions, not just wave
    boundaries;
  * commits carry a small JSON meta blob (sizes, sequence numbers) that
    recovery uses to re-prime the DAG token table without touching the
    data tier;
  * durability follows the backing cache: a volatile cache gives
    stock-Marvel semantics, a write-through (PMEM) cache survives crashes
    — the paper's central trade, unchanged.

Key layout is compatible with the pre-refactor MapReduce journal
(``<ns>/done/<entry>``), so journals written by older runs still resume.

Thread-safety: the journal itself holds no mutable state — every op is a
single atomic :class:`StateCache` operation, so concurrent invokers can
commit through one journal instance.  Crash consistency under a torn
``put_many`` (see :class:`~repro.storage.faults.FaultInjectingTier`) is an
*ordering* contract: batches persist in mapping order, so commit markers
that summarize other entries must come **last** in the batch —
:meth:`StateJournal.commit_many_ordered` encodes that rule.
"""

from __future__ import annotations

import json
import threading
import time
from collections import OrderedDict
from typing import Callable, Dict, Iterable, List, Optional

from repro.storage.kvcache import StateCache
from repro.storage.tiers import TierStats, tier_accounting

__all__ = ["CommitTicket", "GroupCommitter", "StateJournal"]


class StateJournal:
    """Append-only commit markers, namespaced, over a :class:`StateCache`."""

    def __init__(self, cache: StateCache, namespace: str) -> None:
        self.cache = cache
        self.namespace = namespace.rstrip("/")

    def _key(self, entry_id: str) -> str:
        return f"{self.namespace}/done/{entry_id}"

    # -- commit side -------------------------------------------------------
    def commit(self, entry_id: str, meta: Optional[dict] = None) -> None:
        self.cache.put(self._key(entry_id), json.dumps(meta or {}).encode())

    def commit_many(self, entries: Dict[str, dict]) -> None:
        self.cache.put_many(
            {self._key(e): json.dumps(m or {}).encode()
             for e, m in entries.items()}
        )

    def commit_many_ordered(
        self, entries: Dict[str, dict], marker: str
    ) -> None:
        """Commit a batch whose ``marker`` entry summarizes the rest.

        The marker is moved to the **end** of the batch so a torn
        ``put_many`` (crash mid-commit) can persist detail entries without
        their summary, but never a summary whose details are missing — the
        invariant mid-wave resume relies on.
        """
        ordered = {e: m for e, m in entries.items() if e != marker}
        if marker in entries:
            ordered[marker] = entries[marker]
        self.commit_many(ordered)

    def retract(self, entry_id: str) -> None:
        """Remove a commit marker (write-back dirty records retire this
        way once their home flush lands)."""
        self.cache.delete(self._key(entry_id))

    # -- recovery side -----------------------------------------------------
    def committed(self, entry_id: str) -> bool:
        return self.cache.contains(self._key(entry_id))

    def meta(self, entry_id: str) -> dict:
        return json.loads(self.cache.get(self._key(entry_id)))

    def entries(self, prefix: str = "") -> Dict[str, dict]:
        """All committed entry ids (under ``prefix``) with their meta."""
        base = f"{self.namespace}/done/{prefix}"
        plen = len(f"{self.namespace}/done/")
        out: Dict[str, dict] = {}
        for key in self.cache.keys(base):
            out[key[plen:]] = json.loads(self.cache.get(key))
        return out

    def pending(self, entry_ids: Iterable[str]) -> List[str]:
        """The subset of ``entry_ids`` not yet committed (work remaining)."""
        return [e for e in entry_ids if not self.committed(e)]

    def clear(self) -> None:
        for key in self.cache.keys(f"{self.namespace}/done/"):
            self.cache.delete(key)


# -- group commit --------------------------------------------------------------

class CommitTicket:
    """Resolution handle for one group-committed (blob, marker) pair.

    Resolves exactly once, when the flush round containing the pair lands
    (``error is None``) or fails (``error`` set — e.g. a torn
    ``put_many``).  ``add_done_callback`` runs the callback on the flusher
    thread, or inline if already resolved; each registered callback runs
    exactly once regardless of the registration/resolution race.
    """

    __slots__ = ("_done", "error", "_callbacks")

    def __init__(self) -> None:
        # No Event allocated up front: the warm path resolves tickets via
        # callbacks (the gateway's deferred ack), so most tickets are
        # never waited on — blockers allocate their own event in wait().
        self._done = False
        self.error: Optional[BaseException] = None
        self._callbacks: List[Callable[["CommitTicket"], None]] = []

    def done(self) -> bool:
        return self._done

    def wait(self, timeout: Optional[float] = None) -> None:
        """Block until durable; re-raise the flush error if it failed."""
        if not self._done:
            event = threading.Event()
            self.add_done_callback(lambda _t: event.set())
            if not event.wait(timeout):
                raise TimeoutError("group commit did not flush in time")
        if self.error is not None:
            raise self.error

    def add_done_callback(
        self, fn: Callable[["CommitTicket"], None]
    ) -> None:
        self._callbacks.append(fn)  # GIL-atomic append
        if self._done:
            self._drain()

    def _resolve(self, error: Optional[BaseException]) -> None:
        self.error = error
        self._done = True
        self._drain()

    def _drain(self) -> None:
        # pop() is atomic, so a callback runs once even when resolver and
        # a concurrent add_done_callback both reach here.
        while self._callbacks:
            try:
                cb = self._callbacks.pop()
            except IndexError:
                break
            cb(self)


class _PendingCommit:
    __slots__ = ("blob_key", "blob", "entry_id", "meta", "tickets",
                 "on_durable")

    def __init__(self, blob_key: str) -> None:
        self.blob_key = blob_key
        self.blob: bytes = b""
        self.entry_id: Optional[str] = None
        self.meta: Optional[dict] = None
        self.tickets: List[CommitTicket] = []
        self.on_durable: List[Callable[[], None]] = []


class GroupCommitter:
    """Coalesces concurrent state commits into batched ``put_many`` calls.

    Warm invocations enqueue a ``(state blob, journal marker)`` pair and
    continue; a dedicated flusher drains the queue and lands one
    ``put_many`` per round — so N concurrent sessions pay one modeled
    tier request instead of 2N.  Commits to the *same* state key coalesce
    (latest blob/marker win; every enqueuer's ticket resolves together) —
    safe because the gateway's lease makes each session's enqueues
    already serialized.

    Crash ordering: the batch interleaves ``blob, marker, blob, marker,
    ...`` — the pair-adjacent generalization of
    :meth:`StateJournal.commit_many_ordered`'s marker-last rule.  Tiers
    persist ``put_many`` batches in mapping order and a torn batch lands
    a strict prefix, so a crash mid-flush can strand at most one blob
    without its marker and **never** a marker without its blob — the
    same exposure as the unbatched put-blob-then-put-marker path, which
    is what keeps recovery byte-identical at the last landed marker.

    ``stats`` accounts the flusher thread's tier I/O (it runs outside
    any invoker's accounting scope).
    """

    def __init__(
        self,
        journal: StateJournal,
        flush_interval: float = 0.0,
        name: str = "group-commit",
    ) -> None:
        self.journal = journal
        self.flush_interval = flush_interval
        self.stats = TierStats()
        self.batches = 0  # flush rounds that performed I/O
        self.entries = 0  # coalesced pairs flushed
        self._lock = threading.Lock()
        self._idle = threading.Condition(self._lock)
        self._wake = threading.Event()
        self._pending: "OrderedDict[str, _PendingCommit]" = OrderedDict()
        self._inflight = 0  # pairs drained but not yet resolved
        self._closed = False
        self._thread = threading.Thread(
            target=self._run, name=name, daemon=True
        )
        self._thread.start()

    # -- commit side -------------------------------------------------------
    def enqueue(
        self,
        blob_key: str,
        blob: bytes,
        entry_id: Optional[str] = None,
        meta: Optional[dict] = None,
        on_durable: Optional[Callable[[], None]] = None,
    ) -> CommitTicket:
        """Queue one blob (+ its journal marker) for the next flush."""
        ticket = CommitTicket()
        with self._lock:
            if self._closed:
                raise RuntimeError("group committer is closed")
            pc = self._pending.get(blob_key)
            if pc is None:
                pc = _PendingCommit(blob_key)
                self._pending[blob_key] = pc
            pc.blob = blob
            pc.entry_id = entry_id
            pc.meta = meta
            pc.tickets.append(ticket)
            if on_durable is not None:
                pc.on_durable.append(on_durable)
        self._wake.set()
        return ticket

    def flush(self, timeout: Optional[float] = 30.0) -> bool:
        """Block until everything enqueued so far is resolved (durable or
        failed).  Returns False on timeout."""
        self._wake.set()
        with self._idle:
            return self._idle.wait_for(
                lambda: not self._pending and self._inflight == 0, timeout
            )

    def drop_pending(self, error: BaseException) -> None:
        """Discard everything still queued (a crash before the flush):
        the pairs never reach the tier and their tickets fail with
        ``error`` — queued-but-unflushed commits are volatile state."""
        with self._lock:
            drained = list(self._pending.values())
            self._pending.clear()
        for pc in drained:
            for t in pc.tickets:
                t._resolve(error)

    def close(self, flush: bool = True) -> None:
        """Stop accepting commits; drain (default) and join the flusher."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        if not flush:
            self.drop_pending(
                RuntimeError("group committer closed before flush")
            )
        self._wake.set()
        self._thread.join(timeout=10.0)

    # -- flusher -----------------------------------------------------------
    def _run(self) -> None:
        with tier_accounting(self.stats):
            while True:
                self._wake.wait()
                if self.flush_interval > 0.0:
                    # accumulation window: let concurrent invokers pile
                    # more commits into this round before it pays I/O.
                    time.sleep(self.flush_interval)
                with self._lock:
                    drained = list(self._pending.values())
                    self._pending.clear()
                    self._wake.clear()
                    self._inflight = len(drained)
                    closed = self._closed
                if drained:
                    self._flush_round(drained)
                with self._idle:
                    self._inflight = 0
                    self._idle.notify_all()
                    if closed and not self._pending:
                        return

    def _flush_round(self, drained: List[_PendingCommit]) -> None:
        batch: "OrderedDict[str, bytes]" = OrderedDict()
        for pc in drained:  # pair-adjacent: every marker right after its blob
            batch[pc.blob_key] = pc.blob
            if pc.entry_id is not None:
                batch[self.journal._key(pc.entry_id)] = json.dumps(
                    pc.meta or {}
                ).encode()
        err: Optional[BaseException] = None
        try:
            self.journal.cache.put_many(batch)
        except BaseException as exc:
            err = exc
        self.batches += 1
        self.entries += len(drained)
        for pc in drained:
            if err is None:
                for cb in pc.on_durable:
                    try:
                        cb()
                    except Exception:
                        pass  # bookkeeping must not kill the flusher
            for t in pc.tickets:
                t._resolve(err)
