"""Task scheduler — the YARN analog.

Plans and executes task sets (map waves, reduce waves) over a pool of
worker slots with the fault-tolerance features a 1000-node deployment
needs and the paper defers to future work:

  * retry with bounded attempts on task failure,
  * speculative execution: when a task runs longer than
    ``speculation_factor ×`` the median completed duration, a backup
    attempt is launched and the first finisher wins (straggler
    mitigation),
  * locality-aware placement: tasks carry preferred workers (from the
    BlockStore replica map) and the scheduler matches when possible,
  * elastic pool: workers can be added/removed between waves.

Execution is thread-based; tasks are host-side functions (MapReduce tasks
do tier I/O + compute).  Determinism for tests comes from task outputs
being content-addressed, not from scheduling order.
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

__all__ = ["Task", "TaskResult", "Scheduler", "TaskFailedError"]


class TaskFailedError(RuntimeError):
    pass


@dataclass
class Task:
    task_id: str
    run: Callable[[str], Any]  # worker_id -> result
    #: preferred worker ids (data locality), best-effort.
    preferred: Sequence[str] = ()


@dataclass
class TaskResult:
    task_id: str
    value: Any
    worker: str
    attempts: int
    speculative_win: bool
    seconds: float


@dataclass
class _Attempt:
    task: Task
    worker: str
    future: Future
    started: float
    speculative: bool


class Scheduler:
    def __init__(
        self,
        workers: Sequence[str],
        max_attempts: int = 3,
        speculation_factor: Optional[float] = 2.0,
        min_speculation_seconds: float = 0.05,
    ) -> None:
        self.workers: List[str] = list(workers)
        self.max_attempts = max_attempts
        self.speculation_factor = speculation_factor
        self.min_speculation_seconds = min_speculation_seconds
        self._lock = threading.Lock()

    # -- elastic pool ----------------------------------------------------------
    def add_workers(self, workers: Sequence[str]) -> None:
        with self._lock:
            self.workers.extend(w for w in workers if w not in self.workers)

    def remove_workers(self, workers: Sequence[str]) -> None:
        with self._lock:
            self.workers = [w for w in self.workers if w not in workers]

    # -- execution -----------------------------------------------------------
    def run_wave(self, tasks: Sequence[Task]) -> Dict[str, TaskResult]:
        """Run a wave of tasks to completion; returns task_id -> result."""
        if not self.workers:
            raise RuntimeError("scheduler has no workers")
        results: Dict[str, TaskResult] = {}
        attempts_used: Dict[str, int] = {t.task_id: 0 for t in tasks}
        durations: List[float] = []
        pending: List[Task] = list(tasks)
        live: Dict[Future, _Attempt] = {}
        # One slot per worker models one invoker container per node.
        pool = ThreadPoolExecutor(max_workers=max(1, len(self.workers)))
        free: List[str] = list(self.workers)

        def launch(task: Task, speculative: bool) -> None:
            worker = None
            for w in task.preferred:
                if w in free:
                    worker = w
                    break
            if worker is None and free:
                worker = free[0]
            if worker is None:
                return
            free.remove(worker)
            attempts_used[task.task_id] += 1
            fut = pool.submit(task.run, worker)
            live[fut] = _Attempt(task, worker, fut, time.perf_counter(), speculative)

        try:
            while len(results) < len(tasks):
                while pending and free:
                    launch(pending.pop(0), speculative=False)
                if not live:
                    # All remaining tasks exhausted their attempts.
                    missing = [t for t in tasks if t.task_id not in results]
                    raise TaskFailedError(
                        f"tasks failed permanently: {[t.task_id for t in missing]}"
                    )
                done, _ = wait(live.keys(), timeout=0.01, return_when=FIRST_COMPLETED)
                now = time.perf_counter()
                for fut in done:
                    att = live.pop(fut)
                    free.append(att.worker)
                    tid = att.task.task_id
                    if tid in results:
                        continue  # a sibling attempt already won
                    err = fut.exception()
                    dur = now - att.started
                    if err is None:
                        durations.append(dur)
                        results[tid] = TaskResult(
                            tid, fut.result(), att.worker,
                            attempts_used[tid], att.speculative, dur,
                        )
                    else:
                        if getattr(err, "non_retryable", False):
                            raise err  # quota-style failures: fail fast
                        still_running = any(
                            a.task.task_id == tid for a in live.values()
                        )
                        if attempts_used[tid] < self.max_attempts:
                            pending.append(att.task)  # retry
                        elif not still_running:
                            missing = [tid]
                            raise TaskFailedError(
                                f"task {tid} failed after "
                                f"{attempts_used[tid]} attempts"
                            ) from err
                # Speculation: back up the slowest outliers.
                if (
                    self.speculation_factor is not None
                    and durations
                    and free
                    and not pending
                ):
                    median = sorted(durations)[len(durations) // 2]
                    threshold = max(
                        self.min_speculation_seconds,
                        median * self.speculation_factor,
                    )
                    running_tids = [a.task.task_id for a in live.values()]
                    for att in list(live.values()):
                        if not free:
                            break
                        tid = att.task.task_id
                        if (
                            now - att.started > threshold
                            and running_tids.count(tid) == 1
                            and attempts_used[tid] < self.max_attempts
                        ):
                            launch(att.task, speculative=True)
            return results
        finally:
            pool.shutdown(wait=False, cancel_futures=True)
