"""Task scheduler — the YARN analog.

Plans and executes task sets (map waves, reduce waves) over a pool of
worker slots with the fault-tolerance features a 1000-node deployment
needs and the paper defers to future work:

  * retry with bounded attempts on task failure,
  * speculative execution: when a task runs longer than
    ``speculation_factor ×`` the median completed duration, a backup
    attempt is launched and the first finisher wins (straggler
    mitigation),
  * locality-aware placement: tasks carry preferred workers (from the
    BlockStore replica map) and the scheduler matches when possible,
  * elastic pool: workers can be added/removed between waves.

Execution is thread-based; tasks are host-side functions (MapReduce tasks
do tier I/O + compute).  Determinism for tests comes from task outputs
being content-addressed, not from scheduling order.
"""

from __future__ import annotations

import contextlib
import queue
import threading
import time
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.dag import TaskContext, TaskSpec, task_token

__all__ = ["Task", "TaskResult", "Scheduler", "TaskFailedError"]


class TaskFailedError(RuntimeError):
    pass


@dataclass
class Task:
    task_id: str
    run: Callable[[str], Any]  # worker_id -> result
    #: preferred worker ids (data locality), best-effort.
    preferred: Sequence[str] = ()


@dataclass
class TaskResult:
    task_id: str
    value: Any
    worker: str
    attempts: int
    speculative_win: bool
    seconds: float
    #: perf_counter timestamps of the winning attempt (pipeline metrics).
    started: float = 0.0
    ended: float = 0.0


@dataclass
class _Attempt:
    task: Task
    worker: str
    future: Future
    started: float
    speculative: bool


class Scheduler:
    def __init__(
        self,
        workers: Sequence[str],
        max_attempts: int = 3,
        speculation_factor: Optional[float] = 2.0,
        min_speculation_seconds: float = 0.05,
        reuse_pool: bool = False,
    ) -> None:
        """``reuse_pool=True`` keeps one ThreadPoolExecutor alive across
        ``run_dag`` calls (grown when workers are added) instead of
        creating/tearing one down per run — the shared-pool mode the
        gateway uses so MapReduce jobs ride the same invoker pool as
        function invocations (call :meth:`close` when done)."""
        self.workers: List[str] = list(workers)
        self.max_attempts = max_attempts
        self.speculation_factor = speculation_factor
        self.min_speculation_seconds = min_speculation_seconds
        self.reuse_pool = reuse_pool
        self._lock = threading.Lock()
        self._pool: Optional[ThreadPoolExecutor] = None
        self._pool_size = 0
        self._retired_pools: List[ThreadPoolExecutor] = []

    # -- elastic pool ----------------------------------------------------------
    def add_workers(self, workers: Sequence[str]) -> None:
        with self._lock:
            self.workers.extend(w for w in workers if w not in self.workers)

    def remove_workers(self, workers: Sequence[str]) -> None:
        with self._lock:
            self.workers = [w for w in self.workers if w not in workers]

    @contextlib.contextmanager
    def pooled(self):
        """Keep one executor alive across consecutive :meth:`run_dag`
        calls for the duration of the scope — an iterative driver's
        supersteps reuse warm threads instead of paying pool setup and
        teardown per superstep.  Restores the previous mode on exit and
        reaps the pool if this scope was the one that created it (a
        scheduler already in ``reuse_pool`` mode keeps its pool)."""
        with self._lock:
            prev = self.reuse_pool
            self.reuse_pool = True
        try:
            yield self
        finally:
            with self._lock:
                self.reuse_pool = prev
            if not prev:
                self.close()

    def close(self) -> None:
        """Shut down the persistent pool(s) (``reuse_pool=True`` mode)."""
        with self._lock:
            pools = list(self._retired_pools)
            if self._pool is not None:
                pools.append(self._pool)
            self._pool, self._pool_size = None, 0
            self._retired_pools.clear()
        for pool in pools:
            pool.shutdown(wait=False, cancel_futures=True)

    def _acquire_pool(self, slots: int) -> Tuple[ThreadPoolExecutor, bool]:
        """An executor with >= ``slots`` threads; bool = caller owns it.

        Growth never shuts the outgrown pool down — a concurrent
        ``run_dag`` may still be submitting to it; outgrown pools are
        parked and reaped in :meth:`close`.
        """
        if not self.reuse_pool:
            return ThreadPoolExecutor(max_workers=slots), True
        with self._lock:
            if self._pool is None or self._pool_size < slots:
                if self._pool is not None:
                    self._retired_pools.append(self._pool)
                self._pool = ThreadPoolExecutor(max_workers=slots)
                self._pool_size = slots
            return self._pool, False

    # -- execution -----------------------------------------------------------
    def run_wave(self, tasks: Sequence[Task]) -> Dict[str, TaskResult]:
        """Run a wave of tasks to completion; returns task_id -> result.

        A wave is the degenerate DAG: dependency-free barrier tasks.
        Retry, locality, and speculation all come from :meth:`run_dag`.
        """
        specs = [
            TaskSpec(
                t.task_id,
                (lambda ctx, t=t: t.run(ctx.worker)),
                preferred=t.preferred,
            )
            for t in tasks
        ]
        return self.run_dag(specs)

    # -- continuous DAG execution ---------------------------------------------
    def run_dag(
        self,
        specs: Sequence[TaskSpec],
        initial_tokens: Sequence[str] = (),
        subscribers: Sequence[Callable[[Callable[[str], None]], Callable[[], None]]] = (),
    ) -> Dict[str, TaskResult]:
        """Continuous, dependency-aware execution of a task DAG.

        Unlike :meth:`run_wave` there is no barrier: any task whose
        dependency tokens are all published is dispatched immediately, and
        *streaming* tasks launch right away on overlap slots and consume
        tokens as they appear — so consumers overlap with producers.

        ``initial_tokens`` primes the token table (journal-resumed work).
        ``subscribers`` are callables receiving the run's thread-safe
        ``publish`` function and returning an unsubscribe callable — the
        hook tier ``watch`` plugs into, turning storage commits into
        dataflow events.

        Retains :meth:`run_wave` semantics per task: bounded retry,
        locality preference, and speculative backups (barrier tasks only —
        a streaming attempt owns a live event cursor and cannot be raced).
        """
        if not self.workers:
            raise RuntimeError("scheduler has no workers")
        specs = list(specs)
        if len({s.task_id for s in specs}) != len(specs):
            raise ValueError("duplicate task ids in DAG")
        results: Dict[str, TaskResult] = {}
        attempts_used: Dict[str, int] = {s.task_id: 0 for s in specs}
        durations: List[float] = []
        live: Dict[Future, _Attempt] = {}

        lock = threading.Lock()
        published: set = set(initial_tokens)
        missing: Dict[str, set] = {
            s.task_id: set(s.deps) - published for s in specs
        }
        waiters: Dict[str, List[str]] = {}
        for s in specs:
            for dep in s.deps:
                waiters.setdefault(dep, []).append(s.task_id)
        #: task_id -> event queue of the live streaming attempt.
        stream_queues: Dict[str, "queue.Queue[str]"] = {}
        spec_by_id = {s.task_id: s for s in specs}
        stop_event = threading.Event()

        def publish(token: str) -> None:
            with lock:
                if token in published:
                    return
                published.add(token)
                for tid in waiters.get(token, ()):
                    missing[tid].discard(token)
                for tid, q in stream_queues.items():
                    listens = spec_by_id[tid].listens
                    if listens is not None and listens(token):
                        q.put(token)

        unsubscribes = [sub(publish) for sub in subscribers]

        pending: List[TaskSpec] = list(specs)
        # Compute slots (producers/barrier tasks) and overlap slots
        # (streaming consumers) — one of each per worker, so pipelined
        # consumers can never starve producers: no self-deadlock.
        with self._lock:
            run_workers = list(self.workers)
        free: List[str] = list(run_workers)
        overlap_free: List[str] = list(run_workers)
        pool, own_pool = self._acquire_pool(2 * max(1, len(run_workers)))

        def runnable() -> List[TaskSpec]:
            with lock:
                return [s for s in pending if not missing[s.task_id]]

        def launch(spec: TaskSpec, speculative: bool) -> None:
            slots = overlap_free if spec.streaming else free
            worker = next((w for w in spec.preferred if w in slots), None)
            if worker is None and slots:
                worker = slots[0]
            if worker is None:
                return
            slots.remove(worker)
            attempts_used[spec.task_id] += 1
            events = None
            if spec.streaming:
                events = queue.Queue()
                with lock:
                    # Prime with everything already published so a late
                    # launch (or a retry) never misses data tokens.
                    if spec.listens is not None:
                        for tok in published:
                            if spec.listens(tok):
                                events.put(tok)
                    stream_queues[spec.task_id] = events
            ctx = TaskContext(
                worker=worker, publish=publish, events=events,
                stopped=stop_event,
            )
            fut = pool.submit(spec.run, ctx)
            live[fut] = _Attempt(spec, worker, fut, time.perf_counter(), speculative)

        try:
            while len(results) < len(specs):
                # Launch every ready task a slot can take; one pass over
                # the ready snapshot per round (tokens published by these
                # launches are picked up next tick).
                progressed = True
                while progressed:
                    progressed = False
                    for spec in runnable():  # insertion order: producers first
                        slots = overlap_free if spec.streaming else free
                        if not slots:
                            continue
                        pending.remove(spec)
                        launch(spec, speculative=False)
                        progressed = True
                if not live:
                    stuck = {
                        s.task_id: sorted(missing[s.task_id])
                        for s in pending
                    }
                    raise TaskFailedError(
                        f"DAG stalled: no running tasks, waiting on {stuck}"
                        if stuck else
                        "tasks failed permanently: "
                        f"{[s for s in attempts_used if s not in results]}"
                    )
                done, _ = wait(live.keys(), timeout=0.01, return_when=FIRST_COMPLETED)
                now = time.perf_counter()
                for fut in done:
                    att = live.pop(fut)
                    spec: TaskSpec = att.task
                    tid = spec.task_id
                    (overlap_free if spec.streaming else free).append(att.worker)
                    with lock:
                        if stream_queues.get(tid) is not None and not any(
                            a.task.task_id == tid for a in live.values()
                        ):
                            stream_queues.pop(tid, None)
                    if tid in results:
                        continue  # a sibling attempt already won
                    err = fut.exception()
                    dur = now - att.started
                    if err is None:
                        durations.append(dur)
                        res = TaskResult(
                            tid, fut.result(), att.worker,
                            attempts_used[tid], att.speculative, dur,
                            started=att.started, ended=now,
                        )
                        if spec.on_complete is not None:
                            # Runs before the task token publishes, so a
                            # journal commit is durable before dependents
                            # can observe completion.
                            spec.on_complete(res)
                        results[tid] = res
                        publish(task_token(tid))
                        for tok in spec.produces:
                            publish(tok)
                    else:
                        if getattr(err, "non_retryable", False):
                            raise err
                        still_running = any(
                            a.task.task_id == tid for a in live.values()
                        )
                        if attempts_used[tid] < self.max_attempts:
                            pending.append(spec)  # retry
                        elif not still_running:
                            raise TaskFailedError(
                                f"task {tid} failed after "
                                f"{attempts_used[tid]} attempts"
                            ) from err
                # Speculation: back up slow barrier-task outliers.  Gate on
                # "nothing launchable is waiting" (pending tasks blocked on
                # unmet deps — e.g. wave-mode reducers — must not suppress
                # backups for straggler producers).
                with lock:
                    launchable_waiting = any(
                        not missing[s.task_id] for s in pending
                    )
                if (
                    self.speculation_factor is not None
                    and durations
                    and free
                    and not launchable_waiting
                ):
                    median = sorted(durations)[len(durations) // 2]
                    threshold = max(
                        self.min_speculation_seconds,
                        median * self.speculation_factor,
                    )
                    running_tids = [a.task.task_id for a in live.values()]
                    for att in list(live.values()):
                        if not free:
                            break
                        spec = att.task
                        tid = spec.task_id
                        if (
                            not spec.streaming
                            and spec.speculatable
                            and now - att.started > threshold
                            and running_tids.count(tid) == 1
                            and attempts_used[tid] < self.max_attempts
                        ):
                            launch(spec, speculative=True)
            return results
        finally:
            stop_event.set()
            for unsub in unsubscribes:
                unsub()
            if own_pool:
                pool.shutdown(wait=False, cancel_futures=True)
