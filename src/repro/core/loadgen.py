"""Trace-driven load generation: seeded workloads + an SLO replay harness.

Every benchmark before this module drove *fixed* offered load — the warm
pool, admission control, and the cluster membership machinery had never
seen the diurnal, bursty, multi-tenant traffic the paper's "millions of
users" framing implies.  This module closes that gap in two halves:

* :func:`generate_trace` draws a deterministic arrival trace from a
  :class:`TraceSpec`: Poisson arrivals (thinning against the peak rate)
  under a diurnal sine envelope, multiplicative :class:`BurstSpec`
  episodes, Zipf-skewed tenants and sessions, and a pluggable op mix of
  :class:`OpSpec` entries.  Same seed, same trace — byte for byte.
* :func:`replay` fires a trace open-loop at a ``submit`` callable (the
  :class:`~repro.api.MarvelClient` façade, single-node or sharded) and
  records per-tenant completion latencies, sheds, and backpressure
  stalls.  The returned :class:`ReplayResult` computes the SLO metrics
  the harness gates on: windowed ``p99_under_slo_frac`` (a shed counts
  as an infinite-latency sample, so a window that rejects >1% of its
  arrivals fails its p99), ``goodput_frac``, and the tenant-isolation
  ratio (did tenant A's burst move everyone else's p99?).

The replay loop is single-threaded and *pumps* an optional ``tick``
callback between dispatches — the autoscaler's control loop runs off
that pump (see :mod:`repro.core.autoscale`), so a replayed experiment
stays deterministic in structure even though wall-clock latencies vary.
"""

from __future__ import annotations

import math
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.gateway import AdmissionError

__all__ = [
    "Arrival",
    "BurstSpec",
    "IsolationReport",
    "OpSpec",
    "ReplayResult",
    "TenantSeries",
    "TraceSpec",
    "generate_trace",
    "rate_at",
    "replay",
]


# ---------------------------------------------------------------------------
# Trace specification
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class OpSpec:
    """One entry in the op mix: a function name, call kwargs, a weight."""

    fn: str
    weight: float = 1.0
    inputs: Tuple[Tuple[str, Any], ...] = ()

    def kwargs(self) -> Dict[str, Any]:
        return dict(self.inputs)


@dataclass(frozen=True)
class BurstSpec:
    """A burst episode: multiply one tenant's (or everyone's) rate.

    ``factor`` is the total multiplier while the episode is active — a
    ``factor=4.0`` burst is the issue's "4x burst".  ``tenant=None``
    bursts the whole trace.
    """

    start: float
    duration: float
    factor: float
    tenant: Optional[str] = None

    def active(self, t: float) -> bool:
        return self.start <= t < self.start + self.duration

    @property
    def end(self) -> float:
        return self.start + self.duration


@dataclass(frozen=True)
class TraceSpec:
    """Seeded description of a workload trace.

    ``base_rate`` is the aggregate arrival rate (1/s) at envelope mean.
    Tenant ``i`` gets weight ``(i + 1) ** -zipf_skew`` (normalised);
    sessions within a tenant are skewed the same way by
    ``session_skew``.  The diurnal envelope is
    ``1 + amplitude * sin(2 * pi * t / period)``.
    """

    seed: int = 0
    duration: float = 10.0
    base_rate: float = 100.0
    tenants: int = 4
    sessions_per_tenant: int = 8
    zipf_skew: float = 0.8
    session_skew: float = 0.6
    amplitude: float = 0.25
    period: float = 60.0
    bursts: Tuple[BurstSpec, ...] = ()
    ops: Tuple[OpSpec, ...] = (OpSpec("noop"),)

    def tenant_names(self) -> List[str]:
        return [f"t{i}" for i in range(self.tenants)]

    def tenant_weights(self) -> List[float]:
        raw = [(i + 1) ** -self.zipf_skew for i in range(self.tenants)]
        total = sum(raw)
        return [w / total for w in raw]

    def session_weights(self) -> List[float]:
        raw = [(i + 1) ** -self.session_skew for i in range(self.sessions_per_tenant)]
        total = sum(raw)
        return [w / total for w in raw]


@dataclass(frozen=True)
class Arrival:
    """One trace event: at virtual time ``t``, tenant/session calls op."""

    t: float
    tenant: str
    session: str
    op: OpSpec


# ---------------------------------------------------------------------------
# Generation (Poisson thinning)
# ---------------------------------------------------------------------------


def _envelope(spec: TraceSpec, t: float) -> float:
    return 1.0 + spec.amplitude * math.sin(2.0 * math.pi * t / spec.period)


def _burst_factor(spec: TraceSpec, tenant: str, t: float) -> float:
    factor = 1.0
    for burst in spec.bursts:
        if burst.active(t) and burst.tenant in (None, tenant):
            factor *= burst.factor
    return factor


def rate_at(spec: TraceSpec, t: float, tenant: Optional[str] = None) -> float:
    """Instantaneous arrival rate (1/s) at virtual time ``t``.

    With ``tenant`` set, the rate of that tenant alone; otherwise the
    aggregate over all tenants.  Exposed for tests: the empirical rate
    of a generated trace must track this function.
    """
    env = _envelope(spec, t)
    names = spec.tenant_names()
    weights = spec.tenant_weights()
    if tenant is not None:
        idx = names.index(tenant)
        return spec.base_rate * env * weights[idx] * _burst_factor(spec, tenant, t)
    return sum(
        spec.base_rate * env * w * _burst_factor(spec, name, t)
        for name, w in zip(names, weights)
    )


def _peak_rate(spec: TraceSpec) -> float:
    """A safe upper bound on :func:`rate_at` for thinning."""
    factor = 1.0
    for burst in spec.bursts:
        factor *= max(1.0, burst.factor)
    return spec.base_rate * (1.0 + abs(spec.amplitude)) * factor


def generate_trace(spec: TraceSpec) -> List[Arrival]:
    """Draw the arrival list for ``spec`` — deterministic in the seed.

    Homogeneous Poisson at the peak rate, thinned to the instantaneous
    rate; each accepted arrival then samples its tenant proportional to
    ``weight * burst_factor(t)``, its session by the session skew, and
    its op by the op-mix weights.
    """
    import random

    rng = random.Random(spec.seed)
    names = spec.tenant_names()
    weights = spec.tenant_weights()
    session_weights = spec.session_weights()
    session_ids = list(range(spec.sessions_per_tenant))
    ops = list(spec.ops)
    op_weights = [op.weight for op in ops]
    lam_max = _peak_rate(spec)
    arrivals: List[Arrival] = []
    t = 0.0
    while True:
        t += rng.expovariate(lam_max)
        if t >= spec.duration:
            break
        tenant_rates = [
            w * _burst_factor(spec, name, t) for name, w in zip(names, weights)
        ]
        lam_t = spec.base_rate * _envelope(spec, t) * sum(tenant_rates)
        if rng.random() * lam_max > lam_t:
            continue
        tenant = rng.choices(names, weights=tenant_rates)[0]
        session = f"s{rng.choices(session_ids, weights=session_weights)[0]}"
        op = rng.choices(ops, weights=op_weights)[0] if len(ops) > 1 else ops[0]
        arrivals.append(Arrival(t=t, tenant=tenant, session=session, op=op))
    return arrivals


# ---------------------------------------------------------------------------
# Replay results
# ---------------------------------------------------------------------------


def _pct(sorted_vals: Sequence[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, int(q * len(sorted_vals)))
    return sorted_vals[idx]


@dataclass
class TenantSeries:
    """Per-tenant replay record: counts plus timestamped samples."""

    tenant: str
    offered: int = 0
    completed: int = 0
    shed: int = 0
    backpressured: int = 0
    errors: int = 0
    latencies: List[Tuple[float, float]] = field(default_factory=list)
    shed_t: List[float] = field(default_factory=list)
    error_t: List[float] = field(default_factory=list)


@dataclass
class IsolationReport:
    """Did a burst on one tenant move the *other* tenants' p99?"""

    burst_tenant: str
    burst_p99_ms: float
    calm_p99_ms: float

    @property
    def ratio(self) -> float:
        if self.calm_p99_ms <= 0.0:
            return 1.0 if self.burst_p99_ms <= 0.0 else float("inf")
        return self.burst_p99_ms / self.calm_p99_ms


@dataclass
class ReplayResult:
    """Everything :func:`replay` measured, plus the SLO math over it.

    Latency samples are keyed by the *virtual* arrival time of their
    request, so windowed metrics line up with the trace's bursts no
    matter how long the wall-clock replay took.
    """

    spec: TraceSpec
    slo_ms: float
    window_s: float
    wall_s: float = 0.0
    tenants: Dict[str, TenantSeries] = field(default_factory=dict)

    # -- totals ---------------------------------------------------------

    def _sum(self, attr: str) -> int:
        return sum(getattr(ts, attr) for ts in self.tenants.values())

    @property
    def offered(self) -> int:
        return self._sum("offered")

    @property
    def completed(self) -> int:
        return self._sum("completed")

    @property
    def shed(self) -> int:
        return self._sum("shed")

    @property
    def backpressured(self) -> int:
        return self._sum("backpressured")

    @property
    def errors(self) -> int:
        return self._sum("errors")

    # -- windowed SLO ---------------------------------------------------

    def _series(self, tenant: Optional[str]) -> List[TenantSeries]:
        if tenant is None:
            return list(self.tenants.values())
        return [self.tenants[tenant]] if tenant in self.tenants else []

    def _window_samples(self, tenant: Optional[str]) -> Dict[int, List[float]]:
        """Latency ms per window; sheds/errors land as ``inf`` samples."""
        out: Dict[int, List[float]] = {}
        for ts in self._series(tenant):
            for t, lat in ts.latencies:
                out.setdefault(int(t / self.window_s), []).append(lat * 1e3)
            for t in ts.shed_t:
                out.setdefault(int(t / self.window_s), []).append(float("inf"))
            for t in ts.error_t:
                out.setdefault(int(t / self.window_s), []).append(float("inf"))
        return out

    def window_p99_ms(self, tenant: Optional[str] = None) -> Dict[int, float]:
        return {
            w: _pct(sorted(vals), 0.99)
            for w, vals in sorted(self._window_samples(tenant).items())
        }

    def p99_under_slo_frac(self, tenant: Optional[str] = None) -> float:
        """Fraction of non-empty windows whose p99 meets the SLO."""
        per_window = self.window_p99_ms(tenant)
        if not per_window:
            return 0.0
        ok = sum(1 for p99 in per_window.values() if p99 <= self.slo_ms)
        return ok / len(per_window)

    def p99_ms(
        self,
        tenant: Optional[str] = None,
        t0: float = 0.0,
        t1: float = float("inf"),
    ) -> float:
        vals = [
            lat * 1e3
            for ts in self._series(tenant)
            for t, lat in ts.latencies
            if t0 <= t < t1
        ]
        vals.sort()
        return _pct(vals, 0.99)

    def goodput_frac(self, tenant: Optional[str] = None) -> float:
        """Completions within SLO over everything offered."""
        offered = sum(ts.offered for ts in self._series(tenant))
        if offered == 0:
            return 1.0
        good = sum(
            1
            for ts in self._series(tenant)
            for _t, lat in ts.latencies
            if lat * 1e3 <= self.slo_ms
        )
        return good / offered

    # -- isolation ------------------------------------------------------

    def isolation(self, burst_tenant: Optional[str] = None) -> IsolationReport:
        """p99 of the *other* tenants during vs outside burst episodes."""
        bursts = [b for b in self.spec.bursts if b.tenant is not None]
        if burst_tenant is None and bursts:
            burst_tenant = bursts[0].tenant
        if burst_tenant is None:
            return IsolationReport("", 0.0, 0.0)
        episodes = [
            (b.start, b.end) for b in bursts if b.tenant in (None, burst_tenant)
        ]
        burst_ms: List[float] = []
        calm_ms: List[float] = []
        for name, ts in self.tenants.items():
            if name == burst_tenant:
                continue
            for t, lat in ts.latencies:
                in_burst = any(lo <= t < hi for lo, hi in episodes)
                (burst_ms if in_burst else calm_ms).append(lat * 1e3)
        burst_ms.sort()
        calm_ms.sort()
        return IsolationReport(
            burst_tenant=burst_tenant,
            burst_p99_ms=_pct(burst_ms, 0.99),
            calm_p99_ms=_pct(calm_ms, 0.99),
        )

    # -- export ---------------------------------------------------------

    def series_dict(self) -> Dict[str, Any]:
        """JSON-able per-tenant series for the nightly artifact."""
        return {
            "slo_ms": self.slo_ms,
            "window_s": self.window_s,
            "wall_s": round(self.wall_s, 3),
            "trace": {
                "seed": self.spec.seed,
                "duration": self.spec.duration,
                "base_rate": self.spec.base_rate,
                "tenants": self.spec.tenants,
            },
            "tenants": {
                name: {
                    "offered": ts.offered,
                    "completed": ts.completed,
                    "shed": ts.shed,
                    "backpressured": ts.backpressured,
                    "errors": ts.errors,
                    "latency_ms": [
                        [round(t, 4), round(lat * 1e3, 3)] for t, lat in ts.latencies
                    ],
                    "shed_t": [round(t, 4) for t in ts.shed_t],
                }
                for name, ts in sorted(self.tenants.items())
            },
        }


# ---------------------------------------------------------------------------
# Replay
# ---------------------------------------------------------------------------


def replay(
    submit: Callable[..., Any],
    trace: Sequence[Arrival],
    *,
    spec: Optional[TraceSpec] = None,
    slo_ms: float = 100.0,
    window_s: float = 0.5,
    admission: str = "shed",
    tick: Optional[Callable[[float], None]] = None,
    tick_interval: float = 0.05,
    retry_workers: int = 4,
    retry_timeout: float = 10.0,
    drain_timeout: float = 120.0,
) -> ReplayResult:
    """Fire ``trace`` open-loop at ``submit`` and measure the fallout.

    ``submit`` must have the :meth:`repro.api.MarvelClient.submit`
    shape: ``submit(fn, app=..., session=..., block=..., **inputs)``
    returning a future.  ``admission="shed"`` counts every
    :class:`AdmissionError` as a shed request; ``admission="block"``
    instead hands rejected requests to a small retry pool that
    re-submits with ``block=True`` (counted as *backpressured*; a retry
    that still fails within ``retry_timeout`` degrades to a shed).

    ``tick`` is pumped with the current virtual time roughly every
    ``tick_interval`` seconds while the replay runs — wire the
    autoscaler's ``maybe_tick`` here.
    """
    if admission not in ("shed", "block"):
        raise ValueError(f"unknown admission policy: {admission!r}")
    if spec is None:
        spec = TraceSpec(duration=trace[-1].t if trace else 0.0)
    result = ReplayResult(spec=spec, slo_ms=slo_ms, window_s=window_s)
    for arr in trace:
        result.tenants.setdefault(arr.tenant, TenantSeries(arr.tenant))
    lock = threading.Lock()
    outstanding = [0]
    pool = None
    if admission == "block":
        pool = ThreadPoolExecutor(max_workers=retry_workers)

    def _finish(ts: TenantSeries, arr: Arrival, started: float, fut: Any) -> None:
        latency = time.perf_counter() - started
        with lock:
            try:
                fut.result()
            except AdmissionError:
                ts.shed += 1
                ts.shed_t.append(arr.t)
            except BaseException:
                ts.errors += 1
                ts.error_t.append(arr.t)
            else:
                ts.completed += 1
                ts.latencies.append((arr.t, latency))
            outstanding[0] -= 1

    def _retry(ts: TenantSeries, arr: Arrival, started: float) -> None:
        try:
            fut = submit(
                arr.op.fn,
                app=arr.tenant,
                session=arr.session,
                block=True,
                timeout=retry_timeout,
                **arr.op.kwargs(),
            )
            fut.result()
        except BaseException as exc:
            with lock:
                if isinstance(exc, AdmissionError):
                    ts.shed += 1
                    ts.shed_t.append(arr.t)
                else:
                    ts.errors += 1
                    ts.error_t.append(arr.t)
                outstanding[0] -= 1
                done.notify_all()
            return
        latency = time.perf_counter() - started
        with lock:
            ts.completed += 1
            ts.latencies.append((arr.t, latency))
            outstanding[0] -= 1

    t0 = time.perf_counter()
    next_tick = tick_interval
    i = 0
    n = len(trace)
    while i < n:
        now = time.perf_counter() - t0
        if tick is not None and now >= next_tick:
            tick(now)
            next_tick += tick_interval
        arr = trace[i]
        if arr.t > now:
            horizon = min(arr.t, next_tick) if tick is not None else arr.t
            delay = horizon - now
            if delay > 0:
                time.sleep(min(delay, 0.02))
            continue
        i += 1
        ts = result.tenants[arr.tenant]
        started = time.perf_counter()
        with lock:
            ts.offered += 1
            outstanding[0] += 1
        try:
            fut = submit(
                arr.op.fn,
                app=arr.tenant,
                session=arr.session,
                block=False,
                **arr.op.kwargs(),
            )
        except AdmissionError:
            if pool is not None:
                with lock:
                    ts.backpressured += 1
                pool.submit(_retry, ts, arr, started)
            else:
                with lock:
                    ts.shed += 1
                    ts.shed_t.append(arr.t)
                    outstanding[0] -= 1
        except BaseException:
            with lock:
                ts.errors += 1
                ts.error_t.append(arr.t)
                outstanding[0] -= 1
        else:
            fut.add_done_callback(
                lambda f, ts=ts, arr=arr, started=started: _finish(
                    ts, arr, started, f
                )
            )
    deadline = time.perf_counter() + drain_timeout
    while time.perf_counter() < deadline:
        with lock:
            if outstanding[0] == 0:
                break
        now = time.perf_counter() - t0
        if tick is not None and now >= next_tick:
            tick(now)
            next_tick += tick_interval
        time.sleep(0.005)
    if pool is not None:
        pool.shutdown(wait=True)
    result.wall_s = time.perf_counter() - t0
    return result
