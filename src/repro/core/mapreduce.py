"""MapReduce engine with pluggable intermediate-state tier.

This is the faithful reproduction of the paper's measured system: the same
job runs with its shuffle (intermediate) data living in

  * ``DramTier``                     — Marvel w/ IGFS (best curve, Fig. 4-6),
  * ``PmemTier`` / sim PMEM          — Marvel w/ PMEM-HDFS,
  * ``SimulatedTier(SSD_SPEC)``      — local-SSD baseline,
  * ``SimulatedTier(S3_SPEC)``       — Corral/Lambda baseline (slow, and
                                       trips the 15 GB quota → job failure).

Input/output live in a :class:`BlockStore` (HDFS analog).  Mappers are
scheduled with block locality; intermediate partitions are content-keyed so
retried/speculative attempts are idempotent.  Job progress (which tasks
committed) is journaled in a :class:`StateCache`, so a crashed job resumes
without redoing finished work — the stateful-execution contribution.

Record model: inputs are newline-separated byte records; ``mapper(record)``
yields ``(key, value)`` pairs; ``reducer(key, values)`` yields output pairs.
A ``combiner`` (defaults to the reducer for associative reductions) runs
map-side to cut shuffle volume.
"""

from __future__ import annotations

import io
import json
import pickle
import struct
import time
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, Iterator, List, Optional, Sequence, Tuple

from repro.core.scheduler import Scheduler, Task
from repro.storage.blockstore import BlockStore
from repro.storage.kvcache import StateCache
from repro.storage.tiers import Tier

__all__ = ["MapReduceJob", "JobReport", "run_job"]

KV = Tuple[Any, Any]


@dataclass
class MapReduceJob:
    name: str
    mapper: Callable[[bytes], Iterable[KV]]
    reducer: Callable[[Any, List[Any]], Iterable[KV]]
    combiner: Optional[Callable[[Any, List[Any]], Iterable[KV]]] = None
    n_reducers: int = 4


@dataclass
class JobReport:
    job: str
    input_bytes: int = 0
    intermediate_bytes: int = 0
    output_bytes: int = 0
    map_tasks: int = 0
    reduce_tasks: int = 0
    wall_seconds: float = 0.0
    #: modeled device seconds accumulated in the intermediate tier
    modeled_io_seconds: float = 0.0
    speculative_wins: int = 0
    retried_tasks: int = 0
    resumed_tasks: int = 0

    @property
    def total_seconds(self) -> float:
        """Wall time plus the modeled (not-slept) device time."""
        return self.wall_seconds + self.modeled_io_seconds


# -- intermediate partition encoding (grouped kv runs) -------------------------

def _encode_pairs(pairs: List[KV]) -> bytes:
    payload = pickle.dumps(pairs, protocol=pickle.HIGHEST_PROTOCOL)
    return struct.pack("<Q", len(payload)) + payload


def _decode_pairs(blob: bytes) -> List[KV]:
    (n,) = struct.unpack_from("<Q", blob, 0)
    return pickle.loads(blob[8 : 8 + n])


def _group(pairs: Iterable[KV]) -> Dict[Any, List[Any]]:
    groups: Dict[Any, List[Any]] = defaultdict(list)
    for k, v in pairs:
        groups[k].append(v)
    return groups


def _partition(key: Any, n: int) -> int:
    # Stable across processes (hash() is salted for str/bytes).
    if isinstance(key, bytes):
        h = int.from_bytes(key[:8].ljust(8, b"\0"), "little") ^ len(key)
    elif isinstance(key, str):
        return _partition(key.encode(), n)
    else:
        h = int(key)
    return h % n


# -- engine ---------------------------------------------------------------

def run_job(
    job: MapReduceJob,
    store: BlockStore,
    input_path: str,
    output_path: str,
    intermediate: Tier,
    scheduler: Optional[Scheduler] = None,
    journal: Optional[StateCache] = None,
    fail_map_attempts: Optional[Dict[str, int]] = None,
) -> JobReport:
    """Execute ``job`` end to end.

    ``journal``: if given, map/reduce commits are recorded; re-running the
    same job resumes from the journal (stateful recovery).
    ``fail_map_attempts``: test hook — ``{task_id: n}`` makes the first
    ``n`` attempts of that task raise (exercises retry paths).
    """
    t0 = time.perf_counter()
    report = JobReport(job=job.name)
    blocks = store.locate(input_path)
    report.input_bytes = store.file_meta(input_path).length
    if scheduler is None:
        scheduler = Scheduler(workers=[f"w{i}" for i in range(4)])
    combiner = job.combiner
    jprefix = f"mr/{job.name}"
    io_before = intermediate.stats.modeled_seconds
    fail_budget = dict(fail_map_attempts or {})

    def journal_key(task_id: str) -> str:
        return f"{jprefix}/done/{task_id}"

    def committed(task_id: str) -> bool:
        return journal is not None and journal.contains(journal_key(task_id))

    def commit(task_id: str, meta: dict) -> None:
        if journal is not None:
            journal.put(journal_key(task_id), json.dumps(meta).encode())

    # ---- map wave -----------------------------------------------------------
    def make_map_task(i: int, block_meta) -> Task:
        task_id = f"map_{i:05d}"

        def run(worker: str) -> dict:
            if fail_budget.get(task_id, 0) > 0:
                fail_budget[task_id] -= 1
                raise RuntimeError(f"injected failure in {task_id}")
            data = store.read_block(block_meta, prefer_node=worker)
            pairs: List[KV] = []
            for record in data.split(b"\n"):
                if record:
                    pairs.extend(job.mapper(record))
            if combiner is not None:
                pairs = [
                    kv
                    for k, vs in _group(pairs).items()
                    for kv in combiner(k, vs)
                ]
            parts: Dict[int, List[KV]] = defaultdict(list)
            for k, v in pairs:
                parts[_partition(k, job.n_reducers)].append((k, v))
            sizes = {}
            for p, ppairs in parts.items():
                blob = _encode_pairs(ppairs)
                # Content key includes the map task, so retries overwrite
                # idempotently rather than duplicating.
                intermediate.put(f"{jprefix}/{task_id}/part_{p:04d}", blob)
                sizes[p] = len(blob)
            return {"task": task_id, "sizes": sizes}

        preferred = list(block_meta.replicas)
        return Task(task_id, run, preferred=preferred)

    map_tasks = []
    for i, bm in enumerate(blocks):
        tid = f"map_{i:05d}"
        if committed(tid):
            report.resumed_tasks += 1
            continue
        map_tasks.append(make_map_task(i, bm))
    report.map_tasks = len(blocks)
    if map_tasks:
        map_results = scheduler.run_wave(map_tasks)
        for res in map_results.values():
            commit(res.task_id, res.value)
            report.speculative_wins += int(res.speculative_win)
            report.retried_tasks += int(res.attempts > 1)

    # intermediate volume (authoritative: what's in the tier for this job)
    for key in intermediate.keys():
        if key.startswith(jprefix + "/map_"):
            report.intermediate_bytes += intermediate.size_of(key)

    # ---- reduce wave ----------------------------------------------------------
    def make_reduce_task(p: int) -> Task:
        task_id = f"reduce_{p:04d}"

        def run(worker: str) -> dict:
            pairs: List[KV] = []
            for i in range(len(blocks)):
                key = f"{jprefix}/map_{i:05d}/part_{p:04d}"
                if intermediate.contains(key):
                    pairs.extend(_decode_pairs(intermediate.get(key)))
            out = io.BytesIO()
            groups = _group(pairs)
            for k in sorted(groups.keys(), key=repr):
                for ok, ov in job.reducer(k, groups[k]):
                    out.write(repr(ok).encode() + b"\t" + repr(ov).encode() + b"\n")
            blob = out.getvalue()
            store.write(f"{output_path}/part_{p:04d}", blob)
            return {"task": task_id, "bytes": len(blob)}

        return Task(task_id, run)

    reduce_tasks = []
    for p in range(job.n_reducers):
        tid = f"reduce_{p:04d}"
        if committed(tid):
            report.resumed_tasks += 1
            continue
        reduce_tasks.append(make_reduce_task(p))
    report.reduce_tasks = job.n_reducers
    if reduce_tasks:
        red_results = scheduler.run_wave(reduce_tasks)
        for res in red_results.values():
            commit(res.task_id, res.value)
            report.speculative_wins += int(res.speculative_win)
            report.retried_tasks += int(res.attempts > 1)

    for p in range(job.n_reducers):
        path = f"{output_path}/part_{p:04d}"
        if store.exists(path):
            report.output_bytes += store.file_meta(path).length

    report.wall_seconds = time.perf_counter() - t0
    report.modeled_io_seconds = intermediate.stats.modeled_seconds - io_before
    return report


# -- canonical workloads (paper §4.2, Table 1) --------------------------------

def wordcount_job(n_reducers: int = 4) -> MapReduceJob:
    def mapper(record: bytes) -> Iterator[KV]:
        for w in record.split():
            yield (w, 1)

    def reducer(k: Any, vs: List[Any]) -> Iterator[KV]:
        yield (k, sum(vs))

    return MapReduceJob("wordcount", mapper, reducer, combiner=reducer,
                        n_reducers=n_reducers)


def grep_job(pattern: bytes, n_reducers: int = 4) -> MapReduceJob:
    import re

    rx = re.compile(pattern)

    def mapper(record: bytes) -> Iterator[KV]:
        for w in record.split():
            if rx.search(w):
                yield (w, 1)

    def reducer(k: Any, vs: List[Any]) -> Iterator[KV]:
        yield (k, sum(vs))

    return MapReduceJob("grep", mapper, reducer, combiner=reducer,
                        n_reducers=n_reducers)


def aggregation_job(n_reducers: int = 4) -> MapReduceJob:
    """SUM(value) GROUP BY key over ``key,value`` CSV records."""

    def mapper(record: bytes) -> Iterator[KV]:
        k, _, v = record.partition(b",")
        yield (k, float(v))

    def reducer(k: Any, vs: List[Any]) -> Iterator[KV]:
        yield (k, sum(vs))

    return MapReduceJob("aggregation", mapper, reducer, combiner=reducer,
                        n_reducers=n_reducers)


def scan_job(predicate: Callable[[bytes], bool], n_reducers: int = 4) -> MapReduceJob:
    """SELECT * WHERE predicate — map-heavy, small output."""

    def mapper(record: bytes) -> Iterator[KV]:
        if predicate(record):
            yield (record, b"")

    def reducer(k: Any, vs: List[Any]) -> Iterator[KV]:
        yield (k, len(vs))

    return MapReduceJob("scan", mapper, reducer, n_reducers=n_reducers)


def join_job(n_reducers: int = 4) -> MapReduceJob:
    """Reduce-side equi-join of records tagged ``L,key,val`` / ``R,key,val``.

    Intermediate blowup is the cross-tag copy — matches Table 1's join row
    (intermediate ≈ 4× input).
    """

    def mapper(record: bytes) -> Iterator[KV]:
        tag, _, rest = record.partition(b",")
        k, _, v = rest.partition(b",")
        yield (k, (tag, v))

    def reducer(k: Any, vs: List[Any]) -> Iterator[KV]:
        left = [v for t, v in vs if t == b"L"]
        right = [v for t, v in vs if t == b"R"]
        for lv in left:
            for rv in right:
                yield (k, (lv, rv))

    return MapReduceJob("join", mapper, reducer, n_reducers=n_reducers)
