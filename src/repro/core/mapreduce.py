"""MapReduce front-end: lowers jobs onto the stage-DAG execution engine.

This is the faithful reproduction of the paper's measured system: the same
job runs with its shuffle (intermediate) data living in

  * ``DramTier``                     — Marvel w/ IGFS (best curve, Fig. 4-6),
  * ``PmemTier`` / sim PMEM          — Marvel w/ PMEM-HDFS,
  * ``SimulatedTier(SSD_SPEC)``      — local-SSD baseline,
  * ``SimulatedTier(S3_SPEC)``       — Corral/Lambda baseline (slow, and
                                       trips the 15 GB quota → job failure).

Input/output live in a :class:`BlockStore` (HDFS analog).  Mappers are
scheduled with block locality; intermediate partitions are content-keyed so
retried/speculative attempts are idempotent.  Job progress is journaled at
*partition* granularity in a :class:`StateJournal`, so a crashed job
resumes mid-wave without redoing finished work.

A job is two stages of the DAG (see ``core/dag.py`` and DESIGN.md §4):

  * ``mode="wave"``       — reduce tasks depend on every map-task token:
    the classic barrier.  Byte-identical behaviour to the pre-DAG engine.
  * ``mode="pipelined"``  — map tasks batch-publish their partitions
    (``put_many``) and the tier's watch hook turns each landing blob into
    a dataflow token; *streaming* reduce tasks launch immediately on
    overlap slots and fetch/decode partitions as they commit, so shuffle
    movement overlaps the map tail.  Outputs are bit-identical to wave
    mode: merge order is canonicalized before the final reduce.

Record model: inputs are newline-separated byte records; ``mapper(record)``
yields ``(key, value)`` pairs; ``reducer(key, values)`` yields output pairs.
A ``combiner`` (defaults to the reducer for associative reductions) runs
map-side to cut shuffle volume.
"""

from __future__ import annotations

import hashlib
import io
import math
import pickle
import struct
import time
from collections import defaultdict
from dataclasses import dataclass
from typing import (
    TYPE_CHECKING, Any, Callable, Dict, Iterable, Iterator, List, Optional,
    Sequence, Tuple,
)

from repro.core.dag import StageDag, TaskContext
from repro.core.dataflow import Stage, StageTask, lower_stages

if TYPE_CHECKING:  # annotation only — keeps the import graph acyclic
    from repro.core.device_shuffle import DeviceExec
    from repro.core.gateway import Gateway
from repro.core.journal import StateJournal
from repro.core.scheduler import Scheduler, TaskResult
from repro.storage.blockstore import BlockStore
from repro.storage.kvcache import StateCache
from repro.storage.tiers import Tier

__all__ = ["MapReduceJob", "JobReport", "LoweredJob", "lower_job", "run_job",
           "run_jobs"]

KV = Tuple[Any, Any]


@dataclass
class MapReduceJob:
    name: str
    mapper: Callable[[bytes], Iterable[KV]]
    reducer: Callable[[Any, List[Any]], Iterable[KV]]
    combiner: Optional[Callable[[Any, List[Any]], Iterable[KV]]] = None
    n_reducers: int = 4
    #: Declared reduction semantics.  ``"sum"`` promises the reducer
    #: yields exactly ``(k, sum(vs))`` per key and the sum is
    #: order-independent; device mode then lowers eligible reduce tasks
    #: onto the jitted segment-sum and may reorder over-capacity pairs
    #: through the spill path.  ``None`` (opaque reducer) always runs the
    #: host reducer and partitions with exact-sized device buffers.
    reduce_kind: Optional[str] = None


@dataclass
class JobReport:
    job: str
    input_bytes: int = 0
    intermediate_bytes: int = 0
    output_bytes: int = 0
    map_tasks: int = 0
    reduce_tasks: int = 0
    wall_seconds: float = 0.0
    #: modeled device seconds accumulated in the intermediate tier
    modeled_io_seconds: float = 0.0
    speculative_wins: int = 0
    retried_tasks: int = 0
    resumed_tasks: int = 0
    #: execution mode this report came from ("wave" or "pipelined")
    mode: str = "wave"
    #: seconds of reduce-task runtime overlapped with the map stage
    overlap_seconds: float = 0.0
    #: shuffle partitions consumed by reducers before the map stage ended
    partitions_streamed: int = 0
    #: device execution mode (``device=``) accounting — zeros on host runs
    device_mode: bool = False
    #: pairs whose partition step ran on the Pallas histogram kernel
    device_pairs: int = 0
    #: key groups whose reduce ran as the jitted device segment-sum
    device_groups: int = 0
    #: over-capacity pairs recovered through the spill tier (not dropped)
    device_spilled_pairs: int = 0
    #: reduce tasks that fell back to the host reducer (ineligible sums)
    device_fallback_tasks: int = 0

    @property
    def total_seconds(self) -> float:
        """Wall time plus the modeled (not-slept) device time."""
        return self.wall_seconds + self.modeled_io_seconds


# -- intermediate partition encoding (grouped kv runs) -------------------------

def _encode_pairs(pairs: List[KV]) -> bytes:
    payload = pickle.dumps(pairs, protocol=pickle.HIGHEST_PROTOCOL)
    return struct.pack("<Q", len(payload)) + payload


def _decode_pairs(blob: bytes) -> List[KV]:
    (n,) = struct.unpack_from("<Q", blob, 0)
    return pickle.loads(blob[8 : 8 + n])


def _group(pairs: Iterable[KV]) -> Dict[Any, List[Any]]:
    groups: Dict[Any, List[Any]] = defaultdict(list)
    for k, v in pairs:
        groups[k].append(v)
    return groups


def _partition(key: Any, n: int) -> int:
    # Stable across processes (hash() is salted for str/bytes).
    if isinstance(key, bytes):
        h = int.from_bytes(key[:8].ljust(8, b"\0"), "little") ^ len(key)
    elif isinstance(key, str):
        return _partition(key.encode(), n)
    elif isinstance(key, int):  # includes bool (legacy placement)
        h = key
    else:
        # Composite/float/etc. keys (e.g. a join on tuple keys): fall back
        # to a deterministic digest of the pickled key.  (The old int()
        # coercion collapsed distinct floats onto one partition and raised
        # TypeError for tuples/None.)
        digest = hashlib.blake2b(
            pickle.dumps(key, protocol=pickle.HIGHEST_PROTOCOL), digest_size=8
        ).digest()
        h = int.from_bytes(digest, "little")
    return h % n


def _device_reducible(job: MapReduceJob, groups: Dict[Any, List[Any]]) -> bool:
    """May this reduce task lower onto the device segment-sum?

    Only when the declared reduction is ``"sum"`` over Python ints whose
    exact total provably fits the device's int32 accumulator
    (``max|v| · n_pairs < 2^31`` bounds every partial sum).  Anything
    else — float values (addition-order sensitive), custom reducers,
    possible overflow — falls back to the host reducer, which is
    bit-identical by construction.
    """
    if job.reduce_kind != "sum":
        return False
    total = 0
    vmax = 0
    for vs in groups.values():
        total += len(vs)
        for v in vs:
            if not isinstance(v, int):
                return False
            a = -v if v < 0 else v
            if a > vmax:
                vmax = a
    return vmax * total < 2**31


# -- lowering: MapReduceJob -> 2-stage DAG ------------------------------------

@dataclass
class LoweredJob:
    """A MapReduce job lowered to DAG task specs, plus the hooks and the
    finalizer that turns raw task results into a :class:`JobReport`.

    Several LoweredJobs can be concatenated into one ``run_dag`` call
    (:func:`run_jobs`) so independent jobs share a single worker pool.
    ``prepare`` re-snapshots the wall/IO baselines and must be called
    immediately before the run so a report never includes time spent
    lowering *other* jobs.  (Jobs sharing one intermediate tier each see
    the tier's full modeled delta for the merged run — per-tenant I/O
    attribution needs per-task accounting this model doesn't carry.)
    """

    job: MapReduceJob
    dag: StageDag
    initial_tokens: List[str]
    subscribers: List[Callable]
    prepare: Callable[[], None]
    finalize: Callable[[Dict[str, TaskResult]], JobReport]


def lower_job(
    job: MapReduceJob,
    store: BlockStore,
    input_path: str,
    output_path: str,
    intermediate: Tier,
    journal: Optional[StateCache] = None,
    fail_map_attempts: Optional[Dict[str, int]] = None,
    mode: str = "wave",
    device: Optional["DeviceExec"] = None,
) -> LoweredJob:
    """Lower ``job`` to a 2-stage DAG (map stage, reduce stage).

    With ``device``, the map-side partition step runs on the Pallas
    histogram kernel (:func:`~repro.core.device_shuffle.device_partition`)
    and eligible reduce tasks run as the jitted device segment-sum;
    over-capacity partitions spill through ``intermediate`` instead of
    being dropped.  Output bytes are identical to the host path.
    """
    if mode not in ("wave", "pipelined"):
        raise ValueError(f"unknown mode {mode!r}")
    blocks = store.locate(input_path)
    n_maps = len(blocks)
    combiner = job.combiner
    jprefix = f"mr/{job.name}"
    sj = StateJournal(journal, jprefix) if journal is not None else None
    baseline = {
        "t0": time.perf_counter(),
        "io": intermediate.stats.modeled_seconds,
    }

    def prepare() -> None:
        baseline["t0"] = time.perf_counter()
        baseline["io"] = intermediate.stats.modeled_seconds

    fail_budget = dict(fail_map_attempts or {})
    resumed: List[str] = []

    def spec_id(tid: str) -> str:
        # Task ids are job-namespaced so several jobs can share one DAG
        # run; journal entries keep the bare id (layout-compatible with
        # journals written before the DAG refactor).
        return f"{jprefix}/{tid}"

    def part_key(map_tid: str, p: int) -> str:
        return f"{jprefix}/{map_tid}/part_{p:04d}"

    def commit(res: TaskResult) -> None:
        if sj is not None:
            # journal the durable facts only (not runtime telemetry)
            meta = {k: v for k, v in res.value.items() if k != "fetch_times"}
            tid = meta["task"]
            entries = {tid: meta}
            # Partition-granular commits: a resumed run re-primes the DAG
            # token table from these without touching the data tier.
            # (Dot separator: on PmemTier a '/' would need ``tid`` to be a
            # directory, but the task marker above is already a file.)
            for p in meta.get("sizes", {}):
                entries[f"{tid}.part_{int(p):04d}"] = {
                    "bytes": meta["sizes"][p]
                }
            # Task marker last: a torn batch (crash mid-commit) may leave
            # partitions without their task marker — the resume path then
            # just re-runs the task — but never a marker whose partition
            # entries are missing.
            sj.commit_many_ordered(entries, marker=tid)

    # ---- map stage ----------------------------------------------------------
    map_task_ids = [f"map_{i:05d}" for i in range(n_maps)]

    # One journal read for the whole resume: task entries plus the
    # partition-granular `<tid>.part_NNNN` entries committed alongside
    # them.  Legacy journals (pre-DAG) carry partitions in the task
    # meta's "sizes" instead.
    committed_entries = sj.entries() if sj is not None else {}
    committed_parts: Dict[str, List[int]] = {}
    for entry in committed_entries:
        if ".part_" in entry:
            owner, _, pnum = entry.partition(".part_")
            committed_parts.setdefault(owner, []).append(int(pnum))

    def journaled_parts(tid: str) -> List[int]:
        parts = committed_parts.get(tid)
        if parts is None:
            meta = committed_entries.get(tid, {})
            parts = [int(p) for p in meta.get("sizes", {})]
        return sorted(parts)

    def map_resumable(tid: str) -> bool:
        """Committed *and* every journaled partition blob still present
        (a volatile intermediate tier may have lost them since)."""
        if tid not in committed_entries:
            return False
        return all(
            intermediate.contains(part_key(tid, p))
            for p in journaled_parts(tid)
        )

    def make_map_task(i: int) -> StageTask:
        tid = map_task_ids[i]
        block_meta = blocks[i]

        def run(ctx: TaskContext) -> dict:
            if fail_budget.get(tid, 0) > 0:
                fail_budget[tid] -= 1
                raise RuntimeError(f"injected failure in {tid}")
            data = store.read_block(block_meta, prefer_node=ctx.worker)
            pairs: List[KV] = []
            for record in data.split(b"\n"):
                if record:
                    pairs.extend(job.mapper(record))
            if combiner is not None:
                pairs = [
                    kv
                    for k, vs in _group(pairs).items()
                    for kv in combiner(k, vs)
                ]
            parts: Dict[int, List[KV]] = defaultdict(list)
            if device is not None and pairs:
                from repro.core import device_shuffle as _ds

                dest = [_partition(k, job.n_reducers) for k, _ in pairs]
                # Capacity-bounded buffers (with tier spill for overflow)
                # are only byte-safe when the reduction is an integer sum:
                # spill appends reorder pairs within a partition.
                cap = None
                if job.reduce_kind == "sum" and all(
                    isinstance(v, int) for _, v in pairs
                ):
                    cap = max(1, math.ceil(
                        device.capacity_factor * len(pairs) / job.n_reducers
                    ))
                idx_parts, overflow = _ds.device_partition(
                    dest, job.n_reducers, capacity=cap,
                    interpret=device.interpret,
                )
                for p, idxs in enumerate(idx_parts):
                    if len(idxs):
                        parts[p] = [pairs[i] for i in idxs]
                if len(overflow):
                    # Over-capacity pairs take the slow path: one real
                    # round-trip through the intermediate tier (the spill
                    # cost), then merge back into their partitions.
                    skey = f"{jprefix}/{tid}/spill"
                    intermediate.put(skey, _encode_pairs(
                        [(dest[i], pairs[i]) for i in overflow]
                    ))
                    for d, kv in _decode_pairs(intermediate.get(skey)):
                        parts[d].append(kv)
                    device.account(spilled_pairs=len(overflow))
                device.account(
                    partitioned_pairs=len(pairs), device_tasks=1
                )
            else:
                for k, v in pairs:
                    parts[_partition(k, job.n_reducers)].append((k, v))
            blobs = {
                part_key(tid, p): _encode_pairs(ppairs)
                for p, ppairs in sorted(parts.items())
            }
            # Batched publish: one modeled request for the whole task's
            # shuffle output; the tier watch turns each landing partition
            # into a token for streaming reducers.
            if blobs:
                intermediate.put_many(blobs)
            return {
                "task": tid,
                "sizes": {p: len(blobs[part_key(tid, p)]) for p in parts},
            }

        return StageTask(
            spec_id(tid), run,
            preferred=list(block_meta.replicas), on_complete=commit,
        )

    map_tasks: List[StageTask] = []
    for i, tid in enumerate(map_task_ids):
        if map_resumable(tid):
            resumed.append(tid)
            map_tasks.append(StageTask(
                spec_id(tid), resumed=True,
                produces=[part_key(tid, p) for p in journaled_parts(tid)],
            ))
            continue
        map_tasks.append(make_map_task(i))

    # ---- reduce stage ----------------------------------------------------------

    def make_reduce_task(p: int) -> StageTask:
        tid = f"reduce_{p:04d}"
        suffix = f"/part_{p:04d}"

        def write_output(groups: Dict[Any, List[Any]]) -> dict:
            out = io.BytesIO()
            skeys = sorted(groups.keys(), key=repr)
            if device is not None and skeys and _device_reducible(job, groups):
                from repro.core import device_shuffle as _ds

                ids: List[int] = []
                vals: List[int] = []
                for i, k in enumerate(skeys):
                    vs = groups[k]
                    ids.extend([i] * len(vs))
                    vals.extend(vs)
                totals = _ds.device_segment_reduce(ids, vals, len(skeys))
                for i, k in enumerate(skeys):
                    out.write(
                        repr(k).encode() + b"\t"
                        + repr(int(totals[i])).encode() + b"\n"
                    )
                device.account(reduced_groups=len(skeys), device_tasks=1)
            else:
                if device is not None and skeys:
                    device.account(fallback_tasks=1)
                for k in skeys:
                    for ok, ov in job.reducer(k, groups[k]):
                        out.write(repr(ok).encode() + b"\t" + repr(ov).encode() + b"\n")
            blob = out.getvalue()
            store.write(f"{output_path}/part_{p:04d}", blob)
            return {"task": tid, "bytes": len(blob)}

        def run_barrier(ctx: TaskContext) -> dict:
            pairs: List[KV] = []
            for mt in map_task_ids:
                key = part_key(mt, p)
                if intermediate.contains(key):
                    pairs.extend(_decode_pairs(intermediate.get(key)))
            return write_output(_group(pairs))

        def run_streaming(ctx: TaskContext) -> dict:
            # Incremental merge: fetch + decode each partition as its
            # token arrives (overlapping the map tail); the final group +
            # reduce runs over partitions in canonical (map-index) order
            # so output bytes are identical to barrier mode for any
            # reducer, commutative or not.
            fetched: Dict[str, List[KV]] = {}
            done_maps: set = set()
            fetch_times: List[float] = []
            # Data tokens always precede their map's task token (the put
            # happens inside the map run; the token publishes after), so
            # once every map token is seen and the queue is drained, no
            # more data for this job can arrive.
            while len(done_maps) < n_maps or not ctx.events.empty():
                tok = ctx.next_event(timeout=0.02)
                if tok is None:
                    continue
                if tok.startswith("task:"):
                    done_maps.add(tok)
                elif tok not in fetched:
                    fetched[tok] = _decode_pairs(intermediate.get(tok))
                    # Timestamped so finalize can judge overlap against
                    # the map stage's true end, not this queue's order.
                    fetch_times.append(time.perf_counter())
            pairs: List[KV] = []
            for key in sorted(fetched):  # map_%05d: lexicographic == index
                pairs.extend(fetched[key])
            res = write_output(_group(pairs))
            res["fetch_times"] = fetch_times
            return res

        def listens(tok: str) -> bool:
            return (
                tok.startswith(f"task:{jprefix}/map_")
                or (tok.startswith(f"{jprefix}/map_") and tok.endswith(suffix))
            )

        if mode == "wave":
            return StageTask(spec_id(tid), run_barrier, on_complete=commit)
        return StageTask(
            spec_id(tid), run_streaming,
            streaming=True, listens=listens, on_complete=commit,
        )

    reduce_tasks: List[StageTask] = []
    for p in range(job.n_reducers):
        tid = f"reduce_{p:04d}"
        if tid in committed_entries:
            resumed.append(tid)
            reduce_tasks.append(StageTask(spec_id(tid), resumed=True))
            continue
        reduce_tasks.append(make_reduce_task(p))

    # MapReduce is the trivial dataflow: a 2-stage job.  Wave mode is the
    # default stage barrier (reduce after every map — live and resumed
    # alike); pipelined reducers declare no barrier (``after=()``) and
    # stream partitions off the tier watch instead.
    dag = lower_stages(job.name, [
        Stage("map", map_tasks),
        Stage("reduce", reduce_tasks,
              after=None if mode == "wave" else ()),
    ])
    initial_tokens = dag.initial_tokens
    # Only pipelined reducers listen to data tokens; wave mode skips the
    # watch so barrier jobs don't pay a publish per shuffle partition.
    subscribers: List[Callable] = (
        [] if mode == "wave"
        else [lambda publish: intermediate.watch(jprefix + "/", publish)]
    )

    # ---- finalize: raw task results -> JobReport ----------------------------
    def finalize(results: Dict[str, TaskResult]) -> JobReport:
        report = JobReport(job=job.name, mode=mode)
        report.input_bytes = store.file_meta(input_path).length
        report.map_tasks = n_maps
        report.reduce_tasks = job.n_reducers
        report.resumed_tasks = len(resumed)
        own = {
            tid: res for tid, res in results.items()
            if tid.startswith(jprefix + "/")
        }
        for res in own.values():
            report.speculative_wins += int(res.speculative_win)
            report.retried_tasks += int(res.attempts > 1)
        map_results = [
            r for tid, r in own.items()
            if tid.startswith(f"{jprefix}/map_")
        ]
        reduce_results = [
            r for tid, r in own.items()
            if tid.startswith(f"{jprefix}/reduce_")
        ]
        if map_results:
            map_end = max(r.ended for r in map_results)
            for r in reduce_results:
                report.overlap_seconds += max(
                    0.0, min(r.ended, map_end) - r.started
                )
                # A partition "streamed" iff a reducer consumed it before
                # the map stage actually finished.
                report.partitions_streamed += sum(
                    1 for t in r.value.get("fetch_times", ()) if t < map_end
                )
        # intermediate volume (authoritative: what's in the tier for this job)
        for key in intermediate.keys():
            if key.startswith(jprefix + "/map_"):
                report.intermediate_bytes += intermediate.size_of(key)
        for p in range(job.n_reducers):
            path = f"{output_path}/part_{p:04d}"
            if store.exists(path):
                report.output_bytes += store.file_meta(path).length
        report.wall_seconds = time.perf_counter() - baseline["t0"]
        report.modeled_io_seconds = (
            intermediate.stats.modeled_seconds - baseline["io"]
        )
        if device is not None:
            report.device_mode = True
            report.device_pairs = device.partitioned_pairs
            report.device_groups = device.reduced_groups
            report.device_spilled_pairs = device.spilled_pairs
            report.device_fallback_tasks = device.fallback_tasks
        return report

    return LoweredJob(job, dag, initial_tokens, subscribers, prepare, finalize)


# -- engine ---------------------------------------------------------------

def run_job(
    job: MapReduceJob,
    store: BlockStore,
    input_path: str,
    output_path: str,
    intermediate: Tier,
    scheduler: Optional[Scheduler] = None,
    journal: Optional[StateCache] = None,
    fail_map_attempts: Optional[Dict[str, int]] = None,
    mode: str = "wave",
    gateway: Optional["Gateway"] = None,
    adaptive: bool = False,
) -> JobReport:
    """Deprecated entry point — delegate through the :mod:`repro.api`
    façade (same engine, byte-identical outputs).  New code should build
    a :class:`repro.api.MarvelClient` and use ``client.dataset(...)`` or
    ``client.mapreduce(...)``."""
    from repro.api import _legacy_run_job

    return _legacy_run_job(
        job, store, input_path, output_path, intermediate,
        scheduler=scheduler, journal=journal,
        fail_map_attempts=fail_map_attempts, mode=mode, gateway=gateway,
        adaptive=adaptive,
    )


def _run_job_impl(
    job: MapReduceJob,
    store: BlockStore,
    input_path: str,
    output_path: str,
    intermediate: Tier,
    scheduler: Optional[Scheduler] = None,
    journal: Optional[StateCache] = None,
    fail_map_attempts: Optional[Dict[str, int]] = None,
    mode: str = "wave",
    gateway: Optional["Gateway"] = None,
    adaptive: bool = False,
    device: Optional["DeviceExec"] = None,
) -> JobReport:
    """Execute ``job`` end to end (the engine behind the façade).

    ``journal``: if given, map/reduce commits are recorded; re-running the
    same job resumes from the journal (stateful recovery).
    ``fail_map_attempts``: test hook — ``{task_id: n}`` makes the first
    ``n`` attempts of that task raise (exercises retry paths).
    ``mode``: ``"wave"`` (barrier between stages, the paper's measured
    configuration) or ``"pipelined"`` (streaming shuffle).
    ``gateway``: schedule the job on worker slots mirroring the gateway's
    invoker pool (scales with the serving fleet) instead of a dedicated
    scheduler.
    ``device``: a :class:`~repro.core.device_shuffle.DeviceExec` context —
    partition on the Pallas histogram kernel, reduce eligible sums on the
    jitted device segment-sum, spill over-capacity pairs through the
    intermediate tier.  Output bytes are identical to host execution.
    ``adaptive``: front ``intermediate`` with a write-back DRAM level
    (:func:`~repro.storage.hierarchy.adaptive_shuffle_tier`) — map tasks
    ack shuffle output at DRAM latency while the background flusher
    drains to the given tier; the hierarchy is flushed before the report
    is finalized, so durability and journaled resume are unchanged.
    """
    if scheduler is None and gateway is not None:
        scheduler = gateway.shared_scheduler()
    if scheduler is None:
        scheduler = Scheduler(workers=[f"w{i}" for i in range(4)])
    hierarchy = None
    if adaptive:
        from repro.storage.hierarchy import adaptive_shuffle_tier

        hierarchy = adaptive_shuffle_tier(
            intermediate, journal=journal, name=f"mr-{job.name}"
        )
        intermediate = hierarchy
    ok = False
    try:
        lowered = lower_job(
            job, store, input_path, output_path, intermediate,
            journal=journal, fail_map_attempts=fail_map_attempts, mode=mode,
            device=device,
        )
        lowered.prepare()
        results = scheduler.run_dag(
            lowered.dag.specs,
            initial_tokens=lowered.initial_tokens,
            subscribers=lowered.subscribers,
        )
        if hierarchy is not None:
            # Drain outstanding write-backs so the backing tier is
            # complete before the report (the drain wall-time overlaps
            # nothing here, but everything the flusher already moved
            # during the run was free).
            hierarchy.flush()
        report = lowered.finalize(results)
        ok = True
        return report
    finally:
        if hierarchy is not None:
            # On failure, don't retry a (possibly broken) home tier for
            # the flush timeout and mask the real error — acked shuffle
            # data is still replayable from the journal on the next run.
            hierarchy.close(flush=ok)


def run_jobs(
    lowered: Sequence[LoweredJob],
    scheduler: Optional[Scheduler] = None,
    gateway: Optional["Gateway"] = None,
) -> List[JobReport]:
    """Run several lowered jobs over ONE worker pool, interleaved.

    The DAGs are concatenated into a single ``run_dag`` call, so a short
    job's reducers overlap a long job's map tail — multi-tenant serving of
    the shared state tier (DESIGN.md §6).  Passing ``gateway`` runs the
    merged DAG on the gateway's invoker pool (DESIGN.md §5).
    """
    if scheduler is None and gateway is not None:
        scheduler = gateway.shared_scheduler()
    if scheduler is None:
        scheduler = Scheduler(workers=[f"w{i}" for i in range(4)])
    merged = StageDag("multi-job")
    tokens: List[str] = []
    subscribers: List[Callable] = []
    for lj in lowered:
        merged.merge(lj.dag)
        tokens.extend(lj.initial_tokens)
        subscribers.extend(lj.subscribers)
        lj.prepare()
    results = scheduler.run_dag(
        merged.specs, initial_tokens=tokens, subscribers=subscribers
    )
    return [lj.finalize(results) for lj in lowered]


# -- canonical workloads (paper §4.2, Table 1) --------------------------------

def wordcount_job(n_reducers: int = 4) -> MapReduceJob:
    def mapper(record: bytes) -> Iterator[KV]:
        for w in record.split():
            yield (w, 1)

    def reducer(k: Any, vs: List[Any]) -> Iterator[KV]:
        yield (k, sum(vs))

    return MapReduceJob("wordcount", mapper, reducer, combiner=reducer,
                        n_reducers=n_reducers, reduce_kind="sum")


def grep_job(pattern: bytes, n_reducers: int = 4) -> MapReduceJob:
    import re

    rx = re.compile(pattern)

    def mapper(record: bytes) -> Iterator[KV]:
        for w in record.split():
            if rx.search(w):
                yield (w, 1)

    def reducer(k: Any, vs: List[Any]) -> Iterator[KV]:
        yield (k, sum(vs))

    return MapReduceJob("grep", mapper, reducer, combiner=reducer,
                        n_reducers=n_reducers, reduce_kind="sum")


def aggregation_job(n_reducers: int = 4) -> MapReduceJob:
    """SUM(value) GROUP BY key over ``key,value`` CSV records."""

    def mapper(record: bytes) -> Iterator[KV]:
        k, _, v = record.partition(b",")
        yield (k, float(v))

    def reducer(k: Any, vs: List[Any]) -> Iterator[KV]:
        yield (k, sum(vs))

    # Float sums are addition-order sensitive: reduce_kind stays None so
    # device runs keep exact-capacity partitioning + the host reducer.
    return MapReduceJob("aggregation", mapper, reducer, combiner=reducer,
                        n_reducers=n_reducers)


def scan_job(predicate: Callable[[bytes], bool], n_reducers: int = 4) -> MapReduceJob:
    """SELECT * WHERE predicate — map-heavy, small output."""

    def mapper(record: bytes) -> Iterator[KV]:
        if predicate(record):
            yield (record, b"")

    def reducer(k: Any, vs: List[Any]) -> Iterator[KV]:
        yield (k, len(vs))

    return MapReduceJob("scan", mapper, reducer, n_reducers=n_reducers)


def join_job(n_reducers: int = 4) -> MapReduceJob:
    """Reduce-side equi-join of records tagged ``L,key,val`` / ``R,key,val``.

    Intermediate blowup is the cross-tag copy — matches Table 1's join row
    (intermediate ≈ 4× input).
    """

    def mapper(record: bytes) -> Iterator[KV]:
        tag, _, rest = record.partition(b",")
        k, _, v = rest.partition(b",")
        yield (k, (tag, v))

    def reducer(k: Any, vs: List[Any]) -> Iterator[KV]:
        left = [v for t, v in vs if t == b"L"]
        right = [v for t, v in vs if t == b"R"]
        for lv in left:
            for rv in right:
                yield (k, (lv, rv))

    return MapReduceJob("join", mapper, reducer, n_reducers=n_reducers)
