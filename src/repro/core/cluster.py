"""Multi-node cluster: sharded gateways + state over simulated nodes.

The paper's Marvel deployment is a *cluster* — OpenWhisk invokers spread
over machines with a PMEM-backed HDFS underneath (paper §3) — but until
this module everything ran as one process sharing one tier stack.  Here a
:class:`Node` owns a full single-machine Marvel: its own tier hierarchy,
its own :class:`~repro.core.gateway.Gateway` invoker pool, its own
journal cache, and one :class:`~repro.storage.blockstore.DataNode` of the
shared :class:`~repro.storage.blockstore.BlockStore`.

A :class:`ClusterRouter` fronts the nodes:

* **Placement** is consistent hashing (:class:`HashRing`, Cloudburst's
  idiom): sessions and shuffle partitions hash onto a ring of virtual
  nodes, so ``add_node``/``remove_node`` re-home only the moved arc.
* **The network is modeled like a tier.**  :class:`NetworkFabric` charges
  every cross-node byte against a per-link latency/bandwidth model with
  the same :class:`~repro.storage.tiers.TierStats` accounting as the
  storage tiers — ``JobReport`` can roll up network vs storage bytes.
  Links can be partitioned (:class:`LinkPartitionError`), extending the
  storage fault harness to the fabric.
* **Cross-node shuffle** reuses the single-node engine's partition
  function, pair encoding, and output format byte-for-byte: each map runs
  on a replica-local node, ships every partition blob to the partition's
  ring owner over the fabric, and each reduce concatenates blobs in
  map-index order — so cluster output is byte-identical to the
  single-node engine for *any* reducer, commutative or not.
* **Whole-node crash** kills the node's threads and volatile tiers but
  not its PMEM.  ``fail_node`` re-homes the dead node's sessions onto
  survivors by replaying its surviving durable journal (``state/...``
  blobs + ``fn/done/...`` markers) over the fabric, then restores block
  replication — sessions resume byte-identically on their new owner, the
  same contract the single-node crash matrix asserts.

Construction stays in :mod:`repro.api` (``ClusterConfig(sharded=True,
nodes=N)``); this module only defines the machinery.
"""

from __future__ import annotations

import io
import threading
import time
from bisect import bisect_right
from collections import defaultdict
from concurrent.futures import CancelledError, Future, ThreadPoolExecutor
from dataclasses import dataclass
from hashlib import blake2b
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

from repro.core.gateway import Gateway, LoadSnapshot, Session
from repro.core.journal import StateJournal
from repro.core.mapreduce import (
    JobReport,
    MapReduceJob,
    _decode_pairs,
    _encode_pairs,
    _group,
    _partition,
)
from repro.core.stateful import FunctionRuntime, StatefulFunction
from repro.storage.blockstore import BlockMeta, BlockStore, DataNode
from repro.storage.faults import LinkPartitionError
from repro.storage.kvcache import StateCache
from repro.storage.tiers import Tier, TierStats

__all__ = [
    "ClusterRouter",
    "HashRing",
    "LinkSpec",
    "NetworkFabric",
    "Node",
    "NodeDownError",
]


class NodeDownError(RuntimeError):
    """An operation was routed to (or executing on) a dead node."""


# -- the modeled network tier --------------------------------------------------


@dataclass(frozen=True)
class LinkSpec:
    """One point-to-point link's cost model (distinct from storage tiers).

    Defaults model a 10 GbE datacenter link; ``sleep=True`` makes
    transfers really take their modeled time (the scaling benchmark uses
    this so multi-node parallelism shows up in wall clock)."""

    latency: float = 50e-6  # per-transfer setup seconds
    bandwidth: float = 1.25 * 2**30  # bytes/second (~10 GbE)
    sleep: bool = False
    sleep_scale: float = 1.0


class NetworkFabric:
    """All-to-all modeled links between nodes, with per-link accounting.

    Same :class:`TierStats` schema as the storage tiers: a transfer is
    ``write_ops``/``bytes_written`` on the directed ``src->dst`` link and
    its modeled cost is ``latency*ops + nbytes/bandwidth``.  Local
    transfers (``src == dst``) are free — shipping a shuffle partition to
    its own node never charges the fabric, exactly like the single-node
    engine."""

    def __init__(self, spec: Optional[LinkSpec] = None) -> None:
        self.spec = spec or LinkSpec()
        self.total = TierStats()
        self._links: Dict[Tuple[str, str], TierStats] = defaultdict(TierStats)
        self._partitioned: Set[frozenset] = set()
        self._lock = threading.Lock()

    # -- partitions (the fault harness, extended to links) -----------------
    def partition(self, a: str, b: str) -> None:
        """Partition the (symmetric) link between two nodes."""
        with self._lock:
            self._partitioned.add(frozenset((a, b)))

    def heal(self, a: Optional[str] = None, b: Optional[str] = None) -> None:
        """Heal one link, or every link when called with no arguments."""
        with self._lock:
            if a is None:
                self._partitioned.clear()
            else:
                self._partitioned.discard(frozenset((a, b)))

    def is_partitioned(self, a: str, b: str) -> bool:
        with self._lock:
            return frozenset((a, b)) in self._partitioned

    # -- transfers ---------------------------------------------------------
    def transfer(self, src: str, dst: str, nbytes: int, ops: int = 1) -> float:
        """Charge one cross-node transfer; returns the modeled seconds.

        Raises :class:`LinkPartitionError` while the link is partitioned
        (nothing is charged)."""
        if src == dst:
            return 0.0
        if self.is_partitioned(src, dst):
            raise LinkPartitionError(f"link {src}<->{dst} is partitioned")
        spec = self.spec
        modeled = spec.latency * ops + nbytes / spec.bandwidth
        with self._lock:
            for stats in (self._links[(src, dst)], self.total):
                stats.write_ops += ops
                stats.bytes_written += nbytes
                stats.modeled_seconds += modeled
        if spec.sleep and modeled > 0:
            time.sleep(modeled * spec.sleep_scale)
        return modeled

    def stats_by_link(self) -> Dict[str, TierStats]:
        """Per-directed-link counters, keyed ``"src->dst"``."""
        with self._lock:
            return {
                f"{a}->{b}": TierStats().merge(stats)
                for (a, b), stats in sorted(self._links.items())
            }


# -- consistent hashing --------------------------------------------------------

#: Sorts after every real node id at equal hash — makes ``bisect_right``
#: pick the first ring point strictly clockwise of a key's hash.
_MAX_NODE_ID = "\U0010ffff"


class HashRing:
    """Consistent-hash ring with virtual nodes (Cloudburst placement).

    Each node contributes ``vnodes`` points; a key belongs to the first
    point clockwise from its hash.  Adding or removing a node moves only
    the arcs adjacent to that node's points — every other key keeps its
    owner (asserted by the arc-stability property test)."""

    def __init__(self, node_ids: Sequence[str] = (), vnodes: int = 64) -> None:
        self.vnodes = vnodes
        self._points: List[Tuple[int, str]] = []  # sorted (hash, node_id)
        self._nodes: Set[str] = set()
        for nid in node_ids:
            self.add_node(nid)

    @staticmethod
    def _hash(key: str) -> int:
        return int.from_bytes(blake2b(key.encode(), digest_size=8).digest(), "big")

    @property
    def node_ids(self) -> List[str]:
        return sorted(self._nodes)

    def add_node(self, node_id: str) -> None:
        if node_id in self._nodes:
            return
        self._nodes.add(node_id)
        for v in range(self.vnodes):
            self._points.append((self._hash(f"{node_id}#{v}"), node_id))
        self._points.sort()

    def remove_node(self, node_id: str) -> None:
        self._nodes.discard(node_id)
        self._points = [(h, n) for h, n in self._points if n != node_id]

    def owner(self, key: str) -> str:
        if not self._points:
            raise RuntimeError("hash ring is empty (no live nodes)")
        h = self._hash(key)
        i = bisect_right(self._points, (h, _MAX_NODE_ID)) % len(self._points)
        return self._points[i][1]

    def owners(self, key: str, k: int) -> List[str]:
        """The first ``k`` distinct nodes clockwise from ``key`` (replica
        placement order)."""
        if not self._points:
            raise RuntimeError("hash ring is empty (no live nodes)")
        h = self._hash(key)
        start = bisect_right(self._points, (h, _MAX_NODE_ID))
        out: List[str] = []
        for j in range(len(self._points)):
            nid = self._points[(start + j) % len(self._points)][1]
            if nid not in out:
                out.append(nid)
                if len(out) == k:
                    break
        return out


# -- one simulated machine -----------------------------------------------------


class Node:
    """One cluster node: its own tier stack, invoker pool, and journal.

    ``durable`` is the node's PMEM tier — the piece that survives
    :meth:`crash` (DRAM, threads, and task pool all die) and that the
    router replays to re-home the node's sessions."""

    def __init__(
        self,
        node_id: str,
        state: Tier,
        runtime: FunctionRuntime,
        gateway: Gateway,
        datanode: DataNode,
        journal: Optional[StateCache] = None,
        durable: Optional[Tier] = None,
        workers: int = 4,
    ) -> None:
        self.node_id = node_id
        self.state = state
        self.runtime = runtime
        self.gateway = gateway
        self.datanode = datanode
        self.journal = journal
        self.durable = durable
        self.alive = True
        self._pool = ThreadPoolExecutor(
            max_workers=max(1, workers),
            thread_name_prefix=f"{node_id}-task",
        )

    def submit(self, fn: Callable[[], Any]) -> Future:
        """Run a cluster task (map/reduce) on this node's worker pool."""
        if not self.alive:
            raise NodeDownError(self.node_id)
        try:
            return self._pool.submit(fn)
        except RuntimeError as exc:  # pool shut down by a concurrent crash
            raise NodeDownError(self.node_id) from exc

    def _close_state(self, flush: bool) -> None:
        close = getattr(self.state, "close", None)
        if callable(close):
            try:
                close(flush=flush)
            except TypeError:
                close()

    def crash(self) -> None:
        """Whole-node failure: threads and volatile tiers die, PMEM lives."""
        if not self.alive:
            return
        self.alive = False
        self._pool.shutdown(wait=False, cancel_futures=True)
        self.gateway.close(drain=False)
        self.runtime.crash()
        self.runtime.close()
        self._close_state(flush=False)

    def close(self, drain: bool = True) -> None:
        if not self.alive:
            return
        self.alive = False
        self._pool.shutdown(wait=True)
        self.gateway.close(drain=drain)
        self.runtime.close()
        self._close_state(flush=True)


def _modeled_seconds(tier: Tier) -> float:
    by_level = getattr(tier, "stats_by_level", None)
    if callable(by_level):
        return sum(s.modeled_seconds for s in by_level().values())
    stats = getattr(tier, "stats", None)
    return stats.modeled_seconds if stats is not None else 0.0


# -- the router ----------------------------------------------------------------


class ClusterRouter:
    """Routes sessions and dataset jobs to their ring-owning node."""

    def __init__(
        self,
        nodes: Sequence[Node],
        store: BlockStore,
        fabric: Optional[NetworkFabric] = None,
        vnodes: int = 64,
    ) -> None:
        if not nodes:
            raise ValueError("ClusterRouter needs at least one Node")
        self.nodes: Dict[str, Node] = {n.node_id: n for n in nodes}
        self.store = store
        self.fabric = fabric or NetworkFabric()
        self.ring = HashRing([n.node_id for n in nodes], vnodes=vnodes)
        self._functions: List[StatefulFunction] = []
        self._lock = threading.Lock()
        #: node ids that joined after sessions existed: their first touch
        #: of a moved-arc session triggers the lazy migration below.
        self._lazy_migrate: Set[str] = set()
        #: scoped session -> completion event of its (single) migration
        #: check — concurrent first touches wait instead of racing it.
        self._homed: Dict[str, threading.Event] = {}
        #: cumulative lazy-migration accounting (observability).
        self.migrations: Dict[str, int] = {"sessions": 0, "bytes": 0}

    # -- membership --------------------------------------------------------
    def live_nodes(self) -> List[Node]:
        return [n for n in self.nodes.values() if n.alive]

    def add_node(self, node: Node) -> None:
        """Grow the cluster: the new node joins the ring (only its arcs
        re-home), the block store, and gets every registered function.

        Sessions on the moved arcs are **not** shipped eagerly — the ring
        flip makes the new node the only ingest point for them, and the
        first routed touch of each one migrates its committed state and
        journal markers from the previous owner (see
        :meth:`_migrate_session`).  Arc stability bounds the work to the
        new node's share of the key space."""
        with self._lock:
            self.nodes[node.node_id] = node
            self.ring.add_node(node.node_id)
            self.store.add_node(node.datanode)
            for fn in self._functions:
                node.runtime.register(fn)
            self._lazy_migrate.add(node.node_id)
            # ownership changed: every session's homing must be re-checked
            # on its next touch.
            self._homed.clear()

    def remove_node(self, node_id: str) -> Dict[str, Any]:
        """Graceful scale-in (the autoscaler's shrink actuator).

        Refuses (raises ``RuntimeError``) while the node owns in-flight
        or queued invocations — the caller quiesces first; the autoscaler
        only ever nominates idle nodes.  Otherwise: flip the ring (new
        traffic re-homes immediately), drain stragglers admitted in the
        window before the flip, push every committed session/journal key
        to its new ring owner over the fabric, close the node, and
        restore block replication (blocks need a surviving replica —
        ``replication >= 2`` — exactly like :meth:`fail_node`)."""
        node = self.nodes.get(node_id)
        if node is None or not node.alive:
            raise NodeDownError(node_id)
        if len(self.live_nodes()) <= 1:
            raise RuntimeError("cannot remove the last live node")
        snap = node.gateway.load_snapshot()
        if snap.inflight or snap.queue_depth:
            raise RuntimeError(
                f"node {node_id} owns in-flight work (inflight="
                f"{snap.inflight}, queued={snap.queue_depth}); "
                "quiesce before removing it"
            )
        with self._lock:
            self.ring.remove_node(node_id)
            self._lazy_migrate.discard(node_id)
            self._homed.clear()
        # Drain anything admitted between the snapshot and the ring flip,
        # then make every slot's latest state durable in the cache.
        node.gateway.quiesce(timeout=30.0)
        node.runtime.commit_all()
        sessions: Set[str] = set()
        net_bytes = 0
        keys = node.runtime.cache.keys("state/") + node.runtime.cache.keys(
            "fn/done/"
        )
        for key in sorted(keys):
            if key.startswith("state/"):
                scoped = key[len("state/") :].rsplit("/", 1)[0]
            else:
                scoped = key[len("fn/done/") :].rsplit("/", 1)[0]
            target = self.nodes[self.ring.owner(scoped)]
            blob = node.runtime.cache.get(key)
            self.fabric.transfer(node_id, target.node_id, len(blob))
            target.runtime.cache.put(key, blob)
            sessions.add(scoped)
            net_bytes += len(blob)
        node.close(drain=True)
        self.store.fail_node(node.datanode.node_id)
        reblocks = self.re_replicate()
        with self._lock:
            del self.nodes[node_id]
        return {
            "node": node_id,
            "sessions_moved": len(sessions),
            "net_bytes": net_bytes,
            "blocks_rereplicated": reblocks,
        }

    def load_snapshots(self) -> Dict[str, LoadSnapshot]:
        """Per-live-node gateway load observations (the autoscaler poll).
        Each snapshot is the cheap one-stripe-at-a-time read — safe on a
        tight control interval."""
        return {n.node_id: n.gateway.load_snapshot() for n in self.live_nodes()}

    # -- session routing ---------------------------------------------------
    def register(self, fn: StatefulFunction) -> StatefulFunction:
        """Register on every live node — a session may land anywhere."""
        with self._lock:
            self._functions.append(fn)
            for node in self.live_nodes():
                node.runtime.register(fn)
        return fn

    def owner_node(self, session: str = "default", app: str = "default") -> Node:
        scoped = Gateway.scoped_session(app, session)
        node = self.nodes[self.ring.owner(scoped)]
        if not node.alive:
            raise NodeDownError(node.node_id)
        if self._lazy_migrate and node.node_id in self._lazy_migrate:
            self._ensure_homed(scoped, node)
        return node

    def _ensure_homed(self, scoped: str, target: Node) -> None:
        """First-touch homing check for a session owned by a recently
        added node: exactly one caller runs the migration; concurrent
        touches of the same session wait for it instead of racing."""
        with self._lock:
            ev = self._homed.get(scoped)
            if ev is not None:
                owner = False
            else:
                ev = self._homed[scoped] = threading.Event()
                owner = True
        if not owner:
            ev.wait(timeout=30.0)
            return
        try:
            self._migrate_session(scoped, target)
        finally:
            ev.set()

    def _migrate_session(self, scoped: str, target: Node) -> None:
        """Move one session's committed state + journal markers onto its
        new ring owner (the add-node analog of the crash-path
        :meth:`_rehome_from_durable`, but from a *live* previous owner).

        The previous owner's hot slots for the session are committed
        first (under the runtime slot lock, so an in-flight invocation
        that slipped in before the ring flip serializes ahead of the
        move), then the ``state/`` and ``fn/done/`` keys ship over the
        fabric and are deleted at the source — a later crash of the old
        owner cannot resurrect a stale copy."""
        prefixes = (f"state/{scoped}/", f"fn/done/{scoped}/")
        if any(target.runtime.cache.keys(p) for p in prefixes):
            return  # the target already holds this session
        for src in self.live_nodes():
            if src.node_id == target.node_id:
                continue
            for fn_name, sess in list(src.runtime.hot_state):
                if sess == scoped:
                    src.runtime.evict(fn_name, scoped, commit=True)
            keys = [k for p in prefixes for k in src.runtime.cache.keys(p)]
            if not keys:
                continue
            moved = 0
            for key in sorted(keys):
                blob = src.runtime.cache.get(key)
                self.fabric.transfer(src.node_id, target.node_id, len(blob))
                target.runtime.cache.put(key, blob)
                src.runtime.cache.delete(key)
                moved += len(blob)
            with self._lock:
                self.migrations["sessions"] += 1
                self.migrations["bytes"] += moved
            return

    def submit(
        self,
        fn_name: str,
        app: str = "default",
        session: str = "default",
        init_kwargs: Optional[dict] = None,
        block: bool = True,
        timeout: Optional[float] = None,
        **inputs: Any,
    ) -> Future:
        return self.owner_node(session, app).gateway.submit(
            fn_name,
            app=app,
            session=session,
            init_kwargs=init_kwargs,
            block=block,
            timeout=timeout,
            **inputs,
        )

    def invoke(
        self,
        fn_name: str,
        app: str = "default",
        session: str = "default",
        **inputs: Any,
    ) -> Any:
        return self.owner_node(session, app).gateway.invoke(
            fn_name, app=app, session=session, **inputs
        )

    def session(self, session_id: str = "default", app: str = "default") -> Session:
        """A session whose invokes re-resolve the owner on every call, so
        it keeps working across node loss and re-homing."""
        sess = self.owner_node(session_id, app).runtime.session(
            Gateway.scoped_session(app, session_id)
        )

        def route(fn_name: str, **inputs: Any) -> Any:
            return self.invoke(fn_name, app=app, session=session_id, **inputs)

        sess._route = route
        return sess

    # -- node loss + re-homing ---------------------------------------------
    def _node_of_datanode(self, datanode_id: str) -> str:
        for nid, node in self.nodes.items():
            if node.datanode.node_id == datanode_id:
                return nid
        return datanode_id

    def re_replicate(self) -> int:
        """Restore block replication, charging copies to the fabric.

        Partitioned links make their candidate unreachable — the block
        stays under-replicated until the link heals (asserted by the
        partition-tolerance cell of the crash matrix)."""

        def on_copy(src_dn: str, dst_dn: str, nbytes: int) -> None:
            self.fabric.transfer(
                self._node_of_datanode(src_dn),
                self._node_of_datanode(dst_dn),
                nbytes,
            )

        return self.store.re_replicate(on_copy=on_copy)

    def fail_node(self, node_id: str) -> Dict[str, Any]:
        """Whole-node crash: kill it, shrink the ring, replay its PMEM
        journal onto the survivors, and restore block replication.

        Returns a re-homing summary (sessions moved, bytes shipped,
        blocks re-replicated)."""
        node = self.nodes[node_id]
        summary: Dict[str, Any] = {
            "node": node_id,
            "sessions_rehomed": 0,
            "state_keys": 0,
            "journal_keys": 0,
            "net_bytes": 0,
            "blocks_rereplicated": 0,
        }
        if not node.alive:
            return summary
        node.crash()
        with self._lock:
            self.ring.remove_node(node_id)
            self._lazy_migrate.discard(node_id)
            self._homed.clear()
        self.store.fail_node(node.datanode.node_id)
        if not self.live_nodes():
            raise RuntimeError("cluster lost its last node")
        if node.durable is not None:
            summary.update(self._rehome_from_durable(node))
        summary["blocks_rereplicated"] = self.re_replicate()
        return summary

    def _rehome_from_durable(self, dead: Node) -> Dict[str, Any]:
        """Replay the crashed node's surviving PMEM onto the new owners.

        Two key families move: ``state/<session>/<fn>`` committed state
        blobs and ``fn/done/<session>/<fn>`` journal markers.  Both land
        in the new owner's runtime cache (memory + its own PMEM), so the
        next invocation on the survivor resumes the session's sequence
        from the journal scan with byte-identical state."""
        sessions: Set[str] = set()
        state_keys = journal_keys = net_bytes = 0
        for key in sorted(dead.durable.keys()):
            if key.startswith("state/"):
                scoped = key[len("state/") :].rsplit("/", 1)[0]
                state_keys += 1
            elif key.startswith("fn/done/"):
                scoped = key[len("fn/done/") :].rsplit("/", 1)[0]
                journal_keys += 1
            else:
                continue  # job journals re-plan from shuffle-blob presence
            target = self.nodes[self.ring.owner(scoped)]
            blob = dead.durable.get(key)
            self.fabric.transfer(dead.node_id, target.node_id, len(blob))
            target.runtime.cache.put(key, blob)
            sessions.add(scoped)
            net_bytes += len(blob)
        return {
            "sessions_rehomed": len(sessions),
            "state_keys": state_keys,
            "journal_keys": journal_keys,
            "net_bytes": net_bytes,
        }

    # -- cluster MapReduce -------------------------------------------------
    def run_mapreduce(
        self,
        job: MapReduceJob,
        input_path: str,
        output_path: str,
        on_map_done: Optional[Callable[[int], None]] = None,
    ) -> JobReport:
        """Run a job with replica-local maps and ring-owned reduces.

        Byte-identity contract: partitions use the engine's
        ``_partition``/``_encode_pairs``, each reduce concatenates its
        partition blobs in map-index order, and output lines are the
        engine's sorted ``repr(k)\\trepr(v)`` format — so the output file
        bytes equal a single-node run of the same job on the same input.

        ``on_map_done(completed_count)`` fires after each map completes
        and may call :meth:`fail_node` — the driver re-plans: maps whose
        partition blobs died with their owner re-run, reduces re-home to
        the shrunken ring (the kill-one-node-mid-job row of fig11)."""
        t0 = time.perf_counter()
        jprefix = f"mr/{job.name}"
        blocks = self.store.locate(input_path)
        n_maps = len(blocks)
        map_ids = [f"map_{i:05d}" for i in range(n_maps)]
        n_red = job.n_reducers
        modeled0 = {nid: _modeled_seconds(n.state) for nid, n in self.nodes.items()}

        def pkey(tid: str, p: int) -> str:
            return f"{jprefix}/{tid}/part_{p:04d}"

        def part_owner(p: int) -> Node:
            return self.nodes[self.ring.owner(f"{jprefix}/part_{p:04d}")]

        # Completed maps and their per-partition blob sizes.  An entry is
        # only valid while every listed blob is present on the partition's
        # *current* ring owner — node loss invalidates entries, which is
        # exactly the re-plan trigger.
        done: Dict[str, Dict[int, int]] = {}
        exclusions: Dict[str, Set[str]] = defaultdict(set)

        def blobs_present(tid: str, sizes: Dict[int, int]) -> bool:
            return all(
                part_owner(p).alive and part_owner(p).state.contains(pkey(tid, p))
                for p in sizes
            )

        # Cross-run resume: a map journaled on any surviving node whose
        # blobs still sit on the current owners does not re-run.
        for node in self.live_nodes():
            if node.journal is None:
                continue
            for tid, meta in StateJournal(node.journal, jprefix).entries().items():
                if tid not in map_ids or tid in done:
                    continue
                sizes = {int(p): int(s) for p, s in (meta.get("sizes") or {}).items()}
                if blobs_present(tid, sizes):
                    done[tid] = sizes
        resumed = len(done)
        completed = len(done)

        def pick_map_node(block: BlockMeta, excluded: Set[str]) -> Node:
            for dn in block.replicas:
                nid = self._node_of_datanode(dn)
                node = self.nodes.get(nid)
                if node is not None and node.alive and nid not in excluded:
                    return node
            live = [n for n in self.live_nodes() if n.node_id not in excluded]
            if not live:
                live = self.live_nodes()
            if not live:
                raise RuntimeError("no live nodes to run maps")
            return live[HashRing._hash(block.block_id) % len(live)]

        def map_runner(i: int, node: Node) -> Callable[[], Dict[int, int]]:
            tid = map_ids[i]
            block = blocks[i]

            def run() -> Dict[int, int]:
                if not node.alive:
                    raise NodeDownError(node.node_id)
                data = self.store.read_block(block, prefer_node=node.datanode.node_id)
                pairs = []
                for record in data.split(b"\n"):
                    if record:
                        pairs.extend(job.mapper(record))
                if job.combiner is not None:
                    pairs = [
                        kv
                        for k, vs in _group(pairs).items()
                        for kv in job.combiner(k, vs)
                    ]
                parts: Dict[int, list] = defaultdict(list)
                for k, v in pairs:
                    parts[_partition(k, n_red)].append((k, v))
                sizes: Dict[int, int] = {}
                by_owner: Dict[str, Dict[str, bytes]] = defaultdict(dict)
                for p, ppairs in sorted(parts.items()):
                    blob = _encode_pairs(ppairs)
                    sizes[p] = len(blob)
                    by_owner[part_owner(p).node_id][pkey(tid, p)] = blob
                for owner_id in sorted(by_owner):
                    owner = self.nodes[owner_id]
                    if not owner.alive:
                        raise NodeDownError(owner_id)
                    blobs = by_owner[owner_id]
                    # One modeled request per destination node for the
                    # whole task, mirroring the engine's batched put_many.
                    self.fabric.transfer(
                        node.node_id,
                        owner_id,
                        sum(len(b) for b in blobs.values()),
                    )
                    owner.state.put_many(blobs)
                if node.journal is not None:
                    StateJournal(node.journal, jprefix).commit_many_ordered(
                        {
                            **{
                                f"{tid}.part_{p:04d}": {"size": sizes[p]}
                                for p in sorted(sizes)
                            },
                            tid: {"sizes": sizes},
                        },
                        marker=tid,
                    )
                return sizes

            return run

        def run_maps() -> None:
            nonlocal completed
            rounds = 0
            while len(done) < n_maps:
                rounds += 1
                if rounds > 2 * max(2, len(self.nodes)):
                    raise RuntimeError(
                        f"cluster job {job.name}: maps did not converge"
                    )
                futs = []
                for i, tid in enumerate(map_ids):
                    if tid in done:
                        continue
                    try:
                        node = pick_map_node(blocks[i], exclusions[tid])
                        futs.append((tid, node, node.submit(map_runner(i, node))))
                    except NodeDownError:
                        continue
                for tid, node, fut in futs:
                    try:
                        sizes = fut.result()
                    except LinkPartitionError:
                        # Re-route this map around the partitioned link.
                        exclusions[tid].add(node.node_id)
                    except (NodeDownError, CancelledError):
                        continue  # node died mid-round; re-plan next round
                    else:
                        done[tid] = sizes
                        completed += 1
                        if on_map_done is not None:
                            on_map_done(completed)
                # Node loss during the round invalidates blobs that lived
                # on the dead owner: those maps go back in the plan.
                for tid in [t for t, s in done.items() if not blobs_present(t, s)]:
                    del done[tid]

        def reduce_runner(p: int, owner: Node) -> Callable[[], int]:
            def run() -> int:
                if not owner.alive:
                    raise NodeDownError(owner.node_id)
                pairs = []
                for tid in map_ids:  # map-index order: byte-identity
                    key = pkey(tid, p)
                    if owner.state.contains(key):
                        pairs.extend(_decode_pairs(owner.state.get(key)))
                groups = _group(pairs)
                out = io.BytesIO()
                for k in sorted(groups.keys(), key=repr):
                    for ok, ov in job.reducer(k, groups[k]):
                        out.write(repr(ok).encode() + b"\t" + repr(ov).encode() + b"\n")
                blob = out.getvalue()
                self.store.write(f"{output_path}/part_{p:04d}", blob)
                if owner.journal is not None:
                    StateJournal(owner.journal, jprefix).commit(
                        f"reduce_{p:04d}", {"bytes": len(blob)}
                    )
                return len(blob)

            return run

        reduce_done: Dict[int, int] = {}
        for attempt in range(2 * max(2, len(self.nodes))):
            run_maps()
            futs = []
            for p in range(n_red):
                if p in reduce_done:
                    continue
                try:
                    owner = part_owner(p)
                    futs.append((p, owner.submit(reduce_runner(p, owner))))
                except NodeDownError:
                    continue
            for p, fut in futs:
                try:
                    reduce_done[p] = fut.result()
                except (NodeDownError, CancelledError):
                    continue
            if len(reduce_done) == n_red:
                break
            # A reduce owner died: its partition blobs are gone, so some
            # maps are invalid again — loop back through the map plan.
            for tid in [t for t, s in done.items() if not blobs_present(t, s)]:
                del done[tid]
        else:
            raise RuntimeError(f"cluster job {job.name}: reduces did not converge")

        modeled = sum(
            _modeled_seconds(n.state) - modeled0.get(nid, 0.0)
            for nid, n in self.nodes.items()
            if n.alive
        )
        return JobReport(
            job=job.name,
            input_bytes=sum(b.length for b in blocks),
            intermediate_bytes=sum(sum(s.values()) for s in done.values()),
            output_bytes=sum(reduce_done.values()),
            map_tasks=n_maps,
            reduce_tasks=n_red,
            wall_seconds=time.perf_counter() - t0,
            modeled_io_seconds=modeled,
            resumed_tasks=resumed,
            mode="cluster",
        )

    # -- lifecycle ---------------------------------------------------------
    def close(self, drain: bool = True) -> None:
        for node in self.nodes.values():
            node.close(drain=drain)
