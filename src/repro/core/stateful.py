"""Stateful function execution — Marvel's contribution (1), functionally.

OpenWhisk actions are stateless; Marvel makes them stateful by giving every
action access to a shared in-memory state tier (Ignite) keyed by
application/session, with durable spill to PMEM.

JAX jitted functions are pure, so statefulness lives in the *runtime*:

  * a :class:`StatefulFunction` declares named state slots; its pure step
    is ``(state, **inputs) -> (state, outputs)``,
  * the :class:`FunctionRuntime` owns the authoritative state in a
    :class:`StateCache` (DRAM tier, optional PMEM write-through) and keeps
    a device-resident *hot view* so repeated invocations don't round-trip
    through host memory — this is exactly the Ignite-vs-S3 distinction the
    paper measures,
  * sessions namespace state per application instance (a training run, a
    serving conversation, a MapReduce job).

Failure semantics: ``runtime.crash()`` drops device + DRAM state; if the
cache has write-through (the PMEM variant) the session resumes from the
last committed state, otherwise it's lost — reproducing the paper's
argument for persistent-memory-backed state.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax

from repro.core.journal import StateJournal
from repro.storage import serde
from repro.storage.kvcache import StateCache

__all__ = ["StatefulFunction", "FunctionRuntime", "Session", "InvocationRecord"]


@dataclass
class StatefulFunction:
    """A named, stateful serverless function.

    ``step`` must be pure: ``(state, **inputs) -> (new_state, outputs)``.
    ``init`` builds the initial state pytree from kwargs.
    """

    name: str
    step: Callable[..., Tuple[Any, Any]]
    init: Callable[..., Any]
    #: jit the step (disable for host-side functions like MapReduce tasks).
    jit: bool = True
    _compiled: Optional[Callable] = None

    def compiled_step(self) -> Callable:
        if not self.jit:
            return self.step
        if self._compiled is None:
            self._compiled = jax.jit(self.step)
        return self._compiled


@dataclass
class InvocationRecord:
    function: str
    session: str
    #: per-session invocation sequence (recovery replays one session's
    #: invocations in this order; sessions are mutually independent).
    seq: int
    wall_seconds: float
    cold: bool


class Session:
    """Per-application state namespace (an OpenWhisk activation chain).

    Owns the per-session invocation sequence.  After a crash the runtime
    rebuilds a session from the :class:`StateJournal`, resuming ``seq``
    from the last committed invocation so recovery ordering stays
    per-session (not position in the global log).
    """

    def __init__(self, runtime: "FunctionRuntime", session_id: str,
                 seq: int = 0) -> None:
        self.runtime = runtime
        self.session_id = session_id
        self.seq = seq

    def invoke(self, fn_name: str, **inputs: Any) -> Any:
        return self.runtime.invoke(fn_name, session=self.session_id, **inputs)


class FunctionRuntime:
    """Executes stateful functions against the tiered state store.

    ``hot_state`` is the device/process-resident view (no serialization);
    ``cache`` is the authoritative Ignite-analog tier.  ``commit_every``
    controls how often hot state is serialized into the cache (and thus to
    PMEM when the cache has write-through) — the knob trading I/O overhead
    against recovery freshness, which is the paper's central trade.
    """

    def __init__(self, cache: Optional[StateCache] = None, commit_every: int = 1) -> None:
        self.cache = cache if cache is not None else StateCache()
        self.commit_every = max(1, commit_every)
        self.functions: Dict[str, StatefulFunction] = {}
        self.hot_state: Dict[Tuple[str, str], Any] = {}
        self._dirty: Dict[Tuple[str, str], int] = {}
        self.log: list[InvocationRecord] = []
        #: same journal abstraction the MapReduce engine uses — commit
        #: markers ride the cache (durable iff the cache write-throughs).
        self.journal = StateJournal(self.cache, "fn")
        self._sessions: Dict[str, Session] = {}
        #: last *invoked* per-session seq of each (session, fn) — what a
        #: commit of that fn's state actually reflects.
        self._last_seq: Dict[Tuple[str, str], int] = {}

    # -- registry -----------------------------------------------------------
    def register(self, fn: StatefulFunction) -> StatefulFunction:
        self.functions[fn.name] = fn
        return fn

    def function(self, name: str, init: Callable[..., Any], jit: bool = True):
        """Decorator: ``@rt.function("f", init=...)`` over the step fn."""

        def deco(step: Callable[..., Tuple[Any, Any]]) -> StatefulFunction:
            return self.register(StatefulFunction(name, step, init, jit=jit))

        return deco

    # -- sessions -----------------------------------------------------------
    def session(self, session_id: str) -> Session:
        """The per-session namespace; rebuilt from the journal after a
        crash so ``seq`` resumes from the last *committed* invocation."""
        sess = self._sessions.get(session_id)
        if sess is None:
            committed = self.journal.entries(prefix=f"{session_id}/")
            seq = max(
                (m.get("seq", -1) + 1 for m in committed.values()), default=0
            )
            sess = Session(self, session_id, seq=seq)
            self._sessions[session_id] = sess
        return sess

    # -- state plumbing -------------------------------------------------------
    def _state_key(self, fn_name: str, session: str) -> str:
        return f"state/{session}/{fn_name}"

    def _load_state(self, fn: StatefulFunction, session: str, init_kwargs: dict) -> Tuple[Any, bool]:
        hot_key = (fn.name, session)
        if hot_key in self.hot_state:
            return self.hot_state[hot_key], False
        key = self._state_key(fn.name, session)
        if self.cache.contains(key):  # warm-from-cache (recovery or eviction)
            state = serde.loads(self.cache.get(key))
            self.hot_state[hot_key] = state
            return state, False
        state = fn.init(**init_kwargs)  # cold start
        self.hot_state[hot_key] = state
        return state, True

    def commit(self, fn_name: str, session: str) -> None:
        """Serialize hot state into the cache (durable if write-through).

        The state blob and its journal marker (which per-session ``seq``
        the blob reflects) commit together, so recovery knows exactly how
        far each session got.
        """
        hot_key = (fn_name, session)
        state = self.hot_state.get(hot_key)
        if state is None:
            return
        self.cache.put(self._state_key(fn_name, session), serde.dumps(state))
        # Stamp the seq this fn's state actually reflects (its own last
        # invocation) — not the session-wide counter, which may include
        # later invocations of *other* functions whose state is not yet
        # durable.
        last = self._last_seq.get((session, fn_name))
        if last is not None:
            self.journal.commit(f"{session}/{fn_name}", {"seq": last})
        self._dirty[hot_key] = 0

    def commit_all(self) -> None:
        for fn_name, session in list(self.hot_state.keys()):
            self.commit(fn_name, session)

    # -- invoke -----------------------------------------------------------
    def invoke(
        self,
        fn_name: str,
        session: str = "default",
        init_kwargs: Optional[dict] = None,
        **inputs: Any,
    ) -> Any:
        """Invoke a stateful function; state is read/updated transparently."""
        fn = self.functions[fn_name]
        t0 = time.perf_counter()
        sess = self.session(session)
        state, cold = self._load_state(fn, session, init_kwargs or {})
        new_state, outputs = fn.compiled_step()(state, **inputs)
        hot_key = (fn.name, session)
        self.hot_state[hot_key] = new_state
        self._dirty[hot_key] = self._dirty.get(hot_key, 0) + 1
        seq = sess.seq
        sess.seq += 1
        self._last_seq[(session, fn.name)] = seq
        if self._dirty[hot_key] >= self.commit_every:
            self.commit(fn.name, session)
        self.log.append(
            InvocationRecord(fn.name, session, seq, time.perf_counter() - t0, cold)
        )
        return outputs

    def peek_state(self, fn_name: str, session: str = "default") -> Any:
        return self.hot_state.get((fn_name, session))

    # -- failure/recovery -----------------------------------------------------
    def crash(self) -> None:
        """Lose device + DRAM state (node failure). PMEM tier survives."""
        self.hot_state.clear()
        self._dirty.clear()
        self._sessions.clear()  # rebuilt from the journal on next use
        self._last_seq.clear()
        self.cache.crash()

    def recover(self) -> int:
        """Repopulate the DRAM tier from write-through storage."""
        return self.cache.recover()
